//! Scenario tour: list the built-in registry, render a scenario to its
//! file format, then run a miniature sweep (2 scenarios × 2 seeds) on
//! the tiny profile and print where the JSONL traces landed.
//!
//!     make artifacts && cargo run --release --example scenario_sweep
//!
//! The same thing from the CLI:
//!
//!     qccf sweep --scenarios paper-femnist,zipf-skew --seeds 1,2 \
//!                --algorithms qccf --rounds 2 --profile tiny --out /tmp/sweep
//!
//! Scenario file format + every built-in's rationale: docs/SCENARIOS.md.

use anyhow::Result;

use qccf::experiments::sweep;
use qccf::runtime::Runtime;
use qccf::scenario::{self, registry, ScenarioRegistry};

fn main() -> Result<()> {
    qccf::util::logging::init();

    let reg = ScenarioRegistry::builtin();
    println!("built-in scenarios:");
    for sc in reg.all() {
        println!(
            "  {:<16} U={:<5} C={:<3} aps={} dist={:?} algs=[{}]",
            sc.name,
            sc.topology.clients,
            sc.topology.channels,
            sc.topology.aps,
            sc.data.dist,
            sc.train.algorithms.join(",")
        );
    }

    println!("\n`zipf-skew` rendered as a scenario file (edit + --scenario-file to fork it):\n");
    println!("{}", scenario::render(reg.get("zipf-skew").unwrap()));

    let rt = Runtime::load_default("tiny")?;
    println!("PJRT platform: {}   model Z = {}", rt.platform(), rt.info.z);

    // Fresh output dir: sweep never clears --out, and stale traces from
    // an earlier run would sit next to a summary.csv that omits them.
    let out_dir = std::env::temp_dir().join("qccf_scenario_sweep_example");
    std::fs::remove_dir_all(&out_dir).ok();
    let cfg = sweep::SweepConfig {
        scenarios: vec![registry::paper_femnist(), registry::zipf_skew()],
        seeds: vec![1, 2],
        algorithms: Some(vec!["qccf".to_string()]),
        rounds: Some(2),
        out_dir: out_dir.clone(),
        threads: qccf::util::threadpool::default_threads(),
        resume: false,
        checkpoint_every: 0,
    };
    let rows = sweep::run(&rt, &cfg)?;
    sweep::print(&rows);
    println!("traces + summary.csv under {}", out_dir.display());
    println!("(bit-identical for any --threads; each run is deterministic per seed)");
    Ok(())
}
