//! Wireless-substrate playground (no artifacts needed): sample the
//! channel model of §IV-A and show how pathloss, Rician fading and the
//! OFDMA rates translate into the per-client feasibility region —
//! i.e. which quantization levels fit inside T^max (constraint C4).
//!
//!     cargo run --release --example wireless_playground

use qccf::config::SystemParams;
use qccf::energy;
use qccf::solver;
use qccf::util::rng::Rng;
use qccf::util::table;
use qccf::wireless::ChannelModel;

fn main() {
    let params = SystemParams::femnist_small();
    let mut rng = Rng::seed_from(7);
    let model = ChannelModel::new(&params, &mut rng);
    let state = model.draw(&mut rng);

    println!(
        "cell radius {} m, carrier {} GHz, gain {} dB, B = {} MHz, Z = {}\n",
        params.cell_radius_m,
        params.carrier_ghz,
        params.gain_db,
        params.bandwidth_hz / 1e6,
        params.z
    );

    let mut rows = Vec::new();
    for i in 0..params.num_clients {
        let best = state.best_channel(i);
        let rate = state.rate(i, best);
        let d_i = 1200.0;
        let qmax = solver::q_max_feasible(&params, d_i, rate);
        let f_q8 = energy::s_of_q(&params, d_i, 8, rate);
        let energy_q8 = f_q8.map(|f| energy::client_energy(&params, d_i, f, 8, rate));
        rows.push(vec![
            i.to_string(),
            format!("{:.0}", model.distances_m[i]),
            format!("{:.1}", rate / 1e6),
            qmax.map(|q| q.to_string()).unwrap_or_else(|| "infeasible".into()),
            f_q8.map(|f| format!("{f:.2e}")).unwrap_or_else(|| "-".into()),
            energy_q8.map(|e| format!("{e:.4}")).unwrap_or_else(|| "-".into()),
        ]);
    }
    println!(
        "{}",
        table::render(
            &["client", "dist (m)", "best rate (Mb/s)", "q_max (C4)", "f@q=8 (Hz)", "E@q=8 (J)"],
            &rows
        )
    );

    // Rate vs distance curve (mean over fading).
    println!("mean best-channel rate vs distance (10k fading draws):");
    for d in [50.0, 100.0, 200.0, 300.0, 400.0, 500.0] {
        let mut p2 = params.clone();
        p2.num_clients = 1;
        let mut r = Rng::seed_from(13);
        let mut m = ChannelModel::new(&p2, &mut r);
        // Overwrite placement with the probe distance.
        m.distances_m[0] = d;
        m.large_scale[0] = qccf::config::params::db_to_lin(p2.gain_db)
            * qccf::wireless::pathloss_gain(d, p2.carrier_ghz);
        let mut acc = 0.0;
        let n = 10_000;
        for _ in 0..n {
            let st = m.draw(&mut r);
            acc += (0..p2.num_channels).map(|c| st.rate(0, c)).fold(0.0, f64::max);
        }
        println!("  d = {d:>3.0} m  →  {:.1} Mb/s", acc / n as f64 / 1e6);
    }
}
