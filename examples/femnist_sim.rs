//! FEMNIST-sim head-to-head: QCCF vs the four §VI baselines on the same
//! federation and channel statistics (a compact version of Fig. 3).
//!
//!     make artifacts && cargo run --release --example femnist_sim -- [rounds]

use anyhow::Result;

use qccf::baselines::ALL_ALGORITHMS;
use qccf::experiments::{fig3, run_one, RunSpec, Task};
use qccf::runtime::Runtime;

fn main() -> Result<()> {
    qccf::util::logging::init();
    let rounds: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(12);
    let rt = Runtime::load_default("small")?;
    println!("profile `small` (Z = {}), {rounds} rounds, β = 150\n", rt.info.z);

    let mut rows = Vec::new();
    for alg in ALL_ALGORITHMS {
        let mut spec = RunSpec::new(alg, Task::Femnist);
        spec.rounds = rounds;
        spec.seed = 1;
        let trace = run_one(&rt, &spec)?;
        println!(
            "{alg:<18} best acc {:.3}   energy {:>8.4} J   dropouts {}",
            trace.best_accuracy().unwrap_or(f64::NAN),
            trace.total_energy(),
            trace.total_dropouts(),
        );
        rows.push(fig3::summarize(&trace, 150.0));
    }
    println!();
    fig3::print(&rows, "femnist_sim summary");
    Ok(())
}
