//! Quickstart: run QCCF wireless federated learning end to end on the
//! tiny profile (10 clients, synthetic non-IID data, OFDMA channel
//! simulator) and print the per-round accuracy / energy trajectory.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This is the smallest complete tour of the stack: the AOT-compiled
//! JAX/Pallas model executes through PJRT from Rust, the QCCF scheduler
//! (Lyapunov queues → GA channel allocation → closed-form KKT) makes
//! every round's decision, and the wireless/energy models account the
//! cost per paper eqs. (14)–(17).

use anyhow::Result;

use qccf::baselines::make_scheduler_with_threads;
use qccf::data::{self, DataGenConfig};
use qccf::experiments::common::params_for;
use qccf::experiments::Task;
use qccf::fl::Server;
use qccf::runtime::Runtime;

fn main() -> Result<()> {
    qccf::util::logging::init();
    let rt = Runtime::load_default("tiny")?;
    println!("PJRT platform: {}   model Z = {}", rt.platform(), rt.info.z);

    // Table-I parameters adapted to the tiny profile; µ = 300 samples so
    // the latency budget matches the small model (see DESIGN.md §5).
    let params = params_for(&rt, Task::Femnist, 300.0);
    let mut dcfg = DataGenConfig::new(params.num_clients, rt.info.image, rt.info.classes);
    dcfg.size_mean = 300.0;
    dcfg.size_std = 60.0;
    let fed = data::generate(&dcfg, 1);
    println!(
        "federation: {} clients, D_i = {:?}",
        fed.clients.len(),
        fed.sizes().iter().map(|d| *d as usize).collect::<Vec<_>>()
    );

    // Round engine fan-out: scheduled clients train/quantize in
    // parallel; any thread count (including 1) is bit-identical.
    let threads = qccf::util::threadpool::default_threads();
    let sched = make_scheduler_with_threads("qccf", 1, threads).unwrap();
    let mut server = Server::new(params, &rt, fed, sched, 1)?;
    server.eval_every = 2;
    server.threads = threads;
    println!("round engine: {threads} worker thread(s)");

    println!("\nround  sched  aggr  mean_q   energy(J)  cum(J)    acc");
    let mut cum = 0.0;
    for _ in 0..14 {
        let rec = server.run_round()?;
        cum += rec.energy;
        println!(
            "{:>5}  {:>5}  {:>4}  {:>6.2}  {:>9.5}  {:>7.4}  {}",
            rec.round,
            rec.scheduled,
            rec.aggregated,
            rec.mean_q,
            rec.energy,
            cum,
            rec.test_acc.map(|a| format!("{a:.3}")).unwrap_or_else(|| "-".into()),
        );
    }
    println!("\nqueues: λ1 = {:.3}, λ2 = {:.5}", server.queues.lambda1, server.queues.lambda2);
    println!("done — see `qccf fig3` for the full baseline comparison.");
    Ok(())
}
