//! Doubly-adaptive-quantization analysis (a compact Fig. 5): run the
//! quantizing algorithms, then print the two relationships the paper's
//! Remarks 1–2 predict — q rising with the round index, and q negatively
//! correlated with dataset size for the wireless-aware policies.
//!
//!     make artifacts && cargo run --release --example quant_analysis

use anyhow::Result;

use qccf::experiments::fig5;
use qccf::runtime::Runtime;

fn main() -> Result<()> {
    qccf::util::logging::init();
    let rt = Runtime::load_default("small")?;
    let data = fig5::run(&rt, 16, &[1, 2])?;
    fig5::print(&data);

    // Sparkline-ish view of q per round for QCCF.
    if let Some(qccf) = data.iter().find(|d| d.algorithm == "qccf") {
        println!("QCCF mean q per round:");
        let line: Vec<String> =
            qccf.q_by_round.iter().map(|(n, q)| format!("{n}:{q:.1}")).collect();
        println!("  {}", line.join("  "));
    }
    Ok(())
}
