//! Scenario-subsystem integration: registry round-trips through the
//! file format, scenario files load from disk, the fig harnesses
//! reproduce their pre-refactor traces through the scenario path, and
//! `sweep` emits deterministic, schema-valid JSONL for any thread
//! count.
//!
//! Runtime-dependent tests no-op (with a note) when `make artifacts`
//! hasn't run, same as the other integration suites.

use std::path::PathBuf;

use qccf::baselines::make_scheduler_with_threads;
use qccf::data::{self, DataGenConfig};
use qccf::experiments::common::params_for;
use qccf::experiments::{run_one, run_scenario, sweep, RunSpec, Task};
use qccf::fl::Server;
use qccf::metrics::Trace;
use qccf::runtime::{artifacts_dir, Runtime};
use qccf::scenario::{self, registry, ScenarioRegistry};
use qccf::util::json;

fn runtime() -> Option<Runtime> {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::load(&artifacts_dir(), "tiny").expect("load tiny runtime"))
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("qccf_scn_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn registry_roundtrip_parse_render_parse() {
    // parse → render → parse must be the identity for every builtin.
    for sc in ScenarioRegistry::builtin().all() {
        let text = scenario::render(sc);
        let once = scenario::parse_scenario(&text).expect(&sc.name);
        assert_eq!(&once, sc, "{}: parse(render(s)) != s", sc.name);
        let twice = scenario::parse_scenario(&scenario::render(&once)).unwrap();
        assert_eq!(twice, once, "{}: second round-trip diverged", sc.name);
    }
}

#[test]
fn scenario_file_loads_from_disk() {
    let dir = tmp_dir("file");
    let path = dir.join("custom.scn");
    std::fs::write(
        &path,
        "[scenario]\nname = disk-check\nbase = femnist\n\
         [topology]\nclients = 30\nchannels = 10\n\
         [data]\nsize_dist = uniform\nuniform_lo = 200\nuniform_hi = 400\n\
         [train]\nalgorithms = qccf\nrounds = 5\n",
    )
    .unwrap();
    let sc = scenario::load_file(&path).unwrap();
    assert_eq!(sc.name, "disk-check");
    assert_eq!((sc.topology.clients, sc.topology.channels), (30, 10));
    assert_eq!(sc.train.rounds, 5);

    // Invalid files are rejected with the validation message.
    let bad = dir.join("bad.scn");
    std::fs::write(&bad, "[scenario]\nname = broken\n[topology]\nclients = 4\nchannels = 9\n")
        .unwrap();
    let err = scenario::load_file(&bad).unwrap_err();
    assert!(err.contains("channels"), "{err}");
    assert!(scenario::load_file(&dir.join("missing.scn")).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fig_regression_scenario_path_matches_pre_refactor_runner() {
    // The paper-femnist profile through the scenario path must equal
    // the pre-refactor `run_one` (replicated inline below exactly as it
    // was: params_for → DataGenConfig → scheduler(seed*31+7) → Server)
    // — this is the fig2 grid point (qccf, V = 10, seed 7).
    let Some(rt) = runtime() else { return };
    let (seed, rounds, v) = (7u64, 3usize, 10.0);

    let mut params = params_for(&rt, Task::Femnist, 1200.0);
    params.v = v;
    let mut dcfg = DataGenConfig::new(params.num_clients, rt.info.image, rt.info.classes);
    dcfg.size_mean = 1200.0;
    dcfg.size_std = 150.0;
    let fed = data::generate(&dcfg, seed);
    let sched =
        make_scheduler_with_threads("qccf", seed.wrapping_mul(31).wrapping_add(7), 1).unwrap();
    let mut server = Server::new(params, &rt, fed, sched, seed).expect("server");
    server.eval_every = 2;
    server.threads = 1;
    let legacy = server.run(rounds).unwrap();

    let mut spec = RunSpec::new("qccf", Task::Femnist);
    spec.rounds = rounds;
    spec.v = Some(v);
    spec.seed = seed;
    spec.threads = 1;
    let via_scenario = run_one(&rt, &spec).unwrap();

    assert_traces_identical(&legacy, &via_scenario);

    // And the same through an explicit registry scenario.
    let mut sc = registry::paper_femnist();
    sc.train.rounds = rounds;
    sc.train.v = Some(v);
    let via_registry = run_scenario(&rt, &sc, "qccf", seed, 1).unwrap();
    assert_traces_identical(&legacy, &via_registry);
}

fn assert_traces_identical(a: &Trace, b: &Trace) {
    assert_eq!(a.records.len(), b.records.len());
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.scheduled, y.scheduled);
        assert_eq!(x.aggregated, y.aggregated);
        assert_eq!(x.energy.to_bits(), y.energy.to_bits());
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits());
        assert_eq!(x.test_loss, y.test_loss);
        assert_eq!(x.test_acc, y.test_acc);
        assert_eq!(x.mean_q, y.mean_q);
        assert_eq!(x.q_per_client, y.q_per_client);
        assert_eq!(x.lambda1.to_bits(), y.lambda1.to_bits());
        assert_eq!(x.lambda2.to_bits(), y.lambda2.to_bits());
        assert_eq!(x.max_latency.to_bits(), y.max_latency.to_bits());
    }
}

fn sweep_cfg(out_dir: PathBuf, threads: usize) -> sweep::SweepConfig {
    sweep::SweepConfig {
        scenarios: vec![registry::paper_femnist(), registry::zipf_skew()],
        seeds: vec![1, 2],
        algorithms: Some(vec!["qccf".to_string()]),
        rounds: Some(2),
        out_dir,
        threads,
        resume: false,
        checkpoint_every: 0,
    }
}

#[test]
fn sweep_deterministic_across_threads_and_schema_valid() {
    let Some(rt) = runtime() else { return };
    let dir_serial = tmp_dir("sweep1");
    let dir_parallel = tmp_dir("sweep3");
    let rows_serial = sweep::run(&rt, &sweep_cfg(dir_serial.clone(), 1)).unwrap();
    let rows_parallel = sweep::run(&rt, &sweep_cfg(dir_parallel.clone(), 3)).unwrap();

    // One JSONL per (scenario, seed, algorithm) unit + identical rows.
    assert_eq!(rows_serial.len(), 4);
    assert_eq!(rows_parallel.len(), 4);
    for (a, b) in rows_serial.iter().zip(&rows_parallel) {
        assert_eq!((&a.scenario, &a.algorithm, a.seed), (&b.scenario, &b.algorithm, b.seed));
        assert_eq!(a.cum_energy.to_bits(), b.cum_energy.to_bits());
    }

    // Bit-identical output trees for any --threads value.
    let mut names: Vec<String> = std::fs::read_dir(&dir_serial)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    names.sort();
    assert_eq!(
        names.len(),
        7,
        "4 traces + summary.csv + 2 scenario identity sidecars: {names:?}"
    );
    assert!(names.contains(&"summary.csv".to_string()));
    assert!(names.contains(&"paper-femnist__qccf__seed1.jsonl".to_string()));
    assert!(names.contains(&"zipf-skew__qccf__seed2.jsonl".to_string()));
    assert!(names.contains(&"paper-femnist.scenario".to_string()));
    assert!(names.contains(&"zipf-skew.scenario".to_string()));
    for name in &names {
        let a = std::fs::read(dir_serial.join(name)).unwrap();
        let b = std::fs::read(dir_parallel.join(name)).unwrap();
        assert_eq!(a, b, "{name} differs across sweep --threads");
    }

    // Schema check: every JSONL line parses and carries the required
    // keys with consistent meta.
    for name in names.iter().filter(|n| n.ends_with(".jsonl")) {
        let text = std::fs::read_to_string(dir_serial.join(name)).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2, "{name}: expected 2 rounds");
        for (i, line) in lines.iter().enumerate() {
            let v = json::parse(line).unwrap_or_else(|e| panic!("{name} line {i}: {e}"));
            for key in [
                "scenario",
                "algorithm",
                "seed",
                "round",
                "scheduled",
                "aggregated",
                "energy_j",
                "cum_energy_j",
                "mean_q",
                "q_per_client",
                "lambda1",
                "lambda2",
                "max_latency_s",
            ] {
                assert!(v.get(key).is_some(), "{name} line {i}: missing `{key}`");
            }
            assert_eq!(v.get("round").and_then(|x| x.as_usize()), Some(i + 1));
            assert!(name.starts_with(v.get("scenario").unwrap().as_str().unwrap()));
        }
    }
    std::fs::remove_dir_all(&dir_serial).ok();
    std::fs::remove_dir_all(&dir_parallel).ok();
}

#[test]
fn heterogeneity_scenarios_run_end_to_end() {
    // The class-based scenarios must execute through the real engine:
    // deep-fade (channel classes) and cpu-straggler (throttled realized
    // frequency) for 2 rounds each on the tiny profile.
    let Some(rt) = runtime() else { return };
    for name in ["deep-fade", "cpu-straggler"] {
        let mut sc = ScenarioRegistry::builtin().get(name).unwrap().clone();
        sc.train.rounds = 2;
        let trace = run_scenario(&rt, &sc, "qccf", 3, 1)
            .unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert_eq!(trace.records.len(), 2, "{name}");
        let scheduled: usize = trace.records.iter().map(|r| r.scheduled).sum();
        assert!(scheduled > 0, "{name}: nothing scheduled");
        assert!(trace.total_energy() > 0.0 && trace.total_energy().is_finite(), "{name}");
    }
}
