//! Property pins for the fault-injection layer (`fl::faults`) and the
//! retransmission-aware accounting helpers (`fl::exec`):
//!
//! * fault histories are a pure function of `(seed, cfg, client id,
//!   #ticks)` — invariant to the order clients are ticked in (draws
//!   happen before the worker fan-out, so this is exactly the property
//!   that makes chaos runs thread-count invariant), and stable under
//!   fleet growth (client `i`'s stream never depends on `U`, because
//!   streams fork off the salted root in ascending id order);
//! * retransmission energy is monotone non-decreasing in `attempts`
//!   and exactly `0.0` on the first attempt;
//! * the benign draw is a bitwise no-op: `fault_latency`,
//!   `fault_energy`, and `fault_payload_bytes` reproduce the
//!   chaos-disabled `realized_latency` / `realized_energy` /
//!   single-shot payload IEEE-exactly, and an all-zero-rate
//!   [`FaultCfg`] draws benign forever — which is what pins
//!   fault-rate-0 runs bit-identical to a chaos-disabled engine.

use qccf::config::SystemParams;
use qccf::fl::exec::{
    fault_energy, fault_latency, fault_payload_bytes, realized_energy, realized_latency,
    retry_energy, STRAGGLE_FACTOR,
};
use qccf::fl::faults::{FaultCfg, FaultDraw, FaultPlan};
use qccf::quant::wire;
use qccf::sched::ClientDecision;
use qccf::util::prop;
use qccf::util::rng::Rng;

#[derive(Debug)]
struct ChaosCase {
    u: usize,
    cfg: FaultCfg,
    seed: u64,
    rounds: usize,
    /// Seed for the per-round tick permutations of run B.
    order_seed: u64,
}

fn chaos_case(rng: &mut Rng) -> ChaosCase {
    ChaosCase {
        u: 2 + rng.below(48),
        cfg: FaultCfg {
            p_decode: rng.range(0.0, 1.0),
            p_straggle: rng.range(0.0, 1.0),
            p_panic: rng.range(0.0, 1.0),
            retries: rng.below(5) as u32,
            p_ckpt: rng.range(0.0, 1.0),
        },
        seed: rng.next_u64(),
        rounds: 1 + rng.below(20),
        order_seed: rng.next_u64(),
    }
}

#[test]
fn fault_history_invariant_to_tick_order() {
    prop::check("faults-tick-order", prop::iters(40), chaos_case, |cs| {
        let mut a = FaultPlan::new(cs.u, cs.cfg, cs.seed);
        let mut b = FaultPlan::new(cs.u, cs.cfg, cs.seed);
        let mut order: Vec<usize> = (0..cs.u).collect();
        let mut orng = Rng::seed_from(cs.order_seed);
        for round in 0..cs.rounds {
            a.tick();
            // A fresh random permutation every round: each tick touches
            // exactly one private stream, so any order must land on the
            // same draws.
            orng.shuffle(&mut order);
            for &i in &order {
                b.tick_one(i);
            }
            if a.draws() != b.draws() {
                return Err(format!("round {round}: draws diverged under permuted ticks"));
            }
            // The plan-level checkpoint stream is independent of every
            // client stream — interleaving snapshot draws must agree
            // and must not perturb the client futures.
            if a.draw_ckpt_corrupt() != b.draw_ckpt_corrupt() {
                return Err(format!("round {round}: ckpt-corruption draw diverged"));
            }
        }
        a.tick();
        b.tick();
        if a.draws() != b.draws() {
            return Err("post-history tick diverged — stream state corrupted".into());
        }
        Ok(())
    });
}

#[test]
fn fault_history_pure_function_of_seed_and_client_id() {
    prop::check("faults-replay", prop::iters(30), chaos_case, |cs| {
        let run = |u: usize, ticks: usize| -> Vec<Vec<FaultDraw>> {
            let mut p = FaultPlan::new(u, cs.cfg, cs.seed);
            (0..ticks)
                .map(|_| {
                    p.tick();
                    p.draws().to_vec()
                })
                .collect()
        };
        if run(cs.u, cs.rounds) != run(cs.u, cs.rounds) {
            return Err("same (seed, U, cfg, #ticks) produced different histories".into());
        }
        // Fleet growth leaves existing clients' streams untouched:
        // client i's stream is a function of (seed, i), not of U.
        let small = run(cs.u, cs.rounds);
        let big = run(cs.u + 1 + cs.u / 2, cs.rounds);
        for (round, (s, b)) in small.iter().zip(&big).enumerate() {
            if s[..] != b[..cs.u] {
                return Err(format!("round {round}: growing the fleet rewrote client draws"));
            }
        }
        Ok(())
    });
}

#[derive(Debug)]
struct DecisionCase {
    size: f64,
    d: ClientDecision,
    cpu_scale: f64,
    budget: u32,
}

fn decision_case(rng: &mut Rng) -> DecisionCase {
    DecisionCase {
        size: rng.range(50.0, 5000.0),
        d: ClientDecision {
            channel: rng.below(16),
            q: if rng.chance(0.85) { Some(1 + rng.below(14) as u32) } else { None },
            f: rng.range(1e8, 2e9),
            rate: rng.range(1e4, 4e7),
        },
        cpu_scale: rng.range(0.25, 1.0),
        budget: 1 + rng.below(6) as u32,
    }
}

#[test]
fn retry_energy_monotone_and_free_on_first_attempt() {
    prop::check("retry-energy-monotone", prop::iters(120), decision_case, |cs| {
        let p = SystemParams::femnist_small();
        // The first transmission is part of the base eq. (5) cost —
        // retransmission airtime starts at attempt two, exactly.
        if retry_energy(&p, &cs.d, 0) != 0.0 || retry_energy(&p, &cs.d, 1) != 0.0 {
            return Err("retry_energy non-zero without a retry".into());
        }
        let mut prev = 0.0f64;
        for attempts in 1..=(1 + cs.budget) {
            let e = retry_energy(&p, &cs.d, attempts);
            if !e.is_finite() || e < prev {
                return Err(format!("attempts {attempts}: retry energy {e} < prior {prev}"));
            }
            prev = e;
        }
        // Each extra attempt strictly adds airtime at a finite rate.
        if retry_energy(&p, &cs.d, 2) <= 0.0 {
            return Err("a retry charged no airtime energy".into());
        }
        Ok(())
    });
}

#[test]
fn benign_draws_are_bitwise_noops() {
    prop::check("benign-noop", prop::iters(120), decision_case, |cs| {
        let p = SystemParams::femnist_small();
        let benign = FaultDraw::benign();
        let lat = realized_latency(&p, cs.size, &cs.d, cs.cpu_scale);
        let flat = fault_latency(&p, cs.size, &cs.d, cs.cpu_scale, &benign);
        if lat.to_bits() != flat.to_bits() {
            return Err(format!("benign latency diverged: {lat} vs {flat}"));
        }
        let en = realized_energy(&p, cs.size, &cs.d, cs.cpu_scale);
        let fen = fault_energy(&p, cs.size, &cs.d, cs.cpu_scale, &benign);
        if en.to_bits() != fen.to_bits() {
            return Err(format!("benign energy diverged: {en} vs {fen}"));
        }
        let single = match cs.d.q {
            Some(q) => wire::encoded_len(p.z, q),
            None => (p.raw_payload_bits() as usize + 7) / 8,
        };
        if fault_payload_bytes(&p, &cs.d, &benign) != single {
            return Err("benign draw changed the wire byte count".into());
        }
        // Non-benign draws move in the right direction: a straggle
        // stretches latency, retries multiply the payload.
        let faulty = FaultDraw { straggle: true, panic: false, attempts: 3, decoded: false };
        if !(STRAGGLE_FACTOR > 1.0) {
            return Err("straggle factor must stretch compute".into());
        }
        if fault_latency(&p, cs.size, &cs.d, cs.cpu_scale, &faulty) <= lat {
            return Err("straggle + retries failed to stretch latency".into());
        }
        if fault_payload_bytes(&p, &cs.d, &faulty) != 3 * single {
            return Err("3 attempts should put 3 payloads on the wire".into());
        }
        Ok(())
    });
}

#[test]
fn zero_rate_cfg_draws_benign_forever() {
    prop::check("fault-rate-zero-pin", prop::iters(30), chaos_case, |cs| {
        let cfg = FaultCfg {
            p_decode: 0.0,
            p_straggle: 0.0,
            p_panic: 0.0,
            retries: cs.cfg.retries,
            p_ckpt: 0.0,
        };
        let mut plan = FaultPlan::new(cs.u, cfg, cs.seed);
        for round in 0..cs.rounds {
            plan.tick();
            if plan.draws().iter().any(|d| *d != FaultDraw::benign()) {
                return Err(format!("round {round}: zero-rate cfg drew a fault"));
            }
            if plan.draw_ckpt_corrupt() {
                return Err(format!("round {round}: zero-rate cfg corrupted a snapshot"));
            }
        }
        Ok(())
    });
}
