//! Golden-file pin for the `report` aggregator: a hand-built sweep
//! directory (summary.csv + ledger.jsonl + sketch sidecars, fixed
//! numbers throughout) must render to exactly the committed
//! `tests/golden/report_tiny.txt` — byte for byte. The report is a
//! pure function of its on-disk inputs, so any formatting or
//! aggregation change shows up as a readable diff here instead of as
//! silent drift in `verify.sh` logs.

use std::collections::BTreeMap;

use qccf::metrics::{RoundRecord, Trace};
use qccf::obs::ledger::{self, LedgerEntry};
use qccf::obs::report;
use qccf::obs::sketch::{self, TraceSketches};
use qccf::obs::spans::{Span, SpanTotals};

/// A trace whose only meaningful payload is the per-round energy
/// sequence (the golden directory's sketch sidecars are derived from
/// these).
fn trace_with_energies(energies: &[f64]) -> Trace {
    let mut t = Trace::new("qccf");
    for (i, &e) in energies.iter().enumerate() {
        t.push(RoundRecord {
            round: i + 1,
            energy: e,
            max_latency: 0.5,
            wire_bytes: 1000,
            q_per_client: vec![Some(4)],
            ..Default::default()
        });
    }
    t
}

/// A ledger entry with fixed, exactly-representable span seconds so the
/// JSON round trip and the rendered quantiles are bit-stable.
fn unit_entry(seed: u64, decide: f64, execute: f64, unit: f64) -> LedgerEntry {
    let mut spans = SpanTotals::default();
    spans.secs[Span::Decide.index()] = decide;
    spans.calls[Span::Decide.index()] = 2;
    spans.secs[Span::Execute.index()] = execute;
    spans.calls[Span::Execute.index()] = 2;
    spans.secs[Span::SweepUnit.index()] = unit;
    spans.calls[Span::SweepUnit.index()] = 1;
    LedgerEntry {
        kind: "sweep-unit".into(),
        scenario: "alpha".into(),
        algorithm: "qccf".into(),
        seed,
        rounds: 2,
        status: "ok".into(),
        wall_secs: unit,
        threads: 1,
        spans,
        sketch_digests: BTreeMap::new(),
        git: "fixed".into(),
    }
}

#[test]
fn report_renders_exactly_the_golden_bytes() {
    let dir = std::env::temp_dir().join("qccf_golden_report");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();

    // summary.csv exactly as `sweep` would write it: two ok units of
    // scenario `alpha`, one failed unit of `beta` (NaN metric cells,
    // like a failed row's).
    let summary = "\
scenario,algorithm,seed,rounds,final_acc,best_acc,cum_energy_j,wire_bytes,dropouts,scheduled,aggregated,departed,retries,energy_p50_j,energy_p95_j,status,trace_file\n\
alpha,qccf,1,2,0.500000,0.600000,3.000000000,1000,1,10,9,0,2,1.250000000,2.500000000,ok,alpha__qccf__seed1.jsonl\n\
alpha,qccf,2,2,0.550000,0.650000,12.000000000,2000,0,10,10,0,1,5.000000000,10.000000000,ok,alpha__qccf__seed2.jsonl\n\
beta,qccf,1,0,NaN,NaN,0.000000000,0,0,0,0,0,0,NaN,NaN,failed,beta__qccf__seed1.jsonl\n";
    std::fs::write(dir.join("summary.csv"), summary).unwrap();

    // Ledger: one line per ok unit, spans chosen so totals and
    // percentiles are exact dyadic values.
    ledger::append(&dir, &unit_entry(1, 0.5, 1.0, 2.0)).unwrap();
    ledger::append(&dir, &unit_entry(2, 0.75, 1.25, 2.5)).unwrap();

    // Sketch sidecars next to where the traces would be: energies
    // {1,2} and {4,8} J, merged by the report into {1,2,4,8}.
    TraceSketches::from_trace(&trace_with_energies(&[1.0, 2.0]))
        .save(&sketch::sidecar_path(&dir.join("alpha__qccf__seed1.jsonl")))
        .unwrap();
    TraceSketches::from_trace(&trace_with_energies(&[4.0, 8.0]))
        .save(&sketch::sidecar_path(&dir.join("alpha__qccf__seed2.jsonl")))
        .unwrap();

    let got = report::render(&dir, None, None).unwrap();
    let want = include_str!("golden/report_tiny.txt");
    if got != want {
        // Line-by-line diff for a readable failure.
        for (i, (g, w)) in got.lines().zip(want.lines()).enumerate() {
            assert_eq!(g, w, "report line {} diverges from the golden file", i + 1);
        }
        assert_eq!(
            got.lines().count(),
            want.lines().count(),
            "report line count diverges from the golden file"
        );
        panic!("report differs from golden only in trailing whitespace/newlines");
    }
    std::fs::remove_dir_all(&dir).ok();
}
