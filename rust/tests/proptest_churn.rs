//! Property pins for the availability layer (`fl::avail`) and the
//! churn-era aggregation plumbing (`fl::exec`):
//!
//! * availability histories are a pure function of `(seed, U, cfg,
//!   #ticks)` — invariant to the order clients are ticked in (the
//!   engine never ticks inside the worker fan-out, so this is exactly
//!   the property that makes churn draws thread-count invariant; the
//!   full-engine `--threads {1,8}` pin lives in
//!   `integration_churn.rs`);
//! * over-selection never aggregates more than the
//!   `ceil(S/(1+β))` target, and keeps survivors in ascending order;
//! * staleness-scaled fold weights are finite, non-negative, zero for
//!   non-survivors, and renormalize to 1;
//! * `p_leave = 0` pins the churn path to the always-available engine:
//!   the mask stays all-true forever, and an all-true mask is
//!   bit-identical to no mask at every decision entry point.

use qccf::config::SystemParams;
use qccf::fl::avail::{aggregation_target, AvailCfg, AvailProcess};
use qccf::fl::exec::{apply_aggregation_cap, survivor_weights};
use qccf::lyapunov::Queues;
use qccf::sched::{evaluate_allocation, greedy_allocation, EvalCtx, RoundInputs};
use qccf::solver::Case5Mode;
use qccf::util::prop;
use qccf::util::rng::Rng;
use qccf::wireless::ChannelState;

#[derive(Debug)]
struct ChurnCase {
    u: usize,
    p_join: f64,
    p_leave: f64,
    seed: u64,
    rounds: usize,
    /// Seed for the per-round tick permutations of run B.
    order_seed: u64,
}

fn churn_case(rng: &mut Rng) -> ChurnCase {
    ChurnCase {
        u: 2 + rng.below(60),
        p_join: rng.range(0.0, 1.0),
        p_leave: rng.range(0.0, 1.0),
        seed: rng.next_u64(),
        rounds: 1 + rng.below(25),
        order_seed: rng.next_u64(),
    }
}

#[test]
fn avail_history_invariant_to_tick_order() {
    prop::check("avail-tick-order", prop::iters(40), churn_case, |cs| {
        let cfg = AvailCfg { p_join: cs.p_join, p_leave: cs.p_leave, ..AvailCfg::default() };
        let mut a = AvailProcess::new(cs.u, cfg, cs.seed);
        let mut b = AvailProcess::new(cs.u, cfg, cs.seed);
        let mut order: Vec<usize> = (0..cs.u).collect();
        let mut orng = Rng::seed_from(cs.order_seed);
        for round in 0..cs.rounds {
            a.tick();
            // A fresh random permutation every round: each tick touches
            // exactly one private stream, so any order must land on the
            // same state.
            orng.shuffle(&mut order);
            for &i in &order {
                b.tick_one(i);
            }
            if a.mask() != b.mask() {
                return Err(format!("round {round}: masks diverged under permuted ticks"));
            }
        }
        // The streams themselves (not just the flags) must agree: the
        // futures stay identical after the permuted history.
        a.tick();
        b.tick();
        if a.mask() != b.mask() {
            return Err("post-history tick diverged — stream state corrupted".into());
        }
        Ok(())
    });
}

#[test]
fn avail_history_is_a_pure_function_of_seed() {
    prop::check("avail-replay", prop::iters(30), churn_case, |cs| {
        let cfg = AvailCfg { p_join: cs.p_join, p_leave: cs.p_leave, ..AvailCfg::default() };
        let run = |ticks: usize| -> Vec<Vec<bool>> {
            let mut p = AvailProcess::new(cs.u, cfg, cs.seed);
            (0..ticks)
                .map(|_| {
                    p.tick();
                    p.mask().to_vec()
                })
                .collect()
        };
        if run(cs.rounds) != run(cs.rounds) {
            return Err("same (seed, U, cfg, #ticks) produced different histories".into());
        }
        Ok(())
    });
}

#[derive(Debug)]
struct CapCase {
    survive: Vec<bool>,
    beta: f64,
}

fn cap_case(rng: &mut Rng) -> CapCase {
    let s = rng.below(50);
    CapCase {
        survive: (0..s).map(|_| rng.chance(0.6)).collect(),
        beta: rng.range(0.0, 3.0),
    }
}

#[test]
fn over_selection_never_aggregates_more_than_target() {
    prop::check("over-selection-cap", prop::iters(120), cap_case, |cs| {
        let scheduled = cs.survive.len();
        let n = aggregation_target(scheduled, cs.beta);
        if scheduled > 0 && !(1..=scheduled).contains(&n) {
            return Err(format!("target {n} outside 1..={scheduled}"));
        }
        let mut capped = cs.survive.clone();
        let kept = apply_aggregation_cap(&mut capped, n);
        let survivors = cs.survive.iter().filter(|&&s| s).count();
        if kept != survivors.min(n) {
            return Err(format!("kept {kept}, want min({survivors}, {n})"));
        }
        if capped.iter().filter(|&&s| s).count() != kept {
            return Err("flag count != reported kept".into());
        }
        // The kept survivors are exactly the *first* `kept` survivors in
        // ascending task order — over-selection demotes from the tail.
        let mut seen = 0usize;
        for (i, (&orig, &now)) in cs.survive.iter().zip(&capped).enumerate() {
            if now && !orig {
                return Err(format!("slot {i}: cap promoted a non-survivor"));
            }
            if orig {
                let should_keep = seen < n;
                seen += 1;
                if now != should_keep {
                    return Err(format!("slot {i}: cap is not a prefix of survivors"));
                }
            }
        }
        Ok(())
    });
}

#[derive(Debug)]
struct WeightCase {
    sizes: Vec<f64>,
    missed: Vec<u64>,
    survive: Vec<bool>,
}

fn weight_case(rng: &mut Rng) -> WeightCase {
    let u = 1 + rng.below(40);
    let mut survive: Vec<bool> = (0..u).map(|_| rng.chance(0.5)).collect();
    // Keep at least one survivor with positive mass: the zero-mass
    // regime is `survivor_weights -> None` (pinned in exec's unit
    // tests); this property is about the well-formed regime.
    let forced = rng.below(u);
    survive[forced] = true;
    WeightCase {
        sizes: (0..u).map(|_| rng.range(1.0, 5000.0)).collect(),
        missed: (0..u).map(|_| rng.below(20) as u64).collect(),
        survive,
    }
}

#[test]
fn staleness_weights_finite_nonneg_and_renormalized() {
    prop::check("staleness-weights", prop::iters(120), weight_case, |cs| {
        // The engine's staleness path: effective mass D_i / (1 + missed)
        // through the same renormalization the default path uses.
        let scaled: Vec<f64> = cs
            .sizes
            .iter()
            .zip(&cs.missed)
            .map(|(d, m)| {
                let scale = 1.0 / (1.0 + *m as f64);
                if !(scale > 0.0 && scale <= 1.0) {
                    return f64::NAN; // caught by the finiteness check
                }
                d * scale
            })
            .collect();
        let Some(w) = survivor_weights(&scaled, &cs.survive) else {
            return Err("positive surviving mass yielded no weights".into());
        };
        let mut sum = 0.0f64;
        for (i, (&wi, &s)) in w.iter().zip(&cs.survive).enumerate() {
            if !wi.is_finite() || wi < 0.0 {
                return Err(format!("w[{i}] = {wi} not finite/non-negative"));
            }
            if !s && wi != 0.0 {
                return Err(format!("non-survivor {i} got weight {wi}"));
            }
            sum += wi as f64;
        }
        if (sum - 1.0).abs() > 1e-3 {
            return Err(format!("weights sum to {sum}, want 1"));
        }
        Ok(())
    });
}

#[derive(Debug)]
struct MaskRegime {
    u: usize,
    c: usize,
    rates: Vec<f64>,
    sizes: Vec<f64>,
    g2: Vec<f64>,
    sigma2: Vec<f64>,
    theta_max: Vec<f64>,
    q_prev: Vec<f64>,
    lambda1: f64,
    lambda2: f64,
}

fn mask_regime(rng: &mut Rng) -> MaskRegime {
    let u = 2 + rng.below(24);
    let c = (u / 2).max(1);
    MaskRegime {
        u,
        c,
        rates: (0..u * c).map(|_| rng.range(1e4, 4e7)).collect(),
        sizes: (0..u).map(|_| rng.range(100.0, 3000.0)).collect(),
        g2: (0..u).map(|_| rng.range(0.01, 25.0)).collect(),
        sigma2: (0..u).map(|_| rng.range(0.01, 4.0)).collect(),
        theta_max: (0..u).map(|_| rng.range(0.05, 2.0)).collect(),
        q_prev: (0..u).map(|_| rng.range(1.0, 14.0)).collect(),
        lambda1: 10f64.powf(rng.range(1.0, 4.0)),
        lambda2: 10f64.powf(rng.range(1.0, 3.5)),
    }
}

#[test]
fn p_leave_zero_pins_the_always_available_engine() {
    prop::check("p-leave-zero-pin", prop::iters(25), mask_regime, |r| {
        // Part 1: with p_leave = 0 the Markov chain can never leave the
        // all-on state, whatever p_join does.
        let cfg = AvailCfg { p_join: 0.7, p_leave: 0.0, ..AvailCfg::default() };
        let mut av = AvailProcess::new(r.u, cfg, r.lambda1.to_bits());
        for _ in 0..20 {
            av.tick();
            if !av.mask().iter().all(|&o| o) {
                return Err("p_leave = 0 produced an offline client".into());
            }
        }

        // Part 2: the all-true mask that chain feeds the scheduler is
        // bit-identical to no mask at every decision entry point.
        let mut params = SystemParams::femnist_small();
        params.num_clients = r.u;
        params.num_channels = r.c;
        let state = ChannelState::from_rates(r.u, r.c, r.rates.clone());
        let total: f64 = r.sizes.iter().sum();
        let w_full: Vec<f64> = r.sizes.iter().map(|d| d / total).collect();
        let mut queues = Queues::new();
        queues.lambda1 = r.lambda1;
        queues.lambda2 = r.lambda2;
        let base = RoundInputs {
            params: &params,
            round: 3,
            channels: &state,
            sizes: &r.sizes,
            w_full: &w_full,
            g2: &r.g2,
            sigma2: &r.sigma2,
            theta_max: &r.theta_max,
            q_prev: &r.q_prev,
            queues: &queues,
            avail: None,
        };
        let masked = RoundInputs {
            params: &params,
            round: 3,
            channels: &state,
            sizes: &r.sizes,
            w_full: &w_full,
            g2: &r.g2,
            sigma2: &r.sigma2,
            theta_max: &r.theta_max,
            q_prev: &r.q_prev,
            queues: &queues,
            avail: Some(av.mask()),
        };
        let chrom = greedy_allocation(&base);
        if chrom.alloc != greedy_allocation(&masked).alloc {
            return Err("greedy allocation diverged under the all-true mask".into());
        }
        let (j_base, a_base) = evaluate_allocation(&base, &chrom, Case5Mode::Taylor);
        let (j_mask, a_mask) = evaluate_allocation(&masked, &chrom, Case5Mode::Taylor);
        if j_base.to_bits() != j_mask.to_bits() {
            return Err(format!("reference J0 diverged: {j_base} vs {j_mask}"));
        }
        let bits = |assigns: &[Option<qccf::sched::ClientDecision>]| -> Vec<_> {
            assigns
                .iter()
                .map(|a| a.map(|d| (d.channel, d.q, d.f.to_bits(), d.rate.to_bits())))
                .collect::<Vec<_>>()
        };
        if bits(&a_base) != bits(&a_mask) {
            return Err("reference assignments diverged under the all-true mask".into());
        }
        let ctx_base = EvalCtx::new(&base, Case5Mode::Taylor);
        let ctx_mask = EvalCtx::new(&masked, Case5Mode::Taylor);
        let mut s1 = ctx_base.make_scratch();
        let mut s2 = ctx_mask.make_scratch();
        let jc_base = ctx_base.evaluate_j0(&chrom, &mut s1);
        let jc_mask = ctx_mask.evaluate_j0(&chrom, &mut s2);
        if jc_base.to_bits() != jc_mask.to_bits() {
            return Err(format!("cached J0 diverged: {jc_base} vs {jc_mask}"));
        }
        Ok(())
    });
}
