//! Fault-injection integration: the chaos subsystem's acceptance pins.
//! A chaos-enabled run must stay **bit-identical** across engine thread
//! counts and across checkpoint/resume (fault draws are a pure function
//! of the seed, never of the fan-out), retry-exhausted clients must
//! degrade into the departed path with finite θ, and a CRC-corrupted
//! mid-sweep snapshot must fall down the latest → previous → fresh
//! recovery ladder under `sweep --resume` instead of killing the sweep.
//!
//! All tests no-op (with a note) when `make artifacts` hasn't run.

use std::path::PathBuf;

use qccf::ckpt;
use qccf::experiments::common::{run_scenario, run_scenario_ckpt, CheckpointPolicy};
use qccf::experiments::sweep;
use qccf::metrics::Trace;
use qccf::runtime::{artifacts_dir, Runtime};
use qccf::scenario::registry;

fn runtime() -> Option<Runtime> {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::load(&artifacts_dir(), "tiny").expect("load tiny runtime"))
}

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Every deterministic trace field — including the chaos columns —
/// compared bit for bit. Wall-clock fields excluded, same contract as
/// `integration_ckpt.rs`.
fn assert_traces_bit_identical(want: &Trace, got: &Trace, tag: &str) {
    assert_eq!(want.algorithm, got.algorithm, "{tag}: algorithm");
    assert_eq!(want.records.len(), got.records.len(), "{tag}: length");
    for (a, b) in want.records.iter().zip(&got.records) {
        let r = a.round;
        assert_eq!(a.round, b.round, "{tag}: round");
        assert_eq!(a.scheduled, b.scheduled, "{tag} r{r}: scheduled");
        assert_eq!(a.aggregated, b.aggregated, "{tag} r{r}: aggregated");
        assert_eq!(a.departed, b.departed, "{tag} r{r}: departed");
        assert_eq!(a.retries, b.retries, "{tag} r{r}: retries");
        assert_eq!(a.failed_decodes, b.failed_decodes, "{tag} r{r}: failed_decodes");
        assert_eq!(a.wire_bytes, b.wire_bytes, "{tag} r{r}: wire_bytes");
        assert_eq!(a.energy.to_bits(), b.energy.to_bits(), "{tag} r{r}: energy");
        assert_eq!(a.cum_energy.to_bits(), b.cum_energy.to_bits(), "{tag} r{r}: cum_energy");
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "{tag} r{r}: train_loss");
        assert_eq!(a.mean_q.to_bits(), b.mean_q.to_bits(), "{tag} r{r}: mean_q");
        assert_eq!(a.q_per_client, b.q_per_client, "{tag} r{r}: q_per_client");
        assert_eq!(a.lambda1.to_bits(), b.lambda1.to_bits(), "{tag} r{r}: lambda1");
        assert_eq!(a.lambda2.to_bits(), b.lambda2.to_bits(), "{tag} r{r}: lambda2");
        assert_eq!(a.max_latency.to_bits(), b.max_latency.to_bits(), "{tag} r{r}: max_latency");
    }
}

/// paper-femnist shrunk to test scale with the chaos layer turned on
/// hot: decode failures frequent enough that the 8-round horizon sees
/// both successful retries and exhausted budgets, plus stragglers.
/// `chaos_ckpt` stays 0 so the mid-run snapshot this test resumes from
/// is sound (the corruption path has its own test below).
fn chaos_scenario_8() -> qccf::scenario::Scenario {
    let mut sc = registry::paper_femnist();
    sc.data.size_mean = 300.0;
    sc.data.size_std = 60.0;
    sc.data.test_size = 128;
    sc.train.rounds = 8;
    sc.train.chaos = true;
    sc.train.chaos_decode = 0.4;
    sc.train.chaos_straggle = 0.2;
    sc.train.chaos_retries = 2;
    sc
}

#[test]
fn chaos_run_bit_identical_across_threads_and_resume() {
    // The tentpole acceptance pin: a chaos-enabled run is bit-identical
    // for --threads 1 vs 8 and across a checkpoint/resume split, while
    // actually exercising the fault machinery (retries observed) and
    // degrading — never crashing — on exhausted retry budgets.
    let Some(rt) = runtime() else { return };
    let sc = chaos_scenario_8();
    let seed = 11u64;

    let reference = run_scenario(&rt, &sc, "qccf", seed, 1).unwrap();
    assert_eq!(reference.records.len(), 8);
    let retries: usize = reference.records.iter().map(|r| r.retries).sum();
    assert!(retries > 0, "p_decode = 0.4 over 8 rounds drew no retries");
    for rec in &reference.records {
        assert!(
            rec.train_loss.is_finite() && rec.energy.is_finite(),
            "round {}: chaos run lost finiteness (loss {}, energy {})",
            rec.round,
            rec.train_loss,
            rec.energy
        );
        // Exhausted budgets take the departed path — a failed decode
        // never reaches the fold, so it bounds the aggregate count.
        assert!(
            rec.aggregated + rec.failed_decodes <= rec.scheduled,
            "round {}: {} aggregated + {} failed decodes exceeds {} scheduled",
            rec.round,
            rec.aggregated,
            rec.failed_decodes,
            rec.scheduled
        );
    }

    let parallel = run_scenario(&rt, &sc, "qccf", seed, 8).unwrap();
    assert_traces_bit_identical(&reference, &parallel, "threads=8");

    // Checkpoint at round 4, resume to the full horizon on both thread
    // counts: the fault streams snapshot/restore like every other RNG.
    let ckpt_dir = fresh_dir("qccf_integration_faults_run");
    let mut sc4 = sc.clone();
    sc4.train.rounds = 4;
    run_scenario_ckpt(
        &rt,
        &sc4,
        "qccf",
        seed,
        8,
        &CheckpointPolicy { every: 4, dir: Some(ckpt_dir.clone()), resume: None, ..Default::default() },
    )
    .unwrap();
    let snap_path = ckpt_dir.join(ckpt::snapshot_file_name(&sc.name, "qccf", seed));
    assert!(snap_path.exists(), "snapshot not written at round 4");
    for threads in [1usize, 8] {
        let resumed = run_scenario_ckpt(
            &rt,
            &sc,
            "qccf",
            seed,
            threads,
            &CheckpointPolicy { every: 0, dir: None, resume: Some(snap_path.clone()), ..Default::default() },
        )
        .unwrap();
        assert_traces_bit_identical(&reference, &resumed, &format!("resumed threads={threads}"));
    }

    std::fs::remove_dir_all(&ckpt_dir).ok();
}

#[test]
fn corrupt_mid_sweep_snapshot_walks_the_recovery_ladder() {
    // The satellite regression pin: a CRC-bit-flipped mid-sweep
    // snapshot must not kill `sweep --resume`. With no usable rung the
    // unit restarts fresh; with a sound `.prev` rung it resumes from
    // there. Either way the unit completes and its trace is
    // byte-identical to the uninterrupted sweep's.
    let Some(rt) = runtime() else { return };
    let out_dir = fresh_dir("qccf_integration_faults_sweep");
    let cfg = |resume: bool| sweep::SweepConfig {
        scenarios: vec![registry::paper_femnist()],
        seeds: vec![1],
        algorithms: Some(vec!["qccf".into()]),
        rounds: Some(2),
        out_dir: out_dir.clone(),
        threads: 1,
        resume,
        checkpoint_every: 1,
    };

    let rows = sweep::run(&rt, &cfg(false)).unwrap();
    assert_eq!(rows.len(), 1);
    assert_eq!(rows[0].status, "ok");
    let jsonl = out_dir.join(format!("{}.jsonl", sweep::unit_stem("paper-femnist", "qccf", 1)));
    let full = std::fs::read(&jsonl).unwrap();
    let snap = out_dir.join("ckpt").join(ckpt::snapshot_file_name("paper-femnist", "qccf", 1));
    assert!(!snap.exists(), "completed unit left a stale snapshot");

    // Rung 1 — corrupted latest, no .prev: the ladder warns twice and
    // restarts fresh; determinism makes the rerun byte-identical.
    std::fs::remove_file(&jsonl).unwrap();
    sweep::write_summary(&[], &out_dir).unwrap();
    let mut sc1 = registry::paper_femnist();
    sc1.train.rounds = 1;
    run_scenario_ckpt(
        &rt,
        &sc1,
        "qccf",
        1,
        1,
        &CheckpointPolicy { every: 1, dir: Some(out_dir.join("ckpt")), resume: None, ..Default::default() },
    )
    .unwrap();
    assert!(snap.exists(), "simulated kill must leave the round-1 snapshot");
    let mut bytes = std::fs::read(&snap).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    std::fs::write(&snap, &bytes).unwrap();

    let rows2 = sweep::run(&rt, &cfg(true)).unwrap();
    assert_eq!(rows2.len(), 1);
    assert_eq!(rows2[0].status, "ok");
    assert_eq!(
        std::fs::read(&jsonl).unwrap(),
        full,
        "fresh-restart rung must reproduce the uninterrupted trace"
    );

    // Rung 2 — corrupted latest, sound .prev: a full 2-round run with
    // checkpoint_every=1 leaves the round-1 snapshot rotated to .prev
    // under the round-2 one. Flipping a bit in the latest forces the
    // ladder onto the .prev rung, which must carry the unit home.
    std::fs::remove_file(&jsonl).unwrap();
    sweep::write_summary(&[], &out_dir).unwrap();
    let mut sc2 = registry::paper_femnist();
    sc2.train.rounds = 2;
    run_scenario_ckpt(
        &rt,
        &sc2,
        "qccf",
        1,
        1,
        &CheckpointPolicy { every: 1, dir: Some(out_dir.join("ckpt")), resume: None, ..Default::default() },
    )
    .unwrap();
    let prev = out_dir.join("ckpt").join(format!(
        "{}.prev",
        ckpt::snapshot_file_name("paper-femnist", "qccf", 1)
    ));
    assert!(snap.exists() && prev.exists(), "rotation must leave latest + .prev");
    let mut bytes = std::fs::read(&snap).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    std::fs::write(&snap, &bytes).unwrap();

    let rows3 = sweep::run(&rt, &cfg(true)).unwrap();
    assert_eq!(rows3.len(), 1);
    assert_eq!(rows3[0].status, "ok");
    assert_eq!(
        std::fs::read(&jsonl).unwrap(),
        full,
        ".prev rung must reproduce the uninterrupted trace"
    );
    // Completion sweeps both rungs away.
    assert!(!snap.exists() && !prev.exists(), "completed unit left snapshot rungs behind");

    std::fs::remove_dir_all(&out_dir).ok();
}
