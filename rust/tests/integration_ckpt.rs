//! Checkpoint/resume integration: the `ckpt` subsystem's acceptance
//! pins. A run checkpointed mid-horizon and resumed must produce a
//! trace **bit-identical** to the uninterrupted run — for any engine
//! thread count on either side of the split — and `sweep --resume`
//! must complete a partially finished sweep without re-running
//! completed triples, restarting interrupted runs from their latest
//! snapshot.
//!
//! All tests no-op (with a note) when `make artifacts` hasn't run.

use std::path::PathBuf;

use qccf::ckpt;
use qccf::experiments::common::{run_scenario, run_scenario_ckpt, CheckpointPolicy};
use qccf::experiments::sweep;
use qccf::metrics::Trace;
use qccf::runtime::{artifacts_dir, Runtime};
use qccf::scenario::registry;

fn runtime() -> Option<Runtime> {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::load(&artifacts_dir(), "tiny").expect("load tiny runtime"))
}

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Every deterministic trace field, compared bit for bit. The two
/// wall-clock fields (`decide_seconds`/`compute_seconds`) are measured,
/// not derived, so they are the only exclusions — same contract as the
/// JSONL trace schema.
fn assert_traces_bit_identical(want: &Trace, got: &Trace, tag: &str) {
    assert_eq!(want.algorithm, got.algorithm, "{tag}: algorithm");
    assert_eq!(want.records.len(), got.records.len(), "{tag}: length");
    for (a, b) in want.records.iter().zip(&got.records) {
        let r = a.round;
        assert_eq!(a.round, b.round, "{tag}: round");
        assert_eq!(a.scheduled, b.scheduled, "{tag} r{r}: scheduled");
        assert_eq!(a.aggregated, b.aggregated, "{tag} r{r}: aggregated");
        assert_eq!(a.departed, b.departed, "{tag} r{r}: departed");
        assert_eq!(a.wire_bytes, b.wire_bytes, "{tag} r{r}: wire_bytes");
        assert_eq!(a.energy.to_bits(), b.energy.to_bits(), "{tag} r{r}: energy");
        assert_eq!(a.cum_energy.to_bits(), b.cum_energy.to_bits(), "{tag} r{r}: cum_energy");
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "{tag} r{r}: train_loss");
        assert_eq!(
            a.test_loss.map(f64::to_bits),
            b.test_loss.map(f64::to_bits),
            "{tag} r{r}: test_loss"
        );
        assert_eq!(
            a.test_acc.map(f64::to_bits),
            b.test_acc.map(f64::to_bits),
            "{tag} r{r}: test_acc"
        );
        assert_eq!(a.mean_q.to_bits(), b.mean_q.to_bits(), "{tag} r{r}: mean_q");
        assert_eq!(a.q_per_client, b.q_per_client, "{tag} r{r}: q_per_client");
        assert_eq!(a.lambda1.to_bits(), b.lambda1.to_bits(), "{tag} r{r}: lambda1");
        assert_eq!(a.lambda2.to_bits(), b.lambda2.to_bits(), "{tag} r{r}: lambda2");
        assert_eq!(a.max_latency.to_bits(), b.max_latency.to_bits(), "{tag} r{r}: max_latency");
    }
}

/// The paper-femnist scenario shrunk to test scale (the data volume,
/// not the physics), 12-round horizon.
fn scenario_12() -> qccf::scenario::Scenario {
    let mut sc = registry::paper_femnist();
    sc.data.size_mean = 300.0;
    sc.data.size_std = 60.0;
    sc.data.test_size = 128;
    sc.train.rounds = 12;
    sc
}

#[test]
fn checkpoint_at_6_resume_bit_identical_to_straight_12() {
    // The tentpole acceptance pin: paper-femnist 12 rounds straight vs
    // checkpoint-at-6 + resume, whole-trace bit equality — energies, q
    // levels, queues, wire bytes — with the interrupted half run at 8
    // engine threads and the resumed half at both 1 and 8.
    let Some(rt) = runtime() else { return };
    let sc = scenario_12();
    let seed = 5u64;

    let reference = run_scenario(&rt, &sc, "qccf", seed, 1).unwrap();
    assert_eq!(reference.records.len(), 12);

    // "Interrupted" run: a 6-round horizon with a snapshot at round 6
    // is exactly the state a kill after round 6 leaves behind (the
    // snapshot is written when the round completes, atomically).
    let ckpt_dir = fresh_dir("qccf_integration_ckpt_run");
    let mut sc6 = sc.clone();
    sc6.train.rounds = 6;
    let part = run_scenario_ckpt(
        &rt,
        &sc6,
        "qccf",
        seed,
        8,
        &CheckpointPolicy { every: 6, dir: Some(ckpt_dir.clone()), resume: None, ..Default::default() },
    )
    .unwrap();
    assert_eq!(part.records.len(), 6);
    let snap_path = ckpt_dir.join(ckpt::snapshot_file_name(&sc.name, "qccf", seed));
    assert!(snap_path.exists(), "snapshot not written at round 6");

    // The first 6 rounds already agree (threads are a non-input).
    let prefix = Trace { algorithm: reference.algorithm.clone(), records: reference.records[..6].to_vec() };
    assert_traces_bit_identical(&prefix, &part, "prefix");

    for threads in [1usize, 8] {
        let resumed = run_scenario_ckpt(
            &rt,
            &sc,
            "qccf",
            seed,
            threads,
            &CheckpointPolicy { every: 0, dir: None, resume: Some(snap_path.clone()), ..Default::default() },
        )
        .unwrap();
        assert_traces_bit_identical(&reference, &resumed, &format!("resumed threads={threads}"));
    }

    // Identity mismatches are refused, not silently diverged from.
    let wrong_seed = run_scenario_ckpt(
        &rt,
        &sc,
        "qccf",
        seed + 1,
        1,
        &CheckpointPolicy { every: 0, dir: None, resume: Some(snap_path.clone()), ..Default::default() },
    );
    assert!(
        format!("{:#}", wrong_seed.unwrap_err()).contains("seed"),
        "wrong-seed resume must name the seed mismatch"
    );
    let mut sc_drift = sc.clone();
    sc_drift.data.size_mean = 301.0;
    let drift = run_scenario_ckpt(
        &rt,
        &sc_drift,
        "qccf",
        seed,
        1,
        &CheckpointPolicy { every: 0, dir: None, resume: Some(snap_path.clone()), ..Default::default() },
    );
    assert!(
        format!("{:#}", drift.unwrap_err()).contains("differs"),
        "drifted scenario resume must be refused"
    );

    // A corrupted snapshot is a typed rejection (CRC), not a zero-fill.
    let mut bytes = std::fs::read(&snap_path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    let bad_path = ckpt_dir.join("corrupt.qckpt");
    std::fs::write(&bad_path, &bytes).unwrap();
    let corrupt = run_scenario_ckpt(
        &rt,
        &sc,
        "qccf",
        seed,
        1,
        &CheckpointPolicy { every: 0, dir: None, resume: Some(bad_path), ..Default::default() },
    );
    assert!(
        format!("{:#}", corrupt.unwrap_err()).contains("corrupt"),
        "corrupted snapshot must fail with the CRC rejection"
    );

    std::fs::remove_dir_all(&ckpt_dir).ok();
}

#[test]
fn sweep_resume_completes_partial_sweep_without_rerunning() {
    // The sweep acceptance pin: after a simulated kill — one triple's
    // outputs erased from summary.csv, a mid-horizon snapshot left in
    // --out/ckpt — `--resume` must (a) not touch the completed triple,
    // (b) restart the partial one from its snapshot, and (c) produce a
    // JSONL trace byte-identical to the uninterrupted sweep's.
    let Some(rt) = runtime() else { return };
    let out_dir = fresh_dir("qccf_integration_ckpt_sweep");
    let cfg = |resume: bool| sweep::SweepConfig {
        scenarios: vec![registry::paper_femnist()],
        seeds: vec![1, 2],
        algorithms: Some(vec!["qccf".into()]),
        rounds: Some(2),
        out_dir: out_dir.clone(),
        threads: 1,
        resume,
        checkpoint_every: 1,
    };

    // Uninterrupted sweep: 2 units × 2 rounds.
    let rows = sweep::run(&rt, &cfg(false)).unwrap();
    assert_eq!(rows.len(), 2);
    let jsonl1 = out_dir.join(format!("{}.jsonl", sweep::unit_stem("paper-femnist", "qccf", 1)));
    let jsonl2 = out_dir.join(format!("{}.jsonl", sweep::unit_stem("paper-femnist", "qccf", 2)));
    let full_seed1 = std::fs::read(&jsonl1).unwrap();
    let full_seed2 = std::fs::read(&jsonl2).unwrap();
    // Every sweep records each scenario's canonical render next to the
    // traces — the identity the resume path verifies.
    let sidecar = out_dir.join("paper-femnist.scenario");
    assert!(sidecar.exists(), "scenario identity sidecar not written");
    // Completed units leave no snapshots behind.
    let snap2 = out_dir.join("ckpt").join(ckpt::snapshot_file_name("paper-femnist", "qccf", 2));
    assert!(!snap2.exists(), "completed unit left a stale snapshot");

    // Simulate the kill: seed 2 never finished — its trace and summary
    // row are gone, only a round-1 snapshot survives (what the unit's
    // checkpoint_every=1 policy would have written mid-run).
    std::fs::remove_file(&jsonl2).unwrap();
    sweep::write_summary(&rows[..1], &out_dir).unwrap();
    let mut sc1 = registry::paper_femnist();
    sc1.train.rounds = 1;
    run_scenario_ckpt(
        &rt,
        &sc1,
        "qccf",
        2,
        1,
        &CheckpointPolicy { every: 1, dir: Some(out_dir.join("ckpt")), resume: None, ..Default::default() },
    )
    .unwrap();
    assert!(snap2.exists(), "simulated kill must leave the round-1 snapshot");
    // Sentinel: if --resume re-ran the completed seed-1 unit, its
    // deterministic rewrite would erase this marker line.
    let mut seed1_bytes = std::fs::read(&jsonl1).unwrap();
    seed1_bytes.extend_from_slice(b"{\"sentinel\":true}\n");
    std::fs::write(&jsonl1, &seed1_bytes).unwrap();

    let rows2 = sweep::run(&rt, &cfg(true)).unwrap();
    assert_eq!(rows2.len(), 2);
    // (a) completed triple untouched (sentinel survived).
    let seed1_after = std::fs::read(&jsonl1).unwrap();
    assert!(
        seed1_after.ends_with(b"{\"sentinel\":true}\n"),
        "resume re-ran the completed seed-1 unit"
    );
    // (b)+(c) the resumed partial run finished rounds 2..2 from the
    // snapshot and its trace is byte-identical to the uninterrupted
    // sweep's (bit-identical resume ⇒ identical JSONL bytes).
    let resumed_seed2 = std::fs::read(&jsonl2).unwrap();
    assert_eq!(resumed_seed2, full_seed2, "resumed seed-2 trace diverged");
    // Summary rows match the uninterrupted sweep's (same unit order).
    for (a, b) in rows.iter().zip(&rows2) {
        assert_eq!(a.scenario, b.scenario);
        assert_eq!(a.algorithm, b.algorithm);
        assert_eq!(a.seed, b.seed);
        assert_eq!(a.rounds, b.rounds);
        assert_eq!(a.cum_energy.to_bits(), b.cum_energy.to_bits(), "seed {}", a.seed);
        assert_eq!(a.wire_bytes, b.wire_bytes);
        assert_eq!(a.dropouts, b.dropouts);
    }
    // The stale snapshot was cleaned up after the unit completed.
    assert!(!snap2.exists(), "resumed unit left its snapshot behind");

    // Scenario drift: if the recorded identity sidecar differs from the
    // current definition beyond the horizon, the scenario's triples are
    // stale — --resume must re-run them (the sentinel disappears under
    // the fresh deterministic rewrite) instead of silently carrying
    // results produced under different physics.
    let mut seed1_resumed = std::fs::read(&jsonl1).unwrap();
    assert!(seed1_resumed.ends_with(b"{\"sentinel\":true}\n"), "setup drifted");
    let mut drifted = registry::paper_femnist();
    drifted.train.rounds = 2;
    drifted.wireless.gain_db += 1.0;
    std::fs::write(&sidecar, qccf::scenario::render(&drifted)).unwrap();
    let rows3 = sweep::run(&rt, &cfg(true)).unwrap();
    assert_eq!(rows3.len(), 2);
    seed1_resumed = std::fs::read(&jsonl1).unwrap();
    assert_eq!(
        seed1_resumed, full_seed1,
        "drifted scenario's triples must re-run to the fresh deterministic trace"
    );
    // The sidecar now records the (restored) current definition again.
    let recorded = std::fs::read_to_string(&sidecar).unwrap();
    assert!(!recorded.contains(&format!("gain_db = {}", drifted.wireless.gain_db)));

    std::fs::remove_dir_all(&out_dir).ok();
}
