//! Class-hierarchy equivalence properties (pure Rust — no artifacts):
//! when every member of an equivalence class is *exactly* identical
//! (same dataset size, same rate on every channel, same gradient
//! stats, same θ^max / q_prev), the classed decision path collapses to
//! the per-client truth:
//!
//! * the class partition recovers exactly the templates;
//! * the broadcast representative solve is **bit-identical** to each
//!   member's own `solver::solve_client` (class means of identical
//!   dyadic inputs are exact in IEEE-754);
//! * the classed decide's reported `(J0, assignments)` are exact for
//!   the allocation it chose — re-scoring the returned allocation
//!   through the reference `evaluate_allocation` reproduces them
//!   bitwise — and never worse than the greedy backstop;
//! * scheduled members of one class share identical `(q, f)` bits;
//! * the representative-solve memo is a pure cache (cache on/off
//!   decides are bit-identical).
//!
//! Sizes are exact integers, rates are powers of two, and the shared
//! stats are dyadic (θ = 0.25, q_prev = 4.0, Ĝ² = 2.0, σ̂² = 0.5), so
//! every class mean is exactly representable and the bitwise claims
//! are meaningful, across U ∈ {10, 100, 1000}.

use qccf::config::SystemParams;
use qccf::energy::client_energy;
use qccf::ga::{Chromosome, GaParams};
use qccf::lyapunov::Queues;
use qccf::sched::classes::decide_with_classes;
use qccf::sched::{
    evaluate_allocation, greedy_allocation, ClassEvalCtx, ClassPlan, ClassingConfig,
    ClientDecision, RoundInputs,
};
use qccf::solver::{solve_client, Case5Mode, ClientCtx};
use qccf::util::prop;
use qccf::util::rng::Rng;
use qccf::wireless::ChannelState;

struct Case {
    params: SystemParams,
    /// Number of templates (= classes the plan must recover); divides U.
    t: usize,
    rates: Vec<f64>,
    sizes: Vec<f64>,
    w_full: Vec<f64>,
    mode: Case5Mode,
    seed: u64,
}

impl std::fmt::Debug for Case {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Case {{ U: {}, C: {}, templates: {}, mode: {:?}, seed: {} }}",
            self.params.num_clients, self.params.num_channels, self.t, self.mode, self.seed
        )
    }
}

/// Draw one round of T identical-member templates: client `i` belongs
/// to template `i % T`, template `t` has size `512·(t+1)` samples
/// (exact integer, distinct per template) and rate `2^(23 + t mod 4)`
/// bit/s on **every** channel. T divides every U in {10, 100, 1000},
/// so the T equal-mass size-rank bins land exactly on the templates.
fn case(rng: &mut Rng) -> Case {
    let u = [10usize, 100, 1000][rng.below(3)];
    let t = [2usize, 5][rng.below(2)];
    let c = u.min(16);
    let mut params = SystemParams::femnist_small();
    params.num_clients = u;
    params.num_channels = c;
    let sizes: Vec<f64> = (0..u).map(|i| 512.0 * (i % t + 1) as f64).collect();
    let total: f64 = sizes.iter().sum();
    let w_full: Vec<f64> = sizes.iter().map(|d| d / total).collect();
    let mut rates = Vec::with_capacity(u * c);
    for i in 0..u {
        let r = (1u64 << (23 + (i % t) % 4)) as f64;
        for _ in 0..c {
            rates.push(r);
        }
    }
    let mode = if rng.chance(0.5) { Case5Mode::Taylor } else { Case5Mode::Bisect };
    Case { params, t, rates, sizes, w_full, mode, seed: rng.next_u64() }
}

fn bits_of(assigns: &[Option<ClientDecision>]) -> Vec<Option<(usize, Option<u32>, u64, u64)>> {
    assigns
        .iter()
        .map(|a| a.map(|d| (d.channel, d.q, d.f.to_bits(), d.rate.to_bits())))
        .collect()
}

#[test]
fn identical_members_make_the_classed_path_exact() {
    prop::check("classed-identical-members", prop::iters(24), case, |cs| {
        let (u, c) = (cs.params.num_clients, cs.params.num_channels);
        let state = ChannelState::from_rates(u, c, cs.rates.clone());
        let g2 = vec![2.0; u];
        let sigma2 = vec![0.5; u];
        let theta_max = vec![0.25; u];
        let q_prev = vec![4.0; u];
        let mut queues = Queues::new();
        queues.lambda1 = 1024.0;
        queues.lambda2 = 8.0;
        let inp = RoundInputs {
            params: &cs.params,
            round: 3,
            channels: &state,
            sizes: &cs.sizes,
            w_full: &cs.w_full,
            g2: &g2,
            sigma2: &sigma2,
            theta_max: &theta_max,
            q_prev: &q_prev,
            queues: &queues,
            avail: None,
        };
        let cfg = ClassingConfig { size_bins: cs.t, rate_bins: 1 };
        let plan = ClassPlan::build(&inp, cfg);

        // The partition recovers exactly the templates.
        if plan.num_classes() != cs.t {
            return Err(format!("K = {} classes, expected {}", plan.num_classes(), cs.t));
        }
        let mut covered = 0usize;
        for k in 0..plan.num_classes() {
            let members = plan.class_members(k);
            covered += members.len();
            let tmpl = members[0] % cs.t;
            if members.iter().any(|&i| i % cs.t != tmpl) {
                return Err(format!("class {k} mixes templates"));
            }
        }
        if covered != u {
            return Err(format!("classes cover {covered} of {u} clients"));
        }

        // Broadcast representative solve == each member's own solve,
        // bitwise, at every feasible (class, pool) pair.
        let ctx = ClassEvalCtx::new(&inp, &plan, cs.mode, true);
        let total: f64 = cs.sizes.iter().sum();
        for k in 0..plan.num_classes() {
            let members = plan.class_members(k);
            for pool in 0..plan.num_pools() {
                if !ctx.class_feasible(k, pool) {
                    continue;
                }
                let (_, plen) = plan.pool(pool);
                let n = members.len().min(plen);
                let d_rep = ctx.sched_size_sum(k, n) / n as f64;
                if d_rep.to_bits() != cs.sizes[members[0]].to_bits() {
                    return Err(format!("class {k}: d_rep {d_rep} not exact"));
                }
                let rate = ctx.class_rate(k, pool);
                if rate.to_bits() != inp.channels.rate(members[0], 0).to_bits() {
                    return Err(format!("class {k}: pool rate {rate} not exact"));
                }
                let w = d_rep / total;
                let broadcast = ctx.broadcast_solve(k, d_rep, w, rate);
                for &i in &members[..n] {
                    let cctx = ClientCtx {
                        d_i: cs.sizes[i],
                        w_round: w,
                        rate,
                        theta_max: 0.25,
                        q_prev: 4.0,
                    };
                    let own = solve_client(&cs.params, queues.lambda2, &cctx, cs.mode).map(
                        |dec| (dec, client_energy(&cs.params, cs.sizes[i], dec.f, dec.q, rate)),
                    );
                    match (broadcast, own) {
                        (None, None) => {}
                        (Some((bd, be)), Some((od, oe))) => {
                            if bd.q != od.q
                                || bd.f.to_bits() != od.f.to_bits()
                                || be.to_bits() != oe.to_bits()
                            {
                                return Err(format!(
                                    "class {k} member {i}: broadcast (q={}, f={}, e={be}) \
                                     vs own (q={}, f={}, e={oe})",
                                    bd.q, bd.f, od.q, od.f
                                ));
                            }
                        }
                        (b, o) => {
                            return Err(format!(
                                "class {k} member {i}: broadcast feasibility {} vs own {}",
                                b.is_some(),
                                o.is_some()
                            ));
                        }
                    }
                }
            }
        }

        // The classed decide: exact for its chosen allocation, never
        // worse than greedy, class-uniform (q, f), cache-invariant.
        let mut rng = Rng::seed_from(cs.seed);
        let (j0, assigns, evals) =
            decide_with_classes(&inp, cs.mode, &GaParams::default(), &mut rng, cfg, true);
        if evals == 0 {
            return Err("classed decide reported zero evaluations".into());
        }
        let (j_gr, _) = evaluate_allocation(&inp, &greedy_allocation(&inp), cs.mode);
        if !j0.is_finite() {
            return Err(format!("classed J0 infinite on a feasible round (greedy {j_gr})"));
        }
        if j0 > j_gr {
            return Err(format!("classed J0 {j0} worse than greedy backstop {j_gr}"));
        }
        let mut alloc = vec![None; c];
        for (i, d) in assigns.iter().enumerate() {
            if let Some(d) = d {
                if alloc[d.channel].is_some() {
                    return Err(format!("channel {} assigned twice", d.channel));
                }
                alloc[d.channel] = Some(i);
            }
        }
        let (j_re, a_re) = evaluate_allocation(&inp, &Chromosome { alloc }, cs.mode);
        if j_re.to_bits() != j0.to_bits() {
            return Err(format!("reported J0 {j0} not exact (reference re-score {j_re})"));
        }
        if bits_of(&a_re) != bits_of(&assigns) {
            return Err("reported assignments diverge from reference re-score".into());
        }
        for k in 0..plan.num_classes() {
            let mut qf: Option<(Option<u32>, u64)> = None;
            for &i in plan.class_members(k) {
                let Some(d) = assigns[i] else { continue };
                let here = (d.q, d.f.to_bits());
                match qf {
                    None => qf = Some(here),
                    Some(first) if first != here => {
                        return Err(format!("class {k}: scheduled members differ in (q, f)"));
                    }
                    Some(_) => {}
                }
            }
        }
        let mut rng = Rng::seed_from(cs.seed);
        let (j_off, a_off, _) =
            decide_with_classes(&inp, cs.mode, &GaParams::default(), &mut rng, cfg, false);
        if j_off.to_bits() != j0.to_bits() || bits_of(&a_off) != bits_of(&assigns) {
            return Err("cache-off classed decide diverged".into());
        }
        Ok(())
    });
}
