//! Cross-module property tests (pure Rust — no artifacts needed):
//! solver ∘ energy ∘ wireless ∘ GA invariants under randomized regimes.

use qccf::config::SystemParams;
use qccf::energy;
use qccf::ga::Chromosome;
use qccf::lyapunov::Queues;
use qccf::quant;
use qccf::sched::{evaluate_allocation, greedy_allocation, RoundInputs};
use qccf::solver::{self, Case5Mode};
use qccf::util::prop;
use qccf::util::rng::Rng;
use qccf::wireless::ChannelModel;

struct Regime {
    params: SystemParams,
    rates: Vec<f64>, // flattened [client][channel]
    sizes: Vec<f64>,
    w_full: Vec<f64>,
    g2: Vec<f64>,
    sigma2: Vec<f64>,
    theta_max: Vec<f64>,
    q_prev: Vec<f64>,
    queues: Queues,
}

impl std::fmt::Debug for Regime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Regime {{ v: {}, λ1: {:.3}, λ2: {:.3}, sizes: {:?} }}",
            self.params.v, self.queues.lambda1, self.queues.lambda2, self.sizes
        )
    }
}

fn regime(rng: &mut Rng) -> Regime {
    let mut params = SystemParams::femnist_small();
    params.v = 10f64.powf(rng.range(0.0, 3.0));
    let model = ChannelModel::new(&params, rng);
    let state = model.draw(rng);
    let u = params.num_clients;
    let c = params.num_channels;
    let mut rates = Vec::with_capacity(u * c);
    for i in 0..u {
        for ch in 0..c {
            rates.push(state.rate(i, ch));
        }
    }
    let sizes: Vec<f64> = (0..u).map(|_| rng.gaussian(1200.0, 300.0).max(64.0)).collect();
    let total: f64 = sizes.iter().sum();
    let w_full = sizes.iter().map(|d| d / total).collect();
    let mut queues = Queues::new();
    queues.lambda1 = 10f64.powf(rng.range(-1.0, 5.0));
    queues.lambda2 = 10f64.powf(rng.range(-2.0, 4.0));
    Regime {
        params,
        rates,
        sizes,
        w_full,
        g2: (0..u).map(|_| rng.range(0.01, 25.0)).collect(),
        sigma2: (0..u).map(|_| rng.range(0.01, 4.0)).collect(),
        theta_max: (0..u).map(|_| rng.range(0.05, 2.0)).collect(),
        q_prev: (0..u).map(|_| rng.range(1.0, 14.0)).collect(),
        queues,
    }
}

#[test]
fn every_evaluated_decision_is_feasible() {
    prop::check("eval-alloc-feasible", prop::iters(120), regime, |r| {
        let state = qccf::wireless::ChannelState::from_rates(
            r.params.num_clients,
            r.params.num_channels,
            r.rates.clone(),
        );
        let inp = RoundInputs {
            params: &r.params,
            round: 3,
            channels: &state,
            sizes: &r.sizes,
            w_full: &r.w_full,
            g2: &r.g2,
            sigma2: &r.sigma2,
            theta_max: &r.theta_max,
            q_prev: &r.q_prev,
            queues: &r.queues,
            avail: None,
        };
        let chrom = greedy_allocation(&inp);
        let (j0, assigns) = evaluate_allocation(&inp, &chrom, Case5Mode::Taylor);
        if !j0.is_finite() && assigns.iter().flatten().count() > 0 {
            return Err("finite participants but infinite J0".into());
        }
        let mut used = std::collections::BTreeSet::new();
        for (i, d) in assigns.iter().enumerate() {
            let Some(d) = d else { continue };
            if !used.insert(d.channel) {
                return Err(format!("channel {} reused (C3)", d.channel));
            }
            let q = d.q.unwrap();
            let lat = energy::client_latency(&r.params, r.sizes[i], d.f, q, d.rate);
            if lat > r.params.t_max * (1.0 + 1e-9) {
                return Err(format!("client {i}: latency {lat} > T^max (C4)"));
            }
            if d.f < r.params.f_min - 1.0 || d.f > r.params.f_max + 1.0 {
                return Err(format!("client {i}: f {} out of C5", d.f));
            }
            if q < 1 {
                return Err("q < 1 (C8)".into());
            }
        }
        Ok(())
    });
}

#[test]
fn taylor_matches_bisect_near_anchor() {
    // Eq. (39) is a first-order step around q from the client's last
    // participation; the paper's premise is that models (hence optimal
    // levels) move little between participations. On those terms — an
    // anchor within ±1 level of the true root — Taylor must land within
    // one integer level of the exact bisection answer.
    let mut agree = 0usize;
    let mut total = 0usize;
    prop::check("taylor-vs-bisect-near", prop::iters(250), regime, |r| {
        let i = 0usize;
        let rate = r.rates[i * r.params.num_channels];
        let mut ctx = solver::ClientCtx {
            d_i: r.sizes[i],
            w_round: r.w_full[i],
            rate,
            theta_max: r.theta_max[i],
            q_prev: r.q_prev[i],
        };
        let Some(db) = solver::solve_client(&r.params, r.queues.lambda2, &ctx, Case5Mode::Bisect)
        else {
            return Ok(());
        };
        // Anchor near the exact continuous optimum (paper's premise).
        ctx.q_prev = (db.q_hat + (r.q_prev[i] - 7.0) / 7.0).max(1.0);
        let Some(da) = solver::solve_client(&r.params, r.queues.lambda2, &ctx, Case5Mode::Taylor)
        else {
            return Err("taylor infeasible where bisect feasible".into());
        };
        total += 1;
        if da.q == db.q {
            agree += 1;
        }
        if da.q.abs_diff(db.q) > 1 {
            Err(format!("taylor q={} vs bisect q={} (q̂={:.2})", da.q, db.q, db.q_hat))
        } else {
            Ok(())
        }
    });
    assert!(agree * 10 >= total * 8, "agreement too low: {agree}/{total}");
}

#[test]
fn taylor_iterates_to_bisect_fixed_point() {
    // Across rounds the paper's scheme is a fixed-point iteration:
    // repeatedly re-anchoring eq. (39) at its own output must converge
    // to the exact root of eq. (38) whenever Case 5 governs.
    prop::check("taylor-fixed-point", prop::iters(120), regime, |r| {
        let i = 1usize;
        let rate = r.rates[i * r.params.num_channels];
        let mut ctx = solver::ClientCtx {
            d_i: r.sizes[i],
            w_round: r.w_full[i],
            rate,
            theta_max: r.theta_max[i],
            q_prev: r.q_prev[i],
        };
        let exact = solver::solve_continuous(&r.params, r.queues.lambda2, &ctx, Case5Mode::Bisect);
        let Some((q_exact, _, 5)) = exact else { return Ok(()) };
        for _ in 0..30 {
            match solver::solve_continuous(&r.params, r.queues.lambda2, &ctx, Case5Mode::Taylor) {
                Some((q_hat, _, 5)) => ctx.q_prev = q_hat.max(1.0),
                // A boundary case took over (numerically legitimate).
                _ => return Ok(()),
            }
        }
        if (ctx.q_prev - q_exact).abs() > 0.05 {
            Err(format!("fixed point {:.4} vs exact {q_exact:.4}", ctx.q_prev))
        } else {
            Ok(())
        }
    });
}

#[test]
fn wire_codec_roundtrip_random_vectors() {
    // Bit-exact transport contract: encode ∘ knot_indices followed by
    // decode must reproduce the kernel mirror's dequantized model with
    // to_bits() equality (the decode op order matches the mirror), and
    // the fused decode-fold must match decode-then-fold bitwise.
    prop::check(
        "wire-roundtrip",
        prop::iters(80),
        |rng| {
            let n = 1 + rng.below(3000);
            let q = 1 + rng.below(32) as u32;
            let scale = 10f64.powf(rng.range(-3.0, 3.0));
            let theta: Vec<f32> =
                (0..n).map(|_| (rng.gaussian(0.0, scale)) as f32).collect();
            let mut noise = vec![0.0f32; n];
            rng.fill_uniform_f32(&mut noise);
            let w = rng.range(-1.0, 1.0) as f32;
            (theta, noise, q, w)
        },
        |(theta, noise, q, w)| {
            let (deq, tmax) = quant::stochastic_quantize(theta, noise, *q as f32);
            let (idx, signs, tmax2) = quant::knot_indices(theta, noise, *q);
            if tmax.to_bits() != tmax2.to_bits() {
                return Err("tmax mismatch".into());
            }
            let bytes = quant::encode(tmax, &signs, &idx, *q);
            if bytes.len() != quant::encoded_len(theta.len(), *q) {
                return Err("eq. (5) length violated".into());
            }
            let (tmax3, decoded) =
                quant::decode(&bytes, theta.len(), *q).map_err(|e| e.to_string())?;
            if tmax3.to_bits() != tmax.to_bits() {
                return Err("range header corrupted".into());
            }
            for (i, (d, e)) in decoded.iter().zip(&deq).enumerate() {
                if d.to_bits() != e.to_bits() {
                    return Err(format!("element {i}: {d} vs {e} (bits differ)"));
                }
            }
            // Fused fold == decode-then-fold, bit for bit.
            let mut fused = vec![0.5f32; theta.len()];
            quant::wire::fold_into(&mut fused, *w, &bytes, *q).map_err(|e| e.to_string())?;
            for (i, (f, d)) in fused.iter().zip(&decoded).enumerate() {
                if f.to_bits() != (0.5f32 + w * d).to_bits() {
                    return Err(format!("fused fold diverged at {i}"));
                }
            }
            // Truncation must be rejected, never zero-filled.
            if quant::decode(&bytes[..bytes.len() - 1], theta.len(), *q).is_ok() {
                return Err("truncated buffer accepted".into());
            }
            Ok(())
        },
    );
}

#[test]
fn wire_transport_payload_matches_eq5() {
    // The transport-path length property: for any (Z, q) — including
    // Z = 0 and q up to the 32-bit cap, with adversarial index
    // patterns — the realized upload bytes equal ceil(eq. (5)/8)
    // exactly, the bit fields roundtrip exactly, and every truncated
    // buffer is rejected with a typed error.
    prop::check(
        "wire-eq5-bytes",
        prop::iters(120),
        |rng| {
            let z = if rng.chance(0.1) { 0 } else { rng.below(2000) };
            let q = 1 + rng.below(32) as u32;
            let mask: u64 = u64::MAX >> (64 - q);
            let idx: Vec<u32> = (0..z)
                .map(|i| match i % 3 {
                    0 => mask as u32,
                    1 => 0,
                    _ => (rng.next_u64() & mask) as u32,
                })
                .collect();
            let signs: Vec<bool> = (0..z).map(|_| rng.chance(0.5)).collect();
            (idx, signs, q, rng.range(0.0, 5.0) as f32)
        },
        |(idx, signs, q, tmax)| {
            let z = idx.len();
            let bytes = quant::encode(*tmax, signs, idx, *q);
            let mut p = SystemParams::femnist_small();
            p.z = z;
            let analytic = (p.payload_bits(*q) as usize + 7) / 8;
            if bytes.len() != analytic {
                return Err(format!("{} bytes vs eq. (5) ceil {analytic}", bytes.len()));
            }
            let up = qccf::fl::exec::Upload::Wire { bytes: bytes.clone(), q: *q };
            if up.wire_bytes() != analytic {
                return Err("Upload::wire_bytes disagrees with eq. (5)".into());
            }
            let raw = qccf::fl::exec::Upload::Raw(vec![0.0f32; z]);
            if raw.wire_bytes() != (p.raw_payload_bits() as usize + 7) / 8 {
                return Err("raw upload bytes != 4Z".into());
            }
            let (t2, s2, i2) =
                quant::decode_indices(&bytes, z, *q).map_err(|e| e.to_string())?;
            if t2.to_bits() != tmax.to_bits() || &s2 != signs || &i2 != idx {
                return Err("field roundtrip corrupted".into());
            }
            if !bytes.is_empty()
                && quant::decode_indices(&bytes[..bytes.len() - 1], z, *q).is_ok()
            {
                return Err("truncated buffer accepted".into());
            }
            Ok(())
        },
    );
}

#[test]
fn ga_never_worse_than_seeded_greedy() {
    prop::check("ga-vs-greedy", prop::iters(25), regime, |r| {
        let state = qccf::wireless::ChannelState::from_rates(
            r.params.num_clients,
            r.params.num_channels,
            r.rates.clone(),
        );
        let inp = RoundInputs {
            params: &r.params,
            round: 3,
            channels: &state,
            sizes: &r.sizes,
            w_full: &r.w_full,
            g2: &r.g2,
            sigma2: &r.sigma2,
            theta_max: &r.theta_max,
            q_prev: &r.q_prev,
            queues: &r.queues,
            avail: None,
        };
        let greedy = greedy_allocation(&inp);
        let (jg, _) = evaluate_allocation(&inp, &greedy, Case5Mode::Taylor);
        let mut sched = qccf::sched::qccf::QccfScheduler::new(13);
        let dec = qccf::sched::Scheduler::decide(&mut sched, &inp);
        if dec.j0.is_finite() && jg.is_finite() && dec.j0 > jg * (1.0 + 1e-9) + 1e-9 {
            return Err(format!("GA {j} worse than greedy {jg}", j = dec.j0));
        }
        Ok(())
    });
}

#[test]
fn queues_remain_stable_under_achievable_budgets() {
    // Feed the queues the arrivals of a full-participation policy with
    // ε set 2% above: λ must stay bounded (mean-rate stability, §V-A).
    prop::check("queue-stability", prop::iters(40), regime, |r| {
        let mut p = r.params.clone();
        let u = p.num_clients;
        let participating = vec![true; u];
        let data = qccf::convergence::data_term(
            &p,
            &participating,
            &r.w_full,
            &r.w_full,
            &r.g2,
            &r.sigma2,
        );
        p.eps1 = data * 1.02;
        p.eps2 = 0.1;
        let mut queues = Queues::new();
        for _ in 0..500 {
            queues.update(&p, data, p.eps2 * 0.9);
        }
        if queues.lambda1 > data {
            return Err(format!("λ1 {} unbounded", queues.lambda1));
        }
        if queues.lambda2 != 0.0 {
            return Err("λ2 should drain to zero".into());
        }
        Ok(())
    });
}

#[test]
fn chromosome_channel_of_consistency() {
    prop::check(
        "chromosome-consistency",
        prop::iters(150),
        |rng| Chromosome::random(1 + rng.below(16), 1 + rng.below(16), rng),
        |c| {
            let u = 16;
            let parts = c.participants(u);
            for (i, &p) in parts.iter().enumerate() {
                match (p, c.channel_of(i)) {
                    (true, Some(ch)) => {
                        if c.alloc[ch] != Some(i) {
                            return Err(format!("channel_of({i}) inconsistent"));
                        }
                    }
                    (false, None) => {}
                    (a, b) => return Err(format!("client {i}: participant={a} channel={b:?}")),
                }
            }
            Ok(())
        },
    );
}
