//! Decision-stage equivalence properties (pure Rust — no artifacts):
//! the cached evaluation subsystem (`sched::EvalCtx` + exact-key solve
//! memo + reusable `EvalScratch`) must return **bit-identical**
//! `(J0, assignments)` to the uncached reference
//! `sched::evaluate_allocation` for any chromosome — including
//! infeasible clients, empty allocations and repeated (memo-hit)
//! evaluations — at several federation sizes.

use qccf::config::SystemParams;
use qccf::ga::Chromosome;
use qccf::lyapunov::Queues;
use qccf::sched::{evaluate_allocation, ClientDecision, EvalCtx, RoundInputs};
use qccf::solver::Case5Mode;
use qccf::util::prop;
use qccf::util::rng::Rng;
use qccf::wireless::ChannelState;

struct Case {
    params: SystemParams,
    rates: Vec<f64>,
    sizes: Vec<f64>,
    w_full: Vec<f64>,
    g2: Vec<f64>,
    sigma2: Vec<f64>,
    theta_max: Vec<f64>,
    q_prev: Vec<f64>,
    queues: Queues,
    mode: Case5Mode,
    chroms: Vec<Chromosome>,
}

impl std::fmt::Debug for Case {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Case {{ U: {}, C: {}, mode: {:?}, λ1: {:.3}, λ2: {:.3}, chroms: {:?} }}",
            self.params.num_clients,
            self.params.num_channels,
            self.mode,
            self.queues.lambda1,
            self.queues.lambda2,
            self.chroms
        )
    }
}

/// Draw one randomized round: U ∈ {1, 7, 40}, C ≤ U, a rate matrix
/// mixing plausible channels with hopeless (1 bit/s → q = 1 gate
/// fails) and borderline ones, plus a chromosome batch containing the
/// empty allocation and random (repaired) candidates.
fn case(rng: &mut Rng) -> Case {
    let u = [1usize, 7, 40][rng.below(3)];
    let c = 1 + rng.below(u);
    let mut params = SystemParams::femnist_small();
    params.num_clients = u;
    params.num_channels = c;
    params.v = 10f64.powf(rng.range(0.0, 3.0));
    let rates: Vec<f64> = (0..u * c)
        .map(|_| {
            if rng.chance(0.15) {
                1.0 // infeasible: communication alone exceeds T^max
            } else if rng.chance(0.1) {
                rng.range(0.8e6, 2e6) // borderline
            } else {
                rng.range(8e6, 40e6)
            }
        })
        .collect();
    let sizes: Vec<f64> = (0..u).map(|_| rng.gaussian(1200.0, 300.0).max(64.0)).collect();
    let total: f64 = sizes.iter().sum();
    let w_full = sizes.iter().map(|d| d / total).collect();
    let mut queues = Queues::new();
    queues.lambda1 = 10f64.powf(rng.range(-1.0, 5.0));
    queues.lambda2 = 10f64.powf(rng.range(-2.0, 4.0));
    let mode = if rng.chance(0.5) { Case5Mode::Taylor } else { Case5Mode::Bisect };
    let mut chroms = vec![Chromosome { alloc: vec![None; c] }];
    for _ in 0..4 {
        chroms.push(Chromosome::random(c, u, rng));
    }
    Case {
        params,
        rates,
        sizes,
        w_full,
        g2: (0..u).map(|_| rng.range(0.01, 25.0)).collect(),
        sigma2: (0..u).map(|_| rng.range(0.01, 4.0)).collect(),
        theta_max: (0..u).map(|_| rng.range(0.05, 2.0)).collect(),
        q_prev: (0..u).map(|_| rng.range(1.0, 14.0)).collect(),
        queues,
        mode,
        chroms,
    }
}

fn bits_of(assigns: &[Option<ClientDecision>]) -> Vec<Option<(usize, Option<u32>, u64, u64)>> {
    assigns
        .iter()
        .map(|a| a.map(|d| (d.channel, d.q, d.f.to_bits(), d.rate.to_bits())))
        .collect()
}

#[test]
fn eval_ctx_bit_identical_to_reference() {
    prop::check("evalctx-vs-reference", prop::iters(60), case, |cs| {
        let state = ChannelState::from_rates(
            cs.params.num_clients,
            cs.params.num_channels,
            cs.rates.clone(),
        );
        let inp = RoundInputs {
            params: &cs.params,
            round: 3,
            channels: &state,
            sizes: &cs.sizes,
            w_full: &cs.w_full,
            g2: &cs.g2,
            sigma2: &cs.sigma2,
            theta_max: &cs.theta_max,
            q_prev: &cs.q_prev,
            queues: &cs.queues,
            avail: None,
        };
        let ctx = EvalCtx::new(&inp, cs.mode);
        let ctx_nomemo = EvalCtx::new(&inp, cs.mode).with_memo(false);
        // One scratch reused across every chromosome: a stale reset
        // would leak the previous allocation into the next result.
        let mut scratch = ctx.make_scratch();
        let mut scratch2 = ctx_nomemo.make_scratch();
        for (k, chrom) in cs.chroms.iter().enumerate() {
            let (j_ref, a_ref) = evaluate_allocation(&inp, chrom, cs.mode);
            let (j_ctx, a_ctx) = ctx.evaluate(chrom, &mut scratch);
            if j_ref.to_bits() != j_ctx.to_bits() {
                return Err(format!("chrom {k}: J0 {j_ref} vs {j_ctx} (memo)"));
            }
            if bits_of(&a_ref) != bits_of(&a_ctx) {
                return Err(format!("chrom {k}: assignments diverged (memo)"));
            }
            // Memo hit: the second pass must replay identical bits.
            let (j_hit, a_hit) = ctx.evaluate(chrom, &mut scratch);
            if j_hit.to_bits() != j_ref.to_bits() || bits_of(&a_hit) != bits_of(&a_ref) {
                return Err(format!("chrom {k}: memo hit diverged"));
            }
            // j0-only fast path.
            if ctx.evaluate_j0(chrom, &mut scratch).to_bits() != j_ref.to_bits() {
                return Err(format!("chrom {k}: evaluate_j0 diverged"));
            }
            // Memo disabled.
            let (j_nm, a_nm) = ctx_nomemo.evaluate(chrom, &mut scratch2);
            if j_nm.to_bits() != j_ref.to_bits() || bits_of(&a_nm) != bits_of(&a_ref) {
                return Err(format!("chrom {k}: memo-off diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn eval_ctx_handles_fully_infeasible_rounds() {
    // Every (client, channel) pair hopeless: both paths must agree on
    // INFINITY with an all-None assignment vector, for every U.
    for u in [1usize, 7, 40] {
        let c = (u / 2).max(1);
        let mut params = SystemParams::femnist_small();
        params.num_clients = u;
        params.num_channels = c;
        let state = ChannelState::from_rates(u, c, vec![1.0; u * c]);
        let sizes = vec![1200.0; u];
        let w_full = vec![1.0 / u as f64; u];
        let g2 = vec![2.0; u];
        let sigma2 = vec![0.5; u];
        let theta_max = vec![0.4; u];
        let q_prev = vec![6.0; u];
        let mut queues = Queues::new();
        queues.lambda1 = 50.0;
        queues.lambda2 = 5.0;
        let inp = RoundInputs {
            params: &params,
            round: 1,
            channels: &state,
            sizes: &sizes,
            w_full: &w_full,
            g2: &g2,
            sigma2: &sigma2,
            theta_max: &theta_max,
            q_prev: &q_prev,
            queues: &queues,
            avail: None,
        };
        let chrom = Chromosome { alloc: (0..c).map(Some).collect() };
        let (j_ref, a_ref) = evaluate_allocation(&inp, &chrom, Case5Mode::Taylor);
        let ctx = EvalCtx::new(&inp, Case5Mode::Taylor);
        let mut scratch = ctx.make_scratch();
        let (j_ctx, a_ctx) = ctx.evaluate(&chrom, &mut scratch);
        assert!(j_ref.is_infinite() && j_ctx.is_infinite(), "U={u}");
        assert_eq!(bits_of(&a_ref), bits_of(&a_ctx), "U={u}");
        assert!(a_ctx.iter().all(|a| a.is_none()), "U={u}");
    }
}
