//! Property tests for the checkpoint snapshot codec (`qccf::ckpt`):
//! random snapshots — adversarial float bit patterns included — must
//! round-trip **bit for bit** through encode/decode, and damaged
//! buffers (truncated, bit-flipped, wrong version, wrong magic,
//! trailing bytes) must be rejected with the *right* typed
//! [`CkptError`] variant. No silent zero-fill, ever.
//!
//! Pure Rust, no artifacts needed. Runs on the in-tree property
//! harness (`qccf::util::prop`): failures print the case seed for
//! exact replay via `QCCF_PROP_SEED`.

use qccf::ckpt::{AvailCkpt, CkptError, ClientCkpt, RunState, Snapshot, VERSION};
use qccf::metrics::{RoundRecord, Trace};
use qccf::util::prop;
use qccf::util::rng::{Rng, RngState};

/// An adversarial f64: specials, arbitrary bit patterns (NaNs with
/// payloads included), and ordinary magnitudes.
fn weird_f64(rng: &mut Rng) -> f64 {
    match rng.below(8) {
        0 => f64::NAN,
        1 => f64::INFINITY,
        2 => f64::NEG_INFINITY,
        3 => -0.0,
        4 => f64::from_bits(rng.next_u64()),
        _ => rng.gaussian(0.0, 100.0),
    }
}

fn weird_f32(rng: &mut Rng) -> f32 {
    match rng.below(6) {
        0 => f32::NAN,
        1 => f32::NEG_INFINITY,
        2 => f32::from_bits(rng.next_u64() as u32),
        _ => rng.gaussian(0.0, 10.0) as f32,
    }
}

fn rand_rng_state(rng: &mut Rng) -> RngState {
    RngState {
        s: [rng.next_u64(), rng.next_u64(), rng.next_u64(), rng.next_u64()],
        spare: rng.chance(0.5).then(|| weird_f64(rng)),
    }
}

fn rand_string(rng: &mut Rng) -> String {
    let choices = [
        "",
        "[scenario]\nname = \"x\"\n",
        "unicode: λ₁/λ₂ → θ^max ✓",
        "line\nbreaks\nand\ttabs",
        "plain-ascii-stem_1.2",
    ];
    choices[rng.below(choices.len())].to_string()
}

fn rand_record(rng: &mut Rng, u: usize) -> RoundRecord {
    RoundRecord {
        round: rng.below(10_000),
        scheduled: rng.below(u + 1),
        aggregated: rng.below(u + 1),
        departed: rng.below(u + 1),
        wire_bytes: rng.below(1 << 30),
        energy: weird_f64(rng),
        cum_energy: weird_f64(rng),
        train_loss: weird_f64(rng),
        test_loss: rng.chance(0.5).then(|| weird_f64(rng)),
        test_acc: rng.chance(0.5).then(|| weird_f64(rng)),
        mean_q: weird_f64(rng),
        q_per_client: (0..u)
            .map(|_| rng.chance(0.7).then(|| rng.next_u64() as u32))
            .collect(),
        lambda1: weird_f64(rng),
        lambda2: weird_f64(rng),
        max_latency: weird_f64(rng),
        decide_seconds: weird_f64(rng),
        compute_seconds: weird_f64(rng),
    }
}

/// A structurally valid snapshot of random shape: 0..~200 model dims,
/// 0..20 clients, 0..8 trace records, optional scheduler stream.
fn rand_snapshot(rng: &mut Rng) -> Snapshot {
    let z = rng.below(200);
    let u = rng.below(20);
    let nrec = rng.below(8);
    let mut trace = Trace::new(["qccf", "same-size", "no-quant"][rng.below(3)]);
    for _ in 0..nrec {
        trace.push(rand_record(rng, u));
    }
    Snapshot {
        scenario_text: rand_string(rng),
        algorithm: trace.algorithm.clone(),
        seed: rng.next_u64(),
        state: RunState {
            round: rng.below(10_000) as u64,
            eps1: weird_f64(rng),
            eps2: weird_f64(rng),
            theta: (0..z).map(|_| weird_f32(rng)).collect(),
            lambda1: weird_f64(rng),
            lambda2: weird_f64(rng),
            queue_history: (0..rng.below(12))
                .map(|_| (weird_f64(rng), weird_f64(rng)))
                .collect(),
            clients: (0..u)
                .map(|_| ClientCkpt {
                    g: weird_f64(rng),
                    sigma: weird_f64(rng),
                    ema: weird_f64(rng),
                    observed: rng.chance(0.5),
                    theta_max: weird_f64(rng),
                    q_prev: weird_f64(rng),
                    rng: rand_rng_state(rng),
                })
                .collect(),
            server_rng: rand_rng_state(rng),
            sched_rng: rng.chance(0.7).then(|| rand_rng_state(rng)),
            avail: rng.chance(0.5).then(|| {
                (0..u)
                    .map(|_| AvailCkpt {
                        on: rng.chance(0.5),
                        missed: rng.next_u64(),
                        rng: rand_rng_state(rng),
                    })
                    .collect()
            }),
            runtime_nanos: [rng.next_u64(), rng.next_u64(), rng.next_u64(), rng.next_u64()],
        },
        trace,
    }
}

#[test]
fn encode_decode_round_trips_bit_for_bit() {
    prop::check("ckpt-round-trip", prop::iters(150), rand_snapshot, |snap| {
        let bytes = snap.encode();
        let back = Snapshot::decode(&bytes)
            .map_err(|e| format!("decode of freshly encoded snapshot failed: {e}"))?;
        // Re-encoding the decoded value must reproduce the exact bytes:
        // that covers every field — floats by bit pattern (NaN payloads
        // and -0.0 included), options, strings, and vec lengths.
        let again = back.encode();
        if again != bytes {
            return Err(format!(
                "re-encode diverged: {} vs {} bytes (first diff at {:?})",
                again.len(),
                bytes.len(),
                bytes.iter().zip(&again).position(|(a, b)| a != b)
            ));
        }
        Ok(())
    });
}

#[test]
fn truncated_buffers_rejected_as_truncated() {
    prop::check(
        "ckpt-truncation",
        prop::iters(100),
        |rng| {
            let snap = rand_snapshot(rng);
            let bytes = snap.encode();
            // Random cut plus the pathological prefixes.
            let cut = match rng.below(4) {
                0 => 0,
                1 => rng.below(16),
                2 => 16,
                _ => rng.below(bytes.len()),
            };
            (bytes, cut)
        },
        |(bytes, cut)| match Snapshot::decode(&bytes[..*cut]) {
            Err(CkptError::Truncated { expected, got }) => {
                if got != *cut {
                    return Err(format!("reported got={got}, actual {cut}"));
                }
                if expected <= got {
                    return Err(format!("expected={expected} not past got={got}"));
                }
                Ok(())
            }
            Err(other) => Err(format!("wrong variant for cut={cut}: {other}")),
            Ok(_) => Err(format!("truncation at {cut} decoded successfully")),
        },
    );
}

#[test]
fn payload_bit_flips_rejected_by_crc() {
    prop::check(
        "ckpt-bit-flip",
        prop::iters(150),
        |rng| {
            let snap = rand_snapshot(rng);
            let bytes = snap.encode();
            // Anywhere from the first payload byte through the CRC
            // itself: either the payload no longer matches its seal or
            // the seal no longer matches its payload.
            let pos = 16 + rng.below(bytes.len() - 16);
            let bit = rng.below(8) as u8;
            (bytes, pos, bit)
        },
        |(bytes, pos, bit)| {
            let mut bad = bytes.clone();
            bad[*pos] ^= 1u8 << *bit;
            match Snapshot::decode(&bad) {
                Err(CkptError::Crc { expected, got }) => {
                    if expected == got {
                        return Err("Crc error with matching checksums".into());
                    }
                    Ok(())
                }
                Err(other) => Err(format!("wrong variant for flip at {pos}: {other}")),
                Ok(_) => Err(format!("bit flip at {pos}:{bit} decoded successfully")),
            }
        },
    );
}

#[test]
fn wrong_version_magic_and_trailing_bytes_rejected() {
    prop::check(
        "ckpt-envelope",
        prop::iters(100),
        |rng| (rand_snapshot(rng).encode(), rng.next_u64()),
        |(bytes, aux)| {
            let mut mix = Rng::seed_from(*aux);

            // Version: any value but VERSION is refused by name, before
            // the CRC is even consulted.
            let mut v = mix.next_u64() as u32;
            if v == VERSION {
                v = VERSION + 1;
            }
            let mut bad = bytes.clone();
            bad[4..8].copy_from_slice(&v.to_le_bytes());
            match Snapshot::decode(&bad) {
                Err(CkptError::Version { got, supported }) => {
                    if got != v || supported != VERSION {
                        return Err(format!("version fields wrong: got={got} sup={supported}"));
                    }
                }
                other => return Err(format!("version patch -> {other:?}")),
            }

            // Magic: corrupt one of the four magic bytes.
            let mut bad = bytes.clone();
            let k = mix.below(4);
            bad[k] ^= 0x5A;
            if !matches!(Snapshot::decode(&bad), Err(CkptError::Magic { .. })) {
                return Err("magic corruption not rejected as Magic".into());
            }

            // Trailing garbage past the envelope.
            let extra = 1 + mix.below(9);
            let mut bad = bytes.clone();
            bad.resize(bytes.len() + extra, 0xAB);
            match Snapshot::decode(&bad) {
                Err(CkptError::Trailing { extra: e }) if e == extra => Ok(()),
                other => Err(format!("{extra} trailing bytes -> {other:?}")),
            }
        },
    );
}
