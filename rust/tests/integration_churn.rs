//! Churn-engine integration: the availability layer's acceptance pins.
//!
//! * A churn-100 run checkpointed mid-horizon and resumed is
//!   **bit-identical** to the uninterrupted run, for engine thread
//!   counts 1 and 8 on the resumed half — the churn analog of
//!   `integration_ckpt.rs`, additionally covering the availability
//!   state (`RunState::avail`) in the snapshot.
//! * The all-depart regression: when every over-selected client departs
//!   mid-round, the round takes the `d_surv = 0` no-aggregate path —
//!   energy spent, nothing folded, θ kept, **no NaN** anywhere the
//!   model touches.
//! * `p_leave = 0` pins the whole churn engine bit-identical to the
//!   always-available engine (churn = false), end to end.
//!
//! All tests no-op (with a note) when `make artifacts` hasn't run.

use std::path::PathBuf;

use qccf::ckpt;
use qccf::experiments::common::{run_scenario, run_scenario_ckpt, CheckpointPolicy};
use qccf::fl::avail::aggregation_target;
use qccf::metrics::Trace;
use qccf::runtime::{artifacts_dir, Runtime};
use qccf::scenario::registry;

fn runtime() -> Option<Runtime> {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::load(&artifacts_dir(), "tiny").expect("load tiny runtime"))
}

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Every deterministic trace field, compared bit for bit (same
/// exclusions as `integration_ckpt.rs`: only the two measured
/// wall-clock fields are skipped).
fn assert_traces_bit_identical(want: &Trace, got: &Trace, tag: &str) {
    assert_eq!(want.algorithm, got.algorithm, "{tag}: algorithm");
    assert_eq!(want.records.len(), got.records.len(), "{tag}: length");
    for (a, b) in want.records.iter().zip(&got.records) {
        let r = a.round;
        assert_eq!(a.round, b.round, "{tag}: round");
        assert_eq!(a.scheduled, b.scheduled, "{tag} r{r}: scheduled");
        assert_eq!(a.aggregated, b.aggregated, "{tag} r{r}: aggregated");
        assert_eq!(a.departed, b.departed, "{tag} r{r}: departed");
        assert_eq!(a.wire_bytes, b.wire_bytes, "{tag} r{r}: wire_bytes");
        assert_eq!(a.energy.to_bits(), b.energy.to_bits(), "{tag} r{r}: energy");
        assert_eq!(a.cum_energy.to_bits(), b.cum_energy.to_bits(), "{tag} r{r}: cum_energy");
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "{tag} r{r}: train_loss");
        assert_eq!(
            a.test_loss.map(f64::to_bits),
            b.test_loss.map(f64::to_bits),
            "{tag} r{r}: test_loss"
        );
        assert_eq!(
            a.test_acc.map(f64::to_bits),
            b.test_acc.map(f64::to_bits),
            "{tag} r{r}: test_acc"
        );
        assert_eq!(a.mean_q.to_bits(), b.mean_q.to_bits(), "{tag} r{r}: mean_q");
        assert_eq!(a.q_per_client, b.q_per_client, "{tag} r{r}: q_per_client");
        assert_eq!(a.lambda1.to_bits(), b.lambda1.to_bits(), "{tag} r{r}: lambda1");
        assert_eq!(a.lambda2.to_bits(), b.lambda2.to_bits(), "{tag} r{r}: lambda2");
        assert_eq!(a.max_latency.to_bits(), b.max_latency.to_bits(), "{tag} r{r}: max_latency");
    }
}

/// churn-100 shrunk to test scale (the data volume, not the physics or
/// the churn knobs), 12-round horizon — the same shrink
/// `integration_ckpt.rs` applies to paper-femnist.
fn churn_scenario_12() -> qccf::scenario::Scenario {
    let mut sc = registry::churn_100();
    sc.data.size_mean = 300.0;
    sc.data.size_std = 60.0;
    sc.data.test_size = 128;
    sc.train.rounds = 12;
    sc
}

#[test]
fn churn_checkpoint_at_6_resume_bit_identical_to_straight_12() {
    // The churn acceptance pin: churn-100 (over-selection 0.5,
    // staleness weighting on) 12 rounds straight vs checkpoint-at-6 +
    // resume, whole-trace bit equality including the departed column —
    // with the interrupted half at 8 engine threads and the resumed
    // half at both 1 and 8. Passing at both thread counts also pins the
    // "availability draws are thread-count invariant" half of the
    // determinism contract at the full-engine level.
    let Some(rt) = runtime() else { return };
    let sc = churn_scenario_12();
    let seed = 5u64;

    let reference = run_scenario(&rt, &sc, "qccf", seed, 1).unwrap();
    assert_eq!(reference.records.len(), 12);
    // Over-selection's cap is a hard invariant of every record.
    for r in &reference.records {
        assert!(
            r.aggregated <= aggregation_target(r.scheduled, sc.train.over_select),
            "round {}: aggregated {} > target of {} scheduled",
            r.round,
            r.aggregated,
            r.scheduled
        );
    }

    // Full 12-round run at 8 threads: threads are a non-input even with
    // the availability chain in the loop.
    let threads8 = run_scenario(&rt, &sc, "qccf", seed, 8).unwrap();
    assert_traces_bit_identical(&reference, &threads8, "threads=8 straight");

    // "Interrupted" run: 6-round horizon with a snapshot at round 6.
    let ckpt_dir = fresh_dir("qccf_integration_churn_ckpt");
    let mut sc6 = sc.clone();
    sc6.train.rounds = 6;
    let part = run_scenario_ckpt(
        &rt,
        &sc6,
        "qccf",
        seed,
        8,
        &CheckpointPolicy {
            every: 6,
            dir: Some(ckpt_dir.clone()),
            resume: None,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(part.records.len(), 6);
    let snap_path = ckpt_dir.join(ckpt::snapshot_file_name(&sc.name, "qccf", seed));
    assert!(snap_path.exists(), "snapshot not written at round 6");

    let prefix =
        Trace { algorithm: reference.algorithm.clone(), records: reference.records[..6].to_vec() };
    assert_traces_bit_identical(&prefix, &part, "prefix");

    // Resume must replay the exact availability future the straight run
    // saw — the snapshot's RunState::avail carries every client's
    // on/off flag, missed counter, and Markov stream position.
    for threads in [1usize, 8] {
        let resumed = run_scenario_ckpt(
            &rt,
            &sc,
            "qccf",
            seed,
            threads,
            &CheckpointPolicy {
                every: 0,
                dir: None,
                resume: Some(snap_path.clone()),
                ..Default::default()
            },
        )
        .unwrap();
        assert_traces_bit_identical(&reference, &resumed, &format!("resumed threads={threads}"));
    }

    std::fs::remove_dir_all(&ckpt_dir).ok();
}

#[test]
fn all_departed_round_takes_no_aggregate_path_without_nan() {
    // Adversarial knobs: p_leave = 1, p_join = 0. Round 1 decides over
    // the initial all-on mask, then the post-decide tick flips every
    // client off — every scheduled client departs mid-round, so the
    // round must take the d_surv = 0 no-aggregate path: energy and
    // airtime spent, nothing folded, θ^{n+1} = θ^n. Every later round
    // short-circuits before the scheduler (nobody ever rejoins). The
    // old unguarded 0/0 weight division would have poisoned θ with NaN
    // here; eval must stay finite for the whole horizon.
    let Some(rt) = runtime() else { return };
    let mut sc = churn_scenario_12();
    sc.train.rounds = 4;
    sc.train.eval_every = 1;
    sc.train.p_leave = 1.0;
    sc.train.p_join = 0.0;
    let seed = 11u64;

    let trace = run_scenario(&rt, &sc, "qccf", seed, 1).unwrap();
    assert_eq!(trace.records.len(), 4);

    let r1 = &trace.records[0];
    assert!(r1.scheduled > 0, "round 1 must schedule from the all-on mask");
    assert_eq!(r1.departed, r1.scheduled, "every scheduled client departs");
    assert_eq!(r1.aggregated, 0, "departed uploads must not be folded");
    assert!(r1.energy > 0.0, "departure energy is spent, not refunded");
    assert!(r1.wire_bytes > 0, "departure airtime is spent, not refunded");

    for r in &trace.records[1..] {
        assert_eq!(r.scheduled, 0, "round {}: all-off mask must short-circuit", r.round);
        assert_eq!(r.departed, 0, "round {}", r.round);
        assert_eq!(r.aggregated, 0, "round {}", r.round);
        assert_eq!(r.energy, 0.0, "round {}: no clients, no energy", r.round);
    }

    // θ was never touched by a fold, so every evaluation is of the
    // initial model — finite, and identical across the horizon.
    let mut evals = trace.records.iter().filter_map(|r| r.test_loss);
    let first = evals.next().expect("eval_every = 1 must evaluate round 1");
    assert!(first.is_finite(), "NaN θ leaked into evaluation");
    for l in evals {
        assert_eq!(l.to_bits(), first.to_bits(), "θ changed without any aggregate");
    }

    // The no-aggregate path is thread-count invariant too.
    let t8 = run_scenario(&rt, &sc, "qccf", seed, 8).unwrap();
    assert_traces_bit_identical(&trace, &t8, "all-departed threads=8");
}

#[test]
fn p_leave_zero_engine_bit_identical_to_churn_off() {
    // With p_leave = 0 the Markov chain never leaves the all-on state:
    // the mask is always all-true (bit-identical decisions — pinned at
    // the unit level), nobody departs, the over-selection target at
    // β = 0 is the identity, and staleness is off — so the churn engine
    // must retrace the churn = false engine bit for bit, end to end.
    let Some(rt) = runtime() else { return };
    let mut churn = churn_scenario_12();
    churn.train.rounds = 8;
    churn.train.p_leave = 0.0;
    churn.train.over_select = 0.0;
    churn.train.staleness = false;
    let mut plain = churn.clone();
    plain.train.churn = false;
    let seed = 7u64;

    let a = run_scenario(&rt, &churn, "qccf", seed, 1).unwrap();
    let b = run_scenario(&rt, &plain, "qccf", seed, 1).unwrap();
    assert_traces_bit_identical(&b, &a, "p_leave=0 vs churn off");
    assert!(a.records.iter().all(|r| r.departed == 0), "p_leave = 0 cannot depart anyone");
}
