//! Observability bit-identity pin: profiling must be a pure
//! side-channel. A run with the obs gate ON produces byte-identical
//! deterministic outputs — per-unit JSONL traces, sketch sidecars,
//! summary.csv, checkpoint snapshots — to the same run with the gate
//! OFF (`QCCF_OBS=0`), at engine/sweep thread counts 1 and 8. Only
//! `ledger.jsonl` (the completion-ordered wall-clock journal) may
//! differ; it is explicitly excluded from the `--out` contract
//! (docs/OBSERVABILITY.md).
//!
//! One `#[test]` on purpose: the obs gate is process-global state, so
//! the on/off phases must not interleave with a concurrent test.
//!
//! No-ops (with a note) when `make artifacts` hasn't run.

use std::path::{Path, PathBuf};

use qccf::ckpt;
use qccf::experiments::common::{run_scenario_ckpt, CheckpointPolicy};
use qccf::experiments::sweep;
use qccf::runtime::{artifacts_dir, Runtime};
use qccf::scenario::registry;

fn runtime() -> Option<Runtime> {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::load(&artifacts_dir(), "tiny").expect("load tiny runtime"))
}

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// paper-femnist shrunk to test scale, like the ckpt battery uses.
fn small_scenario(rounds: usize) -> qccf::scenario::Scenario {
    let mut sc = registry::paper_femnist();
    sc.data.size_mean = 300.0;
    sc.data.size_std = 60.0;
    sc.data.test_size = 128;
    sc.train.rounds = rounds;
    sc
}

/// Byte equality of one file across the two output directories.
fn assert_same_bytes(on: &Path, off: &Path, tag: &str) {
    let a = std::fs::read(on).unwrap_or_else(|e| panic!("{tag}: read {}: {e}", on.display()));
    let b = std::fs::read(off).unwrap_or_else(|e| panic!("{tag}: read {}: {e}", off.display()));
    assert_eq!(a, b, "{tag}: bytes differ between QCCF_OBS on and off");
}

#[test]
fn profiled_outputs_are_bit_identical_to_unprofiled() {
    let Some(rt) = runtime() else { return };

    // Phase 1 — sweep path: JSONL trace, sketch sidecar, and
    // summary.csv bytes must not depend on the obs gate, at sweep
    // thread counts 1 and 8.
    for threads in [1usize, 8] {
        let mut dirs = Vec::new();
        for enabled in [true, false] {
            let out = fresh_dir(&format!("qccf_obs_ident_sweep_{threads}_{enabled}"));
            qccf::obs::set_enabled(enabled);
            let cfg = sweep::SweepConfig {
                scenarios: vec![small_scenario(2)],
                seeds: vec![1],
                algorithms: Some(vec!["qccf".into()]),
                rounds: Some(2),
                out_dir: out.clone(),
                threads,
                resume: false,
                checkpoint_every: 0,
            };
            let rows = sweep::run(&rt, &cfg).unwrap();
            assert_eq!(rows.len(), 1);
            assert_eq!(rows[0].status, "ok");
            dirs.push(out);
        }
        qccf::obs::set_enabled(true);
        let stem = sweep::unit_stem("paper-femnist", "qccf", 1);
        for name in [format!("{stem}.jsonl"), format!("{stem}.sketch.json"), "summary.csv".into()]
        {
            assert_same_bytes(
                &dirs[0].join(&name),
                &dirs[1].join(&name),
                &format!("sweep threads={threads} {name}"),
            );
        }
        // The ledger is the sanctioned exception: it must exist in the
        // profiled run (it records spans) and in the unprofiled run
        // (appends are not gated — only span measurement is).
        assert!(dirs[0].join("ledger.jsonl").exists());
        assert!(dirs[1].join("ledger.jsonl").exists());
        for d in dirs {
            std::fs::remove_dir_all(&d).ok();
        }
    }

    // Phase 2 — checkpoint path: snapshot bytes (which embed the trace
    // with its wall columns zeroed at capture) must not depend on the
    // obs gate, at engine thread counts 1 and 8.
    let sc = small_scenario(4);
    for threads in [1usize, 8] {
        let mut snaps = Vec::new();
        for enabled in [true, false] {
            let ckpt_dir = fresh_dir(&format!("qccf_obs_ident_ckpt_{threads}_{enabled}"));
            qccf::obs::set_enabled(enabled);
            let policy = CheckpointPolicy {
                every: 4,
                dir: Some(ckpt_dir.clone()),
                resume: None,
                ..Default::default()
            };
            let trace = run_scenario_ckpt(&rt, &sc, "qccf", 3, threads, &policy).unwrap();
            assert_eq!(trace.records.len(), 4);
            snaps.push(ckpt_dir);
        }
        qccf::obs::set_enabled(true);
        let name = ckpt::snapshot_file_name(&sc.name, "qccf", 3);
        assert_same_bytes(
            &snaps[0].join(&name),
            &snaps[1].join(&name),
            &format!("snapshot threads={threads}"),
        );
        for d in snaps {
            std::fs::remove_dir_all(&d).ok();
        }
    }
}
