//! Integration tests over the AOT artifacts: Rust ⇄ PJRT ⇄ lowered
//! JAX/Pallas. These are the cross-layer correctness guarantees — in
//! particular that the Rust quantizer mirror and the Pallas kernel
//! artifact agree **bit for bit** given the same noise stream.
//!
//! All tests no-op (with a note) when `make artifacts` hasn't run.

use qccf::quant;
use qccf::runtime::{artifacts_dir, Runtime};
use qccf::util::rng::Rng;
use qccf::util::stats::linf_norm;
use qccf::util::threadpool;

fn runtime() -> Option<Runtime> {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::load(&artifacts_dir(), "tiny").expect("load tiny runtime"))
}

fn toy_batches(rt: &Runtime, seed: u64) -> (Vec<f32>, Vec<i32>) {
    // Class-prototype toy data (learnable in a few steps).
    let info = &rt.info;
    let pix = info.pix();
    let mut rng = Rng::seed_from(seed);
    let protos: Vec<f32> =
        (0..info.classes * pix).map(|_| rng.gaussian(0.0, 1.0) as f32).collect();
    let n = info.tau * info.batch;
    let mut xs = Vec::with_capacity(n * pix);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let label = rng.below(info.classes);
        ys.push(label as i32);
        for p in 0..pix {
            xs.push(protos[label * pix + p] + 0.1 * rng.gaussian(0.0, 1.0) as f32);
        }
    }
    (xs, ys)
}

#[test]
fn init_is_deterministic_and_sized() {
    let Some(rt) = runtime() else { return };
    let a = rt.init().unwrap();
    let b = rt.init().unwrap();
    assert_eq!(a.len(), rt.info.z);
    assert_eq!(a, b);
    assert!(linf_norm(&a) > 0.0);
}

#[test]
fn train_step_learns_toy_task() {
    let Some(rt) = runtime() else { return };
    let mut theta = rt.init().unwrap();
    let (xs, ys) = toy_batches(&rt, 3);
    let first = rt.train_step(&theta, &xs, &ys, 0.05).unwrap();
    assert_eq!(first.gnorms.len(), rt.info.tau);
    assert!(first.gnorms.iter().all(|&g| g > 0.0));
    theta = first.theta;
    let mut last_loss = first.mean_loss;
    for _ in 0..10 {
        let out = rt.train_step(&theta, &xs, &ys, 0.05).unwrap();
        theta = out.theta;
        last_loss = out.mean_loss;
    }
    assert!(
        last_loss < first.mean_loss * 0.7,
        "loss did not decrease: {} -> {last_loss}",
        first.mean_loss
    );
}

#[test]
fn train_step_zero_lr_identity() {
    let Some(rt) = runtime() else { return };
    let theta = rt.init().unwrap();
    let (xs, ys) = toy_batches(&rt, 5);
    let out = rt.train_step(&theta, &xs, &ys, 0.0).unwrap();
    assert_eq!(out.theta, theta);
}

#[test]
fn concurrent_execute_matches_serial() {
    // The round engine shares one &Runtime across workers: concurrent
    // `execute` through the PJRT CPU client must yield the same bits as
    // back-to-back serial calls (PJRT thread-safety contract; see the
    // `unsafe impl Sync for Runtime` note and QCCF_PJRT_SERIALIZE).
    let Some(rt) = runtime() else { return };
    let theta = rt.init().unwrap();
    let batches: Vec<(Vec<f32>, Vec<i32>)> = (0..8u64).map(|k| toy_batches(&rt, 50 + k)).collect();
    let step = |xs: &[f32], ys: &[i32]| -> Vec<u32> {
        let out = rt.train_step(&theta, xs, ys, 0.05).unwrap();
        out.theta.iter().map(|x| x.to_bits()).collect()
    };
    let serial: Vec<Vec<u32>> = batches.iter().map(|(xs, ys)| step(xs, ys)).collect();
    for threads in [2, 4, 8] {
        let parallel: Vec<Vec<u32>> =
            threadpool::parallel_map(&batches, threads, |_, (xs, ys)| step(xs, ys));
        assert_eq!(serial, parallel, "divergence at {threads} threads");
    }
}

#[test]
fn quantize_artifact_matches_rust_mirror_bitwise() {
    // The L1 Pallas kernel (through HLO + PJRT) and quant::stochastic_
    // quantize implement the same float ops in the same order; with the
    // same noise they must agree exactly.
    let Some(rt) = runtime() else { return };
    let theta = rt.init().unwrap();
    let mut rng = Rng::seed_from(11);
    let mut noise = vec![0.0f32; rt.info.z];
    for q in [1.0f32, 3.0, 8.0, 16.0] {
        rng.fill_uniform_f32(&mut noise);
        let (hlo, hlo_max) = rt.quantize(&theta, &noise, q).unwrap();
        let (rust, rust_max) = quant::stochastic_quantize(&theta, &noise, q);
        assert_eq!(hlo_max, rust_max, "theta_max mismatch at q={q}");
        let diff = hlo.iter().zip(&rust).filter(|(a, b)| a != b).count();
        assert_eq!(diff, 0, "{diff} mismatching elements at q={q}");
    }
}

#[test]
fn quantize_error_obeys_lemma1_bound() {
    let Some(rt) = runtime() else { return };
    let theta = rt.init().unwrap();
    let mut rng = Rng::seed_from(13);
    let mut noise = vec![0.0f32; rt.info.z];
    for q in [2u32, 6] {
        let mut mse = 0.0f64;
        let reps = 20;
        let mut tmax = 0.0f32;
        for _ in 0..reps {
            rng.fill_uniform_f32(&mut noise);
            let (out, m) = rt.quantize(&theta, &noise, q as f32).unwrap();
            tmax = m;
            mse += out
                .iter()
                .zip(&theta)
                .map(|(&o, &t)| ((o - t) as f64).powi(2))
                .sum::<f64>();
        }
        let bound = quant::error_bound(rt.info.z, tmax as f64, q);
        assert!(mse / reps as f64 <= bound * 1.05, "q={q}");
    }
}

#[test]
fn eval_masks_padding() {
    let Some(rt) = runtime() else { return };
    let theta = rt.init().unwrap();
    let info = &rt.info;
    let pix = info.pix();
    let mut rng = Rng::seed_from(17);
    let x: Vec<f32> =
        (0..info.eval_batch * pix).map(|_| rng.gaussian(0.0, 1.0) as f32).collect();
    let y: Vec<i32> = (0..info.eval_batch).map(|_| rng.below(info.classes) as i32).collect();
    let half = info.eval_batch / 2;
    let mut w = vec![0.0f32; info.eval_batch];
    for v in w.iter_mut().take(half) {
        *v = 1.0;
    }
    let (loss, correct, n) = rt.eval_chunk(&theta, &x, &y, &w).unwrap();
    assert_eq!(n, half as f64);
    assert!(correct <= half as f64);
    assert!(loss.is_finite());
}

#[test]
fn evaluate_full_set_chunks_and_pads() {
    let Some(rt) = runtime() else { return };
    let theta = rt.init().unwrap();
    let pix = rt.info.pix();
    let mut rng = Rng::seed_from(19);
    // Deliberately not a multiple of eval_batch.
    let n = rt.info.eval_batch + rt.info.eval_batch / 3 + 1;
    let images: Vec<f32> = (0..n * pix).map(|_| rng.gaussian(0.0, 1.0) as f32).collect();
    let labels: Vec<i32> = (0..n).map(|_| rng.below(rt.info.classes) as i32).collect();
    let (loss, acc) = rt.evaluate(&theta, &images, &labels).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!((0.0..=1.0).contains(&acc));
}
