//! Full-stack FL integration: server loop over the tiny profile with
//! every scheduler — loss must fall, accuracy must beat chance, energy
//! accounting must be positive and finite, traces deterministic per seed.
//!
//! All tests no-op (with a note) when `make artifacts` hasn't run.

use qccf::baselines::{make_scheduler, ALL_ALGORITHMS};
use qccf::data::{self, DataGenConfig};
use qccf::experiments::common::params_for;
use qccf::experiments::Task;
use qccf::fl::Server;
use qccf::runtime::{artifacts_dir, Runtime};

fn runtime() -> Option<Runtime> {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::load(&artifacts_dir(), "tiny").expect("load tiny runtime"))
}

fn make_server<'rt>(rt: &'rt Runtime, alg: &str, seed: u64) -> Server<'rt> {
    let params = params_for(rt, Task::Femnist, 300.0);
    let mut dcfg = DataGenConfig::new(params.num_clients, rt.info.image, rt.info.classes);
    dcfg.size_mean = 300.0;
    dcfg.size_std = 60.0;
    dcfg.test_size = 128;
    let fed = data::generate(&dcfg, seed);
    let sched = make_scheduler(alg, seed).unwrap();
    let mut s = Server::new(params, rt, fed, sched, seed).expect("server");
    s.eval_every = 2;
    s
}

#[test]
fn qccf_learns_and_accounts_energy() {
    let Some(rt) = runtime() else { return };
    let mut server = make_server(&rt, "qccf", 1);
    let trace = server.run(10).unwrap();
    assert_eq!(trace.records.len(), 10);
    let acc = trace.best_accuracy().expect("eval ran");
    assert!(acc > 0.5, "accuracy {acc} not above chance");
    assert!(trace.total_energy() > 0.0);
    assert!(trace.total_energy().is_finite());
    // Cumulative energy is monotone.
    let mut prev = 0.0;
    for r in &trace.records {
        assert!(r.cum_energy >= prev);
        prev = r.cum_energy;
        assert!(r.lambda1.is_finite() && r.lambda2.is_finite());
        assert!(r.lambda1 >= 0.0 && r.lambda2 >= 0.0);
    }
}

#[test]
fn every_scheduler_completes_rounds() {
    let Some(rt) = runtime() else { return };
    for alg in ALL_ALGORITHMS {
        let mut server = make_server(&rt, alg, 2);
        let trace = server.run(4).unwrap();
        assert_eq!(trace.records.len(), 4, "{alg}");
        let scheduled: usize = trace.records.iter().map(|r| r.scheduled).sum();
        assert!(scheduled > 0, "{alg}: nothing ever scheduled");
        assert!(trace.total_energy() > 0.0, "{alg}");
        // Aggregated ≤ scheduled (dropouts possible but not negative).
        for r in &trace.records {
            assert!(r.aggregated <= r.scheduled, "{alg}");
        }
    }
}

#[test]
fn traces_deterministic_per_seed() {
    let Some(rt) = runtime() else { return };
    let t1 = make_server(&rt, "qccf", 7).run(4).unwrap();
    let t2 = make_server(&rt, "qccf", 7).run(4).unwrap();
    for (a, b) in t1.records.iter().zip(&t2.records) {
        assert_eq!(a.scheduled, b.scheduled);
        assert_eq!(a.energy, b.energy);
        assert_eq!(a.mean_q, b.mean_q);
        assert_eq!(a.test_acc, b.test_acc);
    }
    let t3 = make_server(&rt, "qccf", 8).run(4).unwrap();
    let same = t1
        .records
        .iter()
        .zip(&t3.records)
        .all(|(a, b)| a.energy == b.energy && a.mean_q == b.mean_q);
    assert!(!same, "different seeds must diverge");
}

#[test]
fn quantizing_schedulers_report_levels() {
    let Some(rt) = runtime() else { return };
    for alg in ["qccf", "channel-allocate", "principle", "same-size"] {
        let trace = make_server(&rt, alg, 3).run(4).unwrap();
        let any_q = trace.records.iter().any(|r| r.mean_q >= 1.0);
        assert!(any_q, "{alg}: no quantization levels recorded");
    }
}

#[test]
fn no_quant_uploads_raw() {
    let Some(rt) = runtime() else { return };
    let trace = make_server(&rt, "no-quant", 4).run(3).unwrap();
    for r in &trace.records {
        // mean_q counts only quantized uploads (q ≥ 1) — none here.
        assert_eq!(r.mean_q, 0.0);
        for q in r.q_per_client.iter().flatten() {
            assert_eq!(*q, 0, "raw upload sentinel");
        }
    }
}

#[test]
fn queue_pressure_raises_q_over_time() {
    // Remark 1 at system level: QCCF's mean q in late rounds should not
    // be below its first-round value.
    let Some(rt) = runtime() else { return };
    let trace = make_server(&rt, "qccf", 5).run(10).unwrap();
    let qs: Vec<f64> = trace.records.iter().filter(|r| r.mean_q > 0.0).map(|r| r.mean_q).collect();
    assert!(qs.len() >= 3);
    let early = qs[..2.min(qs.len())].iter().sum::<f64>() / 2.0;
    let late = qs[qs.len().saturating_sub(2)..].iter().sum::<f64>() / 2.0;
    assert!(
        late >= early - 0.75,
        "q collapsed over training: early {early:.2} late {late:.2} ({qs:?})"
    );
}
