//! Full-stack FL integration: server loop over the tiny profile with
//! every scheduler — loss must fall, accuracy must beat chance, energy
//! accounting must be positive and finite, traces deterministic per seed.
//!
//! All tests no-op (with a note) when `make artifacts` hasn't run.

use qccf::baselines::{make_scheduler_with_threads, ALL_ALGORITHMS};
use qccf::config::SystemParams;
use qccf::data::{self, DataGenConfig};
use qccf::experiments::common::params_for;
use qccf::experiments::Task;
use qccf::fl::exec::{self, ClientTask, Upload, WorkerScratch};
use qccf::fl::Server;
use qccf::quant;
use qccf::runtime::{artifacts_dir, Runtime};
use qccf::sched::{ClientDecision, RoundDecision, RoundInputs, Scheduler};
use qccf::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::load(&artifacts_dir(), "tiny").expect("load tiny runtime"))
}

fn make_server_threads<'rt>(rt: &'rt Runtime, alg: &str, seed: u64, threads: usize) -> Server<'rt> {
    let params = params_for(rt, Task::Femnist, 300.0);
    let mut dcfg = DataGenConfig::new(params.num_clients, rt.info.image, rt.info.classes);
    dcfg.size_mean = 300.0;
    dcfg.size_std = 60.0;
    dcfg.test_size = 128;
    let fed = data::generate(&dcfg, seed);
    let sched = make_scheduler_with_threads(alg, seed, threads).unwrap();
    let mut s = Server::new(params, rt, fed, sched, seed).expect("server");
    s.eval_every = 2;
    s.threads = threads;
    s
}

fn make_server<'rt>(rt: &'rt Runtime, alg: &str, seed: u64) -> Server<'rt> {
    make_server_threads(rt, alg, seed, 1)
}

#[test]
fn qccf_learns_and_accounts_energy() {
    let Some(rt) = runtime() else { return };
    let mut server = make_server(&rt, "qccf", 1);
    let trace = server.run(10).unwrap();
    assert_eq!(trace.records.len(), 10);
    let acc = trace.best_accuracy().expect("eval ran");
    assert!(acc > 0.5, "accuracy {acc} not above chance");
    assert!(trace.total_energy() > 0.0);
    assert!(trace.total_energy().is_finite());
    // Cumulative energy is monotone.
    let mut prev = 0.0;
    for r in &trace.records {
        assert!(r.cum_energy >= prev);
        prev = r.cum_energy;
        assert!(r.lambda1.is_finite() && r.lambda2.is_finite());
        assert!(r.lambda1 >= 0.0 && r.lambda2 >= 0.0);
    }
}

#[test]
fn every_scheduler_completes_rounds() {
    let Some(rt) = runtime() else { return };
    for alg in ALL_ALGORITHMS {
        let mut server = make_server(&rt, alg, 2);
        let trace = server.run(4).unwrap();
        assert_eq!(trace.records.len(), 4, "{alg}");
        let scheduled: usize = trace.records.iter().map(|r| r.scheduled).sum();
        assert!(scheduled > 0, "{alg}: nothing ever scheduled");
        assert!(trace.total_energy() > 0.0, "{alg}");
        // Aggregated ≤ scheduled (dropouts possible but not negative).
        for r in &trace.records {
            assert!(r.aggregated <= r.scheduled, "{alg}");
        }
    }
}

#[test]
fn traces_deterministic_per_seed() {
    let Some(rt) = runtime() else { return };
    let t1 = make_server(&rt, "qccf", 7).run(4).unwrap();
    let t2 = make_server(&rt, "qccf", 7).run(4).unwrap();
    for (a, b) in t1.records.iter().zip(&t2.records) {
        assert_eq!(a.scheduled, b.scheduled);
        assert_eq!(a.energy, b.energy);
        assert_eq!(a.mean_q, b.mean_q);
        assert_eq!(a.test_acc, b.test_acc);
    }
    let t3 = make_server(&rt, "qccf", 8).run(4).unwrap();
    let same = t1
        .records
        .iter()
        .zip(&t3.records)
        .all(|(a, b)| a.energy == b.energy && a.mean_q == b.mean_q);
    assert!(!same, "different seeds must diverge");
}

#[test]
fn quantizing_schedulers_report_levels() {
    let Some(rt) = runtime() else { return };
    for alg in ["qccf", "channel-allocate", "principle", "same-size"] {
        let trace = make_server(&rt, alg, 3).run(4).unwrap();
        let any_q = trace.records.iter().any(|r| r.mean_q >= 1.0);
        assert!(any_q, "{alg}: no quantization levels recorded");
    }
}

#[test]
fn no_quant_uploads_raw() {
    let Some(rt) = runtime() else { return };
    let trace = make_server(&rt, "no-quant", 4).run(3).unwrap();
    for r in &trace.records {
        // mean_q counts only quantized uploads (q ≥ 1) — none here.
        assert_eq!(r.mean_q, 0.0);
        for q in r.q_per_client.iter().flatten() {
            assert_eq!(*q, 0, "raw upload sentinel");
        }
    }
}

#[test]
fn parallel_round_bit_identical_to_serial() {
    // The engine's determinism contract (see fl::exec): `threads = N`
    // must produce bit-identical θ and identical Trace records to the
    // legacy serial path, GA fitness fan-out included.
    let Some(rt) = runtime() else { return };
    let mut serial = make_server_threads(&rt, "qccf", 11, 1);
    let mut parallel = make_server_threads(&rt, "qccf", 11, 4);
    let t1 = serial.run(4).unwrap();
    let t4 = parallel.run(4).unwrap();
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&serial.theta), bits(&parallel.theta), "theta diverged");
    assert_eq!(t1.records.len(), t4.records.len());
    for (a, b) in t1.records.iter().zip(&t4.records) {
        assert_eq!(a.scheduled, b.scheduled);
        assert_eq!(a.aggregated, b.aggregated);
        assert_eq!(a.energy, b.energy);
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
        assert_eq!(a.test_loss, b.test_loss);
        assert_eq!(a.test_acc, b.test_acc);
        assert_eq!(a.mean_q, b.mean_q);
        assert_eq!(a.q_per_client, b.q_per_client);
        assert_eq!(a.lambda1, b.lambda1);
        assert_eq!(a.lambda2, b.lambda2);
        assert_eq!(a.max_latency, b.max_latency);
    }
}

#[test]
fn decision_cache_trace_bit_identical_on_off() {
    // The decision-stage caches (per-round `sched::EvalCtx` solve memo
    // + GA fitness cache, PR-4) must not move a single trace bit — at
    // 1 worker and at 8. Cache hits replay exact f64-bit-keyed
    // results, so a QCCF run with caching disabled is the reference
    // the cached run must reproduce exactly.
    let Some(rt) = runtime() else { return };
    for threads in [1usize, 8] {
        let run = |cache: bool| {
            let params = params_for(&rt, Task::Femnist, 300.0);
            let mut dcfg = DataGenConfig::new(params.num_clients, rt.info.image, rt.info.classes);
            dcfg.size_mean = 300.0;
            dcfg.size_std = 60.0;
            dcfg.test_size = 128;
            let fed = data::generate(&dcfg, 13);
            let sched = Box::new(
                qccf::sched::qccf::QccfScheduler::new(13)
                    .with_threads(threads)
                    .with_cache(cache),
            );
            let mut s = Server::new(params, &rt, fed, sched, 13).expect("server");
            s.eval_every = 2;
            s.threads = threads;
            let trace = s.run(4).unwrap();
            let theta: Vec<u32> = s.theta.iter().map(|x| x.to_bits()).collect();
            (trace, theta)
        };
        let (t_on, th_on) = run(true);
        let (t_off, th_off) = run(false);
        assert_eq!(th_on, th_off, "theta diverged (threads={threads})");
        assert_eq!(t_on.records.len(), t_off.records.len());
        for (a, b) in t_on.records.iter().zip(&t_off.records) {
            assert_eq!(a.scheduled, b.scheduled, "threads={threads}");
            assert_eq!(a.aggregated, b.aggregated, "threads={threads}");
            assert_eq!(a.wire_bytes, b.wire_bytes, "threads={threads}");
            assert_eq!(a.energy.to_bits(), b.energy.to_bits(), "threads={threads}");
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "threads={threads}");
            assert_eq!(a.test_loss, b.test_loss, "threads={threads}");
            assert_eq!(a.test_acc, b.test_acc, "threads={threads}");
            assert_eq!(a.mean_q, b.mean_q, "threads={threads}");
            assert_eq!(a.q_per_client, b.q_per_client, "threads={threads}");
            assert_eq!(a.lambda1.to_bits(), b.lambda1.to_bits(), "threads={threads}");
            assert_eq!(a.lambda2.to_bits(), b.lambda2.to_bits(), "threads={threads}");
            assert_eq!(a.max_latency.to_bits(), b.max_latency.to_bits(), "threads={threads}");
        }
    }
}

/// Test-only scheduler that replays a fixed decision every round.
struct FixedScheduler {
    assignments: Vec<Option<ClientDecision>>,
}

impl Scheduler for FixedScheduler {
    fn name(&self) -> &'static str {
        "fixed"
    }
    fn decide(&mut self, _inp: &RoundInputs<'_>) -> RoundDecision {
        RoundDecision {
            assignments: self.assignments.clone(),
            j0: f64::NAN,
            evals: 0,
            deadline_exempt: false,
        }
    }
}

#[test]
fn timed_out_uploads_renormalized_out_of_aggregation() {
    // C4 regression: a client past T^max spends its energy but must be
    // renormalized out of eq. (2). The aggregate must equal the weighted
    // mean over the *surviving* uploads only — i.e. bit-identical to a
    // round that never scheduled the straggler at all.
    let Some(rt) = runtime() else { return };
    let params = params_for(&rt, Task::Femnist, 300.0);
    let u = params.num_clients;
    let good = |ch: usize| {
        Some(ClientDecision { channel: ch, q: Some(4), f: params.f_max, rate: 50e6 })
    };
    let mut with_straggler: Vec<Option<ClientDecision>> = vec![None; u];
    with_straggler[0] = good(0);
    with_straggler[1] = good(1);
    // 1 bit/s: communication alone exceeds T^max by orders of magnitude.
    with_straggler[2] =
        Some(ClientDecision { channel: 2, q: Some(4), f: params.f_max, rate: 1.0 });
    let mut without_straggler: Vec<Option<ClientDecision>> = vec![None; u];
    without_straggler[0] = good(0);
    without_straggler[1] = good(1);

    let run = |assignments: Vec<Option<ClientDecision>>| {
        let mut dcfg = DataGenConfig::new(params.num_clients, rt.info.image, rt.info.classes);
        dcfg.size_mean = 300.0;
        dcfg.size_std = 60.0;
        dcfg.test_size = 128;
        let fed = data::generate(&dcfg, 6);
        let mut server =
            Server::new(params.clone(), &rt, fed, Box::new(FixedScheduler { assignments }), 6)
                .unwrap();
        server.eval_every = 0;
        let rec = server.run_round().unwrap();
        (server.theta.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(), rec)
    };
    let (theta_a, rec_a) = run(with_straggler);
    let (theta_b, rec_b) = run(without_straggler);

    assert_eq!(rec_a.scheduled, 3);
    assert_eq!(rec_a.aggregated, 2, "straggler was not dropped");
    assert!(rec_a.aggregated < rec_a.scheduled);
    assert_eq!(rec_b.scheduled, 2);
    assert_eq!(rec_b.aggregated, 2, "survivors unexpectedly dropped");
    // Straggler energy is spent even though its upload is dropped.
    assert!(rec_a.energy > rec_b.energy);
    assert_eq!(theta_a, theta_b, "aggregate not renormalized over survivors");
}

#[test]
fn wire_transport_bit_identical_to_kernel_dequantize_fold() {
    // The byte-transport acceptance pin: a round executed through the
    // wire codec (knot indices packed into eq. (5) bytes, fused
    // decode-fold on the server) must produce a bit-identical θ^{n+1}
    // to the pre-transport reference — kernel dequantize (PJRT Pallas
    // artifact) followed by the weighted Vec<f32> fold — for both the
    // serial path and an 8-worker pool. Covers quantized levels across
    // the range, a raw upload, and a C4 dropout.
    let Some(rt) = runtime() else { return };
    let params = SystemParams::tiny_test();
    assert_eq!(params.z, rt.info.z, "tiny profile drifted");
    let n = 6usize;
    let mut dcfg = DataGenConfig::new(n, rt.info.image, rt.info.classes);
    dcfg.size_mean = 200.0;
    dcfg.size_std = 30.0;
    dcfg.test_size = 64;
    let fed = data::generate(&dcfg, 21);
    let theta = rt.init().unwrap();

    let mut master = Rng::seed_from(77);
    let streams: Vec<Rng> = (0..n).map(|i| master.fork(1000 + i as u64)).collect();
    let qs: [Option<u32>; 6] = [Some(1), Some(4), Some(8), Some(12), None, Some(4)];
    let rates: [f64; 6] = [50e6, 50e6, 50e6, 50e6, 50e6, 1.0]; // last one misses C4
    let decision = |i: usize| ClientDecision {
        channel: i,
        q: qs[i],
        f: params.f_max,
        rate: rates[i],
    };
    let mk_tasks = || {
        (0..n)
            .map(|i| ClientTask {
                id: i,
                size: fed.clients[i].size as f64,
                decision: decision(i),
                deadline_exempt: false,
                cpu_scale: 1.0,
                data: &fed.clients[i],
                rng: streams[i].clone(),
            })
            .collect::<Vec<_>>()
    };

    // Reference: the pre-transport path, replayed on the same RNG
    // streams — PJRT train_step, PJRT quantize (dequantized Vec<f32>),
    // serial weighted fold over the C4 survivors.
    let sizes: Vec<f64> = (0..n).map(|i| fed.clients[i].size as f64).collect();
    let survive: Vec<bool> = (0..n)
        .map(|i| {
            let d = decision(i);
            exec::survives_deadline(
                &params,
                exec::realized_latency(&params, sizes[i], &d, 1.0),
                false,
            )
        })
        .collect();
    assert_eq!(survive, [true, true, true, true, true, false], "setup drifted");
    let d_surv: f64 = sizes.iter().zip(&survive).filter(|(_, s)| **s).map(|(d, _)| *d).sum();
    let mut want = vec![0.0f32; rt.info.z];
    for i in 0..n {
        let mut rng = streams[i].clone();
        let (xs, ys) =
            fed.clients[i].sample_batches(&mut rng, rt.info.tau, rt.info.batch, rt.info.pix());
        let out = rt.train_step(&theta, &xs, &ys, rt.info.lr as f32).unwrap();
        let model = match qs[i] {
            Some(q) => {
                let mut noise = vec![0.0f32; rt.info.z];
                rng.fill_uniform_f32(&mut noise);
                rt.quantize(&out.theta, &noise, q as f32).unwrap().0
            }
            None => out.theta,
        };
        if survive[i] {
            let w = (sizes[i] / d_surv) as f32;
            for (a, m) in want.iter_mut().zip(&model) {
                *a += w * m;
            }
        }
    }
    let want_bits: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();

    let expected_bytes: usize = (0..n)
        .map(|i| match qs[i] {
            Some(q) => quant::encoded_len(rt.info.z, q),
            None => 4 * rt.info.z,
        })
        .sum();
    for threads in [1usize, 8] {
        let mut scratch: Vec<WorkerScratch> = Vec::new();
        let out = exec::execute_round(&params, &rt, &theta, mk_tasks(), threads, &mut scratch)
            .unwrap();
        assert_eq!(out.scheduled, n);
        assert_eq!(out.aggregated, 5, "threads={threads}");
        assert_eq!(out.wire_bytes, expected_bytes, "threads={threads}");
        let got = out.aggregate.expect("survivors present");
        assert_eq!(
            got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            want_bits,
            "byte transport diverged from kernel-dequantize fold at threads={threads}"
        );
    }
}

#[test]
fn surviving_upload_bytes_decode_to_kernel_output_exactly() {
    // Acceptance pin #2: decoding a surviving upload's wire bytes must
    // reproduce the quantized model the Pallas kernel would have
    // produced, to_bits()-exactly — and truncated payloads must be
    // rejected with an error, never zero-filled.
    let Some(rt) = runtime() else { return };
    let params = SystemParams::tiny_test();
    let mut dcfg = DataGenConfig::new(2, rt.info.image, rt.info.classes);
    dcfg.size_mean = 150.0;
    dcfg.size_std = 20.0;
    dcfg.test_size = 64;
    let fed = data::generate(&dcfg, 9);
    let theta = rt.init().unwrap();
    let mut scratch = WorkerScratch::default();
    for q in [1u32, 3, 8, 16] {
        let stream = Rng::seed_from(500 + q as u64);
        let task = ClientTask {
            id: 0,
            size: fed.clients[0].size as f64,
            decision: ClientDecision { channel: 0, q: Some(q), f: params.f_max, rate: 50e6 },
            deadline_exempt: false,
            cpu_scale: 1.0,
            data: &fed.clients[0],
            rng: stream.clone(),
        };
        let mut oc = exec::run_client(&params, &rt, &theta, task, true, &mut scratch).unwrap();
        let Some(Upload::Wire { bytes, q: packed_q }) = oc.upload.take() else {
            panic!("quantized upload must take the wire path");
        };
        assert_eq!(packed_q, q);
        assert_eq!(oc.payload_bytes, bytes.len());
        assert_eq!(bytes.len(), quant::encoded_len(rt.info.z, q), "eq. (5) bytes");

        // Replay the kernel path on the same stream.
        let mut rng = stream.clone();
        let (xs, ys) =
            fed.clients[0].sample_batches(&mut rng, rt.info.tau, rt.info.batch, rt.info.pix());
        let out = rt.train_step(&theta, &xs, &ys, rt.info.lr as f32).unwrap();
        let mut noise = vec![0.0f32; rt.info.z];
        rng.fill_uniform_f32(&mut noise);
        let (qtheta, tmax) = rt.quantize(&out.theta, &noise, q as f32).unwrap();
        assert_eq!(oc.theta_max, tmax as f64, "q={q}");

        let (tmax_wire, decoded) = quant::decode(&bytes, rt.info.z, q).unwrap();
        assert_eq!(tmax_wire.to_bits(), tmax.to_bits(), "q={q}");
        assert_eq!(
            decoded.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            qtheta.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "q={q}: wire decode != kernel dequantize"
        );
        assert!(quant::decode(&bytes[..bytes.len() - 1], rt.info.z, q).is_err(), "q={q}");
    }
}

#[test]
fn zero_surviving_data_mass_skips_aggregation() {
    // Regression for the d_surv = 0 NaN: clients whose D_i metadata is
    // zero can survive C4 (zero compute latency), but the renormalized
    // eq. (2) weights would be 0/0 — the round must keep θ^n instead of
    // folding NaN into it.
    let Some(rt) = runtime() else { return };
    let params = SystemParams::tiny_test();
    let mut dcfg = DataGenConfig::new(2, rt.info.image, rt.info.classes);
    dcfg.size_mean = 150.0;
    dcfg.size_std = 20.0;
    dcfg.test_size = 64;
    let fed = data::generate(&dcfg, 31);
    let theta = rt.init().unwrap();
    let mut master = Rng::seed_from(3);
    let tasks: Vec<ClientTask<'_>> = (0..2)
        .map(|i| ClientTask {
            id: i,
            size: 0.0,
            decision: ClientDecision { channel: i, q: Some(4), f: params.f_max, rate: 50e6 },
            deadline_exempt: false,
            cpu_scale: 1.0,
            data: &fed.clients[i],
            rng: master.fork(1000 + i as u64),
        })
        .collect();
    let mut scratch = Vec::new();
    let out = exec::execute_round(&params, &rt, &theta, tasks, 1, &mut scratch).unwrap();
    assert_eq!(out.scheduled, 2);
    assert_eq!(out.aggregated, 2, "zero-size uploads still make the deadline");
    assert!(out.aggregate.is_none(), "zero data mass must not aggregate");
    assert!(out.round_energy.is_finite() && out.round_energy > 0.0);
    for oc in &out.outcomes {
        assert!(oc.latency.is_finite());
        assert!(oc.payload_bytes > 0);
    }
}

#[test]
fn queue_pressure_raises_q_over_time() {
    // Remark 1 at system level: QCCF's mean q in late rounds should not
    // be below its first-round value.
    let Some(rt) = runtime() else { return };
    let trace = make_server(&rt, "qccf", 5).run(10).unwrap();
    let qs: Vec<f64> = trace.records.iter().filter(|r| r.mean_q > 0.0).map(|r| r.mean_q).collect();
    assert!(qs.len() >= 3);
    let early = qs[..2.min(qs.len())].iter().sum::<f64>() / 2.0;
    let late = qs[qs.len().saturating_sub(2)..].iter().sum::<f64>() / 2.0;
    assert!(
        late >= early - 0.75,
        "q collapsed over training: early {early:.2} late {late:.2} ({qs:?})"
    );
}
