//! `qccf` — CLI for the wireless-FL reproduction.
//!
//! Subcommands:
//!   params                       print Table I as configured
//!   train   [--algorithm A] [--profile P] [--rounds N] [--beta B] [--v V] [--seed S]
//!           [--threads T]        worker threads for the round engine and
//!                                GA fitness fan-out (default: all cores
//!                                minus one; 1 = serial legacy path; any
//!                                value is bit-identical)
//!           [--checkpoint-every N] [--checkpoint-dir D] [--resume F]
//!                                periodic atomic snapshots every N rounds
//!                                (default dir results/ckpt), and resume
//!                                from snapshot F — the resumed trace is
//!                                bit-identical to the uninterrupted run
//!                                (docs/CHECKPOINTS.md)
//!   fig2    [--profile P] [--v-values 1,10,100,1000] [--rounds N] [--quick]
//!   fig3    [--profile P] [--betas 150,300] [--rounds N] [--quick]
//!   fig4    [--profile P] [--betas 150,300] [--rounds N] [--quick]
//!   fig5    [--profile P] [--rounds N] [--seeds K] [--quick]
//!   sweep   [--scenarios a,b,...] [--scenario-file f.scn,...] [--seeds 1,2,...]
//!           [--algorithms all|x,y] [--rounds N] [--out DIR] [--threads T]
//!           [--quick] [--list]   scenario sweep: cross-product scenarios ×
//!                                seeds × algorithms, runs fanned out over
//!                                the thread pool, one JSONL trace per run
//!                                plus summary.csv under --out (bit-identical
//!                                for any --threads). `--list` prints the
//!                                built-ins; format reference: docs/SCENARIOS.md
//!           [--resume] [--checkpoint-every N]
//!                                preemption-safe restart: skip `ok` triples
//!                                already in summary.csv (`failed` rows re-run)
//!                                and restart partial runs from their latest
//!                                snapshot under --out/ckpt (written every N
//!                                rounds; a corrupt one falls back to the
//!                                rotated .prev snapshot, then to fresh).
//!                                A panicking unit becomes a `failed` summary
//!                                row and the fleet keeps draining; the sweep
//!                                exits non-zero only after every unit ran.
//!                                Deterministic fault injection (chaos-*
//!                                scenarios and the [train] chaos knobs) is
//!                                documented in docs/FAULTS.md
//!   decide  [--profile P] [--seed S]    one-round decision demo (all algorithms)
//!   ablate  [--draws N] [--seed S] [--quick]   design-choice ablations (no artifacts)
//!   bench-wire [--z Z] [--qs 4,8] [--out F]    wire-codec microbench (encode +
//!                                fused decode-fold), written as BENCH_wire.json
//!                                (default target/BENCH_wire.json; no artifacts) —
//!                                the byte-transport perf baseline verify.sh seeds
//!   bench-sched [--us 100,1000] [--pool 32] [--out F]   decision-stage microbench:
//!                                J0 evaluations/sec at U clients, C = U/2, cached
//!                                (sched::EvalCtx + solve memo + scratch) vs the
//!                                uncached reference path, over a converging-GA-
//!                                shaped chromosome pool; written as
//!                                BENCH_sched.json (default target/; no artifacts)
//!           [--class-us 1000,10000,100000]   classed-vs-exact rows: class-level
//!                                J0 throughput (sched::classes) against the
//!                                cached exact evaluator at the stress shape
//!                                (C = min(U/2, 64), 10% stragglers), plus the
//!                                approximation gap of one full decide per path
//!   bench-diff [--baseline DIR] [--fresh DIR] [--threshold 0.2]   compare fresh
//!                                BENCH_*.json under --fresh (default target/)
//!                                against committed baselines under --baseline
//!                                (default .); prints one advisory warning per
//!                                metric regressed past the threshold and always
//!                                exits 0 — verify.sh runs it before refreshing
//!                                the committed baselines
//!   bench-ckpt [--z Z] [--us 100,1000] [--out F]   snapshot-codec microbench:
//!                                encode/decode MB/s and snapshot bytes at
//!                                Z model dims × U clients; written as
//!                                BENCH_ckpt.json (default target/; no artifacts)
//!   report  [--dir DIR] [--bench-baseline DIR --bench-fresh DIR]   aggregate a
//!                                sweep directory into a health report — unit
//!                                outcomes, per-stage p50/p95/p99 wall times
//!                                from ledger.jsonl, energy quantiles from the
//!                                deterministic sketch sidecars, and advisory
//!                                bench deltas — without rereading any per-round
//!                                JSONL trace (docs/OBSERVABILITY.md; no artifacts)
//!
//! The fig2..fig5 harnesses are presets over the `paper-femnist` /
//! `paper-cifar10` scenarios — the same path `sweep` runs (see
//! docs/ARCHITECTURE.md).
//!
//! Requires `make artifacts` (HLO text under ./artifacts), except
//! `ablate`, `bench-wire`, `bench-sched`, `bench-ckpt`, `bench-diff`,
//! `report` and `sweep --list`.

use std::path::PathBuf;

use anyhow::Result;

use qccf::baselines::{make_scheduler, ALL_ALGORITHMS};
use qccf::config::SystemParams;
use qccf::experiments::{common, fig2, fig3, fig4, fig5, sweep, RunSpec, Task};
use qccf::info;
use qccf::lyapunov::Queues;
use qccf::obs::{ledger, sketch, spans, wall};
use qccf::runtime::Runtime;
use qccf::scenario::{self, ScenarioRegistry};
use qccf::sched::RoundInputs;
use qccf::util::argparse::Args;
use qccf::util::rng::Rng;
use qccf::util::table;
use qccf::util::threadpool;
use qccf::wireless::ChannelModel;

fn main() {
    qccf::util::logging::init();
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn task_of(args: &Args) -> Task {
    match args.get_or("task", "femnist") {
        "cifar" => Task::Cifar,
        _ => Task::Femnist,
    }
}

fn run(args: &Args) -> Result<()> {
    match args.cmd.as_deref() {
        Some("params") => cmd_params(args),
        Some("train") => cmd_train(args),
        Some("fig2") => cmd_fig2(args),
        Some("fig3") => cmd_fig3(args),
        Some("fig4") => cmd_fig4(args),
        Some("fig5") => cmd_fig5(args),
        Some("sweep") => cmd_sweep(args),
        Some("decide") => cmd_decide(args),
        Some("ablate") => cmd_ablate(args),
        Some("bench-wire") => cmd_bench_wire(args),
        Some("bench-sched") => cmd_bench_sched(args),
        Some("bench-ckpt") => cmd_bench_ckpt(args),
        Some("bench-diff") => cmd_bench_diff(args),
        Some("report") => cmd_report(args),
        Some(other) => anyhow::bail!("unknown subcommand `{other}` (see README)"),
        None => {
            println!("usage: qccf <params|train|fig2|fig3|fig4|fig5|sweep|decide|ablate|bench-wire|bench-sched|bench-ckpt|bench-diff|report> [options]");
            println!("see README.md for the full option list; `qccf sweep --list` shows scenarios");
            Ok(())
        }
    }
}

fn cmd_params(args: &Args) -> Result<()> {
    let p = match task_of(args) {
        Task::Femnist => SystemParams::femnist_small(),
        Task::Cifar => SystemParams::cifar_small(),
    };
    let rows = vec![
        vec!["U (clients)".into(), p.num_clients.to_string()],
        vec!["C (channels)".into(), p.num_channels.to_string()],
        vec!["B (Hz)".into(), table::fnum(p.bandwidth_hz)],
        vec!["p (W)".into(), p.tx_power_w.to_string()],
        vec!["N0 (W/Hz)".into(), table::fnum(p.noise_psd_w_hz)],
        vec!["Rician K / ζ".into(), format!("{} / {}", p.rician_k, p.rician_zeta)],
        vec!["α".into(), table::fnum(p.alpha)],
        vec!["γ (cycles/sample)".into(), table::fnum(p.gamma)],
        vec!["f_min / f_max (Hz)".into(), format!("{:.1e} / {:.1e}", p.f_min, p.f_max)],
        vec!["τ / τ^e".into(), format!("{} / {}", p.tau, p.tau_e)],
        vec!["T_max (s)".into(), p.t_max.to_string()],
        vec!["Z".into(), p.z.to_string()],
        vec!["η / L".into(), format!("{} / {}", p.eta, p.lips)],
        vec!["V / ε1 / ε2".into(), format!("{} / {} / {}", p.v, p.eps1, p.eps2)],
    ];
    println!("Table I system parameters ({:?} column):", task_of(args));
    println!("{}", table::render(&["parameter", "value"], &rows));
    let errs = p.validate();
    if errs.is_empty() {
        println!("validation: OK (Theorem 1/2 prerequisites hold)");
    } else {
        println!("validation issues: {errs:?}");
    }
    Ok(())
}

fn load_runtime(args: &Args) -> Result<Runtime> {
    let profile = args.get_or("profile", "small");
    info!("main", "loading artifacts for profile `{profile}`");
    let rt = Runtime::load_default(profile)?;
    info!("main", "PJRT platform: {}, Z = {}", rt.platform(), rt.info.z);
    Ok(rt)
}

fn cmd_train(args: &Args) -> Result<()> {
    // Fresh span totals + a wall stopwatch so the run's ledger entry
    // attributes only this invocation (docs/OBSERVABILITY.md).
    spans::reset();
    let train_wall = wall::Stopwatch::start();
    let rt = load_runtime(args)?;
    let mut spec = RunSpec::new(args.get_or("algorithm", "qccf"), task_of(args));
    spec.rounds = args.get_usize("rounds", 40);
    spec.beta = args.get_f64("beta", 150.0);
    spec.mu = args.get_f64("mu", 1200.0);
    spec.seed = args.get_u64("seed", 1);
    spec.eval_every = args.get_usize("eval-every", 2);
    spec.threads = args.get_usize("threads", spec.threads).max(1);
    if let Some(v) = args.get("v") {
        spec.v = v.parse().ok();
    }
    info!("main", "round engine threads: {}", spec.threads);
    // Checkpoint policy: periodic atomic snapshots and/or resume from
    // one (docs/CHECKPOINTS.md). The resumed trace is bit-identical to
    // the uninterrupted run's. Strict parse, like sweep's: a typo'd
    // cadence must not silently run the long job with checkpointing
    // off — losing exactly the run the flag was meant to protect.
    let every = match args.get("checkpoint-every") {
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| anyhow::anyhow!("--checkpoint-every: bad value `{v}`"))?,
        None => 0,
    };
    // Bare `--resume` (no path) parses as a flag and would silently
    // start from round 0 — the opposite of what was asked.
    anyhow::ensure!(
        !args.flag("resume") || args.get("resume").is_some(),
        "train --resume needs a snapshot path (e.g. --resume results/ckpt/<run>.qckpt)"
    );
    let ckpt_dir = args
        .get("checkpoint-dir")
        .map(PathBuf::from)
        .unwrap_or_else(|| common::results_dir().join("ckpt"));
    let policy = common::CheckpointPolicy {
        every,
        dir: (every > 0).then_some(ckpt_dir),
        resume: args.get("resume").map(PathBuf::from),
        // `train` owns its runtime exclusively, so the resumed profile
        // may continue the original accounting.
        restore_runtime_clock: true,
    };
    if policy.every > 0 {
        info!(
            "main",
            "checkpointing every {} round(s) under {}",
            policy.every,
            policy.dir.as_ref().unwrap().display()
        );
    }
    let sc = spec.to_scenario();
    let trace =
        common::run_scenario_ckpt(&rt, &sc, &spec.algorithm, spec.seed, spec.threads, &policy)?;
    let row = fig3::summarize(&trace, spec.beta);
    fig3::print(std::slice::from_ref(&row), &format!("train — {}", spec.algorithm));
    let path = common::results_dir().join(format!("train_{}.csv", spec.algorithm));
    trace.write_csv(&path)?;
    println!("wrote {}", path.display());
    // Ledger line: completion-ordered wall-clock journal next to the
    // CSV (best-effort — telemetry must never fail the run).
    let sketches = sketch::TraceSketches::from_trace(&trace);
    let entry = ledger::LedgerEntry {
        kind: "train".into(),
        scenario: sc.name.clone(),
        algorithm: spec.algorithm.clone(),
        seed: spec.seed,
        rounds: trace.records.len(),
        status: "ok".into(),
        wall_secs: train_wall.elapsed_secs(),
        threads: spec.threads,
        spans: spans::totals(),
        sketch_digests: sketches.digests().into_iter().map(|(k, d)| (k.to_string(), d)).collect(),
        git: ledger::git_describe(),
    };
    if let Err(e) = ledger::append(&common::results_dir(), &entry) {
        info!("main", "ledger append failed (non-fatal): {e}");
    }
    let prof = rt.exec_profile();
    info!(
        "main",
        "runtime seconds: init={:.2} train={:.2} eval={:.2} quantize={:.2}",
        prof[0],
        prof[1],
        prof[2],
        prof[3]
    );
    Ok(())
}

fn cmd_fig2(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    let rounds = args.get_usize("rounds", if args.flag("quick") { 16 } else { 40 });
    let v_values = args.get_f64_list("v-values", &[1.0, 10.0, 100.0, 1000.0]);
    let rows = fig2::run(&rt, task_of(args), &v_values, rounds, args.get_u64("seed", 1))?;
    fig2::print(&rows);
    fig2::write_summary(&rows, task_of(args))
}

fn cmd_fig3(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    let rounds = args.get_usize("rounds", if args.flag("quick") { 16 } else { 40 });
    let betas = args.get_f64_list("betas", &[150.0, 300.0]);
    let rows = fig3::run_grid(&rt, Task::Femnist, &betas, rounds, args.get_u64("seed", 1), "fig3")?;
    fig3::print(&rows, "Fig. 3 — FEMNIST-sim: accuracy & accumulated energy (5 algorithms)");
    fig3::write_summary(&rows, "fig3")
}

fn cmd_fig4(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    let rounds = args.get_usize("rounds", if args.flag("quick") { 16 } else { 40 });
    let betas = args.get_f64_list("betas", &[150.0, 300.0]);
    let rows = fig4::run_grid(&rt, &betas, rounds, args.get_u64("seed", 1))?;
    fig4::print(&rows);
    fig4::write_summary(&rows)
}

fn cmd_fig5(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    let rounds = args.get_usize("rounds", if args.flag("quick") { 20 } else { 40 });
    let seed = args.get_u64("seed", 1);
    let nseeds = args.get_usize("seeds", if args.flag("quick") { 1 } else { 3 });
    let seeds: Vec<u64> = (0..nseeds as u64).map(|k| seed + k).collect();
    let data = fig5::run(&rt, rounds, &seeds)?;
    fig5::print(&data);
    fig5::write_csv(&data)
}

fn print_sweep_usage() {
    println!("usage: qccf sweep --scenarios a,b[,...] [options]");
    println!("  --scenarios a,b       built-in scenarios to run (`sweep --list` to enumerate)");
    println!("  --scenario-file p,... scenario files to load (KV-text; see docs/SCENARIOS.md)");
    println!("  --seeds 1,2           master seeds (default: 1)");
    println!("  --algorithms all|x,y  override each scenario's own algorithm list");
    println!("  --rounds N            override each scenario's round count");
    println!("  --out DIR             output directory (default: results/sweep)");
    println!("  --threads T           concurrent runs (default: cores - 1); outputs are");
    println!("                        bit-identical for any value");
    println!("  --quick               2-round smoke (tier-1 uses this; see verify.sh)");
    println!("  --profile P           artifact profile (default: small)");
    println!("  --resume              skip triples already in summary.csv; restart partial");
    println!("                        runs from their latest snapshot under --out/ckpt");
    println!("  --checkpoint-every N  per-run snapshot cadence in rounds (default 0 = off;");
    println!("                        what makes long runs resumable mid-horizon)");
    println!("scenario format + every built-in's rationale: docs/SCENARIOS.md");
}

/// Scenario sweep: cross-product scenarios × seeds × algorithms, fan
/// the runs out, write one JSONL trace per run + summary.csv.
fn cmd_sweep(args: &Args) -> Result<()> {
    let registry = ScenarioRegistry::builtin();
    if args.flag("list") {
        println!("built-in scenarios (docs/SCENARIOS.md has the full rationale):");
        for sc in registry.all() {
            println!("\n  {} — U={} C={} aps={} rounds={} algs=[{}]", sc.name,
                     sc.topology.clients, sc.topology.channels, sc.topology.aps,
                     sc.train.rounds, sc.train.algorithms.join(","));
            println!("    {}", sc.description);
        }
        return Ok(());
    }
    if args.flag("help") {
        print_sweep_usage();
        return Ok(());
    }
    let mut scenarios = Vec::new();
    for name in args.get_str_list("scenarios", &[]) {
        let sc = registry.get(&name).cloned().ok_or_else(|| {
            anyhow::anyhow!("unknown scenario `{name}` — `qccf sweep --list` enumerates the built-ins")
        })?;
        scenarios.push(sc);
    }
    for path in args.get_str_list("scenario-file", &[]) {
        scenarios.push(
            scenario::load_file(std::path::Path::new(&path)).map_err(|e| anyhow::anyhow!(e))?,
        );
    }
    if scenarios.is_empty() {
        print_sweep_usage();
        anyhow::bail!("no scenarios selected (use --scenarios and/or --scenario-file)");
    }
    // Strict numeric options: a typo'd value must not silently fall
    // back and run each scenario at its full default round count.
    let rounds = match args.get("rounds") {
        Some(v) => {
            Some(v.parse::<usize>().map_err(|_| anyhow::anyhow!("--rounds: bad value `{v}`"))?)
        }
        None if args.flag("quick") => Some(2),
        None => None,
    };
    let algorithms = args.get("algorithms").map(qccf::baselines::algorithm_list);
    // Seeds too: the lenient list helpers would drop a bad token and
    // shrink the run set without a word.
    let seeds_raw = args.get_or("seeds", "1");
    let seeds: Vec<u64> = seeds_raw
        .split(',')
        .map(|t| {
            let t = t.trim();
            t.parse::<u64>()
                .map_err(|_| anyhow::anyhow!("--seeds: bad seed `{t}` in `{seeds_raw}`"))
        })
        .collect::<Result<_>>()?;
    // And --threads: a typo here should not silently fan out over all
    // cores on a box the user was trying to protect.
    let threads = match args.get("threads") {
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| anyhow::anyhow!("--threads: bad value `{v}`"))?
            .max(1),
        None => threadpool::default_threads(),
    };
    let checkpoint_every = match args.get("checkpoint-every") {
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| anyhow::anyhow!("--checkpoint-every: bad value `{v}`"))?,
        None => 0,
    };
    // `--resume <value>` parses as an option, so `flag("resume")` would
    // be false and the fresh-sweep branch would *delete* the summary
    // the user asked to resume from — reject the wrong arity instead
    // (sweep's --resume is a bare flag; train's takes the path).
    if let Some(v) = args.get("resume") {
        anyhow::ensure!(
            v == "true",
            "sweep --resume takes no value (it resumes everything under --out); \
             got `--resume {v}`"
        );
    }
    let cfg = sweep::SweepConfig {
        scenarios,
        seeds,
        algorithms,
        rounds,
        out_dir: PathBuf::from(args.get_or("out", "results/sweep")),
        threads,
        resume: args.flag("resume"),
        checkpoint_every,
    };
    let rt = load_runtime(args)?;
    let rows = sweep::run(&rt, &cfg)?;
    sweep::print(&rows);
    println!(
        "wrote {} JSONL trace(s) + summary.csv under {}",
        rows.len(),
        cfg.out_dir.display()
    );
    Ok(())
}

/// Wire-codec microbench (no artifacts needed — pure Rust): encode +
/// fused decode-fold at the requested Z and levels, emitted as
/// `BENCH_wire.json` so later PRs have a perf baseline to diff against
/// (verify.sh runs this as a quick smoke).
fn cmd_bench_wire(args: &Args) -> Result<()> {
    let z = args.get_usize("z", 20_000);
    let qs: Vec<u32> = args.get_f64_list("qs", &[4.0, 8.0]).into_iter().map(|q| q as u32).collect();
    anyhow::ensure!(!qs.is_empty(), "--qs: need at least one level");
    anyhow::ensure!(qs.iter().all(|&q| (1..=32).contains(&q)), "--qs: levels must be in 1..=32");
    let out = PathBuf::from(args.get_or("out", "target/BENCH_wire.json"));
    let rows = qccf::bench::run_wire_bench(z, &qs);
    qccf::bench::write_wire_bench_json(&out, z, &rows)?;
    println!("wrote {} ({} benchmarks)", out.display(), rows.len());
    Ok(())
}

/// Decision-stage microbench (no artifacts needed — pure Rust): J0
/// evaluation throughput at each U with C = U/2, through the cached
/// path (`sched::EvalCtx` + exact-key solve memo + reusable scratch)
/// and the uncached `evaluate_allocation` reference, emitted as
/// `BENCH_sched.json` — the decision-stage perf baseline verify.sh
/// seeds and later PRs diff against.
fn cmd_bench_sched(args: &Args) -> Result<()> {
    let us: Vec<usize> =
        args.get_f64_list("us", &[100.0, 1000.0]).into_iter().map(|u| u as usize).collect();
    anyhow::ensure!(!us.is_empty(), "--us: need at least one client count");
    anyhow::ensure!(us.iter().all(|&u| u >= 2), "--us: client counts must be >= 2");
    let pool = args.get_usize("pool", 32);
    anyhow::ensure!(pool >= 1, "--pool: need at least one chromosome");
    let class_us: Vec<usize> = args
        .get_f64_list("class-us", &[1000.0, 10_000.0, 100_000.0])
        .into_iter()
        .map(|u| u as usize)
        .collect();
    anyhow::ensure!(
        class_us.iter().all(|&u| u >= 2),
        "--class-us: client counts must be >= 2"
    );
    let out = PathBuf::from(args.get_or("out", "target/BENCH_sched.json"));
    let rows = qccf::bench::run_sched_bench(&us, pool);
    let classed = qccf::bench::run_classed_sched_bench(&class_us);
    qccf::bench::write_sched_bench_json(&out, pool, &rows, &classed)?;
    for r in &rows {
        println!(
            "{:<28} U={:<5} C={:<5} {:>12.0} evals/sec",
            r.name, r.u, r.c, r.evals_per_sec
        );
    }
    for r in &classed {
        println!(
            "classed U={:<6} K={:<4} P={:<3} exact {:>11.0}/s classed {:>11.0}/s \
             speedup {:>7.1}x gap {:>+.3}%",
            r.u,
            r.classes,
            r.pools,
            r.exact_evals_per_sec,
            r.classed_evals_per_sec,
            r.speedup,
            r.gap * 100.0
        );
    }
    println!(
        "wrote {} ({} benchmarks, {} classed rows)",
        out.display(),
        rows.len(),
        classed.len()
    );
    Ok(())
}

/// Advisory perf-regression gate: diff each fresh BENCH_*.json under
/// `--fresh` against the committed baseline of the same name under
/// `--baseline`, printing one warning line per metric that regressed
/// more than `--threshold` (fraction, default 0.2 = 20%). Always exits
/// 0 — micro-bench noise on shared hardware must not fail the tier-1
/// gate; verify.sh runs this right before refreshing the committed
/// baselines so a real regression is loud in the log.
fn cmd_bench_diff(args: &Args) -> Result<()> {
    let fresh_dir = PathBuf::from(args.get_or("fresh", "target"));
    let base_dir = PathBuf::from(args.get_or("baseline", "."));
    let threshold = args.get_f64("threshold", 0.2);
    anyhow::ensure!(threshold > 0.0, "--threshold: must be > 0");
    let mut total = 0usize;
    for name in qccf::bench::BENCH_FILES {
        let bp = base_dir.join(name);
        let fp = fresh_dir.join(name);
        if !bp.is_file() {
            println!("bench-diff: no committed baseline {} (skipped)", bp.display());
            continue;
        }
        if !fp.is_file() {
            println!("bench-diff: no fresh run {} (skipped)", fp.display());
            continue;
        }
        let base = qccf::util::json::parse(std::fs::read_to_string(&bp)?.trim())
            .map_err(|e| anyhow::anyhow!("{}: {e}", bp.display()))?;
        let fresh = qccf::util::json::parse(std::fs::read_to_string(&fp)?.trim())
            .map_err(|e| anyhow::anyhow!("{}: {e}", fp.display()))?;
        let warnings = qccf::bench::bench_diff_report(&base, &fresh, threshold);
        for w in &warnings {
            println!("bench-diff WARNING [{name}] {w}");
        }
        total += warnings.len();
    }
    if total == 0 {
        println!("bench-diff: no metric regressed beyond {:.0}%", threshold * 100.0);
    } else {
        println!(
            "bench-diff: {total} advisory warning(s) — micro-bench noise is possible; \
             investigate before committing refreshed baselines"
        );
    }
    Ok(())
}

/// Sweep health report (no artifacts needed — pure file aggregation):
/// fold `--dir`'s summary.csv, ledger.jsonl, and deterministic sketch
/// sidecars into unit outcomes, per-stage wall-time quantiles, and
/// energy quantiles, plus advisory bench deltas when both bench dirs
/// are given. Never rereads a per-round JSONL trace
/// (docs/OBSERVABILITY.md).
fn cmd_report(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.get_or("dir", "results/sweep"));
    let baseline = args.get("bench-baseline").map(PathBuf::from);
    let fresh = args.get("bench-fresh").map(PathBuf::from);
    anyhow::ensure!(
        baseline.is_some() == fresh.is_some(),
        "report: --bench-baseline and --bench-fresh must be given together"
    );
    let text = qccf::obs::report::render(&dir, baseline.as_deref(), fresh.as_deref())?;
    print!("{text}");
    Ok(())
}

/// Snapshot-codec microbench (no artifacts needed — pure Rust):
/// `ckpt::Snapshot` encode/decode throughput over a synthetic
/// mid-horizon snapshot at Z model dims × U clients, emitted as
/// `BENCH_ckpt.json` — the checkpoint-path perf baseline verify.sh
/// seeds and later PRs diff against.
fn cmd_bench_ckpt(args: &Args) -> Result<()> {
    let z = args.get_usize("z", 20_000);
    let us: Vec<usize> =
        args.get_f64_list("us", &[100.0, 1000.0]).into_iter().map(|u| u as usize).collect();
    anyhow::ensure!(!us.is_empty(), "--us: need at least one client count");
    anyhow::ensure!(us.iter().all(|&u| u >= 1), "--us: client counts must be >= 1");
    let out = PathBuf::from(args.get_or("out", "target/BENCH_ckpt.json"));
    let rows = qccf::bench::run_ckpt_bench(z, &us);
    qccf::bench::write_ckpt_bench_json(&out, z, &rows)?;
    for r in &rows {
        println!(
            "{:<28} U={:<5} {:>10} B {:>10.1} MB/s",
            r.name, r.u, r.bytes, r.mb_per_sec
        );
    }
    println!("wrote {} ({} benchmarks)", out.display(), rows.len());
    Ok(())
}

/// Design-choice ablations (no artifacts needed — pure decision math).
fn cmd_ablate(args: &Args) -> Result<()> {
    let draws = args.get_usize("draws", if args.flag("quick") { 10 } else { 40 });
    let seed = args.get_u64("seed", 1);
    let ga_rows = qccf::experiments::ablate::ga_budget(draws, seed);
    qccf::experiments::ablate::print_ga(&ga_rows);
    let c5 = qccf::experiments::ablate::case5_modes(draws * 20, seed);
    qccf::experiments::ablate::print_case5(&c5);
    Ok(())
}

/// One-round decision demo: same channel draw, every algorithm's choices.
fn cmd_decide(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    let mut p = common::params_for(&rt, task_of(args), 1200.0);
    p.v = args.get_f64("v", p.v);
    let seed = args.get_u64("seed", 1);
    let mut rng = Rng::seed_from(seed);
    let model = ChannelModel::new(&p, &mut rng);
    let channels = model.draw(&mut rng);
    let sizes: Vec<f64> =
        (0..p.num_clients).map(|_| rng.gaussian(1200.0, 150.0).max(64.0)).collect();
    let total: f64 = sizes.iter().sum();
    let w_full: Vec<f64> = sizes.iter().map(|d| d / total).collect();
    let mut queues = Queues::new();
    queues.update(&p, p.eps1 + 30.0, p.eps2 + 1.0);
    let g2 = vec![2.0; p.num_clients];
    let sigma2 = vec![0.5; p.num_clients];
    let theta_max = vec![0.4; p.num_clients];
    let q_prev = vec![6.0; p.num_clients];
    let inputs = RoundInputs {
        params: &p,
        round: 5,
        channels: &channels,
        sizes: &sizes,
        w_full: &w_full,
        g2: &g2,
        sigma2: &sigma2,
        theta_max: &theta_max,
        q_prev: &q_prev,
        queues: &queues,
        avail: None,
    };
    for alg in ALL_ALGORITHMS {
        let mut s = make_scheduler(alg, seed).unwrap();
        let dec = s.decide(&inputs);
        let mut body = Vec::new();
        for (i, d) in dec.assignments.iter().enumerate() {
            match d {
                Some(d) => body.push(vec![
                    i.to_string(),
                    format!("{:.0}", sizes[i]),
                    d.channel.to_string(),
                    d.q.map(|q| q.to_string()).unwrap_or_else(|| "raw".into()),
                    format!("{:.2e}", d.f),
                    format!("{:.1}", d.rate / 1e6),
                ]),
                None => body.push(vec![
                    i.to_string(),
                    format!("{:.0}", sizes[i]),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]),
            }
        }
        println!("{alg} (J0 = {}):", table::fnum(dec.j0));
        println!(
            "{}",
            table::render(&["client", "D_i", "channel", "q", "f (Hz)", "rate (Mb/s)"], &body)
        );
    }
    Ok(())
}
