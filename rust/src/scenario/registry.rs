//! Built-in scenario registry: the two paper profiles plus twelve
//! stress/heterogeneity workloads drawn from the related work. Each
//! builder documents *why* the scenario exists; `docs/SCENARIOS.md`
//! carries the same rationale next to a rendered copy of each file.

use crate::baselines::ALL_ALGORITHMS;
use crate::experiments::Task;

use super::{Scenario, SizeDistKind};

/// Name-indexed collection of scenarios (built-ins by default; callers
/// may [`ScenarioRegistry::add`] file-loaded ones).
pub struct ScenarioRegistry {
    scenarios: Vec<Scenario>,
}

impl ScenarioRegistry {
    /// The fourteen built-in scenarios, in documentation order.
    pub fn builtin() -> ScenarioRegistry {
        ScenarioRegistry {
            scenarios: vec![
                paper_femnist(),
                paper_cifar10(),
                megacell_100(),
                zipf_skew(),
                deep_fade(),
                cpu_straggler(),
                cell_free_lite(),
                stress_1000(),
                stress_100k(),
                churn_100(),
                churn_1000(),
                churn_10000(),
                chaos_100(),
                chaos_panic(),
            ],
        }
    }

    /// Look a scenario up by name.
    pub fn get(&self, name: &str) -> Option<&Scenario> {
        self.scenarios.iter().find(|s| s.name == name)
    }

    /// All scenarios in registration order.
    pub fn all(&self) -> &[Scenario] {
        &self.scenarios
    }

    /// Registered names, in order.
    pub fn names(&self) -> Vec<&str> {
        self.scenarios.iter().map(|s| s.name.as_str()).collect()
    }

    /// Register an additional scenario (e.g. from `--scenario-file`);
    /// replaces any existing scenario of the same name.
    pub fn add(&mut self, sc: Scenario) {
        self.scenarios.retain(|s| s.name != sc.name);
        self.scenarios.push(sc);
    }
}

/// Table I, FEMNIST column — the paper's §VI headline setting
/// (U = C = 10, Gaussian D_i with µ = 1200 / β = 150, V = 100), all
/// five algorithms. `fig2`/`fig3`/`fig5` are thin presets over this
/// scenario; its trace is the cross-version regression anchor.
pub fn paper_femnist() -> Scenario {
    let mut sc = Scenario::defaults("paper-femnist", Task::Femnist);
    sc.description = "Paper Table I, FEMNIST column: U = C = 10 over a 500 m cell, \
                      Gaussian dataset sizes (1200 +/- 150), all five algorithms. \
                      The fig2/fig3/fig5 harnesses preset this scenario."
        .into();
    sc.train.algorithms = ALL_ALGORITHMS.iter().map(|s| s.to_string()).collect();
    sc
}

/// Table I, CIFAR-10 column (γ = 2000 cycles/sample, T^max = 0.05 s,
/// V = 10) — the `fig4` preset.
pub fn paper_cifar10() -> Scenario {
    let mut sc = Scenario::defaults("paper-cifar10", Task::Cifar);
    sc.description = "Paper Table I, CIFAR-10 column: gamma = 2000, T^max = 0.05 s, \
                      V = 10, all five algorithms (the fig4 preset)."
        .into();
    sc.train.algorithms = ALL_ALGORITHMS.iter().map(|s| s.to_string()).collect();
    sc
}

/// 100 clients contending for 24 channels in a bigger cell — the
/// scheduling constraints C1–C3 finally bind (the paper's U = C = 10
/// never exercises them), so participation selection matters every
/// round. Scale regime of the multi-device designs in arXiv:2012.11070.
pub fn megacell_100() -> Scenario {
    let mut sc = Scenario::defaults("megacell-100", Task::Femnist);
    sc.description = "100 clients, 24 channels, 900 m cell: C < U makes channel \
                      contention and participation selection real (cf. \
                      arXiv:2012.11070's many-device regime)."
        .into();
    sc.topology.clients = 100;
    sc.topology.channels = 24;
    sc.topology.cell_radius_m = 900.0;
    sc.train.rounds = 20;
    sc
}

/// Zipf-distributed dataset sizes: a heavy-headed federation where a
/// few clients hold most data — harsher than the paper's Gaussian β
/// sweep and exactly where size-aware quantization (Remark 2) should
/// shine while the equal-size assumption of Same-Size breaks.
pub fn zipf_skew() -> Scenario {
    let mut sc = Scenario::defaults("zipf-skew", Task::Femnist);
    sc.description = "20 clients, 12 channels, Zipf(1.1) dataset sizes: the heavy \
                      head stresses Remark-2 size-adaptivity; same-size runs for \
                      contrast (its equal-D assumption is maximally wrong here)."
        .into();
    sc.topology.clients = 20;
    sc.topology.channels = 12;
    sc.data.dist = SizeDistKind::Zipf;
    sc.data.zipf_exponent = 1.1;
    sc.train.algorithms = vec!["qccf".into(), "same-size".into()];
    sc.train.rounds = 30;
    sc
}

/// A 30% deep-fade class (18 dB extra attenuation): bimodal channel
/// statistics like the shadowed users of cell-free studies
/// (arXiv:2412.20785). Channel-aware methods should route around the
/// faded class; channel-oblivious ones pay in dropouts.
pub fn deep_fade() -> Scenario {
    let mut sc = Scenario::defaults("deep-fade", Task::Femnist);
    sc.description = "30% of clients carry 18 dB extra attenuation: bimodal channel \
                      quality (cf. arXiv:2412.20785's shadowed users). Contrasts \
                      channel-aware qccf with channel-allocate."
        .into();
    sc.wireless.deep_fade_frac = 0.3;
    sc.wireless.deep_fade_db = 18.0;
    sc.train.algorithms = vec!["qccf".into(), "channel-allocate".into()];
    sc.train.rounds = 30;
    sc
}

/// A 20% CPU-straggler class throttled to 45% of the decided frequency:
/// the scheduler plans at nominal capability, realized latency pays —
/// the compute-heterogeneity analog of the paper's large-D timeout
/// analysis (and of arXiv:2012.11070's heterogeneous mobile devices).
pub fn cpu_straggler() -> Scenario {
    let mut sc = Scenario::defaults("cpu-straggler", Task::Femnist);
    sc.description = "20% of clients throttled to 45% realized CPU frequency: \
                      oblivious decisions meet heterogeneous compute (cf. \
                      arXiv:2012.11070). Principle's deadline-blind ramp is the \
                      natural victim baseline."
        .into();
    sc.compute.straggler_frac = 0.2;
    sc.compute.straggler_slowdown = 0.45;
    sc.train.algorithms = vec!["qccf".into(), "principle".into()];
    sc.train.rounds = 30;
    sc
}

/// Cell-free lite: 24 clients served by the nearest of 4 APs in an
/// 800 m area — pathloss variance collapses versus a single cell, the
/// setting of adaptive quantization for cell-free massive MIMO
/// (arXiv:2412.20785).
pub fn cell_free_lite() -> Scenario {
    let mut sc = Scenario::defaults("cell-free-lite", Task::Femnist);
    sc.description = "24 clients, 12 channels, 4 access points (nearest-AP \
                      pathloss, 800 m area): the cell-free topology of \
                      arXiv:2412.20785, lite — fading stays per-channel Rician."
        .into();
    sc.topology.clients = 24;
    sc.topology.channels = 12;
    sc.topology.aps = 4;
    sc.topology.cell_radius_m = 800.0;
    sc.train.rounds = 20;
    sc
}

/// 1000 clients / 64 channels: the ROADMAP's scale direction. Synthetic
/// data covers any U on any artifact profile, so this exercises the
/// decision pipeline (GA over a 64-channel allocation, 1000-client
/// bookkeeping) and the sweep fan-out rather than model quality —
/// rounds are few and evaluation is off by default.
pub fn stress_1000() -> Scenario {
    let mut sc = Scenario::defaults("stress-1000", Task::Femnist);
    sc.description = "1000 clients, 64 channels, 1200 m cell, 3 rounds, no eval: \
                      a decision-pipeline and sweep-runner scale smoke (synthetic \
                      data covers any U, so no artifact change is needed)."
        .into();
    sc.topology.clients = 1000;
    sc.topology.channels = 64;
    sc.topology.cell_radius_m = 1200.0;
    sc.train.rounds = 3;
    sc.train.eval_every = 0;
    sc
}

/// 100 000 clients / 64 channels with class-based scheduling on: the
/// hierarchical decision stage's target regime (`sched::classes`).
/// The exact per-client GA would pay O(pop x U x C) per round here;
/// the class GA pays O(pop x K x P) and broadcasts one representative
/// solve per (class, pool). A 10% straggler class keeps the CPU axis
/// of the class partition non-trivial.
pub fn stress_100k() -> Scenario {
    let mut sc = Scenario::defaults("stress-100k", Task::Femnist);
    sc.description = "100000 clients, 64 channels, 1500 m cell, 2 rounds, no eval, \
                      class-based scheduling on (4 size bins x 4 rate bins x CPU \
                      class): the hierarchical decision stage's target scale; 10% \
                      stragglers keep the CPU axis populated."
        .into();
    sc.topology.clients = 100_000;
    sc.topology.channels = 64;
    sc.topology.cell_radius_m = 1500.0;
    sc.compute.straggler_frac = 0.1;
    sc.compute.straggler_slowdown = 0.6;
    sc.train.rounds = 2;
    sc.train.eval_every = 0;
    sc.train.classes = true;
    sc
}

/// 100 clients / 24 channels under Markov churn with the full
/// availability toolkit on: over-selection hedges mid-round departures
/// and staleness weighting discounts long-absent clients. The
/// churn-family's default member — small enough for checkpointed
/// integration tests, contended enough (C < U) that the
/// availability-masked candidate set changes the decision.
pub fn churn_100() -> Scenario {
    let mut sc = Scenario::defaults("churn-100", Task::Femnist);
    sc.description = "100 clients, 24 channels under Markov on/off churn \
                      (p_leave = 0.1, p_join = 0.25) with over-selection 0.5 and \
                      staleness-weighted aggregation: the asynchronous-FL regime \
                      (clients depart mid-round, energy spent, upload lost) at a \
                      size the determinism test battery can checkpoint."
        .into();
    sc.topology.clients = 100;
    sc.topology.channels = 24;
    sc.topology.cell_radius_m = 900.0;
    sc.train.rounds = 20;
    sc.train.churn = true;
    sc.train.over_select = 0.5;
    sc.train.staleness = true;
    sc
}

/// 1000 clients / 64 channels under churn, evaluation off: the
/// decision-pipeline scale smoke of `stress-1000` with the
/// availability mask thinning the candidate set every round.
pub fn churn_1000() -> Scenario {
    let mut sc = Scenario::defaults("churn-1000", Task::Femnist);
    sc.description = "1000 clients, 64 channels, 3 rounds, no eval, Markov churn: \
                      the stress-1000 decision-pipeline smoke with an \
                      availability-masked candidate set."
        .into();
    sc.topology.clients = 1000;
    sc.topology.channels = 64;
    sc.topology.cell_radius_m = 1200.0;
    sc.train.rounds = 3;
    sc.train.eval_every = 0;
    sc.train.churn = true;
    sc.train.over_select = 0.5;
    sc
}

/// 10 000 clients / 64 channels, churn + class-based scheduling: the
/// hierarchical decision stage re-partitions the *available* clients
/// each round — classes shrink and grow with the availability mask.
pub fn churn_10000() -> Scenario {
    let mut sc = Scenario::defaults("churn-10000", Task::Femnist);
    sc.description = "10000 clients, 64 channels, 2 rounds, no eval, Markov churn \
                      with class-based scheduling: the class partition is rebuilt \
                      over the available clients every round."
        .into();
    sc.topology.clients = 10_000;
    sc.topology.channels = 64;
    sc.topology.cell_radius_m = 1500.0;
    sc.train.rounds = 2;
    sc.train.eval_every = 0;
    sc.train.classes = true;
    sc.train.churn = true;
    sc.train.over_select = 0.5;
    sc
}

/// 100 clients / 24 channels under deterministic fault injection
/// (`fl::faults`): decode failures trigger the bounded retransmission
/// loop, a straggle class stalls compute past C4, and snapshot writes
/// are occasionally corrupted to exercise the checkpoint recovery
/// ladder. No injected panics — every unit of a sweep over this
/// scenario completes, degraded but finite.
pub fn chaos_100() -> Scenario {
    let mut sc = Scenario::defaults("chaos-100", Task::Femnist);
    sc.description = "100 clients, 24 channels with deterministic fault injection: \
                      15% decode failures (2 retransmissions budgeted), 10% compute \
                      straggles, 25% of snapshot writes corrupted. Retry energy is \
                      charged against the eq.-(5) wire cost; retry-exhausted \
                      clients fold into the departed path. Fault history is a pure \
                      function of (seed, knobs) — bit-identical for any --threads \
                      and across checkpoint/resume."
        .into();
    sc.topology.clients = 100;
    sc.topology.channels = 24;
    sc.topology.cell_radius_m = 900.0;
    sc.train.rounds = 20;
    sc.train.chaos = true;
    sc.train.chaos_decode = 0.15;
    sc.train.chaos_straggle = 0.1;
    sc.train.chaos_ckpt = 0.25;
    sc
}

/// A deliberately poisoned unit: every scheduled client panics on round
/// one (`chaos_panic = 1`). A sweep containing this scenario must still
/// drain every other unit and record exactly one `failed` row — the
/// per-unit isolation contract verify.sh's chaos smoke pins.
pub fn chaos_panic() -> Scenario {
    let mut sc = Scenario::defaults("chaos-panic", Task::Femnist);
    sc.description = "10 clients, chaos_panic = 1: every scheduled worker panics, \
                      poisoning the unit on its first round. Exists to exercise \
                      sweep-level catch_unwind isolation — the fleet keeps \
                      draining and summary.csv records this unit as `failed`."
        .into();
    sc.train.rounds = 3;
    sc.train.eval_every = 0;
    sc.train.chaos = true;
    sc.train.chaos_panic = 1.0;
    sc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::format;

    #[test]
    fn builtins_present_and_valid() {
        let reg = ScenarioRegistry::builtin();
        let names = reg.names();
        for want in [
            "paper-femnist",
            "paper-cifar10",
            "megacell-100",
            "zipf-skew",
            "deep-fade",
            "cpu-straggler",
            "cell-free-lite",
            "stress-1000",
            "stress-100k",
            "churn-100",
            "churn-1000",
            "churn-10000",
            "chaos-100",
            "chaos-panic",
        ] {
            assert!(names.contains(&want), "missing builtin `{want}`");
            let sc = reg.get(want).unwrap();
            assert!(sc.validate().is_empty(), "{want}: {:?}", sc.validate());
            assert!(!sc.description.is_empty(), "{want} undocumented");
        }
        assert_eq!(reg.all().len(), 14);
    }

    #[test]
    fn builtins_roundtrip_through_text() {
        // parse(render(s)) == s for every builtin — the registry
        // round-trip contract of the scenario file format.
        for sc in ScenarioRegistry::builtin().all() {
            let text = format::render(sc);
            let back = format::parse_scenario(&text)
                .unwrap_or_else(|e| panic!("{}: {e}", sc.name));
            assert_eq!(&back, sc, "{} did not round-trip", sc.name);
        }
    }

    #[test]
    fn paper_preset_matches_table_i() {
        let sc = paper_femnist();
        let p = sc.params();
        let want = crate::config::SystemParams::femnist_small();
        assert_eq!(p.num_clients, want.num_clients);
        assert_eq!(p.num_channels, want.num_channels);
        assert_eq!(p.gamma, want.gamma);
        assert_eq!(p.t_max, want.t_max);
        assert_eq!(p.v, want.v);
        assert_eq!(sc.train.algorithms.len(), 5);
        let p = paper_cifar10().params();
        assert_eq!(p.gamma, 2000.0);
        assert_eq!(p.v, 10.0);
    }

    #[test]
    fn add_replaces_same_name() {
        let mut reg = ScenarioRegistry::builtin();
        let mut sc = paper_femnist();
        sc.train.rounds = 7;
        reg.add(sc);
        assert_eq!(reg.all().len(), 14);
        assert_eq!(reg.get("paper-femnist").unwrap().train.rounds, 7);
    }

    #[test]
    fn contention_scenarios_have_c_below_u() {
        let reg = ScenarioRegistry::builtin();
        for name in [
            "megacell-100",
            "zipf-skew",
            "cell-free-lite",
            "stress-1000",
            "stress-100k",
            "churn-100",
            "churn-1000",
            "churn-10000",
        ] {
            let t = &reg.get(name).unwrap().topology;
            assert!(t.channels < t.clients, "{name} should exercise C < U");
        }
    }

    #[test]
    fn churn_family_opts_into_churn() {
        for name in ["churn-100", "churn-1000", "churn-10000"] {
            let reg = ScenarioRegistry::builtin();
            let sc = reg.get(name).unwrap();
            assert!(sc.train.churn, "{name} must enable churn");
            assert_eq!(sc.train.over_select, 0.5, "{name} over-selects");
            assert_eq!((sc.train.p_join, sc.train.p_leave), (0.25, 0.1));
        }
        assert!(churn_100().train.staleness, "churn-100 exercises staleness weights");
        assert!(churn_10000().train.classes, "churn-10000 composes churn with classes");
        assert_eq!(churn_1000().train.eval_every, 0, "decision-only scale smoke");
    }

    #[test]
    fn chaos_family_opts_into_chaos() {
        let sc = chaos_100();
        assert!(sc.train.chaos, "chaos-100 must enable chaos");
        assert!(sc.train.chaos_decode > 0.0 && sc.train.chaos_straggle > 0.0);
        assert!(sc.train.chaos_ckpt > 0.0, "chaos-100 exercises snapshot corruption");
        assert_eq!(sc.train.chaos_panic, 0.0, "chaos-100 units must complete");
        assert_eq!(sc.train.chaos_retries, 2, "default retransmission budget");
        let sc = chaos_panic();
        assert!(sc.train.chaos);
        assert_eq!(sc.train.chaos_panic, 1.0, "chaos-panic poisons its unit");
        assert_eq!(sc.train.eval_every, 0, "no eval before the injected panic");
    }

    #[test]
    fn stress_100k_opts_into_classes() {
        let sc = stress_100k();
        assert!(sc.train.classes);
        assert_eq!((sc.train.class_size_bins, sc.train.class_rate_bins), (4, 4));
        assert_eq!((sc.topology.clients, sc.topology.channels), (100_000, 64));
        assert_eq!(sc.train.eval_every, 0, "decision-only scale smoke");
    }
}
