//! Scenario **file format**: a tiny sectioned KV-text dialect (TOML-ish;
//! serde/toml are unavailable offline, so this reuses the hand-rolled
//! parsing style of `util::argparse`). The full key reference with
//! defaults and paper cross-references lives in `docs/SCENARIOS.md`.
//!
//! ```text
//! # Full-line comments start with '#' (no inline comments).
//! # Values are bare tokens or "quoted strings".
//! [scenario]
//! name = "zipf-skew"
//! base = femnist
//!
//! [topology]
//! clients = 20
//! # channels is REQUIRED whenever clients is set:
//! channels = 12
//! ```
//!
//! Parsing is strict: unknown sections/keys are errors (catching typos
//! beats silently running the wrong physics), and setting `clients`
//! without `channels` is rejected — the legacy "C silently defaults to
//! U" behavior is exactly what made contention scenarios unreachable.
//!
//! [`render`] emits the canonical form; `parse(render(s)) == s` for
//! every valid scenario (the registry round-trip test pins this).

use std::fmt::Write as _;

use crate::experiments::Task;

use super::{Scenario, SizeDistKind};

/// Parse one scenario document. Returns a descriptive error with the
/// 1-based line number. The result is **not** validated — callers run
/// [`Scenario::validate`] (as [`super::load_file`] does) so presets
/// under construction can round-trip through text while still invalid.
pub fn parse_scenario(text: &str) -> Result<Scenario, String> {
    // Pass 1: (section, key, value) triples in file order.
    let mut entries: Vec<(String, String, String)> = Vec::new();
    let mut section = String::new();
    for (idx, raw) in text.lines().enumerate() {
        let line = raw.trim();
        let lineno = idx + 1;
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .ok_or_else(|| format!("line {lineno}: unterminated [section] header"))?;
            section = name.trim().to_string();
            // Reject unknown sections at the header, not only via their
            // keys — an empty typo'd section would otherwise slip
            // through the strict grammar.
            const SECTIONS: [&str; 6] =
                ["scenario", "topology", "data", "wireless", "compute", "train"];
            if !SECTIONS.contains(&section.as_str()) {
                return Err(format!(
                    "line {lineno}: unknown section `[{section}]` (known: {})",
                    SECTIONS.join(", ")
                ));
            }
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| format!("line {lineno}: expected `key = value` or `[section]`"))?;
        if section.is_empty() {
            return Err(format!("line {lineno}: key `{}` before any [section]", k.trim()));
        }
        let value = parse_value(v.trim()).map_err(|e| format!("line {lineno}: {e}"))?;
        let key = k.trim().to_string();
        // Strict like the rest of the grammar: a duplicated key would
        // otherwise resolve inconsistently (`base` is consumed before
        // the defaults are built, everything else after), and "which
        // assignment won?" is exactly the silent-wrong-physics failure
        // this parser exists to prevent.
        if entries.iter().any(|(s, k2, _)| *s == section && *k2 == key) {
            return Err(format!("line {lineno}: duplicate key `[{section}] {key}`"));
        }
        entries.push((section.clone(), key, value));
    }

    // The base column decides every default, so resolve it first.
    fn find<'a>(
        entries: &'a [(String, String, String)],
        sec: &str,
        key: &str,
    ) -> Option<&'a str> {
        entries.iter().find(|(s, k, _)| s == sec && k == key).map(|(_, _, v)| v.as_str())
    }
    let base = match find(&entries, "scenario", "base") {
        None | Some("femnist") => Task::Femnist,
        Some("cifar") | Some("cifar10") => Task::Cifar,
        Some(other) => return Err(format!("unknown base `{other}` (femnist|cifar)")),
    };
    let name = find(&entries, "scenario", "name")
        .ok_or("missing `[scenario] name`")?
        .to_string();

    let mut sc = Scenario::defaults(&name, base);
    let (mut saw_clients, mut saw_channels) = (false, false);
    for (section, key, value) in &entries {
        apply(&mut sc, section, key, value, &mut saw_clients, &mut saw_channels)?;
    }
    if saw_clients && !saw_channels {
        return Err(
            "`[topology] clients` set without `channels` — the channel count must be \
             explicit in scenario files (C silently defaulting to U is exactly the \
             bug that hid contention scenarios; see docs/SCENARIOS.md)"
                .into(),
        );
    }
    Ok(sc)
}

/// Apply one `[section] key = value` entry onto the scenario.
fn apply(
    sc: &mut Scenario,
    section: &str,
    key: &str,
    value: &str,
    saw_clients: &mut bool,
    saw_channels: &mut bool,
) -> Result<(), String> {
    let bad_num = |v: &str| format!("`[{section}] {key}`: bad number `{v}`");
    let f = |v: &str| v.parse::<f64>().map_err(|_| bad_num(v));
    let n = |v: &str| v.parse::<usize>().map_err(|_| bad_num(v));
    let b = |v: &str| match v {
        "true" | "on" | "1" => Ok(true),
        "false" | "off" | "0" => Ok(false),
        other => Err(format!(
            "`[{section}] {key}`: bad boolean `{other}` (true|false|on|off|1|0)"
        )),
    };
    match (section, key) {
        ("scenario", "name") => sc.name = value.to_string(),
        ("scenario", "description") => sc.description = value.to_string(),
        ("scenario", "base") => {} // consumed before defaults were built
        ("topology", "clients") => {
            sc.topology.clients = n(value)?;
            *saw_clients = true;
        }
        ("topology", "channels") => {
            sc.topology.channels = n(value)?;
            *saw_channels = true;
        }
        ("topology", "cell_radius_m") => sc.topology.cell_radius_m = f(value)?,
        ("topology", "aps") => sc.topology.aps = n(value)?,
        ("data", "size_dist") => {
            sc.data.dist = match value {
                "gaussian" => SizeDistKind::Gaussian,
                "uniform" => SizeDistKind::Uniform,
                "zipf" => SizeDistKind::Zipf,
                other => {
                    return Err(format!(
                        "`[data] size_dist`: unknown distribution `{other}` \
                         (gaussian|uniform|zipf)"
                    ))
                }
            }
        }
        ("data", "size_mean") => sc.data.size_mean = f(value)?,
        ("data", "size_std") => sc.data.size_std = f(value)?,
        ("data", "uniform_lo") => sc.data.uniform_lo = f(value)?,
        ("data", "uniform_hi") => sc.data.uniform_hi = f(value)?,
        ("data", "zipf_exponent") => sc.data.zipf_exponent = f(value)?,
        ("data", "dirichlet_alpha") => sc.data.dirichlet_alpha = f(value)?,
        ("data", "test_size") => sc.data.test_size = n(value)?,
        ("wireless", "gain_db") => sc.wireless.gain_db = f(value)?,
        ("wireless", "carrier_ghz") => sc.wireless.carrier_ghz = f(value)?,
        ("wireless", "rician_k") => sc.wireless.rician_k = f(value)?,
        ("wireless", "deep_fade_frac") => sc.wireless.deep_fade_frac = f(value)?,
        ("wireless", "deep_fade_db") => sc.wireless.deep_fade_db = f(value)?,
        ("compute", "gamma") => sc.compute.gamma = f(value)?,
        ("compute", "f_min") => sc.compute.f_min = f(value)?,
        ("compute", "f_max") => sc.compute.f_max = f(value)?,
        ("compute", "straggler_frac") => sc.compute.straggler_frac = f(value)?,
        ("compute", "straggler_slowdown") => sc.compute.straggler_slowdown = f(value)?,
        ("train", "algorithms") => {
            sc.train.algorithms = crate::baselines::algorithm_list(value)
        }
        ("train", "rounds") => sc.train.rounds = n(value)?,
        ("train", "v") => sc.train.v = Some(f(value)?),
        ("train", "tau") => sc.train.tau = Some(n(value)?),
        ("train", "eval_every") => sc.train.eval_every = n(value)?,
        ("train", "classes") => sc.train.classes = b(value)?,
        ("train", "class_size_bins") => sc.train.class_size_bins = n(value)?,
        ("train", "class_rate_bins") => sc.train.class_rate_bins = n(value)?,
        ("train", "churn") => sc.train.churn = b(value)?,
        ("train", "p_join") => sc.train.p_join = f(value)?,
        ("train", "p_leave") => sc.train.p_leave = f(value)?,
        ("train", "over_select") => sc.train.over_select = f(value)?,
        ("train", "staleness") => sc.train.staleness = b(value)?,
        ("train", "chaos") => sc.train.chaos = b(value)?,
        ("train", "chaos_decode") => sc.train.chaos_decode = f(value)?,
        ("train", "chaos_straggle") => sc.train.chaos_straggle = f(value)?,
        ("train", "chaos_panic") => sc.train.chaos_panic = f(value)?,
        ("train", "chaos_retries") => sc.train.chaos_retries = n(value)?,
        ("train", "chaos_ckpt") => sc.train.chaos_ckpt = f(value)?,
        _ => {
            return Err(format!(
                "unknown key `[{section}] {key}` (see docs/SCENARIOS.md for the reference)"
            ))
        }
    }
    Ok(())
}

/// Decode a value token: `"..."` with `\"`/`\\`/`\n` escapes, or a bare
/// token taken verbatim.
fn parse_value(v: &str) -> Result<String, String> {
    if let Some(rest) = v.strip_prefix('"') {
        let mut out = String::new();
        let mut chars = rest.chars();
        loop {
            match chars.next() {
                None => return Err("unterminated quoted value".into()),
                Some('"') => {
                    let tail: String = chars.collect();
                    if !tail.trim().is_empty() {
                        return Err(format!("trailing data after quoted value: `{tail}`"));
                    }
                    return Ok(out);
                }
                Some('\\') => match chars.next() {
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    Some('n') => out.push('\n'),
                    other => return Err(format!("bad escape `\\{}`", other.unwrap_or(' '))),
                },
                Some(c) => out.push(c),
            }
        }
    } else if v.contains('"') {
        Err(format!("stray quote in bare value `{v}`"))
    } else {
        Ok(v.to_string())
    }
}

/// Encode for [`render`]: bare when safe, quoted otherwise.
fn render_value(v: &str) -> String {
    let bare_safe = !v.is_empty()
        && !v.contains(|c: char| c.is_whitespace() || c == '"' || c == '#' || c == '=');
    if bare_safe {
        v.to_string()
    } else {
        let mut out = String::from("\"");
        for c in v.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                c => out.push(c),
            }
        }
        out.push('"');
        out
    }
}

/// Render the canonical scenario-file form (every key explicit, so a
/// rendered file doubles as a fully-specified record of the run).
/// Round-trips: `parse_scenario(render(sc)) == sc`.
pub fn render(sc: &Scenario) -> String {
    let mut o = String::new();
    let _ = writeln!(o, "# scenario `{}` (format reference: docs/SCENARIOS.md)", sc.name);
    let _ = writeln!(o, "[scenario]");
    let _ = writeln!(o, "name = {}", render_value(&sc.name));
    let _ = writeln!(o, "description = {}", render_value(&sc.description));
    let base = match sc.base {
        Task::Femnist => "femnist",
        Task::Cifar => "cifar",
    };
    let _ = writeln!(o, "base = {base}");
    let _ = writeln!(o);
    let t = &sc.topology;
    let _ = writeln!(o, "[topology]");
    let _ = writeln!(o, "clients = {}", t.clients);
    let _ = writeln!(o, "channels = {}", t.channels);
    let _ = writeln!(o, "cell_radius_m = {}", t.cell_radius_m);
    let _ = writeln!(o, "aps = {}", t.aps);
    let _ = writeln!(o);
    let d = &sc.data;
    let _ = writeln!(o, "[data]");
    let dist = match d.dist {
        SizeDistKind::Gaussian => "gaussian",
        SizeDistKind::Uniform => "uniform",
        SizeDistKind::Zipf => "zipf",
    };
    let _ = writeln!(o, "size_dist = {dist}");
    let _ = writeln!(o, "size_mean = {}", d.size_mean);
    let _ = writeln!(o, "size_std = {}", d.size_std);
    let _ = writeln!(o, "uniform_lo = {}", d.uniform_lo);
    let _ = writeln!(o, "uniform_hi = {}", d.uniform_hi);
    let _ = writeln!(o, "zipf_exponent = {}", d.zipf_exponent);
    let _ = writeln!(o, "dirichlet_alpha = {}", d.dirichlet_alpha);
    let _ = writeln!(o, "test_size = {}", d.test_size);
    let _ = writeln!(o);
    let w = &sc.wireless;
    let _ = writeln!(o, "[wireless]");
    let _ = writeln!(o, "gain_db = {}", w.gain_db);
    let _ = writeln!(o, "carrier_ghz = {}", w.carrier_ghz);
    let _ = writeln!(o, "rician_k = {}", w.rician_k);
    let _ = writeln!(o, "deep_fade_frac = {}", w.deep_fade_frac);
    let _ = writeln!(o, "deep_fade_db = {}", w.deep_fade_db);
    let _ = writeln!(o);
    let c = &sc.compute;
    let _ = writeln!(o, "[compute]");
    let _ = writeln!(o, "gamma = {}", c.gamma);
    let _ = writeln!(o, "f_min = {}", c.f_min);
    let _ = writeln!(o, "f_max = {}", c.f_max);
    let _ = writeln!(o, "straggler_frac = {}", c.straggler_frac);
    let _ = writeln!(o, "straggler_slowdown = {}", c.straggler_slowdown);
    let _ = writeln!(o);
    let tr = &sc.train;
    let _ = writeln!(o, "[train]");
    let _ = writeln!(o, "algorithms = {}", tr.algorithms.join(","));
    let _ = writeln!(o, "rounds = {}", tr.rounds);
    if let Some(v) = tr.v {
        let _ = writeln!(o, "v = {v}");
    }
    if let Some(tau) = tr.tau {
        let _ = writeln!(o, "tau = {tau}");
    }
    let _ = writeln!(o, "eval_every = {}", tr.eval_every);
    let _ = writeln!(o, "classes = {}", tr.classes);
    let _ = writeln!(o, "class_size_bins = {}", tr.class_size_bins);
    let _ = writeln!(o, "class_rate_bins = {}", tr.class_rate_bins);
    // The churn block is all-or-nothing and appears only when any knob
    // differs from its default: pre-churn scenarios keep byte-identical
    // canonical renders (the ckpt identity check compares renders), and
    // `parse(render(sc)) == sc` holds either way because parsing starts
    // from the same defaults.
    let churn_default = !tr.churn
        && tr.p_join == 0.25
        && tr.p_leave == 0.1
        && tr.over_select == 0.0
        && !tr.staleness;
    if !churn_default {
        let _ = writeln!(o, "churn = {}", tr.churn);
        let _ = writeln!(o, "p_join = {}", tr.p_join);
        let _ = writeln!(o, "p_leave = {}", tr.p_leave);
        let _ = writeln!(o, "over_select = {}", tr.over_select);
        let _ = writeln!(o, "staleness = {}", tr.staleness);
    }
    // Chaos block: same all-or-nothing rule as churn, for the same
    // reasons (byte-identical canonical renders for chaos-free
    // scenarios; round-trip holds either way).
    let chaos_default = !tr.chaos
        && tr.chaos_decode == 0.0
        && tr.chaos_straggle == 0.0
        && tr.chaos_panic == 0.0
        && tr.chaos_retries == 2
        && tr.chaos_ckpt == 0.0;
    if !chaos_default {
        let _ = writeln!(o, "chaos = {}", tr.chaos);
        let _ = writeln!(o, "chaos_decode = {}", tr.chaos_decode);
        let _ = writeln!(o, "chaos_straggle = {}", tr.chaos_straggle);
        let _ = writeln!(o, "chaos_panic = {}", tr.chaos_panic);
        let _ = writeln!(o, "chaos_retries = {}", tr.chaos_retries);
        let _ = writeln!(o, "chaos_ckpt = {}", tr.chaos_ckpt);
    }
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimal_file_inherits_base_defaults() {
        let sc = parse_scenario("[scenario]\nname = tiny-check\n").unwrap();
        assert_eq!(sc.name, "tiny-check");
        assert_eq!(sc.base, Task::Femnist);
        assert_eq!(sc.topology.clients, 10);
        assert_eq!(sc.data.size_mean, 1200.0);
        assert_eq!(sc.train.algorithms, vec!["qccf"]);
    }

    #[test]
    fn full_file_parses() {
        let text = r#"
            # a contention scenario
            [scenario]
            name = "contended"
            description = "C < U with a \"quoted\" word"
            base = cifar

            [topology]
            clients = 24
            channels = 8
            cell_radius_m = 750
            aps = 2

            [data]
            size_dist = zipf
            zipf_exponent = 1.3
            size_mean = 900

            [train]
            algorithms = qccf, same-size
            rounds = 12
            v = 25
        "#;
        let sc = parse_scenario(text).unwrap();
        assert_eq!(sc.base, Task::Cifar);
        assert_eq!((sc.topology.clients, sc.topology.channels, sc.topology.aps), (24, 8, 2));
        assert_eq!(sc.data.dist, SizeDistKind::Zipf);
        assert_eq!(sc.data.zipf_exponent, 1.3);
        assert_eq!(sc.description, "C < U with a \"quoted\" word");
        assert_eq!(sc.train.algorithms, vec!["qccf", "same-size"]);
        assert_eq!(sc.train.v, Some(25.0));
        // Base (cifar) fills what the file leaves out.
        assert_eq!(sc.compute.gamma, 2000.0);
        assert!(sc.validate().is_empty(), "{:?}", sc.validate());
    }

    #[test]
    fn clients_without_channels_rejected() {
        let text = "[scenario]\nname = x\n[topology]\nclients = 50\n";
        let err = parse_scenario(text).unwrap_err();
        assert!(err.contains("channels"), "{err}");
    }

    #[test]
    fn unknown_keys_and_sections_rejected() {
        let err =
            parse_scenario("[scenario]\nname = x\n[topology]\nclientz = 5\n").unwrap_err();
        assert!(err.contains("unknown key"), "{err}");
        // Unknown sections fail at the header — even when empty.
        let err = parse_scenario("[scenario]\nname = x\n[mystery]\nfoo = 1\n").unwrap_err();
        assert!(err.contains("unknown section"), "{err}");
        let err = parse_scenario("[scenario]\nname = x\n[wirelss]\n").unwrap_err();
        assert!(err.contains("unknown section"), "{err}");
        let err = parse_scenario("name = x\n").unwrap_err();
        assert!(err.contains("before any"), "{err}");
        assert!(parse_scenario("[scenario]\nrounds\n").is_err());
    }

    #[test]
    fn duplicate_keys_rejected() {
        let err = parse_scenario("[scenario]\nname = x\nname = y\n").unwrap_err();
        assert!(err.contains("duplicate key"), "{err}");
        let err =
            parse_scenario("[scenario]\nname = x\nbase = femnist\nbase = cifar\n").unwrap_err();
        assert!(err.contains("duplicate key"), "{err}");
        // Same key in different sections is fine (none exist today, but
        // the check is per (section, key)).
        assert!(parse_scenario("[scenario]\nname = x\n[train]\nrounds = 3\n").is_ok());
    }

    #[test]
    fn missing_name_rejected() {
        assert!(parse_scenario("[scenario]\nbase = femnist\n").unwrap_err().contains("name"));
    }

    #[test]
    fn algorithms_all_expands() {
        let sc = parse_scenario("[scenario]\nname = x\n[train]\nalgorithms = all\n").unwrap();
        assert_eq!(sc.train.algorithms.len(), crate::baselines::ALL_ALGORITHMS.len());
    }

    #[test]
    fn classes_knobs_parse_and_reject_bad_bool() {
        let text = "[scenario]\nname = cls\n[train]\nclasses = on\nclass_size_bins = 8\n";
        let sc = parse_scenario(text).unwrap();
        assert!(sc.train.classes);
        assert_eq!(sc.train.class_size_bins, 8);
        assert_eq!(sc.train.class_rate_bins, 4, "untouched knob keeps its default");
        let bad = "[scenario]\nname = cls\n[train]\nclasses = maybe\n";
        let err = parse_scenario(bad).unwrap_err();
        assert!(err.contains("bad boolean"), "{err}");
    }

    #[test]
    fn churn_knobs_parse_render_and_reject_bad_values() {
        let text = "[scenario]\nname = ch\n[train]\nchurn = on\np_leave = 0.2\n\
                    over_select = 0.5\nstaleness = true\n";
        let sc = parse_scenario(text).unwrap();
        assert!(sc.train.churn && sc.train.staleness);
        assert_eq!(sc.train.p_leave, 0.2);
        assert_eq!(sc.train.p_join, 0.25, "untouched knob keeps its default");
        assert_eq!(sc.train.over_select, 0.5);
        // Round-trips through the canonical render.
        let back = parse_scenario(&render(&sc)).unwrap();
        assert_eq!(back, sc);
        // Bad boolean / number are named errors.
        let err = parse_scenario("[scenario]\nname = ch\n[train]\nchurn = maybe\n")
            .unwrap_err();
        assert!(err.contains("bad boolean"), "{err}");
        let err = parse_scenario("[scenario]\nname = ch\n[train]\np_leave = often\n")
            .unwrap_err();
        assert!(err.contains("bad number"), "{err}");
    }

    #[test]
    fn default_churn_knobs_render_no_churn_block() {
        // Pre-churn scenarios must keep byte-identical canonical
        // renders: all five knobs at defaults = no churn lines at all.
        let sc = Scenario::defaults("plain", Task::Femnist);
        let text = render(&sc);
        for key in ["churn", "p_join", "p_leave", "over_select", "staleness"] {
            assert!(
                !text.lines().any(|l| l.starts_with(&format!("{key} ="))),
                "default render leaked `{key}`:\n{text}"
            );
        }
        // Any single non-default knob brings the whole block.
        let mut sc = Scenario::defaults("plain", Task::Femnist);
        sc.train.over_select = 0.25;
        let text = render(&sc);
        for key in ["churn", "p_join", "p_leave", "over_select", "staleness"] {
            assert!(
                text.lines().any(|l| l.starts_with(&format!("{key} ="))),
                "non-default render missing `{key}`:\n{text}"
            );
        }
        assert_eq!(parse_scenario(&text).unwrap(), sc);
    }

    #[test]
    fn chaos_knobs_parse_render_and_reject_bad_values() {
        let text = "[scenario]\nname = cz\n[train]\nchaos = on\nchaos_decode = 0.3\n\
                    chaos_retries = 5\nchaos_ckpt = 0.1\n";
        let sc = parse_scenario(text).unwrap();
        assert!(sc.train.chaos);
        assert_eq!(sc.train.chaos_decode, 0.3);
        assert_eq!(sc.train.chaos_straggle, 0.0, "untouched knob keeps its default");
        assert_eq!(sc.train.chaos_retries, 5);
        assert_eq!(sc.train.chaos_ckpt, 0.1);
        // Round-trips through the canonical render.
        let back = parse_scenario(&render(&sc)).unwrap();
        assert_eq!(back, sc);
        // Bad boolean / number are named errors.
        let err =
            parse_scenario("[scenario]\nname = cz\n[train]\nchaos = maybe\n").unwrap_err();
        assert!(err.contains("bad boolean"), "{err}");
        let err = parse_scenario("[scenario]\nname = cz\n[train]\nchaos_decode = lots\n")
            .unwrap_err();
        assert!(err.contains("bad number"), "{err}");
        let err = parse_scenario("[scenario]\nname = cz\n[train]\nchaos_retries = 1.5\n")
            .unwrap_err();
        assert!(err.contains("bad number"), "{err}");
    }

    #[test]
    fn default_chaos_knobs_render_no_chaos_block() {
        // Chaos-free scenarios must keep byte-identical canonical
        // renders: all six knobs at defaults = no chaos lines at all.
        let sc = Scenario::defaults("plain", Task::Femnist);
        let text = render(&sc);
        for key in
            ["chaos", "chaos_decode", "chaos_straggle", "chaos_panic", "chaos_retries",
             "chaos_ckpt"]
        {
            assert!(
                !text.lines().any(|l| l.starts_with(&format!("{key} ="))),
                "default render leaked `{key}`:\n{text}"
            );
        }
        // Any single non-default knob brings the whole block.
        let mut sc = Scenario::defaults("plain", Task::Femnist);
        sc.train.chaos_retries = 4;
        let text = render(&sc);
        for key in
            ["chaos", "chaos_decode", "chaos_straggle", "chaos_panic", "chaos_retries",
             "chaos_ckpt"]
        {
            assert!(
                text.lines().any(|l| l.starts_with(&format!("{key} ="))),
                "non-default render missing `{key}`:\n{text}"
            );
        }
        assert_eq!(parse_scenario(&text).unwrap(), sc);
    }

    #[test]
    fn value_quoting_roundtrips() {
        for v in ["plain", "two words", "esc \" and \\ and\nnewline", "# hash", "a=b"] {
            let enc = render_value(v);
            assert_eq!(parse_value(&enc).unwrap(), v, "enc={enc}");
        }
        assert!(parse_value("\"unterminated").is_err());
        assert!(parse_value("stray\"quote").is_err());
    }

    #[test]
    fn render_parse_roundtrip_with_overrides() {
        let mut sc = Scenario::defaults("rt-check", Task::Cifar);
        sc.description = "multi word, with = sign".into();
        sc.topology.clients = 64;
        sc.topology.channels = 16;
        sc.data.dist = SizeDistKind::Uniform;
        sc.train.v = Some(12.5);
        sc.train.tau = Some(6);
        sc.train.algorithms = vec!["qccf".into(), "principle".into()];
        sc.train.classes = true;
        sc.train.class_size_bins = 6;
        sc.train.class_rate_bins = 3;
        let text = render(&sc);
        let back = parse_scenario(&text).unwrap();
        assert_eq!(back, sc);
        // And canonical text is a fixed point.
        assert_eq!(render(&back), text);
    }
}
