//! Declarative **scenario subsystem**: one [`Scenario`] value describes
//! a complete workload — topology (clients, channels, cell layout),
//! heterogeneity profiles (dataset-size distribution, channel-gain
//! classes, CPU classes), algorithm list and hyperparameters (β, V, τ),
//! and scale knobs — and converts into the [`SystemParams`] +
//! [`DataGenConfig`] pair the round engine runs on.
//!
//! Scenarios come from three places:
//!
//! * the [`registry::ScenarioRegistry`] of built-ins (the two Table-I
//!   profiles plus six stress/heterogeneity workloads motivated by the
//!   related work — see `docs/SCENARIOS.md` for each one's rationale);
//! * KV-text **scenario files** ([`format::parse_scenario`] /
//!   `--scenario-file` on the CLI) — the format reference lives in
//!   `docs/SCENARIOS.md`;
//! * the fig harnesses, whose [`crate::experiments::RunSpec`] is now a
//!   thin preset over [`registry::paper_femnist`] /
//!   [`registry::paper_cifar10`] (so every figure reproduces through
//!   the same path a custom scenario takes).
//!
//! The `sweep` CLI subcommand cross-products scenarios × seeds ×
//! algorithms and fans the runs out over the thread pool
//! ([`crate::experiments::sweep`]); each run inherits the round
//! engine's per-run determinism contract, so sweep outputs are
//! bit-identical for any `--threads` value.

pub mod format;
pub mod registry;

use std::path::Path;

use crate::baselines::ALL_ALGORITHMS;
use crate::config::SystemParams;
use crate::data::{DataGenConfig, SizeDist};
use crate::experiments::Task;
use crate::runtime::Runtime;

pub use format::{parse_scenario, render};
pub use registry::ScenarioRegistry;

/// Which dataset-size distribution a scenario uses (the spec-level
/// mirror of [`SizeDist`]; the numeric knobs live in [`DataSpec`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SizeDistKind {
    /// `D_i ~ N(µ, β)` — the paper's §VI setting.
    Gaussian,
    /// `D_i ~ U[uniform_lo, uniform_hi)`.
    Uniform,
    /// `D_i ∝ rank^{-zipf_exponent}`, mean-preserving.
    Zipf,
}

/// Topology: federation size, spectrum, and cell layout.
#[derive(Clone, Debug, PartialEq)]
pub struct Topology {
    /// U — number of clients (scale knob; 10 in the paper, up to ~1000
    /// for stress scenarios — data is synthetic, so any U works on any
    /// artifact profile).
    pub clients: usize,
    /// C — OFDMA channels. **Must be explicit in scenario files** and
    /// satisfy `1 <= C <= U` ([`Scenario::validate`]); `C < U` creates
    /// the contention regime the paper's C1–C3 constraints are about.
    pub channels: usize,
    /// Deployment radius in meters (paper: 500 m disk).
    pub cell_radius_m: f64,
    /// Access points: `1` = single cell (paper), `> 1` = cell-free
    /// lite — nearest-AP pathloss (cf. arXiv:2412.20785).
    pub aps: usize,
}

/// Data heterogeneity profile.
#[derive(Clone, Debug, PartialEq)]
pub struct DataSpec {
    /// Which size distribution applies.
    pub dist: SizeDistKind,
    /// µ — mean dataset size (all distributions).
    pub size_mean: f64,
    /// β — dataset-size std (Gaussian only; the paper sweeps 150/300).
    pub size_std: f64,
    /// Lower size bound (Uniform only).
    pub uniform_lo: f64,
    /// Upper size bound (Uniform only).
    pub uniform_hi: f64,
    /// Skew exponent (Zipf only; > 0, larger = heavier head).
    pub zipf_exponent: f64,
    /// Dirichlet concentration for label skew (smaller = more non-IID).
    pub dirichlet_alpha: f64,
    /// Balanced test-set size.
    pub test_size: usize,
}

/// Wireless profile: calibration knobs plus the deep-fade class.
#[derive(Clone, Debug, PartialEq)]
pub struct WirelessSpec {
    /// h^Gain in dB (the calibration knob; see `config` module docs).
    pub gain_db: f64,
    /// Carrier frequency in GHz.
    pub carrier_ghz: f64,
    /// Rician K-factor.
    pub rician_k: f64,
    /// Fraction of clients in the deep-fade class (0 disables).
    pub deep_fade_frac: f64,
    /// Extra large-scale attenuation (dB) for that class.
    pub deep_fade_db: f64,
}

/// Compute profile: DVFS range, workload constant, and the straggler
/// class.
#[derive(Clone, Debug, PartialEq)]
pub struct ComputeSpec {
    /// γ — CPU cycles per sample.
    pub gamma: f64,
    /// f^min — DVFS lower bound (Hz).
    pub f_min: f64,
    /// f^max — DVFS upper bound (Hz).
    pub f_max: f64,
    /// Fraction of clients whose realized frequency is throttled
    /// (0 disables; see [`SystemParams::straggler_frac`]).
    pub straggler_frac: f64,
    /// Realized-frequency multiplier for the straggler class, (0, 1].
    pub straggler_slowdown: f64,
}

/// What to run: algorithms and training hyperparameters.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainSpec {
    /// Scheduling algorithms to run (subset of
    /// [`ALL_ALGORITHMS`]; `all` in a scenario file expands to the full
    /// list).
    pub algorithms: Vec<String>,
    /// Communication rounds per run.
    pub rounds: usize,
    /// V — Lyapunov penalty weight (`None` = the base column's
    /// default: 100 for FEMNIST, 10 for CIFAR).
    pub v: Option<f64>,
    /// τ — local updates per round at the *decision* layer (`None` =
    /// base default). Note the artifact's train-step count is fixed at
    /// AOT time; this knob only moves the latency/energy accounting
    /// and the theorem constants.
    pub tau: Option<usize>,
    /// Evaluate every k rounds (0 = never — decision-only runs).
    pub eval_every: usize,
    /// Hierarchical class-based scheduling for the GA decision stage
    /// (`sched::classes`): QCCF buckets clients into equivalence
    /// classes and searches class × channel-pool chromosomes. Off by
    /// default — the exact per-client GA runs — and additionally
    /// subject to the process-wide `QCCF_DECISION_CLASSES=0` kill
    /// switch.
    pub classes: bool,
    /// Rank bins over dataset sizes for the class partition (≥ 1;
    /// only read when `classes = true`).
    pub class_size_bins: usize,
    /// Rank bins over mean uplink rates for the class partition (≥ 1;
    /// only read when `classes = true`).
    pub class_rate_bins: usize,
    /// Client churn: clients follow a seeded per-client Markov on/off
    /// availability process ([`crate::fl::avail`]). Off by default —
    /// everyone is always available and the engine takes the exact
    /// pre-churn path.
    pub churn: bool,
    /// Per-round probability an *offline* client rejoins (churn only).
    pub p_join: f64,
    /// Per-round probability an *online* client departs (churn only).
    pub p_leave: f64,
    /// Over-selection factor β ≥ 0 (churn only): the round schedules S
    /// clients but aggregates only the first ⌈S/(1+β)⌉ survivors in
    /// client order, hedging against mid-round departures. 0 disables
    /// the cap.
    pub over_select: f64,
    /// Staleness-weighted aggregation (churn only): a client's
    /// aggregation weight is scaled by `1/(1+m)` where `m` is the
    /// number of rounds since its update last entered an aggregate.
    pub staleness: bool,
    /// Deterministic fault injection ([`crate::fl::faults`]). Off by
    /// default — the engine takes the exact chaos-free path and the
    /// `chaos_*` knobs below are ignored.
    pub chaos: bool,
    /// Per-attempt probability an upload fails to decode and must be
    /// retransmitted (chaos only).
    pub chaos_decode: f64,
    /// Per-round probability a scheduled client straggles — its compute
    /// stalls by [`crate::fl::exec::STRAGGLE_FACTOR`] (chaos only).
    pub chaos_straggle: f64,
    /// Per-round probability a scheduled client's worker panics
    /// (chaos only; exercises sweep-level unit isolation).
    pub chaos_panic: f64,
    /// Retransmission budget: retries allowed after the first decode
    /// attempt before the client folds into the departed path
    /// (chaos only).
    pub chaos_retries: usize,
    /// Per-snapshot probability a checkpoint write is corrupted after
    /// landing on disk (chaos only; exercises the recovery ladder).
    pub chaos_ckpt: f64,
}

/// A complete declarative workload description. See the module docs for
/// where scenarios come from and `docs/SCENARIOS.md` for the file
/// format; [`Scenario::params`] / [`Scenario::datagen`] are the bridges
/// into the run path.
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    /// Unique kebab-case name (file stem for sweep traces).
    pub name: String,
    /// One-paragraph rationale (shown by `sweep --list`).
    pub description: String,
    /// Which Table-I column supplies the unlisted constants
    /// (`femnist` or `cifar`).
    pub base: Task,
    /// Federation size, spectrum and layout.
    pub topology: Topology,
    /// Dataset-size / label-skew heterogeneity.
    pub data: DataSpec,
    /// Channel statistics and gain classes.
    pub wireless: WirelessSpec,
    /// DVFS range and CPU classes.
    pub compute: ComputeSpec,
    /// Algorithms + hyperparameters.
    pub train: TrainSpec,
}

impl Scenario {
    /// A scenario named `name` whose every knob equals the `base`
    /// Table-I column (plus the paper's data defaults µ = 1200,
    /// β = 150, Dirichlet 0.5). Built-ins and file parsing start here
    /// and override.
    pub fn defaults(name: &str, base: Task) -> Scenario {
        let p = match base {
            Task::Femnist => SystemParams::femnist_small(),
            Task::Cifar => SystemParams::cifar_small(),
        };
        Scenario {
            name: name.to_string(),
            description: String::new(),
            base,
            topology: Topology {
                clients: p.num_clients,
                channels: p.num_channels,
                cell_radius_m: p.cell_radius_m,
                aps: p.num_aps,
            },
            data: DataSpec {
                dist: SizeDistKind::Gaussian,
                size_mean: 1200.0,
                size_std: 150.0,
                uniform_lo: 600.0,
                uniform_hi: 1800.0,
                zipf_exponent: 1.1,
                dirichlet_alpha: 0.5,
                test_size: 512,
            },
            wireless: WirelessSpec {
                gain_db: p.gain_db,
                carrier_ghz: p.carrier_ghz,
                rician_k: p.rician_k,
                deep_fade_frac: p.deep_fade_frac,
                deep_fade_db: p.deep_fade_db,
            },
            compute: ComputeSpec {
                gamma: p.gamma,
                f_min: p.f_min,
                f_max: p.f_max,
                straggler_frac: p.straggler_frac,
                straggler_slowdown: p.straggler_slowdown,
            },
            train: TrainSpec {
                algorithms: vec!["qccf".to_string()],
                rounds: 40,
                v: None,
                tau: None,
                eval_every: 2,
                classes: false,
                class_size_bins: 4,
                class_rate_bins: 4,
                churn: false,
                p_join: 0.25,
                p_leave: 0.1,
                over_select: 0.0,
                staleness: false,
                chaos: false,
                chaos_decode: 0.0,
                chaos_straggle: 0.0,
                chaos_panic: 0.0,
                chaos_retries: 2,
                chaos_ckpt: 0.0,
            },
        }
    }

    /// The raw [`SystemParams`] this scenario describes: the base
    /// Table-I column with every scenario knob applied. Use
    /// [`Scenario::params_for_runtime`] on the run path — it also
    /// adapts T^max/η to the loaded artifact profile.
    pub fn params(&self) -> SystemParams {
        let mut p = match self.base {
            Task::Femnist => SystemParams::femnist_small(),
            Task::Cifar => SystemParams::cifar_small(),
        };
        p.num_clients = self.topology.clients;
        p.num_channels = self.topology.channels;
        p.cell_radius_m = self.topology.cell_radius_m;
        p.num_aps = self.topology.aps;
        p.gain_db = self.wireless.gain_db;
        p.carrier_ghz = self.wireless.carrier_ghz;
        p.rician_k = self.wireless.rician_k;
        p.deep_fade_frac = self.wireless.deep_fade_frac;
        p.deep_fade_db = self.wireless.deep_fade_db;
        p.gamma = self.compute.gamma;
        p.f_min = self.compute.f_min;
        p.f_max = self.compute.f_max;
        p.straggler_frac = self.compute.straggler_frac;
        p.straggler_slowdown = self.compute.straggler_slowdown;
        if let Some(v) = self.train.v {
            p.v = v;
        }
        if let Some(tau) = self.train.tau {
            p.tau = tau;
        }
        p
    }

    /// [`Scenario::params`] adapted to a loaded runtime, mirroring the
    /// historical `params_for` calibration exactly: T^max scales with
    /// the profile's Z (same per-dimension latency pressure), keeps
    /// 2× headroom over the minimum compute latency at µ, and η comes
    /// from the artifact's tuned learning rate.
    pub fn params_for_runtime(&self, rt: &Runtime) -> SystemParams {
        let mut p = self.params();
        let z_ref = p.z;
        p.z = rt.info.z;
        p.t_max *= rt.info.z as f64 / z_ref as f64;
        let t_cmp_min = p.tau_e as f64 * p.gamma * self.data.size_mean / p.f_max;
        if p.t_max < 2.0 * t_cmp_min {
            p.t_max = 2.0 * t_cmp_min;
        }
        p.eta = rt.info.lr;
        p
    }

    /// The [`SizeDist`] value [`Scenario::datagen`] installs.
    pub fn size_dist(&self) -> SizeDist {
        match self.data.dist {
            SizeDistKind::Gaussian => SizeDist::Gaussian,
            SizeDistKind::Uniform => SizeDist::Uniform {
                lo: self.data.uniform_lo,
                hi: self.data.uniform_hi,
            },
            SizeDistKind::Zipf => SizeDist::Zipf { exponent: self.data.zipf_exponent },
        }
    }

    /// Federation-generation config for this scenario on a loaded
    /// runtime (image dims / class count come from the artifact).
    pub fn datagen(&self, rt: &Runtime) -> DataGenConfig {
        let mut d = DataGenConfig::new(self.topology.clients, rt.info.image, rt.info.classes);
        d.size_dist = self.size_dist();
        d.size_mean = self.data.size_mean;
        d.size_std = self.data.size_std;
        d.dirichlet_alpha = self.data.dirichlet_alpha;
        d.test_size = self.data.test_size;
        d
    }

    /// Validate the scenario; returns the violated conditions (empty =
    /// good). Includes [`SystemParams::validate`] on the derived
    /// parameters, so theorem prerequisites and the explicit-C rule
    /// (C = 0 or C > U is an error) are enforced on every path.
    pub fn validate(&self) -> Vec<String> {
        let mut errs = Vec::new();
        // The name becomes a trace-file stem under the sweep's --out
        // directory, so it must not be able to traverse out of it.
        let name_ok = !self.name.is_empty()
            && self
                .name
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'))
            && !self.name.contains("..");
        if !name_ok {
            errs.push(format!(
                "name `{}` must be non-empty, use only [A-Za-z0-9._-], and not contain `..` \
                 (it becomes a file stem)",
                self.name
            ));
        }
        let t = &self.topology;
        if t.clients == 0 {
            errs.push("topology: need at least one client".into());
        }
        if t.channels == 0 {
            errs.push(
                "topology: C = 0 channels — no round could schedule anyone; \
                 set `channels` explicitly (1 ..= clients)"
                    .into(),
            );
        }
        if t.channels > t.clients {
            errs.push(format!(
                "topology: C = {} channels > U = {} clients — idle channels are \
                 unreachable under C1–C3; set channels <= clients",
                t.channels, t.clients
            ));
        }
        if t.cell_radius_m <= 0.0 {
            errs.push("topology: cell_radius_m must be positive".into());
        }
        let d = &self.data;
        if d.size_mean <= 0.0 {
            errs.push("data: size_mean must be positive".into());
        }
        match d.dist {
            SizeDistKind::Gaussian => {
                if d.size_std < 0.0 {
                    errs.push("data: size_std must be non-negative".into());
                }
            }
            SizeDistKind::Uniform => {
                if !(d.uniform_lo > 0.0 && d.uniform_lo <= d.uniform_hi) {
                    errs.push(format!(
                        "data: need 0 < uniform_lo <= uniform_hi (got {} .. {})",
                        d.uniform_lo, d.uniform_hi
                    ));
                }
            }
            SizeDistKind::Zipf => {
                if d.zipf_exponent <= 0.0 {
                    errs.push("data: zipf_exponent must be positive".into());
                }
            }
        }
        if d.test_size == 0 {
            errs.push("data: test_size must be at least 1".into());
        }
        let tr = &self.train;
        if tr.rounds == 0 {
            errs.push("train: rounds must be at least 1".into());
        }
        if tr.algorithms.is_empty() {
            errs.push("train: need at least one algorithm".into());
        }
        let mut seen_algs = std::collections::BTreeSet::new();
        for alg in &tr.algorithms {
            if !ALL_ALGORITHMS.contains(&alg.as_str()) {
                errs.push(format!(
                    "train: unknown algorithm `{alg}` (known: {})",
                    ALL_ALGORITHMS.join(", ")
                ));
            }
            if !seen_algs.insert(alg.as_str()) {
                errs.push(format!(
                    "train: algorithm `{alg}` listed twice (each (scenario, algorithm, \
                     seed) run owns one trace file)"
                ));
            }
        }
        if self.train.class_size_bins == 0 {
            errs.push("class_size_bins must be >= 1".to_string());
        }
        if self.train.class_rate_bins == 0 {
            errs.push("class_rate_bins must be >= 1".to_string());
        }
        if !(tr.p_join.is_finite() && (0.0..=1.0).contains(&tr.p_join)) {
            errs.push(format!("train: p_join must be in [0, 1] (got {})", tr.p_join));
        }
        if !(tr.p_leave.is_finite() && (0.0..=1.0).contains(&tr.p_leave)) {
            errs.push(format!("train: p_leave must be in [0, 1] (got {})", tr.p_leave));
        }
        if !(tr.over_select.is_finite() && tr.over_select >= 0.0) {
            errs.push(format!(
                "train: over_select must be finite and >= 0 (got {})",
                tr.over_select
            ));
        }
        if !(tr.chaos_decode.is_finite() && (0.0..=1.0).contains(&tr.chaos_decode)) {
            errs.push(format!(
                "train: chaos_decode must be in [0, 1] (got {})",
                tr.chaos_decode
            ));
        }
        if !(tr.chaos_straggle.is_finite() && (0.0..=1.0).contains(&tr.chaos_straggle)) {
            errs.push(format!(
                "train: chaos_straggle must be in [0, 1] (got {})",
                tr.chaos_straggle
            ));
        }
        if !(tr.chaos_panic.is_finite() && (0.0..=1.0).contains(&tr.chaos_panic)) {
            errs.push(format!(
                "train: chaos_panic must be in [0, 1] (got {})",
                tr.chaos_panic
            ));
        }
        if !(tr.chaos_ckpt.is_finite() && (0.0..=1.0).contains(&tr.chaos_ckpt)) {
            errs.push(format!("train: chaos_ckpt must be in [0, 1] (got {})", tr.chaos_ckpt));
        }
        // Derived-parameter checks (C bounds again with the base U, the
        // heterogeneity-class knobs, τ/τ^e divisibility, theorem
        // prerequisites, physical sanity).
        for e in self.params().validate() {
            let msg = format!("params: {e}");
            if !errs.contains(&msg) {
                errs.push(msg);
            }
        }
        errs
    }
}

/// Load and validate a scenario file (the KV-text format of
/// `docs/SCENARIOS.md`).
pub fn load_file(path: &Path) -> Result<Scenario, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
    let sc = format::parse_scenario(&text)?;
    let errs = sc.validate();
    if !errs.is_empty() {
        return Err(format!("scenario `{}` invalid: {}", sc.name, errs.join("; ")));
    }
    Ok(sc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_base_columns() {
        let sc = Scenario::defaults("x", Task::Femnist);
        let p = sc.params();
        let want = SystemParams::femnist_small();
        assert_eq!(p.num_clients, want.num_clients);
        assert_eq!(p.num_channels, want.num_channels);
        assert_eq!(p.gamma, want.gamma);
        assert_eq!(p.t_max, want.t_max);
        assert_eq!(p.v, want.v);
        let sc = Scenario::defaults("y", Task::Cifar);
        let p = sc.params();
        let want = SystemParams::cifar_small();
        assert_eq!(p.gamma, want.gamma);
        assert_eq!(p.t_max, want.t_max);
        assert_eq!(p.v, want.v);
    }

    #[test]
    fn overrides_flow_into_params() {
        let mut sc = Scenario::defaults("x", Task::Femnist);
        sc.topology.clients = 40;
        sc.topology.channels = 12;
        sc.topology.aps = 4;
        sc.wireless.deep_fade_frac = 0.25;
        sc.wireless.deep_fade_db = 15.0;
        sc.compute.straggler_frac = 0.1;
        sc.compute.straggler_slowdown = 0.5;
        sc.train.v = Some(37.0);
        let p = sc.params();
        assert_eq!((p.num_clients, p.num_channels, p.num_aps), (40, 12, 4));
        assert_eq!((p.deep_fade_frac, p.deep_fade_db), (0.25, 15.0));
        assert_eq!((p.straggler_frac, p.straggler_slowdown), (0.1, 0.5));
        assert_eq!(p.v, 37.0);
        assert!(sc.validate().is_empty(), "{:?}", sc.validate());
    }

    #[test]
    fn validate_rejects_channel_misuse() {
        let mut sc = Scenario::defaults("x", Task::Femnist);
        sc.topology.channels = 0;
        assert!(sc.validate().iter().any(|e| e.contains("C = 0")), "{:?}", sc.validate());
        sc.topology.channels = sc.topology.clients + 5;
        assert!(sc.validate().iter().any(|e| e.contains("channels")), "{:?}", sc.validate());
    }

    #[test]
    fn validate_rejects_bad_dist_and_algorithms() {
        let mut sc = Scenario::defaults("x", Task::Femnist);
        sc.data.dist = SizeDistKind::Uniform;
        sc.data.uniform_lo = 500.0;
        sc.data.uniform_hi = 100.0;
        assert!(!sc.validate().is_empty());
        let mut sc = Scenario::defaults("x", Task::Femnist);
        sc.train.algorithms = vec!["nonsense".into()];
        assert!(sc.validate().iter().any(|e| e.contains("unknown algorithm")));
        let mut sc = Scenario::defaults("bad name", Task::Femnist);
        sc.name = "bad name".into();
        assert!(!sc.validate().is_empty());
    }

    #[test]
    fn validate_rejects_path_escaping_names() {
        // The name is a sweep trace-file stem; it must not traverse.
        for bad in ["../evil", "a/b", "a\\b", "..", ""] {
            let mut sc = Scenario::defaults("x", Task::Femnist);
            sc.name = bad.to_string();
            assert!(
                sc.validate().iter().any(|e| e.contains("file stem")),
                "`{bad}` accepted: {:?}",
                sc.validate()
            );
        }
        let sc = Scenario::defaults("ok-name_v1.2", Task::Femnist);
        assert!(sc.validate().is_empty(), "{:?}", sc.validate());
    }

    #[test]
    fn validate_rejects_negative_fade() {
        let mut sc = Scenario::defaults("x", Task::Femnist);
        sc.wireless.deep_fade_frac = 0.3;
        sc.wireless.deep_fade_db = -18.0;
        assert!(
            sc.validate().iter().any(|e| e.contains("deep_fade_db")),
            "{:?}",
            sc.validate()
        );
    }

    #[test]
    fn validate_rejects_bad_churn_knobs() {
        let mut sc = Scenario::defaults("x", Task::Femnist);
        sc.train.churn = true;
        sc.train.p_join = 1.5;
        assert!(sc.validate().iter().any(|e| e.contains("p_join")), "{:?}", sc.validate());
        sc.train.p_join = 0.25;
        sc.train.p_leave = -0.1;
        assert!(sc.validate().iter().any(|e| e.contains("p_leave")), "{:?}", sc.validate());
        sc.train.p_leave = f64::NAN;
        assert!(sc.validate().iter().any(|e| e.contains("p_leave")), "{:?}", sc.validate());
        sc.train.p_leave = 0.1;
        sc.train.over_select = -0.5;
        assert!(sc.validate().iter().any(|e| e.contains("over_select")), "{:?}", sc.validate());
        sc.train.over_select = 0.5;
        sc.train.staleness = true;
        assert!(sc.validate().is_empty(), "{:?}", sc.validate());
        // Boundary probabilities are legal (the all-depart regression
        // scenario uses p_leave = 1, p_join = 0).
        sc.train.p_leave = 1.0;
        sc.train.p_join = 0.0;
        assert!(sc.validate().is_empty(), "{:?}", sc.validate());
    }

    #[test]
    fn validate_rejects_bad_chaos_knobs() {
        let mut sc = Scenario::defaults("x", Task::Femnist);
        sc.train.chaos = true;
        sc.train.chaos_decode = 1.5;
        assert!(sc.validate().iter().any(|e| e.contains("chaos_decode")), "{:?}", sc.validate());
        sc.train.chaos_decode = 0.1;
        sc.train.chaos_straggle = -0.2;
        assert!(
            sc.validate().iter().any(|e| e.contains("chaos_straggle")),
            "{:?}",
            sc.validate()
        );
        sc.train.chaos_straggle = 0.05;
        sc.train.chaos_panic = f64::NAN;
        assert!(sc.validate().iter().any(|e| e.contains("chaos_panic")), "{:?}", sc.validate());
        sc.train.chaos_panic = 0.0;
        sc.train.chaos_ckpt = 2.0;
        assert!(sc.validate().iter().any(|e| e.contains("chaos_ckpt")), "{:?}", sc.validate());
        sc.train.chaos_ckpt = 0.25;
        // A retry budget of 0 is legal: one decode attempt, no retries.
        sc.train.chaos_retries = 0;
        assert!(sc.validate().is_empty(), "{:?}", sc.validate());
        // Boundary probabilities are legal (chaos-panic pins
        // chaos_panic = 1 to poison a sweep unit on purpose).
        sc.train.chaos_panic = 1.0;
        sc.train.chaos_decode = 1.0;
        assert!(sc.validate().is_empty(), "{:?}", sc.validate());
    }

    #[test]
    fn size_dist_maps_kind_to_knobs() {
        let mut sc = Scenario::defaults("x", Task::Femnist);
        assert_eq!(sc.size_dist(), SizeDist::Gaussian);
        sc.data.dist = SizeDistKind::Zipf;
        sc.data.zipf_exponent = 1.4;
        assert_eq!(sc.size_dist(), SizeDist::Zipf { exponent: 1.4 });
        sc.data.dist = SizeDistKind::Uniform;
        assert_eq!(
            sc.size_dist(),
            SizeDist::Uniform { lo: sc.data.uniform_lo, hi: sc.data.uniform_hi }
        );
    }
}
