//! Criterion-style micro-bench harness (criterion is unavailable offline).
//!
//! Each file in `rust/benches/` is a `harness = false` binary that builds a
//! [`BenchSet`], registers closures, and calls [`BenchSet::finish`], which
//! prints a table and appends JSON lines to `target/qccf-bench.jsonl` so
//! the perf pass in EXPERIMENTS.md §Perf can diff before/after.
//!
//! Protocol per benchmark: warm up for `warmup`, then run fixed-size
//! batches until `measure` elapses, recording per-iteration wall time;
//! report mean / p50 / p95 / min and iteration count.

use std::time::{Duration, Instant};

use crate::util::stats;

/// One benchmark's timing summary.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// `group/name` identifier.
    pub name: String,
    /// Iterations measured.
    pub iters: u64,
    /// Mean per-iteration wall time (ns).
    pub mean_ns: f64,
    /// Median per-iteration wall time (ns).
    pub p50_ns: f64,
    /// 95th-percentile per-iteration wall time (ns).
    pub p95_ns: f64,
    /// Fastest iteration (ns).
    pub min_ns: f64,
}

/// A named group of benchmarks sharing warmup/measure budgets.
pub struct BenchSet {
    group: String,
    warmup: Duration,
    measure: Duration,
    results: Vec<BenchResult>,
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

impl BenchSet {
    /// New group; budgets come from `QCCF_BENCH_*_MS` or defaults.
    pub fn new(group: &str) -> BenchSet {
        // Defaults keep `cargo bench` wall time sane on 1 core; override
        // with QCCF_BENCH_MEASURE_MS / QCCF_BENCH_WARMUP_MS.
        let ms = |var: &str, default: u64| {
            Duration::from_millis(
                std::env::var(var).ok().and_then(|v| v.parse().ok()).unwrap_or(default),
            )
        };
        BenchSet {
            group: group.to_string(),
            warmup: ms("QCCF_BENCH_WARMUP_MS", 200),
            measure: ms("QCCF_BENCH_MEASURE_MS", 1000),
            results: Vec::new(),
        }
    }

    /// Benchmark `f`; its return value is black-boxed to keep the work alive.
    pub fn bench<R, F: FnMut() -> R>(&mut self, name: &str, mut f: F) {
        // Warmup.
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure.
        let mut samples_ns: Vec<f64> = Vec::new();
        let t0 = Instant::now();
        while t0.elapsed() < self.measure && samples_ns.len() < 2_000_000 {
            let it = Instant::now();
            std::hint::black_box(f());
            samples_ns.push(it.elapsed().as_nanos() as f64);
        }
        let res = BenchResult {
            name: format!("{}/{}", self.group, name),
            iters: samples_ns.len() as u64,
            mean_ns: stats::mean(&samples_ns),
            p50_ns: stats::percentile(&samples_ns, 50.0),
            p95_ns: stats::percentile(&samples_ns, 95.0),
            min_ns: samples_ns.iter().cloned().fold(f64::INFINITY, f64::min),
        };
        println!(
            "{:<48} {:>10} iters   mean {:>12}   p50 {:>12}   p95 {:>12}",
            res.name,
            res.iters,
            fmt_ns(res.mean_ns),
            fmt_ns(res.p50_ns),
            fmt_ns(res.p95_ns),
        );
        self.results.push(res);
    }

    /// Print a summary and append JSONL records for the perf log.
    pub fn finish(self) {
        let path = std::path::Path::new("target").join("qccf-bench.jsonl");
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let mut lines = String::new();
        for r in &self.results {
            lines.push_str(&format!(
                "{{\"name\":\"{}\",\"iters\":{},\"mean_ns\":{:.1},\"p50_ns\":{:.1},\"p95_ns\":{:.1},\"min_ns\":{:.1}}}\n",
                r.name, r.iters, r.mean_ns, r.p50_ns, r.p95_ns, r.min_ns
            ));
        }
        use std::io::Write;
        if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&path) {
            f.write_all(lines.as_bytes()).ok();
        }
        println!("== {} done ({} benchmarks) ==", self.group, self.results.len());
    }
}

/// One row of the wire-transport perf baseline (`BENCH_wire.json`).
#[derive(Clone, Debug)]
pub struct WireBenchRow {
    /// `wire/<op>_z<Z>_q<q>` identifier.
    pub name: String,
    /// Iterations measured.
    pub iters: u64,
    /// Mean per-iteration wall time (ns).
    pub mean_ns: f64,
    /// Mean wall time per model dimension (ns/elem) — the
    /// size-independent number later PRs regress against.
    pub ns_per_elem: f64,
}

/// Run the byte-transport microbench: `quant::wire::encode` and the
/// fused decode-fold (`quant::wire::fold_into`) over a Z-dimensional
/// model at each level in `qs`. Pure Rust — no artifacts needed — so
/// `verify.sh` can run it as a tier-1 smoke (see the `bench-wire` CLI
/// subcommand, which writes the rows to `BENCH_wire.json`).
pub fn run_wire_bench(z: usize, qs: &[u32]) -> Vec<WireBenchRow> {
    let mut set = BenchSet::new("wire");
    let mut rng = crate::util::rng::Rng::seed_from(0xB17E);
    let theta: Vec<f32> = (0..z).map(|_| rng.gaussian(0.0, 0.5) as f32).collect();
    let mut noise = vec![0.0f32; z];
    rng.fill_uniform_f32(&mut noise);
    for &q in qs {
        let (idx, signs, tmax) = crate::quant::knot_indices(&theta, &noise, q);
        set.bench(&format!("encode_z{z}_q{q}"), || crate::quant::encode(tmax, &signs, &idx, q));
        let bytes = crate::quant::encode(tmax, &signs, &idx, q);
        let mut acc = vec![0.0f32; z];
        set.bench(&format!("decode_fold_z{z}_q{q}"), || {
            crate::quant::wire::fold_into(&mut acc, 0.25, &bytes, q).unwrap()
        });
    }
    set.results
        .iter()
        .map(|r| WireBenchRow {
            name: r.name.clone(),
            iters: r.iters,
            mean_ns: r.mean_ns,
            ns_per_elem: r.mean_ns / z.max(1) as f64,
        })
        .collect()
}

/// Write wire-bench rows as a single JSON document (`BENCH_wire.json`):
/// `{"z": Z, "benches": [{name, iters, mean_ns, ns_per_elem}, ...]}` —
/// the perf baseline subsequent PRs diff against.
pub fn write_wire_bench_json(
    path: &std::path::Path,
    z: usize,
    rows: &[WireBenchRow],
) -> std::io::Result<()> {
    use crate::util::json::{self, Json};
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let benches = Json::Arr(
        rows.iter()
            .map(|r| {
                json::obj(vec![
                    ("name", json::s(&r.name)),
                    ("iters", json::num(r.iters as f64)),
                    ("mean_ns", json::num(r.mean_ns)),
                    ("ns_per_elem", json::num(r.ns_per_elem)),
                ])
            })
            .collect(),
    );
    let doc = json::obj(vec![("z", json::num(z as f64)), ("benches", benches)]);
    std::fs::write(path, format!("{}\n", doc.to_string_compact()))
}

/// One row of the decision-stage perf baseline (`BENCH_sched.json`).
#[derive(Clone, Debug)]
pub struct SchedBenchRow {
    /// `sched/eval_{cached|uncached}_u<U>` identifier.
    pub name: String,
    /// U — clients in the synthetic round.
    pub u: usize,
    /// C — channels (U/2).
    pub c: usize,
    /// Whether this row ran the cached path (`sched::EvalCtx` + solve
    /// memo + reusable scratch) or the uncached reference
    /// (`sched::evaluate_allocation`).
    pub cached: bool,
    /// Iterations measured.
    pub iters: u64,
    /// Mean wall time per J0 evaluation (ns).
    pub mean_ns: f64,
    /// J0 evaluations per second (1e9 / mean_ns).
    pub evals_per_sec: f64,
}

/// Run the decision-stage microbench: J0 evaluation throughput at each
/// `U` in `us` with C = U/2, cached vs uncached. Pure Rust — no
/// artifacts — so `verify.sh` runs it as a tier-1 smoke (see the
/// `bench-sched` CLI subcommand, which writes `BENCH_sched.json`).
///
/// The workload cycles a fixed pool of `pool` chromosomes shaped like a
/// *converging* GA population — perturbations of the greedy seed — so
/// participant sets (hence solve-memo keys) recur across evaluations
/// exactly as Algorithm 1's late generations do. The uncached row is
/// the honest reference: `evaluate_allocation` per candidate, as the
/// fitness loop ran before the EvalCtx subsystem.
pub fn run_sched_bench(us: &[usize], pool: usize) -> Vec<SchedBenchRow> {
    use crate::ga::Chromosome;
    use crate::lyapunov::Queues;
    use crate::sched::{self, RoundInputs};
    use crate::solver::Case5Mode;
    use crate::wireless::ChannelModel;

    let mut set = BenchSet::new("sched");
    let mut meta: Vec<(usize, usize, bool)> = Vec::new(); // (u, c, cached) per row
    for &u in us {
        let c = (u / 2).max(1);
        let mut params = crate::config::SystemParams::femnist_small();
        params.num_clients = u;
        params.num_channels = c;
        let mut rng = crate::util::rng::Rng::seed_from(0x5C4E_D000 + u as u64);
        let model = ChannelModel::new(&params, &mut rng);
        let channels = model.draw(&mut rng);
        let sizes: Vec<f64> = (0..u).map(|_| rng.gaussian(1200.0, 300.0).max(64.0)).collect();
        let total: f64 = sizes.iter().sum();
        let w_full: Vec<f64> = sizes.iter().map(|d| d / total).collect();
        let g2: Vec<f64> = (0..u).map(|_| rng.range(0.05, 16.0)).collect();
        let sigma2: Vec<f64> = (0..u).map(|_| rng.range(0.05, 2.0)).collect();
        let theta_max = vec![0.4; u];
        let q_prev = vec![6.0; u];
        let mut queues = Queues::new();
        queues.lambda1 = 1e3;
        queues.lambda2 = 10.0;
        let inp = RoundInputs {
            params: &params,
            round: 5,
            channels: &channels,
            sizes: &sizes,
            w_full: &w_full,
            g2: &g2,
            sigma2: &sigma2,
            theta_max: &theta_max,
            q_prev: &q_prev,
            queues: &queues,
        };
        let greedy = sched::greedy_allocation(&inp);
        let chroms: Vec<Chromosome> = (0..pool.max(1))
            .map(|_| {
                let mut chrom = greedy.clone();
                for _ in 0..(c / 8).max(1) {
                    let a = rng.below(c);
                    let b = rng.below(c);
                    chrom.alloc.swap(a, b);
                    if rng.chance(0.5) {
                        chrom.alloc[a] = Some(rng.below(u));
                    }
                }
                chrom.repair(u);
                chrom
            })
            .collect();

        let mut k = 0usize;
        set.bench(&format!("eval_uncached_u{u}"), || {
            k = (k + 1) % chroms.len();
            sched::evaluate_allocation(&inp, &chroms[k], Case5Mode::Taylor).0
        });
        meta.push((u, c, false));

        let ctx = sched::EvalCtx::new(&inp, Case5Mode::Taylor);
        let mut scratch = ctx.make_scratch();
        let mut k = 0usize;
        set.bench(&format!("eval_cached_u{u}"), || {
            k = (k + 1) % chroms.len();
            ctx.evaluate_j0(&chroms[k], &mut scratch)
        });
        meta.push((u, c, true));
    }
    set.results
        .iter()
        .zip(meta)
        .map(|(r, (u, c, cached))| SchedBenchRow {
            name: r.name.clone(),
            u,
            c,
            cached,
            iters: r.iters,
            mean_ns: r.mean_ns,
            evals_per_sec: if r.mean_ns > 0.0 { 1e9 / r.mean_ns } else { 0.0 },
        })
        .collect()
}

/// Write sched-bench rows as a single JSON document
/// (`BENCH_sched.json`): the per-row numbers plus per-U
/// cached-vs-uncached speedups — the decision-stage perf baseline
/// subsequent PRs diff against (and the number behind the "cached ≥ 3×
/// at U = 1000" acceptance line).
pub fn write_sched_bench_json(
    path: &std::path::Path,
    pool: usize,
    rows: &[SchedBenchRow],
) -> std::io::Result<()> {
    use crate::util::json::{self, Json};
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let benches = Json::Arr(
        rows.iter()
            .map(|r| {
                json::obj(vec![
                    ("name", json::s(&r.name)),
                    ("u", json::num(r.u as f64)),
                    ("c", json::num(r.c as f64)),
                    ("cached", Json::Bool(r.cached)),
                    ("iters", json::num(r.iters as f64)),
                    ("mean_ns", json::num(r.mean_ns)),
                    ("evals_per_sec", json::num(r.evals_per_sec)),
                ])
            })
            .collect(),
    );
    let mut speedups = Vec::new();
    for r in rows.iter().filter(|r| r.cached) {
        if let Some(base) = rows.iter().find(|b| !b.cached && b.u == r.u) {
            if r.mean_ns > 0.0 {
                speedups.push(json::obj(vec![
                    ("u", json::num(r.u as f64)),
                    ("speedup", json::num(base.mean_ns / r.mean_ns)),
                ]));
            }
        }
    }
    let doc = json::obj(vec![
        ("pool", json::num(pool as f64)),
        ("benches", benches),
        ("speedups", Json::Arr(speedups)),
    ]);
    std::fs::write(path, format!("{}\n", doc.to_string_compact()))
}

/// One row of the snapshot-codec perf baseline (`BENCH_ckpt.json`).
#[derive(Clone, Debug)]
pub struct CkptBenchRow {
    /// `ckpt/<op>_z<Z>_u<U>` identifier.
    pub name: String,
    /// U — clients in the synthetic snapshot.
    pub u: usize,
    /// Encoded snapshot size in bytes.
    pub bytes: usize,
    /// Iterations measured.
    pub iters: u64,
    /// Mean per-iteration wall time (ns).
    pub mean_ns: f64,
    /// Snapshot megabytes processed per second — the size-independent
    /// number later PRs regress against.
    pub mb_per_sec: f64,
}

/// A synthetic mid-horizon snapshot shaped like a real run: Z model
/// dims, U clients (each with estimator state and an RNG stream), a
/// 40-round trace with per-client level vectors, and the rendered
/// `paper-femnist` scenario as identity text.
fn synthetic_snapshot(z: usize, u: usize) -> crate::ckpt::Snapshot {
    use crate::ckpt::{ClientCkpt, RunState, Snapshot};
    use crate::metrics::{RoundRecord, Trace};
    use crate::util::rng::Rng;

    let mut rng = Rng::seed_from(0xC4B7_5EED ^ (z as u64) ^ ((u as u64) << 20));
    let mut trace = Trace::new("qccf");
    let rounds = 40usize;
    let mut cum = 0.0;
    for n in 1..=rounds {
        let energy = rng.range(0.01, 0.2);
        cum += energy;
        trace.push(RoundRecord {
            round: n,
            scheduled: u / 2,
            aggregated: u / 2,
            wire_bytes: (u / 2) * (z / 2),
            energy,
            cum_energy: cum,
            train_loss: rng.range(0.1, 2.0),
            test_loss: (n % 2 == 0).then(|| rng.range(0.1, 2.0)),
            test_acc: (n % 2 == 0).then(|| rng.uniform()),
            mean_q: rng.range(1.0, 12.0),
            q_per_client: (0..u)
                .map(|i| (i % 3 != 2).then_some(1 + (i % 12) as u32))
                .collect(),
            lambda1: rng.range(0.0, 100.0),
            lambda2: rng.range(0.0, 2.0),
            max_latency: rng.range(0.001, 0.02),
            decide_seconds: 0.1,
            compute_seconds: 0.5,
        });
    }
    let mk_rng = |k: u64| Rng::seed_from(k).state();
    Snapshot {
        scenario_text: crate::scenario::render(&crate::scenario::registry::paper_femnist()),
        algorithm: "qccf".into(),
        seed: 1,
        state: RunState {
            round: rounds as u64,
            eps1: 30.0,
            eps2: 0.001,
            theta: (0..z).map(|_| rng.gaussian(0.0, 0.5) as f32).collect(),
            lambda1: 17.0,
            lambda2: 0.25,
            queue_history: (0..=rounds)
                .map(|_| (rng.range(0.0, 100.0), rng.range(0.0, 2.0)))
                .collect(),
            clients: (0..u)
                .map(|i| ClientCkpt {
                    g: rng.range(0.1, 4.0),
                    sigma: rng.range(0.05, 1.0),
                    ema: 0.5,
                    observed: true,
                    theta_max: rng.range(0.1, 0.8),
                    q_prev: rng.range(1.0, 12.0),
                    rng: mk_rng(1000 + i as u64),
                })
                .collect(),
            server_rng: mk_rng(7),
            sched_rng: Some(mk_rng(9)),
            runtime_nanos: [1, 2, 3, 4],
        },
        trace,
    }
}

/// Run the snapshot-codec microbench: `Snapshot::encode` and
/// `Snapshot::decode` over a synthetic mid-horizon snapshot at Z model
/// dims × each U in `us`. Pure Rust — no artifacts — so `verify.sh`
/// runs it as a tier-1 smoke (see the `bench-ckpt` CLI subcommand,
/// which writes `BENCH_ckpt.json`): the checkpoint-path perf baseline
/// later PRs regress against.
pub fn run_ckpt_bench(z: usize, us: &[usize]) -> Vec<CkptBenchRow> {
    let mut set = BenchSet::new("ckpt");
    let mut meta: Vec<(usize, usize)> = Vec::new(); // (u, bytes) per row
    for &u in us {
        let snap = synthetic_snapshot(z, u);
        let bytes = snap.encode();
        set.bench(&format!("encode_z{z}_u{u}"), || snap.encode());
        meta.push((u, bytes.len()));
        set.bench(&format!("decode_z{z}_u{u}"), || {
            crate::ckpt::Snapshot::decode(&bytes).expect("freshly encoded snapshot")
        });
        meta.push((u, bytes.len()));
    }
    set.results
        .iter()
        .zip(meta)
        .map(|(r, (u, bytes))| CkptBenchRow {
            name: r.name.clone(),
            u,
            bytes,
            iters: r.iters,
            mean_ns: r.mean_ns,
            mb_per_sec: if r.mean_ns > 0.0 {
                bytes as f64 * 1e3 / r.mean_ns
            } else {
                0.0
            },
        })
        .collect()
}

/// Write ckpt-bench rows as a single JSON document (`BENCH_ckpt.json`):
/// `{"z": Z, "benches": [{name, u, bytes, iters, mean_ns, mb_per_sec},
/// ...]}` — the snapshot-codec perf baseline subsequent PRs diff
/// against.
pub fn write_ckpt_bench_json(
    path: &std::path::Path,
    z: usize,
    rows: &[CkptBenchRow],
) -> std::io::Result<()> {
    use crate::util::json::{self, Json};
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let benches = Json::Arr(
        rows.iter()
            .map(|r| {
                json::obj(vec![
                    ("name", json::s(&r.name)),
                    ("u", json::num(r.u as f64)),
                    ("bytes", json::num(r.bytes as f64)),
                    ("iters", json::num(r.iters as f64)),
                    ("mean_ns", json::num(r.mean_ns)),
                    ("mb_per_sec", json::num(r.mb_per_sec)),
                ])
            })
            .collect(),
    );
    let doc = json::obj(vec![("z", json::num(z as f64)), ("benches", benches)]);
    std::fs::write(path, format!("{}\n", doc.to_string_compact()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("QCCF_BENCH_WARMUP_MS", "1");
        std::env::set_var("QCCF_BENCH_MEASURE_MS", "5");
        let mut set = BenchSet::new("test");
        let mut acc = 0u64;
        set.bench("noop", || {
            acc = acc.wrapping_add(1);
            acc
        });
        assert_eq!(set.results.len(), 1);
        assert!(set.results[0].iters > 0);
        assert!(set.results[0].mean_ns >= 0.0);
    }

    #[test]
    fn wire_bench_rows_and_json() {
        std::env::set_var("QCCF_BENCH_WARMUP_MS", "1");
        std::env::set_var("QCCF_BENCH_MEASURE_MS", "5");
        let rows = run_wire_bench(512, &[4, 8]);
        assert_eq!(rows.len(), 4, "encode + decode-fold per q");
        assert!(rows.iter().all(|r| r.iters > 0 && r.ns_per_elem >= 0.0));
        assert!(rows.iter().any(|r| r.name.contains("encode_z512_q4")));
        assert!(rows.iter().any(|r| r.name.contains("decode_fold_z512_q8")));
        let dir = std::env::temp_dir().join("qccf_wire_bench_test");
        let path = dir.join("BENCH_wire.json");
        write_wire_bench_json(&path, 512, &rows).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::util::json::parse(text.trim()).unwrap();
        assert_eq!(doc.get("z").and_then(|x| x.as_usize()), Some(512));
        assert_eq!(doc.get("benches").and_then(|x| x.as_arr()).map(|a| a.len()), Some(4));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sched_bench_rows_and_json() {
        std::env::set_var("QCCF_BENCH_WARMUP_MS", "1");
        std::env::set_var("QCCF_BENCH_MEASURE_MS", "5");
        let rows = run_sched_bench(&[8, 12], 4);
        assert_eq!(rows.len(), 4, "uncached + cached per U");
        assert!(rows.iter().all(|r| r.iters > 0 && r.mean_ns > 0.0 && r.evals_per_sec > 0.0));
        assert!(rows.iter().any(|r| r.name.contains("eval_uncached_u8") && !r.cached));
        assert!(rows.iter().any(|r| r.name.contains("eval_cached_u12") && r.cached));
        assert!(rows.iter().all(|r| r.c == r.u / 2));
        let dir = std::env::temp_dir().join("qccf_sched_bench_test");
        let path = dir.join("BENCH_sched.json");
        write_sched_bench_json(&path, 4, &rows).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::util::json::parse(text.trim()).unwrap();
        assert_eq!(doc.get("pool").and_then(|x| x.as_usize()), Some(4));
        assert_eq!(doc.get("benches").and_then(|x| x.as_arr()).map(|a| a.len()), Some(4));
        let speedups = doc.get("speedups").and_then(|x| x.as_arr()).unwrap();
        assert_eq!(speedups.len(), 2);
        assert!(speedups.iter().all(|s| s.get("speedup").and_then(|x| x.as_f64()).unwrap() > 0.0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ckpt_bench_rows_and_json() {
        std::env::set_var("QCCF_BENCH_WARMUP_MS", "1");
        std::env::set_var("QCCF_BENCH_MEASURE_MS", "5");
        let rows = run_ckpt_bench(256, &[10, 25]);
        assert_eq!(rows.len(), 4, "encode + decode per U");
        assert!(rows.iter().all(|r| r.iters > 0 && r.bytes > 0 && r.mb_per_sec > 0.0));
        assert!(rows.iter().any(|r| r.name.contains("encode_z256_u10")));
        assert!(rows.iter().any(|r| r.name.contains("decode_z256_u25")));
        // More clients = bigger snapshot.
        let b10 = rows.iter().find(|r| r.u == 10).unwrap().bytes;
        let b25 = rows.iter().find(|r| r.u == 25).unwrap().bytes;
        assert!(b25 > b10, "b25={b25} b10={b10}");
        let dir = std::env::temp_dir().join("qccf_ckpt_bench_test");
        let path = dir.join("BENCH_ckpt.json");
        write_ckpt_bench_json(&path, 256, &rows).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::util::json::parse(text.trim()).unwrap();
        assert_eq!(doc.get("z").and_then(|x| x.as_usize()), Some(256));
        assert_eq!(doc.get("benches").and_then(|x| x.as_arr()).map(|a| a.len()), Some(4));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }
}
