//! Criterion-style micro-bench harness (criterion is unavailable offline).
//!
//! Each file in `rust/benches/` is a `harness = false` binary that builds a
//! [`BenchSet`], registers closures, and calls [`BenchSet::finish`], which
//! prints a table and appends JSON lines to `target/qccf-bench.jsonl` so
//! the perf pass in EXPERIMENTS.md §Perf can diff before/after.
//!
//! Protocol per benchmark: warm up for `warmup`, then run fixed-size
//! batches until `measure` elapses, recording per-iteration wall time;
//! report mean / p50 / p95 / min and iteration count.

use std::time::{Duration, Instant};

use crate::util::stats;

/// One benchmark's timing summary.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// `group/name` identifier.
    pub name: String,
    /// Iterations measured.
    pub iters: u64,
    /// Mean per-iteration wall time (ns).
    pub mean_ns: f64,
    /// Median per-iteration wall time (ns).
    pub p50_ns: f64,
    /// 95th-percentile per-iteration wall time (ns).
    pub p95_ns: f64,
    /// Fastest iteration (ns).
    pub min_ns: f64,
}

/// A named group of benchmarks sharing warmup/measure budgets.
pub struct BenchSet {
    group: String,
    warmup: Duration,
    measure: Duration,
    results: Vec<BenchResult>,
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

impl BenchSet {
    /// New group; budgets come from `QCCF_BENCH_*_MS` or defaults.
    pub fn new(group: &str) -> BenchSet {
        // Defaults keep `cargo bench` wall time sane on 1 core; override
        // with QCCF_BENCH_MEASURE_MS / QCCF_BENCH_WARMUP_MS.
        let ms = |var: &str, default: u64| {
            Duration::from_millis(
                std::env::var(var).ok().and_then(|v| v.parse().ok()).unwrap_or(default),
            )
        };
        BenchSet {
            group: group.to_string(),
            warmup: ms("QCCF_BENCH_WARMUP_MS", 200),
            measure: ms("QCCF_BENCH_MEASURE_MS", 1000),
            results: Vec::new(),
        }
    }

    /// Benchmark `f`; its return value is black-boxed to keep the work alive.
    pub fn bench<R, F: FnMut() -> R>(&mut self, name: &str, mut f: F) {
        // Warmup.
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure.
        let mut samples_ns: Vec<f64> = Vec::new();
        let t0 = Instant::now();
        while t0.elapsed() < self.measure && samples_ns.len() < 2_000_000 {
            let it = Instant::now();
            std::hint::black_box(f());
            samples_ns.push(it.elapsed().as_nanos() as f64);
        }
        let res = BenchResult {
            name: format!("{}/{}", self.group, name),
            iters: samples_ns.len() as u64,
            mean_ns: stats::mean(&samples_ns),
            p50_ns: stats::percentile(&samples_ns, 50.0),
            p95_ns: stats::percentile(&samples_ns, 95.0),
            min_ns: samples_ns.iter().cloned().fold(f64::INFINITY, f64::min),
        };
        println!(
            "{:<48} {:>10} iters   mean {:>12}   p50 {:>12}   p95 {:>12}",
            res.name,
            res.iters,
            fmt_ns(res.mean_ns),
            fmt_ns(res.p50_ns),
            fmt_ns(res.p95_ns),
        );
        self.results.push(res);
    }

    /// Print a summary and append JSONL records for the perf log.
    pub fn finish(self) {
        let path = std::path::Path::new("target").join("qccf-bench.jsonl");
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let mut lines = String::new();
        for r in &self.results {
            lines.push_str(&format!(
                "{{\"name\":\"{}\",\"iters\":{},\"mean_ns\":{:.1},\"p50_ns\":{:.1},\"p95_ns\":{:.1},\"min_ns\":{:.1}}}\n",
                r.name, r.iters, r.mean_ns, r.p50_ns, r.p95_ns, r.min_ns
            ));
        }
        use std::io::Write;
        // detlint: allow(R5) — append-only local perf log under target/;
        // never read back by the pipeline, torn tails are harmless.
        if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&path) {
            f.write_all(lines.as_bytes()).ok();
        }
        println!("== {} done ({} benchmarks) ==", self.group, self.results.len());
    }
}

/// One row of the wire-transport perf baseline (`BENCH_wire.json`).
#[derive(Clone, Debug)]
pub struct WireBenchRow {
    /// `wire/<op>_z<Z>_q<q>` identifier.
    pub name: String,
    /// Iterations measured.
    pub iters: u64,
    /// Mean per-iteration wall time (ns).
    pub mean_ns: f64,
    /// Mean wall time per model dimension (ns/elem) — the
    /// size-independent number later PRs regress against.
    pub ns_per_elem: f64,
    /// Realized bytes across all transmission attempts for the
    /// retransmission rows (attempts × encoded payload — the wire cost
    /// `fl::exec::fault_payload_bytes` charges); 0 for single-attempt
    /// rows.
    pub retry_bytes: usize,
}

/// Transmission attempts the `retry_fold` rows model: the chaos-default
/// retry budget of 2 exhausted after the first failure (see
/// [`crate::fl::faults::FaultCfg::retries`]).
const RETRY_ATTEMPTS: usize = 3;

/// Run the byte-transport microbench: `quant::wire::encode`, the fused
/// decode-fold (`quant::wire::fold_into`), and the decode-failure /
/// retransmission path (each failed attempt pays a full decode pass
/// before the final one folds — [`RETRY_ATTEMPTS`] passes total) over a
/// Z-dimensional model at each level in `qs`. Pure Rust — no artifacts
/// needed — so `verify.sh` can run it as a tier-1 smoke (see the
/// `bench-wire` CLI subcommand, which writes the rows to
/// `BENCH_wire.json`).
pub fn run_wire_bench(z: usize, qs: &[u32]) -> Vec<WireBenchRow> {
    let mut set = BenchSet::new("wire");
    let mut retry_bytes: Vec<usize> = Vec::new(); // per row, 0 = single attempt
    let mut rng = crate::util::rng::Rng::seed_from(0xB17E);
    let theta: Vec<f32> = (0..z).map(|_| rng.gaussian(0.0, 0.5) as f32).collect();
    let mut noise = vec![0.0f32; z];
    rng.fill_uniform_f32(&mut noise);
    for &q in qs {
        let (idx, signs, tmax) = crate::quant::knot_indices(&theta, &noise, q);
        set.bench(&format!("encode_z{z}_q{q}"), || crate::quant::encode(tmax, &signs, &idx, q));
        retry_bytes.push(0);
        let bytes = crate::quant::encode(tmax, &signs, &idx, q);
        let mut acc = vec![0.0f32; z];
        set.bench(&format!("decode_fold_z{z}_q{q}"), || {
            crate::quant::wire::fold_into(&mut acc, 0.25, &bytes, q).unwrap()
        });
        retry_bytes.push(0);
        let mut racc = vec![0.0f32; z];
        set.bench(&format!("retry_fold_z{z}_q{q}"), || {
            for _ in 0..RETRY_ATTEMPTS {
                crate::quant::wire::fold_into(&mut racc, 0.25, &bytes, q).unwrap();
            }
        });
        retry_bytes.push(RETRY_ATTEMPTS * bytes.len());
    }
    set.results
        .iter()
        .zip(retry_bytes)
        .map(|(r, retry_bytes)| WireBenchRow {
            name: r.name.clone(),
            iters: r.iters,
            mean_ns: r.mean_ns,
            ns_per_elem: r.mean_ns / z.max(1) as f64,
            retry_bytes,
        })
        .collect()
}

/// Write wire-bench rows as a single JSON document (`BENCH_wire.json`):
/// `{"z": Z, "benches": [{name, iters, mean_ns, ns_per_elem,
/// retry_bytes}, ...]}` — the perf baseline subsequent PRs diff
/// against.
pub fn write_wire_bench_json(
    path: &std::path::Path,
    z: usize,
    rows: &[WireBenchRow],
) -> std::io::Result<()> {
    use crate::util::json::{self, Json};
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let benches = Json::Arr(
        rows.iter()
            .map(|r| {
                json::obj(vec![
                    ("name", json::s(&r.name)),
                    ("iters", json::num(r.iters as f64)),
                    ("mean_ns", json::num(r.mean_ns)),
                    ("ns_per_elem", json::num(r.ns_per_elem)),
                    ("retry_bytes", json::num(r.retry_bytes as f64)),
                ])
            })
            .collect(),
    );
    let doc = json::obj(vec![("z", json::num(z as f64)), ("benches", benches)]);
    crate::util::fsio::write_atomic(path, format!("{}\n", doc.to_string_compact()).as_bytes())
}

/// One row of the decision-stage perf baseline (`BENCH_sched.json`).
#[derive(Clone, Debug)]
pub struct SchedBenchRow {
    /// `sched/eval_{cached|uncached}_u<U>` identifier.
    pub name: String,
    /// U — clients in the synthetic round.
    pub u: usize,
    /// C — channels (U/2).
    pub c: usize,
    /// Whether this row ran the cached path (`sched::EvalCtx` + solve
    /// memo + reusable scratch) or the uncached reference
    /// (`sched::evaluate_allocation`).
    pub cached: bool,
    /// Iterations measured.
    pub iters: u64,
    /// Mean wall time per J0 evaluation (ns).
    pub mean_ns: f64,
    /// J0 evaluations per second (1e9 / mean_ns).
    pub evals_per_sec: f64,
}

/// Run the decision-stage microbench: J0 evaluation throughput at each
/// `U` in `us` with C = U/2, cached vs uncached. Pure Rust — no
/// artifacts — so `verify.sh` runs it as a tier-1 smoke (see the
/// `bench-sched` CLI subcommand, which writes `BENCH_sched.json`).
///
/// The workload cycles a fixed pool of `pool` chromosomes shaped like a
/// *converging* GA population — perturbations of the greedy seed — so
/// participant sets (hence solve-memo keys) recur across evaluations
/// exactly as Algorithm 1's late generations do. The uncached row is
/// the honest reference: `evaluate_allocation` per candidate, as the
/// fitness loop ran before the EvalCtx subsystem.
pub fn run_sched_bench(us: &[usize], pool: usize) -> Vec<SchedBenchRow> {
    use crate::ga::Chromosome;
    use crate::lyapunov::Queues;
    use crate::sched::{self, RoundInputs};
    use crate::solver::Case5Mode;
    use crate::wireless::ChannelModel;

    let mut set = BenchSet::new("sched");
    let mut meta: Vec<(usize, usize, bool)> = Vec::new(); // (u, c, cached) per row
    for &u in us {
        let c = (u / 2).max(1);
        let mut params = crate::config::SystemParams::femnist_small();
        params.num_clients = u;
        params.num_channels = c;
        let mut rng = crate::util::rng::Rng::seed_from(0x5C4E_D000 + u as u64);
        let model = ChannelModel::new(&params, &mut rng);
        let channels = model.draw(&mut rng);
        let sizes: Vec<f64> = (0..u).map(|_| rng.gaussian(1200.0, 300.0).max(64.0)).collect();
        let total: f64 = sizes.iter().sum();
        let w_full: Vec<f64> = sizes.iter().map(|d| d / total).collect();
        let g2: Vec<f64> = (0..u).map(|_| rng.range(0.05, 16.0)).collect();
        let sigma2: Vec<f64> = (0..u).map(|_| rng.range(0.05, 2.0)).collect();
        let theta_max = vec![0.4; u];
        let q_prev = vec![6.0; u];
        let mut queues = Queues::new();
        queues.lambda1 = 1e3;
        queues.lambda2 = 10.0;
        let inp = RoundInputs {
            params: &params,
            round: 5,
            channels: &channels,
            sizes: &sizes,
            w_full: &w_full,
            g2: &g2,
            sigma2: &sigma2,
            theta_max: &theta_max,
            q_prev: &q_prev,
            queues: &queues,
            avail: None,
        };
        let greedy = sched::greedy_allocation(&inp);
        let chroms: Vec<Chromosome> = (0..pool.max(1))
            .map(|_| {
                let mut chrom = greedy.clone();
                for _ in 0..(c / 8).max(1) {
                    let a = rng.below(c);
                    let b = rng.below(c);
                    chrom.alloc.swap(a, b);
                    if rng.chance(0.5) {
                        chrom.alloc[a] = Some(rng.below(u));
                    }
                }
                chrom.repair(u);
                chrom
            })
            .collect();

        let mut k = 0usize;
        set.bench(&format!("eval_uncached_u{u}"), || {
            k = (k + 1) % chroms.len();
            sched::evaluate_allocation(&inp, &chroms[k], Case5Mode::Taylor).0
        });
        meta.push((u, c, false));

        let ctx = sched::EvalCtx::new(&inp, Case5Mode::Taylor);
        let mut scratch = ctx.make_scratch();
        let mut k = 0usize;
        set.bench(&format!("eval_cached_u{u}"), || {
            k = (k + 1) % chroms.len();
            ctx.evaluate_j0(&chroms[k], &mut scratch)
        });
        meta.push((u, c, true));

        // Masked-availability row (churn's decide-time shape): the same
        // pool evaluated with 20% of clients offline, so bench-diff can
        // see a regression in the masked candidate-set path.
        let mask: Vec<bool> = (0..u).map(|i| i % 5 != 0).collect();
        let masked = RoundInputs {
            params: &params,
            round: 5,
            channels: &channels,
            sizes: &sizes,
            w_full: &w_full,
            g2: &g2,
            sigma2: &sigma2,
            theta_max: &theta_max,
            q_prev: &q_prev,
            queues: &queues,
            avail: Some(&mask),
        };
        let mctx = sched::EvalCtx::new(&masked, Case5Mode::Taylor);
        let mut mscratch = mctx.make_scratch();
        let mut k = 0usize;
        set.bench(&format!("eval_masked_u{u}"), || {
            k = (k + 1) % chroms.len();
            mctx.evaluate_j0(&chroms[k], &mut mscratch)
        });
        meta.push((u, c, true));
    }
    set.results
        .iter()
        .zip(meta)
        .map(|(r, (u, c, cached))| SchedBenchRow {
            name: r.name.clone(),
            u,
            c,
            cached,
            iters: r.iters,
            mean_ns: r.mean_ns,
            evals_per_sec: if r.mean_ns > 0.0 { 1e9 / r.mean_ns } else { 0.0 },
        })
        .collect()
}

/// One row of the classed-vs-exact decision baseline (the `classed`
/// array of `BENCH_sched.json`): class-level J0 throughput against the
/// production cached evaluator, plus the approximation gap of one full
/// classed decide against one full exact decide on the same round.
#[derive(Clone, Debug)]
pub struct ClassedSchedRow {
    /// U — clients in the synthetic round.
    pub u: usize,
    /// C — channels (U/2 capped at 64, the stress-scenario shape).
    pub c: usize,
    /// K — equivalence classes the default binning produced.
    pub classes: usize,
    /// P — channel pools (min(K, C)).
    pub pools: usize,
    /// Exact-path throughput: cached `EvalCtx` J0 evaluations per
    /// second (the denominator of the ≥ 10× acceptance line).
    pub exact_evals_per_sec: f64,
    /// Classed-path throughput: `ClassEvalCtx` J0 evaluations per
    /// second.
    pub classed_evals_per_sec: f64,
    /// `classed_evals_per_sec / exact_evals_per_sec`.
    pub speedup: f64,
    /// J0 of a full exact GA decide on this round.
    pub j0_exact: f64,
    /// J0 of a full classed GA decide (same scheduler seed) — exact
    /// for the allocation it chose (see `sched::classes`).
    pub j0_classed: f64,
    /// Relative approximation gap `(j0_classed − j0_exact) /
    /// |j0_exact|`; negative = the classed decide found a *better*
    /// allocation. `0.0` when the exact decide was infeasible.
    pub gap: f64,
}

/// Run the classed-vs-exact decision microbench at each `U` in `us`
/// with the stress-scenario shape (C = min(U/2, 64), 10% stragglers at
/// 0.6 slowdown, 1500 m cell): J0 throughput of the class-level
/// evaluator vs the production cached exact evaluator, plus one full
/// decide per path for the approximation gap. Pure Rust — no artifacts
/// — so `verify.sh` runs it as a tier-1 smoke alongside
/// [`run_sched_bench`]; the U = 100 000 entry doubles as the
/// "completes a stress-100k decision round" acceptance check.
pub fn run_classed_sched_bench(us: &[usize]) -> Vec<ClassedSchedRow> {
    use crate::ga::Chromosome;
    use crate::lyapunov::Queues;
    use crate::sched::{self, ClassingConfig, RoundInputs, Scheduler};
    use crate::solver::Case5Mode;
    use crate::wireless::ChannelModel;

    let mut set = BenchSet::new("sched-classed");
    let mut rows = Vec::new();
    for &u in us {
        let c = (u / 2).min(64).max(1);
        let mut params = crate::config::SystemParams::femnist_small();
        params.num_clients = u;
        params.num_channels = c;
        params.cell_radius_m = 1500.0;
        params.straggler_frac = 0.1;
        params.straggler_slowdown = 0.6;
        let mut rng = crate::util::rng::Rng::seed_from(0xC1A5_5000 + u as u64);
        let model = ChannelModel::new(&params, &mut rng);
        let channels = model.draw(&mut rng);
        let sizes: Vec<f64> = (0..u).map(|_| rng.gaussian(1200.0, 300.0).max(64.0)).collect();
        let total: f64 = sizes.iter().sum();
        let w_full: Vec<f64> = sizes.iter().map(|d| d / total).collect();
        let g2: Vec<f64> = (0..u).map(|_| rng.range(0.05, 16.0)).collect();
        let sigma2: Vec<f64> = (0..u).map(|_| rng.range(0.05, 2.0)).collect();
        let theta_max = vec![0.4; u];
        let q_prev = vec![6.0; u];
        let mut queues = Queues::new();
        queues.lambda1 = 1e3;
        queues.lambda2 = 10.0;
        let inp = RoundInputs {
            params: &params,
            round: 5,
            channels: &channels,
            sizes: &sizes,
            w_full: &w_full,
            g2: &g2,
            sigma2: &sigma2,
            theta_max: &theta_max,
            q_prev: &q_prev,
            queues: &queues,
            avail: None,
        };

        // Exact path: the production cached evaluator over a converging
        // chromosome pool (perturbed greedy, as in run_sched_bench).
        let greedy = sched::greedy_allocation(&inp);
        let chroms: Vec<Chromosome> = (0..16)
            .map(|_| {
                let mut chrom = greedy.clone();
                for _ in 0..(c / 8).max(1) {
                    let a = rng.below(c);
                    let b = rng.below(c);
                    chrom.alloc.swap(a, b);
                    if rng.chance(0.5) {
                        chrom.alloc[a] = Some(rng.below(u));
                    }
                }
                chrom.repair(u);
                chrom
            })
            .collect();
        let ctx = sched::EvalCtx::new(&inp, Case5Mode::Taylor);
        let mut scratch = ctx.make_scratch();
        let mut k = 0usize;
        set.bench(&format!("exact_eval_u{u}"), || {
            k = (k + 1) % chroms.len();
            ctx.evaluate_j0(&chroms[k], &mut scratch)
        });
        let exact_ns = set.results.last().map(|r| r.mean_ns).unwrap_or(0.0);

        // Classed path: class-level J0 over a perturbed greedy-seed pool.
        let cfg = ClassingConfig::default();
        let plan = sched::ClassPlan::build(&inp, cfg);
        let cctx = sched::ClassEvalCtx::new(&inp, &plan, Case5Mode::Taylor, true);
        let (kn, np) = (plan.num_classes(), plan.num_pools());
        let seed_chrom = cctx.greedy_seed();
        let cchroms: Vec<Chromosome> = (0..16)
            .map(|_| {
                let mut chrom = seed_chrom.clone();
                for _ in 0..(np / 8).max(1) {
                    let a = rng.below(np);
                    let b = rng.below(np);
                    chrom.alloc.swap(a, b);
                    if rng.chance(0.5) {
                        chrom.alloc[a] = Some(rng.below(kn));
                    }
                }
                chrom.repair(kn);
                chrom
            })
            .collect();
        let mut cscratch = cctx.make_scratch();
        let mut k = 0usize;
        set.bench(&format!("classed_eval_u{u}"), || {
            k = (k + 1) % cchroms.len();
            cctx.evaluate_j0(&cchroms[k], &mut cscratch)
        });
        let classed_ns = set.results.last().map(|r| r.mean_ns).unwrap_or(0.0);

        // Approximation gap: one full decide per path from the same
        // scheduler seed (the classed decide's reported J0 is exact for
        // its chosen allocation, so the gap is a real objective delta).
        let seed = 0xD0 + u as u64;
        let j0_exact = crate::sched::qccf::QccfScheduler::new(seed).decide(&inp).j0;
        let j0_classed = crate::sched::qccf::QccfScheduler::new(seed)
            .with_classes_override(Some(cfg))
            .decide(&inp)
            .j0;
        let gap = if j0_exact.is_finite() && j0_exact != 0.0 {
            (j0_classed - j0_exact) / j0_exact.abs()
        } else {
            0.0
        };
        rows.push(ClassedSchedRow {
            u,
            c,
            classes: kn,
            pools: np,
            exact_evals_per_sec: if exact_ns > 0.0 { 1e9 / exact_ns } else { 0.0 },
            classed_evals_per_sec: if classed_ns > 0.0 { 1e9 / classed_ns } else { 0.0 },
            speedup: if classed_ns > 0.0 { exact_ns / classed_ns } else { 0.0 },
            j0_exact,
            j0_classed,
            gap,
        });
    }
    rows
}

/// Write sched-bench rows as a single JSON document
/// (`BENCH_sched.json`): the per-row numbers plus per-U
/// cached-vs-uncached speedups — the decision-stage perf baseline
/// subsequent PRs diff against (and the number behind the "cached ≥ 3×
/// at U = 1000" acceptance line) — and, when `classed` is non-empty, a
/// `classed` array with the class-level speedups and approximation
/// gaps of [`run_classed_sched_bench`].
pub fn write_sched_bench_json(
    path: &std::path::Path,
    pool: usize,
    rows: &[SchedBenchRow],
    classed: &[ClassedSchedRow],
) -> std::io::Result<()> {
    use crate::util::json::{self, Json};
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let benches = Json::Arr(
        rows.iter()
            .map(|r| {
                json::obj(vec![
                    ("name", json::s(&r.name)),
                    ("u", json::num(r.u as f64)),
                    ("c", json::num(r.c as f64)),
                    ("cached", Json::Bool(r.cached)),
                    ("iters", json::num(r.iters as f64)),
                    ("mean_ns", json::num(r.mean_ns)),
                    ("evals_per_sec", json::num(r.evals_per_sec)),
                ])
            })
            .collect(),
    );
    let mut speedups = Vec::new();
    // Only the plain cached row pairs against the uncached reference —
    // the masked-availability row measures a different workload.
    for r in rows.iter().filter(|r| r.cached && !r.name.contains("masked")) {
        if let Some(base) = rows.iter().find(|b| !b.cached && b.u == r.u) {
            if r.mean_ns > 0.0 {
                speedups.push(json::obj(vec![
                    ("u", json::num(r.u as f64)),
                    ("speedup", json::num(base.mean_ns / r.mean_ns)),
                ]));
            }
        }
    }
    let classed_rows = Json::Arr(
        classed
            .iter()
            .map(|r| {
                json::obj(vec![
                    ("u", json::num(r.u as f64)),
                    ("c", json::num(r.c as f64)),
                    ("classes", json::num(r.classes as f64)),
                    ("pools", json::num(r.pools as f64)),
                    ("exact_evals_per_sec", json::num(r.exact_evals_per_sec)),
                    ("classed_evals_per_sec", json::num(r.classed_evals_per_sec)),
                    ("speedup", json::num(r.speedup)),
                    ("j0_exact", json::num(r.j0_exact)),
                    ("j0_classed", json::num(r.j0_classed)),
                    ("gap", json::num(r.gap)),
                ])
            })
            .collect(),
    );
    let doc = json::obj(vec![
        ("pool", json::num(pool as f64)),
        ("benches", benches),
        ("speedups", Json::Arr(speedups)),
        ("classed", classed_rows),
    ]);
    crate::util::fsio::write_atomic(path, format!("{}\n", doc.to_string_compact()).as_bytes())
}

/// Canonical regression metric of one `benches` row: key, value, and
/// whether higher is better. The first present key wins: `ns_per_elem`
/// (wire, lower better) → `evals_per_sec` (sched, higher) →
/// `mb_per_sec` (ckpt, higher) → `mean_ns` (fallback, lower).
fn bench_row_metric(row: &crate::util::json::Json) -> Option<(&'static str, f64, bool)> {
    for (key, higher) in [
        ("ns_per_elem", false),
        ("evals_per_sec", true),
        ("mb_per_sec", true),
        ("mean_ns", false),
    ] {
        if let Some(v) = row.get(key).and_then(|x| x.as_f64()) {
            return Some((key, v, higher));
        }
    }
    None
}

/// The committed benchmark baseline files, as written by `qccf bench`
/// and compared by `bench-diff` and `report`. One list so the CLI and
/// the report aggregator can never drift apart on which files exist.
pub const BENCH_FILES: [&str; 3] = ["BENCH_wire.json", "BENCH_sched.json", "BENCH_ckpt.json"];

/// Compare a fresh BENCH_*.json document against the committed
/// baseline and return one warning line per metric that regressed more
/// than `threshold` (fractional — 0.2 = 20%). Rows are matched by
/// `name` in the `benches` array (metric per [`bench_row_metric`]) and
/// by `u` in the `classed` array (on `classed_evals_per_sec`). A row
/// present in the baseline but missing from the fresh run warns too;
/// new rows with no baseline are silently fine. Advisory by design:
/// micro-bench noise on shared CI hardware must not fail the build
/// (the `bench-diff` CLI prints the warnings and exits 0).
pub fn bench_diff_report(
    baseline: &crate::util::json::Json,
    fresh: &crate::util::json::Json,
    threshold: f64,
) -> Vec<String> {
    fn arr<'j>(doc: &'j crate::util::json::Json, key: &str) -> &'j [crate::util::json::Json] {
        doc.get(key).and_then(|x| x.as_arr()).unwrap_or(&[])
    }
    let mut warnings = Vec::new();
    let fresh_benches = arr(fresh, "benches");
    for brow in arr(baseline, "benches") {
        let Some(name) = brow.get("name").and_then(|x| x.as_str()) else { continue };
        let Some(frow) = fresh_benches
            .iter()
            .find(|r| r.get("name").and_then(|x| x.as_str()) == Some(name))
        else {
            warnings.push(format!("{name}: in baseline but missing from fresh run"));
            continue;
        };
        let Some((metric, base, higher)) = bench_row_metric(brow) else { continue };
        let Some(val) = frow.get(metric).and_then(|x| x.as_f64()) else { continue };
        if base <= 0.0 || val <= 0.0 {
            continue;
        }
        let regression = if higher { (base - val) / base } else { (val - base) / base };
        if regression > threshold {
            warnings.push(format!(
                "{name}: {metric} regressed {:.0}% ({base:.1} -> {val:.1})",
                regression * 100.0
            ));
        }
    }
    let fresh_classed = arr(fresh, "classed");
    for brow in arr(baseline, "classed") {
        let Some(u) = brow.get("u").and_then(|x| x.as_usize()) else { continue };
        let Some(base) = brow.get("classed_evals_per_sec").and_then(|x| x.as_f64()) else {
            continue;
        };
        let Some(val) = fresh_classed
            .iter()
            .find(|r| r.get("u").and_then(|x| x.as_usize()) == Some(u))
            .and_then(|r| r.get("classed_evals_per_sec"))
            .and_then(|x| x.as_f64())
        else {
            warnings.push(format!("classed u={u}: in baseline but missing from fresh run"));
            continue;
        };
        if base <= 0.0 || val <= 0.0 {
            continue;
        }
        let regression = (base - val) / base;
        if regression > threshold {
            warnings.push(format!(
                "classed u={u}: classed_evals_per_sec regressed {:.0}% ({base:.1} -> {val:.1})",
                regression * 100.0
            ));
        }
    }
    warnings
}

/// One row of the snapshot-codec perf baseline (`BENCH_ckpt.json`).
#[derive(Clone, Debug)]
pub struct CkptBenchRow {
    /// `ckpt/<op>_z<Z>_u<U>` identifier.
    pub name: String,
    /// U — clients in the synthetic snapshot.
    pub u: usize,
    /// Encoded snapshot size in bytes.
    pub bytes: usize,
    /// Iterations measured.
    pub iters: u64,
    /// Mean per-iteration wall time (ns).
    pub mean_ns: f64,
    /// Snapshot megabytes processed per second — the size-independent
    /// number later PRs regress against.
    pub mb_per_sec: f64,
}

/// A synthetic mid-horizon snapshot shaped like a real run: Z model
/// dims, U clients (each with estimator state and an RNG stream), a
/// 40-round trace with per-client level vectors, and the rendered
/// `paper-femnist` scenario as identity text.
fn synthetic_snapshot(z: usize, u: usize) -> crate::ckpt::Snapshot {
    use crate::ckpt::{ClientCkpt, RunState, Snapshot};
    use crate::metrics::{RoundRecord, Trace};
    use crate::util::rng::Rng;

    let mut rng = Rng::seed_from(0xC4B7_5EED ^ (z as u64) ^ ((u as u64) << 20));
    let mut trace = Trace::new("qccf");
    let rounds = 40usize;
    let mut cum = 0.0;
    for n in 1..=rounds {
        let energy = rng.range(0.01, 0.2);
        cum += energy;
        trace.push(RoundRecord {
            round: n,
            scheduled: u / 2,
            aggregated: u / 2,
            departed: u / 10,
            retries: n % 3,
            failed_decodes: n % 2,
            wire_bytes: (u / 2) * (z / 2),
            energy,
            cum_energy: cum,
            train_loss: rng.range(0.1, 2.0),
            test_loss: (n % 2 == 0).then(|| rng.range(0.1, 2.0)),
            test_acc: (n % 2 == 0).then(|| rng.uniform()),
            mean_q: rng.range(1.0, 12.0),
            q_per_client: (0..u)
                .map(|i| (i % 3 != 2).then_some(1 + (i % 12) as u32))
                .collect(),
            lambda1: rng.range(0.0, 100.0),
            lambda2: rng.range(0.0, 2.0),
            max_latency: rng.range(0.001, 0.02),
            decide_seconds: 0.1,
            compute_seconds: 0.5,
        });
    }
    let mk_rng = |k: u64| Rng::seed_from(k).state();
    Snapshot {
        scenario_text: crate::scenario::render(&crate::scenario::registry::paper_femnist()),
        algorithm: "qccf".into(),
        seed: 1,
        state: RunState {
            round: rounds as u64,
            eps1: 30.0,
            eps2: 0.001,
            theta: (0..z).map(|_| rng.gaussian(0.0, 0.5) as f32).collect(),
            lambda1: 17.0,
            lambda2: 0.25,
            queue_history: (0..=rounds)
                .map(|_| (rng.range(0.0, 100.0), rng.range(0.0, 2.0)))
                .collect(),
            clients: (0..u)
                .map(|i| ClientCkpt {
                    g: rng.range(0.1, 4.0),
                    sigma: rng.range(0.05, 1.0),
                    ema: 0.5,
                    observed: true,
                    theta_max: rng.range(0.1, 0.8),
                    q_prev: rng.range(1.0, 12.0),
                    rng: mk_rng(1000 + i as u64),
                })
                .collect(),
            server_rng: mk_rng(7),
            sched_rng: Some(mk_rng(9)),
            avail: None,
            faults: None,
            runtime_nanos: [1, 2, 3, 4],
        },
        trace,
    }
}

/// Run the snapshot-codec microbench: `Snapshot::encode` and
/// `Snapshot::decode` over a synthetic mid-horizon snapshot at Z model
/// dims × each U in `us`. Pure Rust — no artifacts — so `verify.sh`
/// runs it as a tier-1 smoke (see the `bench-ckpt` CLI subcommand,
/// which writes `BENCH_ckpt.json`): the checkpoint-path perf baseline
/// later PRs regress against.
pub fn run_ckpt_bench(z: usize, us: &[usize]) -> Vec<CkptBenchRow> {
    let mut set = BenchSet::new("ckpt");
    let mut meta: Vec<(usize, usize)> = Vec::new(); // (u, bytes) per row
    for &u in us {
        let snap = synthetic_snapshot(z, u);
        let bytes = snap.encode();
        set.bench(&format!("encode_z{z}_u{u}"), || snap.encode());
        meta.push((u, bytes.len()));
        set.bench(&format!("decode_z{z}_u{u}"), || {
            crate::ckpt::Snapshot::decode(&bytes).expect("freshly encoded snapshot")
        });
        meta.push((u, bytes.len()));
    }
    set.results
        .iter()
        .zip(meta)
        .map(|(r, (u, bytes))| CkptBenchRow {
            name: r.name.clone(),
            u,
            bytes,
            iters: r.iters,
            mean_ns: r.mean_ns,
            mb_per_sec: if r.mean_ns > 0.0 {
                bytes as f64 * 1e3 / r.mean_ns
            } else {
                0.0
            },
        })
        .collect()
}

/// Write ckpt-bench rows as a single JSON document (`BENCH_ckpt.json`):
/// `{"z": Z, "benches": [{name, u, bytes, iters, mean_ns, mb_per_sec},
/// ...]}` — the snapshot-codec perf baseline subsequent PRs diff
/// against.
pub fn write_ckpt_bench_json(
    path: &std::path::Path,
    z: usize,
    rows: &[CkptBenchRow],
) -> std::io::Result<()> {
    use crate::util::json::{self, Json};
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let benches = Json::Arr(
        rows.iter()
            .map(|r| {
                json::obj(vec![
                    ("name", json::s(&r.name)),
                    ("u", json::num(r.u as f64)),
                    ("bytes", json::num(r.bytes as f64)),
                    ("iters", json::num(r.iters as f64)),
                    ("mean_ns", json::num(r.mean_ns)),
                    ("mb_per_sec", json::num(r.mb_per_sec)),
                ])
            })
            .collect(),
    );
    let doc = json::obj(vec![("z", json::num(z as f64)), ("benches", benches)]);
    crate::util::fsio::write_atomic(path, format!("{}\n", doc.to_string_compact()).as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("QCCF_BENCH_WARMUP_MS", "1");
        std::env::set_var("QCCF_BENCH_MEASURE_MS", "5");
        let mut set = BenchSet::new("test");
        let mut acc = 0u64;
        set.bench("noop", || {
            acc = acc.wrapping_add(1);
            acc
        });
        assert_eq!(set.results.len(), 1);
        assert!(set.results[0].iters > 0);
        assert!(set.results[0].mean_ns >= 0.0);
    }

    #[test]
    fn wire_bench_rows_and_json() {
        std::env::set_var("QCCF_BENCH_WARMUP_MS", "1");
        std::env::set_var("QCCF_BENCH_MEASURE_MS", "5");
        let rows = run_wire_bench(512, &[4, 8]);
        assert_eq!(rows.len(), 6, "encode + decode-fold + retry-fold per q");
        assert!(rows.iter().all(|r| r.iters > 0 && r.ns_per_elem >= 0.0));
        assert!(rows.iter().any(|r| r.name.contains("encode_z512_q4")));
        assert!(rows.iter().any(|r| r.name.contains("decode_fold_z512_q8")));
        // The retransmission row carries the realized multi-attempt
        // wire bytes; single-attempt rows carry 0.
        let retry = rows.iter().find(|r| r.name.contains("retry_fold_z512_q4")).unwrap();
        assert_eq!(retry.retry_bytes, RETRY_ATTEMPTS * crate::quant::wire::encoded_len(512, 4));
        assert!(rows
            .iter()
            .filter(|r| !r.name.contains("retry"))
            .all(|r| r.retry_bytes == 0));
        let dir = std::env::temp_dir().join("qccf_wire_bench_test");
        let path = dir.join("BENCH_wire.json");
        write_wire_bench_json(&path, 512, &rows).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::util::json::parse(text.trim()).unwrap();
        assert_eq!(doc.get("z").and_then(|x| x.as_usize()), Some(512));
        assert_eq!(doc.get("benches").and_then(|x| x.as_arr()).map(|a| a.len()), Some(6));
        let benches = doc.get("benches").and_then(|x| x.as_arr()).unwrap();
        assert!(benches
            .iter()
            .any(|b| b.get("retry_bytes").and_then(|x| x.as_f64()).unwrap_or(0.0) > 0.0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sched_bench_rows_and_json() {
        std::env::set_var("QCCF_BENCH_WARMUP_MS", "1");
        std::env::set_var("QCCF_BENCH_MEASURE_MS", "5");
        let rows = run_sched_bench(&[8, 12], 4);
        assert_eq!(rows.len(), 6, "uncached + cached + masked per U");
        assert!(rows.iter().all(|r| r.iters > 0 && r.mean_ns > 0.0 && r.evals_per_sec > 0.0));
        assert!(rows.iter().any(|r| r.name.contains("eval_uncached_u8") && !r.cached));
        assert!(rows.iter().any(|r| r.name.contains("eval_cached_u12") && r.cached));
        assert!(rows.iter().any(|r| r.name.contains("eval_masked_u8") && r.cached));
        assert!(rows.iter().all(|r| r.c == r.u / 2));
        let dir = std::env::temp_dir().join("qccf_sched_bench_test");
        let path = dir.join("BENCH_sched.json");
        write_sched_bench_json(&path, 4, &rows, &[]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::util::json::parse(text.trim()).unwrap();
        assert_eq!(doc.get("pool").and_then(|x| x.as_usize()), Some(4));
        assert_eq!(doc.get("benches").and_then(|x| x.as_arr()).map(|a| a.len()), Some(6));
        let speedups = doc.get("speedups").and_then(|x| x.as_arr()).unwrap();
        assert_eq!(speedups.len(), 2);
        assert!(speedups.iter().all(|s| s.get("speedup").and_then(|x| x.as_f64()).unwrap() > 0.0));
        assert_eq!(doc.get("classed").and_then(|x| x.as_arr()).map(|a| a.len()), Some(0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn classed_sched_bench_rows_and_json() {
        std::env::set_var("QCCF_BENCH_WARMUP_MS", "1");
        std::env::set_var("QCCF_BENCH_MEASURE_MS", "5");
        let rows = run_classed_sched_bench(&[8, 12]);
        assert_eq!(rows.len(), 2, "one classed row per U");
        for r in &rows {
            assert_eq!(r.c, (r.u / 2).min(64).max(1));
            assert!(r.classes >= 1 && r.classes <= r.u, "{r:?}");
            assert!(r.pools >= 1 && r.pools <= r.c, "{r:?}");
            assert!(r.exact_evals_per_sec > 0.0 && r.classed_evals_per_sec > 0.0, "{r:?}");
            assert!(r.speedup > 0.0, "{r:?}");
            // The classed decide re-scores its winner exactly and is
            // backstopped by greedy, so both J0s must be finite here.
            assert!(r.j0_exact.is_finite() && r.j0_classed.is_finite(), "{r:?}");
            assert!(r.gap.is_finite(), "{r:?}");
        }
        let dir = std::env::temp_dir().join("qccf_classed_bench_test");
        let path = dir.join("BENCH_sched.json");
        write_sched_bench_json(&path, 4, &[], &rows).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::util::json::parse(text.trim()).unwrap();
        let classed = doc.get("classed").and_then(|x| x.as_arr()).unwrap();
        assert_eq!(classed.len(), 2);
        for row in classed {
            assert!(row.get("gap").and_then(|x| x.as_f64()).unwrap().is_finite());
            assert!(row.get("speedup").and_then(|x| x.as_f64()).unwrap() > 0.0);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bench_diff_flags_regressions_only() {
        let base = crate::util::json::parse(
            r#"{"benches": [{"name": "a", "evals_per_sec": 100.0},
                            {"name": "b", "ns_per_elem": 10.0},
                            {"name": "gone", "mb_per_sec": 5.0}],
                "classed": [{"u": 8, "classed_evals_per_sec": 1000.0}]}"#,
        )
        .unwrap();
        let fresh = crate::util::json::parse(
            r#"{"benches": [{"name": "a", "evals_per_sec": 50.0},
                            {"name": "b", "ns_per_elem": 11.0},
                            {"name": "new", "mb_per_sec": 1.0}],
                "classed": [{"u": 8, "classed_evals_per_sec": 400.0}]}"#,
        )
        .unwrap();
        let warnings = bench_diff_report(&base, &fresh, 0.2);
        // `a` halved (50% down), classed u=8 lost 60%, `gone` vanished;
        // `b` regressed only 10% (under threshold) and `new` has no
        // baseline — both silent.
        assert_eq!(warnings.len(), 3, "{warnings:?}");
        assert!(warnings.iter().any(|w| w.starts_with("a:") && w.contains("evals_per_sec")));
        assert!(warnings.iter().any(|w| w.starts_with("gone:") && w.contains("missing")));
        assert!(warnings.iter().any(|w| w.starts_with("classed u=8:")));
        assert!(!warnings.iter().any(|w| w.starts_with("b:")));
        // Self-diff is clean; improvements never warn (the reverse
        // diff's only complaint is the structurally missing `new` row).
        assert!(bench_diff_report(&base, &base, 0.2).is_empty());
        let reverse = bench_diff_report(&fresh, &base, 0.2);
        assert_eq!(reverse.len(), 1, "{reverse:?}");
        assert!(reverse[0].starts_with("new:") && reverse[0].contains("missing"));
    }

    #[test]
    fn ckpt_bench_rows_and_json() {
        std::env::set_var("QCCF_BENCH_WARMUP_MS", "1");
        std::env::set_var("QCCF_BENCH_MEASURE_MS", "5");
        let rows = run_ckpt_bench(256, &[10, 25]);
        assert_eq!(rows.len(), 4, "encode + decode per U");
        assert!(rows.iter().all(|r| r.iters > 0 && r.bytes > 0 && r.mb_per_sec > 0.0));
        assert!(rows.iter().any(|r| r.name.contains("encode_z256_u10")));
        assert!(rows.iter().any(|r| r.name.contains("decode_z256_u25")));
        // More clients = bigger snapshot.
        let b10 = rows.iter().find(|r| r.u == 10).unwrap().bytes;
        let b25 = rows.iter().find(|r| r.u == 25).unwrap().bytes;
        assert!(b25 > b10, "b25={b25} b10={b10}");
        let dir = std::env::temp_dir().join("qccf_ckpt_bench_test");
        let path = dir.join("BENCH_ckpt.json");
        write_ckpt_bench_json(&path, 256, &rows).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::util::json::parse(text.trim()).unwrap();
        assert_eq!(doc.get("z").and_then(|x| x.as_usize()), Some(256));
        assert_eq!(doc.get("benches").and_then(|x| x.as_arr()).map(|a| a.len()), Some(4));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }
}
