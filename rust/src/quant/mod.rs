//! Stochastic quantization on the Rust side (paper §II-B).
//!
//! This module provides
//!
//! * a bit-exact Rust mirror of the AOT-lowered Pallas kernel
//!   ([`stochastic_quantize`]; the agreement is pinned bitwise by
//!   `tests/integration_runtime.rs::quantize_artifact_matches_rust_mirror_bitwise`),
//! * the actual **wire codec** ([`encode`]/[`decode`]/[`wire::fold_into`])
//!   — range float + sign bits + knot indices — whose encoded length
//!   *is* eq. (5)'s `ℓ = Z·q + Z + 32` bits. Since the byte-transport
//!   PR this is the round engine's *upload path*: `fl::exec` packs each
//!   quantized upload via [`knot_indices_into`] + [`encode`] and the
//!   server folds eq. (2) straight out of the bitstream,
//! * Lemma 1's variance bound ([`error_bound`]).

pub mod wire;

pub use wire::{decode, decode_indices, encode, encoded_bits, encoded_len, WireError};

/// Quantization knot count minus one: `2^q − 1` intervals.
pub fn levels(q: u32) -> f64 {
    (2f64).powi(q as i32) - 1.0
}

/// Lemma 1: `E‖Q(θ)−θ‖² ≤ Z (θ^max)² / (4 (2^q − 1)²)`.
pub fn error_bound(z: usize, theta_max: f64, q: u32) -> f64 {
    let l = levels(q);
    z as f64 * theta_max * theta_max / (4.0 * l * l)
}

/// Bit-exact mirror of the Pallas kernel in
/// `python/compile/kernels/quantize.py`: same float32 operations in the
/// same order, so given identical `noise` the outputs agree bitwise with
/// the HLO artifact (integration-tested in `rust/tests/`).
///
/// Returns `(dequantized, theta_max)`.
pub fn stochastic_quantize(theta: &[f32], noise: &[f32], q: f32) -> (Vec<f32>, f32) {
    assert_eq!(theta.len(), noise.len());
    let theta_max = theta.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    let levels = (2f32).powf(q) - 1.0;
    let safe_max = if theta_max > 0.0 { theta_max } else { 1.0 };
    let out = theta
        .iter()
        .zip(noise.iter())
        .map(|(&t, &u)| {
            if theta_max == 0.0 {
                return 0.0;
            }
            let scaled = t.abs() / safe_max * levels;
            let low = scaled.floor();
            let frac = scaled - low;
            let knot = low + if u < frac { 1.0 } else { 0.0 };
            sign_f32(t) * knot / levels * safe_max
        })
        .collect();
    (out, theta_max)
}

/// `jnp.sign` semantics (sign(0) = 0), which the kernel relies on.
#[inline]
fn sign_f32(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

/// Knot index of each element (what actually goes on the wire), plus the
/// sign bit. `index ∈ [0, 2^q − 1]`.
pub fn knot_indices(theta: &[f32], noise: &[f32], q: u32) -> (Vec<u32>, Vec<bool>, f32) {
    let mut idx = Vec::new();
    let mut signs = Vec::new();
    let theta_max = knot_indices_into(theta, noise, q, &mut idx, &mut signs);
    (idx, signs, theta_max)
}

/// [`knot_indices`] into caller-owned buffers (cleared and refilled) —
/// the round engine's per-worker scratch path, so the only allocation
/// per upload is the payload that actually crosses the uplink.
///
/// The knot arithmetic is the kernel mirror's, element for element, so
/// `wire::decode(wire::encode(·))` reproduces [`stochastic_quantize`]'s
/// output bit for bit. One wire-specific guard: for q ≥ 25 the f32
/// `levels = 2^q − 1` itself rounds up to `2^q`, so the top knot would
/// overflow its q-bit field — it is clamped to the field's max value,
/// which dequantizes to the *same* f32 (the two integers are not
/// distinguishable at f32 precision), keeping the wire bit-faithful.
///
/// Finite inputs only: a non-finite `theta` element has no knot and
/// would pack as index 0 (decoding to +0.0). The round engine
/// (`fl::exec::run_client`) rejects non-finite models before packing;
/// callers bypassing it must do the same.
pub fn knot_indices_into(
    theta: &[f32],
    noise: &[f32],
    q: u32,
    idx: &mut Vec<u32>,
    signs: &mut Vec<bool>,
) -> f32 {
    assert!((1..=32).contains(&q), "q = {q} outside the wire format's 1..=32");
    assert_eq!(theta.len(), noise.len());
    let theta_max = theta.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    let levels = (2f32).powf(q as f32) - 1.0;
    let safe_max = if theta_max > 0.0 { theta_max } else { 1.0 };
    let field_max: u32 = (u64::MAX >> (64 - q)) as u32;
    idx.clear();
    signs.clear();
    idx.reserve(theta.len());
    signs.reserve(theta.len());
    for (&t, &u) in theta.iter().zip(noise.iter()) {
        let scaled = t.abs() / safe_max * levels;
        let low = scaled.floor();
        let frac = scaled - low;
        let knot = low + if u < frac { 1.0 } else { 0.0 };
        idx.push((knot as u32).min(field_max));
        signs.push(t < 0.0);
    }
    theta_max
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::seed_from(seed);
        let theta: Vec<f32> = (0..n).map(|_| rng.gaussian(0.0, 1.0) as f32).collect();
        let mut noise = vec![0.0f32; n];
        rng.fill_uniform_f32(&mut noise);
        (theta, noise)
    }

    #[test]
    fn knots_on_grid_and_bounded() {
        let (theta, noise) = sample(500, 3);
        let q = 3;
        let (out, tmax) = stochastic_quantize(&theta, &noise, q as f32);
        let l = levels(q) as f32;
        for &v in &out {
            let pos = (v.abs() / tmax * l).round();
            let recon = pos / l * tmax;
            assert!((v.abs() - recon).abs() < 1e-4, "off-grid value {v}");
            assert!(v.abs() <= tmax * 1.0001);
        }
    }

    #[test]
    fn zero_vector_quantizes_to_zero() {
        let theta = vec![0.0f32; 64];
        let noise = vec![0.5f32; 64];
        let (out, tmax) = stochastic_quantize(&theta, &noise, 4.0);
        assert_eq!(tmax, 0.0);
        assert!(out.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn unbiased_statistically() {
        // Lemma 1: E[Q(θ)] = θ — average over many noise draws.
        let mut rng = Rng::seed_from(7);
        let theta: Vec<f32> = (0..128).map(|_| rng.gaussian(0.0, 1.0) as f32).collect();
        let reps = 800;
        let mut acc = vec![0.0f64; theta.len()];
        for _ in 0..reps {
            let mut noise = vec![0.0f32; theta.len()];
            rng.fill_uniform_f32(&mut noise);
            let (out, _) = stochastic_quantize(&theta, &noise, 2.0);
            for (a, &o) in acc.iter_mut().zip(&out) {
                *a += o as f64;
            }
        }
        let tmax = theta.iter().fold(0.0f32, |m, &x| m.max(x.abs())) as f64;
        let tol = tmax / levels(2) / (reps as f64).sqrt() * 5.0;
        for (a, &t) in acc.iter().zip(&theta) {
            assert!((a / reps as f64 - t as f64).abs() < tol);
        }
    }

    #[test]
    fn lemma1_variance_bound_holds() {
        let mut rng = Rng::seed_from(11);
        let theta: Vec<f32> = (0..256).map(|_| rng.gaussian(0.0, 2.0) as f32).collect();
        let tmax = theta.iter().fold(0.0f32, |m, &x| m.max(x.abs())) as f64;
        for q in [1u32, 2, 4, 8] {
            let mut mse = 0.0;
            let reps = 60;
            for _ in 0..reps {
                let mut noise = vec![0.0f32; theta.len()];
                rng.fill_uniform_f32(&mut noise);
                let (out, _) = stochastic_quantize(&theta, &noise, q as f32);
                mse += out
                    .iter()
                    .zip(&theta)
                    .map(|(&o, &t)| ((o - t) as f64).powi(2))
                    .sum::<f64>();
            }
            let bound = error_bound(256, tmax, q);
            assert!(mse / reps as f64 <= bound * 1.05, "q={q}");
        }
    }

    #[test]
    fn error_shrinks_with_q() {
        let (theta, noise) = sample(400, 13);
        let mut prev = f64::INFINITY;
        for q in [1u32, 3, 6, 10] {
            let (out, _) = stochastic_quantize(&theta, &noise, q as f32);
            let err: f64 = out
                .iter()
                .zip(&theta)
                .map(|(&o, &t)| ((o - t) as f64).powi(2))
                .sum();
            assert!(err < prev, "q={q} err={err} prev={prev}");
            prev = err;
        }
    }

    #[test]
    fn error_bound_matches_formula() {
        // Z θmax² / (4(2^q−1)²) for Z=100, θmax=2, q=3 ⇒ 100*4/(4*49) = 2.0408…
        let b = error_bound(100, 2.0, 3);
        assert!((b - 100.0 * 4.0 / (4.0 * 49.0)).abs() < 1e-12);
    }

    #[test]
    fn knot_indices_within_range() {
        let (theta, noise) = sample(300, 17);
        for q in [1u32, 4, 9] {
            let (idx, signs, _) = knot_indices(&theta, &noise, q);
            let max = (1u32 << q) - 1;
            assert!(idx.iter().all(|&i| i <= max), "q={q}");
            assert_eq!(signs.len(), 300);
        }
    }

    #[test]
    fn quantize_respects_noise_threshold() {
        // Deterministic check of the stochastic rounding rule: noise below
        // frac rounds up, above rounds down. theta_max = 1.0, q = 1 ⇒ one
        // interval; 0.6 has frac = 0.6.
        let theta = vec![0.6f32, 0.6, 1.0];
        let noise = vec![0.0f32, 0.99, 0.5];
        let (out, _) = stochastic_quantize(&theta, &noise, 1.0);
        assert_eq!(out[0], 1.0); // 0.0 < 0.6 → rounds up to knot 1
        assert_eq!(out[1], 0.0); // 0.99 ≥ 0.6 → rounds down to knot 0
        assert_eq!(out[2], 1.0); // exact knot (frac 0) stays
    }
}
