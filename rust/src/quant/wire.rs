//! Wire codec for quantized uploads — the concrete realization of the
//! paper's payload accounting (eq. (5)): a 32-bit range float, one sign
//! bit per dimension, and a q-bit knot index per dimension, bit-packed.
//!
//! `encoded_bits(z, q) == Z·q + Z + 32` exactly, so the simulator's
//! latency/energy math (which uses eq. (5) analytically) matches what a
//! real radio would transmit.

/// Exact encoded length in bits (eq. (5)).
pub fn encoded_bits(z: usize, q: u32) -> usize {
    z * q as usize + z + 32
}

/// Streaming bit writer over a little-endian byte buffer: accumulates
/// into a u64 word and flushes whole bytes (the bit-at-a-time version
/// was the top L3 hot spot at Z = 20k — see EXPERIMENTS.md §Perf).
struct BitWriter {
    out: Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl BitWriter {
    fn with_capacity(bits: usize) -> BitWriter {
        BitWriter { out: Vec::with_capacity((bits + 7) / 8), acc: 0, nbits: 0 }
    }

    #[inline]
    fn push(&mut self, value: u64, width: u32) {
        debug_assert!(width <= 32);
        self.acc |= value << self.nbits;
        self.nbits += width;
        while self.nbits >= 8 {
            self.out.push(self.acc as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.out.push(self.acc as u8);
        }
        self.out
    }
}

/// Streaming bit reader (inverse of [`BitWriter`]).
struct BitReader<'a> {
    bytes: &'a [u8],
    byte_pos: usize,
    acc: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    fn new(bytes: &'a [u8]) -> BitReader<'a> {
        BitReader { bytes, byte_pos: 0, acc: 0, nbits: 0 }
    }

    #[inline]
    fn pull(&mut self, width: u32) -> u64 {
        while self.nbits < width {
            let b = self.bytes.get(self.byte_pos).copied().unwrap_or(0) as u64;
            self.acc |= b << self.nbits;
            self.byte_pos += 1;
            self.nbits += 8;
        }
        let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
        let v = self.acc & mask;
        self.acc >>= width;
        self.nbits -= width;
        v
    }
}

/// Bit-pack a quantized model: `(theta_max, signs, knot indices)` →
/// little-endian byte vector of `ceil(encoded_bits / 8)` bytes.
pub fn encode(theta_max: f32, signs: &[bool], indices: &[u32], q: u32) -> Vec<u8> {
    assert_eq!(signs.len(), indices.len());
    let z = signs.len();
    let total_bits = encoded_bits(z, q);
    let mut w = BitWriter::with_capacity(total_bits);
    w.push(u32::from_le_bytes(theta_max.to_le_bytes()) as u64, 32);
    for &s in signs {
        w.push(s as u64, 1);
    }
    for &idx in indices {
        debug_assert!(q == 32 || idx < (1u32 << q), "index {idx} overflows q={q}");
        w.push(idx as u64, q);
    }
    let out = w.finish();
    debug_assert_eq!(out.len(), (total_bits + 7) / 8);
    out
}

/// Inverse of [`encode`]; reconstructs the dequantized values directly
/// (what the server aggregates, eq. (2)).
pub fn decode(bytes: &[u8], z: usize, q: u32) -> (f32, Vec<f32>) {
    let mut r = BitReader::new(bytes);
    let theta_max = f32::from_le_bytes((r.pull(32) as u32).to_le_bytes());
    let signs: Vec<bool> = (0..z).map(|_| r.pull(1) == 1).collect();
    let levels = (2f32).powi(q as i32) - 1.0;
    let inv = theta_max / levels;
    let mut values = Vec::with_capacity(z);
    for &s in signs.iter() {
        let idx = r.pull(q);
        let mag = idx as f32 * inv;
        values.push(if s { -mag } else { mag });
    }
    (theta_max, values)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{knot_indices, stochastic_quantize};
    use crate::util::rng::Rng;

    #[test]
    fn encoded_bits_is_eq5() {
        assert_eq!(encoded_bits(246_590, 8), 246_590 * 8 + 246_590 + 32);
        assert_eq!(encoded_bits(0, 5), 32);
        assert_eq!(encoded_bits(10, 1), 10 + 10 + 32);
    }

    #[test]
    fn roundtrip_reconstructs_dequantized_model() {
        let mut rng = Rng::seed_from(3);
        let theta: Vec<f32> = (0..777).map(|_| rng.gaussian(0.0, 1.5) as f32).collect();
        let mut noise = vec![0.0f32; 777];
        rng.fill_uniform_f32(&mut noise);
        for q in [1u32, 3, 7, 12] {
            let (deq, tmax) = stochastic_quantize(&theta, &noise, q as f32);
            let (idx, signs, tmax2) = knot_indices(&theta, &noise, q);
            assert_eq!(tmax, tmax2);
            let bytes = encode(tmax, &signs, &idx, q);
            assert_eq!(bytes.len(), (encoded_bits(777, q) + 7) / 8);
            let (tmax3, decoded) = decode(&bytes, 777, q);
            assert_eq!(tmax3, tmax);
            for (d, e) in decoded.iter().zip(&deq) {
                assert!((d - e).abs() <= 1e-6 * tmax.max(1.0), "{d} vs {e}");
            }
        }
    }

    #[test]
    fn sign_handling() {
        let theta = vec![-1.0f32, 1.0, -0.25];
        let noise = vec![0.9f32; 3];
        let q = 2;
        let (idx, signs, tmax) = knot_indices(&theta, &noise, q);
        let bytes = encode(tmax, &signs, &idx, q);
        let (_, decoded) = decode(&bytes, 3, q);
        assert!(decoded[0] < 0.0);
        assert!(decoded[1] > 0.0);
        assert!(decoded[2] <= 0.0);
    }

    #[test]
    fn payload_grows_linearly_in_q() {
        let d1 = encoded_bits(1000, 4);
        let d2 = encoded_bits(1000, 5);
        assert_eq!(d2 - d1, 1000);
    }
}
