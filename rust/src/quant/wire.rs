//! Wire codec for quantized uploads — the concrete realization of the
//! paper's payload accounting (eq. (5)): a 32-bit range float, one sign
//! bit per dimension, and a q-bit knot index per dimension, bit-packed.
//!
//! `encoded_bits(z, q) == Z·q + Z + 32` exactly, so the simulator's
//! latency/energy math (which uses eq. (5) analytically) matches what a
//! real radio would transmit. Since the byte-transport PR this codec
//! *is* the upload path: `fl::exec::run_client` encodes every quantized
//! upload into these bytes and the `StreamingAggregator` folds eq. (2)
//! straight out of the bitstream ([`fold_into`]) without materializing
//! the dequantized `Vec<f32>`.
//!
//! # Hardening invariants
//!
//! * Every read-side entry point ([`decode`], [`decode_indices`],
//!   [`fold_into`]) validates `bytes.len() == ceil(encoded_bits / 8)`
//!   **up front** and returns [`WireError`] otherwise — a truncated
//!   buffer is rejected, never silently zero-filled.
//! * Dequantization uses the exact op order of the Pallas-kernel mirror
//!   (`knot / levels * θ^max`, see `quant::stochastic_quantize`), so
//!   `decode ∘ encode ∘ knot_indices` reproduces the quantized model
//!   **bit for bit** (`to_bits()` equality, pinned by tests here and in
//!   `tests/integration_fl.rs`).

/// Exact encoded length in bits (eq. (5)).
pub fn encoded_bits(z: usize, q: u32) -> usize {
    z * q as usize + z + 32
}

/// Exact encoded length in bytes: `ceil(encoded_bits / 8)` — what
/// actually crosses the (simulated) uplink.
pub fn encoded_len(z: usize, q: u32) -> usize {
    (encoded_bits(z, q) + 7) / 8
}

/// A malformed wire payload (the codec's only failure mode: the buffer
/// does not have the exact eq. (5) length for the declared `(z, q)`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireError {
    /// Buffer length differs from `ceil(encoded_bits(z, q) / 8)` —
    /// truncated (or padded) in flight, or decoded with the wrong
    /// `(z, q)` pair.
    Length {
        /// Bytes eq. (5) requires for the declared `(z, q)`.
        expected: usize,
        /// Bytes actually presented.
        got: usize,
    },
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Length { expected, got } => write!(
                f,
                "wire payload is {got} bytes but eq. (5) requires exactly {expected} \
                 (truncated/padded buffer, or wrong (Z, q) declared)"
            ),
        }
    }
}

impl std::error::Error for WireError {}

fn check_len(bytes: &[u8], z: usize, q: u32) -> Result<(), WireError> {
    let expected = encoded_len(z, q);
    if bytes.len() != expected {
        return Err(WireError::Length { expected, got: bytes.len() });
    }
    Ok(())
}

/// Streaming bit writer over a little-endian byte buffer: accumulates
/// into a u64 word and flushes whole bytes (the bit-at-a-time version
/// was the top L3 hot spot at Z = 20k — see EXPERIMENTS.md §Perf).
struct BitWriter {
    out: Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl BitWriter {
    fn with_capacity(bits: usize) -> BitWriter {
        BitWriter { out: Vec::with_capacity((bits + 7) / 8), acc: 0, nbits: 0 }
    }

    #[inline]
    fn push(&mut self, value: u64, width: u32) {
        debug_assert!(width <= 32);
        debug_assert!(width == 64 || value < (1u64 << width), "value overflows width");
        self.acc |= value << self.nbits;
        self.nbits += width;
        while self.nbits >= 8 {
            self.out.push(self.acc as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.out.push(self.acc as u8);
        }
        self.out
    }
}

/// Streaming bit reader (inverse of [`BitWriter`]). Callers validate
/// the buffer against eq. (5) via [`check_len`] *before* any pull, so
/// the reader itself never has to invent bits past the end.
struct BitReader<'a> {
    bytes: &'a [u8],
    byte_pos: usize,
    acc: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    fn new(bytes: &'a [u8]) -> BitReader<'a> {
        BitReader { bytes, byte_pos: 0, acc: 0, nbits: 0 }
    }

    /// Reader positioned at absolute `bit` — lets the fused fold walk
    /// the sign region and the index region of one buffer in lockstep.
    fn at_bit(bytes: &'a [u8], bit: usize) -> BitReader<'a> {
        let mut r = BitReader { bytes, byte_pos: bit / 8, acc: 0, nbits: 0 };
        let skew = (bit % 8) as u32;
        if skew > 0 {
            r.pull(skew);
        }
        r
    }

    #[inline]
    fn pull(&mut self, width: u32) -> u64 {
        while self.nbits < width {
            // In range by construction: the buffer length was validated
            // against eq. (5) before the first pull (see `check_len`).
            debug_assert!(self.byte_pos < self.bytes.len(), "BitReader past validated end");
            let b = self.bytes.get(self.byte_pos).copied().unwrap_or(0) as u64;
            self.acc |= b << self.nbits;
            self.byte_pos += 1;
            self.nbits += 8;
        }
        let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
        let v = self.acc & mask;
        self.acc >>= width;
        self.nbits -= width;
        v
    }
}

/// Bit-pack a quantized model: `(theta_max, signs, knot indices)` →
/// little-endian byte vector of `ceil(encoded_bits / 8)` bytes.
pub fn encode(theta_max: f32, signs: &[bool], indices: &[u32], q: u32) -> Vec<u8> {
    assert!((1..=32).contains(&q), "q = {q} outside the wire format's 1..=32");
    assert_eq!(signs.len(), indices.len());
    let z = signs.len();
    let total_bits = encoded_bits(z, q);
    let mut w = BitWriter::with_capacity(total_bits);
    w.push(u32::from_le_bytes(theta_max.to_le_bytes()) as u64, 32);
    for &s in signs {
        w.push(s as u64, 1);
    }
    for &idx in indices {
        debug_assert!(q == 32 || idx < (1u32 << q), "index {idx} overflows q={q}");
        w.push((idx as u64) & (u64::MAX >> (64 - q)), q);
    }
    let out = w.finish();
    debug_assert_eq!(out.len(), (total_bits + 7) / 8);
    out
}

/// The decoder core: validated-length bitstream → per-element f32
/// values, emitted in index order. The value arithmetic is the exact op
/// order of `quant::stochastic_quantize` (`idx / levels * θ^max`), so
/// the emitted stream is bit-identical to the quantized model the
/// client held.
fn stream_values<F: FnMut(usize, f32)>(bytes: &[u8], z: usize, q: u32, mut emit: F) -> f32 {
    let mut signs = BitReader::new(bytes);
    let theta_max = f32::from_le_bytes((signs.pull(32) as u32).to_le_bytes());
    let mut indices = BitReader::at_bit(bytes, 32 + z);
    let levels = (2f32).powf(q as f32) - 1.0;
    for i in 0..z {
        let neg = signs.pull(1) == 1;
        let idx = indices.pull(q) as u32;
        let mag = idx as f32 / levels * theta_max;
        emit(i, if neg { -mag } else { mag });
    }
    theta_max
}

/// Inverse of [`encode`]; reconstructs the dequantized values directly
/// (what the server aggregates, eq. (2)). Rejects buffers whose length
/// is not exactly `ceil(encoded_bits / 8)`.
pub fn decode(bytes: &[u8], z: usize, q: u32) -> Result<(f32, Vec<f32>), WireError> {
    check_len(bytes, z, q)?;
    let mut values = Vec::with_capacity(z);
    let theta_max = stream_values(bytes, z, q, |_, v| values.push(v));
    Ok((theta_max, values))
}

/// Raw field view of a payload: `(θ^max, signs, knot indices)` without
/// dequantizing — exact for *any* bit pattern (diagnostics and the
/// boundary-fuzz tests, where value roundtrips would be lossy).
pub fn decode_indices(bytes: &[u8], z: usize, q: u32) -> Result<(f32, Vec<bool>, Vec<u32>), WireError> {
    check_len(bytes, z, q)?;
    let mut sign_bits = BitReader::new(bytes);
    let theta_max = f32::from_le_bytes((sign_bits.pull(32) as u32).to_le_bytes());
    let mut index_bits = BitReader::at_bit(bytes, 32 + z);
    let mut signs = Vec::with_capacity(z);
    let mut indices = Vec::with_capacity(z);
    for _ in 0..z {
        signs.push(sign_bits.pull(1) == 1);
        indices.push(index_bits.pull(q) as u32);
    }
    Ok((theta_max, signs, indices))
}

/// Fused decode-and-fold for eq. (2): accumulates `w · value_i` into
/// `acc[i]` straight from the bitstream — the dequantized `Vec<f32>` is
/// never materialized, so the server's in-flight memory per upload is
/// the `~(q+1)/8` bytes per dimension that actually crossed the uplink.
/// `acc.len()` is the declared Z. Returns the payload's θ^max.
///
/// Bit-determinism: per element this computes the same f32 value as
/// [`decode`] and then performs the same `acc += w · v` addition the
/// materializing fold performed, in the same order — so a transport
/// round is bit-identical to the old `Vec<f32>` round.
pub fn fold_into(acc: &mut [f32], w: f32, bytes: &[u8], q: u32) -> Result<f32, WireError> {
    check_len(bytes, acc.len(), q)?;
    let theta_max = stream_values(bytes, acc.len(), q, |i, v| acc[i] += w * v);
    Ok(theta_max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{knot_indices, stochastic_quantize};
    use crate::util::rng::Rng;

    #[test]
    fn encoded_bits_is_eq5() {
        assert_eq!(encoded_bits(246_590, 8), 246_590 * 8 + 246_590 + 32);
        assert_eq!(encoded_bits(0, 5), 32);
        assert_eq!(encoded_bits(10, 1), 10 + 10 + 32);
        assert_eq!(encoded_len(0, 5), 4);
        assert_eq!(encoded_len(10, 1), (10 + 10 + 32 + 7) / 8);
    }

    #[test]
    fn roundtrip_reconstructs_dequantized_model_bitwise() {
        // The decode op order matches the kernel mirror exactly, so the
        // wire roundtrip is to_bits()-identical — including q ≥ 25,
        // where `levels` itself rounds in f32 and the top knot index is
        // clamped into its q-bit field, and q = 32 (u32 saturation).
        let mut rng = Rng::seed_from(3);
        let theta: Vec<f32> = (0..777).map(|_| rng.gaussian(0.0, 1.5) as f32).collect();
        let mut noise = vec![0.0f32; 777];
        rng.fill_uniform_f32(&mut noise);
        for q in [1u32, 3, 7, 12, 24, 27, 32] {
            let (deq, tmax) = stochastic_quantize(&theta, &noise, q as f32);
            let (idx, signs, tmax2) = knot_indices(&theta, &noise, q);
            assert_eq!(tmax.to_bits(), tmax2.to_bits());
            let bytes = encode(tmax, &signs, &idx, q);
            assert_eq!(bytes.len(), encoded_len(777, q));
            let (tmax3, decoded) = decode(&bytes, 777, q).unwrap();
            assert_eq!(tmax3.to_bits(), tmax.to_bits());
            for (i, (d, e)) in decoded.iter().zip(&deq).enumerate() {
                assert_eq!(d.to_bits(), e.to_bits(), "q={q} element {i}: {d} vs {e}");
            }
        }
    }

    #[test]
    fn fold_into_matches_decode_then_fold_bitwise() {
        let mut rng = Rng::seed_from(17);
        let theta: Vec<f32> = (0..513).map(|_| rng.gaussian(0.0, 0.7) as f32).collect();
        let mut noise = vec![0.0f32; 513];
        rng.fill_uniform_f32(&mut noise);
        for q in [1u32, 4, 11] {
            let (idx, signs, tmax) = knot_indices(&theta, &noise, q);
            let bytes = encode(tmax, &signs, &idx, q);
            let w = 0.37f32;
            // Reference: materialize, then fold (the pre-transport path).
            let (_, values) = decode(&bytes, 513, q).unwrap();
            let mut want = vec![0.125f32; 513];
            for (a, v) in want.iter_mut().zip(&values) {
                *a += w * v;
            }
            let mut got = vec![0.125f32; 513];
            let tm = fold_into(&mut got, w, &bytes, q).unwrap();
            assert_eq!(tm.to_bits(), tmax.to_bits());
            assert_eq!(
                got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "q={q}"
            );
        }
    }

    #[test]
    fn truncated_and_padded_buffers_rejected() {
        let (idx, signs, tmax) = ((0..50).map(|i| i % 8).collect::<Vec<u32>>(), vec![false; 50], 1.5f32);
        let bytes = encode(tmax, &signs, &idx, 3);
        assert!(decode(&bytes, 50, 3).is_ok());
        // Truncation at every prefix length must be rejected, not
        // zero-filled (the old `.unwrap_or(0)` bug).
        for cut in 0..bytes.len() {
            let err = decode(&bytes[..cut], 50, 3).unwrap_err();
            let WireError::Length { expected, got } = err;
            assert_eq!(expected, encoded_len(50, 3));
            assert_eq!(got, cut);
        }
        // Padding is just as malformed.
        let mut long = bytes.clone();
        long.push(0);
        assert!(decode(&long, 50, 3).is_err());
        assert!(decode_indices(&long, 50, 3).is_err());
        let mut acc = vec![0.0f32; 50];
        assert!(fold_into(&mut acc, 1.0, &bytes[..bytes.len() - 1], 3).is_err());
        // A wrong (z, q) declaration that changes the eq. (5) length is
        // caught by the same check.
        assert!(decode(&bytes, 500, 3).is_err());
        assert!(decode(&bytes, 50, 29).is_err());
    }

    #[test]
    fn bit_boundary_fuzz_all_widths() {
        // BitWriter/BitReader boundary fuzz: random z (incl. 0), every
        // q ∈ {1, …, 32}, adversarial index patterns (all-ones in the
        // q-bit field, zeros, random) — field roundtrips must be exact
        // and every truncated buffer rejected.
        let mut rng = Rng::seed_from(0xF0_22);
        for case in 0..160usize {
            let q = 1 + (case % 32) as u32;
            let z = match case % 5 {
                0 => 0,
                1 => 1,
                2 => 7 + case % 9,
                _ => rng.below(400),
            };
            let mask: u64 = u64::MAX >> (64 - q);
            let idx: Vec<u32> = (0..z)
                .map(|i| match i % 3 {
                    0 => mask as u32,
                    1 => 0,
                    _ => (rng.next_u64() & mask) as u32,
                })
                .collect();
            let signs: Vec<bool> = (0..z).map(|_| rng.chance(0.5)).collect();
            let tmax = rng.range(0.0, 10.0) as f32;
            let bytes = encode(tmax, &signs, &idx, q);
            assert_eq!(bytes.len(), encoded_len(z, q), "q={q} z={z}");
            let (t2, s2, i2) = decode_indices(&bytes, z, q).unwrap();
            assert_eq!(t2.to_bits(), tmax.to_bits(), "q={q} z={z}");
            assert_eq!(s2, signs, "q={q} z={z}");
            assert_eq!(i2, idx, "q={q} z={z}");
            if !bytes.is_empty() {
                assert!(decode_indices(&bytes[..bytes.len() - 1], z, q).is_err());
            }
        }
    }

    #[test]
    fn sign_handling() {
        let theta = vec![-1.0f32, 1.0, -0.25];
        let noise = vec![0.9f32; 3];
        let q = 2;
        let (idx, signs, tmax) = knot_indices(&theta, &noise, q);
        let bytes = encode(tmax, &signs, &idx, q);
        let (_, decoded) = decode(&bytes, 3, q).unwrap();
        assert!(decoded[0] < 0.0);
        assert!(decoded[1] > 0.0);
        assert!(decoded[2] <= 0.0);
    }

    #[test]
    fn zero_vector_roundtrips_to_positive_zero() {
        // θ^max = 0 payloads must reproduce the kernel's all-(+0.0)
        // output exactly (sign bits stay clear for ±0 inputs).
        let theta = vec![0.0f32, -0.0, 0.0];
        let noise = vec![0.5f32; 3];
        let (deq, tmax) = stochastic_quantize(&theta, &noise, 4.0);
        let (idx, signs, tmax2) = knot_indices(&theta, &noise, 4);
        assert_eq!(tmax.to_bits(), tmax2.to_bits());
        let bytes = encode(tmax2, &signs, &idx, 4);
        let (_, decoded) = decode(&bytes, 3, 4).unwrap();
        for (d, e) in decoded.iter().zip(&deq) {
            assert_eq!(d.to_bits(), e.to_bits());
        }
    }

    #[test]
    fn payload_grows_linearly_in_q() {
        let d1 = encoded_bits(1000, 4);
        let d2 = encoded_bits(1000, 5);
        assert_eq!(d2 - d1, 1000);
    }
}
