//! Wireless substrate (paper §IV-A): large-scale pathloss, Rician
//! small-scale fading, per-round channel draws, and OFDMA Shannon rates.
//!
//! `h_{i,c}^n = h^Gain · h^{n,Rician}_{i,c} · h^{n,Loss}_i` — device gain
//! × per-channel Rician(K, ζ) power × distance pathloss (3GPP-style UMa
//! LOS at carrier ν). Channel responses are constant within a round and
//! i.i.d. across rounds, exactly as the paper assumes [29].

pub mod channel;
pub mod pathloss;

pub use channel::{ChannelModel, ChannelState};
pub use pathloss::{pathloss_db, pathloss_gain};

/// Shannon rate of one allocated channel (the summand of the paper's
/// uplink-rate formula): `B log2(1 + p h / (B N0))` in bit/s.
pub fn channel_rate(bandwidth_hz: f64, tx_power_w: f64, h: f64, noise_psd: f64) -> f64 {
    let snr = tx_power_w * h / (bandwidth_hz * noise_psd);
    bandwidth_hz * (1.0 + snr).log2()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_monotone_in_gain() {
        let r1 = channel_rate(1e6, 0.2, 1e-9, 4e-21);
        let r2 = channel_rate(1e6, 0.2, 1e-8, 4e-21);
        assert!(r2 > r1);
        assert!(r1 > 0.0);
    }

    #[test]
    fn rate_zero_gain_is_zero() {
        assert_eq!(channel_rate(1e6, 0.2, 0.0, 4e-21), 0.0);
    }

    #[test]
    fn rate_scale_sanity() {
        // SNR of 2^20 - 1 gives exactly 20 bit/s/Hz.
        let b = 1e6;
        let n0 = 4e-21;
        let h = (2f64.powi(20) - 1.0) * b * n0 / 0.2;
        let r = channel_rate(b, 0.2, h, n0);
        assert!((r - 20e6).abs() < 1.0, "r={r}");
    }
}
