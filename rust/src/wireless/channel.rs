//! Per-round channel state: client placement, composite gains
//! `h_{i,c}^n`, and uplink rates per (client, channel) pair.

use crate::config::params::db_to_lin;
use crate::config::SystemParams;
use crate::util::rng::Rng;

use super::{channel_rate, pathloss_gain};

/// Static geometry + parameters; draws a fresh [`ChannelState`] each round.
#[derive(Clone, Debug)]
pub struct ChannelModel {
    /// Distance of each client from the server (m).
    pub distances_m: Vec<f64>,
    /// Large-scale gain per client (pathloss × device gain), constant
    /// over the run (client mobility is out of scope, as in the paper).
    pub large_scale: Vec<f64>,
    num_channels: usize,
    bandwidth_hz: f64,
    tx_power_w: f64,
    noise_psd: f64,
    rician_k: f64,
    rician_zeta: f64,
}

impl ChannelModel {
    /// Place `U` clients uniformly in the cell disk (area-uniform:
    /// d = R·sqrt(u)) and precompute large-scale gains.
    ///
    /// Two scenario-subsystem extensions, both inert at the Table-I
    /// defaults (they consume **no** extra RNG draws when disabled, so
    /// paper-profile channel realizations are unchanged):
    ///
    /// * `params.num_aps > 1` — *cell-free lite*: APs are placed
    ///   area-uniformly in the same disk and each client's serving
    ///   distance is to its **nearest** AP (the pathloss side of a
    ///   cell-free deployment; small-scale fading stays per-channel
    ///   Rician);
    /// * `params.deep_fade_frac > 0` — the deep-fade client class gets
    ///   `deep_fade_db` of extra large-scale attenuation
    ///   ([`SystemParams::in_deep_fade`]).
    pub fn new(params: &SystemParams, rng: &mut Rng) -> ChannelModel {
        let distances_m: Vec<f64> = if params.num_aps <= 1 {
            (0..params.num_clients)
                .map(|_| params.cell_radius_m * rng.uniform().sqrt())
                .collect()
        } else {
            let place = |rng: &mut Rng| -> (f64, f64) {
                let r = params.cell_radius_m * rng.uniform().sqrt();
                let a = std::f64::consts::TAU * rng.uniform();
                (r * a.cos(), r * a.sin())
            };
            let aps: Vec<(f64, f64)> = (0..params.num_aps).map(|_| place(rng)).collect();
            (0..params.num_clients)
                .map(|_| {
                    let (x, y) = place(rng);
                    aps.iter()
                        .map(|&(ax, ay)| ((x - ax).powi(2) + (y - ay).powi(2)).sqrt())
                        .fold(f64::INFINITY, f64::min)
                })
                .collect()
        };
        let gain = db_to_lin(params.gain_db);
        let fade = db_to_lin(-params.deep_fade_db);
        let large_scale = distances_m
            .iter()
            .enumerate()
            .map(|(i, &d)| {
                let g = gain * pathloss_gain(d, params.carrier_ghz);
                if params.in_deep_fade(i) {
                    g * fade
                } else {
                    g
                }
            })
            .collect();
        ChannelModel {
            distances_m,
            large_scale,
            num_channels: params.num_channels,
            bandwidth_hz: params.bandwidth_hz,
            tx_power_w: params.tx_power_w,
            noise_psd: params.noise_psd_w_hz,
            rician_k: params.rician_k,
            rician_zeta: params.rician_zeta,
        }
    }

    /// Draw the round's `h_{i,c}^n` (frequency-selective: independent
    /// Rician power per channel) and the resulting per-pair rates.
    pub fn draw(&self, rng: &mut Rng) -> ChannelState {
        let u = self.large_scale.len();
        let c = self.num_channels;
        let mut gains = vec![0.0f64; u * c];
        let mut rates = vec![0.0f64; u * c];
        for i in 0..u {
            for ch in 0..c {
                let small = rng.rician_power(self.rician_k, self.rician_zeta);
                let h = self.large_scale[i] * small;
                gains[i * c + ch] = h;
                rates[i * c + ch] =
                    channel_rate(self.bandwidth_hz, self.tx_power_w, h, self.noise_psd);
            }
        }
        ChannelState { num_clients: u, num_channels: c, gains, rates }
    }
}

/// One round's channel realization.
#[derive(Clone, Debug)]
pub struct ChannelState {
    /// U — clients in this realization.
    pub num_clients: usize,
    /// C — channels in this realization.
    pub num_channels: usize,
    /// Row-major `[client][channel]` composite power gains.
    gains: Vec<f64>,
    /// Row-major `[client][channel]` Shannon rates (bit/s).
    rates: Vec<f64>,
}

impl ChannelState {
    /// Composite power gain `h_{i,c}^n`.
    pub fn gain(&self, client: usize, channel: usize) -> f64 {
        self.gains[client * self.num_channels + channel]
    }

    /// Shannon rate of the (client, channel) pair (bit/s).
    pub fn rate(&self, client: usize, channel: usize) -> f64 {
        self.rates[client * self.num_channels + channel]
    }

    /// Best channel for a client (used by greedy baselines).
    pub fn best_channel(&self, client: usize) -> usize {
        (0..self.num_channels)
            .max_by(|&a, &b| self.rate(client, a).total_cmp(&self.rate(client, b)))
            .unwrap_or(0)
    }

    /// Build directly from a rate matrix (testing / synthetic scenarios).
    pub fn from_rates(num_clients: usize, num_channels: usize, rates: Vec<f64>) -> ChannelState {
        assert_eq!(rates.len(), num_clients * num_channels);
        ChannelState { num_clients, num_channels, gains: rates.clone(), rates }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> (ChannelModel, Rng) {
        let params = SystemParams::femnist_small();
        let mut rng = Rng::seed_from(5);
        (ChannelModel::new(&params, &mut rng), rng)
    }

    #[test]
    fn placement_within_cell() {
        let (m, _) = model();
        assert_eq!(m.distances_m.len(), 10);
        assert!(m.distances_m.iter().all(|&d| (0.0..=500.0).contains(&d)));
    }

    #[test]
    fn nearer_clients_have_higher_large_scale_gain() {
        let (m, _) = model();
        let mut pairs: Vec<(f64, f64)> =
            m.distances_m.iter().cloned().zip(m.large_scale.iter().cloned()).collect();
        pairs.sort_by(|a, b| a.0.total_cmp(&b.0));
        for w in pairs.windows(2) {
            assert!(w[0].1 >= w[1].1, "gain should fall with distance");
        }
    }

    #[test]
    fn draw_shapes_and_positivity() {
        let (m, mut rng) = model();
        let st = m.draw(&mut rng);
        assert_eq!((st.num_clients, st.num_channels), (10, 10));
        for i in 0..10 {
            for c in 0..10 {
                assert!(st.gain(i, c) > 0.0);
                assert!(st.rate(i, c) > 0.0);
            }
        }
    }

    #[test]
    fn draws_differ_across_rounds() {
        let (m, mut rng) = model();
        let a = m.draw(&mut rng);
        let b = m.draw(&mut rng);
        assert_ne!(a.gain(0, 0), b.gain(0, 0));
    }

    #[test]
    fn rates_in_plausible_band() {
        // Calibration check: with default params, rates should sit in the
        // ~5–40 Mb/s band that makes q ∈ [1, 16] feasible for Z ≈ 20 k.
        let (m, mut rng) = model();
        let st = m.draw(&mut rng);
        let mut all = Vec::new();
        for i in 0..10 {
            for c in 0..10 {
                all.push(st.rate(i, c));
            }
        }
        let mean = all.iter().sum::<f64>() / all.len() as f64;
        assert!(mean > 5e6 && mean < 60e6, "mean rate {mean}");
    }

    #[test]
    fn deep_fade_class_attenuated() {
        let mut params = SystemParams::femnist_small();
        params.deep_fade_frac = 0.3;
        params.deep_fade_db = 20.0;
        // Same seed with and without the fade: the class loses exactly
        // 20 dB of large-scale gain, everyone else is untouched.
        let mut rng_a = Rng::seed_from(9);
        let faded = ChannelModel::new(&params, &mut rng_a);
        let mut rng_b = Rng::seed_from(9);
        let baseline = ChannelModel::new(&SystemParams::femnist_small(), &mut rng_b);
        for i in 0..10 {
            let ratio = faded.large_scale[i] / baseline.large_scale[i];
            if params.in_deep_fade(i) {
                assert!((ratio - 0.01).abs() < 1e-9, "client {i}: ratio {ratio}");
            } else {
                assert!((ratio - 1.0).abs() < 1e-12, "client {i}: ratio {ratio}");
            }
        }
    }

    #[test]
    fn cell_free_layout_shrinks_serving_distance() {
        // With many APs scattered in the disk the nearest-AP distance
        // is stochastically much smaller than the distance to a single
        // central BS; check the aggregate effect over several seeds.
        let mut cf = SystemParams::femnist_small();
        cf.num_aps = 8;
        let single = SystemParams::femnist_small();
        let (mut d_cf, mut d_sc) = (0.0, 0.0);
        for seed in 0..5u64 {
            let mut r1 = Rng::seed_from(seed);
            d_cf += ChannelModel::new(&cf, &mut r1).distances_m.iter().sum::<f64>();
            let mut r2 = Rng::seed_from(seed);
            d_sc += ChannelModel::new(&single, &mut r2).distances_m.iter().sum::<f64>();
        }
        assert!(d_cf < d_sc, "cell-free mean distance {d_cf} !< single-cell {d_sc}");
        // Serving distances stay inside the deployment area.
        let mut r = Rng::seed_from(3);
        let m = ChannelModel::new(&cf, &mut r);
        assert!(m.distances_m.iter().all(|&d| (0.0..=2.0 * 500.0).contains(&d)));
    }

    #[test]
    fn best_channel_is_argmax() {
        let st = ChannelState::from_rates(2, 3, vec![1.0, 5.0, 2.0, 9.0, 1.0, 3.0]);
        assert_eq!(st.best_channel(0), 1);
        assert_eq!(st.best_channel(1), 0);
    }

    #[test]
    fn best_channel_bit_identical_to_partial_cmp_reference() {
        // Bit-identity pin for the detlint R3 fix: on drawn (finite,
        // positive) rates, the total_cmp argmax picks the same channel
        // the historical partial_cmp argmax picked for every client,
        // and exact rate ties keep the last-max-wins convention.
        let (m, mut rng) = model();
        let st = m.draw(&mut rng);
        for i in 0..st.num_clients {
            let reference = (0..st.num_channels)
                .max_by(|&a, &b| st.rate(i, a).partial_cmp(&st.rate(i, b)).unwrap())
                .unwrap();
            assert_eq!(st.best_channel(i), reference, "client {i}");
        }
        let tie = ChannelState::from_rates(1, 3, vec![5.0, 7.0, 7.0]);
        assert_eq!(tie.best_channel(0), 2, "max_by keeps the last max on ties");
    }
}
