//! Large-scale fading: 3GPP TR 38.901-style UMa LOS pathloss
//! `PL(dB) = 28.0 + 22 log10(d_3D) + 20 log10(f_GHz)` (the paper cites
//! TR 38.901 for its large-scale model [32]).

/// Pathloss in dB at 3D distance `d_m` meters, carrier `fc_ghz` GHz.
/// Clamped below at 1 m to keep the formula sane for co-located clients.
pub fn pathloss_db(d_m: f64, fc_ghz: f64) -> f64 {
    let d = d_m.max(1.0);
    28.0 + 22.0 * d.log10() + 20.0 * fc_ghz.log10()
}

/// Linear power *gain* (≤ 1) corresponding to [`pathloss_db`].
pub fn pathloss_gain(d_m: f64, fc_ghz: f64) -> f64 {
    10f64.powf(-pathloss_db(d_m, fc_ghz) / 10.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone_in_distance() {
        let near = pathloss_db(10.0, 2.4);
        let far = pathloss_db(500.0, 2.4);
        assert!(far > near);
        // 22 dB/decade slope.
        let d1 = pathloss_db(100.0, 2.4);
        let d2 = pathloss_db(1000.0, 2.4);
        assert!((d2 - d1 - 22.0).abs() < 1e-9);
    }

    #[test]
    fn carrier_dependence() {
        // 20 dB per decade of carrier frequency.
        let a = pathloss_db(100.0, 1.0);
        let b = pathloss_db(100.0, 10.0);
        assert!((b - a - 20.0).abs() < 1e-9);
    }

    #[test]
    fn gain_inverse_of_db() {
        let db = pathloss_db(250.0, 2.4);
        let g = pathloss_gain(250.0, 2.4);
        assert!((-10.0 * g.log10() - db).abs() < 1e-9);
        assert!(g > 0.0 && g < 1.0);
    }

    #[test]
    fn clamps_below_one_meter() {
        assert_eq!(pathloss_db(0.0, 2.4), pathloss_db(1.0, 2.4));
    }

    #[test]
    fn expected_magnitude_at_cell_edge() {
        // ~96 dB at 500 m / 2.4 GHz — the regime the calibration note in
        // config/mod.rs reasons about.
        let db = pathloss_db(500.0, 2.4);
        assert!((db - 94.0).abs() < 4.0, "db={db}");
    }
}
