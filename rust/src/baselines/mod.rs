//! The four comparison algorithms of §VI *Baselines*:
//!
//! * [`NoQuantScheduler`] — uploads raw 32-bit models;
//! * [`ChannelAllocateScheduler`] — optimizes channels (GA over sum-rate),
//!   then maximizes q under the latency budget;
//! * [`PrincipleScheduler`] — DAdaQuant-style principle from [24]:
//!   q rises with the round index and is *proportional* to dataset size,
//!   ignoring wireless constraints (so large-D clients eventually time
//!   out, as the paper observes);
//! * [`SameSizeScheduler`] — the Lyapunov method of [26] under its
//!   equal-dataset assumption: QCCF's pipeline run with every D_i
//!   replaced by the mean D̄; clients must then stretch their actual
//!   frequency to meet the deadline their decision underestimated.

use crate::energy;
use crate::ga::{self, Chromosome, GaParams};
use crate::sched::{greedy_allocation, ClientDecision, RoundDecision, RoundInputs, Scheduler};
use crate::solver::{self, Case5Mode};
use crate::util::rng::Rng;

// ------------------------------------------------------------------------
// (a) No Quantization
// ------------------------------------------------------------------------

/// Greedy channels; raw uploads; no latency design whatsoever (the
/// baseline predates the wireless optimization): every client joins,
/// computing at the deadline-meeting frequency when one exists and at
/// f^min otherwise, and uploads are not dropped for lateness — under
/// Table I the raw payload exceeds T^max by construction, yet the
/// paper's Fig. 3/4 show this baseline converging at maximal energy.
pub struct NoQuantScheduler;

impl Scheduler for NoQuantScheduler {
    fn name(&self) -> &'static str {
        "no-quant"
    }

    fn decide(&mut self, inp: &RoundInputs<'_>) -> RoundDecision {
        let p = inp.params;
        let chrom = greedy_allocation(inp);
        let mut assignments = vec![None; p.num_clients];
        for (ch, slot) in chrom.alloc.iter().enumerate() {
            let Some(i) = *slot else { continue };
            let rate = inp.channels.rate(i, ch);
            // No frequency control either: devices run at their default.
            let f = p.nominal_f();
            assignments[i] = Some(ClientDecision { channel: ch, q: None, f, rate });
        }
        RoundDecision { assignments, j0: f64::NAN, evals: 0, deadline_exempt: true }
    }
}

// ------------------------------------------------------------------------
// (b) Channel-Allocate
// ------------------------------------------------------------------------

/// GA over channel allocation maximizing the aggregate rate, then the
/// **maximum feasible** quantization level per client (no convergence
/// awareness): q = q_max, f = 𝒮(q).
pub struct ChannelAllocateScheduler {
    ga: GaParams,
    rng: Rng,
}

impl ChannelAllocateScheduler {
    /// Scheduler with the default GA budget. The GA fitness cache
    /// honors the `QCCF_DECISION_CACHE=0` A/B kill switch like the
    /// other GA-based schedulers (no `EvalCtx` here — the fitness is a
    /// plain rate sum).
    pub fn new(seed: u64) -> Self {
        ChannelAllocateScheduler {
            ga: GaParams {
                fitness_cache: crate::sched::ctx::decision_cache_default(),
                ..GaParams::default()
            },
            rng: Rng::seed_from(seed),
        }
    }

    /// Fan GA fitness evaluations out over `threads` workers.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.ga.threads = threads.max(1);
        self
    }
}

impl Scheduler for ChannelAllocateScheduler {
    fn name(&self) -> &'static str {
        "channel-allocate"
    }

    fn decide(&mut self, inp: &RoundInputs<'_>) -> RoundDecision {
        let p = inp.params;
        // Maximize Σ log rates of assigned clients ⇒ minimize the negation.
        let eval = |c: &Chromosome| -> f64 {
            let mut j = 0.0;
            let mut any = false;
            for (ch, slot) in c.alloc.iter().enumerate() {
                if let Some(i) = *slot {
                    j -= inp.channels.rate(i, ch);
                    any = true;
                }
            }
            if any {
                j
            } else {
                f64::INFINITY
            }
        };
        let out = ga::optimize(p.num_channels, p.num_clients, &self.ga, &mut self.rng, eval);
        let mut assignments = vec![None; p.num_clients];
        for (ch, slot) in out.best.alloc.iter().enumerate() {
            let Some(i) = *slot else { continue };
            let rate = inp.channels.rate(i, ch);
            let Some(q) = solver::q_max_feasible(p, inp.sizes[i], rate) else { continue };
            let Some(f) = energy::s_of_q(p, inp.sizes[i], q, rate) else { continue };
            assignments[i] = Some(ClientDecision { channel: ch, q: Some(q), f, rate });
        }
        RoundDecision { assignments, j0: out.best_j0, evals: out.evals, deadline_exempt: false }
    }

    // Like QCCF: the GA stream is this scheduler's only mutable state,
    // so checkpoint/resume captures exactly this position.
    fn rng_state(&self) -> Option<crate::util::rng::RngState> {
        Some(self.rng.state())
    }

    fn restore_rng_state(&mut self, state: &crate::util::rng::RngState) {
        self.rng.restore(state);
    }
}

// ------------------------------------------------------------------------
// (c) Principle [24]
// ------------------------------------------------------------------------

/// DAdaQuant-style doubly adaptive *principle* with no wireless
/// awareness: `q_i(n) = clamp(round((q0 + ramp·n) · D_i/D̄), 1, q_cap)`.
/// Frequency: stretch to meet the deadline if possible; otherwise run at
/// f^max and let the round time out (the server drops the upload but the
/// energy is spent — reproducing the paper's late-training stall).
pub struct PrincipleScheduler {
    /// Starting level q0.
    pub q0: f64,
    /// Level growth per round.
    pub ramp: f64,
}

impl PrincipleScheduler {
    /// The paper-calibrated ramp (q ≈ 2 → 14 over 40 rounds).
    pub fn new() -> Self {
        // q climbs ~2 → ~14 over a 40-round run at D_i = D̄, so
        // large-dataset clients cross the C4 wall late in training —
        // the stall the paper reports for this baseline.
        PrincipleScheduler { q0: 2.0, ramp: 0.3 }
    }
}

impl Default for PrincipleScheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for PrincipleScheduler {
    fn name(&self) -> &'static str {
        "principle"
    }

    fn decide(&mut self, inp: &RoundInputs<'_>) -> RoundDecision {
        let p = inp.params;
        let chrom = greedy_allocation(inp);
        let d_mean = inp.sizes.iter().sum::<f64>() / inp.sizes.len() as f64;
        let mut assignments = vec![None; p.num_clients];
        for (ch, slot) in chrom.alloc.iter().enumerate() {
            let Some(i) = *slot else { continue };
            let rate = inp.channels.rate(i, ch);
            // The principle: proportional to dataset size, rising with n.
            let q_raw = (self.q0 + self.ramp * inp.round as f64) * inp.sizes[i] / d_mean;
            let q = (q_raw.round() as u32).clamp(1, p.q_cap);
            // No energy-aware frequency design: devices run at their
            // default and only *accelerate* when the deadline demands it
            // ("all clients accelerate CPUs to satisfy the latency
            // constraint", §VI-B) — capped at f^max (then they time out).
            let f = match energy::s_of_q(p, inp.sizes[i], q, rate) {
                Some(f_deadline) => f_deadline.max(p.nominal_f()),
                None => p.f_max,
            };
            assignments[i] = Some(ClientDecision { channel: ch, q: Some(q), f, rate });
        }
        RoundDecision { assignments, j0: f64::NAN, evals: 0, deadline_exempt: false }
    }
}

// ------------------------------------------------------------------------
// (d) Same-Size [26]
// ------------------------------------------------------------------------

/// The Lyapunov design of [26] under its same-dataset-size assumption:
/// run the full QCCF pipeline with D_i ≡ D̄, then fix up frequencies
/// against each client's *actual* D_i (accelerating CPUs, as the paper
/// describes — the source of its energy blow-up at large β).
pub struct SameSizeScheduler {
    ga: GaParams,
    case5: Case5Mode,
    /// Decision-stage caching, honoring the same
    /// `QCCF_DECISION_CACHE=0` A/B kill switch as `QccfScheduler`
    /// (results are bit-identical either way — see `sched::ctx`).
    cache: bool,
    rng: Rng,
}

impl SameSizeScheduler {
    /// Scheduler with the default GA budget and Taylor Case-5 mode.
    pub fn new(seed: u64) -> Self {
        SameSizeScheduler {
            ga: GaParams::default(),
            case5: Case5Mode::Taylor,
            cache: crate::sched::ctx::decision_cache_default(),
            rng: Rng::seed_from(seed),
        }
    }

    /// Fan GA fitness evaluations out over `threads` workers.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.ga.threads = threads.max(1);
        self
    }

    /// Enable or disable the decision-stage caches (default: on).
    pub fn with_cache(mut self, enabled: bool) -> Self {
        self.cache = enabled;
        self
    }
}

impl Scheduler for SameSizeScheduler {
    fn name(&self) -> &'static str {
        "same-size"
    }

    fn decide(&mut self, inp: &RoundInputs<'_>) -> RoundDecision {
        let p = inp.params;
        let d_mean = inp.sizes.iter().sum::<f64>() / inp.sizes.len() as f64;
        let fake_sizes = vec![d_mean; p.num_clients];
        let fake_w = vec![1.0 / p.num_clients as f64; p.num_clients];
        let fake = RoundInputs {
            params: inp.params,
            round: inp.round,
            channels: inp.channels,
            sizes: &fake_sizes,
            w_full: &fake_w,
            g2: inp.g2,
            sigma2: inp.sigma2,
            theta_max: inp.theta_max,
            q_prev: inp.q_prev,
            queues: inp.queues,
            // The availability mask passes through untouched: an
            // offline client stays unschedulable even under the
            // equal-size fiction.
            avail: inp.avail,
        };
        // Same shared decide body as QCCF (sched::ctx::decide_with_ga:
        // per-round EvalCtx + solve memo + per-worker scratch + GA
        // fitness cache), over the equal-size inputs; bit-identical to
        // the old evaluate_allocation-per-candidate loop, with no seed
        // chromosomes so the RNG trajectory is unchanged too.
        let (j0, fake_assignments, evals) = crate::sched::ctx::decide_with_ga(
            &fake,
            self.case5,
            &self.ga,
            &mut self.rng,
            &[],
            self.cache,
        );
        // Realization under heterogeneity: the equal-size controller has
        // no per-client view, so the synchronized round must provision
        // compute for the *largest* dataset — "computation latency is
        // determined by the largest dataset under the same-size
        // assumption. Hence, all clients accelerate CPUs to satisfy the
        // latency constraint" (§VI-B). Every participant therefore runs
        // at the frequency the worst-case D needs for its own q (clamped
        // to f^max; true stragglers may still time out).
        let d_max = inp.sizes.iter().cloned().fold(0.0f64, f64::max);
        let mut assignments = vec![None; p.num_clients];
        for (i, d) in fake_assignments.iter().enumerate() {
            let Some(d) = d else { continue };
            let q = d.q.unwrap();
            let f_worst = energy::s_of_q(p, d_max, q, d.rate).unwrap_or(p.f_max);
            let f = match energy::s_of_q(p, inp.sizes[i], q, d.rate) {
                Some(f_own) => f_own.max(d.f).max(f_worst),
                None => p.f_max, // will time out; energy is still spent
            };
            assignments[i] = Some(ClientDecision { channel: d.channel, q: Some(q), f, rate: d.rate });
        }
        RoundDecision { assignments, j0, evals, deadline_exempt: false }
    }

    // Like QCCF: the GA stream is this scheduler's only mutable state,
    // so checkpoint/resume captures exactly this position.
    fn rng_state(&self) -> Option<crate::util::rng::RngState> {
        Some(self.rng.state())
    }

    fn restore_rng_state(&mut self, state: &crate::util::rng::RngState) {
        self.rng.restore(state);
    }
}

/// Factory used by the CLI / experiment harness (serial GA fitness).
pub fn make_scheduler(name: &str, seed: u64) -> Option<Box<dyn Scheduler>> {
    make_scheduler_with_threads(name, seed, 1)
}

/// [`make_scheduler`] with an explicit worker count for the GA fitness
/// fan-out of the GA-based schedulers (deterministic for any value;
/// the non-GA baselines ignore it).
pub fn make_scheduler_with_threads(
    name: &str,
    seed: u64,
    threads: usize,
) -> Option<Box<dyn Scheduler>> {
    make_scheduler_with_classes(name, seed, threads, None)
}

/// [`make_scheduler_with_threads`] plus the scenario's class-based
/// scheduling request (`[train] classes = true` →
/// `Some(ClassingConfig)`). Only QCCF has a classed decide body today;
/// every other algorithm ignores the request. The
/// `QCCF_DECISION_CLASSES=0` kill switch is honored inside
/// [`crate::sched::qccf::QccfScheduler::with_classes`], so a `Some`
/// here still yields the exact path under the kill switch.
pub fn make_scheduler_with_classes(
    name: &str,
    seed: u64,
    threads: usize,
    classes: Option<crate::sched::ClassingConfig>,
) -> Option<Box<dyn Scheduler>> {
    match name {
        "qccf" => {
            let mut s = crate::sched::qccf::QccfScheduler::new(seed).with_threads(threads);
            if let Some(cfg) = classes {
                s = s.with_classes(cfg);
            }
            Some(Box::new(s))
        }
        "no-quant" => Some(Box::new(NoQuantScheduler)),
        "channel-allocate" => {
            Some(Box::new(ChannelAllocateScheduler::new(seed).with_threads(threads)))
        }
        "principle" => Some(Box::new(PrincipleScheduler::new())),
        "same-size" => Some(Box::new(SameSizeScheduler::new(seed).with_threads(threads))),
        _ => None,
    }
}

/// All algorithm names in the paper's figure order.
pub const ALL_ALGORITHMS: [&str; 5] =
    ["qccf", "no-quant", "channel-allocate", "principle", "same-size"];

/// Expand an algorithm-list spec: the keyword `all` →
/// [`ALL_ALGORITHMS`], otherwise a comma-separated list of names
/// (names are **not** validated here — scenario/sweep validation
/// reports unknown ones with context).
pub fn algorithm_list(spec: &str) -> Vec<String> {
    if spec == "all" {
        ALL_ALGORITHMS.iter().map(|s| s.to_string()).collect()
    } else {
        spec.split(',').map(|s| s.trim().to_string()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::tests::Fixture;

    #[test]
    fn factory_covers_all() {
        for name in ALL_ALGORITHMS {
            assert!(make_scheduler(name, 1).is_some(), "{name}");
        }
        assert!(make_scheduler("bogus", 1).is_none());
    }

    #[test]
    fn algorithm_list_expands_all_and_splits() {
        assert_eq!(algorithm_list("all"), ALL_ALGORITHMS.to_vec());
        assert_eq!(algorithm_list("qccf, same-size"), vec!["qccf", "same-size"]);
        assert_eq!(algorithm_list("typo"), vec!["typo"]); // validated downstream
    }

    #[test]
    fn no_quant_assigns_none_q() {
        let fx = Fixture::new(21);
        let inp = fx.inputs();
        let dec = NoQuantScheduler.decide(&inp);
        for d in dec.assignments.iter().flatten() {
            assert!(d.q.is_none());
            assert!(d.f >= fx.params.f_min && d.f <= fx.params.f_max);
        }
    }

    #[test]
    fn channel_allocate_uses_max_feasible_q() {
        let fx = Fixture::new(22);
        let inp = fx.inputs();
        let dec = ChannelAllocateScheduler::new(3).decide(&inp);
        let mut any = false;
        for (i, d) in dec.assignments.iter().enumerate() {
            if let Some(d) = d {
                any = true;
                let qmax =
                    crate::solver::q_max_feasible(&fx.params, fx.sizes[i], d.rate).unwrap();
                assert_eq!(d.q.unwrap(), qmax);
            }
        }
        assert!(any);
    }

    #[test]
    fn principle_q_rises_with_round_and_size() {
        let fx = Fixture::new(23);
        let mut sched = PrincipleScheduler::new();
        let mut inp = fx.inputs();
        inp.round = 1;
        let early = sched.decide(&inp);
        inp.round = 50;
        let late = sched.decide(&inp);
        let avg = |dec: &RoundDecision| -> f64 {
            let qs: Vec<f64> =
                dec.assignments.iter().flatten().map(|d| d.q.unwrap() as f64).collect();
            qs.iter().sum::<f64>() / qs.len() as f64
        };
        assert!(avg(&late) > avg(&early));
        // Proportional to size: the largest-D client gets ≥ the smallest's q.
        let (mut imax, mut imin) = (0, 0);
        for i in 1..10 {
            if fx.sizes[i] > fx.sizes[imax] {
                imax = i;
            }
            if fx.sizes[i] < fx.sizes[imin] {
                imin = i;
            }
        }
        if let (Some(a), Some(b)) = (&late.assignments[imax], &late.assignments[imin]) {
            assert!(a.q.unwrap() >= b.q.unwrap());
        }
    }

    #[test]
    fn same_size_equalizes_q_but_not_f() {
        let fx = Fixture::new(24);
        let inp = fx.inputs();
        let dec = SameSizeScheduler::new(5).decide(&inp);
        let qs: Vec<u32> = dec.assignments.iter().flatten().map(|d| d.q.unwrap()).collect();
        assert!(!qs.is_empty());
        // Equal-size assumption ⇒ near-identical q across clients
        // (channel rates still differ, so allow a small spread).
        let (qmin, qmax) = (qs.iter().min().unwrap(), qs.iter().max().unwrap());
        assert!(qmax - qmin <= 4, "q spread too wide: {qs:?}");
    }

    #[test]
    fn all_schedulers_produce_valid_channel_sets() {
        let fx = Fixture::new(25);
        let inp = fx.inputs();
        for name in ALL_ALGORITHMS {
            let mut s = make_scheduler(name, 9).unwrap();
            let dec = s.decide(&inp);
            let mut used = std::collections::BTreeSet::new();
            for d in dec.assignments.iter().flatten() {
                assert!(used.insert(d.channel), "{name}: duplicate channel");
            }
        }
    }
}
