//! Shared experiment runner. Since the scenario subsystem landed this
//! is a thin layer: [`RunSpec`] is a *preset* over the paper scenarios
//! ([`RunSpec::to_scenario`]), and every run — figure harness, `train`
//! subcommand, sweep — goes through [`run_scenario`], the one function
//! that turns a [`Scenario`] + (algorithm, seed) into a [`Trace`].

use anyhow::Result;

use crate::baselines::make_scheduler_with_threads;
use crate::config::SystemParams;
use crate::data;
use crate::fl::Server;
use crate::metrics::Trace;
use crate::runtime::Runtime;
use crate::scenario::{registry, Scenario};

/// Which Table-I column drives the wireless/compute constants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// γ = 1000, T^max = 0.02 s, V default 100.
    Femnist,
    /// γ = 2000, T^max = 0.05 s, V default 10.
    Cifar,
}

/// One experiment run, as the fig harnesses and the `train` subcommand
/// parameterize it. This is sugar: [`RunSpec::to_scenario`] maps it
/// onto the corresponding paper scenario and [`run_one`] executes that
/// scenario — there is no second run path.
#[derive(Clone, Debug)]
pub struct RunSpec {
    /// Scheduling algorithm (see `baselines`).
    pub algorithm: String,
    /// Which Table-I column (selects the paper scenario).
    pub task: Task,
    /// Communication rounds.
    pub rounds: usize,
    /// Lyapunov penalty weight V (None = task default).
    pub v: Option<f64>,
    /// β — dataset-size std (paper: 150 / 300).
    pub beta: f64,
    /// µ — dataset-size mean.
    pub mu: f64,
    /// Master seed.
    pub seed: u64,
    /// Evaluate every k rounds (0 = never).
    pub eval_every: usize,
    /// Worker threads for the round engine and GA fitness fan-out
    /// (`1` = legacy serial path; results are identical either way).
    pub threads: usize,
}

impl RunSpec {
    /// Paper defaults (40 rounds, µ = 1200, β = 150, eval every 2).
    pub fn new(algorithm: &str, task: Task) -> RunSpec {
        RunSpec {
            algorithm: algorithm.to_string(),
            task,
            rounds: 40,
            v: None,
            beta: 150.0,
            mu: 1200.0,
            seed: 1,
            eval_every: 2,
            threads: crate::util::threadpool::default_threads(),
        }
    }

    /// The scenario this spec denotes: the task's paper scenario with
    /// the spec's µ/β/V/rounds/eval cadence applied and the algorithm
    /// list narrowed to this run's algorithm.
    pub fn to_scenario(&self) -> Scenario {
        let mut sc = match self.task {
            Task::Femnist => registry::paper_femnist(),
            Task::Cifar => registry::paper_cifar10(),
        };
        sc.data.size_mean = self.mu;
        sc.data.size_std = self.beta;
        sc.train.v = self.v;
        sc.train.rounds = self.rounds;
        sc.train.eval_every = self.eval_every;
        sc.train.algorithms = vec![self.algorithm.clone()];
        sc
    }
}

/// Table-I parameters for `task`, adapted to the loaded profile's Z
/// (T^max scales with Z per the calibration note in `config`).
///
/// Equivalent to `spec.to_scenario().params_for_runtime(rt)` for a
/// default spec — kept public because examples/tests build servers
/// directly from it.
pub fn params_for(rt: &Runtime, task: Task, mu: f64) -> SystemParams {
    let mut sc = match task {
        Task::Femnist => Scenario::defaults("params-for", Task::Femnist),
        Task::Cifar => Scenario::defaults("params-for", Task::Cifar),
    };
    sc.data.size_mean = mu;
    sc.params_for_runtime(rt)
}

/// Run `algorithm` under `scenario` with `seed` on a loaded runtime —
/// the single execution path behind figures, `train`, and `sweep`.
/// `threads` is an engine knob, not part of the scenario: any value
/// (including 1) produces a bit-identical trace (PR-1 contract).
pub fn run_scenario(
    rt: &Runtime,
    scenario: &Scenario,
    algorithm: &str,
    seed: u64,
    threads: usize,
) -> Result<Trace> {
    let errs = scenario.validate();
    anyhow::ensure!(errs.is_empty(), "scenario `{}` invalid: {}", scenario.name, errs.join("; "));
    let params = scenario.params_for_runtime(rt);
    let dcfg = scenario.datagen(rt);
    let fed = data::generate(&dcfg, seed);
    let sched = make_scheduler_with_threads(
        algorithm,
        seed.wrapping_mul(31).wrapping_add(7),
        threads,
    )
    .ok_or_else(|| anyhow::anyhow!("unknown algorithm `{algorithm}`"))?;
    let mut server = Server::new(params, rt, fed, sched, seed)?;
    server.eval_every = scenario.train.eval_every;
    server.threads = threads;
    server.run(scenario.train.rounds)
}

/// Run one (algorithm, task, β, V, seed) experiment on a loaded runtime
/// — [`run_scenario`] over [`RunSpec::to_scenario`].
pub fn run_one(rt: &Runtime, spec: &RunSpec) -> Result<Trace> {
    run_scenario(rt, &spec.to_scenario(), &spec.algorithm, spec.seed, spec.threads)
}

/// Results directory (`$QCCF_RESULTS` or `./results`).
pub fn results_dir() -> std::path::PathBuf {
    std::env::var("QCCF_RESULTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("results"))
}
