//! Shared experiment runner. Since the scenario subsystem landed this
//! is a thin layer: [`RunSpec`] is a *preset* over the paper scenarios
//! ([`RunSpec::to_scenario`]), and every run — figure harness, `train`
//! subcommand, sweep — goes through [`run_scenario`], the one function
//! that turns a [`Scenario`] + (algorithm, seed) into a [`Trace`].

use std::path::PathBuf;

use anyhow::Result;

use crate::baselines::make_scheduler_with_classes;
use crate::ckpt::{self, Snapshot};
use crate::config::SystemParams;
use crate::data;
use crate::fl::Server;
use crate::metrics::Trace;
use crate::obs::spans::{Span, SpanGuard};
use crate::runtime::Runtime;
use crate::scenario::{registry, Scenario};

/// Which Table-I column drives the wireless/compute constants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// γ = 1000, T^max = 0.02 s, V default 100.
    Femnist,
    /// γ = 2000, T^max = 0.05 s, V default 10.
    Cifar,
}

/// One experiment run, as the fig harnesses and the `train` subcommand
/// parameterize it. This is sugar: [`RunSpec::to_scenario`] maps it
/// onto the corresponding paper scenario and [`run_one`] executes that
/// scenario — there is no second run path.
#[derive(Clone, Debug)]
pub struct RunSpec {
    /// Scheduling algorithm (see `baselines`).
    pub algorithm: String,
    /// Which Table-I column (selects the paper scenario).
    pub task: Task,
    /// Communication rounds.
    pub rounds: usize,
    /// Lyapunov penalty weight V (None = task default).
    pub v: Option<f64>,
    /// β — dataset-size std (paper: 150 / 300).
    pub beta: f64,
    /// µ — dataset-size mean.
    pub mu: f64,
    /// Master seed.
    pub seed: u64,
    /// Evaluate every k rounds (0 = never).
    pub eval_every: usize,
    /// Worker threads for the round engine and GA fitness fan-out
    /// (`1` = legacy serial path; results are identical either way).
    pub threads: usize,
}

impl RunSpec {
    /// Paper defaults (40 rounds, µ = 1200, β = 150, eval every 2).
    pub fn new(algorithm: &str, task: Task) -> RunSpec {
        RunSpec {
            algorithm: algorithm.to_string(),
            task,
            rounds: 40,
            v: None,
            beta: 150.0,
            mu: 1200.0,
            seed: 1,
            eval_every: 2,
            threads: crate::util::threadpool::default_threads(),
        }
    }

    /// The scenario this spec denotes: the task's paper scenario with
    /// the spec's µ/β/V/rounds/eval cadence applied and the algorithm
    /// list narrowed to this run's algorithm.
    pub fn to_scenario(&self) -> Scenario {
        let mut sc = match self.task {
            Task::Femnist => registry::paper_femnist(),
            Task::Cifar => registry::paper_cifar10(),
        };
        sc.data.size_mean = self.mu;
        sc.data.size_std = self.beta;
        sc.train.v = self.v;
        sc.train.rounds = self.rounds;
        sc.train.eval_every = self.eval_every;
        sc.train.algorithms = vec![self.algorithm.clone()];
        sc
    }
}

/// Table-I parameters for `task`, adapted to the loaded profile's Z
/// (T^max scales with Z per the calibration note in `config`).
///
/// Equivalent to `spec.to_scenario().params_for_runtime(rt)` for a
/// default spec — kept public because examples/tests build servers
/// directly from it.
pub fn params_for(rt: &Runtime, task: Task, mu: f64) -> SystemParams {
    let mut sc = match task {
        Task::Femnist => Scenario::defaults("params-for", Task::Femnist),
        Task::Cifar => Scenario::defaults("params-for", Task::Cifar),
    };
    sc.data.size_mean = mu;
    sc.params_for_runtime(rt)
}

/// Periodic-snapshot / resume policy for one run (the checkpoint
/// subsystem's run-path knobs; see `docs/CHECKPOINTS.md`). The default
/// — no snapshots, no resume — is exactly the historical
/// [`run_scenario`] behavior.
#[derive(Clone, Debug, Default)]
pub struct CheckpointPolicy {
    /// Write a snapshot after every N completed rounds (0 = never).
    /// The snapshot is atomically replaced in place, so `dir` always
    /// holds at most one — the latest — per run.
    pub every: usize,
    /// Directory snapshots are written into (required when `every > 0`;
    /// file name: [`ckpt::snapshot_file_name`]).
    pub dir: Option<PathBuf>,
    /// Resume from this snapshot before running any round. The
    /// snapshot's identity — resolved scenario (up to the horizon),
    /// algorithm, seed — must match the run ([`snapshot_mismatch`]);
    /// any mismatch is an error, not a silently diverging trace.
    pub resume: Option<PathBuf>,
    /// Also reinstall the snapshot's PJRT profiling clock on resume so
    /// `exec_profile` continues the original accounting. Only safe when
    /// the caller owns the [`Runtime`] exclusively (the `train`
    /// subcommand); the sweep leaves it off — its runtime is shared by
    /// every concurrent unit, and a restore would clobber their
    /// in-flight accounting. Purely cosmetic either way: the clock
    /// never feeds a decision, so trace bits are unaffected.
    pub restore_runtime_clock: bool,
}

/// Why a stored canonical scenario render does **not** match
/// `scenario` (`None` = it matches). Identity is the canonical render
/// of the resolved scenario with the horizon normalized away:
/// `train.rounds` is a run-*length* knob — resuming an interrupted run
/// under the full horizon, or extending a finished run to a longer
/// one, is exactly what snapshots are for — while every
/// physics/heterogeneity/eval knob must match bit for bit or the
/// resumed trace would silently diverge from the uninterrupted run.
/// Used by both snapshot resume ([`snapshot_mismatch`]) and the
/// sweep's per-scenario identity sidecars.
pub fn scenario_identity_mismatch(stored_text: &str, scenario: &Scenario) -> Option<String> {
    let mut stored = match crate::scenario::parse_scenario(stored_text) {
        Ok(sc) => sc,
        Err(e) => return Some(format!("stored scenario text unparseable: {e}")),
    };
    stored.train.rounds = scenario.train.rounds;
    if crate::scenario::render(&stored) != crate::scenario::render(scenario) {
        return Some(format!(
            "stored definition of scenario `{}` differs from the current `{}` beyond the \
             horizon (render both and diff them)",
            stored.name, scenario.name
        ));
    }
    None
}

/// The one resume-eligibility check, shared by [`run_scenario_ckpt`]
/// (which refuses with a hard error) and the sweep's snapshot probe
/// (which downgrades to a fresh restart): why `snap` cannot resume
/// `(scenario, algorithm, seed)` — algorithm/seed identity, scenario
/// identity up to the horizon, horizon bound, and trace/round
/// consistency. `None` = usable. Keeping this in one place means a
/// future refusal condition cannot be added to one caller and missed
/// by the other.
pub fn snapshot_mismatch(
    snap: &Snapshot,
    scenario: &Scenario,
    algorithm: &str,
    seed: u64,
) -> Option<String> {
    if snap.algorithm != algorithm {
        return Some(format!(
            "snapshot is for algorithm `{}`, not `{algorithm}`",
            snap.algorithm
        ));
    }
    if snap.seed != seed {
        return Some(format!("snapshot is for seed {}, not {seed}", snap.seed));
    }
    if let Some(why) = scenario_identity_mismatch(&snap.scenario_text, scenario) {
        return Some(why);
    }
    let rounds = scenario.train.rounds;
    if snap.state.round as usize > rounds {
        return Some(format!(
            "snapshot is at round {} but the scenario horizon is {rounds}",
            snap.state.round
        ));
    }
    if snap.trace.records.len() != snap.state.round as usize {
        return Some(format!(
            "snapshot trace has {} records for {} completed rounds",
            snap.trace.records.len(),
            snap.state.round
        ));
    }
    None
}

/// Run `algorithm` under `scenario` with `seed` on a loaded runtime —
/// the single execution path behind figures, `train`, and `sweep`.
/// `threads` is an engine knob, not part of the scenario: any value
/// (including 1) produces a bit-identical trace (PR-1 contract).
pub fn run_scenario(
    rt: &Runtime,
    scenario: &Scenario,
    algorithm: &str,
    seed: u64,
    threads: usize,
) -> Result<Trace> {
    run_scenario_ckpt(rt, scenario, algorithm, seed, threads, &CheckpointPolicy::default())
}

/// [`run_scenario`] with a [`CheckpointPolicy`]: optionally resumes
/// from a snapshot, then runs the remaining rounds, writing a snapshot
/// after every `policy.every` rounds (atomic tmp + fsync + rename).
///
/// Determinism contract (pinned by `tests/integration_ckpt.rs`): the
/// returned trace — resumed or not, at any `threads` value on either
/// side of the split — is **bit-identical** in every deterministic
/// field to the uninterrupted run's.
pub fn run_scenario_ckpt(
    rt: &Runtime,
    scenario: &Scenario,
    algorithm: &str,
    seed: u64,
    threads: usize,
    policy: &CheckpointPolicy,
) -> Result<Trace> {
    let errs = scenario.validate();
    anyhow::ensure!(errs.is_empty(), "scenario `{}` invalid: {}", scenario.name, errs.join("; "));
    anyhow::ensure!(
        policy.every == 0 || policy.dir.is_some(),
        "checkpoint cadence set ({} rounds) but no checkpoint directory given",
        policy.every
    );
    let params = scenario.params_for_runtime(rt);
    let dcfg = scenario.datagen(rt);
    let fed = data::generate(&dcfg, seed);
    // Scenario-gated class-based scheduling: only QCCF consumes the
    // request (and only outside the QCCF_DECISION_CLASSES=0 kill
    // switch — see sched::classes).
    let classes = scenario.train.classes.then(|| crate::sched::ClassingConfig {
        size_bins: scenario.train.class_size_bins,
        rate_bins: scenario.train.class_rate_bins,
    });
    let sched = make_scheduler_with_classes(
        algorithm,
        seed.wrapping_mul(31).wrapping_add(7),
        threads,
        classes,
    )
    .ok_or_else(|| anyhow::anyhow!("unknown algorithm `{algorithm}`"))?;
    let mut server = Server::new(params, rt, fed, sched, seed)?;
    server.eval_every = scenario.train.eval_every;
    server.threads = threads;
    // Scenario-gated churn: install the availability process *before*
    // any resume — restore_state requires the snapshot's availability
    // presence to match the server's (same-scenario resume guarantees
    // it), and the process is seeded from the run seed (salted
    // internally), independent of the scheduler stream.
    if scenario.train.churn {
        server.set_churn(
            crate::fl::avail::AvailCfg {
                p_join: scenario.train.p_join,
                p_leave: scenario.train.p_leave,
                over_select: scenario.train.over_select,
                staleness: scenario.train.staleness,
            },
            seed,
        );
    }
    // Scenario-gated chaos: same placement discipline as churn — the
    // fault plan must exist before any resume (restore_state requires
    // the snapshot's fault-state presence to match the server's), and
    // it is seeded from the run seed (salted internally), independent
    // of both the scheduler and availability streams.
    if scenario.train.chaos {
        server.set_faults(
            crate::fl::faults::FaultCfg {
                p_decode: scenario.train.chaos_decode,
                p_straggle: scenario.train.chaos_straggle,
                p_panic: scenario.train.chaos_panic,
                retries: scenario.train.chaos_retries as u32,
                p_ckpt: scenario.train.chaos_ckpt,
            },
            seed,
        );
    }

    // The resolved scenario is part of the snapshot's identity: resume
    // compares canonical renders, so *any* drifted knob — not just the
    // name — is a hard mismatch.
    let scenario_text = crate::scenario::render(scenario);
    let rounds = scenario.train.rounds;
    let mut trace = match &policy.resume {
        Some(path) => {
            let snap = Snapshot::load(path)?;
            if let Some(why) = snapshot_mismatch(&snap, scenario, algorithm, seed) {
                anyhow::bail!(
                    "refusing to resume from {} into a diverging run: {why}",
                    path.display()
                );
            }
            server.restore_state(&snap.state)?;
            if policy.restore_runtime_clock {
                rt.restore_exec_nanos(snap.state.runtime_nanos);
            }
            crate::info!(
                "ckpt",
                "resumed {}/{algorithm}/seed{seed} at round {}/{rounds}",
                scenario.name,
                snap.state.round
            );
            snap.trace
        }
        None => Trace::new(server.scheduler_name()),
    };

    let mut cum = trace.records.last().map(|r| r.cum_energy).unwrap_or(0.0);
    while server.round() < rounds {
        let mut rec = server.run_round()?;
        cum += rec.energy;
        rec.cum_energy = cum;
        trace.push(rec);
        if policy.every > 0 && server.round() % policy.every == 0 {
            let dir = policy.dir.as_ref().expect("checked above");
            // Chaos ckpt-corruption draw comes BEFORE capturing state so
            // the snapshot records the post-draw stream position: an
            // uninterrupted run and a resumed one replay the identical
            // corruption future (see fl::faults module docs).
            let corrupt = server.draw_ckpt_corrupt().unwrap_or(false);
            // Normalize the side-channel wall-clock columns out of the
            // snapshot's trace: they are CSV-only profiler readings
            // (outside the bit-identity contract), and carrying them
            // would make snapshot bytes vary run-to-run and across
            // QCCF_OBS settings (pinned by tests/integration_obs.rs).
            // A resumed run's CSV therefore shows zeros for pre-resume
            // rounds' wall columns; every deterministic field is exact.
            let mut snap_trace = trace.clone();
            for r in &mut snap_trace.records {
                r.decide_seconds = 0.0;
                r.compute_seconds = 0.0;
            }
            let snap = Snapshot {
                scenario_text: scenario_text.clone(),
                algorithm: algorithm.to_string(),
                seed,
                state: server.checkpoint_state(),
                trace: snap_trace,
            };
            let path = dir.join(ckpt::snapshot_file_name(&scenario.name, algorithm, seed));
            // Span-profiled at the call site so the `ckpt` module stays
            // obs-free (detlint R7); the guard covers rotation + encode
            // + atomic write.
            let ckpt_span = SpanGuard::enter(Span::CheckpointWrite);
            // Keep the previous snapshot as `<name>.prev` — the
            // recovery ladder's middle rung when the latest write is
            // corrupted (docs/FAULTS.md). Rename failure (e.g. no
            // previous snapshot yet) is not an error.
            if path.exists() {
                let mut prev_name = path
                    .file_name()
                    .map(|n| n.to_os_string())
                    .unwrap_or_default();
                prev_name.push(".prev");
                let _ = std::fs::rename(&path, path.with_file_name(prev_name));
            }
            snap.save(&path)?;
            drop(ckpt_span);
            if corrupt {
                // Injected fault: flip one payload byte after the write
                // lands, exactly the torn/bit-rotted file the CRC
                // envelope exists to catch. Loaders see CkptError::Crc.
                let mut bytes = std::fs::read(&path)?;
                let mid = bytes.len() / 2;
                if let Some(b) = bytes.get_mut(mid) {
                    *b ^= 0x01;
                }
                crate::util::fsio::write_atomic(&path, &bytes)?;
                crate::warn_log!(
                    "chaos",
                    "corrupted snapshot write at round {} -> {}",
                    server.round(),
                    path.display()
                );
            }
            crate::debug_log!(
                "ckpt",
                "snapshot at round {}/{} -> {}",
                server.round(),
                rounds,
                path.display()
            );
        }
    }
    Ok(trace)
}

/// Run one (algorithm, task, β, V, seed) experiment on a loaded runtime
/// — [`run_scenario`] over [`RunSpec::to_scenario`].
pub fn run_one(rt: &Runtime, spec: &RunSpec) -> Result<Trace> {
    run_scenario(rt, &spec.to_scenario(), &spec.algorithm, spec.seed, spec.threads)
}

/// Results directory (`$QCCF_RESULTS` or `./results`).
pub fn results_dir() -> std::path::PathBuf {
    std::env::var("QCCF_RESULTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("results"))
}
