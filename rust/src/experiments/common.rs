//! Shared experiment runner: profile → SystemParams mapping, federation
//! generation, server construction, trace capture.

use anyhow::Result;

use crate::baselines::make_scheduler_with_threads;
use crate::config::SystemParams;
use crate::data::{self, DataGenConfig};
use crate::fl::Server;
use crate::metrics::Trace;
use crate::runtime::Runtime;

/// Which Table-I column drives the wireless/compute constants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// γ = 1000, T^max = 0.02 s, V default 100.
    Femnist,
    /// γ = 2000, T^max = 0.05 s, V default 10.
    Cifar,
}

/// One experiment run.
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub algorithm: String,
    pub task: Task,
    pub rounds: usize,
    /// Lyapunov penalty weight V (None = task default).
    pub v: Option<f64>,
    /// β — dataset-size std (paper: 150 / 300).
    pub beta: f64,
    /// µ — dataset-size mean.
    pub mu: f64,
    pub seed: u64,
    pub eval_every: usize,
    /// Worker threads for the round engine and GA fitness fan-out
    /// (`1` = legacy serial path; results are identical either way).
    pub threads: usize,
}

impl RunSpec {
    pub fn new(algorithm: &str, task: Task) -> RunSpec {
        RunSpec {
            algorithm: algorithm.to_string(),
            task,
            rounds: 40,
            v: None,
            beta: 150.0,
            mu: 1200.0,
            seed: 1,
            eval_every: 2,
            threads: crate::util::threadpool::default_threads(),
        }
    }
}

/// Table-I parameters for `task`, adapted to the loaded profile's Z
/// (T^max scales with Z per the calibration note in `config`).
pub fn params_for(rt: &Runtime, task: Task, mu: f64) -> SystemParams {
    let mut p = match task {
        Task::Femnist => SystemParams::femnist_small(),
        Task::Cifar => SystemParams::cifar_small(),
    };
    let z_ref = p.z;
    p.z = rt.info.z;
    p.t_max *= rt.info.z as f64 / z_ref as f64;
    // Keep computation inside the scaled budget: T^max must leave head
    // room for τ^e γ µ / f^max (matters for the tiny test profile).
    let t_cmp_min = p.tau_e as f64 * p.gamma * mu / p.f_max;
    if p.t_max < 2.0 * t_cmp_min {
        p.t_max = 2.0 * t_cmp_min;
    }
    p.eta = rt.info.lr;
    p
}

/// Run one (algorithm, task, β, V, seed) experiment on a loaded runtime.
pub fn run_one(rt: &Runtime, spec: &RunSpec) -> Result<Trace> {
    let mut params = params_for(rt, spec.task, spec.mu);
    if let Some(v) = spec.v {
        params.v = v;
    }
    let mut dcfg = DataGenConfig::new(params.num_clients, rt.info.image, rt.info.classes);
    dcfg.size_mean = spec.mu;
    dcfg.size_std = spec.beta;
    let fed = data::generate(&dcfg, spec.seed);
    let sched = make_scheduler_with_threads(
        &spec.algorithm,
        spec.seed.wrapping_mul(31).wrapping_add(7),
        spec.threads,
    )
    .ok_or_else(|| anyhow::anyhow!("unknown algorithm `{}`", spec.algorithm))?;
    let mut server = Server::new(params, rt, fed, sched, spec.seed)?;
    server.eval_every = spec.eval_every;
    server.threads = spec.threads;
    server.run(spec.rounds)
}

/// Results directory (`$QCCF_RESULTS` or `./results`).
pub fn results_dir() -> std::path::PathBuf {
    std::env::var("QCCF_RESULTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("results"))
}
