//! Fig. 2 — trade-off between energy consumption and FL performance:
//! sweep the Lyapunov penalty weight V and report final accuracy and
//! accumulated energy of QCCF (paper: both descend as V grows).
//!
//! A thin preset over the `paper-femnist`/`paper-cifar10` scenarios:
//! each grid point is a [`RunSpec`] routed through
//! [`super::common::run_scenario`].

use anyhow::Result;

use super::common::{results_dir, run_one, RunSpec, Task};
use crate::runtime::Runtime;
use crate::util::csv::CsvWriter;
use crate::util::table;

/// One V grid point's outcome.
#[derive(Clone, Debug)]
pub struct Fig2Row {
    /// The Lyapunov weight V of this run.
    pub v: f64,
    /// Last observed test accuracy.
    pub final_acc: f64,
    /// Best test accuracy over the run.
    pub best_acc: f64,
    /// Accumulated energy (J).
    pub cum_energy: f64,
}

/// Run QCCF once per V value; each run's full trace also lands in CSV.
pub fn run(rt: &Runtime, task: Task, v_values: &[f64], rounds: usize, seed: u64) -> Result<Vec<Fig2Row>> {
    let mut rows = Vec::new();
    for &v in v_values {
        let mut spec = RunSpec::new("qccf", task);
        spec.rounds = rounds;
        spec.v = Some(v);
        spec.seed = seed;
        let trace = run_one(rt, &spec)?;
        rows.push(Fig2Row {
            v,
            final_acc: trace.final_accuracy().unwrap_or(f64::NAN),
            best_acc: trace.best_accuracy().unwrap_or(f64::NAN),
            cum_energy: trace.total_energy(),
        });
        let path = results_dir().join(format!("fig2_{:?}_v{v}.csv", task)).with_extension("csv");
        trace.write_csv(&path)?;
    }
    Ok(rows)
}

/// Print the V grid as a table.
pub fn print(rows: &[Fig2Row]) {
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                table::fnum(r.v),
                format!("{:.4}", r.final_acc),
                format!("{:.4}", r.best_acc),
                table::fnum(r.cum_energy),
            ]
        })
        .collect();
    println!("Fig. 2 — QCCF accuracy / accumulated energy vs V");
    println!("{}", table::render(&["V", "final acc", "best acc", "energy (J)"], &body));
}

/// Write the grid summary CSV into the results directory.
pub fn write_summary(rows: &[Fig2Row], task: Task) -> Result<()> {
    let path = results_dir().join(format!("fig2_{task:?}_summary.csv"));
    let mut w = CsvWriter::create(&path, &["v", "final_acc", "best_acc", "cum_energy_j"])?;
    for r in rows {
        w.row_f64(&[r.v, r.final_acc, r.best_acc, r.cum_energy])?;
    }
    w.flush()?;
    println!("wrote {}", path.display());
    Ok(())
}
