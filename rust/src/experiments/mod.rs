//! Experiment harness: regenerates every figure of the paper's §VI
//! (see DESIGN.md §6 for the experiment index and EXPERIMENTS.md for
//! recorded paper-vs-measured outcomes), plus the scenario sweep
//! runner. Every harness funnels into [`common::run_scenario`] — the
//! figures are *presets* over the paper scenarios of
//! [`crate::scenario::registry`], not a separate code path.
//!
//! * [`fig2`] — V trade-off (accuracy & accumulated energy vs V);
//! * [`fig3`] — FEMNIST-sim: accuracy + energy, 5 algorithms, β ∈ {150, 300};
//! * [`fig4`] — CIFAR-sim: same grid under the CIFAR wireless column;
//! * [`fig5`] — quantization-level dynamics (vs round, vs dataset size);
//! * [`sweep`] — scenarios × seeds × algorithms, fanned out in
//!   parallel, JSONL + CSV traces per run.

pub mod ablate;
pub mod common;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod sweep;

pub use common::{run_one, run_scenario, run_scenario_ckpt, CheckpointPolicy, RunSpec, Task};
