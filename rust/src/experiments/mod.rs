//! Experiment harness: regenerates every figure of the paper's §VI
//! (see DESIGN.md §6 for the experiment index and EXPERIMENTS.md for
//! recorded paper-vs-measured outcomes).
//!
//! * [`fig2`] — V trade-off (accuracy & accumulated energy vs V);
//! * [`fig3`] — FEMNIST-sim: accuracy + energy, 5 algorithms, β ∈ {150, 300};
//! * [`fig4`] — CIFAR-sim: same grid under the CIFAR wireless column;
//! * [`fig5`] — quantization-level dynamics (vs round, vs dataset size).

pub mod ablate;
pub mod common;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;

pub use common::{run_one, RunSpec, Task};
