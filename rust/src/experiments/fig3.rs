//! Fig. 3 — FEMNIST-sim: test-accuracy and accumulated-energy curves for
//! all five algorithms under β ∈ {150, 300}. The paper's headline
//! comparisons (QCCF fastest convergence, lowest energy; Principle
//! stalls late from large-D dropouts; Same-Size degrades with β) are the
//! *shapes* this harness regenerates.

use anyhow::Result;

use super::common::{results_dir, run_one, RunSpec, Task};
use crate::baselines::ALL_ALGORITHMS;
use crate::metrics::Trace;
use crate::runtime::Runtime;
use crate::util::csv::CsvWriter;
use crate::util::table;

/// One (algorithm, β) grid cell's outcome.
#[derive(Clone, Debug)]
pub struct AlgRow {
    /// Scheduling algorithm.
    pub algorithm: String,
    /// β — dataset-size std of the run.
    pub beta: f64,
    /// Last observed test accuracy.
    pub final_acc: f64,
    /// Best test accuracy over the run.
    pub best_acc: f64,
    /// Accumulated energy (J).
    pub cum_energy: f64,
    /// Total dropouts (scheduled − aggregated).
    pub dropouts: usize,
    /// Rounds until accuracy first reached 0.5 (convergence speed).
    pub rounds_to_half: Option<usize>,
}

/// Reduce a trace to its grid-cell row.
pub fn summarize(trace: &Trace, beta: f64) -> AlgRow {
    AlgRow {
        algorithm: trace.algorithm.clone(),
        beta,
        final_acc: trace.final_accuracy().unwrap_or(f64::NAN),
        best_acc: trace.best_accuracy().unwrap_or(f64::NAN),
        cum_energy: trace.total_energy(),
        dropouts: trace.total_dropouts(),
        rounds_to_half: trace.rounds_to_accuracy(0.5),
    }
}

/// Run every algorithm × β cell (a preset over the task's paper
/// scenario); each cell's full trace also lands in CSV under `tag`.
pub fn run_grid(
    rt: &Runtime,
    task: Task,
    betas: &[f64],
    rounds: usize,
    seed: u64,
    tag: &str,
) -> Result<Vec<AlgRow>> {
    let mut rows = Vec::new();
    for &beta in betas {
        for alg in ALL_ALGORITHMS {
            let mut spec = RunSpec::new(alg, task);
            spec.rounds = rounds;
            spec.beta = beta;
            spec.seed = seed;
            let trace = run_one(rt, &spec)?;
            let path = results_dir().join(format!("{tag}_{alg}_beta{beta}.csv"));
            trace.write_csv(&path)?;
            rows.push(summarize(&trace, beta));
            crate::info!(
                "fig",
                "{tag}: {alg} β={beta} acc={:.3} energy={:.4} J dropouts={}",
                rows.last().unwrap().best_acc,
                rows.last().unwrap().cum_energy,
                rows.last().unwrap().dropouts
            );
        }
    }
    Ok(rows)
}

/// Print the grid plus the paper's headline energy-savings comparison.
pub fn print(rows: &[AlgRow], title: &str) {
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.algorithm.clone(),
                format!("{}", r.beta),
                format!("{:.4}", r.final_acc),
                format!("{:.4}", r.best_acc),
                table::fnum(r.cum_energy),
                r.dropouts.to_string(),
                r.rounds_to_half.map(|n| n.to_string()).unwrap_or_else(|| "-".into()),
            ]
        })
        .collect();
    println!("{title}");
    println!(
        "{}",
        table::render(
            &["algorithm", "beta", "final acc", "best acc", "energy (J)", "dropouts", "rounds→0.5"],
            &body
        )
    );
    // Paper's headline numbers: energy savings of QCCF vs the two
    // published baselines (48.21% vs Principle, 35.42% vs Same-Size).
    let find = |alg: &str, beta: f64| rows.iter().find(|r| r.algorithm == alg && r.beta == beta);
    let betas: Vec<f64> = {
        let mut b: Vec<f64> = rows.iter().map(|r| r.beta).collect();
        b.dedup();
        b
    };
    for beta in betas {
        if let (Some(q), Some(p), Some(s)) =
            (find("qccf", beta), find("principle", beta), find("same-size", beta))
        {
            println!(
                "β={beta}: QCCF energy savings vs principle {:.2}% (paper: 48.21%), vs same-size {:.2}% (paper: 35.42%)",
                (1.0 - q.cum_energy / p.cum_energy) * 100.0,
                (1.0 - q.cum_energy / s.cum_energy) * 100.0,
            );
        }
    }
}

/// Write the grid summary CSV into the results directory.
pub fn write_summary(rows: &[AlgRow], tag: &str) -> Result<()> {
    let path = results_dir().join(format!("{tag}_summary.csv"));
    let mut w = CsvWriter::create(
        &path,
        &["algorithm", "beta", "final_acc", "best_acc", "cum_energy_j", "dropouts"],
    )?;
    for r in rows {
        w.row(&[
            r.algorithm.clone(),
            format!("{}", r.beta),
            format!("{:.6}", r.final_acc),
            format!("{:.6}", r.best_acc),
            format!("{:.9}", r.cum_energy),
            r.dropouts.to_string(),
        ])?;
    }
    w.flush()?;
    println!("wrote {}", path.display());
    Ok(())
}
