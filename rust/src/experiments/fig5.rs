//! Fig. 5 — the doubly adaptive quantization level itself:
//!
//! * (a) mean q per round for the four quantizing algorithms — QCCF /
//!   Principle / Same-Size rise with the training process,
//!   Channel-Allocate stays flat (channel statistics don't drift);
//! * (b) per-client mean q against dataset size D_i — negative
//!   correlation for QCCF and Channel-Allocate (Remark 2), positive for
//!   Principle, flat for Same-Size.

use anyhow::Result;

use super::common::{results_dir, run_one, RunSpec, Task};
use crate::metrics::Trace;
use crate::runtime::Runtime;
use crate::util::csv::CsvWriter;
use crate::util::table;

/// Quantizing algorithms shown in Fig. 5 (no-quant has no q).
pub const QUANTIZING: [&str; 4] = ["qccf", "channel-allocate", "principle", "same-size"];

/// One algorithm's quantization-level series.
#[derive(Clone, Debug)]
pub struct Fig5Data {
    /// Scheduling algorithm.
    pub algorithm: String,
    /// (round, mean q) series — Fig. 5(a).
    pub q_by_round: Vec<(usize, f64)>,
    /// (D_i, mean q of client i) — Fig. 5(b).
    pub q_by_size: Vec<(f64, f64)>,
}

/// Pearson correlation (the Fig. 5b "negatively correlated" check).
pub fn correlation(xy: &[(f64, f64)]) -> f64 {
    let n = xy.len() as f64;
    if n < 2.0 {
        return f64::NAN;
    }
    let mx = xy.iter().map(|p| p.0).sum::<f64>() / n;
    let my = xy.iter().map(|p| p.1).sum::<f64>() / n;
    let (mut sxy, mut sxx, mut syy) = (0.0f64, 0.0f64, 0.0f64);
    for &(x, y) in xy {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    sxy / (sxx.sqrt() * syy.sqrt()).max(1e-12)
}

fn per_client_mean_q(trace: &Trace, sizes: &[f64]) -> Vec<(f64, f64)> {
    let u = sizes.len();
    let mut sum = vec![0.0f64; u];
    let mut cnt = vec![0usize; u];
    for rec in &trace.records {
        for (i, q) in rec.q_per_client.iter().enumerate() {
            if let Some(q) = q {
                if *q > 0 {
                    sum[i] += *q as f64;
                    cnt[i] += 1;
                }
            }
        }
    }
    (0..u)
        .filter(|&i| cnt[i] > 0)
        .map(|i| (sizes[i], sum[i] / cnt[i] as f64))
        .collect()
}

/// Run the four quantizing algorithms over several seeds: the level
/// trajectory is averaged pointwise, and the (D_i, q̄_i) cloud pools all
/// seeds — with only U = 10 clients a single placement can alias client
/// distance with D_i and fake a correlation, so Remark-2 verdicts need
/// several independent placements.
pub fn run(rt: &Runtime, rounds: usize, seeds: &[u64]) -> Result<Vec<Fig5Data>> {
    let mut out = Vec::new();
    for alg in QUANTIZING {
        let mut traj_sum: Vec<(usize, f64, usize)> = Vec::new();
        let mut cloud: Vec<(f64, f64)> = Vec::new();
        for &seed in seeds {
            let mut spec = RunSpec::new(alg, Task::Femnist);
            spec.rounds = rounds;
            spec.seed = seed;
            spec.eval_every = 0; // Fig. 5 only needs decisions, not accuracy
            let trace = run_one(rt, &spec)?;
            for (round, q) in trace.q_trajectory() {
                match traj_sum.iter_mut().find(|(r, _, _)| *r == round) {
                    Some((_, sum, n)) => {
                        *sum += q;
                        *n += 1;
                    }
                    None => traj_sum.push((round, q, 1)),
                }
            }
            // Recover the D_i of this run (same data seed ⇒ same
            // sizes) through the run's own scenario, so this stays in
            // lock-step with whatever `run_one` generated.
            let sizes = crate::data::generate(&spec.to_scenario().datagen(rt), seed).sizes();
            cloud.extend(per_client_mean_q(&trace, &sizes));
        }
        traj_sum.sort_by_key(|(r, _, _)| *r);
        out.push(Fig5Data {
            algorithm: alg.to_string(),
            q_by_round: traj_sum.into_iter().map(|(r, s, n)| (r, s / n as f64)).collect(),
            q_by_size: cloud,
        });
    }
    Ok(out)
}

/// Print the level trajectory and the Remark-2 correlation verdicts.
pub fn print(data: &[Fig5Data]) {
    println!("Fig. 5(a) — mean quantization level vs communication round");
    let mut body = Vec::new();
    for d in data {
        let first = d.q_by_round.first().map(|p| p.1).unwrap_or(f64::NAN);
        let mid = d.q_by_round.get(d.q_by_round.len() / 2).map(|p| p.1).unwrap_or(f64::NAN);
        let last = d.q_by_round.last().map(|p| p.1).unwrap_or(f64::NAN);
        body.push(vec![
            d.algorithm.clone(),
            format!("{first:.2}"),
            format!("{mid:.2}"),
            format!("{last:.2}"),
            format!("{:+.2}", last - first),
        ]);
    }
    println!(
        "{}",
        table::render(&["algorithm", "q(start)", "q(mid)", "q(end)", "Δq"], &body)
    );

    println!("Fig. 5(b) — quantization level vs dataset size (Pearson r)");
    let mut body = Vec::new();
    for d in data {
        let r = correlation(&d.q_by_size);
        let verdict = if r < -0.2 {
            "negative (Remark 2)"
        } else if r > 0.2 {
            "positive"
        } else {
            "flat"
        };
        body.push(vec![d.algorithm.clone(), format!("{r:+.3}"), verdict.to_string()]);
    }
    println!("{}", table::render(&["algorithm", "corr(q, D_i)", "verdict"], &body));
}

/// Write the (a)/(b) series CSVs into the results directory.
pub fn write_csv(data: &[Fig5Data]) -> Result<()> {
    let dir = results_dir();
    let mut w = CsvWriter::create(dir.join("fig5a_q_by_round.csv"), &["algorithm", "round", "mean_q"])?;
    for d in data {
        for &(round, q) in &d.q_by_round {
            w.row(&[d.algorithm.clone(), round.to_string(), format!("{q:.4}")])?;
        }
    }
    w.flush()?;
    let mut w = CsvWriter::create(dir.join("fig5b_q_by_size.csv"), &["algorithm", "d_i", "mean_q"])?;
    for d in data {
        for &(size, q) in &d.q_by_size {
            w.row(&[d.algorithm.clone(), format!("{size}"), format!("{q:.4}")])?;
        }
    }
    w.flush()?;
    println!("wrote {} and fig5b_q_by_size.csv", dir.join("fig5a_q_by_round.csv").display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn correlation_signs() {
        let pos: Vec<(f64, f64)> = (0..20).map(|i| (i as f64, 2.0 * i as f64 + 1.0)).collect();
        let neg: Vec<(f64, f64)> = (0..20).map(|i| (i as f64, -0.5 * i as f64)).collect();
        assert!(correlation(&pos) > 0.99);
        assert!(correlation(&neg) < -0.99);
        let flat: Vec<(f64, f64)> = (0..20).map(|i| (i as f64, 3.0)).collect();
        assert!(correlation(&flat).abs() < 0.5);
    }
}
