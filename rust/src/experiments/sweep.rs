//! Parallel **sweep runner**: cross-product scenarios × seeds ×
//! algorithms, fan the independent runs out over the thread pool, and
//! write structured traces (JSONL per run + one summary CSV) to an
//! output directory.
//!
//! # Determinism
//!
//! Each unit runs with engine `threads = 1` and the sweep parallelizes
//! *across* units; since a single run is bit-identical for any engine
//! thread count (the PR-1 contract) and each unit owns its output file,
//! the bytes under `--out` are identical for any sweep `--threads`
//! value. Unit order — and with it `summary.csv` row order — is the
//! deterministic (scenario, algorithm, seed) nesting of [`expand`].
//!
//! # Resume
//!
//! Sweeps are **preemption-safe** ([`SweepConfig::resume`]): every
//! output file is replaced atomically (tmp + fsync + rename — a torn
//! `summary.csv` or JSONL trace cannot exist), and `summary.csv` is
//! rewritten after *every* completed unit, so a resumed sweep can
//! trust what it finds and a kill forfeits at most the in-flight
//! units. Triples already recorded in `summary.csv` (with their trace
//! file present and the round count matching) are skipped outright —
//! guarded by per-scenario **identity sidecars** (`<name>.scenario`,
//! the canonical render): a scenario whose definition drifted since
//! the recorded run has its triples re-run, not silently carried.
//! Interrupted runs restart from their latest snapshot under
//! `<out>/ckpt/` when [`SweepConfig::checkpoint_every`] wrote one
//! (bit-identical restart, the `ckpt` contract), and from round 0
//! otherwise. A corrupt latest snapshot falls back to the previous
//! one (`<name>.qckpt.prev`, kept by the run path's rotation) and
//! then to a fresh restart — the recovery ladder of `docs/FAULTS.md`.
//! The final `summary.csv` is identical either way.
//!
//! # Unit isolation
//!
//! A unit that **panics** (an engine bug, or `fl::faults` chaos with
//! `chaos_panic > 0`) is caught per unit (`catch_unwind`): it becomes
//! a `failed` row in `summary.csv` and the fleet keeps draining. Only
//! after every unit has completed does the sweep return an error
//! naming the poisoned units (non-zero process exit). On a later
//! `--resume`, `failed` rows re-run — only `ok` rows are skipped.
//!
//! # Observability
//!
//! Per unit the sweep also writes a **sketch sidecar**
//! (`<stem>.sketch.json`, [`crate::obs::sketch`]) — deterministic, a
//! pure function of the trace, covered by the bytes-identical contract
//! above — and appends one line to the run **ledger**
//! (`ledger.jsonl`, [`crate::obs::ledger`]): unit identity, status,
//! per-stage span totals and wall duration. The ledger is a
//! completion-ordered wall-clock journal, so it is the one file under
//! `--out` *excluded* from the bytes-identical contract (exactly like
//! the train CSV's wall columns — see docs/OBSERVABILITY.md). The
//! `report` subcommand aggregates summary + ledger + sidecars without
//! rereading any per-round trace.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::ckpt;
use crate::metrics::Trace;
use crate::obs::{ledger, sketch, spans, wall};
use crate::runtime::Runtime;
use crate::scenario::Scenario;
use crate::util::csv::CsvWriter;
use crate::util::json;
use crate::util::table;
use crate::util::threadpool;

use super::common::{run_scenario_ckpt, CheckpointPolicy};

/// What to sweep: the cross product of `scenarios × seeds ×` (each
/// scenario's algorithm list, unless overridden).
pub struct SweepConfig {
    /// Scenarios to run (built-ins and/or file-loaded).
    pub scenarios: Vec<Scenario>,
    /// Master seeds; every (scenario, algorithm) pair runs once per
    /// seed.
    pub seeds: Vec<u64>,
    /// When set, overrides every scenario's own algorithm list.
    pub algorithms: Option<Vec<String>>,
    /// When set, overrides every scenario's round count (the `--quick`
    /// smoke path).
    pub rounds: Option<usize>,
    /// Output directory for the JSONL traces and `summary.csv`.
    pub out_dir: PathBuf,
    /// Sweep-level worker threads (how many *runs* execute at once).
    pub threads: usize,
    /// Skip (scenario, algorithm, seed) triples already completed in
    /// `summary.csv`, and restart interrupted runs from their latest
    /// snapshot under `<out>/ckpt/` (see the module docs).
    pub resume: bool,
    /// Per-run snapshot cadence in rounds (0 = no snapshots): what
    /// makes an interrupted long run resumable mid-horizon instead of
    /// from round 0.
    pub checkpoint_every: usize,
}

/// One completed run's summary row.
#[derive(Clone, Debug)]
pub struct SweepRow {
    /// Scenario name.
    pub scenario: String,
    /// Algorithm name.
    pub algorithm: String,
    /// Master seed of the run.
    pub seed: u64,
    /// Rounds executed.
    pub rounds: usize,
    /// Last observed test accuracy (NaN if evaluation was off).
    pub final_acc: f64,
    /// Best test accuracy (NaN if evaluation was off).
    pub best_acc: f64,
    /// Accumulated energy (J).
    pub cum_energy: f64,
    /// Total realized bytes on the wire across the run (the byte
    /// transport's physical payload; `ceil(eq. (5)/8)` per quantized
    /// upload).
    pub wire_bytes: u64,
    /// Total dropouts (scheduled − aggregated).
    pub dropouts: usize,
    /// Total clients scheduled across the run (participation
    /// denominator for churn scenarios).
    pub scheduled: usize,
    /// Total uploads aggregated across the run.
    pub aggregated: usize,
    /// Total mid-round departures (churn; 0 otherwise).
    pub departed: usize,
    /// Total retransmission attempts beyond the first (chaos; 0
    /// otherwise).
    pub retries: usize,
    /// Median per-round energy (J), read off the unit's deterministic
    /// sketch ([`crate::obs::sketch`]; NaN for a failed unit).
    pub energy_p50: f64,
    /// 95th-percentile per-round energy (J), same sketch (NaN for a
    /// failed unit).
    pub energy_p95: f64,
    /// `"ok"` for a completed unit, `"failed"` for one whose run
    /// panicked or errored (caught per unit; see the module docs).
    /// Failed rows carry zero/NaN metrics and re-run on `--resume`.
    pub status: String,
    /// Where the JSONL trace was written.
    pub trace_path: PathBuf,
}

/// Expand the cross product into concrete (scenario, algorithm, seed)
/// units, applying the config's rounds/algorithms overrides. The
/// nesting order (scenarios, then algorithms, then seeds) is the
/// deterministic unit order of the whole sweep.
pub fn expand(cfg: &SweepConfig) -> Vec<(Scenario, String, u64)> {
    let mut units = Vec::new();
    for base in &cfg.scenarios {
        let mut sc = base.clone();
        if let Some(r) = cfg.rounds {
            sc.train.rounds = r;
        }
        let algorithms =
            cfg.algorithms.clone().unwrap_or_else(|| sc.train.algorithms.clone());
        for alg in &algorithms {
            for &seed in &cfg.seeds {
                units.push((sc.clone(), alg.clone(), seed));
            }
        }
    }
    units
}

/// Everything wrong with a sweep config: per-scenario validation,
/// duplicate names (trace paths derive from the name — a duplicate
/// would have two parallel workers writing the same file), and the
/// algorithm/round overrides (applied per unit in [`expand`], so they
/// must be checked before any run starts, not after the valid units
/// already executed). Empty = good.
pub fn config_errors(cfg: &SweepConfig) -> Vec<String> {
    let mut errs = Vec::new();
    if cfg.scenarios.is_empty() {
        errs.push("no scenarios selected".into());
    }
    if cfg.seeds.is_empty() {
        errs.push("no seeds given".into());
    }
    // Every (scenario, algorithm, seed) unit owns one trace file, so
    // any duplicated cross-product axis would race two workers on the
    // same path — reject them all up front.
    let mut seen_seeds = std::collections::BTreeSet::new();
    for &seed in &cfg.seeds {
        if !seen_seeds.insert(seed) {
            errs.push(format!("--seeds: seed {seed} given twice"));
        }
        // Seeds are recorded as JSON numbers in the traces; past 2^53
        // the f64 round-trip would silently record a different seed.
        if seed >= (1u64 << 53) {
            errs.push(format!(
                "--seeds: seed {seed} exceeds 2^53 and would lose precision in the \
                 JSONL trace metadata"
            ));
        }
    }
    if let Some(algorithms) = &cfg.algorithms {
        if algorithms.is_empty() {
            errs.push("--algorithms: empty override".into());
        }
        let mut seen = std::collections::BTreeSet::new();
        for alg in algorithms {
            if !seen.insert(alg.as_str()) {
                errs.push(format!("--algorithms: `{alg}` given twice"));
            }
            if !crate::baselines::ALL_ALGORITHMS.contains(&alg.as_str()) {
                errs.push(format!(
                    "--algorithms: unknown algorithm `{alg}` (known: {})",
                    crate::baselines::ALL_ALGORITHMS.join(", ")
                ));
            }
        }
    }
    let mut seen_names = std::collections::BTreeSet::new();
    for sc in &cfg.scenarios {
        if !seen_names.insert(sc.name.as_str()) {
            errs.push(format!(
                "{}: selected twice (scenario names must be unique within a sweep)",
                sc.name
            ));
        }
        for e in sc.validate() {
            errs.push(format!("{}: {e}", sc.name));
        }
    }
    if cfg.rounds == Some(0) {
        errs.push("--rounds: must be at least 1".into());
    }
    errs
}

/// The canonical JSONL/snapshot file stem of one (scenario, algorithm,
/// seed) unit — the shared [`ckpt::unit_stem`] definition.
pub fn unit_stem(scenario: &str, algorithm: &str, seed: u64) -> String {
    ckpt::unit_stem(scenario, algorithm, seed)
}

/// The rotated-previous sibling of a snapshot path (`<name>.prev`,
/// written by the run path before each replacement).
fn prev_snapshot_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".prev");
    path.with_file_name(name)
}

/// A unit's best resumable snapshot under `ckpt_dir`, if one exists
/// *and* is loadable *and* matches the unit's resolved
/// scenario/horizon. The **recovery ladder** (docs/FAULTS.md): try the
/// latest snapshot, then the rotated previous one (`<name>.qckpt.prev`
/// — a few rounds staler but bit-identical to replay), then restart
/// fresh. Every downgrade warns; resuming a sweep must never be
/// blocked by one damaged file.
fn usable_snapshot(ckpt_dir: &Path, sc: &Scenario, alg: &str, seed: u64) -> Option<PathBuf> {
    let latest = ckpt_dir.join(ckpt::snapshot_file_name(&sc.name, alg, seed));
    let prev = prev_snapshot_path(&latest);
    for path in [latest, prev] {
        if !path.exists() {
            continue;
        }
        match ckpt::Snapshot::load(&path) {
            // The same eligibility rules the hard-refusing run path
            // applies (`common::snapshot_mismatch`) — shared so a
            // future refusal condition cannot be added there and missed
            // here, where it would abort the whole sweep instead of
            // restarting one unit.
            Ok(snap) => match super::common::snapshot_mismatch(&snap, sc, alg, seed) {
                None => return Some(path),
                Some(why) => {
                    crate::warn_log!(
                        "sweep",
                        "snapshot {}: {why} — trying the next recovery rung",
                        path.display()
                    );
                }
            },
            Err(e) => {
                crate::warn_log!(
                    "sweep",
                    "unreadable snapshot {}: {e:#} — trying the next recovery rung",
                    path.display()
                );
            }
        }
    }
    None
}

/// Run the sweep. Fails fast on an invalid config — scenarios,
/// duplicate names, and overrides are all checked via
/// [`config_errors`] before any run starts; a failing *run* aborts the
/// sweep with its unit named. Returns one row per unit in [`expand`]
/// order. With [`SweepConfig::resume`], completed triples are carried
/// over from the existing `summary.csv` instead of re-running.
pub fn run(rt: &Runtime, cfg: &SweepConfig) -> Result<Vec<SweepRow>> {
    let all_errs = config_errors(cfg);
    anyhow::ensure!(all_errs.is_empty(), "invalid sweep:\n  {}", all_errs.join("\n  "));

    std::fs::create_dir_all(&cfg.out_dir)?;
    let ckpt_dir = cfg.out_dir.join("ckpt");
    let units = expand(cfg);

    let mut prior: Vec<SweepRow> =
        if cfg.resume { read_summary(&cfg.out_dir)? } else { Vec::new() };
    if !cfg.resume {
        // A fresh (non-resume) sweep re-produces every row, so any
        // prior summary is stale the moment we start. Dropping it
        // *before* the identity sidecars are rewritten below keeps the
        // invariant that summary.csv rows are always backed by the
        // recorded scenario identity — a kill between the sidecar
        // rewrite and the first completed unit must not leave old rows
        // under fresh sidecars for a later `--resume` to trust.
        std::fs::remove_file(cfg.out_dir.join("summary.csv")).ok();
    }

    // Scenario-identity sidecars: summary.csv rows carry only the
    // scenario *name*, so `--resume` verifies content identity against
    // the canonical render written next to the traces (`<name>.scenario`,
    // horizon-normalized like the snapshot check). A drifted definition
    // makes its triples stale instead of silently carrying results
    // produced under different physics. Order matters for crash
    // safety: detect against the *old* sidecars first, make the pruned
    // summary durable, and only then record the new identities — a
    // kill anywhere in between must never leave old rows on disk under
    // fresh sidecars for a later `--resume` to trust. A missing
    // sidecar (a pre-sidecar output dir) is trusted as-is.
    let mut resolved: Vec<Scenario> = Vec::new();
    let mut stale: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
    for base in &cfg.scenarios {
        let mut sc = base.clone();
        if let Some(r) = cfg.rounds {
            sc.train.rounds = r;
        }
        let sidecar = cfg.out_dir.join(format!("{}.scenario", sc.name));
        if cfg.resume && sidecar.exists() {
            match std::fs::read_to_string(&sidecar) {
                Ok(text) => {
                    if let Some(why) = super::common::scenario_identity_mismatch(&text, &sc) {
                        crate::warn_log!(
                            "sweep",
                            "{}: {why} — its prior results are stale and will re-run",
                            sc.name
                        );
                        stale.insert(sc.name.clone());
                    }
                }
                Err(e) => {
                    crate::warn_log!(
                        "sweep",
                        "{}: unreadable scenario sidecar {}: {e} — treating prior results \
                         as stale",
                        sc.name,
                        sidecar.display()
                    );
                    stale.insert(sc.name.clone());
                }
            }
        }
        resolved.push(sc);
    }
    if !stale.is_empty() {
        prior.retain(|r| !stale.contains(&r.scenario));
        write_summary(&prior, &cfg.out_dir)?;
    }
    for sc in &resolved {
        let sidecar = cfg.out_dir.join(format!("{}.scenario", sc.name));
        crate::util::fsio::write_atomic(&sidecar, crate::scenario::render(sc).as_bytes())?;
    }

    // Resume bookkeeping: a triple counts as complete when the prior
    // summary row exists (and survived the staleness prune), it is an
    // `ok` row (`failed` units re-run — that is the whole point of
    // recording them), its trace file is still on disk, and its round
    // count matches this sweep's (a changed --rounds override makes
    // the old run stale, not reusable). Rows for triples *outside* this
    // sweep's cross product
    // (a narrower resume: fewer scenarios/seeds/algorithms) are
    // carried through every summary rewrite untouched — resuming a
    // subset must not delete the rest of the record.
    let unit_keys: std::collections::BTreeSet<(String, String, u64)> = units
        .iter()
        .map(|(sc, alg, seed)| (sc.name.clone(), alg.clone(), *seed))
        .collect();
    let mut done: BTreeMap<(String, String, u64), SweepRow> = BTreeMap::new();
    let mut carried: Vec<SweepRow> = Vec::new();
    for row in prior {
        let key = (row.scenario.clone(), row.algorithm.clone(), row.seed);
        if unit_keys.contains(&key) {
            done.insert(key, row);
        } else {
            carried.push(row);
        }
    }
    let mut slots: Vec<Option<SweepRow>> = Vec::with_capacity(units.len());
    let mut pending: Vec<(usize, &(Scenario, String, u64))> = Vec::new();
    for (i, unit) in units.iter().enumerate() {
        let (sc, alg, seed) = unit;
        let key = (sc.name.clone(), alg.clone(), *seed);
        match done.get(&key) {
            Some(row)
                if row.status == "ok"
                    && row.rounds == sc.train.rounds
                    && row.trace_path.exists() =>
            {
                slots.push(Some(row.clone()));
            }
            _ => {
                slots.push(None);
                pending.push((i, unit));
            }
        }
    }
    crate::info!(
        "sweep",
        "{} runs ({} scenarios x algorithms x {} seeds), {} already complete, {} to run, \
         {} worker thread(s), out {}",
        units.len(),
        cfg.scenarios.len(),
        cfg.seeds.len(),
        units.len() - pending.len(),
        pending.len(),
        cfg.threads.max(1),
        cfg.out_dir.display()
    );
    let slots = std::sync::Mutex::new(slots);
    // Heartbeat state (satellite of docs/OBSERVABILITY.md): one info
    // line per completed unit with done/total and a monotonic-clock ETA
    // — side-channel wall time, confined to the log.
    let to_run = pending.len();
    let completed = std::sync::atomic::AtomicUsize::new(0);
    let sweep_wall = wall::Stopwatch::start();
    let git_stamp = ledger::git_describe();
    // Record one finished unit — ok or failed — and make the summary
    // durable *immediately*, not at sweep end, so a kill mid-sweep
    // forfeits at most the in-flight units on resume. The lock also
    // serializes the atomic rewrite's shared tmp file.
    let record = |i: usize, row: SweepRow| -> Result<()> {
        let mut slots = slots.lock().unwrap();
        let done = completed.fetch_add(1, std::sync::atomic::Ordering::Relaxed) + 1;
        let elapsed = sweep_wall.elapsed_secs();
        let eta = elapsed / done as f64 * to_run.saturating_sub(done) as f64;
        crate::info!(
            "sweep",
            "unit {done}/{to_run} {} ({}/{}/seed{}) — elapsed {elapsed:.1}s, eta ~{eta:.1}s",
            row.status,
            row.scenario,
            row.algorithm,
            row.seed
        );
        slots[i] = Some(row);
        let mut so_far: Vec<SweepRow> = slots.iter().flatten().cloned().collect();
        so_far.extend(carried.iter().cloned());
        write_summary(&so_far, &cfg.out_dir)
    };
    let results: Vec<Result<()>> =
        threadpool::parallel_map(&pending, cfg.threads.max(1), |_, &(i, (sc, alg, seed))| {
            let policy = CheckpointPolicy {
                every: cfg.checkpoint_every,
                dir: (cfg.checkpoint_every > 0).then(|| ckpt_dir.clone()),
                resume: if cfg.resume {
                    usable_snapshot(&ckpt_dir, sc, alg, *seed)
                } else {
                    None
                },
                // The runtime is shared by every concurrent unit —
                // restoring one snapshot's clock would clobber the
                // others' in-flight accounting.
                restore_runtime_clock: false,
            };
            let path = cfg.out_dir.join(format!("{}.jsonl", unit_stem(&sc.name, alg, *seed)));
            // Unit-scoped observability: drain any stale thread-local
            // span shadow, then open the sweep-unit span — units run
            // with engine threads = 1, so every stage span of this unit
            // lands on this pool thread and `local_take` below reads
            // out exactly this unit's totals for its ledger line.
            let _ = spans::local_take();
            let unit_wall = wall::Stopwatch::start();
            let unit_span = spans::SpanGuard::enter(spans::Span::SweepUnit);
            // Per-unit isolation: a panicking unit (an engine bug, or
            // `fl::faults` chaos) must not take the fleet down. Catch
            // it here, record a `failed` row, and keep draining; the
            // sweep errors only after every unit has run. The borrowed
            // state is sound to reuse after a caught panic: the unit
            // only *reads* rt/sc and its partial outputs (trace file,
            // snapshot) are replaced atomically or re-run on resume.
            let unit = std::panic::AssertUnwindSafe(|| -> Result<(Trace, sketch::TraceSketches)> {
                let trace = run_scenario_ckpt(rt, sc, alg, *seed, 1, &policy)?;
                trace
                    .write_jsonl(
                        &path,
                        &[
                            ("scenario", json::s(&sc.name)),
                            ("algorithm", json::s(alg)),
                            ("seed", json::num(*seed as f64)),
                        ],
                    )
                    .map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))?;
                // Deterministic sketch sidecar next to the trace — a
                // pure function of the trace, so a resumed unit
                // reproduces it bit for bit.
                let sketches = sketch::TraceSketches::from_trace(&trace);
                sketches.save(&sketch::sidecar_path(&path)).map_err(|e| {
                    anyhow::anyhow!("write sketch sidecar for {}: {e}", path.display())
                })?;
                Ok((trace, sketches))
            });
            let caught = std::panic::catch_unwind(unit);
            drop(unit_span);
            let span_totals = spans::local_take();
            let mut entry = ledger::LedgerEntry {
                kind: "sweep-unit".to_string(),
                scenario: sc.name.clone(),
                algorithm: alg.clone(),
                seed: *seed,
                rounds: 0,
                status: "failed".to_string(),
                wall_secs: unit_wall.elapsed_secs(),
                threads: 1,
                spans: span_totals,
                sketch_digests: BTreeMap::new(),
                git: git_stamp.clone(),
            };
            let why = match caught {
                Ok(Ok((trace, sketches))) => {
                    entry.rounds = trace.records.len();
                    entry.status = "ok".to_string();
                    entry.sketch_digests = sketches
                        .digests()
                        .into_iter()
                        .map(|(k, d)| (k.to_string(), d))
                        .collect();
                    append_ledger(&cfg.out_dir, &entry);
                    record(i, summarize(&trace, &sketches, sc, alg, *seed, path))?;
                    // Only after the summary row is durable is the
                    // snapshot stale — dropping it earlier would leave
                    // a killed-right-here unit with neither artifact.
                    let snap = ckpt_dir.join(ckpt::snapshot_file_name(&sc.name, alg, *seed));
                    std::fs::remove_file(prev_snapshot_path(&snap)).ok();
                    std::fs::remove_file(snap).ok();
                    return Ok(());
                }
                Ok(Err(e)) => format!("{e:#}"),
                Err(payload) => format!("panicked: {}", panic_message(&payload)),
            };
            append_ledger(&cfg.out_dir, &entry);
            crate::warn_log!("sweep", "{}/{alg}/seed{seed} failed: {why}", sc.name);
            record(i, failed_row(sc, alg, *seed, path))?;
            Err(anyhow::anyhow!("{}/{alg}/seed{seed}: {why}", sc.name))
        });
    let failures: Vec<String> =
        results.into_iter().filter_map(|r| r.err()).map(|e| format!("{e:#}")).collect();
    let rows: Vec<SweepRow> = slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|s| s.expect("every unit completed, failed, or carried over"))
        .collect();
    let mut all_rows = rows.clone();
    all_rows.extend(carried);
    write_summary(&all_rows, &cfg.out_dir)?;
    // The grid has fully drained; only now does a poisoned unit turn
    // into a non-zero exit (the per-unit isolation contract).
    anyhow::ensure!(
        failures.is_empty(),
        "{} of {} runs failed (recorded as `failed` rows in summary.csv; they re-run on \
         --resume):\n  {}",
        failures.len(),
        units.len(),
        failures.join("\n  ")
    );
    Ok(rows)
}

/// Best-effort ledger append: the run ledger is a side-channel journal
/// (like the wall-clock CSV columns), so a failed append warns and
/// must never fail the unit it describes.
fn append_ledger(dir: &Path, entry: &ledger::LedgerEntry) {
    if let Err(e) = ledger::append(dir, entry) {
        crate::warn_log!("sweep", "ledger append under {} failed: {e}", dir.display());
    }
}

/// Human-readable panic payload (panics carry `&str` or `String` in
/// practice; anything else gets a placeholder).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// The `failed` summary row for a poisoned unit: identity columns
/// filled, metrics zero/NaN, `status = "failed"` — so `--resume` knows
/// to re-run exactly this triple.
fn failed_row(sc: &Scenario, alg: &str, seed: u64, path: PathBuf) -> SweepRow {
    SweepRow {
        scenario: sc.name.clone(),
        algorithm: alg.to_string(),
        seed,
        rounds: 0,
        final_acc: f64::NAN,
        best_acc: f64::NAN,
        cum_energy: 0.0,
        wire_bytes: 0,
        dropouts: 0,
        scheduled: 0,
        aggregated: 0,
        departed: 0,
        retries: 0,
        energy_p50: f64::NAN,
        energy_p95: f64::NAN,
        status: "failed".to_string(),
        trace_path: path,
    }
}

fn summarize(
    trace: &Trace,
    sketches: &sketch::TraceSketches,
    sc: &Scenario,
    alg: &str,
    seed: u64,
    path: PathBuf,
) -> SweepRow {
    SweepRow {
        scenario: sc.name.clone(),
        algorithm: alg.to_string(),
        seed,
        rounds: trace.records.len(),
        final_acc: trace.final_accuracy().unwrap_or(f64::NAN),
        best_acc: trace.best_accuracy().unwrap_or(f64::NAN),
        cum_energy: trace.total_energy(),
        wire_bytes: trace.total_wire_bytes(),
        dropouts: trace.total_dropouts(),
        scheduled: trace.total_scheduled(),
        aggregated: trace.total_aggregated(),
        departed: trace.total_departed(),
        retries: trace.total_retries(),
        energy_p50: sketches.energy.quantile(0.50),
        energy_p95: sketches.energy.quantile(0.95),
        status: "ok".to_string(),
        trace_path: path,
    }
}

/// `summary.csv` column set, shared by [`write_summary`] and
/// [`read_summary`] so the resume path can never drift from the writer.
const SUMMARY_COLUMNS: [&str; 17] = [
    "scenario",
    "algorithm",
    "seed",
    "rounds",
    "final_acc",
    "best_acc",
    "cum_energy_j",
    "wire_bytes",
    "dropouts",
    "scheduled",
    "aggregated",
    "departed",
    "retries",
    "energy_p50_j",
    "energy_p95_j",
    "status",
    "trace_file",
];

/// Write `summary.csv` (one row per run, unit order) into `out_dir` —
/// **atomically** (tmp + fsync + rename), so an interrupted sweep
/// leaves either the previous complete summary or the new one, never a
/// torn file for `--resume` to misread.
pub fn write_summary(rows: &[SweepRow], out_dir: &std::path::Path) -> Result<()> {
    let path = out_dir.join("summary.csv");
    crate::util::fsio::replace_atomic(&path, |tmp| {
        let mut w = CsvWriter::create(tmp, &SUMMARY_COLUMNS)?;
        for r in rows {
            w.row(&[
                r.scenario.clone(),
                r.algorithm.clone(),
                r.seed.to_string(),
                r.rounds.to_string(),
                format!("{:.6}", r.final_acc),
                format!("{:.6}", r.best_acc),
                format!("{:.9}", r.cum_energy),
                r.wire_bytes.to_string(),
                r.dropouts.to_string(),
                r.scheduled.to_string(),
                r.aggregated.to_string(),
                r.departed.to_string(),
                r.retries.to_string(),
                format!("{:.9}", r.energy_p50),
                format!("{:.9}", r.energy_p95),
                r.status.clone(),
                r.trace_path
                    .file_name()
                    .map(|f| f.to_string_lossy().into_owned())
                    .unwrap_or_default(),
            ])?;
        }
        w.flush()
    })?;
    Ok(())
}

/// Parse an existing `summary.csv` back into rows (empty when the file
/// does not exist) — the `--resume` path's source of truth for which
/// triples already completed. Trace paths are re-anchored under
/// `out_dir`. No cell [`write_summary`] emits ever needs CSV escaping
/// (scenario names are restricted to `[A-Za-z0-9._-]`, algorithm names
/// are fixed, numbers are numbers), so a plain comma split is exact; a
/// foreign or incompatible file is a descriptive error, not a silent
/// empty resume.
pub fn read_summary(out_dir: &std::path::Path) -> Result<Vec<SweepRow>> {
    let path = out_dir.join("summary.csv");
    if !path.exists() {
        return Ok(Vec::new());
    }
    let text = std::fs::read_to_string(&path)?;
    let mut lines = text.lines();
    let header = lines.next().unwrap_or("");
    anyhow::ensure!(
        header == SUMMARY_COLUMNS.join(","),
        "{}: unrecognized header `{header}` — not a sweep summary (or one from an \
         incompatible version)",
        path.display()
    );
    let mut rows = Vec::new();
    for (idx, line) in lines.enumerate() {
        let lineno = idx + 2;
        if line.trim().is_empty() {
            continue;
        }
        let cells: Vec<&str> = line.split(',').collect();
        anyhow::ensure!(
            cells.len() == SUMMARY_COLUMNS.len(),
            "{}: line {lineno}: {} cells, expected {}",
            path.display(),
            cells.len(),
            SUMMARY_COLUMNS.len()
        );
        let bad = |what: &str, v: &str| {
            anyhow::anyhow!("{}: line {lineno}: bad {what} `{v}`", path.display())
        };
        rows.push(SweepRow {
            scenario: cells[0].to_string(),
            algorithm: cells[1].to_string(),
            seed: cells[2].parse().map_err(|_| bad("seed", cells[2]))?,
            rounds: cells[3].parse().map_err(|_| bad("rounds", cells[3]))?,
            final_acc: cells[4].parse().map_err(|_| bad("final_acc", cells[4]))?,
            best_acc: cells[5].parse().map_err(|_| bad("best_acc", cells[5]))?,
            cum_energy: cells[6].parse().map_err(|_| bad("cum_energy_j", cells[6]))?,
            wire_bytes: cells[7].parse().map_err(|_| bad("wire_bytes", cells[7]))?,
            dropouts: cells[8].parse().map_err(|_| bad("dropouts", cells[8]))?,
            scheduled: cells[9].parse().map_err(|_| bad("scheduled", cells[9]))?,
            aggregated: cells[10].parse().map_err(|_| bad("aggregated", cells[10]))?,
            departed: cells[11].parse().map_err(|_| bad("departed", cells[11]))?,
            retries: cells[12].parse().map_err(|_| bad("retries", cells[12]))?,
            energy_p50: cells[13].parse().map_err(|_| bad("energy_p50_j", cells[13]))?,
            energy_p95: cells[14].parse().map_err(|_| bad("energy_p95_j", cells[14]))?,
            status: match cells[15] {
                "ok" | "failed" => cells[15].to_string(),
                other => return Err(bad("status", other)),
            },
            trace_path: out_dir.join(cells[16]),
        });
    }
    Ok(rows)
}

/// Print the run summaries as a table.
pub fn print(rows: &[SweepRow]) {
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.scenario.clone(),
                r.algorithm.clone(),
                r.seed.to_string(),
                r.rounds.to_string(),
                format!("{:.4}", r.final_acc),
                format!("{:.4}", r.best_acc),
                table::fnum(r.cum_energy),
                table::fnum(r.wire_bytes as f64),
                r.dropouts.to_string(),
                r.departed.to_string(),
                r.status.clone(),
            ]
        })
        .collect();
    println!("sweep — one row per (scenario, algorithm, seed) run");
    println!(
        "{}",
        table::render(
            &[
                "scenario",
                "algorithm",
                "seed",
                "rounds",
                "final acc",
                "best acc",
                "energy (J)",
                "wire (B)",
                "dropouts",
                "departed",
                "status"
            ],
            &body
        )
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::registry;

    fn cfg(scenarios: Vec<Scenario>) -> SweepConfig {
        SweepConfig {
            scenarios,
            seeds: vec![1, 2],
            algorithms: None,
            rounds: None,
            out_dir: PathBuf::from("/tmp/unused"),
            threads: 1,
            resume: false,
            checkpoint_every: 0,
        }
    }

    #[test]
    fn expand_cross_products_in_deterministic_order() {
        let mut c = cfg(vec![registry::paper_femnist(), registry::zipf_skew()]);
        c.algorithms = Some(vec!["qccf".into()]);
        let units = expand(&c);
        // 2 scenarios x 1 algorithm x 2 seeds.
        assert_eq!(units.len(), 4);
        let keys: Vec<(String, String, u64)> =
            units.iter().map(|(s, a, z)| (s.name.clone(), a.clone(), *z)).collect();
        assert_eq!(keys[0], ("paper-femnist".into(), "qccf".into(), 1));
        assert_eq!(keys[1], ("paper-femnist".into(), "qccf".into(), 2));
        assert_eq!(keys[2], ("zipf-skew".into(), "qccf".into(), 1));
        assert_eq!(keys[3], ("zipf-skew".into(), "qccf".into(), 2));
    }

    #[test]
    fn expand_uses_scenario_algorithms_and_round_override() {
        let mut c = cfg(vec![registry::zipf_skew()]);
        c.rounds = Some(2);
        let units = expand(&c);
        // zipf-skew declares two algorithms.
        assert_eq!(units.len(), 2 * 2);
        assert!(units.iter().all(|(s, _, _)| s.train.rounds == 2));
        let algs: Vec<&str> = units.iter().map(|(_, a, _)| a.as_str()).collect();
        assert!(algs.contains(&"qccf") && algs.contains(&"same-size"));
    }

    #[test]
    fn config_errors_catch_duplicates_and_bad_overrides() {
        let good = cfg(vec![registry::paper_femnist(), registry::zipf_skew()]);
        assert!(config_errors(&good).is_empty(), "{:?}", config_errors(&good));

        // Duplicate names would race on the same trace file.
        let dup = cfg(vec![registry::zipf_skew(), registry::zipf_skew()]);
        assert!(config_errors(&dup).iter().any(|e| e.contains("selected twice")));

        // Overrides are validated up front, not per unit mid-sweep.
        let mut bad_alg = cfg(vec![registry::paper_femnist()]);
        bad_alg.algorithms = Some(vec!["qccf".into(), "typo".into()]);
        assert!(config_errors(&bad_alg).iter().any(|e| e.contains("unknown algorithm `typo`")));
        let mut zero_rounds = cfg(vec![registry::paper_femnist()]);
        zero_rounds.rounds = Some(0);
        assert!(config_errors(&zero_rounds).iter().any(|e| e.contains("--rounds")));
        let mut empty = cfg(vec![]);
        empty.seeds = vec![];
        let errs = config_errors(&empty);
        assert!(errs.iter().any(|e| e.contains("no scenarios")));
        assert!(errs.iter().any(|e| e.contains("no seeds")));

        // Duplicate seeds or override algorithms would race two units
        // on the same trace path; huge seeds lose f64 precision in the
        // JSONL metadata.
        let mut dup_seed = cfg(vec![registry::paper_femnist()]);
        dup_seed.seeds = vec![1, 2, 1];
        assert!(config_errors(&dup_seed).iter().any(|e| e.contains("seed 1 given twice")));
        let mut dup_alg = cfg(vec![registry::paper_femnist()]);
        dup_alg.algorithms = Some(vec!["qccf".into(), "qccf".into()]);
        assert!(config_errors(&dup_alg).iter().any(|e| e.contains("given twice")));
        let mut big_seed = cfg(vec![registry::paper_femnist()]);
        big_seed.seeds = vec![1u64 << 53];
        assert!(config_errors(&big_seed).iter().any(|e| e.contains("2^53")));
        let mut dup_in_scenario = cfg(vec![registry::paper_femnist()]);
        dup_in_scenario.scenarios[0].train.algorithms = vec!["qccf".into(), "qccf".into()];
        assert!(config_errors(&dup_in_scenario)
            .iter()
            .any(|e| e.contains("listed twice")));
    }

    #[test]
    fn summary_csv_shape() {
        let rows = vec![SweepRow {
            scenario: "s".into(),
            algorithm: "qccf".into(),
            seed: 1,
            rounds: 2,
            final_acc: 0.5,
            best_acc: 0.6,
            cum_energy: 1.25,
            wire_bytes: 4242,
            dropouts: 0,
            scheduled: 20,
            aggregated: 20,
            departed: 0,
            retries: 0,
            energy_p50: 0.625,
            energy_p95: 0.75,
            status: "ok".into(),
            trace_path: PathBuf::from("x/s__qccf__seed1.jsonl"),
        }];
        let dir = std::env::temp_dir().join("qccf_sweep_summary_test");
        std::fs::create_dir_all(&dir).unwrap();
        write_summary(&rows, &dir).unwrap();
        let text = std::fs::read_to_string(dir.join("summary.csv")).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.lines().next().unwrap().starts_with("scenario,algorithm,seed"));
        assert!(text.contains("s__qccf__seed1.jsonl"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn summary_round_trips_through_read_summary() {
        // The --resume source of truth: write_summary → read_summary
        // must reproduce every row (NaN accuracies included — a run
        // with eval off writes "NaN", which must parse back as NaN and
        // still count as completed).
        let rows = vec![
            SweepRow {
                scenario: "paper-femnist".into(),
                algorithm: "qccf".into(),
                seed: 1,
                rounds: 12,
                final_acc: 0.5,
                best_acc: 0.625,
                cum_energy: 1.25,
                wire_bytes: 4242,
                dropouts: 3,
                scheduled: 120,
                aggregated: 117,
                departed: 2,
                retries: 5,
                energy_p50: 0.105,
                energy_p95: 0.12,
                status: "ok".into(),
                trace_path: PathBuf::from("ignored/paper-femnist__qccf__seed1.jsonl"),
            },
            SweepRow {
                scenario: "zipf-skew".into(),
                algorithm: "same-size".into(),
                seed: 9,
                rounds: 2,
                final_acc: f64::NAN,
                best_acc: f64::NAN,
                cum_energy: 0.5,
                wire_bytes: 0,
                dropouts: 0,
                scheduled: 8,
                aggregated: 8,
                departed: 0,
                retries: 0,
                energy_p50: f64::NAN,
                energy_p95: f64::NAN,
                status: "failed".into(),
                trace_path: PathBuf::from("ignored/zipf-skew__same-size__seed9.jsonl"),
            },
        ];
        let dir = std::env::temp_dir().join("qccf_sweep_read_summary_test");
        std::fs::create_dir_all(&dir).unwrap();
        write_summary(&rows, &dir).unwrap();
        let back = read_summary(&dir).unwrap();
        assert_eq!(back.len(), 2);
        for (a, b) in rows.iter().zip(&back) {
            assert_eq!(a.scenario, b.scenario);
            assert_eq!(a.algorithm, b.algorithm);
            assert_eq!(a.seed, b.seed);
            assert_eq!(a.rounds, b.rounds);
            assert_eq!(a.wire_bytes, b.wire_bytes);
            assert_eq!(a.dropouts, b.dropouts);
            assert_eq!(a.scheduled, b.scheduled);
            assert_eq!(a.aggregated, b.aggregated);
            assert_eq!(a.departed, b.departed);
            assert_eq!(a.retries, b.retries);
            assert_eq!(a.status, b.status);
            assert!(
                (a.final_acc == b.final_acc) || (a.final_acc.is_nan() && b.final_acc.is_nan())
            );
            assert!(
                (a.energy_p50 == b.energy_p50)
                    || (a.energy_p50.is_nan() && b.energy_p50.is_nan())
            );
            assert!(
                (a.energy_p95 == b.energy_p95)
                    || (a.energy_p95.is_nan() && b.energy_p95.is_nan())
            );
            // Trace paths are re-anchored under the summary's directory.
            assert_eq!(
                b.trace_path,
                dir.join(a.trace_path.file_name().unwrap())
            );
        }
        // Missing file = empty resume set, not an error.
        let empty = std::env::temp_dir().join("qccf_sweep_read_summary_missing");
        std::fs::create_dir_all(&empty).unwrap();
        assert!(read_summary(&empty).unwrap().is_empty());
        // A foreign CSV is a descriptive error, not a silent skip-all.
        std::fs::write(empty.join("summary.csv"), "a,b,c\n1,2,3\n").unwrap();
        assert!(read_summary(&empty).is_err());
        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&empty).ok();
    }

    #[test]
    fn unit_stem_matches_trace_and_snapshot_naming() {
        assert_eq!(unit_stem("deep-fade", "qccf", 7), "deep-fade__qccf__seed7");
        assert_eq!(
            crate::ckpt::snapshot_file_name("deep-fade", "qccf", 7),
            format!("{}.qckpt", unit_stem("deep-fade", "qccf", 7))
        );
        let snap = PathBuf::from("ckpt/deep-fade__qccf__seed7.qckpt");
        assert_eq!(
            prev_snapshot_path(&snap),
            PathBuf::from("ckpt/deep-fade__qccf__seed7.qckpt.prev")
        );
    }

    #[test]
    fn failed_rows_parse_back_and_reject_junk_status() {
        let sc = registry::chaos_panic();
        let row = failed_row(&sc, "qccf", 3, PathBuf::from("x/chaos-panic__qccf__seed3.jsonl"));
        assert_eq!(row.status, "failed");
        assert_eq!(row.rounds, 0);
        assert!(row.final_acc.is_nan() && row.best_acc.is_nan());
        let dir = std::env::temp_dir().join("qccf_sweep_failed_row_test");
        std::fs::create_dir_all(&dir).unwrap();
        write_summary(&[row], &dir).unwrap();
        let back = read_summary(&dir).unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].status, "failed");
        // A status cell outside {ok, failed} is a descriptive error,
        // not a silently trusted resume record.
        let text = std::fs::read_to_string(dir.join("summary.csv")).unwrap();
        std::fs::write(dir.join("summary.csv"), text.replace("failed", "maybe")).unwrap();
        let err = read_summary(&dir).unwrap_err().to_string();
        assert!(err.contains("bad status"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn panic_messages_unwrap_common_payloads() {
        let p: Box<dyn std::any::Any + Send> = Box::new("static str panic");
        assert_eq!(panic_message(&*p), "static str panic");
        let p: Box<dyn std::any::Any + Send> = Box::new(String::from("owned panic"));
        assert_eq!(panic_message(&*p), "owned panic");
        let p: Box<dyn std::any::Any + Send> = Box::new(42u32);
        assert_eq!(panic_message(&*p), "non-string panic payload");
    }
}
