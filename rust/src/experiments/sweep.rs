//! Parallel **sweep runner**: cross-product scenarios × seeds ×
//! algorithms, fan the independent runs out over the thread pool, and
//! write structured traces (JSONL per run + one summary CSV) to an
//! output directory.
//!
//! # Determinism
//!
//! Each unit runs with engine `threads = 1` and the sweep parallelizes
//! *across* units; since a single run is bit-identical for any engine
//! thread count (the PR-1 contract) and each unit owns its output file,
//! the bytes under `--out` are identical for any sweep `--threads`
//! value. Unit order — and with it `summary.csv` row order — is the
//! deterministic (scenario, algorithm, seed) nesting of [`expand`].

use std::path::PathBuf;

use anyhow::Result;

use crate::metrics::Trace;
use crate::runtime::Runtime;
use crate::scenario::Scenario;
use crate::util::csv::CsvWriter;
use crate::util::json;
use crate::util::table;
use crate::util::threadpool;

use super::common::run_scenario;

/// What to sweep: the cross product of `scenarios × seeds ×` (each
/// scenario's algorithm list, unless overridden).
pub struct SweepConfig {
    /// Scenarios to run (built-ins and/or file-loaded).
    pub scenarios: Vec<Scenario>,
    /// Master seeds; every (scenario, algorithm) pair runs once per
    /// seed.
    pub seeds: Vec<u64>,
    /// When set, overrides every scenario's own algorithm list.
    pub algorithms: Option<Vec<String>>,
    /// When set, overrides every scenario's round count (the `--quick`
    /// smoke path).
    pub rounds: Option<usize>,
    /// Output directory for the JSONL traces and `summary.csv`.
    pub out_dir: PathBuf,
    /// Sweep-level worker threads (how many *runs* execute at once).
    pub threads: usize,
}

/// One completed run's summary row.
#[derive(Clone, Debug)]
pub struct SweepRow {
    /// Scenario name.
    pub scenario: String,
    /// Algorithm name.
    pub algorithm: String,
    /// Master seed of the run.
    pub seed: u64,
    /// Rounds executed.
    pub rounds: usize,
    /// Last observed test accuracy (NaN if evaluation was off).
    pub final_acc: f64,
    /// Best test accuracy (NaN if evaluation was off).
    pub best_acc: f64,
    /// Accumulated energy (J).
    pub cum_energy: f64,
    /// Total realized bytes on the wire across the run (the byte
    /// transport's physical payload; `ceil(eq. (5)/8)` per quantized
    /// upload).
    pub wire_bytes: u64,
    /// Total dropouts (scheduled − aggregated).
    pub dropouts: usize,
    /// Where the JSONL trace was written.
    pub trace_path: PathBuf,
}

/// Expand the cross product into concrete (scenario, algorithm, seed)
/// units, applying the config's rounds/algorithms overrides. The
/// nesting order (scenarios, then algorithms, then seeds) is the
/// deterministic unit order of the whole sweep.
pub fn expand(cfg: &SweepConfig) -> Vec<(Scenario, String, u64)> {
    let mut units = Vec::new();
    for base in &cfg.scenarios {
        let mut sc = base.clone();
        if let Some(r) = cfg.rounds {
            sc.train.rounds = r;
        }
        let algorithms =
            cfg.algorithms.clone().unwrap_or_else(|| sc.train.algorithms.clone());
        for alg in &algorithms {
            for &seed in &cfg.seeds {
                units.push((sc.clone(), alg.clone(), seed));
            }
        }
    }
    units
}

/// Everything wrong with a sweep config: per-scenario validation,
/// duplicate names (trace paths derive from the name — a duplicate
/// would have two parallel workers writing the same file), and the
/// algorithm/round overrides (applied per unit in [`expand`], so they
/// must be checked before any run starts, not after the valid units
/// already executed). Empty = good.
pub fn config_errors(cfg: &SweepConfig) -> Vec<String> {
    let mut errs = Vec::new();
    if cfg.scenarios.is_empty() {
        errs.push("no scenarios selected".into());
    }
    if cfg.seeds.is_empty() {
        errs.push("no seeds given".into());
    }
    // Every (scenario, algorithm, seed) unit owns one trace file, so
    // any duplicated cross-product axis would race two workers on the
    // same path — reject them all up front.
    let mut seen_seeds = std::collections::BTreeSet::new();
    for &seed in &cfg.seeds {
        if !seen_seeds.insert(seed) {
            errs.push(format!("--seeds: seed {seed} given twice"));
        }
        // Seeds are recorded as JSON numbers in the traces; past 2^53
        // the f64 round-trip would silently record a different seed.
        if seed >= (1u64 << 53) {
            errs.push(format!(
                "--seeds: seed {seed} exceeds 2^53 and would lose precision in the \
                 JSONL trace metadata"
            ));
        }
    }
    if let Some(algorithms) = &cfg.algorithms {
        if algorithms.is_empty() {
            errs.push("--algorithms: empty override".into());
        }
        let mut seen = std::collections::BTreeSet::new();
        for alg in algorithms {
            if !seen.insert(alg.as_str()) {
                errs.push(format!("--algorithms: `{alg}` given twice"));
            }
            if !crate::baselines::ALL_ALGORITHMS.contains(&alg.as_str()) {
                errs.push(format!(
                    "--algorithms: unknown algorithm `{alg}` (known: {})",
                    crate::baselines::ALL_ALGORITHMS.join(", ")
                ));
            }
        }
    }
    let mut seen_names = std::collections::BTreeSet::new();
    for sc in &cfg.scenarios {
        if !seen_names.insert(sc.name.as_str()) {
            errs.push(format!(
                "{}: selected twice (scenario names must be unique within a sweep)",
                sc.name
            ));
        }
        for e in sc.validate() {
            errs.push(format!("{}: {e}", sc.name));
        }
    }
    if cfg.rounds == Some(0) {
        errs.push("--rounds: must be at least 1".into());
    }
    errs
}

/// Run the sweep. Fails fast on an invalid config — scenarios,
/// duplicate names, and overrides are all checked via
/// [`config_errors`] before any run starts; a failing *run* aborts the
/// sweep with its unit named. Returns one row per unit in [`expand`]
/// order.
pub fn run(rt: &Runtime, cfg: &SweepConfig) -> Result<Vec<SweepRow>> {
    let all_errs = config_errors(cfg);
    anyhow::ensure!(all_errs.is_empty(), "invalid sweep:\n  {}", all_errs.join("\n  "));

    std::fs::create_dir_all(&cfg.out_dir)?;
    let units = expand(cfg);
    crate::info!(
        "sweep",
        "{} runs ({} scenarios x algorithms x {} seeds), {} worker thread(s), out {}",
        units.len(),
        cfg.scenarios.len(),
        cfg.seeds.len(),
        cfg.threads.max(1),
        cfg.out_dir.display()
    );
    let results: Vec<Result<SweepRow>> =
        threadpool::parallel_map(&units, cfg.threads.max(1), |_, (sc, alg, seed)| {
            let trace = run_scenario(rt, sc, alg, *seed, 1)
                .map_err(|e| anyhow::anyhow!("{}/{alg}/seed{seed}: {e:#}", sc.name))?;
            let path = cfg.out_dir.join(format!("{}__{alg}__seed{seed}.jsonl", sc.name));
            trace
                .write_jsonl(
                    &path,
                    &[
                        ("scenario", json::s(&sc.name)),
                        ("algorithm", json::s(alg)),
                        ("seed", json::num(*seed as f64)),
                    ],
                )
                .map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))?;
            Ok(summarize(&trace, sc, alg, *seed, path))
        });
    let rows: Vec<SweepRow> = results.into_iter().collect::<Result<_>>()?;
    write_summary(&rows, &cfg.out_dir)?;
    Ok(rows)
}

fn summarize(trace: &Trace, sc: &Scenario, alg: &str, seed: u64, path: PathBuf) -> SweepRow {
    SweepRow {
        scenario: sc.name.clone(),
        algorithm: alg.to_string(),
        seed,
        rounds: trace.records.len(),
        final_acc: trace.final_accuracy().unwrap_or(f64::NAN),
        best_acc: trace.best_accuracy().unwrap_or(f64::NAN),
        cum_energy: trace.total_energy(),
        wire_bytes: trace.total_wire_bytes(),
        dropouts: trace.total_dropouts(),
        trace_path: path,
    }
}

/// Write `summary.csv` (one row per run, unit order) into `out_dir`.
pub fn write_summary(rows: &[SweepRow], out_dir: &std::path::Path) -> Result<()> {
    let path = out_dir.join("summary.csv");
    let mut w = CsvWriter::create(
        &path,
        &[
            "scenario",
            "algorithm",
            "seed",
            "rounds",
            "final_acc",
            "best_acc",
            "cum_energy_j",
            "wire_bytes",
            "dropouts",
            "trace_file",
        ],
    )?;
    for r in rows {
        w.row(&[
            r.scenario.clone(),
            r.algorithm.clone(),
            r.seed.to_string(),
            r.rounds.to_string(),
            format!("{:.6}", r.final_acc),
            format!("{:.6}", r.best_acc),
            format!("{:.9}", r.cum_energy),
            r.wire_bytes.to_string(),
            r.dropouts.to_string(),
            r.trace_path
                .file_name()
                .map(|f| f.to_string_lossy().into_owned())
                .unwrap_or_default(),
        ])?;
    }
    w.flush()?;
    Ok(())
}

/// Print the run summaries as a table.
pub fn print(rows: &[SweepRow]) {
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.scenario.clone(),
                r.algorithm.clone(),
                r.seed.to_string(),
                r.rounds.to_string(),
                format!("{:.4}", r.final_acc),
                format!("{:.4}", r.best_acc),
                table::fnum(r.cum_energy),
                table::fnum(r.wire_bytes as f64),
                r.dropouts.to_string(),
            ]
        })
        .collect();
    println!("sweep — one row per (scenario, algorithm, seed) run");
    println!(
        "{}",
        table::render(
            &[
                "scenario",
                "algorithm",
                "seed",
                "rounds",
                "final acc",
                "best acc",
                "energy (J)",
                "wire (B)",
                "dropouts"
            ],
            &body
        )
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::registry;

    fn cfg(scenarios: Vec<Scenario>) -> SweepConfig {
        SweepConfig {
            scenarios,
            seeds: vec![1, 2],
            algorithms: None,
            rounds: None,
            out_dir: PathBuf::from("/tmp/unused"),
            threads: 1,
        }
    }

    #[test]
    fn expand_cross_products_in_deterministic_order() {
        let mut c = cfg(vec![registry::paper_femnist(), registry::zipf_skew()]);
        c.algorithms = Some(vec!["qccf".into()]);
        let units = expand(&c);
        // 2 scenarios x 1 algorithm x 2 seeds.
        assert_eq!(units.len(), 4);
        let keys: Vec<(String, String, u64)> =
            units.iter().map(|(s, a, z)| (s.name.clone(), a.clone(), *z)).collect();
        assert_eq!(keys[0], ("paper-femnist".into(), "qccf".into(), 1));
        assert_eq!(keys[1], ("paper-femnist".into(), "qccf".into(), 2));
        assert_eq!(keys[2], ("zipf-skew".into(), "qccf".into(), 1));
        assert_eq!(keys[3], ("zipf-skew".into(), "qccf".into(), 2));
    }

    #[test]
    fn expand_uses_scenario_algorithms_and_round_override() {
        let mut c = cfg(vec![registry::zipf_skew()]);
        c.rounds = Some(2);
        let units = expand(&c);
        // zipf-skew declares two algorithms.
        assert_eq!(units.len(), 2 * 2);
        assert!(units.iter().all(|(s, _, _)| s.train.rounds == 2));
        let algs: Vec<&str> = units.iter().map(|(_, a, _)| a.as_str()).collect();
        assert!(algs.contains(&"qccf") && algs.contains(&"same-size"));
    }

    #[test]
    fn config_errors_catch_duplicates_and_bad_overrides() {
        let good = cfg(vec![registry::paper_femnist(), registry::zipf_skew()]);
        assert!(config_errors(&good).is_empty(), "{:?}", config_errors(&good));

        // Duplicate names would race on the same trace file.
        let dup = cfg(vec![registry::zipf_skew(), registry::zipf_skew()]);
        assert!(config_errors(&dup).iter().any(|e| e.contains("selected twice")));

        // Overrides are validated up front, not per unit mid-sweep.
        let mut bad_alg = cfg(vec![registry::paper_femnist()]);
        bad_alg.algorithms = Some(vec!["qccf".into(), "typo".into()]);
        assert!(config_errors(&bad_alg).iter().any(|e| e.contains("unknown algorithm `typo`")));
        let mut zero_rounds = cfg(vec![registry::paper_femnist()]);
        zero_rounds.rounds = Some(0);
        assert!(config_errors(&zero_rounds).iter().any(|e| e.contains("--rounds")));
        let mut empty = cfg(vec![]);
        empty.seeds = vec![];
        let errs = config_errors(&empty);
        assert!(errs.iter().any(|e| e.contains("no scenarios")));
        assert!(errs.iter().any(|e| e.contains("no seeds")));

        // Duplicate seeds or override algorithms would race two units
        // on the same trace path; huge seeds lose f64 precision in the
        // JSONL metadata.
        let mut dup_seed = cfg(vec![registry::paper_femnist()]);
        dup_seed.seeds = vec![1, 2, 1];
        assert!(config_errors(&dup_seed).iter().any(|e| e.contains("seed 1 given twice")));
        let mut dup_alg = cfg(vec![registry::paper_femnist()]);
        dup_alg.algorithms = Some(vec!["qccf".into(), "qccf".into()]);
        assert!(config_errors(&dup_alg).iter().any(|e| e.contains("given twice")));
        let mut big_seed = cfg(vec![registry::paper_femnist()]);
        big_seed.seeds = vec![1u64 << 53];
        assert!(config_errors(&big_seed).iter().any(|e| e.contains("2^53")));
        let mut dup_in_scenario = cfg(vec![registry::paper_femnist()]);
        dup_in_scenario.scenarios[0].train.algorithms = vec!["qccf".into(), "qccf".into()];
        assert!(config_errors(&dup_in_scenario)
            .iter()
            .any(|e| e.contains("listed twice")));
    }

    #[test]
    fn summary_csv_shape() {
        let rows = vec![SweepRow {
            scenario: "s".into(),
            algorithm: "qccf".into(),
            seed: 1,
            rounds: 2,
            final_acc: 0.5,
            best_acc: 0.6,
            cum_energy: 1.25,
            wire_bytes: 4242,
            dropouts: 0,
            trace_path: PathBuf::from("x/s__qccf__seed1.jsonl"),
        }];
        let dir = std::env::temp_dir().join("qccf_sweep_summary_test");
        std::fs::create_dir_all(&dir).unwrap();
        write_summary(&rows, &dir).unwrap();
        let text = std::fs::read_to_string(dir.join("summary.csv")).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.lines().next().unwrap().starts_with("scenario,algorithm,seed"));
        assert!(text.contains("s__qccf__seed1.jsonl"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
