//! Fig. 4 — CIFAR-sim: the same five-algorithm grid as Fig. 3 under the
//! CIFAR-10 column of Table I (γ = 2000 cycles/sample, T^max = 0.05 s,
//! V = 10). Runs on the loaded profile's model; the wireless/compute
//! constants are what differ from Fig. 3.

use anyhow::Result;

use super::common::Task;
use super::fig3::{self, AlgRow};
use crate::runtime::Runtime;

/// The fig3 grid under the CIFAR column (the `paper-cifar10` preset).
pub fn run_grid(rt: &Runtime, betas: &[f64], rounds: usize, seed: u64) -> Result<Vec<AlgRow>> {
    fig3::run_grid(rt, Task::Cifar, betas, rounds, seed, "fig4")
}

/// Print the grid (fig3 layout, CIFAR title).
pub fn print(rows: &[AlgRow]) {
    fig3::print(rows, "Fig. 4 — CIFAR-sim: accuracy & accumulated energy (5 algorithms)");
}

/// Write the grid summary CSV into the results directory.
pub fn write_summary(rows: &[AlgRow]) -> Result<()> {
    fig3::write_summary(rows, "fig4")
}
