//! Ablations of QCCF's two key design choices (DESIGN.md §6b):
//!
//! * **GA budget** — how much the genetic channel allocation (P3.1)
//!   improves the round objective J0 over the greedy rate-maximizing
//!   allocation, across independent channel draws, for several
//!   population/generation budgets;
//! * **Case-5 mode** — the paper's first-order Taylor step (eq. 39)
//!   vs exact bisection of eq. (38): integer-decision agreement and
//!   objective regret.

use crate::config::SystemParams;
use crate::ga::GaParams;
use crate::lyapunov::Queues;
use crate::sched::{evaluate_allocation, greedy_allocation, RoundInputs};
use crate::solver::{self, Case5Mode};
use crate::util::rng::Rng;
use crate::util::stats;
use crate::util::table;
use crate::wireless::ChannelModel;

/// One GA-budget ablation row.
pub struct GaBudgetRow {
    /// Budget label (population × generations).
    pub label: String,
    /// Mean relative J0 improvement over greedy (percent).
    pub mean_gain_pct: f64,
    /// 95th-percentile relative J0 improvement (percent).
    pub p95_gain_pct: f64,
    /// Mean fitness-evaluator invocations per decision. With the GA
    /// fitness cache (the default) this counts distinct chromosomes
    /// actually scored — elites and duplicate offspring are free.
    pub mean_evals: f64,
}

fn make_state(
    params: &SystemParams,
    rng: &mut Rng,
) -> (crate::wireless::ChannelState, Vec<f64>, Vec<f64>, Queues) {
    let model = ChannelModel::new(params, rng);
    let state = model.draw(rng);
    let sizes: Vec<f64> =
        (0..params.num_clients).map(|_| rng.gaussian(1200.0, 300.0).max(64.0)).collect();
    let total: f64 = sizes.iter().sum();
    let w_full: Vec<f64> = sizes.iter().map(|d| d / total).collect();
    let mut queues = Queues::new();
    queues.lambda1 = 10f64.powf(rng.range(1.0, 4.0));
    queues.lambda2 = 10f64.powf(rng.range(1.0, 3.5));
    (state, sizes, w_full, queues)
}

/// GA-vs-greedy ablation over `draws` independent rounds.
///
/// Uses a *contended* regime — fewer channels than clients (C = 6 < U =
/// 10) and heterogeneous gradient statistics — where the allocation
/// actually decides *which* clients participate. With the default
/// C = U = 10 and homogeneous stats the seeded greedy allocation is
/// already near-optimal and Algorithm 1 buys ≈ 0.001% (also reported by
/// this harness when run with `--channels 10`).
pub fn ga_budget(draws: usize, seed: u64) -> Vec<GaBudgetRow> {
    let mut params = SystemParams::femnist_small();
    params.num_channels = 6;
    let budgets: [(&str, GaParams); 4] = [
        ("greedy (no GA)", GaParams { population: 0, generations: 0, ..GaParams::default() }),
        ("pop12 × gen8", GaParams { population: 12, generations: 8, ..GaParams::default() }),
        ("pop24 × gen16 (default)", GaParams::default()),
        ("pop48 × gen32", GaParams { population: 48, generations: 32, ..GaParams::default() }),
    ];
    let mut rows = Vec::new();
    for (label, ga) in budgets {
        let mut gains = Vec::new();
        let mut evals = Vec::new();
        let mut rng = Rng::seed_from(seed);
        for _ in 0..draws {
            let (state, sizes, w_full, queues) = make_state(&params, &mut rng);
            let g2: Vec<f64> = (0..params.num_clients).map(|_| rng.range(0.05, 16.0)).collect();
            let sigma2: Vec<f64> = (0..params.num_clients).map(|_| rng.range(0.05, 2.0)).collect();
            let theta_max = vec![0.4; params.num_clients];
            let q_prev = vec![6.0; params.num_clients];
            let inp = RoundInputs {
                params: &params,
                round: 5,
                channels: &state,
                sizes: &sizes,
                w_full: &w_full,
                g2: &g2,
                sigma2: &sigma2,
                theta_max: &theta_max,
                q_prev: &q_prev,
                queues: &queues,
                avail: None,
            };
            let greedy = greedy_allocation(&inp);
            let (jg, _) = evaluate_allocation(&inp, &greedy, Case5Mode::Taylor);
            if ga.population == 0 {
                gains.push(0.0);
                evals.push(1.0);
                continue;
            }
            let mut grng = rng.fork(99);
            let out = crate::ga::optimize_with_seeds(
                params.num_channels,
                params.num_clients,
                &ga,
                &mut grng,
                std::slice::from_ref(&greedy),
                |c| evaluate_allocation(&inp, c, Case5Mode::Taylor).0,
            );
            let gain = if jg.is_finite() && jg.abs() > 0.0 {
                (jg - out.best_j0) / jg.abs() * 100.0
            } else {
                0.0
            };
            gains.push(gain.max(0.0));
            evals.push(out.evals as f64);
        }
        rows.push(GaBudgetRow {
            label: label.to_string(),
            mean_gain_pct: stats::mean(&gains),
            p95_gain_pct: stats::percentile(&gains, 95.0),
            mean_evals: stats::mean(&evals),
        });
    }
    rows
}

/// Aggregate Taylor-vs-bisect comparison over sampled Case-5 regimes.
pub struct Case5Row {
    /// Case-5 regimes sampled.
    pub regimes: usize,
    /// Regimes where both solvers found a feasible q.
    pub both_feasible: usize,
    /// Regimes where both picked the same integer level.
    pub same_q: usize,
    /// Largest |q_taylor − q_bisect| observed.
    pub max_q_gap: u32,
    /// Mean relative J3 regret of Taylor vs bisect (percent).
    pub mean_regret_pct: f64,
}

/// Taylor (eq. 39) vs exact bisection of eq. (38).
pub fn case5_modes(draws: usize, seed: u64) -> Case5Row {
    let params = SystemParams::femnist_small();
    let mut rng = Rng::seed_from(seed);
    let mut row = Case5Row {
        regimes: 0,
        both_feasible: 0,
        same_q: 0,
        max_q_gap: 0,
        mean_regret_pct: 0.0,
    };
    let mut regrets = Vec::new();
    for _ in 0..draws {
        let lambda2 = params.eps2 + 10f64.powf(rng.range(-2.0, 3.5));
        let ctx = solver::ClientCtx {
            d_i: rng.range(300.0, 2500.0),
            w_round: rng.range(0.02, 0.5),
            rate: rng.range(8e6, 40e6),
            theta_max: rng.range(0.05, 2.0),
            q_prev: rng.range(1.0, 14.0),
        };
        row.regimes += 1;
        let b = solver::solve_client(&params, lambda2, &ctx, Case5Mode::Bisect);
        // Paper premise: the anchor q' comes from the client's previous
        // participation and sits near the current optimum. Compare the
        // one-step Taylor solve on those terms.
        let mut ctx_near = ctx;
        if let Some(db) = &b {
            ctx_near.q_prev = (db.q_hat + rng.range(-1.0, 1.0)).max(1.0);
        }
        let a = solver::solve_client(&params, lambda2, &ctx_near, Case5Mode::Taylor);
        if let (Some(da), Some(db)) = (a, b) {
            row.both_feasible += 1;
            if da.q == db.q {
                row.same_q += 1;
            }
            row.max_q_gap = row.max_q_gap.max(da.q.abs_diff(db.q));
            if db.j3.abs() > 0.0 {
                regrets.push(((da.j3 - db.j3) / db.j3.abs()).max(0.0) * 100.0);
            }
        }
    }
    row.mean_regret_pct = stats::mean(&regrets);
    row
}

/// Print ablation A (GA budget vs greedy).
pub fn print_ga(rows: &[GaBudgetRow]) {
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.label.clone(),
                format!("{:.3}%", r.mean_gain_pct),
                format!("{:.3}%", r.p95_gain_pct),
                format!("{:.0}", r.mean_evals),
            ]
        })
        .collect();
    println!("Ablation A — GA budget vs greedy channel allocation (J0 gain)");
    println!(
        "{}",
        table::render(&["budget", "mean gain", "p95 gain", "evals/decision"], &body)
    );
}

/// Print ablation B (Case-5 solver modes).
pub fn print_case5(r: &Case5Row) {
    println!("Ablation B — Case-5: paper Taylor step (eq. 39) vs exact bisection");
    let body = vec![vec![
        r.regimes.to_string(),
        r.both_feasible.to_string(),
        format!("{:.1}%", 100.0 * r.same_q as f64 / r.both_feasible.max(1) as f64),
        r.max_q_gap.to_string(),
        format!("{:.4}%", r.mean_regret_pct),
    ]];
    println!(
        "{}",
        table::render(
            &["regimes", "both feasible", "same integer q", "max q gap", "mean J3 regret"],
            &body
        )
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ga_budget_rows_complete() {
        let rows = ga_budget(6, 3);
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].mean_gain_pct, 0.0); // greedy baseline
        for r in &rows[1..] {
            assert!(r.mean_gain_pct >= 0.0);
            assert!(r.mean_evals > 0.0);
        }
        // Bigger budgets never hurt (gains are vs the same greedy).
        assert!(rows[3].mean_gain_pct + 1e-9 >= rows[1].mean_gain_pct * 0.5);
    }

    #[test]
    fn case5_agreement_high() {
        let r = case5_modes(300, 7);
        assert!(r.both_feasible > 100);
        assert!(r.same_q * 10 >= r.both_feasible * 8, "{}/{}", r.same_q, r.both_feasible);
        assert!(r.mean_regret_pct < 1.0);
    }
}
