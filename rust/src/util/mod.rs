//! Offline substrates: everything a normal project would pull from
//! crates.io (RNG, JSON, CSV, CLI parsing, logging, thread pool, stats,
//! tables, property testing) built in-tree because this environment has
//! no registry access. See DESIGN.md §3 "Offline substrates".

pub mod argparse;
pub mod csv;
pub mod fsio;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod threadpool;
