//! ASCII table rendering for experiment harness output (the `qccf figN`
//! commands print the same rows/series the paper's figures report).

/// Render a table with a header row; columns are padded to content width.
pub fn render(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let sep = |out: &mut String| {
        out.push('+');
        for w in &widths {
            out.push_str(&"-".repeat(w + 2));
            out.push('+');
        }
        out.push('\n');
    };
    sep(&mut out);
    out.push('|');
    for (h, w) in headers.iter().zip(&widths) {
        out.push_str(&format!(" {h:<w$} |"));
    }
    out.push('\n');
    sep(&mut out);
    for row in rows {
        out.push('|');
        for (i, w) in widths.iter().enumerate() {
            let cell = row.get(i).map(|s| s.as_str()).unwrap_or("");
            out.push_str(&format!(" {cell:<w$} |"));
        }
        out.push('\n');
    }
    sep(&mut out);
    out
}

/// Format a float with engineering-style precision for tables.
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1000.0 || x.abs() < 0.01 {
        format!("{x:.3e}")
    } else {
        format!("{x:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let t = render(
            &["alg", "energy (J)"],
            &[
                vec!["qccf".into(), "1.23".into()],
                vec!["no-quant".into(), "45.6".into()],
            ],
        );
        assert!(t.contains("| alg      |"));
        assert!(t.contains("| no-quant |"));
        // All lines same width.
        let lens: Vec<usize> = t.lines().map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert!(fnum(1234.5).contains('e'));
        assert!(fnum(0.001).contains('e'));
        assert_eq!(fnum(1.5), "1.5000");
    }
}
