//! Scoped data-parallel helper (tokio/rayon are unavailable offline).
//!
//! `parallel_map` fans a slice out over `n` scoped worker threads pulling
//! indices from a shared atomic counter — enough for the coordinator's
//! per-client train-step fan-out and GA fitness evaluation. On this 1-core
//! box it mostly exercises the code path; on multi-core hosts it scales.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Apply `f` to every element, using up to `threads` workers.
/// Results keep the input order.
///
/// Deliberately *not* routed through [`parallel_map_owned`]: the
/// borrowed form reads the slice lock-free where the owned form pays a
/// `Mutex<Option<T>>` hand-off per element. (The GA fitness loop moved
/// to [`parallel_map_with`] for its per-worker scratch; the sweep
/// runner still fans out through here.)
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return items.iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i, &items[i]);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker filled slot"))
        .collect()
}

/// [`parallel_map`] with one mutable worker-state per thread — the
/// borrowed-items sibling of [`parallel_map_owned_with`]. `states.len()`
/// bounds the worker count and each worker owns exactly one `&mut S`
/// for its whole run. The GA fitness loop threads its per-worker
/// `EvalScratch` buffers through here so the decision hot path performs
/// zero per-evaluation heap allocation (see `sched::ctx`).
///
/// Results keep input order; panics if `items` is non-empty but
/// `states` is empty.
pub fn parallel_map_with<T, R, S, F>(items: &[T], states: &mut [S], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    S: Send,
    F: Fn(usize, &T, &mut S) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    assert!(!states.is_empty(), "parallel_map_with needs at least one worker state");
    let threads = states.len().min(n);
    if threads == 1 {
        let st = &mut states[0];
        return items.iter().enumerate().map(|(i, x)| f(i, x, &mut *st)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        let (next, slots, f) = (&next, &slots, &f);
        for st in states.iter_mut().take(threads) {
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                *slots[i].lock().unwrap() = Some(f(i, &items[i], &mut *st));
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker filled slot"))
        .collect()
}

/// [`parallel_map`] over owned items: each element is handed to exactly
/// one worker by value. The round engine needs this because a client
/// task owns its private RNG stream, which must be advanced in place
/// and returned with the result. Thin wrapper over
/// [`parallel_map_owned_with`] with unit worker states — one body of
/// work-stealing code to maintain.
pub fn parallel_map_owned<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    let mut states = vec![(); threads.max(1).min(n.max(1))];
    parallel_map_owned_with(items, &mut states, |i, x, _| f(i, x))
}

/// [`parallel_map_owned`] with one mutable worker-state per thread:
/// `states.len()` bounds the worker count and each worker owns exactly
/// one `&mut S` for its whole run. The round engine threads its
/// per-worker scratch buffers (quantization noise + wire-encode
/// staging) through here so they are reused across all the clients a
/// worker processes instead of reallocated per client.
///
/// Results keep input order; panics if `items` is non-empty but
/// `states` is empty.
pub fn parallel_map_owned_with<T, R, S, F>(items: Vec<T>, states: &mut [S], f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    S: Send,
    F: Fn(usize, T, &mut S) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    assert!(!states.is_empty(), "parallel_map_owned_with needs at least one worker state");
    let threads = states.len().min(n);
    if threads == 1 {
        let st = &mut states[0];
        let mut out = Vec::with_capacity(n);
        for (i, x) in items.into_iter().enumerate() {
            out.push(f(i, x, &mut *st));
        }
        return out;
    }
    let inputs: Vec<Mutex<Option<T>>> = items.into_iter().map(|x| Mutex::new(Some(x))).collect();
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        let (next, inputs, slots, f) = (&next, &inputs, &slots, &f);
        for st in states.iter_mut().take(threads) {
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let x = inputs[i].lock().unwrap().take().expect("item taken once");
                *slots[i].lock().unwrap() = Some(f(i, x, &mut *st));
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker filled slot"))
        .collect()
}

/// Number of worker threads to use by default (leave one core for the
/// coordinator loop; minimum 1).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = parallel_map(&items, 4, |i, &x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(parallel_map(&empty, 4, |_, &x| x).is_empty());
        assert_eq!(parallel_map(&[7u32], 4, |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn single_thread_path() {
        let items = [1, 2, 3];
        assert_eq!(parallel_map(&items, 1, |_, &x| x * x), vec![1, 4, 9]);
    }

    #[test]
    fn results_complete_under_contention() {
        let items: Vec<usize> = (0..1000).collect();
        let out = parallel_map(&items, 8, |_, &x| x);
        assert_eq!(out.len(), 1000);
        assert!(out.iter().enumerate().all(|(i, &x)| i == x));
    }

    #[test]
    fn borrowed_with_reuses_one_state_per_worker() {
        let items: Vec<usize> = (0..400).collect();
        let mut states = vec![0usize; 3];
        let out = parallel_map_with(&items, &mut states, |i, &x, tally| {
            assert_eq!(i, x);
            *tally += 1;
            x + 7
        });
        assert_eq!(out, (7..407).collect::<Vec<_>>());
        assert_eq!(states.iter().sum::<usize>(), 400);
    }

    #[test]
    fn borrowed_with_single_state_and_empty() {
        let mut none: Vec<u8> = vec![];
        assert!(parallel_map_with(&Vec::<u8>::new(), &mut none, |_, &x, _: &mut u8| x)
            .is_empty());
        let mut one = vec![0u32];
        let out = parallel_map_with(&[5u32, 6], &mut one, |_, &x, s| {
            *s += x;
            x
        });
        assert_eq!(out, vec![5, 6]);
        assert_eq!(one[0], 11);
    }

    #[test]
    fn owned_moves_each_item_once() {
        // Non-Clone payloads prove by-value delivery.
        let items: Vec<Box<usize>> = (0..200).map(Box::new).collect();
        let out = parallel_map_owned(items, 4, |i, x| {
            assert_eq!(i, *x);
            *x + 1
        });
        assert_eq!(out, (1..=200).collect::<Vec<_>>());
    }

    #[test]
    fn owned_with_reuses_one_state_per_worker() {
        // Every item is touched exactly once; each worker accumulates
        // into its own state, and the per-worker tallies sum to n —
        // i.e. states really are reused across a worker's items, not
        // recreated per item.
        let items: Vec<usize> = (0..500).collect();
        let mut states = vec![0usize; 4];
        let out = parallel_map_owned_with(items, &mut states, |i, x, tally| {
            assert_eq!(i, x);
            *tally += 1;
            x * 3
        });
        assert_eq!(out, (0..500).map(|x| x * 3).collect::<Vec<_>>());
        assert_eq!(states.iter().sum::<usize>(), 500);
    }

    #[test]
    fn owned_with_single_state_and_empty() {
        let mut none: Vec<u8> = vec![];
        assert!(parallel_map_owned_with(Vec::<u8>::new(), &mut none, |_, x, _: &mut u8| x)
            .is_empty());
        let mut one = vec![0u32];
        let out = parallel_map_owned_with(vec![5u32, 6], &mut one, |_, x, s| {
            *s += x;
            x
        });
        assert_eq!(out, vec![5, 6]);
        assert_eq!(one[0], 11);
    }

    #[test]
    fn owned_single_thread_and_empty() {
        assert!(parallel_map_owned(Vec::<u8>::new(), 4, |_, x| x).is_empty());
        assert_eq!(parallel_map_owned(vec![1, 2, 3], 1, |_, x| x * 10), vec![10, 20, 30]);
    }
}
