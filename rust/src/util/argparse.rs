//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `qccf <subcommand> [--key value]... [--flag]... [positional]...`
//! Flags vs options are disambiguated by the caller: `get*` consumes an
//! option with a value, `flag` tests presence.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, `--key value` options, `--flag`
/// switches, and positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// The subcommand (first non-option token), if any.
    pub cmd: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Non-option tokens after the subcommand.
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else {
                    // Value form: `--key value` if the next token isn't a flag.
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.opts.insert(name.to_string(), v);
                        }
                        _ => out.flags.push(name.to_string()),
                    }
                }
            } else if out.cmd.is_none() {
                out.cmd = Some(tok);
            } else {
                out.positionals.push(tok);
            }
        }
        out
    }

    /// Parse from the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Whether `--name` was given as a flag (or `--name=true`).
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.opts.get(name).map(|v| v == "true").unwrap_or(false)
    }

    /// Raw value of option `--name`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// Option value with a default.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// `f64` option with a default (unparseable values fall back).
    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// `usize` option with a default (unparseable values fall back).
    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// `u64` option with a default (unparseable values fall back).
    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Comma-separated f64 list, e.g. `--v-values 1,10,100`.
    pub fn get_f64_list(&self, name: &str, default: &[f64]) -> Vec<f64> {
        match self.get(name) {
            Some(v) => v.split(',').filter_map(|t| t.trim().parse().ok()).collect(),
            None => default.to_vec(),
        }
    }

    /// Comma-separated string list.
    pub fn get_str_list(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            Some(v) => v.split(',').map(|t| t.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = args("fig3 --rounds 50 --beta 300 --quick");
        assert_eq!(a.cmd.as_deref(), Some("fig3"));
        assert_eq!(a.get_usize("rounds", 0), 50);
        assert_eq!(a.get_f64("beta", 0.0), 300.0);
        assert!(a.flag("quick"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = args("train --profile=small --v=100");
        assert_eq!(a.get("profile"), Some("small"));
        assert_eq!(a.get_f64("v", 0.0), 100.0);
    }

    #[test]
    fn trailing_flag() {
        let a = args("bench --quick");
        assert!(a.flag("quick"));
    }

    #[test]
    fn lists() {
        let a = args("fig2 --v-values 1,10,100");
        assert_eq!(a.get_f64_list("v-values", &[]), vec![1.0, 10.0, 100.0]);
        assert_eq!(a.get_f64_list("other", &[5.0]), vec![5.0]);
    }

    #[test]
    fn positionals() {
        let a = args("run alpha beta --x 1");
        assert_eq!(a.positionals, vec!["alpha", "beta"]);
    }
}
