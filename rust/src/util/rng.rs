//! Deterministic PRNG substrate (crates.io is unavailable offline, so we
//! carry our own): splitmix64 seeding + xoshiro256++ core, with the
//! samplers the wireless/FL simulation needs — uniform, Gaussian
//! (Box–Muller), Rician fading power gains, and integer helpers.
//!
//! Every stochastic component of the simulator (channel draws, dataset
//! sizes, GA operators, quantization noise streams, data sampling) pulls
//! from an explicitly seeded `Rng`, so full experiments replay
//! bit-for-bit.

/// xoshiro256++ PRNG (public-domain reference algorithm by Blackman/Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    spare: Option<f64>,
}

/// The complete serializable position of an [`Rng`] stream: the four
/// xoshiro256++ state words **and** the cached Box–Muller spare (without
/// it, a restore in the middle of a Gaussian pair would shift every
/// subsequent draw by one). Captured with [`Rng::state`], reinstalled
/// with [`Rng::restore`] / [`Rng::from_state`] — the checkpoint
/// subsystem's contract is that a restored stream replays the exact
/// draw sequence the original would have produced.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RngState {
    /// xoshiro256++ state words.
    pub s: [u64; 4],
    /// Cached second Box–Muller variate, if one is pending.
    pub spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Rng {
    /// Seed via splitmix64 so similar seeds give uncorrelated streams.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Capture the stream's exact position (see [`RngState`]).
    pub fn state(&self) -> RngState {
        RngState { s: self.s, spare: self.spare }
    }

    /// Reposition this stream to a captured state; subsequent draws are
    /// identical to what the captured stream would have produced.
    pub fn restore(&mut self, state: &RngState) {
        self.s = state.s;
        self.spare = state.spare;
    }

    /// A stream positioned at a captured state.
    pub fn from_state(state: &RngState) -> Rng {
        Rng { s: state.s, spare: state.spare }
    }

    /// Derive an independent child stream (used to give every client /
    /// round its own noise stream without coupling draws).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mix = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng::seed_from(mix)
    }

    /// Next raw 64-bit draw (xoshiro256++ step).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform f64 in [0, 1) with 53 random bits.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1) (quantization-noise streams).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our use).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller (cached spare).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// N(mu, sigma^2).
    pub fn gaussian(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Rician fading **power** gain with K-factor `k` and mean power
    /// `omega` (paper Table I: K = 4, zeta = omega = 1).
    ///
    /// Amplitude a = |nu + sigma*(N1 + j N2)| with nu^2 = K*omega/(K+1)
    /// and sigma^2 = omega / (2(K+1)); returns a^2, so E[a^2] = omega.
    pub fn rician_power(&mut self, k: f64, omega: f64) -> f64 {
        let nu = (k * omega / (k + 1.0)).sqrt();
        let sigma = (omega / (2.0 * (k + 1.0))).sqrt();
        let x = nu + sigma * self.normal();
        let y = sigma * self.normal();
        x * x + y * y
    }

    /// Fill a f32 slice with uniforms in [0,1) (quantization noise).
    pub fn fill_uniform_f32(&mut self, buf: &mut [f32]) {
        for v in buf.iter_mut() {
            *v = self.uniform_f32();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices out of n (partial Fisher–Yates).
    pub fn choose_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        let k = k.min(n);
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval_and_well_spread() {
        let mut rng = Rng::seed_from(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seed_from(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn rician_mean_power_is_omega() {
        let mut rng = Rng::seed_from(13);
        let n = 50_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += rng.rician_power(4.0, 1.0);
        }
        let mean = sum / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn rician_large_k_concentrates() {
        // K -> inf approaches a pure LOS link: power ~ omega, low variance.
        let mut rng = Rng::seed_from(17);
        let mut var = 0.0;
        let n = 20_000;
        for _ in 0..n {
            let p = rng.rician_power(1000.0, 1.0);
            var += (p - 1.0) * (p - 1.0);
        }
        assert!((var / n as f64) < 0.01);
    }

    #[test]
    fn choose_indices_distinct() {
        let mut rng = Rng::seed_from(19);
        for _ in 0..50 {
            let picked = rng.choose_indices(20, 7);
            assert_eq!(picked.len(), 7);
            let mut sorted = picked.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seed_from(23);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn state_restore_replays_identical_draws() {
        // The checkpoint contract: capturing a stream mid-flight and
        // restoring it replays the exact draw sequence, bit for bit —
        // including the Box–Muller spare, which a naive save of the
        // four state words alone would drop (shifting every Gaussian
        // after the restore by one half-pair).
        let mut rng = Rng::seed_from(123);
        for _ in 0..37 {
            rng.next_u64();
        }
        // Park a spare: after one normal() the second variate is cached.
        let _ = rng.normal();
        let snap = rng.state();
        assert!(snap.spare.is_some(), "spare must be pending here");

        let reference: Vec<u64> = {
            let mut a = Rng::from_state(&snap);
            let mut out = Vec::new();
            for _ in 0..8 {
                out.push(a.normal().to_bits());
            }
            for _ in 0..32 {
                out.push(a.next_u64());
            }
            out.push(a.uniform().to_bits());
            out.push(a.rician_power(4.0, 1.0).to_bits());
            out
        };
        // The original stream continues identically...
        let continued: Vec<u64> = {
            let mut out = Vec::new();
            for _ in 0..8 {
                out.push(rng.normal().to_bits());
            }
            for _ in 0..32 {
                out.push(rng.next_u64());
            }
            out.push(rng.uniform().to_bits());
            out.push(rng.rician_power(4.0, 1.0).to_bits());
            out
        };
        assert_eq!(reference, continued);
        // ...and an in-place restore rewinds to the same sequence.
        rng.restore(&snap);
        let mut replay = Vec::new();
        for _ in 0..8 {
            replay.push(rng.normal().to_bits());
        }
        for _ in 0..32 {
            replay.push(rng.next_u64());
        }
        replay.push(rng.uniform().to_bits());
        replay.push(rng.rician_power(4.0, 1.0).to_bits());
        assert_eq!(reference, replay);
    }

    #[test]
    fn fork_streams_uncorrelated() {
        let mut root = Rng::seed_from(29);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let mut same = 0;
        for _ in 0..1000 {
            if a.next_u64() == b.next_u64() {
                same += 1;
            }
        }
        assert_eq!(same, 0);
    }
}
