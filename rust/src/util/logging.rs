//! Leveled logger with run-relative timestamps (the `log`/`env_logger`
//! crates are unavailable offline). Level comes from `QCCF_LOG`
//! (error|warn|info|debug|trace), default `info`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity (ascending verbosity).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Unrecoverable problems.
    Error = 0,
    /// Suspicious-but-continuing conditions.
    Warn = 1,
    /// Run-level progress (the default).
    Info = 2,
    /// Per-round diagnostics.
    Debug = 3,
    /// Per-client firehose.
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static START: OnceLock<Instant> = OnceLock::new();

fn start() -> Instant {
    *START.get_or_init(Instant::now)
}

/// Initialize level from the environment; idempotent. An unrecognized
/// `QCCF_LOG` value falls back to `info` *loudly* — a typo like
/// `QCCF_LOG=dbug` used to be silently accepted, hiding exactly the
/// diagnostics the variable was set to reveal.
pub fn init() {
    start();
    if let Ok(v) = std::env::var("QCCF_LOG") {
        let parsed = match v.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        };
        set_level(parsed.unwrap_or(Level::Info));
        if parsed.is_none() {
            // After set_level so the warning itself prints at the
            // fallback level.
            log(
                Level::Warn,
                "logging",
                format_args!(
                    "QCCF_LOG=`{v}` is not a level; using `info` \
                     (accepted: error|warn|info|debug|trace)"
                ),
            );
        }
    }
}

/// Set the global log level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Whether `level` currently prints.
pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

/// Emit one log line (use the `info!`/`warn_log!`/`debug_log!` macros).
pub fn log(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = start().elapsed().as_secs_f64();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{t:9.3}s {tag} {target}] {msg}");
}

/// Log at [`util::logging::Level::Info`](crate::util::logging::Level).
#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target,
                                   format_args!($($arg)*))
    };
}

/// Log at [`util::logging::Level::Warn`](crate::util::logging::Level).
#[macro_export]
macro_rules! warn_log {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target,
                                   format_args!($($arg)*))
    };
}

/// Log at [`util::logging::Level::Debug`](crate::util::logging::Level).
#[macro_export]
macro_rules! debug_log {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target,
                                   format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        init();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
