//! Minimal CSV writer for metric traces (each experiment run dumps
//! per-round rows that EXPERIMENTS.md references).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Buffered CSV writer with a fixed column count.
pub struct CsvWriter {
    out: BufWriter<File>,
    ncol: usize,
}

fn escape(cell: &str) -> String {
    if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

impl CsvWriter {
    /// Create (parents included) and write the header row.
    pub fn create<P: AsRef<Path>>(path: P, headers: &[&str]) -> std::io::Result<CsvWriter> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = CsvWriter {
            // detlint: allow(R5) — raw sink whose durability policy is
            // the caller's: Trace::write_csv and sweep's summary.csv
            // wrap it in fsio::replace_atomic (tmp path in, rename
            // after); the remaining direct uses are streaming side
            // channels where a torn tail row is acceptable.
            out: BufWriter::new(File::create(path)?),
            ncol: headers.len(),
        };
        w.write_raw(headers)?;
        Ok(w)
    }

    fn write_raw(&mut self, cells: &[&str]) -> std::io::Result<()> {
        let line: Vec<String> = cells.iter().map(|c| escape(c)).collect();
        writeln!(self.out, "{}", line.join(","))
    }

    /// Write one row (must match the header's column count).
    pub fn row(&mut self, cells: &[String]) -> std::io::Result<()> {
        debug_assert_eq!(cells.len(), self.ncol, "column count mismatch");
        let refs: Vec<&str> = cells.iter().map(|s| s.as_str()).collect();
        self.write_raw(&refs)
    }

    /// Write one row of numbers.
    pub fn row_f64(&mut self, cells: &[f64]) -> std::io::Result<()> {
        self.row(&cells.iter().map(|x| format!("{x}")).collect::<Vec<_>>())
    }

    /// Flush the underlying buffer.
    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_escapes() {
        let dir = std::env::temp_dir().join("qccf_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&["x,1".into(), "say \"hi\"".into()]).unwrap();
            w.row_f64(&[1.5, 2.5]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            text,
            "a,b\n\"x,1\",\"say \"\"hi\"\"\"\n1.5,2.5\n"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
