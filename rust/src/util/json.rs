//! Minimal JSON substrate (serde is unavailable offline): a recursive-
//! descent parser and a writer, sufficient for the artifact manifest and
//! the metrics/bench output files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (f64-backed).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys — deterministic rendering).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object field lookup (None on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric value truncated to usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Key/value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Render compactly.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Returns a descriptive error with byte offset.
pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                Some(_) => {
                    // Copy a run of plain bytes (UTF-8 passes through).
                    let start = self.i;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|_| "bad utf8")?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

/// Convenience builders for writer-side code.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Shorthand for [`Json::Num`].
pub fn num(x: f64) -> Json {
    Json::Num(x)
}

/// Shorthand for [`Json::Str`].
pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

/// Numeric array from a slice.
pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{"tiny": {"z": 1242, "lr": 0.05, "image": [8, 8, 1],
                        "artifacts": {"init": {"file": "init.hlo.txt", "args": []}}}}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("tiny").unwrap().get("z").unwrap().as_usize(), Some(1242));
        assert_eq!(
            v.get("tiny").unwrap().get("image").unwrap().as_arr().unwrap().len(),
            3
        );
        let back = parse(&v.to_string_compact()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        let text = v.to_string_compact();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn numbers() {
        for (t, want) in [("0", 0.0), ("-12.5", -12.5), ("1e3", 1000.0), ("2.5e-2", 0.025)] {
            assert_eq!(parse(t).unwrap().as_f64(), Some(want));
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{}x").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap().as_str(), Some("A"));
    }
}
