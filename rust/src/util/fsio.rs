//! Atomic file replacement (tmp + fsync + rename): the durability
//! substrate under checkpoint snapshots, sweep `summary.csv`, and the
//! per-run JSONL traces, so an interrupted process never leaves a torn
//! file for `--resume` to misread.
//!
//! POSIX `rename(2)` within one directory is atomic, so readers observe
//! either the previous complete file or the new complete file — never a
//! prefix. The data is fsynced before the rename (and the directory
//! after, best effort) so the rename cannot outlive its contents across
//! a power cut.

use std::fs::{self, File};
use std::io;
use std::path::{Path, PathBuf};

/// The temporary sibling a pending write stages into: `<name>.tmp` in
/// the same directory (same filesystem, so the rename is atomic).
fn tmp_sibling(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Replace `path` atomically: `write` produces the new contents at a
/// temporary sibling path, which is fsynced and renamed over `path`.
/// On any error the temporary file is removed and `path` is left
/// exactly as it was. Parent directories are created as needed.
pub fn replace_atomic<F>(path: &Path, write: F) -> io::Result<()>
where
    F: FnOnce(&Path) -> io::Result<()>,
{
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            fs::create_dir_all(dir)?;
        }
    }
    let tmp = tmp_sibling(path);
    let result = write(&tmp)
        .and_then(|()| File::open(&tmp))
        .and_then(|f| f.sync_all())
        .and_then(|()| fs::rename(&tmp, path));
    if result.is_err() {
        fs::remove_file(&tmp).ok();
    } else if let Some(dir) = path.parent() {
        // Directory fsync is best effort: it makes the rename itself
        // durable, but a failure here does not un-replace the file.
        if !dir.as_os_str().is_empty() {
            if let Ok(d) = File::open(dir) {
                d.sync_all().ok();
            }
        }
    }
    result
}

/// [`replace_atomic`] for a ready byte buffer.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> io::Result<()> {
    replace_atomic(path, |tmp| fs::write(tmp, bytes))
}

/// Append one line (plus `'\n'`) to `path`, creating the file and its
/// parent directories as needed, and fsync the result.
///
/// This is the **journal** primitive (the obs run ledger): unlike
/// [`replace_atomic`], an append is not all-or-nothing — a crash can
/// leave a torn final line — so it is only suitable for line-oriented
/// files whose readers skip unparseable lines. The single `write(2)` of
/// one buffered line keeps concurrent appenders from interleaving
/// *within* a line on POSIX (`O_APPEND`).
pub fn append_line(path: &Path, line: &str) -> io::Result<()> {
    use std::io::Write as _;
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            fs::create_dir_all(dir)?;
        }
    }
    let mut f = fs::OpenOptions::new().create(true).append(true).open(path)?;
    let mut buf = Vec::with_capacity(line.len() + 1);
    buf.extend_from_slice(line.as_bytes());
    buf.push(b'\n');
    f.write_all(&buf)?;
    f.sync_all()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_atomic_round_trips_and_leaves_no_tmp() {
        let dir = std::env::temp_dir().join("qccf_fsio_test_rt");
        let path = dir.join("nested").join("out.bin");
        write_atomic(&path, b"hello").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"hello");
        write_atomic(&path, b"world").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"world");
        let names: Vec<String> = std::fs::read_dir(path.parent().unwrap())
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, vec!["out.bin".to_string()]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn append_line_creates_and_appends() {
        let dir = std::env::temp_dir().join("qccf_fsio_test_append");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("nested").join("ledger.jsonl");
        append_line(&path, "{\"a\":1}").unwrap();
        append_line(&path, "{\"b\":2}").unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "{\"a\":1}\n{\"b\":2}\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn failed_write_preserves_previous_contents() {
        let dir = std::env::temp_dir().join("qccf_fsio_test_fail");
        let path = dir.join("out.txt");
        write_atomic(&path, b"original").unwrap();
        let err = replace_atomic(&path, |tmp| {
            std::fs::write(tmp, b"partial")?;
            Err(io::Error::other("simulated crash mid-write"))
        });
        assert!(err.is_err());
        // The target still holds the previous complete contents and the
        // staging file is gone.
        assert_eq!(std::fs::read(&path).unwrap(), b"original");
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, vec!["out.txt".to_string()]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
