//! Small statistics toolkit: Welford online moments, percentiles, and
//! summary records used by the metrics recorder and the bench harness.

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    /// Fresh accumulator.
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Fold one observation in.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample variance (n - 1 denominator).
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Population standard deviation.
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest observation.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest observation.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Fold another accumulator in (parallel Welford combine).
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.mean = mean;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Percentile of a slice (nearest-rank on a sorted copy); p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Arithmetic mean (NaN for an empty slice).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (0 below two observations).
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// L2 norm of an f32 slice (gradient norms, model deltas).
pub fn l2_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| x as f64 * x as f64).sum::<f64>().sqrt()
}

/// Max |x| (the quantizer's theta^max).
pub fn linf_norm(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |acc, &x| acc.max(x.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let direct_var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 5.0;
        assert!((w.variance() - direct_var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 10.0);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0).collect();
        let mut all = Welford::new();
        for &x in &xs {
            all.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-10);
        assert!((a.variance() - all.variance()).abs() < 1e-10);
    }

    #[test]
    fn percentile_basics() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.0).abs() <= 1.0);
    }

    #[test]
    fn percentile_total_cmp_matches_partial_cmp_on_finite_data() {
        // Bit-identity pin for the detlint R3 fix: on finite inputs —
        // including signed zeros and duplicates — the total_cmp sort
        // inside `percentile` returns bit-for-bit what the historical
        // partial_cmp sort returned, at every rank.
        let xs = [3.5, -0.0, 0.0, 3.5, -7.25, 1e300, -1e-300, 42.0, -0.0, 0.125];
        let mut reference: Vec<f64> = xs.to_vec();
        reference.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (k, p) in (0..=10).map(|k| (k, k as f64 * 10.0)) {
            let got = percentile(&xs, p);
            let rank = ((p / 100.0) * (xs.len() as f64 - 1.0)).round() as usize;
            let want = reference[rank.min(xs.len() - 1)];
            assert_eq!(got.to_bits(), want.to_bits(), "p{k}0: {got} vs {want}");
        }
    }

    #[test]
    fn norms() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
        assert_eq!(linf_norm(&[-3.0, 2.0, 1.0]), 3.0);
        assert_eq!(linf_norm(&[]), 0.0);
    }
}
