//! Property-test runner (proptest is unavailable offline).
//!
//! `check(name, iters, gen, prop)` draws `iters` random cases from `gen`
//! and asserts `prop` on each; on failure it panics with the *case seed*
//! so the exact case replays with `QCCF_PROP_SEED=<seed>`. A fixed default
//! master seed keeps CI deterministic while `QCCF_PROP_ITERS` can crank
//! coverage locally.

use super::rng::Rng;

fn master_seed() -> u64 {
    std::env::var("QCCF_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0x5EED_CAFE)
}

/// Iteration count: `QCCF_PROP_ITERS` or `default`.
pub fn iters(default: usize) -> usize {
    std::env::var("QCCF_PROP_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Run a property over random cases.
///
/// * `gen`: draws one case from an `Rng`.
/// * `prop`: returns `Err(description)` when the property is violated.
pub fn check<C, G, P>(name: &str, n: usize, mut gen: G, mut prop: P)
where
    C: std::fmt::Debug,
    G: FnMut(&mut Rng) -> C,
    P: FnMut(&C) -> Result<(), String>,
{
    let base = master_seed();
    for i in 0..n {
        let case_seed = base.wrapping_add(i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::seed_from(case_seed);
        let case = gen(&mut rng);
        if let Err(msg) = prop(&case) {
            panic!(
                "property `{name}` failed on iteration {i} \
                 (replay with QCCF_PROP_SEED={case_seed} QCCF_PROP_ITERS=1):\n  \
                 case: {case:?}\n  violation: {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("abs-nonneg", 200, |rng| rng.gaussian(0.0, 10.0), |x| {
            if x.abs() >= 0.0 {
                Ok(())
            } else {
                Err(format!("abs({x}) < 0"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails`")]
    fn reports_failures() {
        check("always-fails", 5, |rng| rng.uniform(), |_| Err("nope".into()));
    }

    #[test]
    fn deterministic_given_seed() {
        let mut first: Vec<f64> = Vec::new();
        check("collect", 10, |rng| rng.uniform(), |x| {
            first.push(*x);
            Ok(())
        });
        let mut second: Vec<f64> = Vec::new();
        check("collect", 10, |rng| rng.uniform(), |x| {
            second.push(*x);
            Ok(())
        });
        assert_eq!(first, second);
    }
}
