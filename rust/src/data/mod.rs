//! Synthetic federated datasets (paper §VI *Datasets*, substituted per
//! DESIGN.md §4): per-client non-IID image classification with dataset
//! sizes `D_i ~ N(µ, β)` — exactly the heterogeneity the paper studies —
//! and Dirichlet label skew for the non-IID-ness.
//!
//! Samples are class-prototype images plus Gaussian noise, a synthetic
//! stand-in for FEMNIST/CIFAR that keeps the learning problem real (loss
//! decreases, accuracy is meaningful) while requiring no downloads.

use crate::util::rng::Rng;

/// One client's local dataset (flattened NHWC images + labels).
#[derive(Clone, Debug)]
pub struct ClientData {
    /// D_i — number of samples.
    pub size: usize,
    /// `size * h*w*c` floats.
    pub images: Vec<f32>,
    /// `size` labels.
    pub labels: Vec<i32>,
}

/// The federation: U client datasets + a balanced test set.
#[derive(Clone, Debug)]
pub struct Federation {
    /// (H, W, C) image dimensions.
    pub image_dims: (usize, usize, usize),
    /// Number of label classes.
    pub num_classes: usize,
    /// The U client datasets.
    pub clients: Vec<ClientData>,
    /// Balanced held-out test set.
    pub test: ClientData,
}

/// How per-client dataset sizes `D_i` are drawn (the paper studies the
/// Gaussian case; the scenario subsystem adds the heavier-tailed shapes
/// that related work sweeps — see `docs/SCENARIOS.md`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SizeDist {
    /// `D_i ~ N(µ, β)` using [`DataGenConfig::size_mean`] /
    /// [`DataGenConfig::size_std`] — the paper's §VI setting.
    Gaussian,
    /// `D_i ~ U[lo, hi)` — bounded heterogeneity.
    Uniform {
        /// Lower bound (samples).
        lo: f64,
        /// Upper bound (samples).
        hi: f64,
    },
    /// Zipf by client rank: `D_i ∝ (i+1)^{-s}`, scaled so the mean over
    /// the federation equals [`DataGenConfig::size_mean`]. Deterministic
    /// given the client index — no RNG draw is consumed — which makes
    /// the skew identical across seeds (only placement/labels vary).
    Zipf {
        /// Skew exponent `s` (> 0; larger = heavier head).
        exponent: f64,
    },
}

/// Generation parameters.
#[derive(Clone, Debug)]
pub struct DataGenConfig {
    /// U — number of clients to generate.
    pub num_clients: usize,
    /// (H, W, C) image dimensions (from the artifact profile).
    pub image_dims: (usize, usize, usize),
    /// Number of label classes.
    pub num_classes: usize,
    /// How `D_i` is distributed across clients.
    pub size_dist: SizeDist,
    /// µ — mean dataset size (paper: 1200).
    pub size_mean: f64,
    /// β — dataset size std (paper: 150 / 300; Gaussian only).
    pub size_std: f64,
    /// Dirichlet concentration for label skew (smaller = more skewed).
    pub dirichlet_alpha: f64,
    /// Test-set size.
    pub test_size: usize,
    /// Per-pixel noise std around the class prototype.
    pub noise_std: f64,
    /// Floor on D_i (a client must at least fill one round of batches).
    pub min_size: usize,
}

impl DataGenConfig {
    /// Defaults matching the paper's §VI setting (Gaussian sizes,
    /// µ = 1200, β = 150, Dirichlet(0.5) label skew).
    pub fn new(num_clients: usize, image_dims: (usize, usize, usize), num_classes: usize) -> Self {
        DataGenConfig {
            num_clients,
            image_dims,
            num_classes,
            size_dist: SizeDist::Gaussian,
            size_mean: 1200.0,
            size_std: 150.0,
            dirichlet_alpha: 0.5,
            test_size: 512,
            noise_std: 0.35,
            min_size: 64,
        }
    }
}

/// Gamma(α, 1) sampler (Marsaglia–Tsang; α boost for α < 1) — used for
/// Dirichlet draws.
fn gamma_sample(rng: &mut Rng, alpha: f64) -> f64 {
    if alpha < 1.0 {
        let u = rng.uniform().max(1e-12);
        return gamma_sample(rng, alpha + 1.0) * u.powf(1.0 / alpha);
    }
    let d = alpha - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = rng.normal();
        let v = (1.0 + c * x).powi(3);
        if v <= 0.0 {
            continue;
        }
        let u = rng.uniform().max(1e-12);
        if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
            return d * v;
        }
    }
}

/// Dirichlet(α, …, α) over `k` categories.
fn dirichlet(rng: &mut Rng, alpha: f64, k: usize) -> Vec<f64> {
    let draws: Vec<f64> = (0..k).map(|_| gamma_sample(rng, alpha).max(1e-12)).collect();
    let total: f64 = draws.iter().sum();
    draws.into_iter().map(|x| x / total).collect()
}

/// Generate the federation. Deterministic per seed.
pub fn generate(cfg: &DataGenConfig, seed: u64) -> Federation {
    let mut rng = Rng::seed_from(seed);
    let (h, w, c) = cfg.image_dims;
    let pix = h * w * c;

    // Class prototypes shared by every client (a single global task).
    let prototypes: Vec<f32> = (0..cfg.num_classes * pix)
        .map(|_| rng.gaussian(0.0, 1.0) as f32)
        .collect();

    let sample_into = |rng: &mut Rng, label: usize, images: &mut Vec<f32>| {
        let base = &prototypes[label * pix..(label + 1) * pix];
        for &b in base {
            images.push(b + rng.gaussian(0.0, cfg.noise_std) as f32);
        }
    };

    let zipf_norm = match cfg.size_dist {
        SizeDist::Zipf { exponent } => {
            (1..=cfg.num_clients).map(|k| (k as f64).powf(-exponent)).sum::<f64>()
        }
        _ => f64::NAN,
    };
    let mut clients = Vec::with_capacity(cfg.num_clients);
    for ci in 0..cfg.num_clients {
        let mut crng = rng.fork(ci as u64 + 1);
        // D_i per the configured distribution, floored at min_size. The
        // Gaussian arm consumes exactly the draws the pre-scenario code
        // did, so Gaussian federations are bit-identical across versions.
        let size = match cfg.size_dist {
            SizeDist::Gaussian => crng.gaussian(cfg.size_mean, cfg.size_std),
            SizeDist::Uniform { lo, hi } => crng.range(lo, hi),
            SizeDist::Zipf { exponent } => {
                cfg.size_mean * cfg.num_clients as f64
                    * ((ci + 1) as f64).powf(-exponent)
                    / zipf_norm
            }
        };
        let size = size.round().max(cfg.min_size as f64) as usize;
        // Label-skew mixture for this client.
        let mix = dirichlet(&mut crng, cfg.dirichlet_alpha, cfg.num_classes);
        let mut images = Vec::with_capacity(size * pix);
        let mut labels = Vec::with_capacity(size);
        for _ in 0..size {
            // Sample a label from the client mixture.
            let mut x = crng.uniform();
            let mut label = cfg.num_classes - 1;
            for (k, &p) in mix.iter().enumerate() {
                if x < p {
                    label = k;
                    break;
                }
                x -= p;
            }
            labels.push(label as i32);
            sample_into(&mut crng, label, &mut images);
        }
        clients.push(ClientData { size, images, labels });
    }

    // Balanced test set.
    let mut trng = rng.fork(0xdead);
    let mut images = Vec::with_capacity(cfg.test_size * pix);
    let mut labels = Vec::with_capacity(cfg.test_size);
    for i in 0..cfg.test_size {
        let label = i % cfg.num_classes;
        labels.push(label as i32);
        sample_into(&mut trng, label, &mut images);
    }
    let test = ClientData { size: cfg.test_size, images, labels };

    Federation { image_dims: cfg.image_dims, num_classes: cfg.num_classes, clients, test }
}

impl ClientData {
    /// Sample `tau` mini-batches of `batch` (with replacement), returning
    /// the stacked buffers `train_step` expects:
    /// xs `[tau*batch*pix]`, ys `[tau*batch]`.
    pub fn sample_batches(
        &self,
        rng: &mut Rng,
        tau: usize,
        batch: usize,
        pix: usize,
    ) -> (Vec<f32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(tau * batch * pix);
        let mut ys = Vec::with_capacity(tau * batch);
        for _ in 0..tau * batch {
            let idx = rng.below(self.size);
            xs.extend_from_slice(&self.images[idx * pix..(idx + 1) * pix]);
            ys.push(self.labels[idx]);
        }
        (xs, ys)
    }

    /// Label histogram (diagnostics / tests).
    pub fn label_histogram(&self, num_classes: usize) -> Vec<usize> {
        let mut h = vec![0usize; num_classes];
        for &l in &self.labels {
            h[l as usize] += 1;
        }
        h
    }
}

impl Federation {
    /// Per-client dataset sizes D_i.
    pub fn sizes(&self) -> Vec<f64> {
        self.clients.iter().map(|c| c.size as f64).collect()
    }

    /// Floats per image (H·W·C).
    pub fn pix(&self) -> usize {
        let (h, w, c) = self.image_dims;
        h * w * c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> DataGenConfig {
        let mut c = DataGenConfig::new(10, (8, 8, 1), 10);
        c.size_mean = 300.0;
        c.size_std = 60.0;
        c.test_size = 100;
        c
    }

    #[test]
    fn shapes_consistent() {
        let fed = generate(&cfg(), 1);
        assert_eq!(fed.clients.len(), 10);
        for cd in &fed.clients {
            assert_eq!(cd.images.len(), cd.size * 64);
            assert_eq!(cd.labels.len(), cd.size);
            assert!(cd.labels.iter().all(|&l| (0..10).contains(&l)));
        }
        assert_eq!(fed.test.size, 100);
    }

    #[test]
    fn sizes_follow_gaussian_roughly() {
        let mut c = cfg();
        c.num_clients = 200;
        let fed = generate(&c, 2);
        let sizes = fed.sizes();
        let mean = sizes.iter().sum::<f64>() / sizes.len() as f64;
        let std = (sizes.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / sizes.len() as f64).sqrt();
        assert!((mean - 300.0).abs() < 20.0, "mean={mean}");
        assert!((std - 60.0).abs() < 15.0, "std={std}");
    }

    #[test]
    fn zipf_sizes_skewed_and_mean_preserving() {
        let mut c = cfg();
        c.num_clients = 50;
        c.size_mean = 400.0;
        c.min_size = 1;
        c.size_dist = SizeDist::Zipf { exponent: 1.1 };
        let fed = generate(&c, 1);
        let sizes = fed.sizes();
        // Monotone non-increasing by rank, heavy head.
        for w in sizes.windows(2) {
            assert!(w[0] >= w[1], "{sizes:?}");
        }
        assert!(sizes[0] > 4.0 * sizes[sizes.len() - 1], "not skewed: {sizes:?}");
        // Mean preserved up to rounding.
        let mean = sizes.iter().sum::<f64>() / sizes.len() as f64;
        assert!((mean - 400.0).abs() < 5.0, "mean={mean}");
    }

    #[test]
    fn uniform_sizes_within_bounds() {
        let mut c = cfg();
        c.num_clients = 100;
        c.min_size = 1;
        c.size_dist = SizeDist::Uniform { lo: 100.0, hi: 200.0 };
        let fed = generate(&c, 2);
        assert!(fed.sizes().iter().all(|&d| (100.0..=200.0).contains(&d)), "{:?}", fed.sizes());
    }

    #[test]
    fn gaussian_dist_matches_legacy_default() {
        // SizeDist::Gaussian must reproduce the pre-scenario generator
        // exactly (same RNG consumption) — the fig-regression anchor.
        let a = generate(&cfg(), 7);
        let mut c2 = cfg();
        c2.size_dist = SizeDist::Gaussian;
        let b = generate(&c2, 7);
        assert_eq!(a.sizes(), b.sizes());
        assert_eq!(a.clients[3].images, b.clients[3].images);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&cfg(), 7);
        let b = generate(&cfg(), 7);
        assert_eq!(a.clients[0].images, b.clients[0].images);
        let c = generate(&cfg(), 8);
        assert_ne!(a.clients[0].images, c.clients[0].images);
    }

    #[test]
    fn label_skew_present() {
        // With α = 0.5 the per-client label histograms must be visibly
        // non-uniform for at least some clients.
        let fed = generate(&cfg(), 3);
        let mut max_frac: f64 = 0.0;
        for cd in &fed.clients {
            let h = cd.label_histogram(10);
            let top = *h.iter().max().unwrap() as f64 / cd.size as f64;
            max_frac = max_frac.max(top);
        }
        assert!(max_frac > 0.25, "no skew detected: {max_frac}");
    }

    #[test]
    fn test_set_balanced() {
        let fed = generate(&cfg(), 4);
        let h = fed.test.label_histogram(10);
        assert!(h.iter().all(|&n| n == 10), "{h:?}");
    }

    #[test]
    fn batch_sampling_shapes() {
        let fed = generate(&cfg(), 5);
        let mut rng = Rng::seed_from(9);
        let (xs, ys) = fed.clients[0].sample_batches(&mut rng, 6, 16, 64);
        assert_eq!(xs.len(), 6 * 16 * 64);
        assert_eq!(ys.len(), 6 * 16);
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut rng = Rng::seed_from(11);
        for alpha in [0.1, 0.5, 1.0, 10.0] {
            let d = dirichlet(&mut rng, alpha, 8);
            let s: f64 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&x| x > 0.0));
        }
    }

    #[test]
    fn gamma_mean_matches_alpha() {
        let mut rng = Rng::seed_from(13);
        for alpha in [0.5, 2.0, 5.0] {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| gamma_sample(&mut rng, alpha)).sum::<f64>() / n as f64;
            assert!((mean - alpha).abs() < 0.1 * alpha.max(1.0), "alpha={alpha} mean={mean}");
        }
    }
}
