//! Deterministic **checkpoint/resume** subsystem: a versioned,
//! length-prefixed binary snapshot codec for complete run state, so
//! long-horizon runs (`stress-1000`) and multi-hundred-run sweeps
//! survive crashes and preemption with **bit-identical** restart.
//!
//! The paper's long-term constraints make mid-horizon state first-class
//! data: the Lyapunov virtual queues λ1/λ2 accumulate across the whole
//! horizon T (eqs. (23)–(24)), the per-client `q_prev` anchors the
//! Case-5 Taylor expansion, and every stochastic component draws from an
//! explicitly positioned RNG stream. A [`Snapshot`] captures all of it —
//! round index, θ, queues (with history), per-client estimator/anchor
//! state and RNG streams, the server and scheduler streams, the PJRT
//! profiling clock — plus the **resolved scenario text**
//! ([`crate::scenario::render`]) and (algorithm, seed), so a resume
//! against the wrong workload is a typed mismatch error, not a silently
//! diverging run.
//!
//! # Wire format (version 3)
//!
//! ```text
//! magic    4 B   "QCKP"
//! version  4 B   u32 LE (currently 3)
//! length   8 B   u64 LE — payload byte count
//! payload  N B   the Snapshot fields (see docs/CHECKPOINTS.md)
//! crc32    4 B   u32 LE — CRC32 (IEEE) of the payload
//! ```
//!
//! Every read-side failure is a typed [`CkptError`] — truncation,
//! wrong magic/version, CRC mismatch, trailing bytes, or a structurally
//! inconsistent payload — mirroring the `WireError` hardening of the
//! byte-transport PR: a damaged snapshot is rejected, never zero-filled
//! into a half-restored server.
//!
//! # Determinism contract
//!
//! A run checkpointed after round k and resumed produces a trace
//! **bit-identical** to the uninterrupted run, for any `--threads`
//! value on either side of the split (the engine's PR-1 contract makes
//! thread count a non-input; `tests/integration_ckpt.rs` pins both).
//! Snapshots are written atomically (tmp + fsync + rename, see
//! [`crate::util::fsio`]) so a crash mid-write leaves the previous
//! snapshot intact.
//!
//! This module is deliberately **observability-free**: snapshot bytes
//! are part of the bit-identity contract, so no wall-clock type from
//! [`crate::obs`] may appear here (detlint rule R7). Write timing is
//! measured by the *caller* with a `CheckpointWrite` span
//! ([`crate::obs::spans`]), and the embedded trace's side-channel
//! wall columns are zeroed before capture (docs/OBSERVABILITY.md).

// Snapshot decode must degrade into typed CkptErrors, never an
// `unwrap()` panic on attacker-shaped bytes; scope clippy's unwrap ban
// to this subsystem (see fl/mod.rs for the policy note).
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

pub mod codec;

use std::path::Path;

use anyhow::Context as _;

use crate::metrics::{RoundRecord, Trace};
use crate::util::rng::RngState;
use codec::{crc32, Reader, Writer};

/// Snapshot file magic ("QCKP").
pub const MAGIC: [u8; 4] = *b"QCKP";

/// Current (and only supported) snapshot format version. Bump on any
/// payload-layout change; old versions are rejected with
/// [`CkptError::Version`], never reinterpreted (versioning policy:
/// docs/CHECKPOINTS.md). Version 2 added the per-round `departed`
/// count and the optional availability-process state
/// ([`RunState::avail`]). Version 3 added the per-round
/// `retries`/`failed_decodes` counts and the optional fault-plan state
/// ([`RunState::faults`]).
pub const VERSION: u32 = 3;

/// File-name extension snapshots are written under.
pub const EXTENSION: &str = "qckpt";

/// Everything wrong a snapshot buffer can be. Every variant is a
/// *rejection* — the decoder never patches over damage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CkptError {
    /// Buffer shorter than the envelope + declared payload + CRC.
    Truncated {
        /// Bytes the envelope requires.
        expected: usize,
        /// Bytes actually presented.
        got: usize,
    },
    /// First four bytes are not [`MAGIC`] — not a snapshot file.
    Magic {
        /// The bytes found where the magic should be.
        got: [u8; 4],
    },
    /// Unsupported format version (future or corrupt).
    Version {
        /// Version declared by the buffer.
        got: u32,
        /// The version this build supports.
        supported: u32,
    },
    /// Payload failed its CRC32 seal — corrupted in storage or flight.
    Crc {
        /// CRC recorded in the envelope.
        expected: u32,
        /// CRC computed over the presented payload.
        got: u32,
    },
    /// Bytes beyond the envelope's declared end.
    Trailing {
        /// How many extra bytes follow the envelope.
        extra: usize,
    },
    /// Payload passed the CRC but its structure is inconsistent (a
    /// field lies about a length/tag) — names the field that broke.
    Malformed {
        /// The field being decoded when the structure broke.
        what: &'static str,
    },
}

impl std::fmt::Display for CkptError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CkptError::Truncated { expected, got } => write!(
                f,
                "snapshot truncated: {got} bytes, envelope requires {expected}"
            ),
            CkptError::Magic { got } => {
                write!(f, "not a snapshot: magic {got:02x?} != {MAGIC:02x?} (\"QCKP\")")
            }
            CkptError::Version { got, supported } => write!(
                f,
                "unsupported snapshot version {got} (this build reads version {supported}; \
                 see docs/CHECKPOINTS.md for the versioning policy)"
            ),
            CkptError::Crc { expected, got } => write!(
                f,
                "snapshot payload corrupt: CRC32 {got:#010x} != recorded {expected:#010x}"
            ),
            CkptError::Trailing { extra } => {
                write!(f, "snapshot has {extra} trailing byte(s) past the envelope")
            }
            CkptError::Malformed { what } => {
                write!(f, "snapshot payload malformed while decoding `{what}`")
            }
        }
    }
}

impl std::error::Error for CkptError {}

/// One client's resumable coordinator-side state: the Ĝ/σ̂ estimator,
/// the θ^max estimate, the Case-5 `q_prev` anchor, and the private RNG
/// stream position.
#[derive(Clone, Debug)]
pub struct ClientCkpt {
    /// `GradStats::g` — estimated G_i.
    pub g: f64,
    /// `GradStats::sigma` — estimated σ_i.
    pub sigma: f64,
    /// `GradStats::ema` — estimator smoothing factor.
    pub ema: f64,
    /// `GradStats::observed` — whether any observation arrived.
    pub observed: bool,
    /// Decision-time θ^max estimate.
    pub theta_max: f64,
    /// Last *quantized* participation level (Case-5 anchor).
    pub q_prev: f64,
    /// Private noise-stream position (data sampling + quantization).
    pub rng: RngState,
}

/// One client's resumable availability state: the on/off flag, the
/// staleness counter (rounds since the client's update last entered an
/// aggregate), and the private churn-stream position. Captured by
/// [`crate::fl::avail::AvailProcess::checkpoint`], reinstalled by
/// `AvailProcess::restore` — a resumed churn run replays the exact
/// join/leave future of the uninterrupted one.
#[derive(Clone, Debug)]
pub struct AvailCkpt {
    /// Whether the client is currently available.
    pub on: bool,
    /// Rounds since this client's update was last aggregated.
    pub missed: u64,
    /// Private churn-stream position.
    pub rng: RngState,
}

/// The resumable fault-injection state: every per-client fault-stream
/// position (ascending client id) plus the plan-level
/// checkpoint-corruption stream. Captured by
/// [`crate::fl::faults::FaultPlan::checkpoint`], reinstalled by
/// `FaultPlan::restore` — a resumed chaos run replays the exact fault
/// future of the uninterrupted one.
#[derive(Clone, Debug)]
pub struct FaultsCkpt {
    /// Per-client fault-stream positions, ascending client id.
    pub rngs: Vec<RngState>,
    /// Plan-level checkpoint-corruption stream position.
    pub ckpt_rng: RngState,
}

/// The complete resumable state of a [`crate::fl::Server`] mid-horizon.
/// Captured by `Server::checkpoint_state`, reinstalled by
/// `Server::restore_state` over a freshly constructed server (same
/// scenario, algorithm, seed — the static parts replay from those).
#[derive(Clone, Debug)]
pub struct RunState {
    /// Communication rounds completed.
    pub round: u64,
    /// ε1 as currently (possibly auto-)calibrated.
    pub eps1: f64,
    /// ε2 as currently (possibly auto-)calibrated.
    pub eps2: f64,
    /// Global model θ^n.
    pub theta: Vec<f32>,
    /// Virtual queue λ1 (C6).
    pub lambda1: f64,
    /// Virtual queue λ2 (C7).
    pub lambda2: f64,
    /// `(λ1, λ2)` after every update, starting at `(0, 0)` — the
    /// mean-rate-stability diagnostic depends on its length.
    pub queue_history: Vec<(f64, f64)>,
    /// Per-client estimator/anchor/RNG state, ascending client id.
    pub clients: Vec<ClientCkpt>,
    /// The server's master RNG stream (channel draws).
    pub server_rng: RngState,
    /// The scheduler's private RNG stream (GA-based schedulers;
    /// `None` for stateless policies).
    pub sched_rng: Option<RngState>,
    /// Per-client availability-process state, ascending client id
    /// (`None` for runs without churn).
    pub avail: Option<Vec<AvailCkpt>>,
    /// Fault-plan stream positions (`None` for runs without chaos).
    pub faults: Option<FaultsCkpt>,
    /// The PJRT runtime's cumulative per-entry-point nanosecond clock
    /// `(init, train_step, eval, quantize)` as observed at capture.
    /// Reinstalled only by callers that own the runtime exclusively
    /// (`CheckpointPolicy::restore_runtime_clock`) so a resumed
    /// `exec_profile` continues instead of restarting at zero; a
    /// parallel sweep's shared runtime is never clobbered.
    pub runtime_nanos: [u64; 4],
}

/// A complete run snapshot: identity (resolved scenario text +
/// algorithm + seed, for mismatch detection on resume), the mid-horizon
/// [`RunState`], and the trace of every completed round (so the resumed
/// run emits the *whole* trace, bit-identical to uninterrupted).
#[derive(Clone, Debug)]
pub struct Snapshot {
    /// Canonical render of the resolved scenario
    /// ([`crate::scenario::render`]); resume fails on any mismatch.
    pub scenario_text: String,
    /// Algorithm the run executes.
    pub algorithm: String,
    /// Master seed of the run.
    pub seed: u64,
    /// Mid-horizon server state.
    pub state: RunState,
    /// Records of the rounds completed so far.
    pub trace: Trace,
}

/// The canonical file stem of one (scenario, algorithm, seed) run:
/// `<scenario>__<algorithm>__seed<seed>`. The single definition behind
/// both the sweep's JSONL trace names and [`snapshot_file_name`], so
/// the naming contract is structural, not convention.
pub fn unit_stem(scenario: &str, algorithm: &str, seed: u64) -> String {
    format!("{scenario}__{algorithm}__seed{seed}")
}

/// Canonical snapshot file name for a (scenario, algorithm, seed) run —
/// [`unit_stem`] plus the [`EXTENSION`].
pub fn snapshot_file_name(scenario: &str, algorithm: &str, seed: u64) -> String {
    format!("{}.{EXTENSION}", unit_stem(scenario, algorithm, seed))
}

fn write_rng(w: &mut Writer, st: &RngState) {
    for s in st.s {
        w.u64(s);
    }
    w.opt_f64(st.spare);
}

fn read_rng(r: &mut Reader<'_>, what: &'static str) -> Result<RngState, CkptError> {
    let mut s = [0u64; 4];
    for v in &mut s {
        *v = r.u64(what)?;
    }
    Ok(RngState { s, spare: r.opt_f64(what)? })
}

fn write_record(w: &mut Writer, rec: &RoundRecord) {
    w.u64(rec.round as u64);
    w.u64(rec.scheduled as u64);
    w.u64(rec.aggregated as u64);
    w.u64(rec.departed as u64);
    w.u64(rec.retries as u64);
    w.u64(rec.failed_decodes as u64);
    w.u64(rec.wire_bytes as u64);
    w.f64(rec.energy);
    w.f64(rec.cum_energy);
    w.f64(rec.train_loss);
    w.opt_f64(rec.test_loss);
    w.opt_f64(rec.test_acc);
    w.f64(rec.mean_q);
    w.u64(rec.q_per_client.len() as u64);
    for q in &rec.q_per_client {
        w.opt_u32(*q);
    }
    w.f64(rec.lambda1);
    w.f64(rec.lambda2);
    w.f64(rec.max_latency);
    w.f64(rec.decide_seconds);
    w.f64(rec.compute_seconds);
}

fn read_record(r: &mut Reader<'_>) -> Result<RoundRecord, CkptError> {
    let round = r.u64("record.round")? as usize;
    let scheduled = r.u64("record.scheduled")? as usize;
    let aggregated = r.u64("record.aggregated")? as usize;
    let departed = r.u64("record.departed")? as usize;
    let retries = r.u64("record.retries")? as usize;
    let failed_decodes = r.u64("record.failed_decodes")? as usize;
    let wire_bytes = r.u64("record.wire_bytes")? as usize;
    let energy = r.f64("record.energy")?;
    let cum_energy = r.f64("record.cum_energy")?;
    let train_loss = r.f64("record.train_loss")?;
    let test_loss = r.opt_f64("record.test_loss")?;
    let test_acc = r.opt_f64("record.test_acc")?;
    let mean_q = r.f64("record.mean_q")?;
    let nq = r.seq_len(1, "record.q_per_client")?;
    let mut q_per_client = Vec::with_capacity(nq);
    for _ in 0..nq {
        q_per_client.push(r.opt_u32("record.q_per_client")?);
    }
    Ok(RoundRecord {
        round,
        scheduled,
        aggregated,
        departed,
        retries,
        failed_decodes,
        wire_bytes,
        energy,
        cum_energy,
        train_loss,
        test_loss,
        test_acc,
        mean_q,
        q_per_client,
        lambda1: r.f64("record.lambda1")?,
        lambda2: r.f64("record.lambda2")?,
        max_latency: r.f64("record.max_latency")?,
        decide_seconds: r.f64("record.decide_seconds")?,
        compute_seconds: r.f64("record.compute_seconds")?,
    })
}

impl Snapshot {
    /// Serialize to the versioned envelope (magic + version + length +
    /// payload + CRC32).
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.string(&self.scenario_text);
        w.string(&self.algorithm);
        w.u64(self.seed);

        let st = &self.state;
        w.u64(st.round);
        w.f64(st.eps1);
        w.f64(st.eps2);
        w.u64(st.theta.len() as u64);
        for &x in &st.theta {
            w.f32(x);
        }
        w.f64(st.lambda1);
        w.f64(st.lambda2);
        w.u64(st.queue_history.len() as u64);
        for &(a, b) in &st.queue_history {
            w.f64(a);
            w.f64(b);
        }
        w.u64(st.clients.len() as u64);
        for c in &st.clients {
            w.f64(c.g);
            w.f64(c.sigma);
            w.f64(c.ema);
            w.bool(c.observed);
            w.f64(c.theta_max);
            w.f64(c.q_prev);
            write_rng(&mut w, &c.rng);
        }
        write_rng(&mut w, &st.server_rng);
        match &st.sched_rng {
            Some(rng) => {
                w.bool(true);
                write_rng(&mut w, rng);
            }
            None => w.bool(false),
        }
        match &st.avail {
            Some(avail) => {
                w.bool(true);
                w.u64(avail.len() as u64);
                for a in avail {
                    w.bool(a.on);
                    w.u64(a.missed);
                    write_rng(&mut w, &a.rng);
                }
            }
            None => w.bool(false),
        }
        match &st.faults {
            Some(f) => {
                w.bool(true);
                w.u64(f.rngs.len() as u64);
                for rng in &f.rngs {
                    write_rng(&mut w, rng);
                }
                write_rng(&mut w, &f.ckpt_rng);
            }
            None => w.bool(false),
        }
        for n in st.runtime_nanos {
            w.u64(n);
        }

        w.string(&self.trace.algorithm);
        w.u64(self.trace.records.len() as u64);
        for rec in &self.trace.records {
            write_record(&mut w, rec);
        }

        let payload = w.into_bytes();
        let mut out = Vec::with_capacity(payload.len() + 20);
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        let crc = crc32(&payload);
        out.extend_from_slice(&payload);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    /// Decode a snapshot buffer, validating the complete envelope
    /// (magic, version, length, CRC) **before** touching the payload.
    pub fn decode(bytes: &[u8]) -> Result<Snapshot, CkptError> {
        const HEADER: usize = 16; // magic + version + length
        if bytes.len() < HEADER {
            return Err(CkptError::Truncated { expected: HEADER + 4, got: bytes.len() });
        }
        if bytes[..4] != MAGIC {
            return Err(CkptError::Magic { got: [bytes[0], bytes[1], bytes[2], bytes[3]] });
        }
        let version = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]);
        if version != VERSION {
            return Err(CkptError::Version { got: version, supported: VERSION });
        }
        let len = u64::from_le_bytes([
            bytes[8], bytes[9], bytes[10], bytes[11], bytes[12], bytes[13], bytes[14], bytes[15],
        ]);
        let total = (HEADER as u64).saturating_add(len).saturating_add(4);
        if (bytes.len() as u64) < total {
            return Err(CkptError::Truncated {
                expected: total.min(usize::MAX as u64) as usize,
                got: bytes.len(),
            });
        }
        if (bytes.len() as u64) > total {
            return Err(CkptError::Trailing { extra: (bytes.len() as u64 - total) as usize });
        }
        let payload = &bytes[HEADER..HEADER + len as usize];
        let tail = &bytes[HEADER + len as usize..];
        let recorded = u32::from_le_bytes([tail[0], tail[1], tail[2], tail[3]]);
        let computed = crc32(payload);
        if recorded != computed {
            return Err(CkptError::Crc { expected: recorded, got: computed });
        }

        let mut r = Reader::new(payload);
        let scenario_text = r.string("scenario_text")?;
        let algorithm = r.string("algorithm")?;
        let seed = r.u64("seed")?;

        let round = r.u64("state.round")?;
        let eps1 = r.f64("state.eps1")?;
        let eps2 = r.f64("state.eps2")?;
        let nz = r.seq_len(4, "state.theta")?;
        let mut theta = Vec::with_capacity(nz);
        for _ in 0..nz {
            theta.push(r.f32("state.theta")?);
        }
        let lambda1 = r.f64("state.lambda1")?;
        let lambda2 = r.f64("state.lambda2")?;
        let nh = r.seq_len(16, "state.queue_history")?;
        let mut queue_history = Vec::with_capacity(nh);
        for _ in 0..nh {
            let a = r.f64("state.queue_history")?;
            let b = r.f64("state.queue_history")?;
            queue_history.push((a, b));
        }
        let nc = r.seq_len(8 * 7 + 1 + 1, "state.clients")?;
        let mut clients = Vec::with_capacity(nc);
        for _ in 0..nc {
            clients.push(ClientCkpt {
                g: r.f64("client.g")?,
                sigma: r.f64("client.sigma")?,
                ema: r.f64("client.ema")?,
                observed: r.bool("client.observed")?,
                theta_max: r.f64("client.theta_max")?,
                q_prev: r.f64("client.q_prev")?,
                rng: read_rng(&mut r, "client.rng")?,
            });
        }
        let server_rng = read_rng(&mut r, "state.server_rng")?;
        let sched_rng = if r.bool("state.sched_rng")? {
            Some(read_rng(&mut r, "state.sched_rng")?)
        } else {
            None
        };
        let avail = if r.bool("state.avail")? {
            let na = r.seq_len(1 + 8 + 8, "state.avail")?;
            let mut avail = Vec::with_capacity(na);
            for _ in 0..na {
                avail.push(AvailCkpt {
                    on: r.bool("avail.on")?,
                    missed: r.u64("avail.missed")?,
                    rng: read_rng(&mut r, "avail.rng")?,
                });
            }
            Some(avail)
        } else {
            None
        };
        let faults = if r.bool("state.faults")? {
            let nf = r.seq_len(8 * 4 + 1, "state.faults")?;
            let mut rngs = Vec::with_capacity(nf);
            for _ in 0..nf {
                rngs.push(read_rng(&mut r, "faults.rng")?);
            }
            Some(FaultsCkpt { rngs, ckpt_rng: read_rng(&mut r, "faults.ckpt_rng")? })
        } else {
            None
        };
        let mut runtime_nanos = [0u64; 4];
        for n in &mut runtime_nanos {
            *n = r.u64("state.runtime_nanos")?;
        }

        let trace_algorithm = r.string("trace.algorithm")?;
        let nr = r.seq_len(8, "trace.records")?;
        let mut records = Vec::with_capacity(nr);
        for _ in 0..nr {
            records.push(read_record(&mut r)?);
        }
        r.finish("payload end")?;

        Ok(Snapshot {
            scenario_text,
            algorithm,
            seed,
            state: RunState {
                round,
                eps1,
                eps2,
                theta,
                lambda1,
                lambda2,
                queue_history,
                clients,
                server_rng,
                sched_rng,
                avail,
                faults,
                runtime_nanos,
            },
            trace: Trace { algorithm: trace_algorithm, records },
        })
    }

    /// Write the snapshot **atomically** (tmp + fsync + rename): a
    /// crash mid-write leaves the previous snapshot — or no file —
    /// never a torn one.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        let bytes = self.encode();
        crate::util::fsio::write_atomic(path, &bytes)
            .with_context(|| format!("write snapshot {}", path.display()))
    }

    /// Read and decode a snapshot file.
    pub fn load(path: &Path) -> anyhow::Result<Snapshot> {
        let bytes =
            std::fs::read(path).with_context(|| format!("read snapshot {}", path.display()))?;
        Snapshot::decode(&bytes)
            .with_context(|| format!("decode snapshot {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A small but fully populated snapshot exercising every field
    /// shape (NaN loss, None/Some options, empty and non-empty vecs).
    pub(crate) fn sample_snapshot() -> Snapshot {
        let rng = |k: u64| RngState {
            s: [k, k ^ 0xABCD, k.wrapping_mul(31), !k],
            spare: if k % 2 == 0 { Some(0.25 * k as f64) } else { None },
        };
        let mut rec = RoundRecord {
            round: 3,
            scheduled: 5,
            aggregated: 4,
            departed: 1,
            retries: 2,
            failed_decodes: 1,
            wire_bytes: 12_345,
            energy: 0.75,
            cum_energy: 2.5,
            train_loss: f64::NAN,
            test_loss: Some(1.25),
            test_acc: None,
            mean_q: 6.5,
            q_per_client: vec![Some(4), None, Some(0), Some(31)],
            lambda1: 17.0,
            lambda2: 0.125,
            max_latency: 0.019,
            decide_seconds: 0.5,
            compute_seconds: 1.5,
        };
        let mut trace = Trace::new("qccf");
        trace.push(rec.clone());
        rec.round = 4;
        rec.test_loss = None;
        trace.push(rec);
        Snapshot {
            scenario_text: "[scenario]\nname = \"demo\"\n".into(),
            algorithm: "qccf".into(),
            seed: 42,
            state: RunState {
                round: 4,
                eps1: 30.5,
                eps2: 0.001,
                theta: vec![0.5, -1.25, f32::NAN, 0.0],
                lambda1: 17.0,
                lambda2: 0.125,
                queue_history: vec![(0.0, 0.0), (3.0, 0.5), (17.0, 0.125)],
                clients: (0..3)
                    .map(|i| ClientCkpt {
                        g: 1.0 + i as f64,
                        sigma: 0.5,
                        ema: 0.5,
                        observed: i > 0,
                        theta_max: 0.4,
                        q_prev: 4.0 + i as f64,
                        rng: rng(1000 + i as u64),
                    })
                    .collect(),
                server_rng: rng(7),
                sched_rng: Some(rng(9)),
                avail: Some(
                    (0..3)
                        .map(|i| AvailCkpt {
                            on: i != 1,
                            missed: i as u64 * 3,
                            rng: rng(2000 + i as u64),
                        })
                        .collect(),
                ),
                faults: Some(FaultsCkpt {
                    rngs: (0..3).map(|i| rng(3000 + i as u64)).collect(),
                    ckpt_rng: rng(4000),
                }),
                runtime_nanos: [1, 2, 3, 4],
            },
            trace,
        }
    }

    #[test]
    fn round_trip_is_bit_exact() {
        let snap = sample_snapshot();
        let bytes = snap.encode();
        let back = Snapshot::decode(&bytes).unwrap();
        // Re-encoding the decoded snapshot must reproduce the exact
        // bytes — which covers every field bit-for-bit, NaNs included.
        assert_eq!(back.encode(), bytes);
        assert_eq!(back.scenario_text, snap.scenario_text);
        assert_eq!(back.algorithm, "qccf");
        assert_eq!(back.seed, 42);
        assert_eq!(back.state.round, 4);
        assert_eq!(back.state.theta[2].to_bits(), f32::NAN.to_bits());
        assert!(back.trace.records[0].train_loss.is_nan());
        assert_eq!(back.trace.records.len(), 2);
        assert_eq!(back.state.sched_rng, snap.state.sched_rng);
        let avail = back.state.avail.as_ref().unwrap();
        assert_eq!(avail.len(), 3);
        assert!(!avail[1].on && avail[2].missed == 6);
        assert_eq!(back.trace.records[0].departed, 1);
        let faults = back.state.faults.as_ref().unwrap();
        assert_eq!(faults.rngs.len(), 3);
        assert_eq!(faults.ckpt_rng, snap.state.faults.as_ref().unwrap().ckpt_rng);
        assert_eq!(back.trace.records[0].retries, 2);
        assert_eq!(back.trace.records[0].failed_decodes, 1);
    }

    #[test]
    fn envelope_errors_are_typed() {
        let snap = sample_snapshot();
        let bytes = snap.encode();

        // Truncation anywhere yields Truncated.
        for cut in [0, 3, 15, 16, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                matches!(Snapshot::decode(&bytes[..cut]), Err(CkptError::Truncated { .. })),
                "cut={cut}"
            );
        }
        // Wrong magic.
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(matches!(Snapshot::decode(&bad), Err(CkptError::Magic { .. })));
        // Future version.
        let mut bad = bytes.clone();
        bad[4..8].copy_from_slice(&(VERSION + 1).to_le_bytes());
        assert_eq!(
            Snapshot::decode(&bad).unwrap_err(),
            CkptError::Version { got: VERSION + 1, supported: VERSION }
        );
        // Payload bit-flip is caught by the CRC.
        let mut bad = bytes.clone();
        bad[40] ^= 0x10;
        assert!(matches!(Snapshot::decode(&bad), Err(CkptError::Crc { .. })));
        // Trailing garbage.
        let mut bad = bytes.clone();
        bad.push(0);
        assert_eq!(Snapshot::decode(&bad).unwrap_err(), CkptError::Trailing { extra: 1 });
    }

    #[test]
    fn save_load_round_trips_and_is_atomic() {
        let dir = std::env::temp_dir().join("qccf_ckpt_save_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(snapshot_file_name("demo", "qccf", 42));
        let snap = sample_snapshot();
        snap.save(&path).unwrap();
        // No .tmp residue after a successful atomic write.
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert!(names.iter().all(|n| !n.ends_with(".tmp")), "{names:?}");
        let back = Snapshot::load(&path).unwrap();
        assert_eq!(back.encode(), snap.encode());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn snapshot_file_name_matches_sweep_stem() {
        assert_eq!(
            snapshot_file_name("paper-femnist", "qccf", 3),
            "paper-femnist__qccf__seed3.qckpt"
        );
    }
}
