//! Byte-level primitives for the snapshot codec: a little-endian
//! [`Writer`]/[`Reader`] pair plus the CRC32 the envelope seals the
//! payload with.
//!
//! Floats are moved as their IEEE-754 bit patterns (`to_bits` /
//! `from_bits`), so NaN payloads — e.g. the NaN `train_loss` of an
//! empty round — survive a round-trip **bit for bit**; equality of the
//! re-encoded bytes is the round-trip test, not `==` on floats.
//!
//! The [`Reader`] only ever runs over a payload the envelope has
//! already length- and CRC-validated, so a short or inconsistent read
//! here means the payload *structure* lies about itself (a corrupted
//! length field that still passed CRC can only come from an encoder
//! bug) — every failure maps to [`CkptError::Malformed`] naming the
//! field, never a silent zero-fill.

use super::CkptError;

/// CRC32 (IEEE 802.3, reflected 0xEDB88320) lookup table, built at
/// compile time so the hot path is one table lookup per byte.
const fn make_crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

const CRC_TABLE: [u32; 256] = make_crc_table();

/// CRC32 (IEEE) of `bytes` — the envelope's corruption seal.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// Append-only little-endian byte writer for the snapshot payload.
#[derive(Debug, Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    /// Empty writer.
    pub fn new() -> Writer {
        Writer::default()
    }

    /// The accumulated payload bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// One raw byte.
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// u32, little-endian.
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// u64, little-endian.
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// f32 as its IEEE-754 bit pattern (NaN-preserving).
    pub fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    /// f64 as its IEEE-754 bit pattern (NaN-preserving).
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    /// bool as a 0/1 byte (any other value is rejected on decode).
    pub fn bool(&mut self, v: bool) {
        self.u8(v as u8);
    }

    /// Length-prefixed UTF-8 string (u64 byte count + bytes).
    pub fn string(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// `Option<f64>` as a 0/1 tag byte plus the payload when present.
    pub fn opt_f64(&mut self, v: Option<f64>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.f64(x);
            }
            None => self.u8(0),
        }
    }

    /// `Option<u32>` as a 0/1 tag byte plus the payload when present.
    pub fn opt_u32(&mut self, v: Option<u32>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.u32(x);
            }
            None => self.u8(0),
        }
    }
}

/// Cursor over a CRC-validated payload; every read names the field it
/// was pulling so a [`CkptError::Malformed`] pinpoints the break.
#[derive(Debug)]
pub struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// Cursor at the start of `bytes`.
    pub fn new(bytes: &'a [u8]) -> Reader<'a> {
        Reader { bytes, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.bytes.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], CkptError> {
        if self.remaining() < n {
            return Err(CkptError::Malformed { what });
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// One raw byte.
    pub fn u8(&mut self, what: &'static str) -> Result<u8, CkptError> {
        Ok(self.take(1, what)?[0])
    }

    /// u32, little-endian.
    pub fn u32(&mut self, what: &'static str) -> Result<u32, CkptError> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// u64, little-endian.
    pub fn u64(&mut self, what: &'static str) -> Result<u64, CkptError> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// f32 from its bit pattern.
    pub fn f32(&mut self, what: &'static str) -> Result<f32, CkptError> {
        Ok(f32::from_bits(self.u32(what)?))
    }

    /// f64 from its bit pattern.
    pub fn f64(&mut self, what: &'static str) -> Result<f64, CkptError> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    /// bool from a strict 0/1 byte.
    pub fn bool(&mut self, what: &'static str) -> Result<bool, CkptError> {
        match self.u8(what)? {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(CkptError::Malformed { what }),
        }
    }

    /// A sequence length: u64, validated against the bytes actually
    /// remaining (each element needs at least `min_elem_bytes`), so a
    /// lying length field fails here instead of in a huge allocation.
    pub fn seq_len(
        &mut self,
        min_elem_bytes: usize,
        what: &'static str,
    ) -> Result<usize, CkptError> {
        let n = self.u64(what)?;
        let max = (self.remaining() / min_elem_bytes.max(1)) as u64;
        if n > max {
            return Err(CkptError::Malformed { what });
        }
        Ok(n as usize)
    }

    /// Length-prefixed UTF-8 string.
    pub fn string(&mut self, what: &'static str) -> Result<String, CkptError> {
        let n = self.seq_len(1, what)?;
        let bytes = self.take(n, what)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| CkptError::Malformed { what })
    }

    /// `Option<f64>` (strict 0/1 tag).
    pub fn opt_f64(&mut self, what: &'static str) -> Result<Option<f64>, CkptError> {
        Ok(if self.bool(what)? { Some(self.f64(what)?) } else { None })
    }

    /// `Option<u32>` (strict 0/1 tag).
    pub fn opt_u32(&mut self, what: &'static str) -> Result<Option<u32>, CkptError> {
        Ok(if self.bool(what)? { Some(self.u32(what)?) } else { None })
    }

    /// Assert the payload was consumed exactly.
    pub fn finish(&self, what: &'static str) -> Result<(), CkptError> {
        if self.remaining() != 0 {
            return Err(CkptError::Malformed { what });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // The classic IEEE test vector plus the empty string.
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn primitives_round_trip() {
        let mut w = Writer::new();
        w.u8(7);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 3);
        w.f32(f32::NAN);
        w.f64(-0.0);
        w.bool(true);
        w.string("héllo");
        w.opt_f64(Some(f64::INFINITY));
        w.opt_f64(None);
        w.opt_u32(Some(9));
        w.opt_u32(None);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.u8("a").unwrap(), 7);
        assert_eq!(r.u32("b").unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64("c").unwrap(), u64::MAX - 3);
        assert_eq!(r.f32("d").unwrap().to_bits(), f32::NAN.to_bits());
        assert_eq!(r.f64("e").unwrap().to_bits(), (-0.0f64).to_bits());
        assert!(r.bool("f").unwrap());
        assert_eq!(r.string("g").unwrap(), "héllo");
        assert_eq!(r.opt_f64("h").unwrap(), Some(f64::INFINITY));
        assert_eq!(r.opt_f64("i").unwrap(), None);
        assert_eq!(r.opt_u32("j").unwrap(), Some(9));
        assert_eq!(r.opt_u32("k").unwrap(), None);
        r.finish("end").unwrap();
    }

    // Prefixed `miri_` so verify.sh's nightly gate runs it
    // (`cargo +nightly miri test --lib miri_`): a compact sweep of the
    // codec's pointer/length arithmetic — the byte-slice reads, the
    // UTF-8 reinterpretation, and the CRC table walk — under Miri's
    // UB checks, sized to stay fast in the interpreter.
    #[test]
    fn miri_primitives_round_trip_smoke() {
        let mut w = Writer::new();
        w.u32(42);
        w.f64(f64::NAN);
        w.string("miri");
        w.bool(false);
        let bytes = w.into_bytes();
        assert_ne!(crc32(&bytes), 0);
        let mut r = Reader::new(&bytes);
        assert_eq!(r.u32("a").unwrap(), 42);
        assert_eq!(r.f64("b").unwrap().to_bits(), f64::NAN.to_bits());
        assert_eq!(r.string("c").unwrap(), "miri");
        assert!(!r.bool("d").unwrap());
        r.finish("end").unwrap();
    }

    #[test]
    fn reader_rejects_bad_shapes() {
        // Short read names the field.
        let mut r = Reader::new(&[1, 2]);
        assert_eq!(r.u32("field").unwrap_err(), CkptError::Malformed { what: "field" });
        // Non-0/1 bool byte.
        let mut r = Reader::new(&[2]);
        assert!(matches!(r.bool("flag"), Err(CkptError::Malformed { what: "flag" })));
        // A length field claiming more elements than bytes remain.
        let mut w = Writer::new();
        w.u64(1_000_000);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert!(r.seq_len(4, "vec").is_err());
        // Invalid UTF-8 in a string payload.
        let mut w = Writer::new();
        w.u64(2);
        w.u8(0xFF);
        w.u8(0xFE);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert!(r.string("s").is_err());
        // Unconsumed payload bytes.
        let r = Reader::new(&[0]);
        assert!(r.finish("end").is_err());
    }
}
