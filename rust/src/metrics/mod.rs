//! Per-round metric records + trace recorder (CSV/JSON export). The
//! experiment harness aggregates these into the paper's figure series.

use std::path::Path;

use crate::util::csv::CsvWriter;

/// Everything measured in one communication round.
#[derive(Clone, Debug, Default)]
pub struct RoundRecord {
    pub round: usize,
    /// Participants scheduled / uploads aggregated (dropouts = diff).
    pub scheduled: usize,
    pub aggregated: usize,
    /// Energy spent this round (J) and cumulative (J).
    pub energy: f64,
    pub cum_energy: f64,
    /// Mean training loss reported by participating clients.
    pub train_loss: f64,
    /// Test metrics (only on eval rounds).
    pub test_loss: Option<f64>,
    pub test_acc: Option<f64>,
    /// Mean quantization level among quantizing participants.
    pub mean_q: f64,
    /// Per-client levels (None = not scheduled; Some(0) = raw upload).
    pub q_per_client: Vec<Option<u32>>,
    /// Virtual queues after the round.
    pub lambda1: f64,
    pub lambda2: f64,
    /// Max realized latency among participants (s).
    pub max_latency: f64,
    /// Wall-clock spent deciding (scheduler), s.
    pub decide_seconds: f64,
    /// Wall-clock of the execution stage, s: client fan-out
    /// (train/quantize/accounting) *including* the streaming
    /// aggregation fold, which overlaps with client compute in the
    /// staged engine. (Pre-engine traces timed training only, with
    /// aggregation outside the measurement — compare across versions
    /// accordingly.)
    pub compute_seconds: f64,
}

/// A full experiment trace.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub algorithm: String,
    pub records: Vec<RoundRecord>,
}

impl Trace {
    pub fn new(algorithm: &str) -> Trace {
        Trace { algorithm: algorithm.to_string(), records: Vec::new() }
    }

    pub fn push(&mut self, rec: RoundRecord) {
        self.records.push(rec);
    }

    pub fn total_energy(&self) -> f64 {
        self.records.last().map(|r| r.cum_energy).unwrap_or(0.0)
    }

    /// Last observed test accuracy.
    pub fn final_accuracy(&self) -> Option<f64> {
        self.records.iter().rev().find_map(|r| r.test_acc)
    }

    /// Best test accuracy over the run.
    pub fn best_accuracy(&self) -> Option<f64> {
        self.records.iter().filter_map(|r| r.test_acc).fold(None, |acc, x| {
            Some(acc.map_or(x, |a: f64| a.max(x)))
        })
    }

    /// Rounds until test accuracy first reaches `target` (convergence
    /// speed, the paper's "faster convergence" claim).
    pub fn rounds_to_accuracy(&self, target: f64) -> Option<usize> {
        self.records.iter().find(|r| r.test_acc.map(|a| a >= target).unwrap_or(false)).map(|r| r.round)
    }

    /// Total dropouts (scheduled − aggregated).
    pub fn total_dropouts(&self) -> usize {
        self.records.iter().map(|r| r.scheduled - r.aggregated).sum()
    }

    /// Mean q trajectory (round, mean_q) for quantizing algorithms.
    pub fn q_trajectory(&self) -> Vec<(usize, f64)> {
        self.records.iter().filter(|r| r.mean_q > 0.0).map(|r| (r.round, r.mean_q)).collect()
    }

    /// Dump per-round rows to CSV.
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        let mut w = CsvWriter::create(
            path,
            &[
                "round",
                "algorithm",
                "scheduled",
                "aggregated",
                "energy_j",
                "cum_energy_j",
                "train_loss",
                "test_loss",
                "test_acc",
                "mean_q",
                "lambda1",
                "lambda2",
                "max_latency_s",
                "decide_s",
                "compute_s",
            ],
        )?;
        for r in &self.records {
            w.row(&[
                r.round.to_string(),
                self.algorithm.clone(),
                r.scheduled.to_string(),
                r.aggregated.to_string(),
                format!("{:.9}", r.energy),
                format!("{:.9}", r.cum_energy),
                format!("{:.6}", r.train_loss),
                r.test_loss.map(|x| format!("{x:.6}")).unwrap_or_default(),
                r.test_acc.map(|x| format!("{x:.6}")).unwrap_or_default(),
                format!("{:.4}", r.mean_q),
                format!("{:.6}", r.lambda1),
                format!("{:.6}", r.lambda2),
                format!("{:.6}", r.max_latency),
                format!("{:.4}", r.decide_seconds),
                format!("{:.4}", r.compute_seconds),
            ])?;
        }
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, acc: Option<f64>, energy: f64, cum: f64) -> RoundRecord {
        RoundRecord {
            round,
            test_acc: acc,
            energy,
            cum_energy: cum,
            scheduled: 10,
            aggregated: 9,
            ..Default::default()
        }
    }

    #[test]
    fn trace_aggregates() {
        let mut t = Trace::new("qccf");
        t.push(rec(1, None, 1.0, 1.0));
        t.push(rec(2, Some(0.5), 1.0, 2.0));
        t.push(rec(3, Some(0.8), 1.0, 3.0));
        t.push(rec(4, Some(0.7), 1.0, 4.0));
        assert_eq!(t.total_energy(), 4.0);
        assert_eq!(t.final_accuracy(), Some(0.7));
        assert_eq!(t.best_accuracy(), Some(0.8));
        assert_eq!(t.rounds_to_accuracy(0.75), Some(3));
        assert_eq!(t.rounds_to_accuracy(0.95), None);
        assert_eq!(t.total_dropouts(), 4);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Trace::new("x");
        t.push(rec(1, Some(0.4), 0.5, 0.5));
        let dir = std::env::temp_dir().join("qccf_metrics_test");
        let path = dir.join("trace.csv");
        t.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.lines().next().unwrap().starts_with("round,algorithm"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
