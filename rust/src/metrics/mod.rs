//! Per-round metric records + trace recorder (CSV/JSON export). The
//! experiment harness aggregates these into the paper's figure series.

use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::Path;

use crate::util::csv::CsvWriter;
use crate::util::json::Json;

/// Everything measured in one communication round.
#[derive(Clone, Debug, Default)]
pub struct RoundRecord {
    /// 1-based communication-round index.
    pub round: usize,
    /// Participants scheduled this round.
    pub scheduled: usize,
    /// Uploads aggregated (dropouts = scheduled − aggregated).
    pub aggregated: usize,
    /// Scheduled clients that departed mid-round (churn): their energy
    /// and wire bytes are spent, but the upload never arrives — a
    /// subset of the dropouts. Always 0 without churn.
    pub departed: usize,
    /// Retransmission attempts beyond the first, summed over scheduled
    /// clients (fault injection): each retry puts the full eq. (5)
    /// payload back on the wire and is charged airtime energy. Always
    /// 0 without chaos.
    pub retries: usize,
    /// Scheduled clients whose upload never decoded within the retry
    /// budget (fault injection) — demoted to the departed path: energy
    /// and wire bytes spent, upload discarded. Always 0 without chaos.
    pub failed_decodes: usize,
    /// Realized bytes on the wire this round, summed over scheduled
    /// uploads: `ceil(eq. (5)/8)` per quantized upload, `4·Z` per raw
    /// one. This is the *transmitted* payload (airtime is spent even by
    /// C4 dropouts), checked at encode time against the analytic
    /// accounting the latency/energy math uses.
    pub wire_bytes: usize,
    /// Energy spent this round (J).
    pub energy: f64,
    /// Cumulative energy through this round (J).
    pub cum_energy: f64,
    /// Mean training loss reported by participating clients.
    pub train_loss: f64,
    /// Test loss (only on eval rounds).
    pub test_loss: Option<f64>,
    /// Test accuracy (only on eval rounds).
    pub test_acc: Option<f64>,
    /// Mean quantization level among quantizing participants.
    pub mean_q: f64,
    /// Per-client levels (None = not scheduled; Some(0) = raw upload).
    pub q_per_client: Vec<Option<u32>>,
    /// λ1 (data-property queue) after the round.
    pub lambda1: f64,
    /// λ2 (quantization-error queue) after the round.
    pub lambda2: f64,
    /// Max realized latency among participants (s).
    pub max_latency: f64,
    /// Wall-clock spent deciding (scheduler), s.
    pub decide_seconds: f64,
    /// Wall-clock of the execution stage, s: client fan-out
    /// (train/quantize/accounting) *including* the streaming
    /// aggregation fold, which overlaps with client compute in the
    /// staged engine. (Pre-engine traces timed training only, with
    /// aggregation outside the measurement — compare across versions
    /// accordingly.)
    pub compute_seconds: f64,
}

/// A full experiment trace.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    /// Scheduler name that produced the trace.
    pub algorithm: String,
    /// One record per communication round, in order.
    pub records: Vec<RoundRecord>,
}

impl Trace {
    /// Empty trace for `algorithm`.
    pub fn new(algorithm: &str) -> Trace {
        Trace { algorithm: algorithm.to_string(), records: Vec::new() }
    }

    /// Append one round's record.
    pub fn push(&mut self, rec: RoundRecord) {
        self.records.push(rec);
    }

    /// Final cumulative energy (J).
    pub fn total_energy(&self) -> f64 {
        self.records.last().map(|r| r.cum_energy).unwrap_or(0.0)
    }

    /// Last observed test accuracy.
    pub fn final_accuracy(&self) -> Option<f64> {
        self.records.iter().rev().find_map(|r| r.test_acc)
    }

    /// Best test accuracy over the run.
    pub fn best_accuracy(&self) -> Option<f64> {
        self.records.iter().filter_map(|r| r.test_acc).fold(None, |acc, x| {
            Some(acc.map_or(x, |a: f64| a.max(x)))
        })
    }

    /// Rounds until test accuracy first reaches `target` (convergence
    /// speed, the paper's "faster convergence" claim).
    pub fn rounds_to_accuracy(&self, target: f64) -> Option<usize> {
        self.records.iter().find(|r| r.test_acc.map(|a| a >= target).unwrap_or(false)).map(|r| r.round)
    }

    /// Total dropouts (scheduled − aggregated).
    pub fn total_dropouts(&self) -> usize {
        self.records.iter().map(|r| r.scheduled - r.aggregated).sum()
    }

    /// Total clients scheduled across the run (participation
    /// accounting for churn scenarios).
    pub fn total_scheduled(&self) -> usize {
        self.records.iter().map(|r| r.scheduled).sum()
    }

    /// Total uploads aggregated across the run.
    pub fn total_aggregated(&self) -> usize {
        self.records.iter().map(|r| r.aggregated).sum()
    }

    /// Total mid-round departures across the run (0 without churn).
    pub fn total_departed(&self) -> usize {
        self.records.iter().map(|r| r.departed).sum()
    }

    /// Total retransmission attempts across the run (0 without chaos).
    pub fn total_retries(&self) -> usize {
        self.records.iter().map(|r| r.retries).sum()
    }

    /// Total retry-budget-exhausted uploads across the run (0 without
    /// chaos).
    pub fn total_failed_decodes(&self) -> usize {
        self.records.iter().map(|r| r.failed_decodes).sum()
    }

    /// Total realized bytes on the wire across the run (the physical
    /// quantity behind the paper's communication-energy accounting).
    pub fn total_wire_bytes(&self) -> u64 {
        self.records.iter().map(|r| r.wire_bytes as u64).sum()
    }

    /// Mean q trajectory (round, mean_q) for quantizing algorithms.
    pub fn q_trajectory(&self) -> Vec<(usize, f64)> {
        self.records.iter().filter(|r| r.mean_q > 0.0).map(|r| (r.round, r.mean_q)).collect()
    }

    /// Dump per-round rows to CSV. Replaced **atomically** (tmp +
    /// fsync + rename, see [`crate::util::fsio`]) like the JSONL
    /// trace: a `train` run killed mid-write must not leave a torn
    /// `train_*.csv` that looks complete.
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        crate::util::fsio::replace_atomic(path, |tmp| self.write_csv_plain(tmp))
    }

    /// The raw CSV emitter behind [`Trace::write_csv`]'s atomic wrapper.
    fn write_csv_plain(&self, path: &Path) -> std::io::Result<()> {
        let mut w = CsvWriter::create(
            path,
            &[
                "round",
                "algorithm",
                "scheduled",
                "aggregated",
                "departed",
                "retries",
                "failed_decodes",
                "energy_j",
                "cum_energy_j",
                "train_loss",
                "test_loss",
                "test_acc",
                "mean_q",
                "wire_bytes",
                "lambda1",
                "lambda2",
                "max_latency_s",
                "decide_s",
                "compute_s",
            ],
        )?;
        for r in &self.records {
            w.row(&[
                r.round.to_string(),
                self.algorithm.clone(),
                r.scheduled.to_string(),
                r.aggregated.to_string(),
                r.departed.to_string(),
                r.retries.to_string(),
                r.failed_decodes.to_string(),
                format!("{:.9}", r.energy),
                format!("{:.9}", r.cum_energy),
                format!("{:.6}", r.train_loss),
                r.test_loss.map(|x| format!("{x:.6}")).unwrap_or_default(),
                r.test_acc.map(|x| format!("{x:.6}")).unwrap_or_default(),
                format!("{:.4}", r.mean_q),
                r.wire_bytes.to_string(),
                format!("{:.6}", r.lambda1),
                format!("{:.6}", r.lambda2),
                format!("{:.6}", r.max_latency),
                format!("{:.4}", r.decide_seconds),
                format!("{:.4}", r.compute_seconds),
            ])?;
        }
        w.flush()
    }

    /// Dump per-round rows as JSONL (one self-describing JSON object
    /// per line), prefixing every row with the `meta` key/value pairs
    /// (the sweep runner passes scenario/algorithm/seed).
    ///
    /// Deliberately excludes the wall-clock fields
    /// (`decide_seconds`/`compute_seconds`): everything written here is
    /// a deterministic function of (scenario, algorithm, seed), which
    /// is what makes sweep outputs bit-identical across `--threads`
    /// values. Non-finite values (e.g. an empty round's NaN loss)
    /// serialize as `null` to keep every line valid JSON.
    ///
    /// The file is replaced **atomically** (tmp + fsync + rename, see
    /// [`crate::util::fsio`]): an interrupted sweep never leaves a torn
    /// trace for `sweep --resume` to mistake for a completed run.
    pub fn write_jsonl(&self, path: &Path, meta: &[(&str, Json)]) -> std::io::Result<()> {
        fn num_or_null(x: f64) -> Json {
            if x.is_finite() {
                Json::Num(x)
            } else {
                Json::Null
            }
        }
        fn opt(x: Option<f64>) -> Json {
            x.map(num_or_null).unwrap_or(Json::Null)
        }
        crate::util::fsio::replace_atomic(path, |tmp| {
            let mut out = std::io::BufWriter::new(std::fs::File::create(tmp)?);
            for r in &self.records {
                let mut m: BTreeMap<String, Json> = BTreeMap::new();
                for (k, v) in meta {
                    m.insert((*k).to_string(), v.clone());
                }
                m.insert("round".into(), Json::Num(r.round as f64));
                m.insert("scheduled".into(), Json::Num(r.scheduled as f64));
                m.insert("aggregated".into(), Json::Num(r.aggregated as f64));
                m.insert("departed".into(), Json::Num(r.departed as f64));
                m.insert("retries".into(), Json::Num(r.retries as f64));
                m.insert("failed_decodes".into(), Json::Num(r.failed_decodes as f64));
                m.insert("energy_j".into(), num_or_null(r.energy));
                m.insert("cum_energy_j".into(), num_or_null(r.cum_energy));
                m.insert("train_loss".into(), num_or_null(r.train_loss));
                m.insert("test_loss".into(), opt(r.test_loss));
                m.insert("test_acc".into(), opt(r.test_acc));
                m.insert("mean_q".into(), num_or_null(r.mean_q));
                m.insert("wire_bytes".into(), Json::Num(r.wire_bytes as f64));
                m.insert(
                    "q_per_client".into(),
                    Json::Arr(
                        r.q_per_client
                            .iter()
                            .map(|q| q.map(|q| Json::Num(q as f64)).unwrap_or(Json::Null))
                            .collect(),
                    ),
                );
                m.insert("lambda1".into(), num_or_null(r.lambda1));
                m.insert("lambda2".into(), num_or_null(r.lambda2));
                m.insert("max_latency_s".into(), num_or_null(r.max_latency));
                writeln!(out, "{}", Json::Obj(m).to_string_compact())?;
            }
            out.flush()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, acc: Option<f64>, energy: f64, cum: f64) -> RoundRecord {
        RoundRecord {
            round,
            test_acc: acc,
            energy,
            cum_energy: cum,
            scheduled: 10,
            aggregated: 9,
            departed: 1,
            retries: 2,
            failed_decodes: 1,
            wire_bytes: 1500,
            ..Default::default()
        }
    }

    #[test]
    fn trace_aggregates() {
        let mut t = Trace::new("qccf");
        t.push(rec(1, None, 1.0, 1.0));
        t.push(rec(2, Some(0.5), 1.0, 2.0));
        t.push(rec(3, Some(0.8), 1.0, 3.0));
        t.push(rec(4, Some(0.7), 1.0, 4.0));
        assert_eq!(t.total_energy(), 4.0);
        assert_eq!(t.final_accuracy(), Some(0.7));
        assert_eq!(t.best_accuracy(), Some(0.8));
        assert_eq!(t.rounds_to_accuracy(0.75), Some(3));
        assert_eq!(t.rounds_to_accuracy(0.95), None);
        assert_eq!(t.total_dropouts(), 4);
        assert_eq!(t.total_wire_bytes(), 4 * 1500);
        assert_eq!(t.total_scheduled(), 40);
        assert_eq!(t.total_aggregated(), 36);
        assert_eq!(t.total_departed(), 4);
        assert_eq!(t.total_retries(), 8);
        assert_eq!(t.total_failed_decodes(), 4);
    }

    #[test]
    fn jsonl_lines_valid_and_meta_prefixed() {
        let mut t = Trace::new("qccf");
        let mut r1 = rec(1, None, 1.0, 1.0);
        r1.train_loss = f64::NAN; // empty round — must serialize as null
        r1.q_per_client = vec![Some(4), None, Some(0)];
        t.push(r1);
        t.push(rec(2, Some(0.5), 1.0, 2.0));
        let dir = std::env::temp_dir().join("qccf_metrics_jsonl_test");
        let path = dir.join("t.jsonl");
        t.write_jsonl(&path, &[("scenario", crate::util::json::s("demo")), ("seed", crate::util::json::num(3.0))])
            .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for (i, line) in lines.iter().enumerate() {
            let v = crate::util::json::parse(line).unwrap();
            assert_eq!(v.get("scenario").and_then(|x| x.as_str()), Some("demo"));
            assert_eq!(v.get("seed").and_then(|x| x.as_f64()), Some(3.0));
            assert_eq!(v.get("round").and_then(|x| x.as_usize()), Some(i + 1));
            for key in [
                "scheduled",
                "aggregated",
                "departed",
                "retries",
                "failed_decodes",
                "energy_j",
                "cum_energy_j",
                "train_loss",
                "test_loss",
                "test_acc",
                "mean_q",
                "wire_bytes",
                "q_per_client",
                "lambda1",
                "lambda2",
                "max_latency_s",
            ] {
                assert!(v.get(key).is_some(), "line {i} missing `{key}`");
            }
        }
        // NaN loss became null; q_per_client keeps the raw-upload 0.
        let first = crate::util::json::parse(lines[0]).unwrap();
        assert_eq!(first.get("train_loss"), Some(&crate::util::json::Json::Null));
        let q = first.get("q_per_client").unwrap().as_arr().unwrap();
        assert_eq!(q.len(), 3);
        assert_eq!(q[2].as_f64(), Some(0.0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Trace::new("x");
        t.push(rec(1, Some(0.4), 0.5, 0.5));
        let dir = std::env::temp_dir().join("qccf_metrics_test");
        let path = dir.join("trace.csv");
        t.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.lines().next().unwrap().starts_with("round,algorithm"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
