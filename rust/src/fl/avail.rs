//! Client **availability layer**: a seeded Markov on/off process per
//! client, driving the churn-tolerant round engine (scenario knobs
//! `churn`, `p_join`, `p_leave`, `over_select`, `staleness` — see
//! `docs/SCENARIOS.md`).
//!
//! # Determinism contract
//!
//! Availability draws come from **per-client RNG streams** forked off a
//! private root seeded from the run seed (salted so it can never alias
//! the server stream `Rng::seed_from(seed)` or the scheduler stream
//! `seed·31 + 7`). The streams are forked once, serially, in ascending
//! client-id order at construction, and one Markov draw per client per
//! round advances only that client's stream — so the availability
//! history is a pure function of `(seed, U, cfg, #ticks)`:
//!
//! * **thread-count invariant** — no draw happens inside the worker
//!   fan-out, so `--threads` cannot reorder or split any stream;
//! * **iteration-order invariant** — [`AvailProcess::tick_one`] touches
//!   exactly one stream, so ticking clients in any order produces the
//!   same state (`proptest_churn.rs` pins both properties);
//! * **checkpointable** — the complete per-client state (on/off flag,
//!   missed-round counter, stream position) round-trips through
//!   [`AvailProcess::checkpoint`] / [`AvailProcess::restore`] as
//!   `ckpt::AvailCkpt` records, so a resumed run replays the exact
//!   availability future an uninterrupted run would have seen.
//!
//! # Round protocol
//!
//! The server consults the process twice per round:
//!
//! 1. **decide time** — [`AvailProcess::mask`] is the candidate set the
//!    scheduler may draw from (`RoundInputs::avail`);
//! 2. **post-decide** — one [`AvailProcess::tick`] advances the Markov
//!    chain; a scheduled client whose flag flips off is a **mid-round
//!    departure**, treated exactly like a C4 deadline miss (energy and
//!    airtime spent, upload discarded — `exec::ExecOpts::departed`).
//!
//! [`aggregation_target`] implements over-selection: the scheduler
//! fills up to `(1+β)·N` seats and the engine aggregates only the first
//! `N = ceil(scheduled / (1+β))` survivors in ascending client order.
//! [`AvailProcess::stale_scale`] implements the opt-in
//! staleness-weighted aggregation path: a client aggregated `m` rounds
//! ago contributes effective data mass `D_i / (1 + m)` to the eq. (2)
//! fold weights (`m = 0` keeps the multiplier at exactly `1.0`, so the
//! default path's weights are bit-identical).

use anyhow::{ensure, Result};

use crate::ckpt::AvailCkpt;
use crate::util::rng::Rng;

/// Salt mixed into the run seed for the availability root stream:
/// `"AVAIL_V1"` in ASCII. Keeps the root distinct from every other
/// stream the same run seed feeds.
const AVAIL_SEED_SALT: u64 = 0x4156_4149_4C5F_5631;

/// Churn knobs, resolved from the scenario's `[train]` section.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AvailCfg {
    /// Per-round probability an **offline** client rejoins.
    pub p_join: f64,
    /// Per-round probability an **online** client departs.
    pub p_leave: f64,
    /// Over-selection factor β ≥ 0: the engine aggregates only the
    /// first `ceil(scheduled / (1+β))` survivors (0 = aggregate all).
    pub over_select: f64,
    /// Opt into staleness-weighted aggregation
    /// ([`AvailProcess::stale_scale`]).
    pub staleness: bool,
}

impl Default for AvailCfg {
    fn default() -> AvailCfg {
        AvailCfg { p_join: 0.25, p_leave: 0.1, over_select: 0.0, staleness: false }
    }
}

/// The over-selection aggregation target `N = ceil(scheduled / (1+β))`.
/// Always in `1 ..= scheduled` for `scheduled ≥ 1` (β ≤ 0 or an empty
/// round degrade to the identity), so over-selection can shrink a
/// round's aggregate but never empty it by itself.
pub fn aggregation_target(scheduled: usize, over_select: f64) -> usize {
    if scheduled == 0 || !(over_select > 0.0) {
        return scheduled;
    }
    ((scheduled as f64) / (1.0 + over_select)).ceil() as usize
}

/// Per-client seeded Markov availability process. See the module docs
/// for the determinism and checkpoint contracts.
#[derive(Clone, Debug)]
pub struct AvailProcess {
    cfg: AvailCfg,
    /// Current on/off flag per client. Every client starts **on** (the
    /// chain's first transition happens after round 1's decide stage).
    on: Vec<bool>,
    /// Rounds since the client's upload last made it into an aggregate
    /// (0 = aggregated last round, or never left the initial state).
    missed: Vec<u64>,
    /// Per-client Markov streams, forked in id order at construction.
    rngs: Vec<Rng>,
}

impl AvailProcess {
    /// Build the process for `u` clients from the run seed. Forks the
    /// per-client streams serially in ascending id order — the only
    /// place any ordering enters, and it is fixed.
    pub fn new(u: usize, cfg: AvailCfg, seed: u64) -> AvailProcess {
        let mut root = Rng::seed_from(seed ^ AVAIL_SEED_SALT);
        AvailProcess {
            cfg,
            on: vec![true; u],
            missed: vec![0; u],
            rngs: (0..u).map(|i| root.fork(i as u64)).collect(),
        }
    }

    /// The configured knobs.
    pub fn cfg(&self) -> &AvailCfg {
        &self.cfg
    }

    /// Number of clients.
    pub fn len(&self) -> usize {
        self.on.len()
    }

    /// True when the process tracks no clients.
    pub fn is_empty(&self) -> bool {
        self.on.is_empty()
    }

    /// The current availability mask (decide-time candidate set).
    pub fn mask(&self) -> &[bool] {
        &self.on
    }

    /// True when nobody is available (the engine short-circuits the
    /// round before invoking the scheduler).
    pub fn all_off(&self) -> bool {
        self.on.iter().all(|&o| !o)
    }

    /// Advance client `i`'s Markov chain by one transition — exactly
    /// one draw from client `i`'s private stream, touching no other
    /// state, which is what makes [`AvailProcess::tick`] invariant to
    /// iteration order.
    pub fn tick_one(&mut self, i: usize) {
        let flip = if self.on[i] {
            self.rngs[i].chance(self.cfg.p_leave)
        } else {
            self.rngs[i].chance(self.cfg.p_join)
        };
        if flip {
            self.on[i] = !self.on[i];
        }
    }

    /// Advance every client by one transition (ascending id order —
    /// equivalent to any other order, see [`AvailProcess::tick_one`]).
    pub fn tick(&mut self) {
        for i in 0..self.on.len() {
            self.tick_one(i);
        }
    }

    /// Trace-driven override: force the availability mask to `row`
    /// (e.g. replaying a measured device-availability trace instead of
    /// the Markov chain). Does not advance any stream; callers driving
    /// traces own the alignment of rows to rounds across a resume.
    pub fn override_row(&mut self, row: &[bool]) {
        assert_eq!(row.len(), self.on.len(), "trace row length != client count");
        self.on.copy_from_slice(row);
    }

    /// End-of-round bookkeeping for the staleness counters: every
    /// client's `missed` advances by one round, then the clients whose
    /// uploads made this round's aggregate reset to 0.
    pub fn note_round(&mut self, aggregated_ids: &[usize]) {
        for m in &mut self.missed {
            *m += 1;
        }
        for &i in aggregated_ids {
            self.missed[i] = 0;
        }
    }

    /// Rounds since client `i` last contributed to an aggregate.
    pub fn missed(&self, i: usize) -> u64 {
        self.missed[i]
    }

    /// The staleness multiplier `1 / (1 + missed)` scaling client `i`'s
    /// effective data mass in the fold weights. Exactly `1.0` for a
    /// fresh client (IEEE-exact: `D · 1.0 == D`), decaying harmonically
    /// with the gap — always finite, positive, and ≤ 1.
    pub fn stale_scale(&self, i: usize) -> f64 {
        1.0 / (1.0 + self.missed[i] as f64)
    }

    /// Capture the complete per-client state for a snapshot.
    pub fn checkpoint(&self) -> Vec<AvailCkpt> {
        (0..self.on.len())
            .map(|i| AvailCkpt {
                on: self.on[i],
                missed: self.missed[i],
                rng: self.rngs[i].state(),
            })
            .collect()
    }

    /// Restore from a snapshot's per-client records (inverse of
    /// [`AvailProcess::checkpoint`]). The config is not part of the
    /// record — the caller re-derives it from the scenario, exactly as
    /// the server RNG seeds are re-derived on resume.
    pub fn restore(&mut self, state: &[AvailCkpt]) -> Result<()> {
        ensure!(
            state.len() == self.on.len(),
            "availability snapshot holds {} clients, process has {}",
            state.len(),
            self.on.len()
        );
        for (i, st) in state.iter().enumerate() {
            self.on[i] = st.on;
            self.missed[i] = st.missed;
            self.rngs[i].restore(&st.rng);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(p_join: f64, p_leave: f64) -> AvailCfg {
        AvailCfg { p_join, p_leave, ..AvailCfg::default() }
    }

    #[test]
    fn same_seed_same_history_any_tick_order() {
        let u = 37;
        let mut a = AvailProcess::new(u, cfg(0.3, 0.2), 42);
        let mut b = AvailProcess::new(u, cfg(0.3, 0.2), 42);
        for round in 0..50 {
            a.tick();
            // Reverse iteration order must not change anything — each
            // tick touches exactly one private stream.
            for i in (0..u).rev() {
                b.tick_one(i);
            }
            assert_eq!(a.mask(), b.mask(), "round {round}");
        }
        let mut c = AvailProcess::new(u, cfg(0.3, 0.2), 43);
        c.tick();
        a = AvailProcess::new(u, cfg(0.3, 0.2), 42);
        a.tick();
        assert_ne!(a.mask(), c.mask(), "different seeds should diverge (u = {u})");
    }

    #[test]
    fn p_leave_zero_pins_always_available() {
        let mut a = AvailProcess::new(25, cfg(0.5, 0.0), 7);
        for _ in 0..100 {
            a.tick();
            assert!(a.mask().iter().all(|&o| o));
        }
        assert!(!a.all_off());
    }

    #[test]
    fn p_leave_one_departs_everyone() {
        let mut a = AvailProcess::new(25, cfg(0.0, 1.0), 7);
        a.tick();
        assert!(a.all_off());
        a.tick(); // p_join = 0: nobody comes back
        assert!(a.all_off());
    }

    #[test]
    fn checkpoint_restore_replays_identical_future() {
        let u = 19;
        let mut a = AvailProcess::new(u, cfg(0.3, 0.25), 99);
        for _ in 0..7 {
            a.tick();
        }
        a.note_round(&[2, 5]);
        let snap = a.checkpoint();
        let mut b = AvailProcess::new(u, cfg(0.3, 0.25), 99);
        b.restore(&snap).unwrap();
        for round in 0..20 {
            a.tick();
            b.tick();
            assert_eq!(a.mask(), b.mask(), "round {round}");
            for i in 0..u {
                assert_eq!(a.missed(i), b.missed(i), "round {round} client {i}");
            }
        }
        // Length mismatch is a typed refusal, not a silent truncation.
        let mut c = AvailProcess::new(u + 1, cfg(0.3, 0.25), 99);
        assert!(c.restore(&snap).is_err());
    }

    #[test]
    fn note_round_tracks_rounds_since_aggregation() {
        let mut a = AvailProcess::new(3, AvailCfg::default(), 1);
        assert_eq!(a.stale_scale(0), 1.0);
        a.note_round(&[0]);
        assert_eq!((a.missed(0), a.missed(1)), (0, 1));
        a.note_round(&[1]);
        assert_eq!((a.missed(0), a.missed(1), a.missed(2)), (1, 0, 2));
        assert_eq!(a.stale_scale(0), 0.5);
        assert_eq!(a.stale_scale(2), 1.0 / 3.0);
        assert!(a.stale_scale(2) > 0.0 && a.stale_scale(2) <= 1.0);
    }

    #[test]
    fn aggregation_target_bounds() {
        assert_eq!(aggregation_target(0, 0.5), 0);
        assert_eq!(aggregation_target(10, 0.0), 10);
        assert_eq!(aggregation_target(10, -1.0), 10);
        assert_eq!(aggregation_target(10, 0.25), 8);
        assert_eq!(aggregation_target(10, 0.5), 7);
        assert_eq!(aggregation_target(1, 9.0), 1);
        for s in 1..40usize {
            for beta in [0.0, 0.1, 0.5, 1.0, 3.0] {
                let n = aggregation_target(s, beta);
                assert!(n >= 1 && n <= s, "s={s} beta={beta} n={n}");
            }
        }
    }

    #[test]
    fn override_row_forces_mask() {
        let mut a = AvailProcess::new(4, AvailCfg::default(), 5);
        a.override_row(&[false, true, false, true]);
        assert_eq!(a.mask(), &[false, true, false, true]);
    }
}
