//! Deterministic **fault-injection layer** ("chaos"): seeded per-client
//! fault draws driving the graceful-degradation machinery (scenario
//! knobs `chaos`, `chaos_decode`, `chaos_straggle`, `chaos_panic`,
//! `chaos_retries`, `chaos_ckpt` — see `docs/SCENARIOS.md` and
//! `docs/FAULTS.md`).
//!
//! # Fault taxonomy
//!
//! One [`FaultDraw`] per scheduled client per round, covering:
//!
//! * **decode failure** — the upload fails to decode at the server
//!   (payload bit-flip / outage); the client retransmits up to
//!   `chaos_retries` extra attempts, each charged full eq. (5) airtime
//!   energy and payload bytes. Budget exhausted (`decoded == false`)
//!   folds the client into the churn departed path: energy spent,
//!   upload discarded, θ stays finite.
//! * **compute straggle** — the round's compute term stretches by
//!   [`crate::fl::exec::STRAGGLE_FACTOR`]; a straggler that blows the
//!   C4 deadline is dropped exactly like any other deadline miss.
//! * **client panic** — the worker panics mid-round. The executor's
//!   fold cursor survives (`CommitOnDrop`), the panic propagates, and
//!   the sweep layer isolates the poisoned unit as a `failed` row.
//! * **checkpoint corruption** — a plan-level stream decides whether a
//!   just-written snapshot gets a bit flipped, exercising the
//!   latest → previous → fresh recovery ladder.
//!
//! # Determinism contract
//!
//! Same shape as `fl::avail`: draws come from **per-client RNG
//! streams** forked off a private root seeded from the run seed (salted
//! `"FAULTSV1"` so it can never alias the server, scheduler, or
//! availability streams). Streams are forked once, serially, in
//! ascending client-id order at construction (the checkpoint stream
//! last), and [`FaultPlan::tick_one`] advances exactly one client's
//! stream — so the fault history is a pure function of
//! `(seed, U, cfg, #ticks)`:
//!
//! * **thread-count invariant** — every draw happens before the worker
//!   fan-out, so `--threads` cannot reorder or split any stream;
//! * **iteration-order invariant** — ticking clients in any order
//!   produces the same draws (`proptest_faults.rs` pins this);
//! * **checkpointable** — every stream position round-trips through
//!   [`FaultPlan::checkpoint`] / [`FaultPlan::restore`] as a
//!   `ckpt::FaultsCkpt` record, so a resumed run replays the exact
//!   fault future an uninterrupted run would have seen.
//!
//! With every probability at 0 each draw is [`FaultDraw::benign`], and
//! the engine's accounting is bit-identical to a chaos-disabled run
//! (the benign adjustments are IEEE-exact no-ops; `proptest_faults.rs`
//! pins this too).

use anyhow::{ensure, Result};

use crate::ckpt::FaultsCkpt;
use crate::util::rng::Rng;

/// Salt mixed into the run seed for the fault root stream:
/// `"FAULTSV1"` in ASCII. Keeps the root distinct from the server
/// stream (`seed`), the scheduler stream (`seed·31 + 7`), and the
/// availability stream (`seed ^ AVAIL_V1`).
const FAULT_SEED_SALT: u64 = 0x4641_554C_5453_5631;

/// Chaos knobs, resolved from the scenario's `[train]` section.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultCfg {
    /// Per-attempt probability an upload fails to decode.
    pub p_decode: f64,
    /// Per-round probability a client's compute straggles by
    /// [`crate::fl::exec::STRAGGLE_FACTOR`].
    pub p_straggle: f64,
    /// Per-round probability a client's worker panics mid-round.
    pub p_panic: f64,
    /// Retry budget: extra transmission attempts after the first
    /// (attempts ≤ 1 + retries).
    pub retries: u32,
    /// Per-snapshot probability a just-written checkpoint gets a bit
    /// flipped (drawn from the plan-level stream, not a client's).
    pub p_ckpt: f64,
}

impl Default for FaultCfg {
    fn default() -> FaultCfg {
        FaultCfg { p_decode: 0.0, p_straggle: 0.0, p_panic: 0.0, retries: 2, p_ckpt: 0.0 }
    }
}

/// One client's fault outcome for one round. `attempts` counts every
/// transmission of the payload (first try included), `decoded` is
/// whether any attempt succeeded within the retry budget.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultDraw {
    /// Compute term stretches by `STRAGGLE_FACTOR` this round.
    pub straggle: bool,
    /// The worker panics this round (sweep-level isolation target).
    pub panic: bool,
    /// Transmission attempts actually spent, `1 ..= 1 + retries`.
    pub attempts: u32,
    /// False iff every attempt failed — the client takes the departed
    /// path (energy spent, upload discarded).
    pub decoded: bool,
}

impl FaultDraw {
    /// The no-fault draw: one attempt, decoded, no straggle, no panic.
    /// Every accounting adjustment keyed off this draw is an IEEE-exact
    /// no-op, which is what makes fault-rate-0 runs bit-identical to a
    /// chaos-disabled engine.
    pub fn benign() -> FaultDraw {
        FaultDraw { straggle: false, panic: false, attempts: 1, decoded: true }
    }

    /// Extra transmission attempts beyond the first.
    pub fn retries(&self) -> u32 {
        self.attempts.saturating_sub(1)
    }
}

impl Default for FaultDraw {
    fn default() -> FaultDraw {
        FaultDraw::benign()
    }
}

/// Per-client seeded fault process. See the module docs for the
/// determinism and checkpoint contracts.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    cfg: FaultCfg,
    /// Most recent per-client draws (round-transient working state;
    /// regenerated by [`FaultPlan::tick`], not checkpointed).
    draws: Vec<FaultDraw>,
    /// Per-client fault streams, forked in id order at construction.
    rngs: Vec<Rng>,
    /// Plan-level stream for checkpoint-corruption draws, forked last.
    ckpt_rng: Rng,
}

impl FaultPlan {
    /// Build the plan for `u` clients from the run seed. Forks the
    /// per-client streams serially in ascending id order, then the
    /// checkpoint stream — the only place any ordering enters, and it
    /// is fixed.
    pub fn new(u: usize, cfg: FaultCfg, seed: u64) -> FaultPlan {
        let mut root = Rng::seed_from(seed ^ FAULT_SEED_SALT);
        let rngs: Vec<Rng> = (0..u).map(|i| root.fork(i as u64)).collect();
        let ckpt_rng = root.fork(u as u64);
        FaultPlan { cfg, draws: vec![FaultDraw::benign(); u], rngs, ckpt_rng }
    }

    /// The configured knobs.
    pub fn cfg(&self) -> &FaultCfg {
        &self.cfg
    }

    /// Number of clients.
    pub fn len(&self) -> usize {
        self.rngs.len()
    }

    /// True when the plan tracks no clients.
    pub fn is_empty(&self) -> bool {
        self.rngs.is_empty()
    }

    /// The current per-client draws (valid after a [`FaultPlan::tick`]).
    pub fn draws(&self) -> &[FaultDraw] {
        &self.draws
    }

    /// Draw client `i`'s faults for the round — a fixed draw sequence
    /// (straggle, panic, then one decode draw per attempt until the
    /// first success or the budget runs out) from client `i`'s private
    /// stream, touching no other state, which is what makes
    /// [`FaultPlan::tick`] invariant to iteration order.
    pub fn tick_one(&mut self, i: usize) -> FaultDraw {
        let rng = &mut self.rngs[i];
        let straggle = rng.chance(self.cfg.p_straggle);
        let panic = rng.chance(self.cfg.p_panic);
        let mut attempts = 0u32;
        let mut decoded = false;
        while attempts <= self.cfg.retries {
            attempts += 1;
            if !rng.chance(self.cfg.p_decode) {
                decoded = true;
                break;
            }
        }
        let draw = FaultDraw { straggle, panic, attempts, decoded };
        self.draws[i] = draw;
        draw
    }

    /// Draw every client's faults for the round (ascending id order —
    /// equivalent to any other order, see [`FaultPlan::tick_one`]).
    pub fn tick(&mut self) {
        for i in 0..self.rngs.len() {
            self.tick_one(i);
        }
    }

    /// One checkpoint-corruption draw from the plan-level stream —
    /// called exactly once per snapshot write so the stream position
    /// stays aligned across checkpoint/resume.
    pub fn draw_ckpt_corrupt(&mut self) -> bool {
        self.ckpt_rng.chance(self.cfg.p_ckpt)
    }

    /// Capture every stream position for a snapshot. The transient
    /// draws are not part of the record — snapshots happen between
    /// rounds, and the next round re-ticks.
    pub fn checkpoint(&self) -> FaultsCkpt {
        FaultsCkpt {
            rngs: self.rngs.iter().map(|r| r.state()).collect(),
            ckpt_rng: self.ckpt_rng.state(),
        }
    }

    /// Restore from a snapshot's record (inverse of
    /// [`FaultPlan::checkpoint`]). The config is not part of the record
    /// — the caller re-derives it from the scenario, exactly as the
    /// availability config is.
    pub fn restore(&mut self, state: &FaultsCkpt) -> Result<()> {
        ensure!(
            state.rngs.len() == self.rngs.len(),
            "fault snapshot holds {} clients, plan has {}",
            state.rngs.len(),
            self.rngs.len()
        );
        for (rng, st) in self.rngs.iter_mut().zip(&state.rngs) {
            rng.restore(st);
        }
        self.ckpt_rng.restore(&state.ckpt_rng);
        for d in &mut self.draws {
            *d = FaultDraw::benign();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(p_decode: f64, p_straggle: f64) -> FaultCfg {
        FaultCfg { p_decode, p_straggle, ..FaultCfg::default() }
    }

    #[test]
    fn same_seed_same_history_any_tick_order() {
        let u = 31;
        let mut a = FaultPlan::new(u, cfg(0.4, 0.2), 42);
        let mut b = FaultPlan::new(u, cfg(0.4, 0.2), 42);
        for round in 0..50 {
            a.tick();
            // Reverse iteration order must not change anything — each
            // tick touches exactly one private stream.
            for i in (0..u).rev() {
                b.tick_one(i);
            }
            assert_eq!(a.draws(), b.draws(), "round {round}");
        }
        let mut c = FaultPlan::new(u, cfg(0.4, 0.2), 43);
        c.tick();
        a = FaultPlan::new(u, cfg(0.4, 0.2), 42);
        a.tick();
        assert_ne!(a.draws(), c.draws(), "different seeds should diverge (u = {u})");
    }

    #[test]
    fn zero_rates_draw_benign_forever() {
        let mut a = FaultPlan::new(20, FaultCfg::default(), 7);
        for _ in 0..60 {
            a.tick();
            assert!(a.draws().iter().all(|d| *d == FaultDraw::benign()));
            assert!(!a.draw_ckpt_corrupt());
        }
    }

    #[test]
    fn decode_rate_one_exhausts_the_retry_budget() {
        let mut a = FaultPlan::new(8, FaultCfg { p_decode: 1.0, ..FaultCfg::default() }, 9);
        a.tick();
        for d in a.draws() {
            assert_eq!(d.attempts, 3, "retries = 2 → 3 attempts");
            assert!(!d.decoded);
            assert_eq!(d.retries(), 2);
        }
        // A zero retry budget means exactly one (failing) attempt.
        let mut b =
            FaultPlan::new(8, FaultCfg { p_decode: 1.0, retries: 0, ..FaultCfg::default() }, 9);
        b.tick();
        assert!(b.draws().iter().all(|d| d.attempts == 1 && !d.decoded));
    }

    #[test]
    fn attempts_stay_within_budget_and_failures_only_at_exhaustion() {
        let mut a = FaultPlan::new(64, cfg(0.5, 0.0), 11);
        for _ in 0..40 {
            a.tick();
            for d in a.draws() {
                assert!(d.attempts >= 1 && d.attempts <= 3);
                if !d.decoded {
                    assert_eq!(d.attempts, 3, "failure only after the full budget");
                }
            }
        }
    }

    #[test]
    fn checkpoint_restore_replays_identical_future() {
        let u = 17;
        let mut a = FaultPlan::new(u, cfg(0.35, 0.25), 99);
        for _ in 0..7 {
            a.tick();
            a.draw_ckpt_corrupt();
        }
        let snap = a.checkpoint();
        let mut b = FaultPlan::new(u, cfg(0.35, 0.25), 99);
        b.restore(&snap).unwrap();
        for round in 0..20 {
            a.tick();
            b.tick();
            assert_eq!(a.draws(), b.draws(), "round {round}");
            assert_eq!(a.draw_ckpt_corrupt(), b.draw_ckpt_corrupt(), "round {round}");
        }
        // Length mismatch is a typed refusal, not a silent truncation.
        let mut c = FaultPlan::new(u + 1, cfg(0.35, 0.25), 99);
        assert!(c.restore(&snap).is_err());
    }

    #[test]
    fn panic_rate_one_marks_everyone() {
        let mut a = FaultPlan::new(5, FaultCfg { p_panic: 1.0, ..FaultCfg::default() }, 3);
        a.tick();
        assert!(a.draws().iter().all(|d| d.panic));
    }
}
