//! The FL server loop (paper §II-A, Fig. 1), restructured as a staged
//! **round-execution engine**: per communication round —
//! **decide → execute (parallel fan-out) → aggregate → queue update** —
//! with the wireless/energy bookkeeping and Lyapunov queue updates of
//! §IV–§V.
//!
//! Stage 1 (decision) realizes whatever the scheduler intended — for
//! the GA-based schedulers it runs on the cached evaluation subsystem
//! (`sched::EvalCtx`: per-round precompute + exact-f64-bit-keyed solve
//! memo + per-worker scratch, plus the GA fitness cache), which is
//! bit-identical to the uncached reference evaluator by contract, so
//! the determinism guarantees below are unaffected. Stage 2
//! fans the scheduled clients out over a worker pool ([`exec`]): each
//! client trains through the PJRT runtime, quantizes and **wire-encodes
//! its upload into the eq. (5) bit-packed payload** (raw f32 only for
//! the No-Quantization baseline), re-checks the latency budget C4 with
//! its actual D_i (so wireless-oblivious baselines pay for timeouts
//! exactly as in §VI), and accounts energy with eqs. (14)–(17). Stage 3
//! installs the streamed weighted mean (eq. (2)), folded straight out
//! of the bitstreams of the uploads that made the deadline; stage 4
//! updates the virtual queues. The engine is deterministic: any
//! [`Server::threads`] value yields bit-identical traces (see
//! `fl::exec` for the contract), and the realized bytes on the wire are
//! recorded per round (`RoundRecord::wire_bytes`) with an invariant
//! check against the analytic eq. (5) accounting.
//!
//! The determinism contract extends across process boundaries:
//! [`Server::checkpoint_state`] / [`Server::restore_state`] capture and
//! reinstall the complete resumable state for the `ckpt` subsystem, so
//! a run checkpointed mid-horizon resumes bit-identically
//! (`docs/CHECKPOINTS.md`).

// The round engine is crash-path-critical: a poisoned-lock panic must
// say *what* died, not `unwrap()`. verify.sh relies on this module-tree
// attribute (and its twins in sched/ and ckpt/) to scope the deny to
// the hot subsystems while tests and benches stay free to unwrap.
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

pub mod avail;
pub mod exec;
pub mod faults;

use anyhow::Result;

use crate::config::SystemParams;
use crate::convergence::{self, GradStats};
use crate::data::Federation;
use crate::lyapunov::Queues;
use crate::metrics::{RoundRecord, Trace};
use crate::obs::spans::{Span, SpanGuard};
use crate::runtime::Runtime;
use crate::sched::{RoundDecision, RoundInputs, Scheduler};
use crate::util::rng::Rng;
use crate::util::stats::linf_norm;
use crate::util::threadpool;
use crate::wireless::ChannelModel;

/// `q` bookkeeping sentinels — unified here so the Case-5 anchor can
/// never mistake a raw upload for a real quantization level:
///
/// * [`Q_RECORD_RAW`] (`0`) marks a raw upload in
///   `RoundRecord::q_per_client` (`None` there = not scheduled).
/// * [`ClientState::q_prev`] warm-starts at [`Q_PREV_WARM_START`] and
///   is advanced only by **quantized** uploads. A raw upload carries no
///   quantization information, so it leaves the anchor untouched —
///   previously it wrote a literal `32` that the Taylor expansion in
///   `solver` (eq. (39)) would silently expand around.
pub const Q_RECORD_RAW: u32 = 0;
/// Warm-start value for [`ClientState::q_prev`] (see above).
pub const Q_PREV_WARM_START: f64 = 4.0;

/// Per-client coordinator-side state.
#[derive(Clone, Debug)]
pub struct ClientState {
    /// Client id.
    pub id: usize,
    /// D_i.
    pub size: f64,
    /// Running Ĝ²/σ̂² gradient-statistics estimates.
    pub stats: GradStats,
    /// θ^max estimate used at decision time (from the global model).
    pub theta_max: f64,
    /// q from the last *quantized* participation (Case-5 anchor; see
    /// [`Q_PREV_WARM_START`]).
    pub q_prev: f64,
    /// Private noise stream for data sampling + quantization.
    pub rng: Rng,
}

/// Decision-stage byproducts the later stages need — all captured from
/// coordinator state *before* any client work runs (the queue update
/// must use the decision-time Ĝ/σ̂, not the post-round ones).
struct DecideCtx {
    w_full: Vec<f64>,
    g2: Vec<f64>,
    sigma2: Vec<f64>,
    decide_seconds: f64,
}

/// The FL server.
pub struct Server<'rt> {
    /// System parameters (ε1/ε2 may be recalibrated in place when
    /// [`SystemParams::auto_eps`] is set).
    pub params: SystemParams,
    runtime: &'rt Runtime,
    fed: Federation,
    /// Coordinator-side per-client state.
    pub clients: Vec<ClientState>,
    channel_model: ChannelModel,
    /// The Lyapunov virtual queues λ1/λ2.
    pub queues: Queues,
    scheduler: Box<dyn Scheduler>,
    /// Global model θ^n.
    pub theta: Vec<f32>,
    round: usize,
    rng: Rng,
    /// Evaluate every k rounds (0 = never).
    pub eval_every: usize,
    /// Worker threads for the execution stage (`1` = legacy serial
    /// path). Any value produces bit-identical traces — see `fl::exec`.
    pub threads: usize,
    /// Per-worker reusable encode/noise buffers, kept alive across
    /// rounds (grown on demand when `threads` changes).
    scratch: Vec<exec::WorkerScratch>,
    /// Client-availability process ([`avail`]); `None` = every client
    /// is always available (the legacy engine, bit-for-bit).
    churn: Option<avail::AvailProcess>,
    /// Fault-injection plan ([`faults`]); `None` = no chaos (the
    /// fault-free engine, bit-for-bit).
    faults: Option<faults::FaultPlan>,
}

impl<'rt> Server<'rt> {
    /// Build a server over a loaded runtime, a generated federation and
    /// a scheduler; `seed` drives placement, channel draws and the
    /// per-client RNG streams.
    pub fn new(
        params: SystemParams,
        runtime: &'rt Runtime,
        fed: Federation,
        scheduler: Box<dyn Scheduler>,
        seed: u64,
    ) -> Result<Server<'rt>> {
        let mut rng = Rng::seed_from(seed);
        let channel_model = ChannelModel::new(&params, &mut rng);
        let theta = runtime.init()?;
        let theta_max0 = linf_norm(&theta) as f64;
        let clients: Vec<ClientState> = fed
            .clients
            .iter()
            .enumerate()
            .map(|(id, cd)| ClientState {
                id,
                size: cd.size as f64,
                stats: GradStats::prior(),
                theta_max: theta_max0,
                q_prev: Q_PREV_WARM_START,
                rng: rng.fork(1000 + id as u64),
            })
            .collect();
        // Queue warm start: treat the initial broadcast as a "round 0"
        // in which nothing was uploaded (λ1 sees the full exclusion
        // penalty) and any upload would have been 1-bit (λ2 sees the
        // q = 1 error mass). Without this, round 1 runs with λ = 0 —
        // zero constraint pressure — and QCCF wastes its first round on
        // a minimal, maximally-quantized participation the paper's
        // trajectories do not show.
        let mut queues = Queues::new();
        let w_full: Vec<f64> = {
            let total: f64 = clients.iter().map(|c: &ClientState| c.size).sum();
            clients.iter().map(|c| c.size / total).collect()
        };
        let g2: Vec<f64> = clients.iter().map(|c| c.stats.g2()).collect();
        let sigma2: Vec<f64> = clients.iter().map(|c| c.stats.sigma2()).collect();
        queues.lambda1 = convergence::data_term(
            &params,
            &vec![false; params.num_clients],
            &w_full,
            &vec![0.0; params.num_clients],
            &g2,
            &sigma2,
        );
        // λ2 warm-starts at the backlog that makes the round-1 optimum
        // a *low* level (q ≈ 3 for a typical client) — safely above the
        // destructive q = 1 regime but below equilibrium, so the level
        // trajectory rises over training (the paper's Remark 1 /
        // Fig. 5(a) dynamic) instead of jumping to the stationary point.
        let typical_rate = 18e6_f64.min(params.bandwidth_hz * 25.0);
        queues.lambda2 = crate::solver::lambda2_for_target_q(
            &params,
            3.0,
            typical_rate,
            1.0 / params.num_clients as f64,
            theta_max0,
        );
        Ok(Server {
            params,
            runtime,
            fed,
            clients,
            channel_model,
            queues,
            scheduler,
            theta,
            round: 0,
            rng,
            eval_every: 2,
            threads: threadpool::default_threads(),
            scratch: Vec::new(),
            churn: None,
            faults: None,
        })
    }

    /// Name of the scheduler driving the decisions.
    pub fn scheduler_name(&self) -> &'static str {
        self.scheduler.name()
    }

    /// Opt into client churn: install the seeded availability process
    /// (`seed` is the run seed — [`avail::AvailProcess`] salts it, so
    /// the availability streams never alias the server or scheduler
    /// streams). Call before [`Server::restore_state`] on a resume; the
    /// snapshot must then carry matching availability state.
    pub fn set_churn(&mut self, cfg: avail::AvailCfg, seed: u64) {
        self.churn = Some(avail::AvailProcess::new(self.params.num_clients, cfg, seed));
    }

    /// The availability process, when churn is on (diagnostics/tests).
    pub fn churn(&self) -> Option<&avail::AvailProcess> {
        self.churn.as_ref()
    }

    /// Opt into fault injection: install the seeded fault plan (`seed`
    /// is the run seed — [`faults::FaultPlan`] salts it, so the fault
    /// streams never alias the server, scheduler, or availability
    /// streams). Call before [`Server::restore_state`] on a resume; the
    /// snapshot must then carry matching fault state.
    pub fn set_faults(&mut self, cfg: faults::FaultCfg, seed: u64) {
        self.faults = Some(faults::FaultPlan::new(self.params.num_clients, cfg, seed));
    }

    /// The fault plan, when chaos is on (diagnostics/tests).
    pub fn faults(&self) -> Option<&faults::FaultPlan> {
        self.faults.as_ref()
    }

    /// One checkpoint-corruption draw from the plan-level chaos stream
    /// (`None` when chaos is off). The checkpointing caller asks once
    /// per snapshot write, **before** capturing state — the snapshot
    /// then records the post-draw stream position, so a run resumed
    /// from snapshot `k` draws at snapshot `2k` from exactly the
    /// position the uninterrupted run would have.
    pub fn draw_ckpt_corrupt(&mut self) -> Option<bool> {
        self.faults.as_mut().map(|f| f.draw_ckpt_corrupt())
    }

    /// Round-2 recalibration of ε1/ε2 (see `SystemParams::auto_eps`):
    /// ε1 slightly above the *minimum achievable* C6 arrival (full
    /// participation with the observed Ĝ/σ̂), ε2 at the C7 arrival of a
    /// mid-range q = 8 — so both queues are stabilizable but exert
    /// pressure whenever scheduling or quantization slacks off.
    fn recalibrate_eps(&mut self) {
        let p = &self.params;
        let u = p.num_clients;
        let sizes: Vec<f64> = self.clients.iter().map(|c| c.size).collect();
        let d_total: f64 = sizes.iter().sum();
        let w_full: Vec<f64> = sizes.iter().map(|d| d / d_total).collect();
        let g2: Vec<f64> = self.clients.iter().map(|c| c.stats.g2()).collect();
        let sigma2: Vec<f64> = self.clients.iter().map(|c| c.stats.sigma2()).collect();
        let data_full =
            convergence::data_term(p, &vec![true; u], &w_full, &w_full, &g2, &sigma2);
        let tmax = self.clients.iter().map(|c| c.theta_max).fold(0.0f64, f64::max);
        let quant_q8: f64 = (0..u)
            .map(|i| convergence::quant_term_client(p, w_full[i], tmax, 8))
            .sum();
        self.params.eps1 = 1.02 * data_full;
        self.params.eps2 = quant_q8.max(1e-12);
        crate::debug_log!(
            "fl",
            "auto-eps: eps1={:.4} eps2={:.6}",
            self.params.eps1,
            self.params.eps2
        );
    }

    /// Stage 1 — draw the round's channels and let the scheduler decide
    /// participation, channel allocation, quantization and frequency.
    fn stage_decide(&mut self) -> (RoundDecision, DecideCtx) {
        let p = self.params.clone();
        let channels = self.channel_model.draw(&mut self.rng);
        let sizes: Vec<f64> = self.clients.iter().map(|c| c.size).collect();
        let d_total: f64 = sizes.iter().sum();
        let w_full: Vec<f64> = sizes.iter().map(|d| d / d_total).collect();
        let g2: Vec<f64> = self.clients.iter().map(|c| c.stats.g2()).collect();
        let sigma2: Vec<f64> = self.clients.iter().map(|c| c.stats.sigma2()).collect();
        let theta_max: Vec<f64> = self.clients.iter().map(|c| c.theta_max).collect();
        let q_prev: Vec<f64> = self.clients.iter().map(|c| c.q_prev).collect();
        // Decide-time candidate mask: the availability state *before*
        // this round's Markov transition (the transition itself runs
        // between decide and execute — mid-round departures).
        let avail_mask: Option<Vec<bool>> = self.churn.as_ref().map(|a| a.mask().to_vec());
        let inputs = RoundInputs {
            params: &p,
            round: self.round,
            channels: &channels,
            sizes: &sizes,
            w_full: &w_full,
            g2: &g2,
            sigma2: &sigma2,
            theta_max: &theta_max,
            q_prev: &q_prev,
            queues: &self.queues,
            avail: avail_mask.as_deref(),
        };
        // Span-profiled (obs::spans — wall-clock stays inside the R2
        // allowlist): the reading feeds RoundRecord's decide_seconds
        // CSV column only, never a scheduling decision.
        let span = SpanGuard::enter(Span::Decide);
        let decision: RoundDecision = if avail_mask
            .as_ref()
            .is_some_and(|m| m.iter().all(|&on| !on))
        {
            // Nobody is available: an empty round, decided without
            // invoking the scheduler (whose search spaces degenerate at
            // zero candidates). Deterministic on resume because the
            // mask itself is.
            RoundDecision {
                assignments: vec![None; self.params.num_clients],
                j0: 0.0,
                evals: 0,
                deadline_exempt: false,
            }
        } else {
            self.scheduler.decide(&inputs)
        };
        let decide_seconds = span.finish_secs();
        if crate::util::logging::enabled(crate::util::logging::Level::Debug) {
            let greedy = crate::sched::greedy_allocation(&inputs);
            let (jg, ag) = crate::sched::evaluate_allocation(
                &inputs,
                &greedy,
                crate::solver::Case5Mode::Taylor,
            );
            crate::debug_log!(
                "fl",
                "round {}: decided {} participants (J0={:.3e}); greedy-full {} participants (J0={:.3e}); λ1={:.3e} ε1={:.3e} λ2={:.3e} ε2={:.3e}",
                self.round,
                decision.assignments.iter().flatten().count(),
                decision.j0,
                ag.iter().flatten().count(),
                jg,
                self.queues.lambda1,
                p.eps1,
                self.queues.lambda2,
                p.eps2
            );
        }
        (decision, DecideCtx { w_full, g2, sigma2, decide_seconds })
    }

    /// Between decide and execute under churn: advance every client's
    /// Markov chain by one transition and derive the round's execution
    /// options — mid-round departures (scheduled clients whose flag
    /// flipped off), the over-selection aggregation target, and the
    /// pre-tick staleness multipliers (decision-pure: captured before
    /// the transition, like everything else the fold weights depend
    /// on). Without churn this is `ExecOpts::default()` — the legacy
    /// path, untouched.
    fn churn_opts(&mut self, decision: &RoundDecision) -> exec::ExecOpts {
        let Some(av) = &mut self.churn else {
            return exec::ExecOpts::default();
        };
        let cfg = *av.cfg();
        let sched_ids: Vec<usize> = decision
            .assignments
            .iter()
            .enumerate()
            .filter_map(|(i, d)| d.is_some().then_some(i))
            .collect();
        let stale_scale: Option<Vec<f64>> =
            cfg.staleness.then(|| sched_ids.iter().map(|&i| av.stale_scale(i)).collect());
        av.tick();
        let departed: Vec<bool> = sched_ids.iter().map(|&i| !av.mask()[i]).collect();
        exec::ExecOpts {
            departed: Some(departed),
            n_target: Some(avail::aggregation_target(sched_ids.len(), cfg.over_select)),
            stale_scale,
            faults: None,
        }
    }

    /// Between decide and execute under chaos: draw **every** client's
    /// faults for the round (scheduled or not — the tick count per
    /// stream must not depend on scheduling, or the fault history would
    /// stop being a pure function of `(seed, round)`), then attach the
    /// scheduled clients' draws to the execution options in task order.
    fn fault_opts(&mut self, decision: &RoundDecision, opts: &mut exec::ExecOpts) {
        let Some(fp) = &mut self.faults else {
            return;
        };
        fp.tick();
        let draws: Vec<faults::FaultDraw> = decision
            .assignments
            .iter()
            .enumerate()
            .filter_map(|(i, d)| d.as_ref().map(|_| fp.draws()[i]))
            .collect();
        opts.faults = Some(draws);
    }

    /// Stage 2 — fan the scheduled clients out over the worker pool
    /// (`self.threads`; 1 = serial) and write the advanced per-client
    /// state back in client-id order, exactly as the serial loop did.
    /// Departed clients get the same writebacks as C4 misses — they
    /// trained and transmitted; only their upload is lost.
    fn stage_execute(
        &mut self,
        decision: &RoundDecision,
        opts: &exec::ExecOpts,
    ) -> Result<exec::ExecOutput> {
        // Span-profiled like stage_decide: the execute span's reading
        // becomes RoundRecord's compute_seconds CSV column, nothing
        // deterministic.
        let span = SpanGuard::enter(Span::Execute);
        let mut tasks: Vec<exec::ClientTask<'_>> = Vec::new();
        for (i, d) in decision.assignments.iter().enumerate() {
            let Some(d) = d else { continue };
            tasks.push(exec::ClientTask {
                id: i,
                size: self.clients[i].size,
                decision: *d,
                deadline_exempt: decision.deadline_exempt,
                cpu_scale: self.params.cpu_scale(i),
                data: &self.fed.clients[i],
                rng: self.clients[i].rng.clone(),
            });
        }
        let mut out = exec::execute_round_with(
            &self.params,
            self.runtime,
            &self.theta,
            tasks,
            self.threads,
            &mut self.scratch,
            opts,
        )?;
        for oc in &out.outcomes {
            let c = &mut self.clients[oc.id];
            c.rng = oc.rng.clone();
            c.stats.update(&oc.gnorms);
            c.theta_max = oc.theta_max;
            if let Some(q) = oc.q {
                c.q_prev = q as f64;
            }
        }
        out.compute_seconds = span.finish_secs();
        Ok(out)
    }

    /// Stage 3 — install the streamed weighted mean as θ^{n+1}
    /// (eq. (2)). Uploads past the C4 deadline were never committed to
    /// the fold, so the weights already renormalize over the survivors;
    /// an empty survivor set — or one whose data mass is zero, where
    /// the renormalized weights would be 0/0 — keeps the previous
    /// global model (see `exec::survivor_weights`).
    fn stage_aggregate(&mut self, exec_out: &mut exec::ExecOutput) {
        if let Some(next) = exec_out.aggregate.take() {
            self.theta = next;
        }
    }

    /// Stage 4 — queue updates (eqs. (23)–(24)) with the realized
    /// participation/levels, then refresh the decision-time θ^max
    /// estimates from the new global model.
    fn stage_update_queues(&mut self, ctx: &DecideCtx, exec_out: &exec::ExecOutput) {
        let u = self.params.num_clients;
        let d_sched: f64 = exec_out.outcomes.iter().map(|oc| self.clients[oc.id].size).sum();
        let mut participating = vec![false; u];
        let mut w_round = vec![0.0f64; u];
        let mut realized_theta_max = vec![0.0f64; u];
        let mut realized_q: Vec<Option<u32>> = vec![None; u];
        for oc in &exec_out.outcomes {
            participating[oc.id] = true;
            // w_i^n the server *intended* (over all scheduled clients).
            w_round[oc.id] = self.clients[oc.id].size / d_sched;
            realized_theta_max[oc.id] = oc.theta_max;
            realized_q[oc.id] = oc.q;
        }
        let data = convergence::data_term(
            &self.params,
            &participating,
            &ctx.w_full,
            &w_round,
            &ctx.g2,
            &ctx.sigma2,
        );
        let quant =
            convergence::quant_term(&self.params, &w_round, &realized_theta_max, &realized_q);
        self.queues.update(&self.params, data, quant);

        let tmax_global = linf_norm(&self.theta) as f64;
        for c in self.clients.iter_mut() {
            c.theta_max =
                if c.theta_max > 0.0 { 0.5 * c.theta_max + 0.5 * tmax_global } else { tmax_global };
        }
    }

    /// Evaluation + record assembly.
    fn finish_round(&mut self, ctx: &DecideCtx, exec_out: &exec::ExecOutput) -> Result<RoundRecord> {
        let (test_loss, test_acc) = if self.eval_every > 0 && self.round % self.eval_every == 0 {
            let (l, a) =
                self.runtime.evaluate(&self.theta, &self.fed.test.images, &self.fed.test.labels)?;
            (Some(l), Some(a))
        } else {
            (None, None)
        };

        let qs: Vec<f64> =
            exec_out.outcomes.iter().filter_map(|oc| oc.q).map(|q| q as f64).collect();
        let mean_q = if qs.is_empty() { 0.0 } else { qs.iter().sum::<f64>() / qs.len() as f64 };
        let mut q_per_client: Vec<Option<u32>> = vec![None; self.params.num_clients];
        for oc in &exec_out.outcomes {
            q_per_client[oc.id] = Some(oc.q.unwrap_or(Q_RECORD_RAW));
        }

        Ok(RoundRecord {
            round: self.round,
            scheduled: exec_out.scheduled,
            aggregated: exec_out.aggregated,
            departed: exec_out.departed,
            retries: exec_out.retries,
            failed_decodes: exec_out.failed_decodes,
            wire_bytes: exec_out.wire_bytes,
            energy: exec_out.round_energy,
            cum_energy: 0.0, // filled by run()
            train_loss: if exec_out.loss_n > 0 {
                exec_out.loss_sum / exec_out.loss_n as f64
            } else {
                f64::NAN
            },
            test_loss,
            test_acc,
            mean_q,
            q_per_client,
            lambda1: self.queues.lambda1,
            lambda2: self.queues.lambda2,
            max_latency: exec_out.max_latency,
            decide_seconds: ctx.decide_seconds,
            compute_seconds: exec_out.compute_seconds,
        })
    }

    /// Run one communication round; returns its record.
    pub fn run_round(&mut self) -> Result<RoundRecord> {
        self.round += 1;
        // ε tracking (see `SystemParams::auto_eps`): gradient norms decay
        // as training converges, so a fixed ε1 calibrated early becomes
        // asymptotically slack and the C6 pressure vanishes (the queue
        // drains and scheduling collapses); tracking the current Ĝ/σ̂
        // keeps C6/C7 tight-but-satisfiable all along the run.
        if self.params.auto_eps && self.round >= 2 {
            self.recalibrate_eps();
        }
        let (decision, ctx) = self.stage_decide();
        let mut opts = self.churn_opts(&decision);
        self.fault_opts(&decision, &mut opts);
        let mut exec_out = self.stage_execute(&decision, &opts)?;
        {
            let _span = SpanGuard::enter(Span::Aggregate);
            self.stage_aggregate(&mut exec_out);
        }
        {
            let _span = SpanGuard::enter(Span::QueueUpdate);
            self.stage_update_queues(&ctx, &exec_out);
        }
        // Staleness bookkeeping: one round passed for everyone, and the
        // clients whose uploads made the aggregate reset their gap.
        if let Some(av) = &mut self.churn {
            let agg_ids: Vec<usize> = exec_out
                .outcomes
                .iter()
                .zip(&exec_out.survived)
                .filter_map(|(oc, &s)| s.then_some(oc.id))
                .collect();
            av.note_round(&agg_ids);
        }
        self.finish_round(&ctx, &exec_out)
    }

    /// Run `rounds` communication rounds and return the trace.
    pub fn run(&mut self, rounds: usize) -> Result<Trace> {
        let mut trace = Trace::new(self.scheduler.name());
        let mut cum = 0.0;
        for _ in 0..rounds {
            let mut rec = self.run_round()?;
            cum += rec.energy;
            rec.cum_energy = cum;
            trace.push(rec);
        }
        Ok(trace)
    }

    /// Communication rounds completed so far.
    pub fn round(&self) -> usize {
        self.round
    }

    /// Capture the server's complete resumable state for the `ckpt`
    /// subsystem: round index, θ, virtual queues (with history), the
    /// possibly auto-recalibrated ε1/ε2, every client's estimator /
    /// θ^max / `q_prev` anchor / private RNG stream, the server's
    /// master stream, the scheduler's stream (if it owns one), the
    /// availability process (when churn is on — per-client on/off flag,
    /// missed counter and Markov stream), the fault plan (when chaos is
    /// on — per-client fault streams plus the checkpoint-corruption
    /// stream), and the
    /// runtime's profiling clock (captured as observed; restored only
    /// by exclusive-runtime callers — see [`Server::restore_state`]).
    /// Everything *not* captured here —
    /// federation data, channel pathloss placement, the compiled
    /// artifacts — is a deterministic function of (scenario, seed) and
    /// replays identically through [`Server::new`] on resume.
    pub fn checkpoint_state(&self) -> crate::ckpt::RunState {
        crate::ckpt::RunState {
            round: self.round as u64,
            eps1: self.params.eps1,
            eps2: self.params.eps2,
            theta: self.theta.clone(),
            lambda1: self.queues.lambda1,
            lambda2: self.queues.lambda2,
            queue_history: self.queues.history().to_vec(),
            clients: self
                .clients
                .iter()
                .map(|c| crate::ckpt::ClientCkpt {
                    g: c.stats.g,
                    sigma: c.stats.sigma,
                    ema: c.stats.ema,
                    observed: c.stats.observed,
                    theta_max: c.theta_max,
                    q_prev: c.q_prev,
                    rng: c.rng.state(),
                })
                .collect(),
            server_rng: self.rng.state(),
            sched_rng: self.scheduler.rng_state(),
            avail: self.churn.as_ref().map(|a| a.checkpoint()),
            faults: self.faults.as_ref().map(|f| f.checkpoint()),
            runtime_nanos: self.runtime.exec_nanos_snapshot(),
        }
    }

    /// Reinstall state captured by [`Server::checkpoint_state`] over a
    /// freshly constructed server (same scenario, algorithm and seed —
    /// the caller verifies that identity; see `ckpt::Snapshot`).
    /// Subsequent rounds are bit-identical to the uninterrupted run.
    pub fn restore_state(&mut self, st: &crate::ckpt::RunState) -> Result<()> {
        anyhow::ensure!(
            st.clients.len() == self.clients.len(),
            "snapshot has {} clients, server has {} — scenario mismatch",
            st.clients.len(),
            self.clients.len()
        );
        anyhow::ensure!(
            st.theta.len() == self.theta.len(),
            "snapshot θ has {} dims, runtime profile has {} — artifact profile mismatch",
            st.theta.len(),
            self.theta.len()
        );
        anyhow::ensure!(
            st.sched_rng.is_some() == self.scheduler.rng_state().is_some(),
            "snapshot {} a scheduler RNG stream but `{}` {} one — algorithm mismatch",
            if st.sched_rng.is_some() { "carries" } else { "lacks" },
            self.scheduler.name(),
            if self.scheduler.rng_state().is_some() { "owns" } else { "has no" },
        );
        anyhow::ensure!(
            st.avail.is_some() == self.churn.is_some(),
            "snapshot {} availability state but the server {} churn — \
             scenario churn config mismatch",
            if st.avail.is_some() { "carries" } else { "lacks" },
            if self.churn.is_some() { "runs with" } else { "runs without" },
        );
        anyhow::ensure!(
            st.faults.is_some() == self.faults.is_some(),
            "snapshot {} fault state but the server {} chaos — \
             scenario chaos config mismatch",
            if st.faults.is_some() { "carries" } else { "lacks" },
            if self.faults.is_some() { "runs with" } else { "runs without" },
        );
        if let (Some(av), Some(snap)) = (&mut self.churn, &st.avail) {
            av.restore(snap)?;
        }
        if let (Some(fp), Some(snap)) = (&mut self.faults, &st.faults) {
            fp.restore(snap)?;
        }
        self.round = st.round as usize;
        self.params.eps1 = st.eps1;
        self.params.eps2 = st.eps2;
        self.theta = st.theta.clone();
        self.queues =
            Queues::restore(st.lambda1, st.lambda2, st.queue_history.clone());
        for (c, ck) in self.clients.iter_mut().zip(&st.clients) {
            c.stats.g = ck.g;
            c.stats.sigma = ck.sigma;
            c.stats.ema = ck.ema;
            c.stats.observed = ck.observed;
            c.theta_max = ck.theta_max;
            c.q_prev = ck.q_prev;
            c.rng.restore(&ck.rng);
        }
        self.rng.restore(&st.server_rng);
        if let Some(sr) = &st.sched_rng {
            self.scheduler.restore_rng_state(sr);
        }
        // Deliberately NOT restored here: the runtime profiling clock.
        // The `Runtime` is process-shared (a parallel sweep runs many
        // servers over one runtime), so writing the snapshot's counters
        // back would clobber accounting other in-flight runs are
        // accumulating concurrently. The caller that *owns* the runtime
        // exclusively opts in via
        // `CheckpointPolicy::restore_runtime_clock`.
        Ok(())
    }

    /// Per-client dataset sizes (diagnostics / Fig. 5b).
    pub fn sizes(&self) -> Vec<f64> {
        self.clients.iter().map(|c| c.size).collect()
    }
}
