//! The FL server loop (paper §II-A, Fig. 1): per communication round —
//! **decision → broadcast → local update → quantize → upload →
//! aggregate** — with the wireless/energy bookkeeping and Lyapunov queue
//! updates of §IV–§V.
//!
//! The server *realizes* whatever the scheduler intended: it trains the
//! scheduled clients through the PJRT runtime, quantizes their uploads
//! through the Pallas-kernel artifact, re-checks the latency budget C4
//! with the client's actual D_i (so wireless-oblivious baselines pay for
//! timeouts exactly as in §VI), accounts energy with eqs. (14)–(17), and
//! aggregates per eq. (2) over the uploads that made the deadline.

use anyhow::Result;

use crate::config::SystemParams;
use crate::convergence::{self, GradStats};
use crate::data::Federation;
use crate::energy;
use crate::lyapunov::Queues;
use crate::metrics::{RoundRecord, Trace};
use crate::runtime::Runtime;
use crate::sched::{RoundDecision, RoundInputs, Scheduler};
use crate::util::rng::Rng;
use crate::util::stats::linf_norm;
use crate::wireless::ChannelModel;

/// Per-client coordinator-side state.
#[derive(Clone, Debug)]
pub struct ClientState {
    pub id: usize,
    /// D_i.
    pub size: f64,
    pub stats: GradStats,
    /// θ^max estimate used at decision time (from the global model).
    pub theta_max: f64,
    /// q from the last participation (Case-5 anchor).
    pub q_prev: f64,
    /// Private noise stream for quantization.
    pub rng: Rng,
}

/// The FL server.
pub struct Server<'rt> {
    pub params: SystemParams,
    runtime: &'rt Runtime,
    fed: Federation,
    pub clients: Vec<ClientState>,
    channel_model: ChannelModel,
    pub queues: Queues,
    scheduler: Box<dyn Scheduler>,
    /// Global model θ^n.
    pub theta: Vec<f32>,
    round: usize,
    rng: Rng,
    /// Evaluate every k rounds (0 = never).
    pub eval_every: usize,
}

impl<'rt> Server<'rt> {
    pub fn new(
        params: SystemParams,
        runtime: &'rt Runtime,
        fed: Federation,
        scheduler: Box<dyn Scheduler>,
        seed: u64,
    ) -> Result<Server<'rt>> {
        let mut rng = Rng::seed_from(seed);
        let channel_model = ChannelModel::new(&params, &mut rng);
        let theta = runtime.init()?;
        let theta_max0 = linf_norm(&theta) as f64;
        let clients: Vec<ClientState> = fed
            .clients
            .iter()
            .enumerate()
            .map(|(id, cd)| ClientState {
                id,
                size: cd.size as f64,
                stats: GradStats::prior(),
                theta_max: theta_max0,
                q_prev: 4.0,
                rng: rng.fork(1000 + id as u64),
            })
            .collect();
        // Queue warm start: treat the initial broadcast as a "round 0"
        // in which nothing was uploaded (λ1 sees the full exclusion
        // penalty) and any upload would have been 1-bit (λ2 sees the
        // q = 1 error mass). Without this, round 1 runs with λ = 0 —
        // zero constraint pressure — and QCCF wastes its first round on
        // a minimal, maximally-quantized participation the paper's
        // trajectories do not show.
        let mut queues = Queues::new();
        let w_full: Vec<f64> = {
            let total: f64 = clients.iter().map(|c: &ClientState| c.size).sum();
            clients.iter().map(|c| c.size / total).collect()
        };
        let g2: Vec<f64> = clients.iter().map(|c| c.stats.g2()).collect();
        let sigma2: Vec<f64> = clients.iter().map(|c| c.stats.sigma2()).collect();
        queues.lambda1 = convergence::data_term(
            &params,
            &vec![false; params.num_clients],
            &w_full,
            &vec![0.0; params.num_clients],
            &g2,
            &sigma2,
        );
        // λ2 warm-starts at the backlog that makes the round-1 optimum
        // a *low* level (q ≈ 3 for a typical client) — safely above the
        // destructive q = 1 regime but below equilibrium, so the level
        // trajectory rises over training (the paper's Remark 1 /
        // Fig. 5(a) dynamic) instead of jumping to the stationary point.
        let typical_rate = 18e6_f64.min(params.bandwidth_hz * 25.0);
        queues.lambda2 = crate::solver::lambda2_for_target_q(
            &params,
            3.0,
            typical_rate,
            1.0 / params.num_clients as f64,
            theta_max0,
        );
        Ok(Server {
            params,
            runtime,
            fed,
            clients,
            channel_model,
            queues,
            scheduler,
            theta,
            round: 0,
            rng,
            eval_every: 2,
        })
    }

    pub fn scheduler_name(&self) -> &'static str {
        self.scheduler.name()
    }

    /// Round-2 recalibration of ε1/ε2 (see `SystemParams::auto_eps`):
    /// ε1 slightly above the *minimum achievable* C6 arrival (full
    /// participation with the observed Ĝ/σ̂), ε2 at the C7 arrival of a
    /// mid-range q = 8 — so both queues are stabilizable but exert
    /// pressure whenever scheduling or quantization slacks off.
    fn recalibrate_eps(&mut self) {
        let p = &self.params;
        let u = p.num_clients;
        let sizes: Vec<f64> = self.clients.iter().map(|c| c.size).collect();
        let d_total: f64 = sizes.iter().sum();
        let w_full: Vec<f64> = sizes.iter().map(|d| d / d_total).collect();
        let g2: Vec<f64> = self.clients.iter().map(|c| c.stats.g2()).collect();
        let sigma2: Vec<f64> = self.clients.iter().map(|c| c.stats.sigma2()).collect();
        let data_full =
            convergence::data_term(p, &vec![true; u], &w_full, &w_full, &g2, &sigma2);
        let tmax = self.clients.iter().map(|c| c.theta_max).fold(0.0f64, f64::max);
        let quant_q8: f64 = (0..u)
            .map(|i| convergence::quant_term_client(p, w_full[i], tmax, 8))
            .sum();
        self.params.eps1 = 1.02 * data_full;
        self.params.eps2 = quant_q8.max(1e-12);
        crate::debug_log!(
            "fl",
            "auto-eps: eps1={:.4} eps2={:.6}",
            self.params.eps1,
            self.params.eps2
        );
    }

    /// Run one communication round; returns its record.
    pub fn run_round(&mut self) -> Result<RoundRecord> {
        self.round += 1;
        // ε tracking (see `SystemParams::auto_eps`): gradient norms decay
        // as training converges, so a fixed ε1 calibrated early becomes
        // asymptotically slack and the C6 pressure vanishes (the queue
        // drains and scheduling collapses); tracking the current Ĝ/σ̂
        // keeps C6/C7 tight-but-satisfiable all along the run.
        if self.params.auto_eps && self.round >= 2 {
            self.recalibrate_eps();
        }
        let p = self.params.clone();
        let u = p.num_clients;

        // --- Step 1: decision ------------------------------------------
        let channels = self.channel_model.draw(&mut self.rng);
        let sizes: Vec<f64> = self.clients.iter().map(|c| c.size).collect();
        let d_total: f64 = sizes.iter().sum();
        let w_full: Vec<f64> = sizes.iter().map(|d| d / d_total).collect();
        let g2: Vec<f64> = self.clients.iter().map(|c| c.stats.g2()).collect();
        let sigma2: Vec<f64> = self.clients.iter().map(|c| c.stats.sigma2()).collect();
        let theta_max: Vec<f64> = self.clients.iter().map(|c| c.theta_max).collect();
        let q_prev: Vec<f64> = self.clients.iter().map(|c| c.q_prev).collect();
        let inputs = RoundInputs {
            params: &p,
            round: self.round,
            channels: &channels,
            sizes: &sizes,
            w_full: &w_full,
            g2: &g2,
            sigma2: &sigma2,
            theta_max: &theta_max,
            q_prev: &q_prev,
            queues: &self.queues,
        };
        let t_decide = std::time::Instant::now();
        let decision: RoundDecision = self.scheduler.decide(&inputs);
        let decide_seconds = t_decide.elapsed().as_secs_f64();
        if crate::util::logging::enabled(crate::util::logging::Level::Debug) {
            let greedy = crate::sched::greedy_allocation(&inputs);
            let (jg, ag) = crate::sched::evaluate_allocation(
                &inputs,
                &greedy,
                crate::solver::Case5Mode::Taylor,
            );
            crate::debug_log!(
                "fl",
                "round {}: decided {} participants (J0={:.3e}); greedy-full {} participants (J0={:.3e}); λ1={:.3e} ε1={:.3e} λ2={:.3e} ε2={:.3e}",
                self.round,
                decision.assignments.iter().flatten().count(),
                decision.j0,
                ag.iter().flatten().count(),
                jg,
                self.queues.lambda1,
                p.eps1,
                self.queues.lambda2,
                p.eps2
            );
        }

        // --- Steps 2–4: broadcast, local update, quantize, upload ------
        let t_compute = std::time::Instant::now();
        let info = &self.runtime.info;
        let pix = info.pix();
        let mut uploads: Vec<(usize, Vec<f32>, f64)> = Vec::new(); // (client, model, w-unnormalized)
        let mut scheduled = 0usize;
        let mut round_energy = 0.0f64;
        let mut max_latency = 0.0f64;
        let mut loss_sum = 0.0f64;
        let mut loss_n = 0usize;
        let mut q_per_client: Vec<Option<u32>> = vec![None; u];
        let mut realized_q: Vec<Option<u32>> = vec![None; u];
        let mut w_round = vec![0.0f64; u];
        let mut realized_theta_max = vec![0.0f64; u];
        let mut participating = vec![false; u];

        // w_i^n over scheduled clients (the aggregation weights the
        // server *intends*; uploads that time out are renormalized out).
        let d_sched: f64 = decision
            .assignments
            .iter()
            .enumerate()
            .filter(|(_, d)| d.is_some())
            .map(|(i, _)| sizes[i])
            .sum();

        for (i, d) in decision.assignments.iter().enumerate() {
            let Some(d) = d else { continue };
            scheduled += 1;
            participating[i] = true;
            w_round[i] = sizes[i] / d_sched;

            // Local update (τ steps through the AOT train_step).
            let (xs, ys) =
                self.fed.clients[i].sample_batches(&mut self.clients[i].rng, info.tau, info.batch, pix);
            let out = self.runtime.train_step(&self.theta, &xs, &ys, info.lr as f32)?;
            self.clients[i].stats.update(&out.gnorms);
            loss_sum += out.mean_loss as f64;
            loss_n += 1;

            // Quantize (or raw upload).
            let (upload, tmax, bits) = match d.q {
                Some(q) => {
                    let mut noise = vec![0.0f32; info.z];
                    self.clients[i].rng.fill_uniform_f32(&mut noise);
                    let (qtheta, tmax) = self.runtime.quantize(&out.theta, &noise, q as f32)?;
                    (qtheta, tmax as f64, p.payload_bits(q))
                }
                None => {
                    let tmax = linf_norm(&out.theta) as f64;
                    (out.theta.clone(), tmax, p.raw_payload_bits())
                }
            };
            realized_theta_max[i] = tmax;
            self.clients[i].theta_max = tmax;
            q_per_client[i] = Some(d.q.unwrap_or(0));
            realized_q[i] = d.q;
            self.clients[i].q_prev = d.q.unwrap_or(32) as f64;

            // Latency & energy with the *actual* D_i and decision (f, q).
            let t_cmp = energy::t_cmp(&p, sizes[i], d.f);
            let t_com = bits / d.rate;
            let latency = t_cmp + t_com;
            max_latency = max_latency.max(latency);
            round_energy += energy::e_cmp(&p, sizes[i], d.f) + energy::e_com(&p, t_com);

            // C4 check: uploads past the deadline are dropped (energy
            // already spent) — the paper's timeout/dropout mechanism.
            // The No-Quantization baseline is exempt (no latency design).
            if decision.deadline_exempt || latency <= p.t_max * (1.0 + 1e-9) {
                uploads.push((i, upload, sizes[i]));
            }
        }
        let compute_seconds = t_compute.elapsed().as_secs_f64();

        // --- Step 5: aggregation (eq. (2)) ------------------------------
        let aggregated = uploads.len();
        if aggregated > 0 {
            let w_total: f64 = uploads.iter().map(|(_, _, w)| w).sum();
            let mut next = vec![0.0f32; self.theta.len()];
            for (_, model, w) in &uploads {
                let wf = (*w / w_total) as f32;
                for (n, m) in next.iter_mut().zip(model.iter()) {
                    *n += wf * m;
                }
            }
            self.theta = next;
        }

        // --- Queue updates (eqs. (23)–(24)) with realized terms ---------
        let data = convergence::data_term(&p, &participating, &w_full, &w_round, &g2, &sigma2);
        let quant = convergence::quant_term(&p, &w_round, &realized_theta_max, &realized_q);
        self.queues.update(&p, data, quant);

        // Refresh decision-time θ^max estimates from the new global model.
        let tmax_global = linf_norm(&self.theta) as f64;
        for c in self.clients.iter_mut() {
            c.theta_max = if c.theta_max > 0.0 { 0.5 * c.theta_max + 0.5 * tmax_global } else { tmax_global };
        }

        // --- Evaluation --------------------------------------------------
        let (test_loss, test_acc) = if self.eval_every > 0 && self.round % self.eval_every == 0 {
            let (l, a) = self.runtime.evaluate(&self.theta, &self.fed.test.images, &self.fed.test.labels)?;
            (Some(l), Some(a))
        } else {
            (None, None)
        };

        let qs: Vec<f64> = realized_q.iter().flatten().map(|&q| q as f64).collect();
        let mean_q = if qs.is_empty() { 0.0 } else { qs.iter().sum::<f64>() / qs.len() as f64 };

        Ok(RoundRecord {
            round: self.round,
            scheduled,
            aggregated,
            energy: round_energy,
            cum_energy: 0.0, // filled by run()
            train_loss: if loss_n > 0 { loss_sum / loss_n as f64 } else { f64::NAN },
            test_loss,
            test_acc,
            mean_q,
            q_per_client,
            lambda1: self.queues.lambda1,
            lambda2: self.queues.lambda2,
            max_latency,
            decide_seconds,
            compute_seconds,
        })
    }

    /// Run `rounds` communication rounds and return the trace.
    pub fn run(&mut self, rounds: usize) -> Result<Trace> {
        let mut trace = Trace::new(self.scheduler.name());
        let mut cum = 0.0;
        for _ in 0..rounds {
            let mut rec = self.run_round()?;
            cum += rec.energy;
            rec.cum_energy = cum;
            trace.push(rec);
        }
        Ok(trace)
    }

    pub fn round(&self) -> usize {
        self.round
    }

    /// Per-client dataset sizes (diagnostics / Fig. 5b).
    pub fn sizes(&self) -> Vec<f64> {
        self.clients.iter().map(|c| c.size).collect()
    }
}
