//! Client-side round execution (stage 2 of the round engine): everything
//! a *scheduled client* does — sample → τ local SGD steps → quantize →
//! latency/energy accounting → C4 deadline check — packaged as a pure
//! `ClientTask → ClientOutcome` function so the server can fan the
//! scheduled set out over a worker pool.
//!
//! # Determinism contract
//!
//! A parallel round produces **bit-identical** results to the serial
//! round, for any thread count:
//!
//! * every client trains and quantizes on its own forked RNG stream
//!   (`rng.fork(1000 + id)` at server construction), carried *inside*
//!   the task and returned advanced in the outcome — no draw ever
//!   depends on scheduling order;
//! * C4 survival is a pure function of the decision (`t_cmp + ℓ/rate`),
//!   so the renormalized aggregation weights over the surviving uploads
//!   are known **before** any training runs;
//! * uploads therefore stream into an [`StreamingAggregator`] that
//!   folds models in ascending client order no matter which worker
//!   finishes first, reproducing the serial f32 summation exactly.
//!
//! # Byte-faithful transport
//!
//! What moves between [`run_client`] and the aggregator is what the
//! paper meters: a quantized upload is the eq. (5) bit-packed payload
//! ([`Upload::Wire`], `ceil((Z·q + Z + 32)/8)` bytes — the thing whose
//! airtime eqs. (14)–(15) charge), not a dequantized `Vec<f32>`; only
//! the No-Quantization baseline ships raw 32-bit floats
//! ([`Upload::Raw`]). The aggregator folds `w·(idx·Δ)` straight out of
//! the bitstream (`quant::wire::fold_into`), so in-flight memory per
//! upload drops from 32 bits/dim to ~(q+1) bits/dim while the fold
//! arithmetic — and therefore θ^{n+1} — stays bit-identical to the old
//! `Vec<f32>` path (pinned by `tests/integration_fl.rs::
//! wire_transport_bit_identical_to_kernel_dequantize_fold`).
//!
//! The streaming fold also replaces the old `Vec<(id, model, w)>` of
//! full-model clones: peak memory drops from `O(scheduled × Z)` to
//! `O(threads × Z·(q+1)/32)` (`O(Z)` on the serial path), because each
//! payload is dropped the moment it is folded into the running sum.

use std::collections::BTreeMap;
use std::sync::{Condvar, Mutex};

use anyhow::Result;

use crate::config::SystemParams;
use crate::data::ClientData;
use crate::energy;
use crate::quant::{self, wire};
use crate::runtime::Runtime;
use crate::sched::ClientDecision;
use crate::util::rng::Rng;
use crate::util::stats::linf_norm;
use crate::util::threadpool;

use super::faults::FaultDraw;

/// Compute-term stretch factor for an injected straggle fault
/// (`fl::faults`): the client's `t_cmp` term takes this many times
/// longer on the wall clock. Energy is unchanged — a stall burns time,
/// not joules — but the stretched latency can blow the C4 deadline,
/// dropping the client exactly like any other deadline miss.
pub const STRAGGLE_FACTOR: f64 = 4.0;

/// One scheduled client's work order, built by the server's decision
/// stage. Owns the client's private RNG stream for the duration of the
/// round; the advanced stream comes back in [`ClientOutcome::rng`].
pub struct ClientTask<'a> {
    /// Client id (ascending task order defines the aggregation fold).
    pub id: usize,
    /// D_i.
    pub size: f64,
    /// The scheduler's intended (channel, q, f, rate) for this client.
    pub decision: ClientDecision,
    /// Round-wide C4 exemption (No-Quantization baseline).
    pub deadline_exempt: bool,
    /// Realized-frequency multiplier in (0, 1]
    /// ([`SystemParams::cpu_scale`]): the device runs at
    /// `decision.f × cpu_scale`, so straggler-class clients blow
    /// through the latency the scheduler planned for — decisions stay
    /// oblivious, execution pays.
    pub cpu_scale: f64,
    /// The client's local dataset.
    pub data: &'a ClientData,
    /// The client's private RNG stream (advanced copy returned in the
    /// outcome).
    pub rng: Rng,
}

/// A client's upload as it crosses the (simulated) uplink — the byte
/// transport stage. Quantized uploads are the eq. (5) bit-packed
/// payload; only the No-Quantization baseline ships raw floats.
#[derive(Clone, Debug)]
pub enum Upload {
    /// Bit-packed quantized payload (`quant::wire`): 32-bit θ^max
    /// header + Z sign bits + Z q-bit knot indices.
    Wire {
        /// The `ceil(encoded_bits(Z, q) / 8)` payload bytes.
        bytes: Vec<u8>,
        /// Quantization level the payload was packed at.
        q: u32,
    },
    /// Raw 32-bit float upload (No-Quantization baseline).
    Raw(Vec<f32>),
}

impl Upload {
    /// Realized bytes on the wire for this upload.
    pub fn wire_bytes(&self) -> usize {
        match self {
            Upload::Wire { bytes, .. } => bytes.len(),
            Upload::Raw(model) => 4 * model.len(),
        }
    }
}

/// Per-worker reusable buffers for the execution stage: the
/// quantization noise stream and the wire-encode staging (knot indices
/// + sign bits). One instance per worker thread, reused across every
/// client the worker processes — and across rounds, since the server
/// owns the pool — so the hot path's only per-upload allocation is the
/// payload that actually crosses the uplink.
#[derive(Debug, Default)]
pub struct WorkerScratch {
    noise: Vec<f32>,
    idx: Vec<u32>,
    signs: Vec<bool>,
}

/// Everything the coordinator learns from one client's round.
pub struct ClientOutcome {
    /// Client id (matches the task).
    pub id: usize,
    /// Mean training loss over the τ local steps.
    pub mean_loss: f64,
    /// Per-local-step gradient norms (feeds `GradStats`).
    pub gnorms: Vec<f32>,
    /// Realized θ^max of the upload.
    pub theta_max: f64,
    /// Realized level (`None` = raw upload).
    pub q: Option<u32>,
    /// Realized round latency (s), eqs. (14) + (16).
    pub latency: f64,
    /// Realized round energy (J), eqs. (15) + (17).
    pub energy: f64,
    /// Realized bytes on the wire — `ceil(eq. (5)/8)` for quantized
    /// uploads, `4·Z` raw. Counted whether or not the upload made the
    /// C4 deadline: the airtime (and its energy) is spent either way.
    pub payload_bytes: usize,
    /// The upload payload; present iff it made the C4 deadline, and
    /// taken by the streaming aggregator before the outcome reaches
    /// the server.
    pub upload: Option<Upload>,
    /// The client's RNG stream, advanced exactly as in a serial round.
    pub rng: Rng,
}

/// ℓ of the decision's payload: eq. (5) for quantized uploads, the raw
/// 32-bit payload otherwise.
fn decision_payload_bits(p: &SystemParams, d: &ClientDecision) -> f64 {
    match d.q {
        Some(q) => p.payload_bits(q),
        None => p.raw_payload_bits(),
    }
}

/// Latency the decision realizes on a client with dataset size `size`
/// (eqs. (14), (16)) at effective frequency `f × cpu_scale`
/// (`cpu_scale = 1` outside the straggler class). A pure function of
/// the decision and the static class assignment — this is what makes
/// C4 survival computable before training.
pub fn realized_latency(p: &SystemParams, size: f64, d: &ClientDecision, cpu_scale: f64) -> f64 {
    energy::t_cmp(p, size, d.f * cpu_scale) + decision_payload_bits(p, d) / d.rate
}

/// Energy the decision costs (eqs. (15), (17)) at the effective
/// frequency — spent whether or not the upload survives C4. A throttled
/// device burns *less* compute energy (f² scaling) but risks the
/// deadline, exactly the straggler trade-off the scenario studies.
pub fn realized_energy(p: &SystemParams, size: f64, d: &ClientDecision, cpu_scale: f64) -> f64 {
    energy::e_cmp(p, size, d.f * cpu_scale)
        + energy::e_com(p, decision_payload_bits(p, d) / d.rate)
}

/// C4 with a 1e-9 relative tolerance: uploads that *exactly* meet the
/// budget (decisions at the 𝒮(q) frequency) must not drop to float
/// noise. The No-Quantization baseline is exempt (no latency design).
pub fn survives_deadline(p: &SystemParams, latency: f64, exempt: bool) -> bool {
    exempt || latency <= p.t_max * (1.0 + 1e-9)
}

/// Airtime energy for the retransmission attempts beyond the first:
/// `(attempts − 1) · E_com(ℓ/rate)` — each retry puts the full eq. (5)
/// payload back on the wire at the decision's rate. Monotone
/// non-decreasing in `attempts` (pinned by `proptest_faults.rs`), and
/// exactly `0.0` at one attempt so the benign path stays bit-identical.
pub fn retry_energy(p: &SystemParams, d: &ClientDecision, attempts: u32) -> f64 {
    attempts.saturating_sub(1) as f64
        * energy::e_com(p, decision_payload_bits(p, d) / d.rate)
}

/// [`realized_latency`] under a fault draw: each retry adds one full
/// payload airtime, a straggle stretches the compute term by
/// [`STRAGGLE_FACTOR`]. The benign draw adds exactly `+0.0` twice —
/// IEEE-identity on the finite base — so a chaos-off round and an
/// all-benign chaos round realize the same bits.
pub fn fault_latency(
    p: &SystemParams,
    size: f64,
    d: &ClientDecision,
    cpu_scale: f64,
    fd: &FaultDraw,
) -> f64 {
    let straggle_extra = if fd.straggle {
        (STRAGGLE_FACTOR - 1.0) * energy::t_cmp(p, size, d.f * cpu_scale)
    } else {
        0.0
    };
    realized_latency(p, size, d, cpu_scale)
        + fd.retries() as f64 * (decision_payload_bits(p, d) / d.rate)
        + straggle_extra
}

/// [`realized_energy`] under a fault draw: the base cost plus
/// [`retry_energy`]. A straggle adds no energy (a stall burns time,
/// not joules), so the only fault-era energy term is retransmission
/// airtime — charged whether or not any attempt ultimately decoded.
pub fn fault_energy(
    p: &SystemParams,
    size: f64,
    d: &ClientDecision,
    cpu_scale: f64,
    fd: &FaultDraw,
) -> f64 {
    realized_energy(p, size, d, cpu_scale) + retry_energy(p, d, fd.attempts)
}

/// Realized bytes on the wire under a fault draw: every attempt
/// retransmits the full `ceil(eq. (5)/8)` payload, so the realized
/// byte count is `attempts ×` the single-shot payload.
pub fn fault_payload_bytes(p: &SystemParams, d: &ClientDecision, fd: &FaultDraw) -> usize {
    let single = match d.q {
        Some(q) => wire::encoded_len(p.z, q),
        None => (p.raw_payload_bits() as usize + 7) / 8,
    };
    fd.attempts as usize * single
}

/// Run one client: τ local steps through the AOT `train_step`, then
/// quantize-and-wire-encode (or a raw upload), then the wireless
/// bookkeeping. Pure in the coordinator's state — everything it needs
/// arrives in the task, everything it learns leaves in the outcome.
///
/// The quantized path packs the upload via `quant::knot_indices_into` —
/// the bit-exact Rust mirror of the Pallas kernel (agreement pinned by
/// `integration_runtime.rs::quantize_artifact_matches_rust_mirror_bitwise`)
/// — because the wire needs the knot *indices*, which the dequantizing
/// kernel artifact does not emit. The dequantized `Vec<f32>` is never
/// materialized client-side; the server's fused fold reconstructs the
/// exact same f32 values from the bitstream.
///
/// `survived` is the client's C4 verdict, computed **once** by the
/// caller (from [`survives_deadline`]∘[`realized_latency`]) — the same
/// computation that fixed the aggregation weights — so upload retention
/// and fold weights can never diverge.
pub fn run_client(
    p: &SystemParams,
    rt: &Runtime,
    theta: &[f32],
    mut task: ClientTask<'_>,
    survived: bool,
    scratch: &mut WorkerScratch,
) -> Result<ClientOutcome> {
    let info = &rt.info;
    let d = task.decision;

    // Local update (τ steps through the AOT train_step).
    let (xs, ys) = task.data.sample_batches(&mut task.rng, info.tau, info.batch, info.pix());
    let out = rt.train_step(theta, &xs, &ys, info.lr as f32)?;

    // Quantize + wire-encode (or raw upload). The noise stream draws
    // exactly Z uniforms from the client's RNG, as the kernel path did.
    let (upload, theta_max) = match d.q {
        Some(q) => {
            // The q-bit wire format cannot represent non-finite values
            // (a NaN weight would pack as knot 0 and silently decode to
            // +0.0 — where the old dequantize path propagated the NaN
            // into θ and made the divergence visible). Fail loudly
            // instead: a diverged local model is not a valid upload.
            anyhow::ensure!(
                out.theta.iter().all(|x| x.is_finite()),
                "client {}: non-finite model weights after local training — refusing to \
                 wire-encode a diverged upload",
                task.id
            );
            if scratch.noise.len() != info.z {
                scratch.noise.resize(info.z, 0.0);
            }
            task.rng.fill_uniform_f32(&mut scratch.noise);
            let tmax = quant::knot_indices_into(
                &out.theta,
                &scratch.noise,
                q,
                &mut scratch.idx,
                &mut scratch.signs,
            );
            let bytes = wire::encode(tmax, &scratch.signs, &scratch.idx, q);
            (Upload::Wire { bytes, q }, tmax as f64)
        }
        None => {
            let tmax = linf_norm(&out.theta) as f64;
            (Upload::Raw(out.theta), tmax)
        }
    };

    // eq. (5) invariant: the bytes put on the wire must be exactly the
    // ceil of the analytic bit count the latency/energy math charged —
    // the thing we meter is the thing we move. `params.z` drives the
    // analytic side, the loaded profile's Z drove the encoder, so this
    // also catches the two drifting apart.
    let payload_bytes = upload.wire_bytes();
    let analytic_bytes = match d.q {
        Some(q) => wire::encoded_len(p.z, q),
        None => (p.raw_payload_bits() as usize + 7) / 8,
    };
    anyhow::ensure!(
        payload_bytes == analytic_bytes,
        "client {}: realized payload {payload_bytes} B != analytic eq. (5) {analytic_bytes} B \
         — params.z out of sync with the loaded profile?",
        task.id
    );

    let latency = realized_latency(p, task.size, &d, task.cpu_scale);
    Ok(ClientOutcome {
        id: task.id,
        mean_loss: out.mean_loss as f64,
        gnorms: out.gnorms,
        theta_max,
        q: d.q,
        latency,
        energy: realized_energy(p, task.size, &d, task.cpu_scale),
        payload_bytes,
        upload: survived.then_some(upload),
        rng: task.rng,
    })
}

/// Order-preserving streaming weighted accumulator for eq. (2), with a
/// **fused decode-and-fold** path for wire payloads.
///
/// Workers commit slots in completion order; payloads are folded into
/// the running `Σ w·θ` strictly in ascending slot order, so the f32
/// additions happen in exactly the serial loop's order and θ^{n+1} is
/// bit-identical for any thread count. An [`Upload::Wire`] payload is
/// folded straight out of its bitstream (`quant::wire::fold_into`) —
/// the dequantized `Vec<f32>` is never materialized, so a buffered
/// quantized upload costs ~(q+1)/32 of a raw one. Out-of-order arrivals
/// wait in `pending`, and a committer running more than `max_lag` slots
/// ahead of the fold cursor blocks until the cursor catches up — so
/// live payloads are genuinely bounded by `max_lag + workers`, even
/// when one slow client stalls the cursor while the rest of the pool
/// races ahead.
pub struct StreamingAggregator {
    inner: Mutex<AggState>,
    /// Signaled whenever the fold cursor advances.
    drained: Condvar,
    /// Max slots a commit may run ahead of the cursor before blocking.
    max_lag: usize,
}

struct AggState {
    /// Running Σ w·θ over committed surviving uploads.
    acc: Vec<f32>,
    /// Next slot to fold.
    next: usize,
    /// Total slots expected.
    total: usize,
    /// Finished-but-not-yet-foldable slots (`None` = no upload).
    pending: BTreeMap<usize, Option<(f32, Upload)>>,
}

impl StreamingAggregator {
    /// `max_lag` trades buffering for stall tolerance; a single-threaded
    /// committer must use `max_lag ≥ total` if it commits out of order
    /// (nobody else can advance the cursor for it).
    pub fn new(z: usize, total: usize, max_lag: usize) -> StreamingAggregator {
        StreamingAggregator {
            inner: Mutex::new(AggState {
                acc: vec![0.0; z],
                next: 0,
                total,
                pending: BTreeMap::new(),
            }),
            drained: Condvar::new(),
            max_lag,
        }
    }

    /// Commit slot `seq` with its weighted upload (`None` when the
    /// upload missed the deadline or its client failed — the slot still
    /// advances the fold cursor). Blocks only while `seq` is more than
    /// `max_lag` slots ahead of the cursor; the cursor's own slot never
    /// blocks, so the pipeline always progresses as long as every slot
    /// is eventually committed exactly once.
    pub fn commit(&self, seq: usize, upload: Option<(f32, Upload)>) {
        let mut guard =
            self.inner.lock().expect("aggregator mutex poisoned: a worker panicked mid-commit");
        while seq > guard.next + self.max_lag {
            guard = self
                .drained
                .wait(guard)
                .expect("aggregator condvar wait failed: mutex poisoned");
        }
        let st = &mut *guard;
        debug_assert!(seq >= st.next, "slot {seq} committed twice");
        st.pending.insert(seq, upload);
        let mut advanced = false;
        while let Some(entry) = st.pending.remove(&st.next) {
            match entry {
                Some((w, Upload::Wire { bytes, q })) => {
                    // Fused decode-fold: same per-element f32 value and
                    // the same `acc += w·v` addition the materializing
                    // path performed — bit-identical, minus the Vec.
                    wire::fold_into(&mut st.acc, w, &bytes, q)
                        .expect("wire payload validated against eq. (5) at encode time");
                }
                Some((w, Upload::Raw(model))) => {
                    // Same hardening as the wire path: a mis-sized raw
                    // upload must fail loudly, not zip-truncate into a
                    // silently half-folded θ.
                    assert_eq!(model.len(), st.acc.len(), "raw upload length != Z");
                    for (a, m) in st.acc.iter_mut().zip(model.iter()) {
                        *a += w * m;
                    }
                }
                None => {}
            }
            st.next += 1;
            advanced = true;
        }
        if advanced {
            self.drained.notify_all();
        }
    }

    /// The accumulated Σ w·θ. Panics if a slot was never committed —
    /// only call after every worker returned.
    pub fn finish(self) -> Vec<f32> {
        let st =
            self.inner.into_inner().expect("aggregator mutex poisoned: a worker panicked mid-commit");
        assert_eq!(st.next, st.total, "uncommitted upload slots");
        st.acc
    }
}

/// Commits `seq` as a no-upload slot on drop unless disarmed — so a
/// *panic* inside a client worker (not just an `Err`) still advances
/// the fold cursor. Without this, peer workers past the `max_lag`
/// window would wait on the condvar forever and `thread::scope` would
/// join blocked threads instead of re-raising the panic.
struct CommitOnDrop<'a> {
    agg: &'a StreamingAggregator,
    seq: usize,
    armed: bool,
}

impl Drop for CommitOnDrop<'_> {
    fn drop(&mut self) {
        if self.armed {
            self.agg.commit(self.seq, None);
        }
    }
}

/// Churn-era execution options (`fl::avail`): mid-round departures,
/// the over-selection aggregation cap, and staleness weighting. The
/// default value is the exact legacy behavior — [`execute_round`] is a
/// thin wrapper over [`execute_round_with`] at `ExecOpts::default()`,
/// so the churn-off path shares every instruction with the old engine.
#[derive(Default)]
pub struct ExecOpts {
    /// Per-task mid-round departure flags (task order). A departed
    /// client is treated exactly like a C4 miss: it trains, its energy
    /// and airtime are spent, its state writebacks happen — but its
    /// upload never reaches the fold. `None` = nobody departs.
    pub departed: Option<Vec<bool>>,
    /// Over-selection aggregation target N
    /// ([`crate::fl::avail::aggregation_target`]): only the first N
    /// survivors in ascending task order are aggregated; later
    /// survivors are demoted to the C4-miss path. `None` = aggregate
    /// every survivor.
    pub n_target: Option<usize>,
    /// Per-task staleness multipliers (task order) scaling each
    /// client's **effective data mass** in the eq. (2) fold weights:
    /// `w_i ∝ D_i · scale_i` over survivors. `None` = all `1.0`
    /// (bit-identical to the unscaled path).
    pub stale_scale: Option<Vec<f64>>,
    /// Per-task fault draws (task order) from `fl::faults`: retries
    /// charge extra eq. (5) airtime/bytes, a straggle stretches the
    /// compute latency, an exhausted retry budget (`!decoded`) demotes
    /// the client to the departed path, and a panic draw panics the
    /// worker (the sweep layer isolates it). `None` = no chaos;
    /// `Some(all-benign)` is bit-identical to `None`.
    pub faults: Option<Vec<FaultDraw>>,
}

/// Apply the over-selection cap in place: keep the first `n_target`
/// `true` flags in ascending task order, demote every later survivor
/// to `false`. Returns the surviving count after the cap. With
/// `n_target >= count(true)` this is the identity.
pub fn apply_aggregation_cap(survive: &mut [bool], n_target: usize) -> usize {
    let mut kept = 0usize;
    for s in survive.iter_mut() {
        if *s {
            if kept < n_target {
                kept += 1;
            } else {
                *s = false;
            }
        }
    }
    kept
}

/// The executed round, reduced to what the server's later stages need.
/// Per-client detail stays in `outcomes` (ascending client id).
pub struct ExecOutput {
    /// Per-client outcomes in ascending client-id order.
    pub outcomes: Vec<ClientOutcome>,
    /// θ^{n+1} per eq. (2) over surviving uploads (`None` = keep θ^n).
    pub aggregate: Option<Vec<f32>>,
    /// Clients scheduled this round.
    pub scheduled: usize,
    /// Uploads folded into the aggregate: C4 survivors minus mid-round
    /// departures minus over-selection demotions.
    pub aggregated: usize,
    /// Scheduled clients that departed mid-round
    /// ([`ExecOpts::departed`]) — their energy/airtime is still
    /// counted, like any C4 miss.
    pub departed: usize,
    /// Σ retransmission attempts beyond the first over scheduled
    /// clients ([`ExecOpts::faults`]) — each charged full eq. (5)
    /// airtime energy and payload bytes.
    pub retries: usize,
    /// Scheduled clients whose retry budget was exhausted (no attempt
    /// decoded) — demoted to the departed path: energy spent, upload
    /// discarded.
    pub failed_decodes: usize,
    /// Final per-task survival flags (task order, after departures and
    /// the over-selection cap) — the clients whose uploads made the
    /// aggregate, for the server's staleness bookkeeping.
    pub survived: Vec<bool>,
    /// Σ realized payload bytes over scheduled clients (transmitted
    /// whether or not the upload survived C4 — airtime is spent either
    /// way). Per upload this equals `ceil(eq. (5)/8)`.
    pub wire_bytes: usize,
    /// Σ realized energy over scheduled clients (J).
    pub round_energy: f64,
    /// Max realized latency among scheduled clients (s).
    pub max_latency: f64,
    /// Σ mean training loss over scheduled clients.
    pub loss_sum: f64,
    /// Count behind [`ExecOutput::loss_sum`].
    pub loss_n: usize,
    /// Filled by the server from the execute-stage span guard
    /// (`obs::spans`) around the fan-out — side-channel wall-clock,
    /// CSV-only.
    pub compute_seconds: f64,
}

/// Renormalized eq. (2) fold weights over the surviving slots:
/// `w_i = D_i / Σ_surv D` for survivors, `0` otherwise. Returns `None`
/// when the surviving data mass is zero — an empty survivor set, or
/// survivors that all carry zero-size datasets — because the weights
/// are then `0/0` (NaN) and a fold would silently poison θ; the caller
/// must treat the round as no-aggregate and keep θ^n.
pub fn survivor_weights(sizes: &[f64], survive: &[bool]) -> Option<Vec<f32>> {
    let d_surv: f64 = sizes.iter().zip(survive).filter(|(_, s)| **s).map(|(d, _)| *d).sum();
    if !d_surv.is_finite() || d_surv <= 0.0 {
        return None;
    }
    Some(
        sizes
            .iter()
            .zip(survive)
            .map(|(d, s)| if *s { (d / d_surv) as f32 } else { 0.0 })
            .collect(),
    )
}

/// Fan the scheduled clients out over `threads` workers (1 = the legacy
/// serial path through the same code). Tasks must arrive in ascending
/// client id — that order defines the aggregation fold. `scratch` is
/// the caller-owned per-worker buffer pool (grown to the worker count
/// on demand; the server keeps it alive across rounds).
pub fn execute_round(
    p: &SystemParams,
    rt: &Runtime,
    theta: &[f32],
    tasks: Vec<ClientTask<'_>>,
    threads: usize,
    scratch: &mut Vec<WorkerScratch>,
) -> Result<ExecOutput> {
    execute_round_with(p, rt, theta, tasks, threads, scratch, &ExecOpts::default())
}

/// [`execute_round`] with churn-era options: departures, the
/// over-selection cap, and staleness-scaled fold weights. Survival —
/// and with it every fold weight — is still a pure function of the
/// decisions and the options, computed **before** any training runs,
/// so the streaming-aggregation determinism contract is unchanged.
pub fn execute_round_with(
    p: &SystemParams,
    rt: &Runtime,
    theta: &[f32],
    tasks: Vec<ClientTask<'_>>,
    threads: usize,
    scratch: &mut Vec<WorkerScratch>,
    opts: &ExecOpts,
) -> Result<ExecOutput> {
    let scheduled = tasks.len();
    if let Some(d) = &opts.departed {
        anyhow::ensure!(d.len() == scheduled, "departed flags != task count");
    }
    if let Some(s) = &opts.stale_scale {
        anyhow::ensure!(s.len() == scheduled, "stale_scale != task count");
    }
    if let Some(f) = &opts.faults {
        anyhow::ensure!(f.len() == scheduled, "fault draws != task count");
    }

    // C4 survival — and with it the renormalized aggregation weights —
    // is decided by (f, q, rate) alone, so compute both up front and
    // let uploads stream straight into the accumulator. A zero
    // surviving data mass (all survivors empty) yields no weights at
    // all: the fold runs with w = 0 and the aggregate is discarded
    // below, instead of dividing by zero into NaN weights. A mid-round
    // departure or an over-selection demotion rides the same flag, so
    // the all-departed round reuses the same no-aggregate guard.
    let mut survive: Vec<bool> = tasks
        .iter()
        .enumerate()
        .map(|(seq, t)| {
            let gone = opts.departed.as_ref().is_some_and(|d| d[seq]);
            match &opts.faults {
                // Fault-era survival: an exhausted retry budget drops
                // the upload outright, and the deadline is checked
                // against the fault-stretched latency (retransmission
                // airtime + straggle) — the benign draw reproduces the
                // plain verdict bit for bit.
                Some(fs) => {
                    !gone
                        && fs[seq].decoded
                        && survives_deadline(
                            p,
                            fault_latency(p, t.size, &t.decision, t.cpu_scale, &fs[seq]),
                            t.deadline_exempt,
                        )
                }
                None => {
                    !gone
                        && survives_deadline(
                            p,
                            realized_latency(p, t.size, &t.decision, t.cpu_scale),
                            t.deadline_exempt,
                        )
                }
            }
        })
        .collect();
    if let Some(n) = opts.n_target {
        apply_aggregation_cap(&mut survive, n);
    }
    let departed =
        opts.departed.as_ref().map_or(0, |d| d.iter().filter(|&&g| g).count());
    // Fault accounting, decided pre-fan-out like survival: the realized
    // (latency, energy, payload bytes) per task under its draw. For a
    // benign draw all three equal the plain realized values bit for
    // bit, so overwriting the outcome below is an exact no-op; chaos
    // off (`None`) skips the writeback entirely and the legacy path
    // stays instruction-identical.
    let fault_totals: Option<Vec<(f64, f64, usize)>> = opts.faults.as_ref().map(|fs| {
        tasks
            .iter()
            .zip(fs)
            .map(|(t, fd)| {
                (
                    fault_latency(p, t.size, &t.decision, t.cpu_scale, fd),
                    fault_energy(p, t.size, &t.decision, t.cpu_scale, fd),
                    fault_payload_bytes(p, &t.decision, fd),
                )
            })
            .collect()
    });
    let (retries, failed_decodes) = opts.faults.as_ref().map_or((0, 0), |fs| {
        fs.iter().fold((0usize, 0usize), |(r, n), d| {
            (r + d.retries() as usize, n + usize::from(!d.decoded))
        })
    });
    let sizes: Vec<f64> = match &opts.stale_scale {
        // Effective data mass under staleness weighting; `scale = 1`
        // multiplies exactly (IEEE), keeping fresh clients bit-equal.
        Some(scale) => {
            tasks.iter().zip(scale).map(|(t, s)| t.size * s).collect()
        }
        None => tasks.iter().map(|t| t.size).collect(),
    };
    let weights = survivor_weights(&sizes, &survive);
    let has_data_mass = weights.is_some();
    let weights: Vec<f32> = weights.unwrap_or_else(|| vec![0.0; scheduled]);

    let workers = threads.max(1);
    if scratch.len() < workers {
        scratch.resize_with(workers, WorkerScratch::default);
    }
    // `max_lag` of ~2× the pool keeps every worker busy without letting
    // a straggling fold cursor pile up payloads (the O(threads × Z·
    // (q+1)/32) peak-memory bound; serial path = O(Z)).
    let agg = StreamingAggregator::new(theta.len(), scheduled, workers * 2);
    let results = threadpool::parallel_map_owned_with(
        tasks,
        &mut scratch[..workers],
        |seq, task, ws| -> Result<ClientOutcome> {
            // Hand the payload to the fold the moment it exists, and
            // commit the slot even on failure or panic — an uncommitted
            // slot would stall the cursor and block the rest of the
            // pool in `commit`. On `Err` we bail below before touching
            // the (then meaningless) aggregate.
            let mut fallback = CommitOnDrop { agg: &agg, seq, armed: true };
            // Injected client panic (`fl::faults`): raised only after
            // the fallback is armed, so the fold cursor still advances
            // and the panic propagates cleanly out of the pool for the
            // sweep layer to isolate.
            if opts.faults.as_ref().is_some_and(|fs| fs[seq].panic) {
                panic!("chaos: injected client panic (client {}, slot {seq})", task.id);
            }
            let mut oc = run_client(p, rt, theta, task, survive[seq], ws)?;
            if let Some(totals) = &fault_totals {
                // Retransmission + straggle accounting: airtime energy
                // and payload bytes for every attempt, stretched
                // compute latency — bit-identical under a benign draw.
                let (latency, energy, payload_bytes) = totals[seq];
                oc.latency = latency;
                oc.energy = energy;
                oc.payload_bytes = payload_bytes;
            }
            fallback.armed = false;
            agg.commit(seq, oc.upload.take().map(|u| (weights[seq], u)));
            Ok(oc)
        },
    );
    let outcomes: Vec<ClientOutcome> = results.into_iter().collect::<Result<_>>()?;

    let aggregated = survive.iter().filter(|&&s| s).count();
    let aggregate =
        if aggregated > 0 && has_data_mass { Some(agg.finish()) } else { None };

    let mut out = ExecOutput {
        outcomes,
        aggregate,
        scheduled,
        aggregated,
        departed,
        retries,
        failed_decodes,
        survived: survive,
        wire_bytes: 0,
        round_energy: 0.0,
        max_latency: 0.0,
        loss_sum: 0.0,
        loss_n: 0,
        compute_seconds: 0.0,
    };
    // Scalar reductions in client-id order (same arithmetic as serial).
    for oc in &out.outcomes {
        out.wire_bytes += oc.payload_bytes;
        out.round_energy += oc.energy;
        out.max_latency = out.max_latency.max(oc.latency);
        out.loss_sum += oc.mean_loss;
        out.loss_n += 1;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fold_serial(uploads: &[Option<(f32, Upload)>], z: usize) -> Vec<f32> {
        let mut acc = vec![0.0f32; z];
        for (w, u) in uploads.iter().flatten() {
            match u {
                Upload::Raw(m) => {
                    for (a, m) in acc.iter_mut().zip(m) {
                        *a += w * m;
                    }
                }
                Upload::Wire { bytes, q } => {
                    wire::fold_into(&mut acc, *w, bytes, *q).unwrap();
                }
            }
        }
        acc
    }

    fn toy_uploads(n: usize, z: usize) -> Vec<Option<(f32, Upload)>> {
        let mut rng = Rng::seed_from(99);
        (0..n)
            .map(|i| {
                if i % 4 == 3 {
                    None // dropped upload
                } else {
                    let w = 1.0 / (i + 1) as f32;
                    let m: Vec<f32> = (0..z).map(|_| rng.gaussian(0.0, 1.0) as f32).collect();
                    if i % 3 == 1 {
                        // Wire-encode every third upload so the fused
                        // decode-fold runs in the ordering tests too.
                        let mut noise = vec![0.0f32; z];
                        rng.fill_uniform_f32(&mut noise);
                        let (idx, signs, tmax) = quant::knot_indices(&m, &noise, 6);
                        let bytes = wire::encode(tmax, &signs, &idx, 6);
                        Some((w, Upload::Wire { bytes, q: 6 }))
                    } else {
                        Some((w, Upload::Raw(m)))
                    }
                }
            })
            .collect()
    }

    #[test]
    fn aggregator_in_order_matches_serial() {
        let (n, z) = (9, 37);
        let uploads = toy_uploads(n, z);
        let want = fold_serial(&uploads, z);
        let agg = StreamingAggregator::new(z, n, n);
        for (i, u) in uploads.into_iter().enumerate() {
            agg.commit(i, u);
        }
        let got = agg.finish();
        assert_eq!(
            got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn aggregator_out_of_order_is_bit_identical() {
        let (n, z) = (8, 21);
        let uploads = toy_uploads(n, z);
        let want = fold_serial(&uploads, z);
        // Adversarial arrival order: reverse, then interleaved. A lone
        // committer needs max_lag ≥ n (nobody else advances the cursor).
        for order in [vec![7, 6, 5, 4, 3, 2, 1, 0], vec![1, 0, 3, 2, 5, 4, 7, 6]] {
            let agg = StreamingAggregator::new(z, n, n);
            for &i in &order {
                agg.commit(i, uploads[i].clone());
            }
            let got = agg.finish();
            assert_eq!(
                got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn aggregator_concurrent_commits_match_serial() {
        // Tight max_lag (2) forces the backpressure path under real
        // thread contention; the fold must still be bit-exact.
        let (n, z) = (64, 130);
        let uploads = toy_uploads(n, z);
        let want = fold_serial(&uploads, z);
        let agg = StreamingAggregator::new(z, n, 2);
        let slots: Vec<Option<(f32, Upload)>> = uploads;
        threadpool::parallel_map_owned(
            slots.into_iter().enumerate().collect::<Vec<_>>(),
            8,
            |_, (i, u)| agg.commit(i, u),
        );
        let got = agg.finish();
        assert_eq!(
            got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn wire_commit_matches_raw_commit_bitwise() {
        // Committing the eq. (5) bytes must fold to exactly the same
        // bits as committing the materialized dequantized model — the
        // transport changes the representation, not the arithmetic.
        let z = 333;
        let mut rng = Rng::seed_from(5);
        let theta: Vec<f32> = (0..z).map(|_| rng.gaussian(0.0, 1.3) as f32).collect();
        let mut noise = vec![0.0f32; z];
        rng.fill_uniform_f32(&mut noise);
        for q in [1u32, 4, 9] {
            let (deq, tmax) = quant::stochastic_quantize(&theta, &noise, q as f32);
            let (idx, signs, tmax2) = quant::knot_indices(&theta, &noise, q);
            assert_eq!(tmax.to_bits(), tmax2.to_bits());
            let bytes = wire::encode(tmax, &signs, &idx, q);
            let w = 0.31f32;
            let a_wire = StreamingAggregator::new(z, 1, 1);
            a_wire.commit(0, Some((w, Upload::Wire { bytes, q })));
            let a_raw = StreamingAggregator::new(z, 1, 1);
            a_raw.commit(0, Some((w, Upload::Raw(deq))));
            assert_eq!(
                a_wire.finish().iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                a_raw.finish().iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "q={q}"
            );
        }
    }

    #[test]
    fn upload_wire_bytes_match_eq5() {
        let z = 1242usize;
        let raw = Upload::Raw(vec![0.0f32; z]);
        assert_eq!(raw.wire_bytes(), 4 * z);
        for q in [1u32, 4, 8, 32] {
            let up = Upload::Wire { bytes: vec![0u8; quant::encoded_len(z, q)], q };
            assert_eq!(up.wire_bytes(), (z * q as usize + z + 32 + 7) / 8);
        }
    }

    #[test]
    fn survivor_weights_guard_zero_mass() {
        // All-zero surviving data mass (or no survivors at all) must
        // yield no weights — the 0/0 NaN from the unguarded division
        // used to poison θ through the fold.
        assert!(survivor_weights(&[0.0, 0.0], &[true, true]).is_none());
        assert!(survivor_weights(&[5.0, 3.0], &[false, false]).is_none());
        assert!(survivor_weights(&[], &[]).is_none());
        assert!(survivor_weights(&[0.0, 7.0], &[true, false]).is_none());
        let w = survivor_weights(&[6.0, 5.0, 2.0], &[true, false, true]).unwrap();
        assert_eq!(w[0], 0.75);
        assert_eq!(w[1], 0.0);
        assert_eq!(w[2], 0.25);
        assert!(w.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn aggregation_cap_keeps_first_n_survivors() {
        let mut s = vec![true, false, true, true, false, true];
        assert_eq!(apply_aggregation_cap(&mut s, 2), 2);
        assert_eq!(s, vec![true, false, true, false, false, false]);
        // n_target >= survivor count is the identity.
        let mut s = vec![true, false, true];
        assert_eq!(apply_aggregation_cap(&mut s, 5), 2);
        assert_eq!(s, vec![true, false, true]);
        // n_target = 0 demotes everyone (the no-aggregate guard path).
        let mut s = vec![true, true];
        assert_eq!(apply_aggregation_cap(&mut s, 0), 0);
        assert_eq!(s, vec![false, false]);
    }

    #[test]
    fn survival_is_decision_pure() {
        let p = SystemParams::femnist_small();
        let fast = ClientDecision { channel: 0, q: Some(4), f: p.f_max, rate: 25e6 };
        let slow = ClientDecision { channel: 1, q: Some(4), f: p.f_max, rate: 1.0 };
        let lat_fast = realized_latency(&p, 1200.0, &fast, 1.0);
        let lat_slow = realized_latency(&p, 1200.0, &slow, 1.0);
        assert!(survives_deadline(&p, lat_fast, false), "lat={lat_fast}");
        assert!(!survives_deadline(&p, lat_slow, false), "lat={lat_slow}");
        // Exemption overrides C4 (No-Quantization baseline).
        assert!(survives_deadline(&p, lat_slow, true));
        // Energy is spent either way and scales with the airtime.
        assert!(realized_energy(&p, 1200.0, &slow, 1.0) > realized_energy(&p, 1200.0, &fast, 1.0));
    }

    #[test]
    fn cpu_throttle_stretches_latency_and_saves_compute_energy() {
        let p = SystemParams::femnist_small();
        let d = ClientDecision { channel: 0, q: Some(4), f: p.f_max, rate: 25e6 };
        let full = realized_latency(&p, 1200.0, &d, 1.0);
        let half = realized_latency(&p, 1200.0, &d, 0.5);
        // Compute latency doubles at half the frequency; airtime fixed.
        let t_cmp_full = crate::energy::t_cmp(&p, 1200.0, d.f);
        assert!((half - full - t_cmp_full).abs() < 1e-12, "full={full} half={half}");
        // f² energy scaling: throttled compute costs a quarter.
        let e_full = realized_energy(&p, 1200.0, &d, 1.0);
        let e_half = realized_energy(&p, 1200.0, &d, 0.5);
        assert!(e_half < e_full);
        // A throttle can flip the C4 verdict the scheduler planned on.
        let tight = ClientDecision {
            channel: 0,
            q: Some(4),
            f: crate::energy::s_of_q(&p, 1200.0, 4, 25e6).unwrap(),
            rate: 25e6,
        };
        assert!(survives_deadline(&p, realized_latency(&p, 1200.0, &tight, 1.0), false));
        assert!(!survives_deadline(&p, realized_latency(&p, 1200.0, &tight, 0.4), false));
    }

    #[test]
    fn benign_fault_accounting_is_bit_identical() {
        let p = SystemParams::femnist_small();
        let benign = FaultDraw::benign();
        for q in [Some(1u32), Some(4), Some(9), None] {
            let d = ClientDecision { channel: 0, q, f: p.f_max, rate: 25e6 };
            for cpu_scale in [1.0, 0.5] {
                let lat = fault_latency(&p, 1200.0, &d, cpu_scale, &benign);
                let en = fault_energy(&p, 1200.0, &d, cpu_scale, &benign);
                assert_eq!(
                    lat.to_bits(),
                    realized_latency(&p, 1200.0, &d, cpu_scale).to_bits(),
                    "q={q:?}"
                );
                assert_eq!(
                    en.to_bits(),
                    realized_energy(&p, 1200.0, &d, cpu_scale).to_bits(),
                    "q={q:?}"
                );
            }
            assert_eq!(retry_energy(&p, &d, 1), 0.0);
            let single = fault_payload_bytes(&p, &d, &benign);
            match q {
                Some(q) => assert_eq!(single, wire::encoded_len(p.z, q)),
                None => assert_eq!(single, (p.raw_payload_bits() as usize + 7) / 8),
            }
        }
    }

    #[test]
    fn retry_accounting_scales_with_attempts() {
        let p = SystemParams::femnist_small();
        let d = ClientDecision { channel: 0, q: Some(4), f: p.f_max, rate: 25e6 };
        // Energy monotone in attempts, linear in the retry count.
        let mut prev = -1.0;
        for attempts in 1..=6u32 {
            let e = retry_energy(&p, &d, attempts);
            assert!(e >= prev, "attempts={attempts}");
            prev = e;
        }
        assert_eq!(retry_energy(&p, &d, 3), 2.0 * retry_energy(&p, &d, 2));
        // Bytes: every attempt retransmits the full eq. (5) payload.
        let fd = FaultDraw { attempts: 3, ..FaultDraw::benign() };
        assert_eq!(fault_payload_bytes(&p, &d, &fd), 3 * wire::encoded_len(p.z, 4));
        // Latency: retries add airtime, a straggle stretches compute.
        let base = realized_latency(&p, 1200.0, &d, 1.0);
        assert!(fault_latency(&p, 1200.0, &d, 1.0, &fd) > base);
        let st = FaultDraw { straggle: true, ..FaultDraw::benign() };
        let want = base + (STRAGGLE_FACTOR - 1.0) * crate::energy::t_cmp(&p, 1200.0, d.f);
        assert!((fault_latency(&p, 1200.0, &d, 1.0, &st) - want).abs() < 1e-12);
        // A straggle costs no extra energy.
        assert_eq!(
            fault_energy(&p, 1200.0, &d, 1.0, &st).to_bits(),
            realized_energy(&p, 1200.0, &d, 1.0).to_bits()
        );
    }
}
