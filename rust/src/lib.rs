//! # qccf — Energy-Efficient Wireless Federated Learning via Doubly
//! # Adaptive Quantization
//!
//! Production-shaped reproduction of Han et al. (2024): a three-layer
//! Rust + JAX + Pallas stack where
//!
//! * **Layer 1** (build-time Python) — Pallas kernels for stochastic
//!   quantization (paper eq. 4), fused SGD updates, and the dense-head
//!   matmul (`python/compile/kernels/`);
//! * **Layer 2** (build-time Python) — the paper's CNNs with a flat
//!   parameter interface, AOT-lowered to HLO text (`python/compile/`);
//! * **Layer 3** (this crate) — the paper's actual contribution, run
//!   through a staged **round-execution engine**: per round, a
//!   *decision* stage (Lyapunov virtual queues → genetic channel
//!   allocation → closed-form KKT quantization/frequency control →
//!   Theorem-3 integer rounding, with GA fitness fanned out over a
//!   worker pool and served by a bit-identical caching layer —
//!   per-round `sched::EvalCtx`, exact-key solve memo, per-worker
//!   scratch, GA fitness cache), a *parallel execution* stage (`fl::exec`: every
//!   scheduled client trains, quantizes, **wire-encodes its upload
//!   into the eq. (5) bit-packed payload**, and accounts
//!   latency/energy independently on its private RNG stream), a
//!   streaming *aggregation* stage (eq. (2) folded in client order
//!   straight out of the upload bitstreams — buffered quantized
//!   uploads cost ~(q+1) bits/dim, `O(Z)` fold memory serial), and the
//!   *queue-update* stage. The engine's
//!   determinism contract: any `--threads` value — including the
//!   `1`-thread legacy path — produces bit-identical models and
//!   traces. Around it sit the wireless/energy models, the four
//!   baselines, and the experiment harness that regenerates every
//!   figure in §VI.
//!
//! Python never runs on the round loop: `make artifacts` lowers once and
//! the `qccf` binary executes the HLO through the PJRT CPU client.
//!
//! Workloads are **declarative**: a [`scenario::Scenario`] (built-in,
//! file-loaded, or a fig-harness preset) describes topology,
//! heterogeneity, algorithms and hyperparameters, and the `sweep`
//! runner ([`experiments::sweep`]) fans scenario × seed × algorithm
//! grids out over the worker pool with per-run determinism.
//!
//! Runs and sweeps are **preemption-safe**: the [`ckpt`] subsystem
//! snapshots complete run state (round, θ, Lyapunov queues, per-client
//! anchors and RNG streams) into a versioned CRC-sealed binary format
//! with atomic writes, and a checkpointed-then-resumed run is
//! bit-identical to the uninterrupted one (`docs/CHECKPOINTS.md`).
//!
//! The bit-identity contract is also **machine-checked**: `verify.sh`
//! gates on `detlint` (`rust/xtask`), a static-analysis pass that flags
//! the source patterns that break it — hash-order iteration, ambient
//! wall-clock or entropy, `partial_cmp` float sorts, non-atomic file
//! writes, uncommented `unsafe`, observability wall-clock leaking into
//! deterministic outputs — per the R1–R7 catalog and escape policy in
//! `docs/DETERMINISM.md`. The [`obs`] layer (stage-span profiler,
//! deterministic quantile sketches, run ledger + `report` aggregator)
//! is the one sanctioned home for wall-clock telemetry
//! (`docs/OBSERVABILITY.md`).
//!
//! Start with [`config::SystemParams`] (paper Table I), then
//! [`fl::Server`] for the training loop, or the `examples/`. The full
//! layer-by-layer tour — AOT pipeline, artifacts, PJRT runtime,
//! decision pipeline, round engine — lives in `docs/ARCHITECTURE.md`;
//! the scenario-file reference is `docs/SCENARIOS.md`.
#![warn(missing_docs)]

pub mod bench;
pub mod util;

pub mod baselines;
pub mod ckpt;
pub mod config;
pub mod convergence;
pub mod data;
pub mod energy;
pub mod experiments;
pub mod fl;
pub mod ga;
pub mod lyapunov;
pub mod metrics;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod scenario;
pub mod sched;
pub mod solver;
pub mod wireless;
