//! Convergence machinery (paper §III): the A1/A2 constants, the two
//! bound components that become the long-term constraints C6/C7, and the
//! per-client G_i / σ_i estimators the coordinator maintains from
//! `train_step`'s reported gradient norms.

use crate::config::SystemParams;

/// A1 = 2η²L²(2τ³ − 3τ² + τ) / (3 − 6η²L²τ²)   (below eq. (21)).
pub fn a1(p: &SystemParams) -> f64 {
    let (eta, l, tau) = (p.eta, p.lips, p.tau as f64);
    let num = 2.0 * eta * eta * l * l * (2.0 * tau.powi(3) - 3.0 * tau * tau + tau);
    let den = 3.0 - 6.0 * eta * eta * l * l * tau * tau;
    num / den
}

/// A2 = ηLτ + η²L²(τ² − τ) / (1 − 2η²L²τ²)   (below eq. (21)).
pub fn a2(p: &SystemParams) -> f64 {
    let (eta, l, tau) = (p.eta, p.lips, p.tau as f64);
    eta * l * tau + eta * eta * l * l * (tau * tau - tau) / (1.0 - 2.0 * eta * eta * l * l * tau * tau)
}

/// Per-round **data-property** term — the C6 summand (eq. (20)):
/// `Σ_i [ 4τ(1 − a_i w_i) G_i² + A1 w_i^n G_i² + A2 w_i^n σ_i² ]`.
///
/// * `w_full[i]` — w_i = D_i / ΣD (all clients);
/// * `w_round[i]` — w_i^n (participants only, zero otherwise);
/// * `participating[i]` — a_i^n.
pub fn data_term(
    p: &SystemParams,
    participating: &[bool],
    w_full: &[f64],
    w_round: &[f64],
    g2: &[f64],
    sigma2: &[f64],
) -> f64 {
    let tau = p.tau as f64;
    let (a1v, a2v) = (a1(p), a2(p));
    let mut sum = 0.0;
    for i in 0..participating.len() {
        let a = if participating[i] { 1.0 } else { 0.0 };
        sum += 4.0 * tau * (1.0 - a * w_full[i]) * g2[i];
        sum += a1v * w_round[i] * g2[i] + a2v * w_round[i] * sigma2[i];
    }
    sum
}

/// Per-round **quantization-error** term — the C7 summand (eq. (21)):
/// `Σ_i w_i^n · Z L (θ_i^max)² / (8 (2^{q_i} − 1)²)`.
pub fn quant_term(
    p: &SystemParams,
    w_round: &[f64],
    theta_max: &[f64],
    q: &[Option<u32>],
) -> f64 {
    let mut sum = 0.0;
    for i in 0..w_round.len() {
        if let Some(qi) = q[i] {
            sum += quant_term_client(p, w_round[i], theta_max[i], qi);
        }
    }
    sum
}

/// One client's C7 summand.
pub fn quant_term_client(p: &SystemParams, w_round: f64, theta_max: f64, q: u32) -> f64 {
    let l = (2f64).powi(q as i32) - 1.0;
    w_round * (p.z as f64) * p.lips * theta_max * theta_max / (8.0 * l * l)
}

/// Online estimator of a client's gradient statistics (Assumptions 1 & 3):
/// G_i from the max per-step gradient norm, σ_i from the spread of the
/// per-step norms within a round. EMA-smoothed across the client's
/// participations; priors cover rounds before first participation.
#[derive(Clone, Debug)]
pub struct GradStats {
    /// Estimated G_i (gradient-norm bound).
    pub g: f64,
    /// Estimated σ_i (mini-batch gradient std).
    pub sigma: f64,
    /// EMA factor for updates.
    pub ema: f64,
    /// Whether any observation has arrived.
    pub observed: bool,
}

impl GradStats {
    /// Priors: the coordinator has to decide round 1 before any client
    /// ever trained, so it assumes a unit-scale gradient landscape.
    pub fn prior() -> GradStats {
        GradStats { g: 1.0, sigma: 0.5, ema: 0.5, observed: false }
    }

    /// Fold in one round's per-step gradient norms (from `train_step`).
    pub fn update(&mut self, gnorms: &[f32]) {
        if gnorms.is_empty() {
            return;
        }
        let max = gnorms.iter().fold(0.0f64, |m, &x| m.max(x as f64));
        let mean = gnorms.iter().map(|&x| x as f64).sum::<f64>() / gnorms.len() as f64;
        let var = gnorms
            .iter()
            .map(|&x| (x as f64 - mean).powi(2))
            .sum::<f64>()
            / gnorms.len() as f64;
        let sigma = var.sqrt().max(0.05 * mean);
        if self.observed {
            self.g = (1.0 - self.ema) * self.g + self.ema * max;
            self.sigma = (1.0 - self.ema) * self.sigma + self.ema * sigma;
        } else {
            self.g = max;
            self.sigma = sigma;
            self.observed = true;
        }
    }

    /// Current Ĝ² estimate.
    pub fn g2(&self) -> f64 {
        self.g * self.g
    }

    /// Current σ̂² estimate.
    pub fn sigma2(&self) -> f64 {
        self.sigma * self.sigma
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> SystemParams {
        SystemParams::femnist_small()
    }

    #[test]
    fn constants_positive_under_prereqs() {
        let params = p();
        assert!(a1(&params) > 0.0);
        assert!(a2(&params) > 0.0);
        // Exact spot-check: η=0.05, L=1, τ=6.
        let eta: f64 = 0.05;
        let tau: f64 = 6.0;
        let a1_want =
            2.0 * eta * eta * (2.0 * tau.powi(3) - 3.0 * tau * tau + tau) / (3.0 - 6.0 * eta * eta * tau * tau);
        assert!((a1(&params) - a1_want).abs() < 1e-12);
        let a2_want = eta * tau + eta * eta * (tau * tau - tau) / (1.0 - 2.0 * eta * eta * tau * tau);
        assert!((a2(&params) - a2_want).abs() < 1e-12);
    }

    #[test]
    fn data_term_full_participation_drops_exclusion_penalty() {
        let params = p();
        let n = 4;
        let w_full = vec![0.25; n];
        let w_round = vec![0.25; n];
        let g2 = vec![4.0; n];
        let s2 = vec![1.0; n];
        let all = data_term(&params, &[true; 4], &w_full, &w_round, &g2, &s2);
        let none = data_term(&params, &[false; 4], &w_full, &vec![0.0; n], &g2, &s2);
        // No participants: pure exclusion penalty 4τ Σ G² = 4·6·16.
        assert!((none - 4.0 * 6.0 * 16.0).abs() < 1e-9);
        assert!(all < none);
    }

    #[test]
    fn data_term_monotone_in_participation() {
        let params = p();
        let w_full = vec![0.4, 0.3, 0.2, 0.1];
        let g2 = vec![1.0, 2.0, 3.0, 4.0];
        let s2 = vec![0.5; 4];
        // Adding one participant lowers the exclusion penalty more than the
        // A1/A2 terms add (with these scales).
        let t1 = data_term(&params, &[true, false, false, false], &w_full, &[1.0, 0.0, 0.0, 0.0], &g2, &s2);
        let t2 = data_term(
            &params,
            &[true, true, false, false],
            &w_full,
            &[0.571, 0.429, 0.0, 0.0],
            &g2,
            &s2,
        );
        assert!(t2 < t1, "t1={t1} t2={t2}");
    }

    #[test]
    fn quant_term_decreases_in_q() {
        let params = p();
        let t1 = quant_term_client(&params, 0.3, 0.8, 1);
        let t4 = quant_term_client(&params, 0.3, 0.8, 4);
        let t8 = quant_term_client(&params, 0.3, 0.8, 8);
        assert!(t1 > t4 && t4 > t8);
        // Exact: w Z L θ² / (8(2^q−1)²).
        let want = 0.3 * 20_522.0 * 0.8 * 0.8 / (8.0 * 15.0 * 15.0);
        assert!((t4 - want).abs() < 1e-9);
    }

    #[test]
    fn grad_stats_updates() {
        let mut gs = GradStats::prior();
        assert!(!gs.observed);
        gs.update(&[1.0, 2.0, 3.0]);
        assert!(gs.observed);
        assert!((gs.g - 3.0).abs() < 1e-9);
        let g_before = gs.g;
        gs.update(&[10.0, 10.0, 10.0]);
        assert!(gs.g > g_before && gs.g < 10.0); // EMA smoothing
        assert!(gs.sigma > 0.0);
    }

    #[test]
    fn grad_stats_empty_noop() {
        let mut gs = GradStats::prior();
        gs.update(&[]);
        assert!(!gs.observed);
    }
}
