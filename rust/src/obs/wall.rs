//! Monotonic wall-clock helpers for side-channel telemetry (progress
//! heartbeats, ledger durations). Lives inside `obs` so the rest of the
//! crate never touches `std::time` directly — detlint R2 keeps
//! wall-clock confined here, and R7 keeps these types out of `metrics`
//! and `ckpt`, so no reading can ever reach a deterministic output.

use std::time::Instant;

/// A started monotonic stopwatch.
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Stopwatch {
        Stopwatch { start: Instant::now() }
    }

    /// Seconds elapsed since [`Stopwatch::start`]. Side-channel only:
    /// log lines, ledger `wall_secs`, ETA estimates — never a decision
    /// input or a deterministic-output field.
    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elapsed_is_monotonic_nonnegative() {
        let sw = Stopwatch::start();
        let a = sw.elapsed_secs();
        let b = sw.elapsed_secs();
        assert!(a >= 0.0);
        assert!(b >= a);
    }
}
