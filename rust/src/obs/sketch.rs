//! Deterministic streaming quantile sketches: a fixed-bin log-domain
//! histogram over f64 magnitudes.
//!
//! Design constraints (docs/OBSERVABILITY.md):
//!
//! * **Deterministic by construction** — no sampling, no randomized
//!   compaction, no wall-clock: a sketch is a pure function of the
//!   multiset of pushed values, so sketches over simulated quantities
//!   (energy, latency, q, wire bytes) may land in deterministic
//!   outputs without touching the bit-identity contract.
//! * **Exactly associative merge** — bins are plain `u64` counts and
//!   min/max are exact selections, so `(a ⊕ b) ⊕ c == a ⊕ (b ⊕ c)`
//!   bit-for-bit and sweep shards fold in any grouping (property-tested
//!   below).
//! * **Bounded error** — each binade (power-of-two octave) is split
//!   into 4 linear sub-bins keyed off the top two mantissa bits, so a
//!   quantile estimate is the selected sub-bin's upper edge: at most
//!   25% above the true nearest-rank value, never below it.
//!
//! Layout: 121 octaves (binary exponents −60..=60, covering ~8.7e−19
//! to ~2.3e18 — far beyond any simulated joule/second/byte value) × 4
//! sub-bins = 484 counters, with out-of-range magnitudes clamped into
//! the edge bins and zeros / negatives / non-finites tracked in
//! dedicated counters.

use std::path::Path;

use crate::metrics::Trace;
use crate::util::json::{self, Json};

/// Linear sub-bins per octave (top two mantissa bits).
const SUBS: usize = 4;
/// Lowest binned biased exponent (2^−60).
const EXP_LO: i64 = 963;
/// Highest binned biased exponent (2^60).
const EXP_HI: i64 = 1083;
/// Number of octaves covered without clamping.
const OCTAVES: usize = (EXP_HI - EXP_LO + 1) as usize;
/// Total positive-magnitude bins.
pub const BINS: usize = OCTAVES * SUBS;

/// Schema version stamped into serialized sketches.
pub const SKETCH_SCHEMA: u32 = 1;

fn bin_index(x: f64) -> usize {
    let bits = x.to_bits();
    let e = ((bits >> 52) & 0x7ff) as i64;
    if e < EXP_LO {
        return 0;
    }
    if e > EXP_HI {
        return BINS - 1;
    }
    let s = ((bits >> 50) & 0x3) as usize;
    ((e - EXP_LO) as usize) * SUBS + s
}

/// Upper edge of bin `b`: `2^E · (5 + s)/4` for octave `E`, sub-bin
/// `s` — built by bit manipulation so it is exact on every platform.
fn bin_upper(b: usize) -> f64 {
    let exp = EXP_LO + (b / SUBS) as i64;
    let s = (b % SUBS) as i64;
    let pow = f64::from_bits((exp as u64) << 52);
    pow * ((5 + s) as f64 / 4.0)
}

/// A streaming log-histogram over f64 observations.
#[derive(Clone, Debug, PartialEq)]
pub struct Sketch {
    bins: Vec<u64>,
    negatives: u64,
    zeros: u64,
    non_finite: u64,
    min: f64,
    max: f64,
}

impl Default for Sketch {
    fn default() -> Sketch {
        Sketch::new()
    }
}

impl Sketch {
    /// An empty sketch.
    pub fn new() -> Sketch {
        Sketch {
            bins: vec![0; BINS],
            negatives: 0,
            zeros: 0,
            non_finite: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold one observation in. Non-finite values are counted but
    /// excluded from quantiles and min/max.
    pub fn push(&mut self, x: f64) {
        if !x.is_finite() {
            self.non_finite += 1;
            return;
        }
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
        if x == 0.0 {
            self.zeros += 1;
        } else if x < 0.0 {
            self.negatives += 1;
        } else {
            self.bins[bin_index(x)] += 1;
        }
    }

    /// Number of finite observations.
    pub fn count(&self) -> u64 {
        self.negatives + self.zeros + self.bins.iter().sum::<u64>()
    }

    /// Number of non-finite observations pushed.
    pub fn non_finite(&self) -> u64 {
        self.non_finite
    }

    /// Smallest finite observation (`+inf` when empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest finite observation (`−inf` when empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Fold `other` in. Counts add and min/max select, so the merge is
    /// exactly associative and commutative — shard grouping can never
    /// change a merged sketch by a bit.
    pub fn merge(&mut self, other: &Sketch) {
        for (a, b) in self.bins.iter_mut().zip(&other.bins) {
            *a += b;
        }
        self.negatives += other.negatives;
        self.zeros += other.zeros;
        self.non_finite += other.non_finite;
        if other.min < self.min {
            self.min = other.min;
        }
        if other.max > self.max {
            self.max = other.max;
        }
    }

    /// Nearest-rank quantile estimate for `p ∈ [0, 1]`: the upper edge
    /// of the sub-bin holding the rank-⌈p·n⌉ observation, clamped to
    /// the observed maximum — so the estimate is **never below** the
    /// true quantile and at most 25% above it (property-tested below).
    /// Returns NaN when empty. Negatives (tracked only for robustness;
    /// every sketched quantity is physically nonnegative) collapse to
    /// the observed minimum.
    pub fn quantile(&self, p: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return f64::NAN;
        }
        let rank = ((p.clamp(0.0, 1.0) * n as f64).ceil() as u64).clamp(1, n);
        let mut seen = self.negatives;
        if rank <= seen {
            return self.min;
        }
        seen += self.zeros;
        if rank <= seen {
            return 0.0;
        }
        for (b, &c) in self.bins.iter().enumerate() {
            seen += c;
            if rank <= seen {
                return bin_upper(b).min(self.max);
            }
        }
        self.max
    }

    /// Serialize: sparse `[bin, count]` pairs plus exact min/max as
    /// 16-hex-digit bit patterns (`min`/`max` number fields are
    /// human-readable duplicates, present only when finite).
    pub fn to_json(&self) -> Json {
        let pairs: Vec<Json> = self
            .bins
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| Json::Arr(vec![Json::Num(i as f64), Json::Num(c as f64)]))
            .collect();
        let readable = |x: f64| if x.is_finite() { Json::Num(x) } else { Json::Null };
        json::obj(vec![
            ("schema", json::num(SKETCH_SCHEMA as f64)),
            ("count", json::num(self.count() as f64)),
            ("negatives", json::num(self.negatives as f64)),
            ("zeros", json::num(self.zeros as f64)),
            ("non_finite", json::num(self.non_finite as f64)),
            ("min_bits", json::s(&format!("{:016x}", self.min.to_bits()))),
            ("max_bits", json::s(&format!("{:016x}", self.max.to_bits()))),
            ("min", readable(self.min)),
            ("max", readable(self.max)),
            ("bins", Json::Arr(pairs)),
        ])
    }

    /// Inverse of [`Sketch::to_json`].
    pub fn from_json(v: &Json) -> Result<Sketch, String> {
        let getn = |k: &str| {
            v.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("sketch: missing numeric `{k}`"))
        };
        let getbits = |k: &str| -> Result<f64, String> {
            let t = v
                .get(k)
                .and_then(Json::as_str)
                .ok_or_else(|| format!("sketch: missing `{k}`"))?;
            u64::from_str_radix(t, 16)
                .map(f64::from_bits)
                .map_err(|e| format!("sketch: bad `{k}`: {e}"))
        };
        let mut sk = Sketch::new();
        sk.negatives = getn("negatives")? as u64;
        sk.zeros = getn("zeros")? as u64;
        sk.non_finite = getn("non_finite")? as u64;
        sk.min = getbits("min_bits")?;
        sk.max = getbits("max_bits")?;
        let pairs = v.get("bins").and_then(Json::as_arr).ok_or("sketch: missing `bins`")?;
        for pair in pairs {
            let p = pair.as_arr().ok_or("sketch: bin entry is not a pair")?;
            if p.len() != 2 {
                return Err("sketch: bin entry is not a pair".into());
            }
            let i = p[0].as_f64().ok_or("sketch: bad bin index")? as usize;
            if i >= BINS {
                return Err(format!("sketch: bin index {i} out of range"));
            }
            sk.bins[i] = p[1].as_f64().ok_or("sketch: bad bin count")? as u64;
        }
        Ok(sk)
    }

    /// FNV-1a 64 over the canonical serialization: a short hex string
    /// that is equal iff two sketches serialize identically (ledger
    /// lines carry digests so `report` can spot shard divergence
    /// without loading every sidecar).
    pub fn digest(&self) -> String {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in self.to_json().to_string_compact().bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        format!("{h:016x}")
    }
}

/// The sketch sidecar path of a unit's JSONL trace:
/// `<stem>.sketch.json` next to `<stem>.jsonl` — shared by the sweep
/// writer and the `report` reader so the two can never disagree.
pub fn sidecar_path(trace_path: &Path) -> std::path::PathBuf {
    trace_path.with_extension("sketch.json")
}

/// The four per-run distribution sketches, derived **purely from the
/// trace** — a resumed run (whose trace is restored from the snapshot)
/// reproduces them bit-for-bit, with no extra checkpoint state.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceSketches {
    /// Per-round energy spend (J).
    pub energy: Sketch,
    /// Per-round max realized client latency (s).
    pub latency: Sketch,
    /// Per-client quantization level among quantizing uploads (q > 0).
    pub q: Sketch,
    /// Per-round realized wire bytes.
    pub wire_bytes: Sketch,
}

/// Serialization keys of the four sketches, in report order.
pub const TRACE_SKETCH_KINDS: [&str; 4] = ["energy_j", "max_latency_s", "q", "wire_bytes"];

impl TraceSketches {
    /// Build all four sketches from a trace. Per-*round* aggregates
    /// (energy, latency, wire bytes) rather than per-client raw values
    /// keep this a pure function of the checkpointed trace; q is the
    /// exception — per-client levels are already in the trace.
    pub fn from_trace(trace: &Trace) -> TraceSketches {
        let mut ts = TraceSketches::default();
        for r in &trace.records {
            ts.energy.push(r.energy);
            ts.latency.push(r.max_latency);
            ts.wire_bytes.push(r.wire_bytes as f64);
            for q in r.q_per_client.iter().flatten() {
                if *q > 0 {
                    ts.q.push(*q as f64);
                }
            }
        }
        ts
    }

    /// Fold `other` in, sketch by sketch (exactly associative).
    pub fn merge(&mut self, other: &TraceSketches) {
        self.energy.merge(&other.energy);
        self.latency.merge(&other.latency);
        self.q.merge(&other.q);
        self.wire_bytes.merge(&other.wire_bytes);
    }

    /// Serialize all four sketches under their
    /// [`TRACE_SKETCH_KINDS`] keys.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("schema", json::num(SKETCH_SCHEMA as f64)),
            ("energy_j", self.energy.to_json()),
            ("max_latency_s", self.latency.to_json()),
            ("q", self.q.to_json()),
            ("wire_bytes", self.wire_bytes.to_json()),
        ])
    }

    /// Inverse of [`TraceSketches::to_json`].
    pub fn from_json(v: &Json) -> Result<TraceSketches, String> {
        let get = |k: &str| {
            Sketch::from_json(v.get(k).ok_or_else(|| format!("sketches: missing `{k}`"))?)
        };
        Ok(TraceSketches {
            energy: get("energy_j")?,
            latency: get("max_latency_s")?,
            q: get("q")?,
            wire_bytes: get("wire_bytes")?,
        })
    }

    /// `(kind, digest)` per sketch, in [`TRACE_SKETCH_KINDS`] order.
    pub fn digests(&self) -> Vec<(&'static str, String)> {
        vec![
            ("energy_j", self.energy.digest()),
            ("max_latency_s", self.latency.digest()),
            ("q", self.q.digest()),
            ("wire_bytes", self.wire_bytes.digest()),
        ]
    }

    /// Write the sketch sidecar atomically (`fsio`).
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let mut text = self.to_json().to_string_compact();
        text.push('\n');
        crate::util::fsio::write_atomic(path, text.as_bytes())
    }

    /// Read a sketch sidecar written by [`TraceSketches::save`].
    pub fn load(path: &Path) -> Result<TraceSketches, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        TraceSketches::from_json(&json::parse(text.trim())?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::RoundRecord;
    use crate::util::prop::{check, iters};
    use crate::util::rng::Rng;

    fn sketch_of(xs: &[f64]) -> Sketch {
        let mut sk = Sketch::new();
        for &x in xs {
            sk.push(x);
        }
        sk
    }

    fn gen_positives(rng: &mut Rng, n_max: usize) -> Vec<f64> {
        let n = 1 + rng.below(n_max);
        (0..n).map(|_| 10f64.powf(rng.range(-12.0, 12.0))).collect()
    }

    #[test]
    fn prop_merge_is_associative_and_matches_concat() {
        check(
            "sketch-merge-assoc",
            iters(200),
            |rng| {
                (
                    gen_positives(rng, 40),
                    gen_positives(rng, 40),
                    gen_positives(rng, 40),
                )
            },
            |(a, b, c)| {
                let (sa, sb, sc) = (sketch_of(a), sketch_of(b), sketch_of(c));
                // Left grouping.
                let mut left = sa.clone();
                left.merge(&sb);
                left.merge(&sc);
                // Right grouping.
                let mut bc = sb.clone();
                bc.merge(&sc);
                let mut right = sa.clone();
                right.merge(&bc);
                if left != right {
                    return Err("grouping changed the merged sketch".into());
                }
                let concat: Vec<f64> =
                    a.iter().chain(b).chain(c).copied().collect();
                if left != sketch_of(&concat) {
                    return Err("merge differs from sketching the concatenation".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_quantile_within_one_subbin_above_truth() {
        check(
            "sketch-quantile-bounds",
            iters(200),
            |rng| (gen_positives(rng, 60), rng.uniform()),
            |(xs, p)| {
                let sk = sketch_of(xs);
                let mut v = xs.clone();
                v.sort_by(|a, b| a.total_cmp(b));
                let n = v.len();
                let rank = ((p * n as f64).ceil() as usize).clamp(1, n);
                let truth = v[rank - 1];
                let est = sk.quantile(*p);
                if est < truth {
                    return Err(format!("estimate {est} below true quantile {truth}"));
                }
                if est > truth * 1.25 * (1.0 + 1e-9) {
                    return Err(format!(
                        "estimate {est} more than 25% above true quantile {truth}"
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_json_round_trips_exactly() {
        check(
            "sketch-json-roundtrip",
            iters(100),
            |rng| gen_positives(rng, 50),
            |xs| {
                let sk = sketch_of(xs);
                let text = sk.to_json().to_string_compact();
                let back = Sketch::from_json(&crate::util::json::parse(&text)?)
                    .map_err(|e| format!("reparse: {e}"))?;
                if back != sk {
                    return Err("sketch changed across JSON round trip".into());
                }
                if back.digest() != sk.digest() {
                    return Err("digest changed across JSON round trip".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn empty_zero_negative_and_nonfinite_handling() {
        let empty = Sketch::new();
        assert_eq!(empty.count(), 0);
        assert!(empty.quantile(0.5).is_nan());

        let mut sk = Sketch::new();
        sk.push(0.0);
        sk.push(0.0);
        sk.push(-3.0);
        sk.push(f64::NAN);
        sk.push(f64::INFINITY);
        sk.push(8.0);
        assert_eq!(sk.count(), 4);
        assert_eq!(sk.non_finite(), 2);
        assert_eq!(sk.min(), -3.0);
        assert_eq!(sk.max(), 8.0);
        // rank 1 → negatives; rank 2..=3 → zeros; rank 4 → the 8.0 bin.
        assert_eq!(sk.quantile(0.25), -3.0);
        assert_eq!(sk.quantile(0.5), 0.0);
        let top = sk.quantile(1.0);
        assert!((8.0..=10.0).contains(&top), "top={top}");
    }

    #[test]
    fn exact_powers_land_in_expected_bins() {
        // 1.0 has biased exponent 1023, top mantissa bits 00.
        assert_eq!(bin_index(1.0), (1023 - EXP_LO) as usize * SUBS);
        // Upper edge of 1.0's bin is 1.25 exactly.
        assert_eq!(bin_upper(bin_index(1.0)), 1.25);
        // Clamping: far-out magnitudes hit the edge bins.
        assert_eq!(bin_index(1e-300), 0);
        assert_eq!(bin_index(1e300), BINS - 1);
    }

    #[test]
    fn from_trace_draws_the_documented_fields() {
        let mut t = Trace::new("qccf");
        t.push(RoundRecord {
            round: 1,
            energy: 2.0,
            max_latency: 0.5,
            wire_bytes: 1000,
            q_per_client: vec![Some(4), Some(0), None, Some(6)],
            ..Default::default()
        });
        t.push(RoundRecord {
            round: 2,
            energy: 3.0,
            max_latency: 0.25,
            wire_bytes: 900,
            q_per_client: vec![None, Some(2), None, None],
            ..Default::default()
        });
        let ts = TraceSketches::from_trace(&t);
        assert_eq!(ts.energy.count(), 2);
        assert_eq!(ts.latency.count(), 2);
        assert_eq!(ts.wire_bytes.count(), 2);
        // q: Some(0) is a raw upload, None unscheduled — 3 quantized.
        assert_eq!(ts.q.count(), 3);
        // Round trip through the sidecar format.
        let back = TraceSketches::from_json(&ts.to_json()).unwrap();
        assert_eq!(back, ts);
        assert_eq!(back.digests(), ts.digests());
    }

    #[test]
    fn sidecar_save_load_round_trips() {
        let mut t = Trace::new("qccf");
        t.push(RoundRecord {
            round: 1,
            energy: 1.5,
            max_latency: 0.1,
            wire_bytes: 640,
            q_per_client: vec![Some(4)],
            ..Default::default()
        });
        let ts = TraceSketches::from_trace(&t);
        assert_eq!(
            sidecar_path(Path::new("out/s__qccf__seed1.jsonl")),
            Path::new("out/s__qccf__seed1.sketch.json")
        );
        let dir = std::env::temp_dir().join("qccf_obs_sketch_sidecar");
        let path = dir.join("unit.sketch.json");
        ts.save(&path).unwrap();
        let back = TraceSketches::load(&path).unwrap();
        assert_eq!(back, ts);
        std::fs::remove_dir_all(&dir).ok();
    }
}
