//! Observability layer: stage-span profiler, deterministic streaming
//! sketches, the run ledger, and the `report` aggregator.
//!
//! This module is the repo's **only** home for wall-clock side-channel
//! telemetry outside `runtime/`, `bench.rs`, and the logger — detlint's
//! R2 allowlist admits `obs/`, and the companion R7 rule enforces the
//! reverse boundary: no `obs` wall-clock type may flow into `metrics`
//! or `ckpt`, so nothing here can ever move a trace or snapshot bit
//! (docs/OBSERVABILITY.md, docs/DETERMINISM.md).
//!
//! Three pieces:
//!
//! * [`spans`] — named monotonic stage spans (decide / execute /
//!   aggregate / queue-update / checkpoint-write / sweep-unit) on the
//!   `ExecClock` atomic-accumulation pattern; wall-clock only, never a
//!   decision input.
//! * [`sketch`] — fixed-bin log-histogram quantile sketches over
//!   *simulated* quantities (energy, latency, q, wire bytes):
//!   deterministic by construction (no sampling, no wall-clock), with
//!   exact associative merge so sweep shards fold.
//! * [`ledger`] + [`report`] — one schema-versioned JSONL line per
//!   completed run/unit, and an aggregator that turns a sweep directory
//!   into a health report without rereading per-round traces.

pub mod ledger;
pub mod report;
pub mod sketch;
pub mod spans;
pub mod wall;

use std::sync::atomic::{AtomicU8, Ordering};

/// Tri-state enable flag: 0 = off, 1 = on, 2 = not yet resolved from
/// the environment.
static ENABLED: AtomicU8 = AtomicU8::new(2);

/// Whether wall-clock observability (span profiling) is enabled.
///
/// Resolved once from `QCCF_OBS` (`0`/`false`/`off` disable; anything
/// else — including unset — enables) and cached; [`set_enabled`]
/// overrides the cache. Disabling must not change any deterministic
/// output — the bit-identity pin in `tests/integration_obs.rs` holds
/// traces and snapshot bytes fixed across both settings.
pub fn enabled() -> bool {
    match ENABLED.load(Ordering::Relaxed) {
        0 => false,
        1 => true,
        _ => {
            let on = !matches!(
                std::env::var("QCCF_OBS").as_deref(),
                Ok("0") | Ok("false") | Ok("off")
            );
            ENABLED.store(u8::from(on), Ordering::Relaxed);
            on
        }
    }
}

/// Force the observability gate on or off (tests and tooling; env
/// mutation mid-process would race the cached resolution).
pub fn set_enabled(on: bool) {
    ENABLED.store(u8::from(on), Ordering::Relaxed);
}

/// Serializes tests that flip the global gate: the unit-test runner is
/// multi-threaded, and a `set_enabled(false)` mid-flight would make a
/// concurrent span test's guard silently inert.
#[cfg(test)]
pub(crate) fn test_gate() -> std::sync::MutexGuard<'static, ()> {
    static GATE: std::sync::Mutex<()> = std::sync::Mutex::new(());
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_enabled_overrides_cache() {
        let _gate = test_gate();
        set_enabled(false);
        assert!(!enabled());
        set_enabled(true);
        assert!(enabled());
    }
}
