//! Stage-scoped span profiler on the `ExecClock` pattern
//! (`runtime::ExecClock`): one pair of lock-free cumulative counters
//! (nanoseconds, calls) per named pipeline stage, plus a thread-local
//! shadow so a sweep worker can read out exactly its own unit's spans.
//!
//! Spans are **side-channel wall-clock only**: a [`SpanGuard`] never
//! feeds a decision, and everything it accumulates stays out of
//! deterministic outputs (JSONL traces, snapshots) — the CSV's
//! `decide_s`/`compute_s` columns are read *from* the profiler and the
//! CSV is explicitly excluded from the bit-identity contract
//! (docs/DETERMINISM.md). Guards nest freely; each records its own
//! stage independently, so e.g. `SweepUnit` encloses every per-round
//! span of its unit.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Number of named stages ([`Span::ALL`]).
pub const N_SPANS: usize = 6;

/// A named pipeline stage, in pipeline order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Span {
    /// Scheduler decision stage (`fl::Server::stage_decide`).
    Decide = 0,
    /// Client fan-out incl. the streaming aggregation fold
    /// (`fl::exec::execute_round_with`).
    Execute = 1,
    /// Global-model writeback (`fl::Server::run_round`).
    Aggregate = 2,
    /// Lyapunov virtual-queue update (`fl::Server::run_round`).
    QueueUpdate = 3,
    /// Snapshot encode + atomic write (`experiments::common`, at the
    /// `ckpt` call site — the `ckpt` module itself is obs-free per R7).
    CheckpointWrite = 4,
    /// One whole sweep unit: run + trace/sketch/ledger writes
    /// (`experiments::sweep`).
    SweepUnit = 5,
}

impl Span {
    /// Every stage, in report order.
    pub const ALL: [Span; N_SPANS] = [
        Span::Decide,
        Span::Execute,
        Span::Aggregate,
        Span::QueueUpdate,
        Span::CheckpointWrite,
        Span::SweepUnit,
    ];

    /// The stable name used in ledger lines and reports.
    pub fn name(self) -> &'static str {
        match self {
            Span::Decide => "decide",
            Span::Execute => "execute",
            Span::Aggregate => "aggregate",
            Span::QueueUpdate => "queue-update",
            Span::CheckpointWrite => "checkpoint-write",
            Span::SweepUnit => "sweep-unit",
        }
    }

    /// Index into [`SpanTotals`] arrays.
    pub fn index(self) -> usize {
        self as usize
    }

    /// Inverse of [`Span::name`] (ledger parsing).
    pub fn from_name(name: &str) -> Option<Span> {
        Span::ALL.into_iter().find(|s| s.name() == name)
    }
}

// `[AtomicU64::new(0); N]` needs a const item (AtomicU64 is not Copy).
#[allow(clippy::declare_interior_mutable_const)]
const ZERO: AtomicU64 = AtomicU64::new(0);
/// Process-wide cumulative nanoseconds per stage.
static NANOS: [AtomicU64; N_SPANS] = [ZERO; N_SPANS];
/// Process-wide cumulative guard count per stage.
static CALLS: [AtomicU64; N_SPANS] = [ZERO; N_SPANS];

thread_local! {
    /// Per-thread shadow of (nanos, calls): a sweep worker runs its
    /// unit single-threaded on one pool thread, so [`local_take`]
    /// reads out exactly that unit's spans without cross-unit bleed.
    static LOCAL: RefCell<([u64; N_SPANS], [u64; N_SPANS])> =
        const { RefCell::new(([0; N_SPANS], [0; N_SPANS])) };
}

fn record(span: Span, nanos: u64) {
    let i = span.index();
    // Relaxed suffices, exactly as in `ExecClock`: independent counters
    // read only as point-in-time snapshots, never for synchronization.
    NANOS[i].fetch_add(nanos, Ordering::Relaxed);
    CALLS[i].fetch_add(1, Ordering::Relaxed);
    LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        l.0[i] += nanos;
        l.1[i] += 1;
    });
}

/// Point-in-time span accumulation: seconds and guard counts per stage,
/// indexed by [`Span::index`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SpanTotals {
    /// Cumulative wall seconds per stage.
    pub secs: [f64; N_SPANS],
    /// Number of completed guards per stage.
    pub calls: [u64; N_SPANS],
}

impl SpanTotals {
    /// Seconds accumulated for one stage.
    pub fn secs_of(&self, span: Span) -> f64 {
        self.secs[span.index()]
    }

    /// Completed guard count for one stage.
    pub fn calls_of(&self, span: Span) -> u64 {
        self.calls[span.index()]
    }
}

/// Process-wide totals since start (or the last [`reset`]).
pub fn totals() -> SpanTotals {
    let mut t = SpanTotals::default();
    for i in 0..N_SPANS {
        t.secs[i] = NANOS[i].load(Ordering::Relaxed) as f64 * 1e-9;
        t.calls[i] = CALLS[i].load(Ordering::Relaxed);
    }
    t
}

/// Drain the calling thread's span shadow: returns what this thread
/// accumulated since its last `local_take` and zeroes the shadow. The
/// sweep worker calls this once per unit (units run with engine
/// `threads = 1`, so the whole unit's spans land on one pool thread).
pub fn local_take() -> SpanTotals {
    LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        let mut t = SpanTotals::default();
        for i in 0..N_SPANS {
            t.secs[i] = l.0[i] as f64 * 1e-9;
            t.calls[i] = l.1[i];
        }
        *l = ([0; N_SPANS], [0; N_SPANS]);
        t
    })
}

/// Zero the process-wide counters and the calling thread's shadow
/// (other threads' shadows are untouched — tests and tooling only).
pub fn reset() {
    for i in 0..N_SPANS {
        NANOS[i].store(0, Ordering::Relaxed);
        CALLS[i].store(0, Ordering::Relaxed);
    }
    LOCAL.with(|l| *l.borrow_mut() = ([0; N_SPANS], [0; N_SPANS]));
}

/// An open span: created by [`SpanGuard::enter`], recorded on
/// [`SpanGuard::finish_secs`] or drop. When the [`crate::obs`] gate is
/// off the guard holds no clock at all — zero reads, zero writes.
#[derive(Debug)]
pub struct SpanGuard {
    span: Span,
    start: Option<Instant>,
}

impl SpanGuard {
    /// Open a span for `span`; reads the monotonic clock only when the
    /// observability gate is on.
    pub fn enter(span: Span) -> SpanGuard {
        let start = crate::obs::enabled().then(Instant::now);
        SpanGuard { span, start }
    }

    /// Close the span, record it, and return its elapsed wall seconds
    /// (0.0 when the gate was off at `enter` time). The return value is
    /// **side-channel only** — it may reach the CSV's wall columns, but
    /// never a decision or a deterministic output (detlint R7).
    pub fn finish_secs(mut self) -> f64 {
        self.close().unwrap_or(0.0)
    }

    fn close(&mut self) -> Option<f64> {
        let start = self.start.take()?;
        let nanos = start.elapsed().as_nanos() as u64;
        record(self.span, nanos);
        Some(nanos as f64 * 1e-9)
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        self.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip_and_indices_cover() {
        for (i, s) in Span::ALL.into_iter().enumerate() {
            assert_eq!(s.index(), i);
            assert_eq!(Span::from_name(s.name()), Some(s));
        }
        assert_eq!(Span::from_name("nope"), None);
    }

    #[test]
    fn guard_records_calls_and_nonnegative_secs() {
        let _gate = crate::obs::test_gate();
        crate::obs::set_enabled(true);
        let _ = local_take();
        let before = totals();
        let g = SpanGuard::enter(Span::Decide);
        let secs = g.finish_secs();
        assert!(secs >= 0.0);
        // Global counters are process-wide (other tests may also record),
        // so assert monotonicity there and exactness on the thread shadow.
        let after = totals();
        assert!(after.calls_of(Span::Decide) > before.calls_of(Span::Decide));
        assert!(after.secs_of(Span::Decide) >= before.secs_of(Span::Decide));
        let local = local_take();
        assert_eq!(local.calls_of(Span::Decide), 1);
        assert!(local.secs_of(Span::Decide) >= 0.0);
        // Drained: a second take sees nothing.
        assert_eq!(local_take(), SpanTotals::default());
    }

    #[test]
    fn disabled_guard_is_inert() {
        let _gate = crate::obs::test_gate();
        crate::obs::set_enabled(false);
        let _ = local_take();
        let g = SpanGuard::enter(Span::Aggregate);
        assert_eq!(g.finish_secs(), 0.0);
        assert_eq!(local_take().calls_of(Span::Aggregate), 0);
        crate::obs::set_enabled(true);
    }

    #[test]
    fn drop_records_once_even_after_finish() {
        let _gate = crate::obs::test_gate();
        crate::obs::set_enabled(true);
        let _ = local_take();
        {
            let _g = SpanGuard::enter(Span::QueueUpdate); // drop path
        }
        let g = SpanGuard::enter(Span::QueueUpdate);
        let _ = g.finish_secs(); // finish path — drop must not double-count
        assert_eq!(local_take().calls_of(Span::QueueUpdate), 2);
    }

    #[test]
    fn nested_guards_each_record_their_stage() {
        let _gate = crate::obs::test_gate();
        crate::obs::set_enabled(true);
        let _ = local_take();
        let outer = SpanGuard::enter(Span::SweepUnit);
        let inner = SpanGuard::enter(Span::Decide);
        let _ = inner.finish_secs();
        let _ = outer.finish_secs();
        let t = local_take();
        assert_eq!(t.calls_of(Span::SweepUnit), 1);
        assert_eq!(t.calls_of(Span::Decide), 1);
    }
}
