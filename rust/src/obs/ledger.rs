//! Run ledger: one schema-versioned JSONL line per completed run or
//! sweep unit, appended to `<out>/ledger.jsonl`.
//!
//! The ledger is the side-channel record `report` aggregates instead of
//! rereading per-round traces: scenario identity, seed, status, wall
//! duration, per-stage span totals, sketch digests, and the bench
//! `git describe` stamp. Appends go through [`crate::util::fsio`]'s
//! append helper (single `write(2)` of one line + fsync); readers skip
//! unparseable lines, so a torn tail line degrades to one missing entry
//! rather than a poisoned file.

use std::collections::BTreeMap;
use std::path::Path;

use crate::obs::spans::{Span, SpanTotals};
use crate::util::json::{self, Json};

/// Ledger line schema version (bump on any breaking field change).
pub const LEDGER_SCHEMA: u32 = 1;

/// File name of the ledger within an `--out` directory.
pub const LEDGER_FILE: &str = "ledger.jsonl";

/// One completed run (a `train` invocation or one sweep unit).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LedgerEntry {
    /// `"train"` or `"sweep-unit"`.
    pub kind: String,
    /// Scenario name.
    pub scenario: String,
    /// Algorithm name.
    pub algorithm: String,
    /// Run seed.
    pub seed: u64,
    /// Rounds completed.
    pub rounds: usize,
    /// `"ok"` or `"failed"`.
    pub status: String,
    /// Wall-clock duration of the run (side-channel).
    pub wall_secs: f64,
    /// Engine threads the run used.
    pub threads: usize,
    /// Per-stage span totals accumulated by the run (side-channel).
    pub spans: SpanTotals,
    /// Sketch digests keyed by kind (`energy_j`, …) — empty when the
    /// run produced no sketches (e.g. a failed unit).
    pub sketch_digests: BTreeMap<String, String>,
    /// `git describe` stamp of the producing binary's checkout.
    pub git: String,
}

impl LedgerEntry {
    /// Serialize to one ledger line's JSON object.
    pub fn to_json(&self) -> Json {
        let mut spans = BTreeMap::new();
        for s in Span::ALL {
            spans.insert(
                s.name().to_string(),
                json::obj(vec![
                    ("secs", json::num(self.spans.secs_of(s))),
                    ("calls", json::num(self.spans.calls_of(s) as f64)),
                ]),
            );
        }
        let digests: BTreeMap<String, Json> = self
            .sketch_digests
            .iter()
            .map(|(k, v)| (k.clone(), json::s(v)))
            .collect();
        json::obj(vec![
            ("schema", json::num(LEDGER_SCHEMA as f64)),
            ("kind", json::s(&self.kind)),
            ("scenario", json::s(&self.scenario)),
            ("algorithm", json::s(&self.algorithm)),
            ("seed", json::num(self.seed as f64)),
            ("rounds", json::num(self.rounds as f64)),
            ("status", json::s(&self.status)),
            ("wall_secs", json::num(self.wall_secs)),
            ("threads", json::num(self.threads as f64)),
            ("spans", Json::Obj(spans)),
            ("sketch_digests", Json::Obj(digests)),
            ("git", json::s(&self.git)),
        ])
    }

    /// Inverse of [`LedgerEntry::to_json`].
    pub fn from_json(v: &Json) -> Result<LedgerEntry, String> {
        let schema = v
            .get("schema")
            .and_then(Json::as_f64)
            .ok_or("ledger: missing `schema`")? as u32;
        if schema != LEDGER_SCHEMA {
            return Err(format!("ledger: unsupported schema {schema}"));
        }
        let gets = |k: &str| {
            v.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("ledger: missing string `{k}`"))
        };
        let getn = |k: &str| {
            v.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("ledger: missing numeric `{k}`"))
        };
        let mut spans = SpanTotals::default();
        if let Some(obj) = v.get("spans").and_then(Json::as_obj) {
            for (name, entry) in obj {
                let Some(s) = Span::from_name(name) else { continue };
                spans.secs[s.index()] =
                    entry.get("secs").and_then(Json::as_f64).unwrap_or(0.0);
                spans.calls[s.index()] =
                    entry.get("calls").and_then(Json::as_f64).unwrap_or(0.0) as u64;
            }
        }
        let mut sketch_digests = BTreeMap::new();
        if let Some(obj) = v.get("sketch_digests").and_then(Json::as_obj) {
            for (k, d) in obj {
                if let Some(d) = d.as_str() {
                    sketch_digests.insert(k.clone(), d.to_string());
                }
            }
        }
        Ok(LedgerEntry {
            kind: gets("kind")?,
            scenario: gets("scenario")?,
            algorithm: gets("algorithm")?,
            seed: getn("seed")? as u64,
            rounds: getn("rounds")? as usize,
            status: gets("status")?,
            wall_secs: getn("wall_secs")?,
            threads: getn("threads")? as usize,
            spans,
            sketch_digests,
            git: gets("git")?,
        })
    }
}

/// Append one entry to `<dir>/ledger.jsonl`.
pub fn append(dir: &Path, entry: &LedgerEntry) -> std::io::Result<()> {
    crate::util::fsio::append_line(
        &dir.join(LEDGER_FILE),
        &entry.to_json().to_string_compact(),
    )
}

/// Read every parseable entry of `<dir>/ledger.jsonl`, in file order.
/// A missing file yields an empty vec; unparseable lines (torn tail
/// after a crash, foreign schema) are skipped.
pub fn read(dir: &Path) -> Vec<LedgerEntry> {
    let Ok(text) = std::fs::read_to_string(dir.join(LEDGER_FILE)) else {
        return Vec::new();
    };
    text.lines()
        .filter_map(|line| {
            let line = line.trim();
            if line.is_empty() {
                return None;
            }
            json::parse(line).ok().and_then(|v| LedgerEntry::from_json(&v).ok())
        })
        .collect()
}

/// Best-effort `git describe --always --dirty` of the current checkout
/// (ledger provenance stamp); `"unknown"` when git or the repo is
/// unavailable. Side-channel only.
pub fn git_describe() -> String {
    std::process::Command::new("git")
        .args(["describe", "--always", "--dirty"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry() -> LedgerEntry {
        let mut spans = SpanTotals::default();
        spans.secs[Span::Decide.index()] = 0.5;
        spans.calls[Span::Decide.index()] = 10;
        spans.secs[Span::Execute.index()] = 2.0;
        spans.calls[Span::Execute.index()] = 10;
        let mut digests = BTreeMap::new();
        digests.insert("energy_j".to_string(), "00ff00ff00ff00ff".to_string());
        LedgerEntry {
            kind: "sweep-unit".into(),
            scenario: "paper-femnist".into(),
            algorithm: "qccf".into(),
            seed: 3,
            rounds: 20,
            status: "ok".into(),
            wall_secs: 12.25,
            threads: 1,
            spans,
            sketch_digests: digests,
            git: "abc1234".into(),
        }
    }

    #[test]
    fn json_round_trip_is_exact() {
        let e = entry();
        let text = e.to_json().to_string_compact();
        let back = LedgerEntry::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, e);
    }

    #[test]
    fn append_then_read_skips_torn_and_foreign_lines() {
        let dir = std::env::temp_dir().join("qccf_obs_ledger_test");
        std::fs::remove_dir_all(&dir).ok();
        let e = entry();
        append(&dir, &e).unwrap();
        append(&dir, &e).unwrap();
        // Simulate a torn tail and a foreign line.
        {
            use std::io::Write as _;
            let mut f = std::fs::OpenOptions::new()
                .append(true)
                .open(dir.join(LEDGER_FILE))
                .unwrap();
            writeln!(f, "{{\"schema\":999}}").unwrap();
            write!(f, "{{\"schema\":1,\"kind\":\"tr").unwrap();
        }
        let entries = read(&dir);
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0], e);
        // Missing dir reads empty.
        assert!(read(&dir.join("nope")).is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn git_describe_never_panics() {
        let s = git_describe();
        assert!(!s.is_empty());
    }
}
