//! Sweep health report: aggregate a sweep output directory —
//! `summary.csv`, `ledger.jsonl`, and the per-unit sketch sidecars —
//! into one deterministic text report, **without rereading any
//! per-round JSONL trace**.
//!
//! The report is a pure function of the on-disk aggregates, so the
//! golden-file test (`tests/golden_report.rs`) pins its exact bytes on
//! a synthetic directory. Sections are fixed and greppable (`verify.sh`
//! smokes on them): `-- outcomes --`, `-- stage times`, `-- energy
//! quantiles`, `-- bench deltas --`.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

use crate::experiments::sweep;
use crate::obs::ledger::{self, LedgerEntry};
use crate::obs::sketch::{self, TraceSketches};
use crate::obs::spans::Span;
use crate::util::stats;

/// Pseudo-scenario key for the fold over every scenario; parenthesized
/// so it can never collide with a real scenario name (those are
/// restricted to `[A-Za-z0-9._-]`).
const ALL: &str = "(all)";

/// Render the health report for a sweep directory. `bench_baseline` /
/// `bench_fresh` gate the advisory perf-delta section: with both set,
/// every committed `BENCH_*.json` baseline is diffed against the fresh
/// run of the same name (the `bench-diff` machinery at its default 20%
/// threshold); otherwise the section says it was skipped.
///
/// Missing inputs degrade to explicit lines, not errors — an empty or
/// partly-written directory still reports. Only a structurally foreign
/// `summary.csv` errors (same contract as `sweep --resume`).
pub fn render(
    dir: &Path,
    bench_baseline: Option<&Path>,
    bench_fresh: Option<&Path>,
) -> Result<String> {
    let rows = sweep::read_summary(dir)?;
    let entries = ledger::read(dir);
    let mut out = String::new();
    writeln!(out, "== qccf report ==")?;

    // -- outcomes -- : unit counts and failure/retry/dropout rates,
    // straight off the summary rows.
    let ok = rows.iter().filter(|r| r.status == "ok").count();
    let failed = rows.len() - ok;
    let scheduled: usize = rows.iter().map(|r| r.scheduled).sum();
    let dropouts: usize = rows.iter().map(|r| r.dropouts).sum();
    let retries: usize = rows.iter().map(|r| r.retries).sum();
    let rate = |num: usize, den: usize| if den == 0 { 0.0 } else { num as f64 / den as f64 };
    writeln!(out)?;
    writeln!(out, "-- outcomes --")?;
    writeln!(out, "units: {ok} ok + {failed} failed = {}", rows.len())?;
    writeln!(out, "failed rate: {:.4}", rate(failed, rows.len()))?;
    writeln!(out, "retries: {retries} ({:.6} per scheduled upload)", rate(retries, scheduled))?;
    writeln!(
        out,
        "dropouts: {dropouts} of {scheduled} scheduled ({:.6})",
        rate(dropouts, scheduled)
    )?;

    // -- stage times -- : per-scenario per-stage wall-second quantiles
    // across ledger entries (one entry ≈ one unit), plus the (all)
    // fold. Side-channel numbers by construction — they came from span
    // guards, never from the traces.
    writeln!(out)?;
    writeln!(out, "-- stage times (s, from {} ledger entries) --", entries.len())?;
    if entries.is_empty() {
        writeln!(out, "no ledger entries (run with QCCF_OBS enabled to populate)")?;
    } else {
        let mut groups: BTreeMap<&str, Vec<&LedgerEntry>> = BTreeMap::new();
        for e in &entries {
            groups.entry(e.scenario.as_str()).or_default().push(e);
            groups.entry(ALL).or_default().push(e);
        }
        writeln!(
            out,
            "{:<20} {:<18} {:>7} {:>12} {:>12} {:>12} {:>12}",
            "scenario", "stage", "calls", "total", "p50", "p95", "p99"
        )?;
        for (scenario, group) in &groups {
            for stage in Span::ALL {
                let calls: u64 = group.iter().map(|e| e.spans.calls_of(stage)).sum();
                if calls == 0 {
                    continue;
                }
                let secs: Vec<f64> = group.iter().map(|e| e.spans.secs_of(stage)).collect();
                writeln!(
                    out,
                    "{:<20} {:<18} {:>7} {:>12.6} {:>12.6} {:>12.6} {:>12.6}",
                    scenario,
                    stage.name(),
                    calls,
                    secs.iter().sum::<f64>(),
                    stats::percentile(&secs, 50.0),
                    stats::percentile(&secs, 95.0),
                    stats::percentile(&secs, 99.0),
                )?;
            }
        }
    }

    // -- energy quantiles -- : merge the per-unit sketch sidecars per
    // scenario (the merge is exactly associative, so this equals one
    // sketch over every round of every unit) and read quantiles off
    // the merged sketches. Deterministic: sketches hold simulated
    // joules, not wall-clock.
    writeln!(out)?;
    writeln!(out, "-- energy quantiles (J, from sketch sidecars) --")?;
    let mut merged: BTreeMap<String, (usize, TraceSketches)> = BTreeMap::new();
    let mut missing = 0usize;
    for r in rows.iter().filter(|r| r.status == "ok") {
        match TraceSketches::load(&sketch::sidecar_path(&r.trace_path)) {
            Ok(ts) => {
                for key in [r.scenario.as_str(), ALL] {
                    let slot = merged.entry(key.to_string()).or_default();
                    slot.0 += 1;
                    slot.1.merge(&ts);
                }
            }
            Err(_) => missing += 1,
        }
    }
    if merged.is_empty() {
        writeln!(out, "no sketch sidecars found")?;
    } else {
        writeln!(
            out,
            "{:<20} {:>6} {:>7} {:>12} {:>12} {:>12} {:>12}",
            "scenario", "units", "rounds", "p50", "p90", "p99", "max"
        )?;
        for (scenario, (units, ts)) in &merged {
            writeln!(
                out,
                "{:<20} {:>6} {:>7} {:>12.6} {:>12.6} {:>12.6} {:>12.6}",
                scenario,
                units,
                ts.energy.count(),
                ts.energy.quantile(0.50),
                ts.energy.quantile(0.90),
                ts.energy.quantile(0.99),
                ts.energy.max(),
            )?;
        }
    }
    if missing > 0 {
        writeln!(out, "({missing} ok unit(s) had no readable sketch sidecar)")?;
    }

    // -- bench deltas -- : the advisory perf-regression diff, reusing
    // the exact bench-diff comparison so the two tools can never
    // disagree about what counts as a regression.
    writeln!(out)?;
    writeln!(out, "-- bench deltas --")?;
    match (bench_baseline, bench_fresh) {
        (Some(base_dir), Some(fresh_dir)) => {
            for name in crate::bench::BENCH_FILES {
                let bp = base_dir.join(name);
                let fp = fresh_dir.join(name);
                if !bp.is_file() || !fp.is_file() {
                    writeln!(out, "{name}: skipped (missing baseline or fresh run)")?;
                    continue;
                }
                let parse = |p: &Path| -> Result<crate::util::json::Json> {
                    crate::util::json::parse(std::fs::read_to_string(p)?.trim())
                        .map_err(|e| anyhow::anyhow!("{}: {e}", p.display()))
                };
                match (parse(&bp), parse(&fp)) {
                    (Ok(base), Ok(fresh)) => {
                        let warnings = crate::bench::bench_diff_report(&base, &fresh, 0.2);
                        if warnings.is_empty() {
                            writeln!(out, "{name}: ok (no metric regressed > 20%)")?;
                        }
                        for w in warnings {
                            writeln!(out, "{name}: {w}")?;
                        }
                    }
                    (Err(e), _) | (_, Err(e)) => {
                        writeln!(out, "{name}: unreadable ({e:#})")?;
                    }
                }
            }
        }
        _ => writeln!(out, "skipped (pass --bench-baseline and --bench-fresh to diff)")?,
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_dir_reports_every_section() {
        let dir = std::env::temp_dir().join("qccf_obs_report_empty");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::remove_file(dir.join("summary.csv")).ok();
        std::fs::remove_file(dir.join(ledger::LEDGER_FILE)).ok();
        let text = render(&dir, None, None).unwrap();
        for section in [
            "== qccf report ==",
            "-- outcomes --",
            "units: 0 ok + 0 failed = 0",
            "-- stage times",
            "no ledger entries",
            "-- energy quantiles",
            "no sketch sidecars found",
            "-- bench deltas --",
            "skipped (pass --bench-baseline",
        ] {
            assert!(text.contains(section), "missing `{section}` in:\n{text}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn foreign_summary_is_a_descriptive_error() {
        let dir = std::env::temp_dir().join("qccf_obs_report_foreign");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("summary.csv"), "a,b\n1,2\n").unwrap();
        let err = render(&dir, None, None).unwrap_err().to_string();
        assert!(err.contains("unrecognized header"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
