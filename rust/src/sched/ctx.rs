//! Decision-stage evaluation subsystem: per-round precomputation
//! ([`EvalCtx`]), reusable per-worker buffers ([`EvalScratch`]) and an
//! exact-key memo for the per-client closed-form solve — the
//! performance layer under Algorithm 1's GA fitness loop.
//!
//! The GA scores `population × generations` channel allocations per
//! round, and every score used to re-derive everything from scratch:
//! per-(client, channel) rates and q = 1 feasibility gates, the
//! participation-independent pieces of the eq. (27) convergence terms,
//! eight fresh length-U vectors, and one eq. (41) KKT solve per
//! assigned client. [`EvalCtx`] hoists the per-round invariants out of
//! that loop, [`EvalScratch`] removes the per-evaluation allocations,
//! and the solve memo removes repeated KKT solves as the population
//! converges onto recurring participant sets.
//!
//! ## Bit-identity contract
//!
//! [`EvalCtx::evaluate`] returns **bit-identical** `(J0, assignments)`
//! to the uncached reference [`super::evaluate_allocation`] for every
//! chromosome. Three ingredients make that safe:
//!
//! * precomputed values are *exactly* the f64s the reference computes:
//!   the same expressions in the same operation order, with the only
//!   elisions being multiplications by `1.0` and additions of `±0.0`,
//!   both exact in IEEE 754 (`x * 1.0 == x`; `x + 0.0 == x` whenever
//!   `x` is not `-0.0`, and the skipped summands accumulate into sums
//!   that start at `+0.0` and never become `-0.0`);
//! * the solve-memo key is `(client, rate.to_bits(), w_round.to_bits())`
//!   — exact f64 bit patterns, never an epsilon comparison — and every
//!   other [`solver::solve_client`] input (D_i, θ^max, q_prev, λ2,
//!   Case-5 mode) is constant within a round, so a hit replays the
//!   *identical* decision and energy, not an approximation;
//! * accumulation order is preserved: d_total, the C6/C7 scans and the
//!   energy sum all add in ascending client order exactly as the
//!   reference does.
//!
//! `tests/proptest_decision.rs` pins the equivalence across random
//! chromosomes, federation sizes, infeasible clients and empty
//! allocations; `tests/integration_fl.rs` pins whole-trace equality
//! with the caches on vs off.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::convergence;
use crate::energy;
use crate::ga::{self, Chromosome, GaParams};
use crate::solver::{self, Case5Mode, ClientCtx, Decision};
use crate::util::rng::Rng;

use super::{ClientDecision, RoundInputs};

/// Whether the decision-stage caches are enabled by default for this
/// process: the `QCCF_DECISION_CACHE=0` A/B kill switch, honored by
/// every GA-based scheduler (QCCF, Same-Size, and Channel-Allocate's
/// fitness cache).
pub fn decision_cache_default() -> bool {
    std::env::var("QCCF_DECISION_CACHE").map(|v| v != "0").unwrap_or(true)
}

/// The shared decide body of the GA-based schedulers: build the cached
/// evaluation context (memo gated by `cache`), run Algorithm 1 with
/// per-worker [`EvalScratch`] states and the GA fitness cache, then
/// fully evaluate the winner. Returns `(j0, assignments, evals)` —
/// bit-identical for any `cache` / worker-count combination.
pub fn decide_with_ga(
    inp: &RoundInputs<'_>,
    mode: Case5Mode,
    ga_params: &GaParams,
    rng: &mut Rng,
    seeds: &[Chromosome],
    cache: bool,
) -> (f64, Vec<Option<ClientDecision>>, usize) {
    let ctx = EvalCtx::build(inp, mode, cache);
    let mut scratches: Vec<EvalScratch> =
        (0..ga_params.threads.max(1)).map(|_| ctx.make_scratch()).collect();
    let params = GaParams { fitness_cache: cache && ga_params.fitness_cache, ..*ga_params };
    let outcome = ga::optimize_scratch(
        inp.params.num_channels,
        inp.params.num_clients,
        &params,
        rng,
        seeds,
        &mut scratches,
        |c, s| ctx.evaluate_j0(c, s),
    );
    let (j0, assignments) = ctx.evaluate(&outcome.best, &mut scratches[0]);
    (j0, assignments, outcome.evals)
}

/// Memoized result of one `(client, rate, w_round)` inner solve: the
/// Theorem-3 integer decision plus its eqs. (14)–(17) energy (`None` =
/// the solver declined the client).
type SolveMemo = Option<(Decision, f64)>;

/// Per-client memo shards keyed on `(rate.to_bits(), w_round.to_bits())`.
type MemoShards = Vec<Mutex<HashMap<(u64, u64), SolveMemo>>>;

/// Per-round evaluation context for [`super::evaluate_allocation`]'s
/// hot path: the U×C feasibility-gated (rate, q_max) table, the
/// participation-independent pieces of the eq. (27) convergence terms,
/// and (optionally) the exact-key per-client solve memo. Build once per
/// round from the [`RoundInputs`], share immutably across the GA's
/// fitness workers, drop with the round.
pub struct EvalCtx<'a> {
    inp: &'a RoundInputs<'a>,
    mode: Case5Mode,
    /// Row-major U×C copy of the round's per-(client, channel) rates,
    /// so the hot loop reads contiguously.
    rates: Vec<f64>,
    /// Row-major U×C `q_max_feasible` at that rate; 0 = the q = 1
    /// feasibility gate fails (pair unusable).
    q_max: Vec<u32>,
    /// A1(p) — constant per round (the reference recomputes it per
    /// evaluation; it is a pure function of the params, so the hoisted
    /// value is the same f64).
    a1v: f64,
    /// A2(p) — as above.
    a2v: f64,
    /// `4τ · Ĝ_i²` — client i's C6 summand when *excluded*
    /// (the reference's `4τ(1 − a·w_i)Ĝ_i²` at a = 0, where the
    /// `· 1.0` is exact).
    excl: Vec<f64>,
    /// `4τ(1 − w_i)Ĝ_i²` — client i's C6 summand when participating.
    incl: Vec<f64>,
    /// Per-client solve-memo shards (`None` = memo disabled). One lock
    /// per client: workers contend only when racing on the same
    /// client, and a lost race rewrites the identical value (the solve
    /// is a pure function of the key).
    memo: Option<MemoShards>,
}

/// Reusable per-evaluation buffers for [`EvalCtx`] — sized once by
/// [`EvalCtx::make_scratch`] (one per GA fitness worker), reset with
/// `fill` on every evaluation: the hot loop performs zero heap
/// allocation.
#[derive(Clone, Debug, Default)]
pub struct EvalScratch {
    /// Channel assigned to each client this evaluation (post-gate).
    assigned: Vec<Option<usize>>,
    /// Rate of that channel.
    rate: Vec<f64>,
    /// a_i^n — clients the inner solver accepted.
    participating: Vec<bool>,
    /// w_i^n over the feasibility-gated participant set.
    w_round: Vec<f64>,
}

impl<'a> EvalCtx<'a> {
    /// Precompute the round-invariant tables from `inp` (memo enabled;
    /// see [`EvalCtx::with_memo`]).
    pub fn new(inp: &'a RoundInputs<'a>, mode: Case5Mode) -> EvalCtx<'a> {
        Self::build(inp, mode, true)
    }

    /// [`EvalCtx::new`] with the memo toggle applied at construction,
    /// so a cache-disabled context never allocates the shards at all.
    fn build(inp: &'a RoundInputs<'a>, mode: Case5Mode, memo_enabled: bool) -> EvalCtx<'a> {
        let p = inp.params;
        let (u, c) = (p.num_clients, p.num_channels);
        let mut rates = vec![0.0f64; u * c];
        let mut q_max = vec![0u32; u * c];
        for i in 0..u {
            for ch in 0..c {
                let r = inp.channels.rate(i, ch);
                rates[i * c + ch] = r;
                // An unavailable client's whole row stays 0: the
                // `q_max >= 1` gate in `eval_inner` then rejects every
                // (i, ch) pair exactly where the reference evaluator's
                // availability gate does.
                q_max[i * c + ch] = if inp.is_available(i) {
                    solver::q_max_feasible(p, inp.sizes[i], r).unwrap_or(0)
                } else {
                    0
                };
            }
        }
        let tau = p.tau as f64;
        let a1v = convergence::a1(p);
        let a2v = convergence::a2(p);
        let excl: Vec<f64> = (0..u).map(|i| 4.0 * tau * inp.g2[i]).collect();
        let incl: Vec<f64> = (0..u).map(|i| 4.0 * tau * (1.0 - inp.w_full[i]) * inp.g2[i]).collect();
        let memo = if memo_enabled {
            Some((0..u).map(|_| Mutex::new(HashMap::new())).collect())
        } else {
            None
        };
        EvalCtx { inp, mode, rates, q_max, a1v, a2v, excl, incl, memo }
    }

    /// Enable or disable the per-client solve memo (enabled by
    /// default). Disabling is for A/B validation and the `bench-sched`
    /// uncached reference — results are bit-identical either way.
    /// `with_memo(true)` on an already-enabled ctx keeps the existing
    /// shards (no re-allocation).
    pub fn with_memo(mut self, enabled: bool) -> Self {
        if !enabled {
            self.memo = None;
        } else if self.memo.is_none() {
            let u = self.inp.params.num_clients;
            self.memo = Some((0..u).map(|_| Mutex::new(HashMap::new())).collect());
        }
        self
    }

    /// A worker-sized scratch for this round's dimensions.
    pub fn make_scratch(&self) -> EvalScratch {
        let u = self.inp.params.num_clients;
        EvalScratch {
            assigned: vec![None; u],
            rate: vec![0.0; u],
            participating: vec![false; u],
            w_round: vec![0.0; u],
        }
    }

    /// J0 of `chrom` — bit-identical to
    /// `super::evaluate_allocation(inp, chrom, mode).0` — with zero
    /// heap allocation.
    pub fn evaluate_j0(&self, chrom: &Chromosome, scratch: &mut EvalScratch) -> f64 {
        self.eval_inner(chrom, scratch, None)
    }

    /// `(J0, assignments)` of `chrom` — bit-identical to
    /// `super::evaluate_allocation(inp, chrom, mode)`.
    pub fn evaluate(
        &self,
        chrom: &Chromosome,
        scratch: &mut EvalScratch,
    ) -> (f64, Vec<Option<ClientDecision>>) {
        let mut assignments = vec![None; self.inp.params.num_clients];
        let j0 = self.eval_inner(chrom, scratch, Some(&mut assignments));
        (j0, assignments)
    }

    /// Per-client solve through the memo (or straight through when the
    /// memo is disabled). The solve runs outside the shard lock so
    /// workers only serialize on the (cheap) map accesses.
    fn solve_memo(&self, i: usize, w: f64, rate: f64) -> SolveMemo {
        let Some(shards) = &self.memo else {
            return self.solve(i, w, rate);
        };
        let key = (rate.to_bits(), w.to_bits());
        let poisoned = "solve-memo shard poisoned: a worker panicked holding the lock";
        if let Some(&hit) = shards[i].lock().expect(poisoned).get(&key) {
            return hit;
        }
        let solved = self.solve(i, w, rate);
        shards[i].lock().expect(poisoned).insert(key, solved);
        solved
    }

    /// The uncached inner solve: exactly the reference evaluator's
    /// per-client body (same `ClientCtx`, same energy call).
    fn solve(&self, i: usize, w: f64, rate: f64) -> SolveMemo {
        let inp = self.inp;
        let p = inp.params;
        let ctx = ClientCtx {
            d_i: inp.sizes[i],
            w_round: w,
            rate,
            theta_max: inp.theta_max[i],
            q_prev: inp.q_prev[i],
        };
        let dec = solver::solve_client(p, inp.queues.lambda2, &ctx, self.mode)?;
        let e = energy::client_energy(p, inp.sizes[i], dec.f, dec.q, rate);
        Some((dec, e))
    }

    /// The evaluation body. Mirrors [`super::evaluate_allocation`]
    /// statement for statement — any change there must be replayed
    /// here (the property test will catch a divergence).
    fn eval_inner(
        &self,
        chrom: &Chromosome,
        s: &mut EvalScratch,
        mut out: Option<&mut Vec<Option<ClientDecision>>>,
    ) -> f64 {
        let inp = self.inp;
        let p = inp.params;
        let (u, c) = (p.num_clients, p.num_channels);
        s.assigned.fill(None);
        s.rate.fill(0.0);
        s.participating.fill(false);
        s.w_round.fill(0.0);

        // Channel + rate per assigned client; feasibility gate at q = 1
        // (precomputed: q_max ≥ 1 ⇔ the reference's gate passes).
        for (ch, slot) in chrom.alloc.iter().enumerate() {
            if let Some(i) = *slot {
                if self.q_max[i * c + ch] >= 1 {
                    s.assigned[i] = Some(ch);
                    s.rate[i] = self.rates[i * c + ch];
                }
            }
        }

        // w_i^n over the feasibility-gated participants (ascending
        // client order, as the reference's iterator sum).
        let mut d_total = 0.0f64;
        for i in 0..u {
            if s.assigned[i].is_some() {
                d_total += inp.sizes[i];
            }
        }
        if d_total <= 0.0 {
            return f64::INFINITY;
        }

        // Per-client closed form through the memo; the C7 quant term
        // and ΣE accumulate inline — the same additions, in the same
        // ascending order, the reference performs in its separate
        // passes.
        let mut any = false;
        let mut quant = 0.0f64;
        let mut total_energy = 0.0f64;
        for i in 0..u {
            let Some(ch) = s.assigned[i] else { continue };
            let w = inp.sizes[i] / d_total;
            let rate = s.rate[i];
            let Some((dec, e)) = self.solve_memo(i, w, rate) else { continue };
            any = true;
            s.participating[i] = true;
            s.w_round[i] = w;
            quant += convergence::quant_term_client(p, w, inp.theta_max[i], dec.q);
            total_energy += e;
            if let Some(out) = out.as_deref_mut() {
                out[i] = Some(ClientDecision { channel: ch, q: Some(dec.q), f: dec.f, rate });
            }
        }
        if !any {
            return f64::INFINITY;
        }

        // C6 data term: per-client summands precomputed, scan order
        // preserved (the reference adds both summands per client in
        // ascending order; a non-participant's second summand is an
        // exact ±0.0 and is skipped).
        let mut data = 0.0f64;
        for i in 0..u {
            if s.participating[i] {
                data += self.incl[i];
                let w = s.w_round[i];
                data += self.a1v * w * inp.g2[i] + self.a2v * w * inp.sigma2[i];
            } else {
                data += self.excl[i];
            }
        }

        inp.queues.lambda1 * data + (inp.queues.lambda2 - p.eps2) * quant + p.v * total_energy
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::Fixture;
    use super::super::{evaluate_allocation, greedy_allocation};
    use super::*;
    use crate::util::rng::Rng;

    fn assert_same(
        (j_ref, a_ref): &(f64, Vec<Option<ClientDecision>>),
        (j_ctx, a_ctx): &(f64, Vec<Option<ClientDecision>>),
        label: &str,
    ) {
        assert_eq!(j_ref.to_bits(), j_ctx.to_bits(), "{label}: J0 {j_ref} vs {j_ctx}");
        assert_eq!(a_ref.len(), a_ctx.len(), "{label}");
        for (i, (x, y)) in a_ref.iter().zip(a_ctx.iter()).enumerate() {
            match (x, y) {
                (None, None) => {}
                (Some(x), Some(y)) => {
                    assert_eq!(x.channel, y.channel, "{label}: client {i} channel");
                    assert_eq!(x.q, y.q, "{label}: client {i} q");
                    assert_eq!(x.f.to_bits(), y.f.to_bits(), "{label}: client {i} f");
                    assert_eq!(x.rate.to_bits(), y.rate.to_bits(), "{label}: client {i} rate");
                }
                _ => panic!("{label}: client {i} participation diverged"),
            }
        }
    }

    #[test]
    fn matches_reference_bitwise_on_fixture() {
        for seed in [1u64, 2, 5, 9] {
            let fx = Fixture::new(seed);
            let inp = fx.inputs();
            for mode in [Case5Mode::Taylor, Case5Mode::Bisect] {
                let ctx = EvalCtx::new(&inp, mode);
                let mut scratch = ctx.make_scratch();
                let mut rng = Rng::seed_from(seed ^ 0xC0FFEE);
                let mut chroms = vec![greedy_allocation(&inp)];
                chroms.push(Chromosome { alloc: vec![None; 10] });
                for _ in 0..8 {
                    chroms.push(Chromosome::random(10, 10, &mut rng));
                }
                for (k, chrom) in chroms.iter().enumerate() {
                    let reference = evaluate_allocation(&inp, chrom, mode);
                    // Scratch is reused across all chromosomes — the
                    // reset must be complete.
                    let got = ctx.evaluate(chrom, &mut scratch);
                    assert_same(&reference, &got, &format!("seed {seed} chrom {k}"));
                    // Second pass hits the memo; must replay exactly.
                    let hit = ctx.evaluate(chrom, &mut scratch);
                    assert_same(&reference, &hit, &format!("seed {seed} chrom {k} (memo hit)"));
                    assert_eq!(
                        ctx.evaluate_j0(chrom, &mut scratch).to_bits(),
                        reference.0.to_bits(),
                        "seed {seed} chrom {k}: j0-only path"
                    );
                }
            }
        }
    }

    #[test]
    fn memo_disabled_matches_too() {
        let fx = Fixture::new(4);
        let inp = fx.inputs();
        let ctx = EvalCtx::new(&inp, Case5Mode::Taylor).with_memo(false);
        let mut scratch = ctx.make_scratch();
        let chrom = greedy_allocation(&inp);
        let reference = evaluate_allocation(&inp, &chrom, Case5Mode::Taylor);
        let got = ctx.evaluate(&chrom, &mut scratch);
        assert_same(&reference, &got, "memo off");
    }

    #[test]
    fn masked_matches_reference_bitwise() {
        // Availability masking must keep the cached/uncached
        // bit-identity contract: the mask zeroes q_max rows here and
        // gates the reference's assignment loop there — same exclusions,
        // same J0 bits.
        let fx = Fixture::new(7);
        let mut inp = fx.inputs();
        let mask: Vec<bool> = (0..10).map(|i| i % 3 != 0).collect();
        inp.avail = Some(&mask);
        let ctx = EvalCtx::new(&inp, Case5Mode::Taylor);
        let mut scratch = ctx.make_scratch();
        let mut rng = Rng::seed_from(123);
        let mut chroms = vec![greedy_allocation(&inp)];
        for _ in 0..8 {
            chroms.push(Chromosome::random(10, 10, &mut rng));
        }
        for (k, chrom) in chroms.iter().enumerate() {
            let reference = evaluate_allocation(&inp, chrom, Case5Mode::Taylor);
            let got = ctx.evaluate(chrom, &mut scratch);
            assert_same(&reference, &got, &format!("masked chrom {k}"));
            for (i, a) in got.1.iter().enumerate() {
                assert!(mask[i] || a.is_none(), "offline client {i} scheduled");
            }
        }
    }

    #[test]
    fn empty_allocation_infinite() {
        let fx = Fixture::new(3);
        let inp = fx.inputs();
        let ctx = EvalCtx::new(&inp, Case5Mode::Bisect);
        let mut scratch = ctx.make_scratch();
        let chrom = Chromosome { alloc: vec![None; 10] };
        let (j0, assigns) = ctx.evaluate(&chrom, &mut scratch);
        assert!(j0.is_infinite());
        assert!(assigns.iter().all(|a| a.is_none()));
    }

    #[test]
    fn shared_across_threads() {
        // The ctx is shared immutably by GA fitness workers; concurrent
        // evaluation through the memo must equal the serial reference.
        let fx = Fixture::new(6);
        let inp = fx.inputs();
        let ctx = EvalCtx::new(&inp, Case5Mode::Taylor);
        let mut rng = Rng::seed_from(77);
        let chroms: Vec<Chromosome> =
            (0..32).map(|_| Chromosome::random(10, 10, &mut rng)).collect();
        let want: Vec<u64> = chroms
            .iter()
            .map(|c| evaluate_allocation(&inp, c, Case5Mode::Taylor).0.to_bits())
            .collect();
        let mut scratches: Vec<EvalScratch> = (0..4).map(|_| ctx.make_scratch()).collect();
        let got: Vec<u64> = crate::util::threadpool::parallel_map_with(
            &chroms,
            &mut scratches,
            |_, c, s| ctx.evaluate_j0(c, s).to_bits(),
        );
        assert_eq!(want, got);
    }
}
