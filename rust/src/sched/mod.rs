//! Scheduler interface + the QCCF decision pipeline (paper §V).
//!
//! A [`Scheduler`] sees the round's channel draw and client state and
//! returns, per client, whether it participates and with which channel,
//! quantization level and CPU frequency. The FL server then *realizes*
//! the decision (trains, quantizes, checks the latency budget, accounts
//! energy), so over-optimistic baselines pay for their timeouts exactly
//! as in the paper's §VI analysis.
//!
//! Two evaluation paths score a channel allocation under the QCCF inner
//! solver: [`evaluate_allocation`] — the allocation-per-call reference
//! — and the cached [`EvalCtx`] subsystem ([`ctx`]) the GA fitness
//! loop runs on, which is **bit-identical** to the reference by
//! contract (see `ctx`'s module docs and `tests/proptest_decision.rs`).
//! A third, scenario-gated path ([`classes`]) trades exactness for
//! scale: the GA searches over client *equivalence classes* and channel
//! pools, and the winning expansion is re-scored through the exact
//! reference before anything reaches the trace.
//!
//! Decision-stage wall time is *not* measured here: the server brackets
//! the whole stage with a `Decide` span ([`crate::obs::spans`]), so the
//! scheduler math stays free of wall-clock reads (detlint rule R2).

// Decision-stage code runs under worker pools where an anonymous
// `unwrap()` panic is hard to attribute; scope clippy's unwrap ban to
// this subsystem (see fl/mod.rs for the policy note).
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

pub mod classes;
pub mod ctx;
pub mod qccf;

pub use classes::{decision_classes_default, ClassEvalCtx, ClassPlan, ClassingConfig};
pub use ctx::{EvalCtx, EvalScratch};

use crate::config::SystemParams;
use crate::convergence;
use crate::energy;
use crate::ga::Chromosome;
use crate::lyapunov::Queues;
use crate::solver::{self, Case5Mode, ClientCtx};
use crate::wireless::ChannelState;

/// Everything a scheduler may look at when deciding round n.
pub struct RoundInputs<'a> {
    /// System parameters.
    pub params: &'a SystemParams,
    /// Communication-round index n.
    pub round: usize,
    /// This round's channel realization.
    pub channels: &'a ChannelState,
    /// D_i for every client.
    pub sizes: &'a [f64],
    /// w_i = D_i / ΣD over **all** clients.
    pub w_full: &'a [f64],
    /// Ĝ_i² estimates.
    pub g2: &'a [f64],
    /// σ̂_i² estimates.
    pub sigma2: &'a [f64],
    /// θ^max estimates (from the current global model).
    pub theta_max: &'a [f64],
    /// Last-participation q per client (Case-5 anchor).
    pub q_prev: &'a [f64],
    /// The virtual queues λ1/λ2.
    pub queues: &'a Queues,
    /// Decide-time availability mask from the churn layer
    /// (`fl::avail`): `None` = every client is a candidate (the legacy
    /// engine); `Some(mask)` removes `mask[i] = false` clients from the
    /// candidate set of **every** path — the reference evaluator, the
    /// cached [`EvalCtx`], the greedy backstop, and the classed plan.
    pub avail: Option<&'a [bool]>,
}

impl RoundInputs<'_> {
    /// Whether client `i` may be scheduled this round.
    pub fn is_available(&self, i: usize) -> bool {
        self.avail.map_or(true, |a| a[i])
    }
}

/// Per-client intended decision.
#[derive(Clone, Copy, Debug)]
pub struct ClientDecision {
    /// Allocated OFDMA channel index.
    pub channel: usize,
    /// Quantization level; `None` = raw 32-bit upload (No-Quantization).
    pub q: Option<u32>,
    /// CPU frequency.
    pub f: f64,
    /// Rate of the allocated channel (bit/s).
    pub rate: f64,
}

/// The round's decision vector + diagnostics.
#[derive(Clone, Debug, Default)]
pub struct RoundDecision {
    /// Per-client decision (`None` = not scheduled this round).
    pub assignments: Vec<Option<ClientDecision>>,
    /// Objective value J0 the scheduler believed it achieved (if any).
    pub j0: f64,
    /// GA fitness evaluations (0 for non-GA schedulers).
    pub evals: usize,
    /// When set, the server does not drop late uploads (the
    /// No-Quantization baseline has no latency design at all — under
    /// Table I its raw payload exceeds T^max by construction, and the
    /// paper still shows it converging, just at maximal energy).
    pub deadline_exempt: bool,
}

/// A per-round decision policy.
pub trait Scheduler {
    /// Stable algorithm name (trace/CSV key).
    fn name(&self) -> &'static str;
    /// Decide round n's participation, channels, levels and frequencies.
    fn decide(&mut self, inp: &RoundInputs<'_>) -> RoundDecision;
    /// Position of the scheduler's private RNG stream, if it owns one
    /// (the GA-based schedulers do; stateless policies return `None`).
    /// Every other input to [`Scheduler::decide`] arrives through
    /// [`RoundInputs`], so this stream position is the scheduler's
    /// *entire* resumable state — the checkpoint subsystem captures it
    /// and reinstalls it via [`Scheduler::restore_rng_state`].
    fn rng_state(&self) -> Option<crate::util::rng::RngState> {
        None
    }
    /// Reposition the scheduler's RNG stream from a captured state
    /// (no-op for stateless policies).
    fn restore_rng_state(&mut self, _state: &crate::util::rng::RngState) {}
}

/// Evaluate a channel allocation under the QCCF inner solver:
/// participant set from C2, w_i^n from participating D_i, per-client
/// closed-form (q*, f*), then J0 = (λ1−ε1)·C6-term + (λ2−ε2)·C7-term +
/// V·ΣE (eq. (27)). Infeasible chromosomes (no feasible participant)
/// return `f64::INFINITY`.
///
/// This is the *uncached reference*: it reallocates and re-derives
/// everything per call. The decision hot path ([`qccf`]'s GA fitness
/// loop) runs the bit-identical cached form instead — see [`EvalCtx`].
/// Any semantic change here must be replayed in `ctx::eval_inner`
/// (`tests/proptest_decision.rs` pins the equivalence).
///
/// Semantics note, pinned by
/// `tests::w_round_uses_feasibility_gated_data_mass`: `d_total` — the
/// w_i^n denominator — is the data mass of every client that passes
/// the q = 1 feasibility gate, *before* the per-client solve runs. A
/// client the inner solver declined would still count in `d_total`
/// and deflate the surviving participants' weights. (With the current
/// closed form a gated client is never declined — `solve_brute`
/// backstops the KKT cases — so the sets coincide in practice; see
/// docs/ARCHITECTURE.md, "Decision stage".)
pub fn evaluate_allocation(
    inp: &RoundInputs<'_>,
    chrom: &Chromosome,
    mode: Case5Mode,
) -> (f64, Vec<Option<ClientDecision>>) {
    let p = inp.params;
    let u = p.num_clients;
    let mut assignments: Vec<Option<ClientDecision>> = vec![None; u];

    // Channel + rate per assigned client; feasibility gate at q=1.
    let mut rate = vec![0.0f64; u];
    let mut assigned: Vec<Option<usize>> = vec![None; u];
    for (ch, slot) in chrom.alloc.iter().enumerate() {
        if let Some(i) = *slot {
            // Availability gates ahead of feasibility: an offline
            // client is no candidate at all, on any path.
            if !inp.is_available(i) {
                continue;
            }
            let r = inp.channels.rate(i, ch);
            if solver::q_max_feasible(p, inp.sizes[i], r).is_some() {
                assigned[i] = Some(ch);
                rate[i] = r;
            }
        }
    }

    // w_i^n over the feasible participants.
    let d_total: f64 = (0..u).filter(|&i| assigned[i].is_some()).map(|i| inp.sizes[i]).sum();
    if d_total <= 0.0 {
        return (f64::INFINITY, assignments);
    }

    let mut participating = vec![false; u];
    let mut w_round = vec![0.0f64; u];
    let mut theta_eff = vec![0.0f64; u];
    let mut qs: Vec<Option<u32>> = vec![None; u];
    let mut total_energy = 0.0;
    for i in 0..u {
        let Some(ch) = assigned[i] else { continue };
        let w = inp.sizes[i] / d_total;
        let ctx = ClientCtx {
            d_i: inp.sizes[i],
            w_round: w,
            rate: rate[i],
            theta_max: inp.theta_max[i],
            q_prev: inp.q_prev[i],
        };
        let Some(dec) = solver::solve_client(p, inp.queues.lambda2, &ctx, mode) else {
            continue;
        };
        participating[i] = true;
        w_round[i] = w;
        theta_eff[i] = inp.theta_max[i];
        qs[i] = Some(dec.q);
        total_energy += energy::client_energy(p, inp.sizes[i], dec.f, dec.q, rate[i]);
        assignments[i] = Some(ClientDecision { channel: ch, q: Some(dec.q), f: dec.f, rate: rate[i] });
    }
    if !participating.iter().any(|&a| a) {
        return (f64::INFINITY, assignments);
    }

    let data = convergence::data_term(p, &participating, inp.w_full, &w_round, inp.g2, inp.sigma2);
    let quant = convergence::quant_term(p, &w_round, &theta_eff, &qs);
    // Soundness correction to the paper's eq. (26): standard
    // drift-plus-penalty yields coefficient λ1 on the C6 arrival, not
    // (λ1 − ε1) — the paper's form *rewards* constraint arrivals (i.e.
    // rewards excluding clients) whenever λ1 < ε1, which deadlocks
    // scheduling. We keep the paper's (λ2 − ε2) inside the per-client
    // KKT solver because eq. (41) is derived with it and its λ2 < ε2
    // regime (q → 1) is benign. See DESIGN.md §Corrections.
    let j0 = inp.queues.lambda1 * data
        + (inp.queues.lambda2 - p.eps2) * quant
        + p.v * total_energy;
    (j0, assignments)
}

/// Greedy rate-maximizing channel assignment (used by the non-GA
/// baselines): clients in descending best-rate order pick their best
/// remaining channel.
pub fn greedy_allocation(inp: &RoundInputs<'_>) -> Chromosome {
    let p = inp.params;
    let (u, c) = (p.num_clients, p.num_channels);
    // Each client's best rate once — O(U·C) — instead of recomputing
    // the C-wide max inside the sort comparator (O(U log U · C)).
    let best_rate: Vec<f64> = (0..u)
        .map(|i| (0..c).map(|ch| inp.channels.rate(i, ch)).fold(0.0, f64::max))
        .collect();
    let mut order: Vec<usize> = (0..u).collect();
    // total_cmp instead of partial_cmp().unwrap(): the max-fold above
    // absorbs NaN draws so best_rate is always comparable today, but
    // the sort must stay panic-free if that invariant ever moves —
    // and for finite rates the descending order is identical.
    order.sort_by(|&a, &b| best_rate[b].total_cmp(&best_rate[a]));
    let mut taken = vec![false; c];
    let mut taken_count = 0usize;
    let mut alloc = vec![None; c];
    for &i in &order {
        if !inp.is_available(i) {
            continue;
        }
        // Once every channel is held, the remaining U − C clients can
        // only scan fully-taken channels and assign nothing — at the
        // stress-100k scale (U = 10⁵, C = 64) that tail used to cost
        // O(U·C) for zero work. The early exit skips exactly those
        // no-op iterations, so the allocation is unchanged.
        if taken_count == c {
            break;
        }
        let mut best: Option<(usize, f64)> = None;
        for ch in 0..c {
            if !taken[ch] {
                let r = inp.channels.rate(i, ch);
                // `|| br.is_nan()`: a NaN-rate channel must never be
                // *held* against a later usable one (`r > NaN` is
                // false for every r, so a NaN first pick would stick,
                // burn the channel, and then fail the q = 1 gate).
                // For finite rates the predicate is unchanged.
                if best.map(|(_, br)| r > br || br.is_nan()).unwrap_or(true) {
                    best = Some((ch, r));
                }
            }
        }
        if let Some((ch, _)) = best {
            taken[ch] = true;
            taken_count += 1;
            alloc[ch] = Some(i);
        }
    }
    Chromosome { alloc }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::wireless::ChannelModel;

    pub(crate) struct Fixture {
        pub params: SystemParams,
        pub channels: ChannelState,
        pub sizes: Vec<f64>,
        pub w_full: Vec<f64>,
        pub g2: Vec<f64>,
        pub sigma2: Vec<f64>,
        pub theta_max: Vec<f64>,
        pub q_prev: Vec<f64>,
        pub queues: Queues,
    }

    impl Fixture {
        pub fn new(seed: u64) -> Fixture {
            let params = SystemParams::femnist_small();
            let mut rng = Rng::seed_from(seed);
            let model = ChannelModel::new(&params, &mut rng);
            let channels = model.draw(&mut rng);
            let sizes: Vec<f64> =
                (0..params.num_clients).map(|_| rng.gaussian(1200.0, 150.0).max(64.0)).collect();
            let total: f64 = sizes.iter().sum();
            let w_full = sizes.iter().map(|d| d / total).collect();
            let mut queues = Queues::new();
            queues.update(&params, params.eps1 + 30.0, params.eps2 + 1.0);
            Fixture {
                params,
                channels,
                sizes,
                w_full,
                g2: vec![2.0; 10],
                sigma2: vec![0.5; 10],
                theta_max: vec![0.4; 10],
                q_prev: vec![6.0; 10],
                queues,
            }
        }

        pub fn inputs(&self) -> RoundInputs<'_> {
            RoundInputs {
                params: &self.params,
                round: 1,
                channels: &self.channels,
                sizes: &self.sizes,
                w_full: &self.w_full,
                g2: &self.g2,
                sigma2: &self.sigma2,
                theta_max: &self.theta_max,
                q_prev: &self.q_prev,
                queues: &self.queues,
                avail: None,
            }
        }
    }

    #[test]
    fn greedy_allocation_valid_and_full() {
        let fx = Fixture::new(1);
        let chrom = greedy_allocation(&fx.inputs());
        assert!(chrom.is_valid(10));
        // U = C = 10 ⇒ everyone gets a channel.
        assert_eq!(chrom.participants(10).iter().filter(|&&a| a).count(), 10);
    }

    #[test]
    fn evaluate_allocation_finite_for_reasonable_chromosome() {
        let fx = Fixture::new(2);
        let inp = fx.inputs();
        let chrom = greedy_allocation(&inp);
        let (j0, assigns) = evaluate_allocation(&inp, &chrom, Case5Mode::Bisect);
        assert!(j0.is_finite());
        let n = assigns.iter().flatten().count();
        assert!(n >= 5, "only {n} feasible participants");
        for d in assigns.iter().flatten() {
            assert!(d.q.unwrap() >= 1);
            assert!(d.f >= fx.params.f_min && d.f <= fx.params.f_max);
        }
    }

    #[test]
    fn all_available_mask_matches_no_mask_bitwise() {
        // The churn-off pin at the decision layer: an all-true mask
        // must be indistinguishable — bit for bit — from no mask.
        let fx = Fixture::new(2);
        let mut inp = fx.inputs();
        let chrom = greedy_allocation(&inp);
        let (j_none, a_none) = evaluate_allocation(&inp, &chrom, Case5Mode::Bisect);
        let g_none = greedy_allocation(&inp);
        let mask = vec![true; 10];
        inp.avail = Some(&mask);
        let (j_mask, a_mask) = evaluate_allocation(&inp, &chrom, Case5Mode::Bisect);
        assert_eq!(j_none.to_bits(), j_mask.to_bits());
        assert_eq!(format!("{a_none:?}"), format!("{a_mask:?}"));
        assert_eq!(greedy_allocation(&inp).alloc, g_none.alloc);
    }

    #[test]
    fn unavailable_clients_never_scheduled() {
        let fx = Fixture::new(5);
        let mut inp = fx.inputs();
        let mut mask = vec![true; 10];
        mask[2] = false;
        mask[7] = false;
        inp.avail = Some(&mask);
        let greedy = greedy_allocation(&inp);
        for (ch, slot) in greedy.alloc.iter().enumerate() {
            assert!(*slot != Some(2) && *slot != Some(7), "channel {ch} seats an offline client");
        }
        // Even a chromosome that *names* an offline client must not
        // seat it — the gate runs inside the evaluator.
        let chrom = Chromosome { alloc: (0..10).map(Some).collect() };
        let (j0, assigns) = evaluate_allocation(&inp, &chrom, Case5Mode::Bisect);
        assert!(j0.is_finite());
        assert!(assigns[2].is_none() && assigns[7].is_none());
        assert!(assigns.iter().flatten().count() >= 5);
    }

    #[test]
    fn empty_allocation_infeasible() {
        let fx = Fixture::new(3);
        let inp = fx.inputs();
        let chrom = Chromosome { alloc: vec![None; 10] };
        let (j0, _) = evaluate_allocation(&inp, &chrom, Case5Mode::Bisect);
        assert!(j0.is_infinite());
    }

    #[test]
    fn better_channels_lower_j0() {
        // Degrading every rate must not improve (lower) the objective.
        let fx = Fixture::new(4);
        let inp = fx.inputs();
        let chrom = greedy_allocation(&inp);
        let (j_good, _) = evaluate_allocation(&inp, &chrom, Case5Mode::Bisect);

        let mut weak = Fixture::new(4);
        let rates: Vec<f64> = (0..100)
            .map(|k| fx.channels.rate(k / 10, k % 10) * 0.55)
            .collect();
        weak.channels = ChannelState::from_rates(10, 10, rates);
        let inp_weak = weak.inputs();
        let (j_bad, _) = evaluate_allocation(&inp_weak, &chrom, Case5Mode::Bisect);
        assert!(j_bad >= j_good, "j_bad={j_bad} j_good={j_good}");
    }

    #[test]
    fn greedy_allocation_survives_degenerate_rates() {
        // Equal, zero and NaN rates must neither panic the sort nor
        // assign a client twice — and a NaN-rate channel must not be
        // held against a later usable one.
        let mut fx = Fixture::new(6);
        let mut rates = vec![7e6f64; 100];
        for ch in 0..10 {
            rates[3 * 10 + ch] = 0.0; // client 3: dead everywhere
            if ch < 9 {
                rates[5 * 10 + ch] = f64::NAN; // client 5: corrupt draws...
            }
        }
        // ...but a healthy channel 9 — the pick must land there, not
        // stick on the first untaken NaN channel.
        fx.channels = crate::wireless::ChannelState::from_rates(10, 10, rates);
        let chrom = greedy_allocation(&fx.inputs());
        assert!(chrom.is_valid(10));
        assert_eq!(chrom.alloc[9], Some(5), "client 5 must take its only usable channel");
    }

    #[test]
    fn w_round_uses_feasibility_gated_data_mass() {
        // Pin of a documented semantics quirk (docs/ARCHITECTURE.md,
        // "Decision stage"): d_total — the w_i^n denominator — is the
        // data mass of the clients that pass the q = 1 feasibility
        // gate, settled *before* the per-client solve runs. The test
        // reconstructs J0 from those gated-set weights with the public
        // solver/convergence pieces and requires bit equality; a client
        // failing the gate (client 0 here, 1 bit/s) is excluded, while
        // every gated client counts whether or not the inner solver
        // would later decline it (today it never does — `solve_brute`
        // backstops the KKT cases — which is exactly why this pin, not
        // a behavior change, records the contract).
        let mut fx = Fixture::new(8);
        let mut rates = vec![25e6f64; 100];
        for ch in 0..10 {
            rates[ch] = 1.0; // client 0 fails the q = 1 gate everywhere
        }
        fx.channels = crate::wireless::ChannelState::from_rates(10, 10, rates);
        let inp = fx.inputs();
        let p = &fx.params;
        // Identity allocation: client i on channel i.
        let chrom = Chromosome { alloc: (0..10).map(Some).collect() };
        let (j0, assigns) = evaluate_allocation(&inp, &chrom, Case5Mode::Bisect);
        assert!(assigns[0].is_none(), "1 bit/s client must fail the gate");
        assert!(j0.is_finite());

        // Reconstruction under the documented semantics.
        let gated: Vec<usize> =
            (0..10).filter(|&i| solver::q_max_feasible(p, fx.sizes[i], 25e6).is_some()).collect();
        assert_eq!(gated, (1..10).collect::<Vec<_>>());
        let d_total: f64 = gated.iter().map(|&i| fx.sizes[i]).sum();
        let mut participating = vec![false; 10];
        let mut w_round = vec![0.0f64; 10];
        let mut theta_eff = vec![0.0f64; 10];
        let mut qs: Vec<Option<u32>> = vec![None; 10];
        let mut total_energy = 0.0;
        for &i in &gated {
            let w = fx.sizes[i] / d_total;
            let cctx = ClientCtx {
                d_i: fx.sizes[i],
                w_round: w,
                rate: 25e6,
                theta_max: fx.theta_max[i],
                q_prev: fx.q_prev[i],
            };
            let dec = solver::solve_client(p, fx.queues.lambda2, &cctx, Case5Mode::Bisect)
                .expect("gated client declined — the quirk became observable; update the docs");
            participating[i] = true;
            w_round[i] = w;
            theta_eff[i] = fx.theta_max[i];
            qs[i] = Some(dec.q);
            total_energy += energy::client_energy(p, fx.sizes[i], dec.f, dec.q, 25e6);
            assert_eq!(assigns[i].unwrap().q, Some(dec.q));
        }
        let data = convergence::data_term(p, &participating, &fx.w_full, &w_round, &fx.g2, &fx.sigma2);
        let quant = convergence::quant_term(p, &w_round, &theta_eff, &qs);
        let want = fx.queues.lambda1 * data
            + (fx.queues.lambda2 - p.eps2) * quant
            + p.v * total_energy;
        assert_eq!(want.to_bits(), j0.to_bits(), "w_round denominator drifted from the gated set");
    }
}
