//! The paper's QCCF scheduler: genetic algorithm over channel
//! allocations (P3.1, §V-D) with the closed-form KKT solver as the inner
//! evaluation (P3.2″, §V-C).

use super::{evaluate_allocation, RoundDecision, RoundInputs, Scheduler};
use crate::ga::{self, GaParams};
use crate::solver::Case5Mode;
use crate::util::rng::Rng;

/// The QCCF scheduler (paper Algorithm 1 wrapped around the
/// closed-form per-client solver).
pub struct QccfScheduler {
    /// GA hyperparameters for the channel-allocation search.
    pub ga: GaParams,
    /// Case-5 solver mode (paper Taylor step vs exact bisection).
    pub case5: Case5Mode,
    rng: Rng,
}

impl QccfScheduler {
    /// Scheduler with default GA budget and the paper's Taylor mode.
    pub fn new(seed: u64) -> QccfScheduler {
        QccfScheduler { ga: GaParams::default(), case5: Case5Mode::Taylor, rng: Rng::seed_from(seed) }
    }

    /// Replace the GA hyperparameters.
    pub fn with_ga(mut self, ga: GaParams) -> Self {
        self.ga = ga;
        self
    }

    /// Select the Case-5 solver mode.
    pub fn with_case5(mut self, mode: Case5Mode) -> Self {
        self.case5 = mode;
        self
    }

    /// Fan the GA fitness evaluations out over `threads` workers (the
    /// per-candidate closed-form solve × U clients is the decision hot
    /// path). Deterministic for any value.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.ga.threads = threads.max(1);
        self
    }
}

impl Scheduler for QccfScheduler {
    fn name(&self) -> &'static str {
        "qccf"
    }

    fn decide(&mut self, inp: &RoundInputs<'_>) -> RoundDecision {
        let p = inp.params;
        let mode = self.case5;
        // Seed the population with the greedy rate-maximizing allocation
        // so Algorithm 1 never falls below the trivial policy.
        let greedy = super::greedy_allocation(inp);
        // Fitness memoization: GA populations converge, so late
        // generations re-evaluate the same chromosomes; the inner
        // closed-form solve × U clients is the decision hot path
        // (EXPERIMENTS.md §Perf) and duplicates are pure waste. The
        // mutex makes the cache shareable across the parallel fitness
        // workers; two workers may race to fill the same key, but J0 is
        // a pure function of the chromosome, so last-write-wins is
        // value-identical.
        let cache: std::sync::Mutex<std::collections::HashMap<Vec<Option<usize>>, f64>> =
            std::sync::Mutex::new(std::collections::HashMap::new());
        let outcome = ga::optimize_with_seeds(
            p.num_channels,
            p.num_clients,
            &self.ga,
            &mut self.rng,
            std::slice::from_ref(&greedy),
            |c| {
                if let Some(&hit) = cache.lock().unwrap().get(&c.alloc) {
                    return hit;
                }
                let j0 = evaluate_allocation(inp, c, mode).0;
                cache.lock().unwrap().insert(c.alloc.clone(), j0);
                j0
            },
        );
        let (j0, assignments) = evaluate_allocation(inp, &outcome.best, mode);
        RoundDecision { assignments, j0, evals: outcome.evals, deadline_exempt: false }
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::Fixture;
    use super::super::{evaluate_allocation, greedy_allocation, Scheduler};
    use super::*;

    #[test]
    fn qccf_beats_or_matches_greedy() {
        let fx = Fixture::new(11);
        let inp = fx.inputs();
        let greedy = greedy_allocation(&inp);
        let (j_greedy, _) = evaluate_allocation(&inp, &greedy, Case5Mode::Taylor);
        let mut sched = QccfScheduler::new(42);
        let dec = sched.decide(&inp);
        assert!(dec.j0.is_finite());
        assert!(
            dec.j0 <= j_greedy * (1.0 + 1e-9) || dec.j0 <= j_greedy + 1e-9,
            "GA {j0} worse than greedy {j_greedy}",
            j0 = dec.j0
        );
        assert!(dec.evals > 0);
    }

    #[test]
    fn qccf_decisions_within_bounds() {
        let fx = Fixture::new(12);
        let inp = fx.inputs();
        let mut sched = QccfScheduler::new(7);
        let dec = sched.decide(&inp);
        let mut used = std::collections::BTreeSet::new();
        for d in dec.assignments.iter().flatten() {
            assert!(used.insert(d.channel), "channel reuse (C3 violation)");
            assert!(d.q.unwrap() >= 1);
            assert!(d.f >= fx.params.f_min && d.f <= fx.params.f_max);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let fx = Fixture::new(13);
        let inp = fx.inputs();
        let d1 = QccfScheduler::new(5).decide(&inp);
        let d2 = QccfScheduler::new(5).decide(&inp);
        assert_eq!(d1.j0, d2.j0);
    }

    #[test]
    fn parallel_fitness_same_decision() {
        let fx = Fixture::new(14);
        let inp = fx.inputs();
        let serial = QccfScheduler::new(5).decide(&inp);
        let parallel = QccfScheduler::new(5).with_threads(8).decide(&inp);
        assert_eq!(serial.j0, parallel.j0);
        assert_eq!(serial.evals, parallel.evals);
        let chans = |d: &crate::sched::RoundDecision| -> Vec<Option<usize>> {
            d.assignments.iter().map(|a| a.map(|x| x.channel)).collect()
        };
        assert_eq!(chans(&serial), chans(&parallel));
    }
}
