//! The paper's QCCF scheduler: genetic algorithm over channel
//! allocations (P3.1, §V-D) with the closed-form KKT solver as the inner
//! evaluation (P3.2″, §V-C).

use super::{classes, ctx, RoundDecision, RoundInputs, Scheduler};
use crate::ga::GaParams;
use crate::solver::Case5Mode;
use crate::util::rng::Rng;

use classes::ClassingConfig;

/// The QCCF scheduler (paper Algorithm 1 wrapped around the
/// closed-form per-client solver).
pub struct QccfScheduler {
    /// GA hyperparameters for the channel-allocation search.
    pub ga: GaParams,
    /// Case-5 solver mode (paper Taylor step vs exact bisection).
    pub case5: Case5Mode,
    /// Decision-stage caching: the per-round [`super::EvalCtx`] solve
    /// memo plus the GA fitness cache. On by default;
    /// `QCCF_DECISION_CACHE=0` in the environment or
    /// [`QccfScheduler::with_cache`] disables both for A/B validation —
    /// decisions and traces are bit-identical either way (see
    /// `sched::ctx` and `tests/integration_fl.rs`).
    pub cache: bool,
    /// Hierarchical class-based scheduling (`None` = exact per-client
    /// GA, the default). `Some(cfg)` switches the decide body to
    /// [`classes::decide_with_classes`]: the GA searches class × pool
    /// chromosomes and the winner is re-scored exactly — an
    /// *approximation* of the optimum, not of the reported values (see
    /// `sched::classes`). Scenario-gated (`[train] classes = true`)
    /// with the `QCCF_DECISION_CLASSES=0` kill switch.
    pub classes: Option<ClassingConfig>,
    rng: Rng,
}

impl QccfScheduler {
    /// Scheduler with default GA budget and the paper's Taylor mode.
    pub fn new(seed: u64) -> QccfScheduler {
        QccfScheduler {
            ga: GaParams::default(),
            case5: Case5Mode::Taylor,
            cache: ctx::decision_cache_default(),
            classes: None,
            rng: Rng::seed_from(seed),
        }
    }

    /// Enable class-based scheduling with `cfg`, honoring the
    /// process-wide `QCCF_DECISION_CLASSES=0` kill switch (under the
    /// kill switch this is a no-op and the exact path keeps running).
    pub fn with_classes(mut self, cfg: ClassingConfig) -> Self {
        self.classes = classes::decision_classes_default().then_some(cfg);
        self
    }

    /// Set the classing mode directly, bypassing the environment gate
    /// (A/B validation and tests; `None` restores the exact path).
    pub fn with_classes_override(mut self, classes: Option<ClassingConfig>) -> Self {
        self.classes = classes;
        self
    }

    /// Enable or disable the decision-stage caches (default: on).
    pub fn with_cache(mut self, enabled: bool) -> Self {
        self.cache = enabled;
        self
    }

    /// Replace the GA hyperparameters.
    pub fn with_ga(mut self, ga: GaParams) -> Self {
        self.ga = ga;
        self
    }

    /// Select the Case-5 solver mode.
    pub fn with_case5(mut self, mode: Case5Mode) -> Self {
        self.case5 = mode;
        self
    }

    /// Fan the GA fitness evaluations out over `threads` workers (the
    /// per-candidate closed-form solve × U clients is the decision hot
    /// path). Deterministic for any value.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.ga.threads = threads.max(1);
        self
    }
}

impl Scheduler for QccfScheduler {
    fn name(&self) -> &'static str {
        "qccf"
    }

    fn decide(&mut self, inp: &RoundInputs<'_>) -> RoundDecision {
        // Class-based path: the GA runs over class × pool chromosomes
        // and the winner (or the greedy backstop, if better) is scored
        // through the exact reference evaluator — see sched::classes.
        if let Some(cfg) = self.classes {
            let (j0, assignments, evals) = classes::decide_with_classes(
                inp,
                self.case5,
                &self.ga,
                &mut self.rng,
                cfg,
                self.cache,
            );
            return RoundDecision { assignments, j0, evals, deadline_exempt: false };
        }
        // Seed the population with the greedy rate-maximizing allocation
        // so Algorithm 1 never falls below the trivial policy. The
        // shared decide body (sched::ctx::decide_with_ga) runs the
        // decision hot path: per-round EvalCtx (U×C rate/q_max table +
        // convergence precompute + exact-key solve memo), per-worker
        // reusable scratch, and the GA's own fitness cache (elites and
        // duplicate offspring are never re-scored) — all bit-identical
        // to the uncached reference.
        let greedy = super::greedy_allocation(inp);
        let (j0, assignments, evals) = ctx::decide_with_ga(
            inp,
            self.case5,
            &self.ga,
            &mut self.rng,
            std::slice::from_ref(&greedy),
            self.cache,
        );
        RoundDecision { assignments, j0, evals, deadline_exempt: false }
    }

    // The GA stream is the scheduler's only mutable state (GaParams /
    // case5 / cache / classes are run configuration; the per-round
    // EvalCtx / ClassEvalCtx and fitness caches live and die inside
    // one decide call), so the
    // checkpoint subsystem can resume QCCF from this position alone.
    fn rng_state(&self) -> Option<crate::util::rng::RngState> {
        Some(self.rng.state())
    }

    fn restore_rng_state(&mut self, state: &crate::util::rng::RngState) {
        self.rng.restore(state);
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::Fixture;
    use super::super::{evaluate_allocation, greedy_allocation, Scheduler};
    use super::*;

    #[test]
    fn qccf_beats_or_matches_greedy() {
        let fx = Fixture::new(11);
        let inp = fx.inputs();
        let greedy = greedy_allocation(&inp);
        let (j_greedy, _) = evaluate_allocation(&inp, &greedy, Case5Mode::Taylor);
        let mut sched = QccfScheduler::new(42);
        let dec = sched.decide(&inp);
        assert!(dec.j0.is_finite());
        assert!(
            dec.j0 <= j_greedy * (1.0 + 1e-9) || dec.j0 <= j_greedy + 1e-9,
            "GA {j0} worse than greedy {j_greedy}",
            j0 = dec.j0
        );
        assert!(dec.evals > 0);
    }

    #[test]
    fn qccf_decisions_within_bounds() {
        let fx = Fixture::new(12);
        let inp = fx.inputs();
        let mut sched = QccfScheduler::new(7);
        let dec = sched.decide(&inp);
        let mut used = std::collections::BTreeSet::new();
        for d in dec.assignments.iter().flatten() {
            assert!(used.insert(d.channel), "channel reuse (C3 violation)");
            assert!(d.q.unwrap() >= 1);
            assert!(d.f >= fx.params.f_min && d.f <= fx.params.f_max);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let fx = Fixture::new(13);
        let inp = fx.inputs();
        let d1 = QccfScheduler::new(5).decide(&inp);
        let d2 = QccfScheduler::new(5).decide(&inp);
        assert_eq!(d1.j0, d2.j0);
    }

    #[test]
    fn parallel_fitness_same_decision() {
        let fx = Fixture::new(14);
        let inp = fx.inputs();
        let serial = QccfScheduler::new(5).decide(&inp);
        let parallel = QccfScheduler::new(5).with_threads(8).decide(&inp);
        assert_eq!(serial.j0, parallel.j0);
        assert_eq!(serial.evals, parallel.evals);
        let chans = |d: &crate::sched::RoundDecision| -> Vec<Option<usize>> {
            d.assignments.iter().map(|a| a.map(|x| x.channel)).collect()
        };
        assert_eq!(chans(&serial), chans(&parallel));
    }

    #[test]
    fn cache_off_decision_bit_identical() {
        // The decision-stage caches (solve memo + GA fitness cache)
        // must not move a single bit of the decision — they may only
        // reduce `evals` (evaluator invocations).
        let fx = Fixture::new(15);
        let inp = fx.inputs();
        let on = QccfScheduler::new(9).with_cache(true).decide(&inp);
        let off = QccfScheduler::new(9).with_cache(false).decide(&inp);
        assert_eq!(on.j0.to_bits(), off.j0.to_bits());
        assert_eq!(on.assignments.len(), off.assignments.len());
        for (a, b) in on.assignments.iter().zip(&off.assignments) {
            match (a, b) {
                (None, None) => {}
                (Some(a), Some(b)) => {
                    assert_eq!(a.channel, b.channel);
                    assert_eq!(a.q, b.q);
                    assert_eq!(a.f.to_bits(), b.f.to_bits());
                    assert_eq!(a.rate.to_bits(), b.rate.to_bits());
                }
                _ => panic!("participation diverged"),
            }
        }
        assert!(on.evals <= off.evals, "cache increased evals: {} > {}", on.evals, off.evals);
        assert!(on.evals > 0);
    }

    fn assert_decision_bits_eq(a: &crate::sched::RoundDecision, b: &crate::sched::RoundDecision) {
        assert_eq!(a.j0.to_bits(), b.j0.to_bits());
        assert_eq!(a.assignments.len(), b.assignments.len());
        for (x, y) in a.assignments.iter().zip(&b.assignments) {
            match (x, y) {
                (None, None) => {}
                (Some(x), Some(y)) => {
                    assert_eq!(x.channel, y.channel);
                    assert_eq!(x.q, y.q);
                    assert_eq!(x.f.to_bits(), y.f.to_bits());
                    assert_eq!(x.rate.to_bits(), y.rate.to_bits());
                }
                _ => panic!("participation diverged"),
            }
        }
    }

    #[test]
    fn classed_parallel_fitness_same_decision() {
        // Determinism pin for the classed path: 1 vs 8 fitness workers
        // must yield a bit-identical decision (the acceptance trace
        // contract for `--threads`).
        let fx = Fixture::new(16);
        let inp = fx.inputs();
        let cfg = crate::sched::ClassingConfig::default();
        let serial =
            QccfScheduler::new(5).with_classes_override(Some(cfg)).decide(&inp);
        let parallel = QccfScheduler::new(5)
            .with_classes_override(Some(cfg))
            .with_threads(8)
            .decide(&inp);
        assert_eq!(serial.evals, parallel.evals);
        assert_decision_bits_eq(&serial, &parallel);
    }

    #[test]
    fn classes_override_none_is_exact_path() {
        // `with_classes_override(None)` must behave exactly like a
        // scheduler that never heard of classes — the same contract the
        // QCCF_DECISION_CLASSES=0 kill switch provides process-wide.
        let fx = Fixture::new(17);
        let inp = fx.inputs();
        let plain = QccfScheduler::new(3).decide(&inp);
        let off = QccfScheduler::new(3).with_classes_override(None).decide(&inp);
        assert_eq!(plain.evals, off.evals);
        assert_decision_bits_eq(&plain, &off);
    }

    #[test]
    fn classed_decision_exact_valid_and_not_worse_than_greedy() {
        // The classed decide reports the *exact* J0 of its expanded
        // allocation and is backstopped by greedy — so it can never be
        // worse than the trivial policy, and its decisions respect the
        // same bounds as the exact path.
        let fx = Fixture::new(18);
        let inp = fx.inputs();
        let greedy = greedy_allocation(&inp);
        let (j_greedy, _) = evaluate_allocation(&inp, &greedy, Case5Mode::Taylor);
        let dec = QccfScheduler::new(8)
            .with_classes_override(Some(crate::sched::ClassingConfig::default()))
            .decide(&inp);
        assert!(dec.j0.is_finite());
        assert!(dec.j0 <= j_greedy, "classed {} worse than greedy {}", dec.j0, j_greedy);
        let mut used = std::collections::BTreeSet::new();
        for d in dec.assignments.iter().flatten() {
            assert!(used.insert(d.channel), "channel reuse (C3 violation)");
            assert!(d.q.unwrap() >= 1);
            assert!(d.f >= fx.params.f_min && d.f <= fx.params.f_max);
        }
    }
}
