//! Hierarchical class-based decision stage: bucket the federation into
//! equivalence classes, run the GA over *classes × channel pools*, and
//! broadcast one memoized KKT solve per (class, pool) pair.
//!
//! The closed-form solver's output depends on a client only through
//! `(D_i, w_i, rate, θ^max, q_prev)` — clients that share those
//! coordinates get (near-)identical decisions, yet the exact fitness
//! loop still pays O(pop × U × C) per round. This module collapses the
//! federation onto the heterogeneity axes the scenario subsystem
//! already generates:
//!
//! * **dataset-size bin** — rank-binned `D_i` ([`ClassingConfig::size_bins`]);
//! * **channel-quality bin** — rank-binned mean uplink rate
//!   ([`ClassingConfig::rate_bins`]);
//! * **CPU class** — straggler vs nominal
//!   ([`crate::config::SystemParams::cpu_scale`]).
//!
//! A [`ClassPlan`] groups clients into K classes on those axes and
//! splits the C channels into P = min(K, C) contiguous *pools*; the GA
//! then searches chromosomes of length P whose genes are class indices
//! — O(pop × K × P) per round instead of O(pop × U × C). Within a
//! class the per-client solve is replaced by one representative solve
//! ([`ClassEvalCtx`]) whose `(q*, f*)` broadcasts to every scheduled
//! member.
//!
//! ## Approximation contract
//!
//! Unlike [`super::EvalCtx`] (bit-identical cache), the classed path is
//! an **approximation**: its class-level J0 scores class means, not the
//! per-client truth. Three guard rails keep it honest:
//!
//! * the winning class chromosome is *expanded* to a per-client
//!   allocation and re-scored once through the exact reference
//!   [`super::evaluate_allocation`] — the J0 and assignments a classed
//!   decide reports are therefore **exact** for the allocation it
//!   chose, and the realized trace never contains an approximate
//!   number;
//! * the greedy rate-maximizing allocation is evaluated as a backstop
//!   and wins whenever it scores better, so a classed decide is never
//!   worse than the trivial policy;
//! * `bench-sched` measures the classed-vs-exact J0 gap and the
//!   speedup at U ∈ {1 000, 10 000, 100 000} into BENCH_sched.json
//!   (acceptance: gap ≤ 1 % on the stress-1000 shape).
//!
//! When every member of a class is *exactly* identical (same size,
//! rates, stats), the broadcast solve is bit-identical to each
//! member's own [`solver::solve_client`] — the class means are then
//! exact — and the decide output equals the reference oracle on the
//! expanded chromosome by construction; `tests/proptest_classes.rs`
//! pins both properties across U ∈ {10, 100, 1 000}.
//!
//! Classing is enabled per scenario (`[train] classes = true`) and can
//! be killed process-wide with `QCCF_DECISION_CLASSES=0`, mirroring
//! the `QCCF_DECISION_CACHE` toggle ([`decision_classes_default`]).

use std::collections::{BTreeMap, HashMap};
use std::sync::Mutex;

use crate::convergence;
use crate::energy;
use crate::ga::{self, Chromosome, GaParams};
use crate::solver::{self, Case5Mode, ClientCtx, Decision};
use crate::util::rng::Rng;

use super::{evaluate_allocation, greedy_allocation, ClientDecision, RoundInputs};

/// Whether class-based scheduling is enabled by default for this
/// process: the `QCCF_DECISION_CLASSES=0` kill switch, mirroring
/// [`super::ctx::decision_cache_default`]. A scenario still has to opt
/// in (`[train] classes = true`) — this gate can only turn classing
/// *off*, never force it on.
pub fn decision_classes_default() -> bool {
    std::env::var("QCCF_DECISION_CLASSES").map(|v| v != "0").unwrap_or(true)
}

/// Binning knobs for [`ClassPlan::build`] — how many rank bins each
/// continuous heterogeneity axis is cut into. More bins = more classes
/// = a finer (slower, more faithful) approximation; the CPU axis is
/// always binary (straggler vs nominal).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ClassingConfig {
    /// Rank bins over the dataset sizes `D_i` (≥ 1).
    pub size_bins: usize,
    /// Rank bins over the per-client mean uplink rate (≥ 1).
    pub rate_bins: usize,
}

impl Default for ClassingConfig {
    fn default() -> Self {
        ClassingConfig { size_bins: 4, rate_bins: 4 }
    }
}

/// Rank-bin `0..u` by `key`: sort ids ascending by `(key, id)` and give
/// position `pos` the bin `pos · bins / u` — equal-mass bins that need
/// no distributional assumptions on `key`.
fn rank_bins<F: Fn(usize) -> f64>(u: usize, bins: usize, key: F) -> Vec<usize> {
    let mut order: Vec<usize> = (0..u).collect();
    order.sort_by(|&a, &b| key(a).total_cmp(&key(b)).then(a.cmp(&b)));
    let mut bin = vec![0usize; u];
    for (pos, &i) in order.iter().enumerate() {
        bin[i] = pos * bins / u;
    }
    bin
}

/// The round's class structure: a partition of the clients into
/// equivalence classes and a partition of the channels into contiguous
/// pools. Built once per round from the [`RoundInputs`]
/// ([`ClassPlan::build`]); deterministic — grouping runs through a
/// `BTreeMap` and every sort breaks ties on the client id.
pub struct ClassPlan {
    /// `classes[k]` = member client ids, sorted by (size desc, id asc)
    /// — the *scheduling order*: when a pool holds fewer channels than
    /// the class has members, the largest-data members go first, and
    /// `classes[k][0]` is the feasibility representative.
    classes: Vec<Vec<usize>>,
    /// `pools[p]` = `(first_channel, len)`; contiguous, covering all C
    /// channels.
    pools: Vec<(usize, usize)>,
}

impl ClassPlan {
    /// Bucket the round's clients on (size bin × mean-rate bin × CPU
    /// class) and split the channels into P = min(K, C) pools (the
    /// first `C mod P` pools get the spare channels).
    pub fn build(inp: &RoundInputs<'_>, cfg: ClassingConfig) -> ClassPlan {
        let p = inp.params;
        let (u, c) = (p.num_clients, p.num_channels);
        let mean_rate: Vec<f64> = (0..u)
            .map(|i| (0..c).map(|ch| inp.channels.rate(i, ch)).sum::<f64>() / c as f64)
            .collect();
        let size_bin = rank_bins(u, cfg.size_bins.max(1), |i| inp.sizes[i]);
        let rate_bin = rank_bins(u, cfg.rate_bins.max(1), |i| mean_rate[i]);
        let mut groups: BTreeMap<(usize, usize, bool), Vec<usize>> = BTreeMap::new();
        for i in 0..u {
            // Unavailable clients never enter a class: they cannot be
            // seated by `expand`, and the exact re-score + greedy
            // backstop apply the same mask through
            // [`RoundInputs::is_available`].
            if !inp.is_available(i) {
                continue;
            }
            let slow = p.cpu_scale(i) < 1.0;
            groups.entry((size_bin[i], rate_bin[i], slow)).or_default().push(i);
        }
        let mut classes: Vec<Vec<usize>> = groups.into_values().collect();
        for members in classes.iter_mut() {
            members.sort_by(|&a, &b| inp.sizes[b].total_cmp(&inp.sizes[a]).then(a.cmp(&b)));
        }
        let np = classes.len().min(c).max(1);
        let (base, extra) = (c / np, c % np);
        let mut pools = Vec::with_capacity(np);
        let mut start = 0;
        for k in 0..np {
            let len = base + usize::from(k < extra);
            pools.push((start, len));
            start += len;
        }
        ClassPlan { classes, pools }
    }

    /// K — number of equivalence classes.
    pub fn num_classes(&self) -> usize {
        self.classes.len()
    }

    /// P — number of channel pools (≤ C and ≤ K).
    pub fn num_pools(&self) -> usize {
        self.pools.len()
    }

    /// Member client ids of class `k`, in scheduling order (size desc,
    /// id asc).
    pub fn class_members(&self, k: usize) -> &[usize] {
        &self.classes[k]
    }

    /// `(first_channel, len)` of pool `p`.
    pub fn pool(&self, p: usize) -> (usize, usize) {
        self.pools[p]
    }
}

/// Memoized result of one class-representative solve: the Theorem-3
/// decision plus its per-member energy (`None` = solver declined).
type ClassSolve = Option<(Decision, f64)>;

/// Per-class memo shards keyed on
/// `(rate.to_bits(), w.to_bits(), d_rep.to_bits())`. Unlike
/// [`super::EvalCtx`]'s `(rate, w)` key, `d_rep` — the scheduled-prefix
/// mean size — must be part of the key: it varies with how many
/// members a pool can seat, so two chromosomes can hit the same class
/// with the same `(rate, w)` but a different representative size.
type ClassMemo = Vec<Mutex<HashMap<(u64, u64, u64), ClassSolve>>>;

/// Class-level evaluation context: the K×P mean-rate / feasibility
/// tables, per-class prefix sums over the scheduling order, and the
/// exact-key representative-solve memo. Build once per round next to a
/// [`ClassPlan`], share immutably across GA fitness workers.
pub struct ClassEvalCtx<'a> {
    inp: &'a RoundInputs<'a>,
    plan: &'a ClassPlan,
    mode: Case5Mode,
    /// Row-major K×P mean uplink rate over (member, pool-channel) pairs.
    rate: Vec<f64>,
    /// Row-major K×P `q_max_feasible` of the class representative
    /// (`classes[k][0]`, the largest member) at that mean rate; 0 = the
    /// pair is skipped at class level (the exact re-evaluation still
    /// gates every member individually).
    q_max: Vec<u32>,
    /// A1(p), constant per round.
    a1v: f64,
    /// A2(p), constant per round.
    a2v: f64,
    /// `Σ_i 4τ·Ĝ_i²` over **all** U clients — the C6 data term when
    /// nobody participates; participants then add their gain delta.
    excl_total: f64,
    /// Per class: prefix sums over the scheduling order, index `n` =
    /// first n members, `[0] = 0.0`. Sizes…
    pref_size: Vec<Vec<f64>>,
    /// …Ĝ² estimates…
    pref_g2: Vec<Vec<f64>>,
    /// …σ̂² estimates…
    pref_sigma2: Vec<Vec<f64>>,
    /// …and the C6 gain delta `4τ(1−w_i^full)Ĝ_i² − 4τ·Ĝ_i²` a member
    /// contributes by participating (before the w-dependent part).
    pref_gain: Vec<Vec<f64>>,
    /// Class-mean θ^max (broadcast-solve input).
    theta_rep: Vec<f64>,
    /// Class-mean q_prev (broadcast-solve input).
    q_prev_rep: Vec<f64>,
    /// Representative-solve memo shards, one lock per class (`None` =
    /// caching disabled via the scheduler's cache toggle).
    memo: Option<ClassMemo>,
}

/// Reusable per-evaluation buffer for [`ClassEvalCtx::evaluate_j0`]
/// (one per GA fitness worker): the selected (class, pool, seated
/// members, rate) tuples of the chromosome under evaluation.
#[derive(Clone, Debug, Default)]
pub struct ClassScratch {
    sel: Vec<(usize, usize, usize, f64)>,
}

impl<'a> ClassEvalCtx<'a> {
    /// Precompute the class-level tables from `inp` and `plan`;
    /// `cache` gates the representative-solve memo.
    pub fn new(
        inp: &'a RoundInputs<'a>,
        plan: &'a ClassPlan,
        mode: Case5Mode,
        cache: bool,
    ) -> ClassEvalCtx<'a> {
        let p = inp.params;
        let (kn, np) = (plan.num_classes(), plan.num_pools());
        let mut rate = vec![0.0f64; kn * np];
        let mut q_max = vec![0u32; kn * np];
        for (k, members) in plan.classes.iter().enumerate() {
            for (pi, &(start, len)) in plan.pools.iter().enumerate() {
                let mut sum = 0.0f64;
                for &i in members {
                    for ch in start..start + len {
                        sum += inp.channels.rate(i, ch);
                    }
                }
                let r = sum / (members.len() * len) as f64;
                rate[k * np + pi] = r;
                q_max[k * np + pi] =
                    solver::q_max_feasible(p, inp.sizes[members[0]], r).unwrap_or(0);
            }
        }
        let tau = p.tau as f64;
        let excl_total: f64 = inp.g2.iter().map(|&g| 4.0 * tau * g).sum();
        let mut pref_size = Vec::with_capacity(kn);
        let mut pref_g2 = Vec::with_capacity(kn);
        let mut pref_sigma2 = Vec::with_capacity(kn);
        let mut pref_gain = Vec::with_capacity(kn);
        let mut theta_rep = Vec::with_capacity(kn);
        let mut q_prev_rep = Vec::with_capacity(kn);
        for members in &plan.classes {
            let m = members.len();
            let (mut ps, mut pg) = (vec![0.0f64; m + 1], vec![0.0f64; m + 1]);
            let (mut psg, mut pgn) = (vec![0.0f64; m + 1], vec![0.0f64; m + 1]);
            let (mut th, mut qp) = (0.0f64, 0.0f64);
            for (j, &i) in members.iter().enumerate() {
                ps[j + 1] = ps[j] + inp.sizes[i];
                pg[j + 1] = pg[j] + inp.g2[i];
                psg[j + 1] = psg[j] + inp.sigma2[i];
                pgn[j + 1] = pgn[j]
                    + (4.0 * tau * (1.0 - inp.w_full[i]) * inp.g2[i] - 4.0 * tau * inp.g2[i]);
                th += inp.theta_max[i];
                qp += inp.q_prev[i];
            }
            pref_size.push(ps);
            pref_g2.push(pg);
            pref_sigma2.push(psg);
            pref_gain.push(pgn);
            theta_rep.push(th / m as f64);
            q_prev_rep.push(qp / m as f64);
        }
        let memo = if cache {
            Some((0..kn).map(|_| Mutex::new(HashMap::new())).collect())
        } else {
            None
        };
        ClassEvalCtx {
            inp,
            plan,
            mode,
            rate,
            q_max,
            a1v: convergence::a1(p),
            a2v: convergence::a2(p),
            excl_total,
            pref_size,
            pref_g2,
            pref_sigma2,
            pref_gain,
            theta_rep,
            q_prev_rep,
            memo,
        }
    }

    /// A worker-sized scratch for this plan's dimensions.
    pub fn make_scratch(&self) -> ClassScratch {
        ClassScratch { sel: Vec::with_capacity(self.plan.num_pools()) }
    }

    /// Class-level J0 of a class chromosome (`alloc[pool]` = class
    /// index). O(K + P) after the per-round precompute — this is the
    /// GA fitness function. **Approximate**: scores every scheduled
    /// member of a class at the class-mean coordinates; see the module
    /// docs for the exactness guard rails.
    pub fn evaluate_j0(&self, chrom: &Chromosome, s: &mut ClassScratch) -> f64 {
        let p = self.inp.params;
        let np = self.plan.num_pools();
        s.sel.clear();
        let mut d_total = 0.0f64;
        for (pool, slot) in chrom.alloc.iter().enumerate() {
            let Some(k) = *slot else { continue };
            if self.q_max[k * np + pool] == 0 {
                continue;
            }
            let (_, plen) = self.plan.pools[pool];
            let n = self.plan.classes[k].len().min(plen);
            d_total += self.pref_size[k][n];
            s.sel.push((k, pool, n, self.rate[k * np + pool]));
        }
        if d_total <= 0.0 {
            return f64::INFINITY;
        }
        let mut any = false;
        let mut data = self.excl_total;
        let mut quant = 0.0f64;
        let mut total_energy = 0.0f64;
        for &(k, _pool, n, rate) in &s.sel {
            let nf = n as f64;
            let d_rep = self.pref_size[k][n] / nf;
            let w = d_rep / d_total;
            let Some((dec, e)) = self.solve_memo(k, d_rep, w, rate) else { continue };
            any = true;
            quant += nf * convergence::quant_term_client(p, w, self.theta_rep[k], dec.q);
            total_energy += nf * e;
            data += self.pref_gain[k][n]
                + self.a1v * w * self.pref_g2[k][n]
                + self.a2v * w * self.pref_sigma2[k][n];
        }
        if !any {
            return f64::INFINITY;
        }
        self.inp.queues.lambda1 * data
            + (self.inp.queues.lambda2 - p.eps2) * quant
            + p.v * total_energy
    }

    /// Expand a class chromosome to a per-client [`Chromosome`] over
    /// the real C channels: each selected class seats its scheduling
    /// order onto its pool's channels. Classes that failed the
    /// class-level feasibility probe are expanded too — the exact
    /// evaluator applies the true per-member gate. Valid whenever the
    /// class chromosome is repaired (classes unique ⇒ member sets
    /// disjoint).
    pub fn expand(&self, chrom: &Chromosome) -> Chromosome {
        let mut alloc = vec![None; self.inp.params.num_channels];
        for (pool, slot) in chrom.alloc.iter().enumerate() {
            let Some(k) = *slot else { continue };
            let (start, plen) = self.plan.pools[pool];
            for (j, &i) in self.plan.classes[k].iter().take(plen).enumerate() {
                alloc[start + j] = Some(i);
            }
        }
        Chromosome { alloc }
    }

    /// Greedy class seed: classes in descending best-pool-rate order
    /// each pick their best remaining pool — the class-level analogue
    /// of [`super::greedy_allocation`], used to seed the GA population.
    pub fn greedy_seed(&self) -> Chromosome {
        let (kn, np) = (self.plan.num_classes(), self.plan.num_pools());
        let best: Vec<f64> = (0..kn)
            .map(|k| (0..np).map(|pi| self.rate[k * np + pi]).fold(0.0, f64::max))
            .collect();
        let mut order: Vec<usize> = (0..kn).collect();
        order.sort_by(|&a, &b| best[b].total_cmp(&best[a]));
        let mut alloc: Vec<Option<usize>> = vec![None; np];
        let mut taken = 0usize;
        for &k in &order {
            let mut pick: Option<(usize, f64)> = None;
            for (pi, slot) in alloc.iter().enumerate() {
                if slot.is_none() {
                    let r = self.rate[k * np + pi];
                    if pick.map(|(_, br)| r > br || br.is_nan()).unwrap_or(true) {
                        pick = Some((pi, r));
                    }
                }
            }
            if let Some((pi, _)) = pick {
                alloc[pi] = Some(k);
                taken += 1;
                if taken == np {
                    break;
                }
            }
        }
        Chromosome { alloc }
    }

    /// Mean uplink rate of class `k` over pool `p`'s channels
    /// (test/bench introspection).
    pub fn class_rate(&self, k: usize, p: usize) -> f64 {
        self.rate[k * self.plan.num_pools() + p]
    }

    /// Whether class `k`'s representative passes the q = 1 gate at
    /// pool `p`'s mean rate (test/bench introspection).
    pub fn class_feasible(&self, k: usize, p: usize) -> bool {
        self.q_max[k * self.plan.num_pools() + p] >= 1
    }

    /// Total data size of the first `n` scheduling-order members of
    /// class `k` (test/bench introspection; `d_rep = sum / n`).
    pub fn sched_size_sum(&self, k: usize, n: usize) -> f64 {
        self.pref_size[k][n]
    }

    /// The representative solve the classed path broadcasts for class
    /// `k` at `(d_rep, w, rate)` — exposed so the property tests can
    /// pin it bitwise against each member's own per-client solve.
    pub fn broadcast_solve(&self, k: usize, d_rep: f64, w: f64, rate: f64) -> ClassSolve {
        self.solve_memo(k, d_rep, w, rate)
    }

    /// Representative solve through the memo (or straight through when
    /// caching is off). The solve runs outside the shard lock; a lost
    /// race rewrites the identical value (pure function of the key).
    fn solve_memo(&self, k: usize, d_rep: f64, w: f64, rate: f64) -> ClassSolve {
        let Some(shards) = &self.memo else {
            return self.solve(k, d_rep, w, rate);
        };
        let key = (rate.to_bits(), w.to_bits(), d_rep.to_bits());
        let poisoned = "solve-memo shard poisoned: a worker panicked holding the lock";
        if let Some(&hit) = shards[k].lock().expect(poisoned).get(&key) {
            return hit;
        }
        let solved = self.solve(k, d_rep, w, rate);
        shards[k].lock().expect(poisoned).insert(key, solved);
        solved
    }

    /// The uncached representative solve: one [`solver::solve_client`]
    /// + [`energy::client_energy`] at the class coordinates — exactly
    /// the per-client body with `(D, w, θ, q_prev)` replaced by the
    /// class representatives.
    fn solve(&self, k: usize, d_rep: f64, w: f64, rate: f64) -> ClassSolve {
        let p = self.inp.params;
        let ctx = ClientCtx {
            d_i: d_rep,
            w_round: w,
            rate,
            theta_max: self.theta_rep[k],
            q_prev: self.q_prev_rep[k],
        };
        let dec = solver::solve_client(p, self.inp.queues.lambda2, &ctx, self.mode)?;
        let e = energy::client_energy(p, d_rep, dec.f, dec.q, rate);
        Some((dec, e))
    }
}

/// The classed decide body (class-level analogue of
/// [`super::ctx::decide_with_ga`]): build the [`ClassPlan`] +
/// [`ClassEvalCtx`], run the GA over class chromosomes seeded with
/// [`ClassEvalCtx::greedy_seed`], expand the winner and re-score it
/// **exactly** through [`super::evaluate_allocation`], then keep the
/// better of that and the exact greedy allocation. Returns
/// `(j0, assignments, evals)` — the reported values are exact for the
/// chosen allocation, and the result is bit-identical for any worker
/// count and any `cache` setting.
pub fn decide_with_classes(
    inp: &RoundInputs<'_>,
    mode: Case5Mode,
    ga_params: &GaParams,
    rng: &mut Rng,
    cfg: ClassingConfig,
    cache: bool,
) -> (f64, Vec<Option<ClientDecision>>, usize) {
    let plan = ClassPlan::build(inp, cfg);
    let ctx = ClassEvalCtx::new(inp, &plan, mode, cache);
    let seed = ctx.greedy_seed();
    let mut scratches: Vec<ClassScratch> =
        (0..ga_params.threads.max(1)).map(|_| ctx.make_scratch()).collect();
    let params = GaParams { fitness_cache: cache && ga_params.fitness_cache, ..*ga_params };
    let outcome = ga::optimize_scratch(
        plan.num_pools(),
        plan.num_classes(),
        &params,
        rng,
        std::slice::from_ref(&seed),
        &mut scratches,
        |c, s| ctx.evaluate_j0(c, s),
    );
    let expanded = ctx.expand(&outcome.best);
    let (j_exp, a_exp) = evaluate_allocation(inp, &expanded, mode);
    let (j_gr, a_gr) = evaluate_allocation(inp, &greedy_allocation(inp), mode);
    if j_gr < j_exp {
        (j_gr, a_gr, outcome.evals)
    } else {
        (j_exp, a_exp, outcome.evals)
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::Fixture;
    use super::*;

    #[test]
    fn plan_partitions_clients_and_channels() {
        let fx = Fixture::new(21);
        let inp = fx.inputs();
        let plan = ClassPlan::build(&inp, ClassingConfig::default());
        // Every client in exactly one class.
        let mut seen = vec![0usize; 10];
        for k in 0..plan.num_classes() {
            assert!(!plan.class_members(k).is_empty(), "empty class {k}");
            for &i in plan.class_members(k) {
                seen[i] += 1;
            }
        }
        assert!(seen.iter().all(|&n| n == 1), "client multiplicity {seen:?}");
        // Pools are contiguous and cover all channels.
        assert!(plan.num_pools() >= 1 && plan.num_pools() <= 10);
        let mut next = 0usize;
        for p in 0..plan.num_pools() {
            let (start, len) = plan.pool(p);
            assert_eq!(start, next, "pool {p} not contiguous");
            assert!(len >= 1, "empty pool {p}");
            next = start + len;
        }
        assert_eq!(next, 10, "pools must cover all channels");
        // Scheduling order is size-descending within each class.
        for k in 0..plan.num_classes() {
            let m = plan.class_members(k);
            for w in m.windows(2) {
                assert!(fx.sizes[w[0]] >= fx.sizes[w[1]], "class {k} order");
            }
        }
    }

    #[test]
    fn expansion_of_repaired_chromosomes_is_valid() {
        let fx = Fixture::new(22);
        let inp = fx.inputs();
        let plan = ClassPlan::build(&inp, ClassingConfig { size_bins: 2, rate_bins: 2 });
        let ctx = ClassEvalCtx::new(&inp, &plan, Case5Mode::Taylor, true);
        let (kn, np) = (plan.num_classes(), plan.num_pools());
        let mut rng = Rng::seed_from(99);
        for _ in 0..32 {
            let mut chrom = Chromosome::random(np, kn, &mut rng);
            chrom.repair(kn);
            let expanded = ctx.expand(&chrom);
            assert_eq!(expanded.alloc.len(), 10);
            assert!(expanded.is_valid(10), "expansion invalid: {:?}", chrom.alloc);
        }
        let seed = ctx.greedy_seed();
        assert!(seed.is_valid(kn));
        assert!(ctx.expand(&seed).is_valid(10));
    }

    #[test]
    fn classed_decide_exact_and_not_worse_than_greedy() {
        let fx = Fixture::new(23);
        let inp = fx.inputs();
        let (j_gr, _) = evaluate_allocation(&inp, &greedy_allocation(&inp), Case5Mode::Taylor);
        let mut rng = Rng::seed_from(7);
        let (j0, assigns, evals) = decide_with_classes(
            &inp,
            Case5Mode::Taylor,
            &GaParams::default(),
            &mut rng,
            ClassingConfig::default(),
            true,
        );
        assert!(j0.is_finite());
        assert!(j0 <= j_gr, "classed {j0} worse than greedy backstop {j_gr}");
        assert!(evals > 0);
        // Channel uniqueness (C3) on the expanded decision.
        let mut used = std::collections::BTreeSet::new();
        for d in assigns.iter().flatten() {
            assert!(used.insert(d.channel), "channel reuse");
        }
    }

    #[test]
    fn classed_decide_cache_off_bit_identical() {
        let fx = Fixture::new(24);
        let inp = fx.inputs();
        let run = |cache: bool| {
            let mut rng = Rng::seed_from(11);
            decide_with_classes(
                &inp,
                Case5Mode::Bisect,
                &GaParams::default(),
                &mut rng,
                ClassingConfig::default(),
                cache,
            )
        };
        let (j_on, a_on, _) = run(true);
        let (j_off, a_off, _) = run(false);
        assert_eq!(j_on.to_bits(), j_off.to_bits());
        let bits = |a: &[Option<ClientDecision>]| -> Vec<_> {
            a.iter().map(|d| d.map(|d| (d.channel, d.q, d.f.to_bits(), d.rate.to_bits()))).collect::<Vec<_>>()
        };
        assert_eq!(bits(&a_on), bits(&a_off));
    }

    #[test]
    fn unavailable_clients_never_enter_a_class() {
        let fx = Fixture::new(26);
        let mut inp = fx.inputs();
        let mask: Vec<bool> = (0..10).map(|i| i != 3 && i != 8).collect();
        inp.avail = Some(&mask);
        let plan = ClassPlan::build(&inp, ClassingConfig::default());
        let mut seen = vec![0usize; 10];
        for k in 0..plan.num_classes() {
            for &i in plan.class_members(k) {
                seen[i] += 1;
            }
        }
        assert_eq!(seen[3], 0, "offline client 3 classed");
        assert_eq!(seen[8], 0, "offline client 8 classed");
        assert_eq!(seen.iter().sum::<usize>(), 8, "all online clients classed once");
        // The classed decide still produces a finite, mask-respecting
        // decision on the remaining clients.
        let mut rng = Rng::seed_from(13);
        let (j0, assigns, _) = decide_with_classes(
            &inp,
            Case5Mode::Taylor,
            &GaParams::default(),
            &mut rng,
            ClassingConfig::default(),
            true,
        );
        assert!(j0.is_finite());
        assert!(assigns[3].is_none() && assigns[8].is_none(), "offline client scheduled");
    }

    #[test]
    fn class_j0_finite_on_greedy_seed() {
        let fx = Fixture::new(25);
        let inp = fx.inputs();
        let plan = ClassPlan::build(&inp, ClassingConfig::default());
        let ctx = ClassEvalCtx::new(&inp, &plan, Case5Mode::Taylor, true);
        let mut scratch = ctx.make_scratch();
        let j = ctx.evaluate_j0(&ctx.greedy_seed(), &mut scratch);
        assert!(j.is_finite(), "class-level J0 infinite on the greedy seed");
        // Empty class chromosome is infeasible at class level too.
        let empty = Chromosome { alloc: vec![None; plan.num_pools()] };
        assert!(ctx.evaluate_j0(&empty, &mut scratch).is_infinite());
    }
}
