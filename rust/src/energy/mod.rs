//! Latency & energy models — paper eqs. (14)–(17), verbatim.
//!
//! Everything here is analytic (the paper's own methodology: energy is
//! modeled, not measured), so the figures' energy axes are exact
//! functions of the decisions (q, f, R, a) and the channel draws.
//!
//! Every function is *pure* and bitwise-deterministic in its f64
//! inputs — the decision-stage memo (`sched::ctx`) caches
//! [`client_energy`] alongside the per-client solve on exact f64-bit
//! keys and relies on replayed calls producing identical bits. These
//! are also the innermost calls of the GA fitness loop, hence the
//! `#[inline]` hints.

use crate::config::SystemParams;

/// Uplink latency, eq. (14): `ℓ / v` with ℓ = Z(q+1)+32 from eq. (5).
#[inline]
pub fn t_com(params: &SystemParams, q: u32, rate_bps: f64) -> f64 {
    params.payload_bits(q) / rate_bps
}

/// Uplink latency for a raw (unquantized) 32-bit upload.
#[inline]
pub fn t_com_raw(params: &SystemParams, rate_bps: f64) -> f64 {
    params.raw_payload_bits() / rate_bps
}

/// Uplink energy, eq. (15): `p · T^com`.
#[inline]
pub fn e_com(params: &SystemParams, t_com_s: f64) -> f64 {
    params.tx_power_w * t_com_s
}

/// Computation latency, eq. (16): `τ^e γ D_i / f`.
#[inline]
pub fn t_cmp(params: &SystemParams, d_i: f64, f_hz: f64) -> f64 {
    params.tau_e as f64 * params.gamma * d_i / f_hz
}

/// Computation energy, eq. (17): `τ^e α γ D_i f²`.
#[inline]
pub fn e_cmp(params: &SystemParams, d_i: f64, f_hz: f64) -> f64 {
    params.tau_e as f64 * params.alpha * params.gamma * d_i * f_hz * f_hz
}

/// The frequency that exactly meets the latency budget for payload
/// `bits` at `rate_bps` (the paper's 𝒮(q) before the f^min clamp);
/// `None` when even f = ∞ cannot meet it (communication alone exceeds
/// T^max).
pub fn freq_to_meet_deadline(
    params: &SystemParams,
    d_i: f64,
    bits: f64,
    rate_bps: f64,
) -> Option<f64> {
    let t_budget = params.t_max - bits / rate_bps;
    if t_budget <= 0.0 {
        return None;
    }
    Some(params.tau_e as f64 * params.gamma * d_i / t_budget)
}

/// The paper's 𝒮(q) = max(f^min, ...) — optimal frequency for a fixed
/// integer q (Theorem 3 / Case 1 logic). `None` if infeasible even at
/// f^max.
#[inline]
pub fn s_of_q(params: &SystemParams, d_i: f64, q: u32, rate_bps: f64) -> Option<f64> {
    let f = freq_to_meet_deadline(params, d_i, params.payload_bits(q), rate_bps)?;
    let f = f.max(params.f_min);
    if f > params.f_max {
        None
    } else {
        Some(f)
    }
}

/// Total per-round energy of a participating client (objective summand).
#[inline]
pub fn client_energy(params: &SystemParams, d_i: f64, f_hz: f64, q: u32, rate_bps: f64) -> f64 {
    e_cmp(params, d_i, f_hz) + e_com(params, t_com(params, q, rate_bps))
}

/// Total per-round latency of a participating client (C4 LHS).
pub fn client_latency(params: &SystemParams, d_i: f64, f_hz: f64, q: u32, rate_bps: f64) -> f64 {
    t_cmp(params, d_i, f_hz) + t_com(params, q, rate_bps)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> SystemParams {
        SystemParams::femnist_small()
    }

    #[test]
    fn eq14_t_com_exact() {
        let params = p();
        // ℓ = Z·q + Z + 32 bits at `rate` bit/s.
        let rate = 20e6;
        let want = (20_522.0 * 8.0 + 20_522.0 + 32.0) / rate;
        assert!((t_com(&params, 8, rate) - want).abs() < 1e-15);
    }

    #[test]
    fn eq15_e_com_exact() {
        let params = p();
        assert!((e_com(&params, 0.01) - 0.2 * 0.01).abs() < 1e-15);
    }

    #[test]
    fn eq16_t_cmp_exact() {
        let params = p();
        // τ^e γ D / f = 2 * 1000 * 1200 / 1e9 = 2.4 ms.
        assert!((t_cmp(&params, 1200.0, 1e9) - 0.0024).abs() < 1e-12);
    }

    #[test]
    fn eq17_e_cmp_exact() {
        let params = p();
        // 2 * 1e-26 * 1000 * 1200 * (1e9)^2 = 0.024 J.
        assert!((e_cmp(&params, 1200.0, 1e9) - 0.024).abs() < 1e-9);
    }

    #[test]
    fn e_cmp_quadratic_in_f() {
        let params = p();
        let e1 = e_cmp(&params, 1200.0, 4e8);
        let e2 = e_cmp(&params, 1200.0, 8e8);
        assert!((e2 / e1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn deadline_freq_matches_latency() {
        let params = p();
        let rate = 20e6;
        let q = 6;
        let f = s_of_q(&params, 1200.0, q, rate).unwrap();
        let lat = client_latency(&params, 1200.0, f, q, rate);
        assert!(lat <= params.t_max + 1e-12, "lat={lat}");
        // At f^min the slack case: tiny dataset ⇒ clamped to f_min.
        let f2 = s_of_q(&params, 1.0, 1, rate).unwrap();
        assert_eq!(f2, params.f_min);
    }

    #[test]
    fn infeasible_when_comm_alone_exceeds_budget() {
        let params = p();
        // Very low rate: even q = 1 can't fit in T^max.
        assert!(s_of_q(&params, 1200.0, 1, 0.5e6).is_none());
        // Huge q at a normal rate is also infeasible.
        assert!(s_of_q(&params, 1200.0, 32, 10e6).is_none());
    }

    #[test]
    fn q16_feasible_at_default_calibration() {
        // The calibration promise from config/mod.rs: q up to ~16 feasible
        // at a typical 20 Mb/s rate with D_i = 1200.
        let params = p();
        assert!(s_of_q(&params, 1200.0, 16, 20e6).is_some());
    }
}
