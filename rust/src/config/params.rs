//! [`SystemParams`] (paper Table I + §III–§V constants) and
//! [`ExperimentConfig`] (one experiment run).

/// All physical/algorithmic constants of the wireless FL system.
///
/// Field-by-field mapping to the paper is given inline; defaults are the
/// FEMNIST column of Table I unless noted.
#[derive(Clone, Debug)]
pub struct SystemParams {
    // ----- topology (§VI) -----
    /// U — number of clients (paper: 10).
    pub num_clients: usize,
    /// C — OFDMA channels (paper doesn't state; we default to U so full
    /// participation is possible, which the aggregation eq. (2) assumes
    /// in the no-quantization baseline).
    pub num_channels: usize,
    /// Cell radius in meters (paper: 500 m circular area).
    pub cell_radius_m: f64,

    // ----- communication (Table I) -----
    /// B — per-channel bandwidth in Hz (1 MHz).
    pub bandwidth_hz: f64,
    /// p — uplink transmit power in W (0.2 W).
    pub tx_power_w: f64,
    /// N0 — noise power spectral density in W/Hz (−174 dBm/Hz).
    pub noise_psd_w_hz: f64,
    /// K — Rician K-factor (4).
    pub rician_k: f64,
    /// ζ — Rician mean power (1).
    pub rician_zeta: f64,
    /// Carrier frequency in GHz (unpublished; we use 2.4 GHz).
    pub carrier_ghz: f64,
    /// h^Gain in dB — device/antenna gain "and other settings". The
    /// calibration knob (see module docs).
    pub gain_db: f64,

    // ----- computation (Table I) -----
    /// α — energy coefficient (1e−26).
    pub alpha: f64,
    /// γ — CPU cycles per sample (1000 FEMNIST / 2000 CIFAR-10).
    pub gamma: f64,
    /// f^min, f^max — CPU frequency range in Hz (2e8 .. 1e9).
    pub f_min: f64,
    pub f_max: f64,
    /// τ — local updates per round (6); τ^e — local epochs (2).
    pub tau: usize,
    pub tau_e: usize,
    /// T^max — per-round latency budget in seconds (0.02 FEMNIST).
    pub t_max: f64,

    // ----- model -----
    /// Z — model dimension count (profile-dependent; Table I lists
    /// 246 590 / 576 778 for the paper profiles).
    pub z: usize,

    // ----- convergence constants (§III–§IV) -----
    /// η — learning rate used in the A1/A2 constants.
    pub eta: f64,
    /// L — smoothness constant estimate (Assumption 2).
    pub lips: f64,

    // ----- Lyapunov (§V-A) -----
    /// V — drift-plus-penalty weight (Fig. 2 sweeps this).
    pub v: f64,
    /// ε1 — data-property budget (C6).
    pub eps1: f64,
    /// ε2 — quantization-error budget (C7).
    pub eps2: f64,
    /// The paper never publishes its ε values; when set, the server
    /// recalibrates ε1/ε2 once (at round 2) from the *observed* gradient
    /// statistics so that C6/C7 are tight-but-satisfiable and the queues
    /// are mean-rate stable (see EXPERIMENTS.md §Calibration).
    pub auto_eps: bool,

    // ----- quantization bounds -----
    /// Hard ceiling on integer quantization levels (wire format sanity;
    /// 32 = "effectively unquantized").
    pub q_cap: u32,
}

impl SystemParams {
    /// Table I, FEMNIST column, with the `small` profile's Z (the default
    /// experiment profile — see module docs on feasibility).
    pub fn femnist_small() -> SystemParams {
        SystemParams {
            num_clients: 10,
            num_channels: 10,
            cell_radius_m: 500.0,
            bandwidth_hz: 1e6,
            tx_power_w: 0.2,
            noise_psd_w_hz: dbm_per_hz_to_w_per_hz(-174.0),
            rician_k: 4.0,
            rician_zeta: 1.0,
            carrier_ghz: 2.4,
            gain_db: 10.0,
            alpha: 1e-26,
            gamma: 1000.0,
            f_min: 2e8,
            f_max: 1e9,
            tau: 6,
            tau_e: 2,
            t_max: 0.02,
            z: 20_522,
            eta: 0.05,
            lips: 1.0,
            v: 100.0,
            eps1: 60.0,
            eps2: 0.05,
            auto_eps: true,
            q_cap: 32,
        }
    }

    /// Paper-size FEMNIST profile (Z = 246 590): T^max scaled by Z ratio
    /// so per-dimension latency pressure matches the `small` default.
    pub fn femnist_paper() -> SystemParams {
        let mut p = Self::femnist_small();
        p.z = 246_590;
        p.t_max = 0.02 * 246_590.0 / 20_522.0;
        p
    }

    /// Table I CIFAR-10 column (γ = 2000, T^max = 0.05 s) with scaled Z.
    pub fn cifar_paper() -> SystemParams {
        let mut p = Self::femnist_small();
        p.gamma = 2000.0;
        p.z = 576_778;
        p.t_max = 0.05 * 576_778.0 / 20_522.0;
        p.v = 10.0;
        p
    }

    /// CIFAR-like parameters at `small`-profile Z (default Fig. 4 runs).
    pub fn cifar_small() -> SystemParams {
        let mut p = Self::femnist_small();
        p.gamma = 2000.0;
        p.t_max = 0.05;
        p.v = 10.0;
        p
    }

    /// Tiny-profile params for unit/integration tests (Z from the tiny
    /// artifact, generous latency so every scheduler path is exercised).
    pub fn tiny_test() -> SystemParams {
        let mut p = Self::femnist_small();
        p.z = 1242;
        p.t_max = 0.01;
        p
    }

    /// Nominal CPU frequency used by wireless-oblivious baselines that
    /// perform no frequency control (§VI: the Principle and
    /// No-Quantization baselines have no f design; a device default in
    /// the upper-middle of the DVFS range is the realistic stand-in).
    pub fn nominal_f(&self) -> f64 {
        0.6 * self.f_max
    }

    /// Bits on the wire for a q-bit quantized model: eq. (5).
    pub fn payload_bits(&self, q: u32) -> f64 {
        (self.z as f64) * (q as f64) + self.z as f64 + 32.0
    }

    /// Bits for an unquantized f32 upload (the No-Quantization baseline).
    pub fn raw_payload_bits(&self) -> f64 {
        32.0 * self.z as f64
    }

    /// Validate internal consistency; returns a list of violated
    /// conditions (empty = good). Covers the theorem prerequisites
    /// (2η²τ²L² < 1 for Theorem 2) and physical sanity.
    pub fn validate(&self) -> Vec<String> {
        let mut errs = Vec::new();
        let etl = 2.0 * self.eta * self.eta * (self.tau * self.tau) as f64 * self.lips * self.lips;
        if etl >= 1.0 {
            errs.push(format!("Theorem 2 prerequisite violated: 2η²τ²L² = {etl:.3} >= 1"));
        }
        if self.eta * self.lips >= 1.0 {
            errs.push(format!(
                "Theorem 1 prerequisite violated: ηL = {} >= 1",
                self.eta * self.lips
            ));
        }
        if self.f_min <= 0.0 || self.f_min > self.f_max {
            errs.push("need 0 < f_min <= f_max".into());
        }
        if self.tau % self.tau_e != 0 {
            errs.push(format!("τ = {} must be a multiple of τ^e = {}", self.tau, self.tau_e));
        }
        if self.num_channels == 0 || self.num_clients == 0 {
            errs.push("need at least one client and one channel".into());
        }
        if self.t_max <= 0.0 {
            errs.push("T^max must be positive".into());
        }
        errs
    }
}

/// dBm/Hz → W/Hz (−174 dBm/Hz ≈ 3.98e−21 W/Hz).
pub fn dbm_per_hz_to_w_per_hz(dbm: f64) -> f64 {
    10f64.powf(dbm / 10.0) * 1e-3
}

/// dB → linear power ratio.
pub fn db_to_lin(db: f64) -> f64 {
    10f64.powf(db / 10.0)
}

/// One experiment run (an algorithm on a task profile).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Artifact profile name (`tiny`/`small`/`femnist`/`cifar`).
    pub profile: String,
    /// Scheduling algorithm (see `sched`/`baselines`).
    pub algorithm: String,
    /// Communication rounds N.
    pub rounds: usize,
    /// µ — mean dataset size (paper: 1200).
    pub data_mean: f64,
    /// β — dataset size std (paper: 150 or 300).
    pub data_std: f64,
    /// Dirichlet α for label skew (non-IID; paper just says non-IID).
    pub dirichlet_alpha: f64,
    /// Test set size.
    pub test_size: usize,
    /// Evaluate every k rounds.
    pub eval_every: usize,
    /// Master seed.
    pub seed: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            profile: "small".into(),
            algorithm: "qccf".into(),
            rounds: 60,
            data_mean: 1200.0,
            data_std: 150.0,
            dirichlet_alpha: 0.5,
            test_size: 512,
            eval_every: 2,
            seed: 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_i_values() {
        let p = SystemParams::femnist_small();
        assert_eq!(p.num_clients, 10);
        assert_eq!(p.bandwidth_hz, 1e6);
        assert_eq!(p.tx_power_w, 0.2);
        assert!((p.noise_psd_w_hz - 3.9810717e-21).abs() < 1e-27);
        assert_eq!(p.rician_k, 4.0);
        assert_eq!(p.alpha, 1e-26);
        assert_eq!(p.gamma, 1000.0);
        assert_eq!((p.f_min, p.f_max), (2e8, 1e9));
        assert_eq!((p.tau, p.tau_e), (6, 2));
        assert_eq!(p.t_max, 0.02);
    }

    #[test]
    fn paper_profiles_z() {
        assert_eq!(SystemParams::femnist_paper().z, 246_590);
        assert_eq!(SystemParams::cifar_paper().z, 576_778);
        assert_eq!(SystemParams::cifar_paper().gamma, 2000.0);
    }

    #[test]
    fn payload_bits_eq5() {
        let p = SystemParams::tiny_test();
        // eq. (5): ℓ = Z q + Z + 32.
        assert_eq!(p.payload_bits(8), 1242.0 * 8.0 + 1242.0 + 32.0);
        assert_eq!(p.raw_payload_bits(), 32.0 * 1242.0);
    }

    #[test]
    fn defaults_validate() {
        for p in [
            SystemParams::femnist_small(),
            SystemParams::femnist_paper(),
            SystemParams::cifar_paper(),
            SystemParams::cifar_small(),
            SystemParams::tiny_test(),
        ] {
            let errs = p.validate();
            assert!(errs.is_empty(), "{errs:?}");
        }
    }

    #[test]
    fn validate_catches_bad_theorem_prereq() {
        let mut p = SystemParams::femnist_small();
        p.eta = 0.2;
        p.lips = 2.0;
        assert!(!p.validate().is_empty());
    }

    #[test]
    fn unit_conversions() {
        assert!((dbm_per_hz_to_w_per_hz(0.0) - 1e-3).abs() < 1e-12);
        assert!((db_to_lin(10.0) - 10.0).abs() < 1e-9);
        assert!((db_to_lin(-3.0) - 0.501187).abs() < 1e-5);
    }
}
