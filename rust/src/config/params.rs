//! [`SystemParams`] (paper Table I + §III–§V constants) and
//! [`ExperimentConfig`] (one experiment run).

/// All physical/algorithmic constants of the wireless FL system.
///
/// Field-by-field mapping to the paper is given inline; defaults are the
/// FEMNIST column of Table I unless noted.
#[derive(Clone, Debug)]
pub struct SystemParams {
    // ----- topology (§VI) -----
    /// U — number of clients (paper: 10).
    pub num_clients: usize,
    /// C — OFDMA channels (paper doesn't state; the Table-I constructors
    /// set C = U so full participation is possible, which the
    /// aggregation eq. (2) assumes in the no-quantization baseline).
    /// Scenario files must set this **explicitly** (see
    /// `docs/SCENARIOS.md`); [`SystemParams::validate`] rejects C = 0
    /// and C > U.
    pub num_channels: usize,
    /// Cell radius in meters (paper: 500 m circular area).
    pub cell_radius_m: f64,
    /// Number of access points serving the area. `1` is the paper's
    /// single-cell layout (distance measured from the cell center);
    /// values > 1 enable the *cell-free lite* layout of the scenario
    /// subsystem — APs are placed uniformly in the disk and each
    /// client's pathloss is taken to its **nearest** AP (cf. the
    /// cell-free adaptive-quantization setting of arXiv:2412.20785).
    pub num_aps: usize,

    // ----- communication (Table I) -----
    /// B — per-channel bandwidth in Hz (1 MHz).
    pub bandwidth_hz: f64,
    /// p — uplink transmit power in W (0.2 W).
    pub tx_power_w: f64,
    /// N0 — noise power spectral density in W/Hz (−174 dBm/Hz).
    pub noise_psd_w_hz: f64,
    /// K — Rician K-factor (4).
    pub rician_k: f64,
    /// ζ — Rician mean power (1).
    pub rician_zeta: f64,
    /// Carrier frequency in GHz (unpublished; we use 2.4 GHz).
    pub carrier_ghz: f64,
    /// h^Gain in dB — device/antenna gain "and other settings". The
    /// calibration knob (see module docs).
    pub gain_db: f64,
    /// Fraction of clients in a *deep-fade* class: a heavy extra
    /// large-scale attenuation (shadowed basements, body blockage) on
    /// top of pathloss. `0.0` — the default — reproduces the paper's
    /// homogeneous channel statistics. Class membership is
    /// deterministic (see [`SystemParams::in_deep_fade`]).
    pub deep_fade_frac: f64,
    /// Extra attenuation (dB) applied to the deep-fade class.
    pub deep_fade_db: f64,

    // ----- computation (Table I) -----
    /// α — energy coefficient (1e−26).
    pub alpha: f64,
    /// γ — CPU cycles per sample (1000 FEMNIST / 2000 CIFAR-10).
    pub gamma: f64,
    /// f^min — lower end of the CPU DVFS range in Hz (2e8).
    pub f_min: f64,
    /// f^max — upper end of the CPU DVFS range in Hz (1e9).
    pub f_max: f64,
    /// τ — local updates per round (6); τ^e — local epochs (2).
    pub tau: usize,
    /// τ^e — local epochs per round (2).
    pub tau_e: usize,
    /// T^max — per-round latency budget in seconds (0.02 FEMNIST).
    pub t_max: f64,
    /// Fraction of clients in a *CPU-straggler* class: devices whose
    /// **realized** frequency is the decided `f` scaled by
    /// [`SystemParams::straggler_slowdown`] (thermal throttling,
    /// background load). Decisions stay oblivious — as with real
    /// stragglers, the scheduler plans at nominal capability and the
    /// realized latency/energy pay the difference (cf. the
    /// heterogeneous-device setting of arXiv:2012.11070). `0.0`
    /// disables the class. Membership is deterministic (see
    /// [`SystemParams::cpu_scale`]).
    pub straggler_frac: f64,
    /// Realized-frequency multiplier for the straggler class, in
    /// (0, 1]. `1.0` (the default) is a no-op.
    pub straggler_slowdown: f64,

    // ----- model -----
    /// Z — model dimension count (profile-dependent; Table I lists
    /// 246 590 / 576 778 for the paper profiles).
    pub z: usize,

    // ----- convergence constants (§III–§IV) -----
    /// η — learning rate used in the A1/A2 constants.
    pub eta: f64,
    /// L — smoothness constant estimate (Assumption 2).
    pub lips: f64,

    // ----- Lyapunov (§V-A) -----
    /// V — drift-plus-penalty weight (Fig. 2 sweeps this).
    pub v: f64,
    /// ε1 — data-property budget (C6).
    pub eps1: f64,
    /// ε2 — quantization-error budget (C7).
    pub eps2: f64,
    /// The paper never publishes its ε values; when set, the server
    /// recalibrates ε1/ε2 once (at round 2) from the *observed* gradient
    /// statistics so that C6/C7 are tight-but-satisfiable and the queues
    /// are mean-rate stable (see EXPERIMENTS.md §Calibration).
    pub auto_eps: bool,

    // ----- quantization bounds -----
    /// Hard ceiling on integer quantization levels (wire format sanity;
    /// 32 = "effectively unquantized").
    pub q_cap: u32,
}

impl SystemParams {
    /// Table I, FEMNIST column, with the `small` profile's Z (the default
    /// experiment profile — see module docs on feasibility).
    pub fn femnist_small() -> SystemParams {
        SystemParams {
            num_clients: 10,
            num_channels: 10,
            cell_radius_m: 500.0,
            num_aps: 1,
            bandwidth_hz: 1e6,
            tx_power_w: 0.2,
            noise_psd_w_hz: dbm_per_hz_to_w_per_hz(-174.0),
            rician_k: 4.0,
            rician_zeta: 1.0,
            carrier_ghz: 2.4,
            gain_db: 10.0,
            deep_fade_frac: 0.0,
            deep_fade_db: 0.0,
            alpha: 1e-26,
            gamma: 1000.0,
            f_min: 2e8,
            f_max: 1e9,
            tau: 6,
            tau_e: 2,
            t_max: 0.02,
            straggler_frac: 0.0,
            straggler_slowdown: 1.0,
            z: 20_522,
            eta: 0.05,
            lips: 1.0,
            v: 100.0,
            eps1: 60.0,
            eps2: 0.05,
            auto_eps: true,
            q_cap: 32,
        }
    }

    /// Paper-size FEMNIST profile (Z = 246 590): T^max scaled by Z ratio
    /// so per-dimension latency pressure matches the `small` default.
    pub fn femnist_paper() -> SystemParams {
        let mut p = Self::femnist_small();
        p.z = 246_590;
        p.t_max = 0.02 * 246_590.0 / 20_522.0;
        p
    }

    /// Table I CIFAR-10 column (γ = 2000, T^max = 0.05 s) with scaled Z.
    pub fn cifar_paper() -> SystemParams {
        let mut p = Self::femnist_small();
        p.gamma = 2000.0;
        p.z = 576_778;
        p.t_max = 0.05 * 576_778.0 / 20_522.0;
        p.v = 10.0;
        p
    }

    /// CIFAR-like parameters at `small`-profile Z (default Fig. 4 runs).
    pub fn cifar_small() -> SystemParams {
        let mut p = Self::femnist_small();
        p.gamma = 2000.0;
        p.t_max = 0.05;
        p.v = 10.0;
        p
    }

    /// Tiny-profile params for unit/integration tests (Z from the tiny
    /// artifact, generous latency so every scheduler path is exercised).
    pub fn tiny_test() -> SystemParams {
        let mut p = Self::femnist_small();
        p.z = 1242;
        p.t_max = 0.01;
        p
    }

    /// Size of a deterministic client class covering fraction `frac` of
    /// the federation: `ceil(frac · U)` clients, so any positive
    /// fraction yields a non-empty class (a `round()` here would let a
    /// small `frac` silently produce a fully homogeneous run). Client
    /// placement and data are drawn per seed, so a fixed id range is an
    /// arbitrary — but reproducible and documentation-friendly —
    /// subset.
    fn class_count(&self, frac: f64) -> usize {
        if frac <= 0.0 {
            return 0;
        }
        ((frac * self.num_clients as f64).ceil() as usize).min(self.num_clients)
    }

    /// Whether `client` belongs to the deep-fade class: the **first**
    /// `ceil(deep_fade_frac · U)` client ids (see
    /// [`SystemParams::deep_fade_frac`]).
    pub fn in_deep_fade(&self, client: usize) -> bool {
        client < self.class_count(self.deep_fade_frac)
    }

    /// Realized-frequency multiplier of `client`:
    /// [`SystemParams::straggler_slowdown`] for the straggler class,
    /// `1.0` otherwise. The class is the **last**
    /// `ceil(straggler_frac · U)` client ids — the opposite end of the
    /// id range from the deep-fade class, so enabling both knobs keeps
    /// the two heterogeneity axes disjoint (until the fractions sum
    /// past 1) instead of silently confounding them on the same
    /// clients.
    pub fn cpu_scale(&self, client: usize) -> f64 {
        let k = self.class_count(self.straggler_frac);
        if client >= self.num_clients.saturating_sub(k) && client < self.num_clients {
            self.straggler_slowdown
        } else {
            1.0
        }
    }

    /// Nominal CPU frequency used by wireless-oblivious baselines that
    /// perform no frequency control (§VI: the Principle and
    /// No-Quantization baselines have no f design; a device default in
    /// the upper-middle of the DVFS range is the realistic stand-in).
    pub fn nominal_f(&self) -> f64 {
        0.6 * self.f_max
    }

    /// Bits on the wire for a q-bit quantized model: eq. (5).
    pub fn payload_bits(&self, q: u32) -> f64 {
        (self.z as f64) * (q as f64) + self.z as f64 + 32.0
    }

    /// Bits for an unquantized f32 upload (the No-Quantization baseline).
    pub fn raw_payload_bits(&self) -> f64 {
        32.0 * self.z as f64
    }

    /// Validate internal consistency; returns a list of violated
    /// conditions (empty = good). Covers the theorem prerequisites
    /// (2η²τ²L² < 1 for Theorem 2) and physical sanity.
    pub fn validate(&self) -> Vec<String> {
        let mut errs = Vec::new();
        let etl = 2.0 * self.eta * self.eta * (self.tau * self.tau) as f64 * self.lips * self.lips;
        if etl >= 1.0 {
            errs.push(format!("Theorem 2 prerequisite violated: 2η²τ²L² = {etl:.3} >= 1"));
        }
        if self.eta * self.lips >= 1.0 {
            errs.push(format!(
                "Theorem 1 prerequisite violated: ηL = {} >= 1",
                self.eta * self.lips
            ));
        }
        if self.f_min <= 0.0 || self.f_min > self.f_max {
            errs.push("need 0 < f_min <= f_max".into());
        }
        if self.tau % self.tau_e != 0 {
            errs.push(format!("τ = {} must be a multiple of τ^e = {}", self.tau, self.tau_e));
        }
        if self.num_channels == 0 || self.num_clients == 0 {
            errs.push("need at least one client and one channel".into());
        }
        if self.num_channels > self.num_clients {
            errs.push(format!(
                "C = {} channels exceeds U = {} clients (idle channels are \
                 unreachable by C1–C3; set C <= U explicitly)",
                self.num_channels, self.num_clients
            ));
        }
        if self.num_aps == 0 {
            errs.push("need at least one access point".into());
        }
        if !(0.0..=1.0).contains(&self.deep_fade_frac) {
            errs.push(format!("deep_fade_frac = {} outside [0, 1]", self.deep_fade_frac));
        }
        if self.deep_fade_db < 0.0 {
            errs.push(format!(
                "deep_fade_db = {} must be non-negative (the class is an *attenuation*; \
                 a negative value would silently amplify it)",
                self.deep_fade_db
            ));
        }
        if !(0.0..=1.0).contains(&self.straggler_frac) {
            errs.push(format!("straggler_frac = {} outside [0, 1]", self.straggler_frac));
        }
        if !(self.straggler_slowdown > 0.0 && self.straggler_slowdown <= 1.0) {
            errs.push(format!(
                "straggler_slowdown = {} outside (0, 1]",
                self.straggler_slowdown
            ));
        }
        if self.t_max <= 0.0 {
            errs.push("T^max must be positive".into());
        }
        errs
    }
}

/// dBm/Hz → W/Hz (−174 dBm/Hz ≈ 3.98e−21 W/Hz).
pub fn dbm_per_hz_to_w_per_hz(dbm: f64) -> f64 {
    10f64.powf(dbm / 10.0) * 1e-3
}

/// dB → linear power ratio.
pub fn db_to_lin(db: f64) -> f64 {
    10f64.powf(db / 10.0)
}

/// One experiment run (an algorithm on a task profile).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Artifact profile name (`tiny`/`small`/`femnist`/`cifar`).
    pub profile: String,
    /// Scheduling algorithm (see `sched`/`baselines`).
    pub algorithm: String,
    /// Communication rounds N.
    pub rounds: usize,
    /// µ — mean dataset size (paper: 1200).
    pub data_mean: f64,
    /// β — dataset size std (paper: 150 or 300).
    pub data_std: f64,
    /// Dirichlet α for label skew (non-IID; paper just says non-IID).
    pub dirichlet_alpha: f64,
    /// Test set size.
    pub test_size: usize,
    /// Evaluate every k rounds.
    pub eval_every: usize,
    /// Master seed.
    pub seed: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            profile: "small".into(),
            algorithm: "qccf".into(),
            rounds: 60,
            data_mean: 1200.0,
            data_std: 150.0,
            dirichlet_alpha: 0.5,
            test_size: 512,
            eval_every: 2,
            seed: 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_i_values() {
        let p = SystemParams::femnist_small();
        assert_eq!(p.num_clients, 10);
        assert_eq!(p.bandwidth_hz, 1e6);
        assert_eq!(p.tx_power_w, 0.2);
        assert!((p.noise_psd_w_hz - 3.9810717e-21).abs() < 1e-27);
        assert_eq!(p.rician_k, 4.0);
        assert_eq!(p.alpha, 1e-26);
        assert_eq!(p.gamma, 1000.0);
        assert_eq!((p.f_min, p.f_max), (2e8, 1e9));
        assert_eq!((p.tau, p.tau_e), (6, 2));
        assert_eq!(p.t_max, 0.02);
    }

    #[test]
    fn paper_profiles_z() {
        assert_eq!(SystemParams::femnist_paper().z, 246_590);
        assert_eq!(SystemParams::cifar_paper().z, 576_778);
        assert_eq!(SystemParams::cifar_paper().gamma, 2000.0);
    }

    #[test]
    fn payload_bits_eq5() {
        let p = SystemParams::tiny_test();
        // eq. (5): ℓ = Z q + Z + 32.
        assert_eq!(p.payload_bits(8), 1242.0 * 8.0 + 1242.0 + 32.0);
        assert_eq!(p.raw_payload_bits(), 32.0 * 1242.0);
    }

    #[test]
    fn defaults_validate() {
        for p in [
            SystemParams::femnist_small(),
            SystemParams::femnist_paper(),
            SystemParams::cifar_paper(),
            SystemParams::cifar_small(),
            SystemParams::tiny_test(),
        ] {
            let errs = p.validate();
            assert!(errs.is_empty(), "{errs:?}");
        }
    }

    #[test]
    fn validate_catches_bad_theorem_prereq() {
        let mut p = SystemParams::femnist_small();
        p.eta = 0.2;
        p.lips = 2.0;
        assert!(!p.validate().is_empty());
    }

    #[test]
    fn validate_catches_channel_count_misuse() {
        let mut p = SystemParams::femnist_small();
        p.num_channels = 0;
        assert!(p.validate().iter().any(|e| e.contains("at least one")));
        p.num_channels = p.num_clients + 1;
        assert!(p.validate().iter().any(|e| e.contains("exceeds U")), "{:?}", p.validate());
        p.num_channels = p.num_clients;
        assert!(p.validate().is_empty());
    }

    #[test]
    fn heterogeneity_classes_deterministic_and_disjoint() {
        let mut p = SystemParams::femnist_small();
        // Defaults: nobody faded, nobody throttled.
        assert!((0..10).all(|i| !p.in_deep_fade(i)));
        assert!((0..10).all(|i| p.cpu_scale(i) == 1.0));
        p.deep_fade_frac = 0.3;
        p.deep_fade_db = 18.0;
        p.straggler_frac = 0.2;
        p.straggler_slowdown = 0.5;
        assert!(p.validate().is_empty());
        assert_eq!((0..10).filter(|&i| p.in_deep_fade(i)).count(), 3);
        assert_eq!((0..10).filter(|&i| p.cpu_scale(i) < 1.0).count(), 2);
        // Fade is an id-prefix, stragglers an id-suffix — the two axes
        // stay disjoint instead of confounding on the same clients.
        assert!(p.in_deep_fade(0) && !p.in_deep_fade(3));
        assert_eq!(p.cpu_scale(8), 0.5);
        assert_eq!(p.cpu_scale(9), 0.5);
        assert_eq!(p.cpu_scale(0), 1.0);
        assert!((0..10).all(|i| !(p.in_deep_fade(i) && p.cpu_scale(i) < 1.0)));
    }

    #[test]
    fn small_positive_fractions_still_populate_classes() {
        // ceil semantics: any frac > 0 yields at least one member — a
        // round() here made straggler_frac = 0.04 silently homogeneous.
        let mut p = SystemParams::femnist_small();
        p.straggler_frac = 0.04;
        p.straggler_slowdown = 0.5;
        p.deep_fade_frac = 0.04;
        p.deep_fade_db = 10.0;
        assert_eq!((0..10).filter(|&i| p.cpu_scale(i) < 1.0).count(), 1);
        assert_eq!((0..10).filter(|&i| p.in_deep_fade(i)).count(), 1);
        // frac = 1.0 covers everyone.
        p.straggler_frac = 1.0;
        assert!((0..10).all(|i| p.cpu_scale(i) < 1.0));
    }

    #[test]
    fn validate_catches_bad_class_knobs() {
        let mut p = SystemParams::femnist_small();
        p.straggler_slowdown = 0.0;
        assert!(!p.validate().is_empty());
        let mut p = SystemParams::femnist_small();
        p.deep_fade_frac = 1.5;
        assert!(!p.validate().is_empty());
    }

    #[test]
    fn unit_conversions() {
        assert!((dbm_per_hz_to_w_per_hz(0.0) - 1e-3).abs() < 1e-12);
        assert!((db_to_lin(10.0) - 10.0).abs() < 1e-9);
        assert!((db_to_lin(-3.0) - 0.501187).abs() < 1e-5);
    }
}
