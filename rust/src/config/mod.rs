//! System configuration — paper Table I verbatim, plus the convergence /
//! Lyapunov knobs the optimization needs (§IV–§V).
//!
//! A note on feasibility (recorded here because it shapes the defaults;
//! see EXPERIMENTS.md §Calibration): with Table I taken literally
//! (B = 1 MHz, T^max = 0.02 s, Z = 246 590), the latency constraint C4 is
//! infeasible *even at q = 1* — the minimum payload Z(q+1)+32 ≈ 0.49 Mb
//! needs ≈ 25 Mb/s, i.e. an SNR of ~74 dB, and any q ≳ 2 needs a rate no
//! 1 MHz channel can carry. The paper does not publish its h^Gain or
//! carrier frequency, so we (a) expose `gain_db` as the calibration knob,
//! and (b) default the experiment profile to Z ≈ 20 k (`small`), where
//! Table I's remaining numbers yield exactly the q ∈ [1, 16] dynamic
//! range the paper's Fig. 5 shows. The paper-size profiles scale T^max
//! proportionally to Z (same bits-per-second pressure per dimension).

pub mod params;

pub use params::{ExperimentConfig, SystemParams};
