//! Solver unit + property tests. The key invariant: the closed-form KKT
//! pipeline (5 cases + Theorem-3 rounding) must land on the brute-force
//! integer optimum, across randomized channel/queue/dataset regimes.

use super::*;
use crate::util::prop;
use crate::util::rng::Rng;

fn params() -> SystemParams {
    SystemParams::femnist_small()
}

fn ctx(d_i: f64, rate: f64) -> ClientCtx {
    ClientCtx { d_i, w_round: 0.1, rate, theta_max: 0.5, q_prev: 6.0 }
}

fn rand_regime(rng: &mut Rng) -> (SystemParams, f64, ClientCtx) {
    let mut p = params();
    p.v = 10f64.powf(rng.range(0.0, 3.0));
    let lambda2 = p.eps2 + 10f64.powf(rng.range(-3.0, 3.5)) - if rng.chance(0.15) { 2.0 * p.eps2 } else { 0.0 };
    let c = ClientCtx {
        d_i: rng.range(300.0, 2500.0),
        w_round: rng.range(0.02, 0.5),
        rate: rng.range(8e6, 40e6),
        theta_max: rng.range(0.05, 2.0),
        q_prev: rng.range(1.0, 14.0),
    };
    (p, lambda2, c)
}

#[test]
fn q_max_feasible_monotone_in_rate() {
    let p = params();
    let q_lo = q_max_feasible(&p, 1200.0, 10e6);
    let q_hi = q_max_feasible(&p, 1200.0, 30e6).unwrap();
    if let Some(q_lo) = q_lo {
        assert!(q_hi >= q_lo);
    }
    // Terrible rate ⇒ infeasible.
    assert_eq!(q_max_feasible(&p, 1200.0, 0.2e6), None);
}

#[test]
fn q_max_feasible_respects_deadline() {
    let p = params();
    let q = q_max_feasible(&p, 1200.0, 20e6).unwrap();
    // The returned q must be feasible, q+1 must not be.
    assert!(energy::s_of_q(&p, 1200.0, q, 20e6).is_some());
    if q < p.q_cap {
        assert!(energy::s_of_q(&p, 1200.0, q + 1, 20e6).is_none());
    }
}

#[test]
fn empty_queue_forces_q1() {
    // λ2 = 0 < ε2 ⇒ error term worthless ⇒ Case 1, q = 1.
    let p = params();
    let d = solve_client(&p, 0.0, &ctx(1200.0, 20e6), Case5Mode::Bisect).unwrap();
    assert_eq!(d.case, 1);
    assert_eq!(d.q, 1);
}

#[test]
fn huge_queue_pushes_q_up() {
    let p = params();
    let c = ctx(1200.0, 20e6);
    let d_small = solve_client(&p, p.eps2 + 0.5, &c, Case5Mode::Bisect).unwrap();
    let d_large = solve_client(&p, p.eps2 + 5e3, &c, Case5Mode::Bisect).unwrap();
    assert!(
        d_large.q > d_small.q,
        "λ2 growth must raise q: {} vs {}",
        d_large.q,
        d_small.q
    );
}

#[test]
fn remark1_q_rises_with_queue_trajectory() {
    // Remark 1: with λ2 rising (pre-equilibrium), q̂ rises.
    let p = params();
    let c = ctx(1200.0, 20e6);
    let mut prev = 0.0;
    for step in 1..8 {
        let lambda2 = p.eps2 + (step as f64) * 2.0;
        let (q_hat, _, _) = solve_continuous(&p, lambda2, &c, Case5Mode::Bisect).unwrap();
        assert!(q_hat >= prev, "step {step}: q̂ {q_hat} < {prev}");
        prev = q_hat;
    }
}

#[test]
fn remark2_q_negatively_correlated_with_dataset_size() {
    // Remark 2: larger D_i ⇒ lower q (compute eats the latency budget).
    let p = params();
    let lambda2 = p.eps2 + 50.0;
    let q_small_d = solve_client(&p, lambda2, &ctx(600.0, 15e6), Case5Mode::Bisect).unwrap();
    let q_large_d = solve_client(&p, lambda2, &ctx(2400.0, 15e6), Case5Mode::Bisect).unwrap();
    assert!(
        q_small_d.q >= q_large_d.q,
        "D=600 ⇒ q={}, D=2400 ⇒ q={}",
        q_small_d.q,
        q_large_d.q
    );
}

#[test]
fn infeasible_client_returns_none() {
    let p = params();
    // Rate so low the q=1 payload alone blows T^max.
    assert!(solve_client(&p, 1.0, &ctx(1200.0, 0.5e6), Case5Mode::Bisect).is_none());
    // Dataset so large computation alone blows T^max even at f^max:
    // τ^e γ D / f^max > T^max ⇔ D > 0.02 * 1e9 / 2000 = 10 000.
    assert!(solve_client(&p, 1.0, &ctx(50_000.0, 20e6), Case5Mode::Bisect).is_none());
}

#[test]
fn decision_always_feasible() {
    prop::check("decision-feasible", prop::iters(400), rand_regime, |(p, l2, c)| {
        if let Some(d) = solve_client(p, *l2, c, Case5Mode::Bisect) {
            let lat = energy::client_latency(p, c.d_i, d.f, d.q, c.rate);
            if lat > p.t_max * (1.0 + 1e-9) {
                return Err(format!("latency {lat} > {}", p.t_max));
            }
            if d.f < p.f_min * (1.0 - 1e-12) || d.f > p.f_max * (1.0 + 1e-12) {
                return Err(format!("f {} out of range", d.f));
            }
            if d.q < 1 {
                return Err("q < 1".into());
            }
        }
        Ok(())
    });
}

#[test]
fn closed_form_matches_brute_force() {
    prop::check("kkt-vs-brute", prop::iters(400), rand_regime, |(p, l2, c)| {
        let closed = solve_client(p, *l2, c, Case5Mode::Bisect);
        let brute = solve_brute(p, *l2, c);
        match (closed, brute) {
            (None, None) => Ok(()),
            (Some(d), Some((qb, _fb, jb))) => {
                // Equal objective (ties between adjacent q are fine).
                let rel = (d.j3 - jb).abs() / jb.abs().max(1e-12);
                if rel < 1e-6 || d.q == qb {
                    Ok(())
                } else {
                    Err(format!(
                        "closed form q={} j3={} (case {}) vs brute q={qb} j3={jb}",
                        d.q, d.j3, d.case
                    ))
                }
            }
            (a, b) => Err(format!("feasibility mismatch: {a:?} vs {b:?}")),
        }
    });
}

#[test]
fn taylor_case5_close_to_exact_near_anchor() {
    // Eq. (39) is a first-order step: with q_prev near the true root it
    // must land close to the bisection answer.
    let p = params();
    let lambda2 = p.eps2 + 2e3;
    let mut c = ctx(1600.0, 14e6);
    // Find the exact case-5 root first.
    if let Some((q_exact, _, case)) = solve_continuous(&p, lambda2, &c, Case5Mode::Bisect) {
        if case == 5 {
            c.q_prev = q_exact + 0.4;
            let (q_taylor, _, case_t) =
                solve_continuous(&p, lambda2, &c, Case5Mode::Taylor).unwrap();
            if case_t == 5 {
                assert!(
                    (q_taylor - q_exact).abs() < 0.5,
                    "taylor {q_taylor} vs exact {q_exact}"
                );
            }
        }
    }
}

#[test]
fn integer_round_is_floor_or_ceil() {
    prop::check("thm3-floor-ceil", prop::iters(300), rand_regime, |(p, l2, c)| {
        if let Some((q_hat, _, _)) = solve_continuous(p, *l2, c, Case5Mode::Bisect) {
            if let Some((q, _, _)) = integer_round(p, *l2, c, q_hat) {
                let q_max = q_max_feasible(p, c.d_i, c.rate).unwrap();
                let lo = (q_hat.floor().max(1.0) as u32).min(q_max);
                let hi = (q_hat.ceil().max(1.0) as u32).min(q_max);
                if q != lo && q != hi {
                    return Err(format!("q={q} not in {{{lo},{hi}}} (q̂={q_hat})"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn cases_cover_all_regimes() {
    // Over the random regimes, the solver must exercise several distinct
    // KKT cases (not fall through to brute force everywhere).
    let mut seen = [0usize; 6];
    let mut rng = Rng::seed_from(77);
    for _ in 0..600 {
        let (p, l2, c) = rand_regime(&mut rng);
        if let Some((_, _, case)) = solve_continuous(&p, l2, &c, Case5Mode::Bisect) {
            seen[case] += 1;
        }
    }
    let distinct = seen.iter().filter(|&&n| n > 0).count();
    assert!(distinct >= 3, "case histogram {seen:?}");
    assert!(seen[1] > 0, "case 1 never fired: {seen:?}");
    // Brute fallback should be rare.
    let total: usize = seen.iter().sum();
    assert!(seen[0] * 10 <= total, "fallback dominates: {seen:?}");
}

#[test]
fn j3_matches_formula() {
    let p = params();
    let c = ctx(1200.0, 20e6);
    let lambda2 = p.eps2 + 3.0;
    let q = 4.0;
    let f = 5e8;
    let l = 15.0;
    let want = (lambda2 - p.eps2) * c.w_round * p.z as f64 * p.lips * c.theta_max * c.theta_max
        / (8.0 * l * l)
        + p.v * p.tau_e as f64 * p.alpha * p.gamma * c.d_i * f * f
        + p.tx_power_w * p.v * p.z as f64 * q / c.rate;
    assert!((j3(&p, lambda2, &c, q, f) - want).abs() < 1e-12);
}
