//! The depressed cubic behind Case 2 (paper eq. (41), second row).
//!
//! The q-stationarity condition `pV/v = E ln2 · 2^q / (4(2^q − 1)³)`
//! with `t = 2^q − 1` becomes `t³ = A4 (t + 1)`, i.e. the depressed cubic
//! `t³ − A4·t − A4 = 0` with `A4 = v E ln2 / (4 p V)`.
//!
//! For `A4 < 27/4` Cardano's discriminant is positive and the paper's
//! closed form applies; for larger A4 there are three real roots and we
//! take the unique **positive** one via the trigonometric method (the
//! paper's formula silently assumes the first branch).

/// Positive real root of `t³ − a4·t − a4 = 0` for `a4 > 0`.
pub fn positive_root(a4: f64) -> f64 {
    debug_assert!(a4 > 0.0);
    // Depressed cubic t³ + p t + q with p = −a4, q = −a4.
    let disc = 0.25 - a4 / 27.0; // (q/2)² + (p/3)³ scaled by a4²: see below
    if disc >= 0.0 {
        // Cardano, in the paper's exact form:
        // t = ∛A4 ( ∛(1/2 + √(1/4 − A4/27)) + ∛(1/2 − √(1/4 − A4/27)) ).
        let s = disc.sqrt();
        let c1 = (0.5 + s).cbrt();
        let c2 = (0.5 - s).cbrt();
        a4.cbrt() * (c1 + c2)
    } else {
        // Three real roots: t_k = 2√(a4/3) cos(φ/3 − 2πk/3) with
        // cos φ = (a4/2) / (a4/3)^{3/2}; k = 0 gives the largest
        // (positive) root.
        let m = 2.0 * (a4 / 3.0).sqrt();
        let cos_phi = (0.5 * a4) / (a4 / 3.0).powf(1.5);
        let phi = cos_phi.clamp(-1.0, 1.0).acos();
        m * (phi / 3.0).cos()
    }
}

/// Residual of the cubic (for verification).
pub fn residual(t: f64, a4: f64) -> f64 {
    t * t * t - a4 * t - a4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn root_satisfies_cubic_small_a4() {
        for a4 in [1e-6, 0.01, 0.5, 1.0, 5.0, 6.74] {
            let t = positive_root(a4);
            assert!(t > 0.0, "a4={a4} t={t}");
            let r = residual(t, a4);
            assert!(r.abs() < 1e-9 * (1.0 + a4), "a4={a4} residual={r}");
        }
    }

    #[test]
    fn root_satisfies_cubic_large_a4_trig_branch() {
        for a4 in [6.76, 10.0, 100.0, 1e4, 1e8] {
            let t = positive_root(a4);
            assert!(t > 0.0, "a4={a4}");
            let r = residual(t, a4) / (t * t * t);
            assert!(r.abs() < 1e-9, "a4={a4} rel residual={r}");
        }
    }

    #[test]
    fn boundary_a4_at_half_gives_t_one() {
        // t = 1 ⇔ 1 − A4 − A4 = 0 ⇔ A4 = 1/2 — the Case-1/Case-2
        // boundary (q̂ = log2(1 + t) = 1).
        let t = positive_root(0.5);
        assert!((t - 1.0).abs() < 1e-12, "t={t}");
    }

    #[test]
    fn monotone_in_a4() {
        let mut prev = 0.0;
        for i in 1..200 {
            let a4 = i as f64 * 0.25;
            let t = positive_root(a4);
            assert!(t > prev, "a4={a4}");
            prev = t;
        }
    }

    #[test]
    fn matches_newton_property() {
        prop::check(
            "cubic-vs-newton",
            prop::iters(300),
            |rng| 10f64.powf(rng.range(-6.0, 9.0)),
            |&a4| {
                let t = positive_root(a4);
                // Newton from a safe start.
                let mut x = t.max(1.0) * 2.0;
                for _ in 0..200 {
                    let fx = residual(x, a4);
                    let dfx = 3.0 * x * x - a4;
                    if dfx.abs() < 1e-300 {
                        break;
                    }
                    let nx = x - fx / dfx;
                    if (nx - x).abs() < 1e-14 * x.abs() {
                        x = nx;
                        break;
                    }
                    x = nx;
                }
                if ((t - x) / x.max(1e-12)).abs() > 1e-6 {
                    Err(format!("closed form {t} vs newton {x} (a4={a4})"))
                } else {
                    Ok(())
                }
            },
        );
    }
}
