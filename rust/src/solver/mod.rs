//! Closed-form per-client solver for the continuous subproblem **P3.2″**
//! (paper §V-C): given a channel allocation (hence a rate v_i^n) and the
//! queue state λ2, choose the quantization level q and CPU frequency f
//! minimizing
//!
//! `J₃(f, q) = (λ2−ε2) w_i^n Z L (θ^max)² / (8(2^q−1)²)
//!             + V τ^e α γ D_i f² + p V Z q / v`
//!
//! subject to C4′ (latency), C5 (f range), C8′ (q ≥ 1) — via the five
//! exhaustive KKT cases of eq. (41), then re-integerized with Theorem 3
//! (eq. (42)). A brute-force integer scan backs the closed form both as a
//! numerical-fallback path and as the test oracle.

pub mod cubic;

use crate::config::SystemParams;
use crate::energy;

/// Per-client inputs to the solver for one round.
#[derive(Clone, Copy, Debug)]
pub struct ClientCtx {
    /// D_i — dataset size (samples).
    pub d_i: f64,
    /// w_i^n — aggregation weight among the round's participants.
    pub w_round: f64,
    /// v_i^n — uplink rate of the allocated channel (bit/s).
    pub rate: f64,
    /// θ_i^{n,max} — current L∞ range of the client's model.
    pub theta_max: f64,
    /// q from this client's previous participation (Case-5 Taylor anchor,
    /// eq. (39)).
    pub q_prev: f64,
}

/// Which KKT case produced the solution (0 = brute-force fallback).
pub type CaseId = usize;

/// Solver output: integer decision + diagnostics.
///
/// [`solve_client`] is a *pure* function of `(params, λ2, ClientCtx,
/// mode)` — same inputs, bit-identical `Decision` — which is what lets
/// the decision stage memoize it on exact f64-bit keys
/// (`sched::ctx`): a memo hit replays the identical decision, never an
/// approximation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Decision {
    /// Integer quantization level q_i^n* (C8).
    pub q: u32,
    /// CPU frequency f_i^n* (Hz).
    pub f: f64,
    /// Continuous optimum q̂ before Theorem-3 rounding.
    pub q_hat: f64,
    /// KKT case that fired (1..=5; 0 = brute fallback).
    pub case: CaseId,
    /// Objective value J₃ at the integer decision.
    pub j3: f64,
}

/// How Case 5's transcendental eq. (38) is solved.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Case5Mode {
    /// Paper-faithful: one first-order Taylor step around q_prev (eq. 39).
    Taylor,
    /// Exact: bisection on the (strictly decreasing) stationarity residual.
    Bisect,
}

/// J₃ objective (eq. (31)) at a concrete (q, f); `lambda2` is λ2.
pub fn j3(p: &SystemParams, lambda2: f64, ctx: &ClientCtx, q: f64, f: f64) -> f64 {
    let l = (2f64).powf(q) - 1.0;
    let err = (lambda2 - p.eps2) * ctx.w_round * (p.z as f64) * p.lips * ctx.theta_max
        * ctx.theta_max
        / (8.0 * l * l);
    let cmp = p.v * p.tau_e as f64 * p.alpha * p.gamma * ctx.d_i * f * f;
    let com = p.tx_power_w * p.v * (p.z as f64) * q / ctx.rate;
    err + cmp + com
}

/// Largest integer q (≥1, ≤ q_cap) for which a feasible f exists;
/// `None` when even q = 1 cannot meet C4′.
pub fn q_max_feasible(p: &SystemParams, d_i: f64, rate: f64) -> Option<u32> {
    if energy::s_of_q(p, d_i, 1, rate).is_none() {
        return None;
    }
    // Deadline with f = f^max: v·T − v·τ^e γ D / f^max − Z − 32 ≥ Z·q.
    let slack = rate * p.t_max - rate * p.tau_e as f64 * p.gamma * d_i / p.f_max
        - p.z as f64
        - 32.0;
    let q = (slack / p.z as f64).floor();
    if q < 1.0 {
        None // s_of_q(1) succeeded ⇒ q ≥ 1; guard against fp edge.
    } else {
        Some((q as u32).min(p.q_cap).max(1))
    }
}

/// The error-term coefficient `E = (λ2−ε2) w L (θ^max)²` and the cubic
/// constant `A4 = v E ln2 / (4 p V)` (paper, below eq. (35)).
fn a4(p: &SystemParams, lambda2: f64, ctx: &ClientCtx) -> f64 {
    let e = (lambda2 - p.eps2) * ctx.w_round * p.lips * ctx.theta_max * ctx.theta_max;
    ctx.rate * e * std::f64::consts::LN_2 / (4.0 * p.tx_power_w * p.v)
}

/// κ1 + pV from the q-stationarity row of eq. (33):
/// `v E ln2 · 2^q / (4 (2^q − 1)³)` — the marginal value of raising q.
fn marginal_value(p: &SystemParams, lambda2: f64, ctx: &ClientCtx, q: f64) -> f64 {
    let e = (lambda2 - p.eps2) * ctx.w_round * p.lips * ctx.theta_max * ctx.theta_max;
    let l = (2f64).powf(q) - 1.0;
    ctx.rate * e * std::f64::consts::LN_2 * (2f64).powf(q) / (4.0 * l * l * l)
}

/// C4′-equality frequency for continuous q (no f^min clamp):
/// `f(q) = v τ^e γ D / (v T^max − Z q − Z − 32)`; `None` if the payload
/// alone exceeds the deadline.
fn f_deadline(p: &SystemParams, ctx: &ClientCtx, q: f64) -> Option<f64> {
    let den = ctx.rate * p.t_max - p.z as f64 * q - p.z as f64 - 32.0;
    if den <= 0.0 {
        return None;
    }
    Some(ctx.rate * p.tau_e as f64 * p.gamma * ctx.d_i / den)
}

/// The continuous solution (q̂, f̂) of P3.2″ via the 5 KKT cases.
/// Returns `(q_hat, f_hat, case)`. `None` ⇒ q = 1 itself is infeasible.
pub fn solve_continuous(
    p: &SystemParams,
    lambda2: f64,
    ctx: &ClientCtx,
    mode: Case5Mode,
) -> Option<(f64, f64, CaseId)> {
    // Feasibility gate: C4′ must admit q = 1 at some f ∈ [f^min, f^max].
    let f1 = energy::s_of_q(p, ctx.d_i, 1, ctx.rate)?;

    let a4v = a4(p, lambda2, ctx);

    // ---- Case 1: C8′ strict (q̂ = 1). Pre1 ⇔ marginal value of q at
    // q = 1 does not exceed the marginal comm cost ⇔ A4 ≤ 1/2.
    // (Also fires whenever λ2 ≤ ε2, where the error term is worthless.)
    if a4v <= 0.5 {
        return Some((1.0, f1, 1));
    }

    // ---- Case 2: interior q, C4′ loose ⇒ f = f^min (Lemma 3).
    let t = cubic::positive_root(a4v);
    let q2 = (1.0 + t).log2();
    if q2 > 1.0 {
        // Pre2: C4′ loose at (f^min, q̂2).
        let latency = p.tau_e as f64 * p.gamma * ctx.d_i / p.f_min
            + (p.z as f64 * (q2 + 1.0) + 32.0) / ctx.rate;
        if latency < p.t_max {
            return Some((q2, p.f_min, 2));
        }
    }

    // C4′ binds from here on: f = f(q) on the deadline surface.
    // ---- Case 3: f pinned at f^max.
    if let Some(q3) = deadline_q(p, ctx, p.f_max) {
        if q3 > 1.0 {
            let kappa1 = marginal_value(p, lambda2, ctx, q3) - p.tx_power_w * p.v;
            if kappa1 >= 0.0 && kappa1 >= 2.0 * p.v * p.alpha * p.f_max.powi(3) {
                return Some((q3, p.f_max, 3));
            }
        }
    }

    // ---- Case 4: f pinned at f^min.
    if let Some(q4) = deadline_q(p, ctx, p.f_min) {
        if q4 > 1.0 {
            let kappa1 = marginal_value(p, lambda2, ctx, q4) - p.tx_power_w * p.v;
            if kappa1 >= 0.0 && kappa1 <= 2.0 * p.v * p.alpha * p.f_min.powi(3) {
                return Some((q4, p.f_min, 4));
            }
        }
    }

    // ---- Case 5: interior f on the deadline surface — eq. (38).
    let q5 = match mode {
        Case5Mode::Taylor => case5_taylor(p, lambda2, ctx),
        Case5Mode::Bisect => case5_bisect(p, lambda2, ctx),
    };
    if let Some(q5) = q5 {
        if q5 > 1.0 {
            if let Some(f5) = f_deadline(p, ctx, q5) {
                if f5 > p.f_min && f5 < p.f_max {
                    return Some((q5, f5, 5));
                }
            }
        }
    }

    // Numerical fallback (ill-conditioned boundaries): brute-force the
    // integer problem directly; report the brute optimum as "case 0".
    let (q, f, _) = solve_brute(p, lambda2, ctx)?;
    Some((q as f64, f, 0))
}

/// q on the C4′ deadline at a pinned f (Cases 3 & 4):
/// `q = (v T^max − v τ^e γ D / f − Z − 32) / Z`.
fn deadline_q(p: &SystemParams, ctx: &ClientCtx, f: f64) -> Option<f64> {
    let q = (ctx.rate * p.t_max - ctx.rate * p.tau_e as f64 * p.gamma * ctx.d_i / f
        - p.z as f64
        - 32.0)
        / p.z as f64;
    if q.is_finite() {
        Some(q)
    } else {
        None
    }
}

/// Paper eq. (39): one Newton/Taylor step of eq. (38) around q_prev.
fn case5_taylor(p: &SystemParams, lambda2: f64, ctx: &ClientCtx) -> Option<f64> {
    let qp = ctx.q_prev.max(1.0);
    let fq = f_deadline(p, ctx, qp)?;
    let e = (lambda2 - p.eps2) * ctx.w_round * p.lips * ctx.theta_max * ctx.theta_max;
    let ln2 = std::f64::consts::LN_2;
    let two_q = (2f64).powf(qp);
    let l = two_q - 1.0;
    // Numerator: g(q_prev) = RHS − LHS of eq. (38) at q_prev.
    let rhs = ctx.rate * e * ln2 * two_q / (4.0 * p.v * l * l * l);
    let num = rhs - 2.0 * p.alpha * fq.powi(3) - p.tx_power_w;
    // Denominator: −g′(q_prev). Note a typo in the paper's eq. (39):
    // it prints (2·2^{2q̂}+1) where differentiating eq. (38)'s RHS
    // C·2^q/(2^q−1)³ gives −RHS′ = C ln2 · 2^q (2·2^q+1)/(2^q−1)⁴ —
    // the paper's extra 2^q factor shrinks the Newton step by ~2^q and
    // the across-round fixed-point iteration crawls. We use the correct
    // derivative (DESIGN.md §6b).
    let d_rhs = ctx.rate * e * ln2 * ln2 * (2.0 * two_q + 1.0) * two_q
        / (4.0 * p.v * l * l * l * l);
    let den_c4 = ctx.rate * p.t_max - p.z as f64 * qp - p.z as f64 - 32.0;
    let d_lhs = 6.0 * p.alpha * p.z as f64 * (ctx.rate * p.tau_e as f64 * p.gamma * ctx.d_i).powi(3)
        / den_c4.powi(4);
    if d_rhs + d_lhs <= 0.0 {
        return None;
    }
    Some(qp + num / (d_rhs + d_lhs))
}

/// Exact Case-5 root of eq. (38) by bisection: the residual
/// `g(q) = RHS(q) − p − 2α f(q)³` is strictly decreasing in q.
fn case5_bisect(p: &SystemParams, lambda2: f64, ctx: &ClientCtx) -> Option<f64> {
    // Residual of eq. (38): RHS − LHS with RHS = marginal_value / V.
    let g = |q: f64| -> Option<f64> {
        let fq = f_deadline(p, ctx, q)?;
        Some(marginal_value(p, lambda2, ctx, q) / p.v
            - p.tx_power_w
            - 2.0 * p.alpha * fq.powi(3))
    };
    // Upper bound: q where f(q) = f_max.
    let q_hi = deadline_q(p, ctx, p.f_max)?;
    let q_lo = 1.0;
    if q_hi <= q_lo {
        return None;
    }
    let g_lo = g(q_lo)?;
    let g_hi = g(q_hi)?;
    if g_lo <= 0.0 || g_hi >= 0.0 {
        return None; // root not interior — another case applies
    }
    let (mut lo, mut hi) = (q_lo, q_hi);
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        match g(mid) {
            Some(gm) if gm > 0.0 => lo = mid,
            Some(_) => hi = mid,
            None => hi = mid,
        }
        if hi - lo < 1e-10 {
            break;
        }
    }
    Some(0.5 * (lo + hi))
}

/// Theorem 3 (eq. (42)): integerize q̂ by comparing J₃ at ⌊q̂⌋ and ⌈q̂⌉
/// with the per-q optimal frequency 𝒮(q).
pub fn integer_round(
    p: &SystemParams,
    lambda2: f64,
    ctx: &ClientCtx,
    q_hat: f64,
) -> Option<(u32, f64, f64)> {
    let q_max = q_max_feasible(p, ctx.d_i, ctx.rate)?;
    let lo = (q_hat.floor().max(1.0) as u32).min(q_max);
    let hi = (q_hat.ceil().max(1.0) as u32).min(q_max);
    let mut best: Option<(u32, f64, f64)> = None;
    for q in [lo, hi] {
        if let Some(f) = energy::s_of_q(p, ctx.d_i, q, ctx.rate) {
            let val = j3(p, lambda2, ctx, q as f64, f);
            if best.map(|(_, _, b)| val < b).unwrap_or(true) {
                best = Some((q, f, val));
            }
        }
    }
    best
}

/// Full per-client solve: continuous KKT cases + Theorem-3 rounding.
pub fn solve_client(
    p: &SystemParams,
    lambda2: f64,
    ctx: &ClientCtx,
    mode: Case5Mode,
) -> Option<Decision> {
    let (q_hat, _f_hat, case) = solve_continuous(p, lambda2, ctx, mode)?;
    let (q, f, j) = integer_round(p, lambda2, ctx, q_hat)?;
    Some(Decision { q, f, q_hat, case, j3: j })
}

/// Inverse of the q-stationarity condition: the λ2 at which the
/// (unconstrained) continuous optimum equals `q` for a client with the
/// given rate / weight / range. Used to warm-start the λ2 queue below
/// its equilibrium so the level trajectory rises (Remark 1) instead of
/// jumping to the stationary point.
pub fn lambda2_for_target_q(
    p: &SystemParams,
    q: f64,
    rate: f64,
    w_round: f64,
    theta_max: f64,
) -> f64 {
    // Stationarity: A4 = (2^q − 1)³ / 2^q with
    // A4 = v (λ2 − ε2) w L θ² ln2 / (4 p V).
    let two_q = (2f64).powf(q);
    let l = two_q - 1.0;
    let a4 = l * l * l / two_q;
    p.eps2
        + a4 * 4.0 * p.tx_power_w * p.v
            / (rate * w_round * p.lips * theta_max * theta_max * std::f64::consts::LN_2)
}

/// Test oracle & fallback: exhaustive integer scan of q with f = 𝒮(q).
pub fn solve_brute(p: &SystemParams, lambda2: f64, ctx: &ClientCtx) -> Option<(u32, f64, f64)> {
    let q_max = q_max_feasible(p, ctx.d_i, ctx.rate)?;
    let mut best: Option<(u32, f64, f64)> = None;
    for q in 1..=q_max {
        if let Some(f) = energy::s_of_q(p, ctx.d_i, q, ctx.rate) {
            let val = j3(p, lambda2, ctx, q as f64, f);
            if best.map(|(_, _, b)| val < b).unwrap_or(true) {
                best = Some((q, f, val));
            }
        }
    }
    best
}

#[cfg(test)]
mod tests;
