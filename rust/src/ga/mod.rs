//! Genetic algorithm for the combinatorial subproblem **P3.1**
//! (paper §V-D, Algorithm 1): choose the channel-allocation matrix Rⁿ
//! (and with it the participation vector aⁿ via C2).
//!
//! Chromosome encoding: `alloc[c] ∈ {None, Some(client)}` per channel,
//! with the OFDMA constraints C1–C3 enforced *structurally* — a channel
//! carries at most one client, and a repair pass keeps each client on at
//! most one channel. Fitness is eq. (43): `(J0^max − J0)^ι`, with J0
//! supplied by the caller (the QCCF scheduler evaluates the inner
//! closed-form solver per candidate).

use std::collections::{HashMap, HashSet};

use crate::util::rng::Rng;
use crate::util::threadpool;

/// Per-run fitness memo: chromosome allocation → J0 (pure, so cached
/// scores are the evaluator's own bits — see [`GaParams::fitness_cache`]).
type FitnessCache = HashMap<Vec<Option<usize>>, f64>;

/// One channel-allocation chromosome.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Chromosome {
    /// `alloc[c]` = client on channel c (None = channel idle).
    pub alloc: Vec<Option<usize>>,
}

impl Chromosome {
    /// Number of channels this chromosome allocates over.
    pub fn num_channels(&self) -> usize {
        self.alloc.len()
    }

    /// Participation vector aⁿ implied by C2.
    pub fn participants(&self, num_clients: usize) -> Vec<bool> {
        let mut a = vec![false; num_clients];
        for &slot in &self.alloc {
            if let Some(i) = slot {
                a[i] = true;
            }
        }
        a
    }

    /// Channel assigned to a client, if any.
    pub fn channel_of(&self, client: usize) -> Option<usize> {
        self.alloc.iter().position(|&s| s == Some(client))
    }

    /// C1–C3 hold structurally except client-uniqueness; repair removes
    /// duplicate assignments (keeps the first occurrence).
    pub fn repair(&mut self, num_clients: usize) {
        let mut seen = vec![false; num_clients];
        for slot in self.alloc.iter_mut() {
            if let Some(i) = *slot {
                if i >= num_clients || seen[i] {
                    *slot = None;
                } else {
                    seen[i] = true;
                }
            }
        }
    }

    /// Constraint check (used by tests and debug assertions): every
    /// client on ≤ 1 channel, all indices in range.
    pub fn is_valid(&self, num_clients: usize) -> bool {
        let mut seen = vec![false; num_clients];
        for &slot in &self.alloc {
            if let Some(i) = slot {
                if i >= num_clients || seen[i] {
                    return false;
                }
                seen[i] = true;
            }
        }
        true
    }

    /// Random chromosome: each channel independently idle or carrying a
    /// random client, then repaired.
    pub fn random(num_channels: usize, num_clients: usize, rng: &mut Rng) -> Chromosome {
        let alloc = (0..num_channels)
            .map(|_| {
                if rng.chance(0.8) {
                    Some(rng.below(num_clients))
                } else {
                    None
                }
            })
            .collect();
        let mut ch = Chromosome { alloc };
        ch.repair(num_clients);
        ch
    }
}

/// GA hyperparameters (paper leaves them unspecified; defaults tuned for
/// U = C = 10 where the search space is ~10! permutation-like).
#[derive(Clone, Copy, Debug)]
pub struct GaParams {
    /// Population size per generation.
    pub population: usize,
    /// Generations to evolve.
    pub generations: usize,
    /// p^c — crossover probability.
    pub crossover_p: f64,
    /// p^m — per-gene mutation probability.
    pub mutation_p: f64,
    /// ι — fitness dispersion exponent (eq. (43)).
    pub iota: f64,
    /// Elites copied unchanged each generation.
    pub elites: usize,
    /// Worker threads for fitness evaluation (1 = serial). Population
    /// evals are independent and results keep population order, so any
    /// thread count yields an identical GA trajectory.
    pub threads: usize,
    /// Memoize fitness by chromosome across generations: elites and
    /// duplicate offspring are scored exactly once per run. J0 is a
    /// pure function of the chromosome, so the GA trajectory — and
    /// [`GaOutcome::history`] / [`GaOutcome::best`] — is identical with
    /// the cache on or off; only [`GaOutcome::evals`] (true evaluator
    /// invocations) drops. On by default.
    pub fitness_cache: bool,
}

impl Default for GaParams {
    fn default() -> Self {
        GaParams {
            population: 24,
            generations: 16,
            crossover_p: 0.85,
            mutation_p: 0.08,
            iota: 2.0,
            elites: 2,
            threads: 1,
            fitness_cache: true,
        }
    }
}

/// Result of a GA run.
#[derive(Clone, Debug)]
pub struct GaOutcome {
    /// Best chromosome found.
    pub best: Chromosome,
    /// Its objective value J0.
    pub best_j0: f64,
    /// Best J0 per generation (convergence diagnostics / ablations).
    pub history: Vec<f64>,
    /// Evaluator invocations performed. With
    /// [`GaParams::fitness_cache`] on (the default) this counts cache
    /// *misses* — distinct chromosomes actually scored; with it off,
    /// every population member every generation.
    pub evals: usize,
}

/// Score a population over the per-worker `states`
/// ([`threadpool::parallel_map_with`]); results stay in population
/// order, keeping the GA deterministic per seed for any worker count.
///
/// With the fitness cache enabled, chromosomes already scored this run
/// (elites, duplicate offspring, re-visited allocations) are served
/// from the cache and only the *new* ones are dispatched — collected in
/// deterministic first-occurrence order before any worker runs, so the
/// miss set (and `evals`) is identical for any worker count.
fn eval_population<S, F>(
    pop: &[Chromosome],
    states: &mut [S],
    cache: &mut Option<FitnessCache>,
    evals: &mut usize,
    eval: &F,
) -> Vec<f64>
where
    S: Send,
    F: Fn(&Chromosome, &mut S) -> f64 + Sync,
{
    let Some(cache) = cache.as_mut() else {
        *evals += pop.len();
        return threadpool::parallel_map_with(pop, states, |_, c, s| eval(c, s));
    };
    // Dispatch each distinct unseen chromosome exactly once.
    let mut pending: Vec<usize> = Vec::new();
    {
        let mut batch: HashSet<&[Option<usize>]> = HashSet::new();
        for (i, c) in pop.iter().enumerate() {
            if !cache.contains_key(&c.alloc) && batch.insert(c.alloc.as_slice()) {
                pending.push(i);
            }
        }
    }
    *evals += pending.len();
    let fresh: Vec<f64> =
        threadpool::parallel_map_with(&pending, states, |_, &i, s| eval(&pop[i], s));
    for (&i, &j0) in pending.iter().zip(&fresh) {
        cache.insert(pop[i].alloc.clone(), j0);
    }
    pop.iter().map(|c| cache[&c.alloc]).collect()
}

/// Run Algorithm 1. `eval` returns J0 (lower = better); infeasible
/// allocations should return `f64::INFINITY` (fitness 0 per the paper).
/// `eval` must be `Fn + Sync` so the fitness loop — the decision-stage
/// hot path — can fan out over [`GaParams::threads`] workers.
///
/// **Checkpoint contract:** every random choice the GA makes —
/// population init, selection, crossover, mutation — draws from the
/// caller's `rng` and nothing else, and the fitness cache lives only
/// for the duration of one call. Capturing that stream's
/// [`crate::util::rng::RngState`] therefore checkpoints the GA
/// completely: a restored stream replays the exact same search
/// trajectory (the `ckpt` subsystem relies on this for bit-identical
/// resume of the GA-based schedulers).
pub fn optimize<F>(
    num_channels: usize,
    num_clients: usize,
    params: &GaParams,
    rng: &mut Rng,
    eval: F,
) -> GaOutcome
where
    F: Fn(&Chromosome) -> f64 + Sync,
{
    optimize_with_seeds(num_channels, num_clients, params, rng, &[], eval)
}

/// [`optimize`] with caller-provided seed chromosomes injected into the
/// initial population (e.g. the greedy rate-maximizing allocation), so
/// the GA result is never worse than the best seed.
pub fn optimize_with_seeds<F>(
    num_channels: usize,
    num_clients: usize,
    params: &GaParams,
    rng: &mut Rng,
    seeds: &[Chromosome],
    eval: F,
) -> GaOutcome
where
    F: Fn(&Chromosome) -> f64 + Sync,
{
    let mut unit = vec![(); params.threads.max(1)];
    optimize_scratch(num_channels, num_clients, params, rng, seeds, &mut unit, |c, _| eval(c))
}

/// [`optimize_with_seeds`] with caller-provided per-worker scratch
/// states: `states.len()` is the fitness worker count (it takes the
/// place of [`GaParams::threads`]) and each worker hands its `&mut S`
/// to every evaluation it runs. The QCCF scheduler threads its
/// `sched::EvalScratch` buffers through here so the decision hot loop
/// performs zero per-evaluation heap allocation; any worker count
/// yields an identical GA trajectory.
pub fn optimize_scratch<S, F>(
    num_channels: usize,
    num_clients: usize,
    params: &GaParams,
    rng: &mut Rng,
    seeds: &[Chromosome],
    states: &mut [S],
    eval: F,
) -> GaOutcome
where
    S: Send,
    F: Fn(&Chromosome, &mut S) -> f64 + Sync,
{
    // A zero-size population cannot search (and `best_of` has no
    // candidate to return): yield the infeasible sentinel instead of
    // panicking partway through.
    if params.population == 0 {
        return GaOutcome {
            best: Chromosome { alloc: vec![None; num_channels] },
            best_j0: f64::INFINITY,
            history: vec![f64::INFINITY; params.generations],
            evals: 0,
        };
    }
    let mut evals = 0usize;
    let mut cache: Option<FitnessCache> =
        if params.fitness_cache { Some(HashMap::new()) } else { None };
    let mut pop: Vec<Chromosome> = (0..params.population)
        .map(|_| Chromosome::random(num_channels, num_clients, rng))
        .collect();
    // Seed one greedy identity-ish chromosome so the GA never starts
    // below the trivial "client i on channel i" allocation.
    if num_channels >= 1 {
        let alloc = (0..num_channels)
            .map(|c| if c < num_clients { Some(c) } else { None })
            .collect();
        pop[0] = Chromosome { alloc };
    }
    for (k, seed) in seeds.iter().enumerate() {
        if k + 1 < pop.len() {
            let mut s = seed.clone();
            s.repair(num_clients);
            pop[k + 1] = s;
        }
    }

    let mut score: Vec<f64> =
        eval_population(&pop, states, &mut cache, &mut evals, &eval);
    let mut history = Vec::with_capacity(params.generations);
    let (mut best, mut best_j0) = best_of(&pop, &score);

    for _gen in 0..params.generations {
        // Fitness eq. (43): (J0max − J0)^ι over the *finite* scores.
        let j0max = score.iter().cloned().filter(|x| x.is_finite()).fold(f64::NEG_INFINITY, f64::max);
        let fitness: Vec<f64> = score
            .iter()
            .map(|&j| {
                if !j.is_finite() {
                    0.0
                } else {
                    (j0max - j).max(0.0).powf(params.iota) + 1e-12
                }
            })
            .collect();

        let mut next: Vec<Chromosome> = Vec::with_capacity(params.population);
        // Elitism. total_cmp: a NaN score (degenerate fitness function)
        // must not panic the round; for the finite J0s the decision
        // pipeline produces the order is identical to partial_cmp.
        let mut order: Vec<usize> = (0..pop.len()).collect();
        order.sort_by(|&a, &b| score[a].total_cmp(&score[b]));
        for &i in order.iter().take(params.elites) {
            next.push(pop[i].clone());
        }
        // Offspring via roulette selection + crossover + mutation.
        while next.len() < params.population {
            let p1 = roulette(&fitness, rng);
            let p2 = roulette(&fitness, rng);
            let (mut c1, mut c2) = if rng.chance(params.crossover_p) {
                crossover(&pop[p1], &pop[p2], rng)
            } else {
                (pop[p1].clone(), pop[p2].clone())
            };
            mutate(&mut c1, num_clients, params.mutation_p, rng);
            mutate(&mut c2, num_clients, params.mutation_p, rng);
            c1.repair(num_clients);
            c2.repair(num_clients);
            next.push(c1);
            if next.len() < params.population {
                next.push(c2);
            }
        }
        pop = next;
        score = eval_population(&pop, states, &mut cache, &mut evals, &eval);
        let (gen_best, gen_j0) = best_of(&pop, &score);
        if gen_j0 < best_j0 {
            best = gen_best;
            best_j0 = gen_j0;
        }
        history.push(best_j0);
    }

    GaOutcome { best, best_j0, history, evals }
}

fn best_of(pop: &[Chromosome], score: &[f64]) -> (Chromosome, f64) {
    let mut bi = 0;
    for i in 1..pop.len() {
        if score[i] < score[bi] {
            bi = i;
        }
    }
    (pop[bi].clone(), score[bi])
}

/// Roulette-wheel selection over fitness weights.
fn roulette(fitness: &[f64], rng: &mut Rng) -> usize {
    let total: f64 = fitness.iter().sum();
    if total <= 0.0 {
        return rng.below(fitness.len());
    }
    let mut x = rng.uniform() * total;
    for (i, &f) in fitness.iter().enumerate() {
        x -= f;
        if x <= 0.0 {
            return i;
        }
    }
    fitness.len() - 1
}

/// Uniform crossover on the channel axis.
fn crossover(a: &Chromosome, b: &Chromosome, rng: &mut Rng) -> (Chromosome, Chromosome) {
    let n = a.alloc.len();
    let mut c1 = a.clone();
    let mut c2 = b.clone();
    for i in 0..n {
        if rng.chance(0.5) {
            std::mem::swap(&mut c1.alloc[i], &mut c2.alloc[i]);
        }
    }
    (c1, c2)
}

/// Per-gene mutation: reassign to a random client, clear, or swap two
/// channels.
fn mutate(c: &mut Chromosome, num_clients: usize, p_m: f64, rng: &mut Rng) {
    let n = c.alloc.len();
    for i in 0..n {
        if rng.chance(p_m) {
            match rng.below(3) {
                0 => c.alloc[i] = Some(rng.below(num_clients)),
                1 => c.alloc[i] = None,
                _ => {
                    let j = rng.below(n);
                    c.alloc.swap(i, j);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn repair_enforces_client_uniqueness() {
        let mut c = Chromosome { alloc: vec![Some(1), Some(1), Some(2), Some(9), None] };
        c.repair(5); // client 9 out of range
        assert!(c.is_valid(5));
        assert_eq!(c.alloc, vec![Some(1), None, Some(2), None, None]);
    }

    #[test]
    fn participants_follow_c2() {
        let c = Chromosome { alloc: vec![Some(0), None, Some(3)] };
        assert_eq!(c.participants(4), vec![true, false, false, true]);
        assert_eq!(c.channel_of(3), Some(2));
        assert_eq!(c.channel_of(1), None);
    }

    #[test]
    fn random_chromosomes_valid() {
        prop::check(
            "ga-random-valid",
            prop::iters(200),
            |rng| Chromosome::random(8, 5, rng),
            |c| {
                if c.is_valid(5) {
                    Ok(())
                } else {
                    Err(format!("{c:?}"))
                }
            },
        );
    }

    #[test]
    fn operators_preserve_constraints() {
        prop::check(
            "ga-ops-valid",
            prop::iters(200),
            |rng| {
                let a = Chromosome::random(10, 10, rng);
                let b = Chromosome::random(10, 10, rng);
                let (mut c1, mut c2) = crossover(&a, &b, rng);
                mutate(&mut c1, 10, 0.3, rng);
                mutate(&mut c2, 10, 0.3, rng);
                c1.repair(10);
                c2.repair(10);
                (c1, c2)
            },
            |(c1, c2)| {
                if c1.is_valid(10) && c2.is_valid(10) {
                    Ok(())
                } else {
                    Err("invalid child".into())
                }
            },
        );
    }

    #[test]
    fn finds_known_optimum_on_assignment_toy() {
        // J0 = Σ cost[c][client]; the optimum pairs client i with
        // channel i (diagonal cost 0, off-diagonal 1, unassigned 2).
        let eval = |c: &Chromosome| -> f64 {
            let mut j = 0.0;
            let mut assigned = vec![false; 6];
            for (ch, slot) in c.alloc.iter().enumerate() {
                if let Some(i) = slot {
                    j += if *i == ch { 0.0 } else { 1.0 };
                    assigned[*i] = true;
                }
            }
            j + assigned.iter().filter(|&&a| !a).count() as f64 * 2.0
        };
        let mut rng = Rng::seed_from(9);
        let out = optimize(6, 6, &GaParams::default(), &mut rng, eval);
        assert!(out.best_j0 <= 1.0, "best {}: {:?}", out.best_j0, out.best);
        assert!(out.best.is_valid(6));
        assert!(out.evals > 0);
    }

    #[test]
    fn history_is_monotone_nonincreasing() {
        let eval = |c: &Chromosome| -> f64 {
            c.alloc.iter().filter(|s| s.is_none()).count() as f64
        };
        let mut rng = Rng::seed_from(11);
        let out = optimize(8, 8, &GaParams::default(), &mut rng, eval);
        for w in out.history.windows(2) {
            assert!(w[1] <= w[0]);
        }
    }

    #[test]
    fn infeasible_everywhere_still_returns() {
        let mut rng = Rng::seed_from(13);
        let out = optimize(4, 4, &GaParams::default(), &mut rng, |_| f64::INFINITY);
        assert!(out.best_j0.is_infinite());
    }

    #[test]
    fn deterministic_given_seed() {
        let eval = |c: &Chromosome| -> f64 {
            c.alloc.iter().flatten().map(|&i| i as f64).sum()
        };
        let mut r1 = Rng::seed_from(21);
        let mut r2 = Rng::seed_from(21);
        let o1 = optimize(6, 6, &GaParams::default(), &mut r1, eval);
        let o2 = optimize(6, 6, &GaParams::default(), &mut r2, eval);
        assert_eq!(o1.best, o2.best);
        assert_eq!(o1.best_j0, o2.best_j0);
    }

    #[test]
    fn parallel_fitness_matches_serial() {
        // The fan-out only reorders *when* evals run, never their
        // inputs or how results are consumed — trajectories must match.
        let eval = |c: &Chromosome| -> f64 {
            c.alloc.iter().flatten().map(|&i| ((i * i) % 7) as f64).sum()
        };
        let serial = GaParams::default();
        let par = GaParams { threads: 8, ..GaParams::default() };
        let o1 = optimize(8, 8, &serial, &mut Rng::seed_from(31), eval);
        let o8 = optimize(8, 8, &par, &mut Rng::seed_from(31), eval);
        assert_eq!(o1.best, o8.best);
        assert_eq!(o1.best_j0, o8.best_j0);
        assert_eq!(o1.history, o8.history);
        assert_eq!(o1.evals, o8.evals);
    }

    #[test]
    fn fitness_cache_skips_elites_without_changing_trajectory() {
        // Elites are copied unchanged into every next generation; with
        // the fitness cache they must never be re-scored — `evals`
        // drops below the uncached population × (generations + 1)
        // while `history` (and the winner) stays identical, because a
        // cache hit returns the very same J0 the evaluator produced.
        use std::sync::atomic::{AtomicUsize, Ordering};
        let eval = |c: &Chromosome| -> f64 {
            c.alloc.iter().flatten().map(|&i| ((i * i + 3) % 11) as f64).sum()
        };
        let on = GaParams::default();
        let off = GaParams { fitness_cache: false, ..GaParams::default() };
        let calls_off = AtomicUsize::new(0);
        let o_off = optimize(8, 8, &off, &mut Rng::seed_from(41), |c| {
            calls_off.fetch_add(1, Ordering::Relaxed);
            eval(c)
        });
        let calls_on = AtomicUsize::new(0);
        let o_on = optimize(8, 8, &on, &mut Rng::seed_from(41), |c| {
            calls_on.fetch_add(1, Ordering::Relaxed);
            eval(c)
        });
        assert_eq!(o_on.history, o_off.history, "cache changed the GA trajectory");
        assert_eq!(o_on.best, o_off.best);
        assert_eq!(o_on.best_j0.to_bits(), o_off.best_j0.to_bits());
        let budget = off.population * (off.generations + 1);
        assert_eq!(o_off.evals, budget);
        assert_eq!(calls_off.load(Ordering::Relaxed), budget);
        // ≥ elites × generations guaranteed duplicates are skipped.
        assert!(
            o_on.evals + on.elites * on.generations <= budget,
            "evals {} did not drop below {budget}",
            o_on.evals
        );
        assert_eq!(calls_on.load(Ordering::Relaxed), o_on.evals, "evals must count misses");
    }

    #[test]
    fn duplicate_chromosomes_scored_once_per_population() {
        // Two identical chromosomes in the *same* population are one
        // cache miss — the batch dedup, not just the cross-generation
        // cache. A 1-channel space over 1 client has 2 possible
        // chromosomes, so every generation is saturated with dupes.
        use std::sync::atomic::{AtomicUsize, Ordering};
        let calls = AtomicUsize::new(0);
        let out = optimize(1, 1, &GaParams::default(), &mut Rng::seed_from(3), |c| {
            calls.fetch_add(1, Ordering::Relaxed);
            if c.alloc[0].is_some() {
                1.0
            } else {
                2.0
            }
        });
        assert!(calls.load(Ordering::Relaxed) <= 2, "{} evaluator calls", calls.load(Ordering::Relaxed));
        assert_eq!(out.evals, calls.load(Ordering::Relaxed));
        assert_eq!(out.best_j0, 1.0);
    }

    #[test]
    fn scratch_states_thread_through_workers() {
        // optimize_scratch hands each worker exactly one reusable
        // state; the per-worker tallies must sum to the evaluator
        // invocation count (= evals with the cache on).
        let mut states = vec![0usize; 3];
        let params = GaParams { threads: 3, ..GaParams::default() };
        let out = optimize_scratch(
            6,
            6,
            &params,
            &mut Rng::seed_from(5),
            &[],
            &mut states,
            |c, tally: &mut usize| {
                *tally += 1;
                c.alloc.iter().filter(|s| s.is_none()).count() as f64
            },
        );
        assert!(out.evals > 0);
        assert_eq!(states.iter().sum::<usize>(), out.evals);
    }

    #[test]
    fn zero_population_returns_infeasible_sentinel() {
        let params = GaParams { population: 0, generations: 3, ..GaParams::default() };
        let out = optimize(4, 4, &params, &mut Rng::seed_from(1), |_| 0.0);
        assert!(out.best_j0.is_infinite());
        assert_eq!(out.evals, 0);
        assert_eq!(out.history.len(), 3);
        assert!(out.best.alloc.iter().all(|s| s.is_none()));
    }

    #[test]
    fn nan_fitness_does_not_panic_elitism() {
        // A degenerate evaluator returning NaN must not abort the
        // round (the elitism sort uses total_cmp).
        let out = optimize(4, 4, &GaParams::default(), &mut Rng::seed_from(17), |c| {
            if c.alloc.iter().flatten().count() % 2 == 0 {
                f64::NAN
            } else {
                1.0
            }
        });
        assert!(out.evals > 0);
    }
}
