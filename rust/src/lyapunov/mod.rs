//! Lyapunov optimization (paper §V-A): virtual queues λ1/λ2 for the
//! long-term constraints C6/C7 (eqs. (23)–(24)) and the per-round
//! drift-plus-penalty objective Jⁿ (eq. (27)).

use crate::config::SystemParams;

/// The two virtual queues. Mean-rate stability of these is equivalent to
/// satisfying C6 and C7 (paper §V-A).
#[derive(Clone, Debug)]
pub struct Queues {
    /// λ1 — data-property queue (C6).
    pub lambda1: f64,
    /// λ2 — quantization-error queue (C7).
    pub lambda2: f64,
    history: Vec<(f64, f64)>,
}

impl Queues {
    /// Both queues at zero (callers may warm-start the fields).
    pub fn new() -> Queues {
        Queues { lambda1: 0.0, lambda2: 0.0, history: vec![(0.0, 0.0)] }
    }

    /// Rebuild queues from checkpointed state: the current backlogs
    /// plus the full post-update history (whose *length* feeds the
    /// mean-rate-stability diagnostic, so a resumed run must not
    /// restart it at 1). An empty history — which [`Queues::new`]
    /// never produces — falls back to the fresh-queue `[(0, 0)]`.
    pub fn restore(lambda1: f64, lambda2: f64, history: Vec<(f64, f64)>) -> Queues {
        let history = if history.is_empty() { vec![(0.0, 0.0)] } else { history };
        Queues { lambda1, lambda2, history }
    }

    /// Eqs. (23)–(24): `λ ← max(λ + arrival − ε, 0)` with the realized
    /// per-round C6/C7 terms as arrivals.
    pub fn update(&mut self, p: &SystemParams, data_term: f64, quant_term: f64) {
        self.lambda1 = (self.lambda1 + data_term - p.eps1).max(0.0);
        self.lambda2 = (self.lambda2 + quant_term - p.eps2).max(0.0);
        self.history.push((self.lambda1, self.lambda2));
    }

    /// Mean-rate stability diagnostic: λ^n / n (should tend to 0).
    pub fn mean_rates(&self) -> (f64, f64) {
        let n = self.history.len().max(1) as f64;
        (self.lambda1 / n, self.lambda2 / n)
    }

    /// (λ1, λ2) after every update, starting at (0, 0).
    pub fn history(&self) -> &[(f64, f64)] {
        &self.history
    }
}

impl Default for Queues {
    fn default() -> Self {
        Self::new()
    }
}

/// The per-round objective Jⁿ (eq. (27)) given the realized decision:
/// `(λ1−ε1)·data + (λ2−ε2)·quant + V·Σ a_i (E^cmp + E^com)`.
pub fn objective_j(
    p: &SystemParams,
    queues: &Queues,
    data_term: f64,
    quant_term: f64,
    total_energy: f64,
) -> f64 {
    (queues.lambda1 - p.eps1) * data_term
        + (queues.lambda2 - p.eps2) * quant_term
        + p.v * total_energy
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> SystemParams {
        SystemParams::femnist_small()
    }

    #[test]
    fn queues_start_empty() {
        let q = Queues::new();
        assert_eq!((q.lambda1, q.lambda2), (0.0, 0.0));
    }

    #[test]
    fn update_follows_eq23_eq24() {
        let params = p();
        let mut q = Queues::new();
        q.update(&params, params.eps1 + 3.0, params.eps2 + 0.5);
        assert!((q.lambda1 - 3.0).abs() < 1e-12);
        assert!((q.lambda2 - 0.5).abs() < 1e-12);
        // Under-budget arrivals drain, floored at zero.
        q.update(&params, 0.0, 0.0);
        assert!((q.lambda1 - (3.0 - params.eps1).max(0.0)).abs() < 1e-12);
        assert!((q.lambda2 - (0.5 - params.eps2).max(0.0)).abs() < 1e-12);
    }

    #[test]
    fn queues_never_negative() {
        let params = p();
        let mut q = Queues::new();
        for _ in 0..50 {
            q.update(&params, 0.0, 0.0);
            assert!(q.lambda1 >= 0.0 && q.lambda2 >= 0.0);
        }
    }

    #[test]
    fn stable_arrivals_keep_queue_bounded() {
        // Arrivals exactly at ε keep λ at 0; slightly below keep it at 0.
        let params = p();
        let mut q = Queues::new();
        for _ in 0..1000 {
            q.update(&params, params.eps1 * 0.9, params.eps2 * 0.9);
        }
        assert_eq!(q.lambda1, 0.0);
        assert_eq!(q.lambda2, 0.0);
        let (r1, r2) = q.mean_rates();
        assert_eq!((r1, r2), (0.0, 0.0));
    }

    #[test]
    fn overloaded_queue_grows_linearly() {
        let params = p();
        let mut q = Queues::new();
        for _ in 0..100 {
            q.update(&params, params.eps1 + 1.0, params.eps2);
        }
        assert!((q.lambda1 - 100.0).abs() < 1e-9);
    }

    #[test]
    fn objective_weights_terms() {
        let params = p();
        let mut q = Queues::new();
        q.update(&params, params.eps1 + 10.0, params.eps2 + 1.0);
        let j = objective_j(&params, &q, 2.0, 0.3, 0.05);
        let want = (q.lambda1 - params.eps1) * 2.0
            + (q.lambda2 - params.eps2) * 0.3
            + params.v * 0.05;
        assert!((j - want).abs() < 1e-12);
    }
}
