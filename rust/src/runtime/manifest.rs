//! Artifact manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`): per-profile model metadata + HLO file map.

use std::path::{Path, PathBuf};

use crate::util::json::{parse, Json};

/// One profile's stanza from the manifest.
#[derive(Clone, Debug)]
pub struct ProfileInfo {
    /// Profile name (manifest key).
    pub name: String,
    /// Z — flat parameter count.
    pub z: usize,
    /// τ — local updates per round.
    pub tau: usize,
    /// τ^e — local epochs.
    pub tau_e: usize,
    /// B — local mini-batch size.
    pub batch: usize,
    /// Eval chunk size.
    pub eval_batch: usize,
    /// (H, W, C).
    pub image: (usize, usize, usize),
    /// Number of label classes.
    pub classes: usize,
    /// Default learning rate η the model was tuned with.
    pub lr: f64,
    /// Artifact name → HLO text path.
    pub files: Vec<(String, PathBuf)>,
}

impl ProfileInfo {
    /// Floats per image (H·W·C).
    pub fn pix(&self) -> usize {
        self.image.0 * self.image.1 * self.image.2
    }

    /// Path of the named HLO artifact, if present.
    pub fn file(&self, name: &str) -> Option<&Path> {
        self.files.iter().find(|(n, _)| n == name).map(|(_, p)| p.as_path())
    }
}

/// Parse one profile from the manifest at `dir/manifest.json`.
pub fn load_profile(dir: &Path, profile: &str) -> Result<ProfileInfo, String> {
    let text = std::fs::read_to_string(dir.join("manifest.json"))
        .map_err(|e| format!("read manifest: {e} (run `make artifacts`)"))?;
    let root = parse(&text)?;
    let stanza = root
        .get(profile)
        .ok_or_else(|| format!("profile `{profile}` not in manifest (run `make artifacts`)"))?;
    let us = |k: &str| -> Result<usize, String> {
        stanza
            .get(k)
            .and_then(Json::as_usize)
            .ok_or_else(|| format!("manifest missing `{k}`"))
    };
    let image = stanza
        .get("image")
        .and_then(Json::as_arr)
        .filter(|a| a.len() == 3)
        .ok_or("manifest missing image dims")?;
    let arts = stanza
        .get("artifacts")
        .and_then(Json::as_obj)
        .ok_or("manifest missing artifacts")?;
    let mut files = Vec::new();
    for (name, art) in arts {
        let file = art
            .get("file")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("artifact `{name}` missing file"))?;
        files.push((name.clone(), dir.join(profile).join(file)));
    }
    Ok(ProfileInfo {
        name: profile.to_string(),
        z: us("z")?,
        tau: us("tau")?,
        tau_e: us("tau_e")?,
        batch: us("batch")?,
        eval_batch: us("eval_batch")?,
        image: (
            image[0].as_usize().unwrap_or(0),
            image[1].as_usize().unwrap_or(0),
            image[2].as_usize().unwrap_or(0),
        ),
        classes: us("classes")?,
        lr: stanza.get("lr").and_then(Json::as_f64).unwrap_or(0.05),
        files,
    })
}

/// Default artifacts directory: `$QCCF_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("QCCF_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_tiny_profile() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let info = load_profile(&artifacts_dir(), "tiny").unwrap();
        assert_eq!(info.z, 1242);
        assert_eq!(info.tau, 6);
        assert_eq!(info.image, (8, 8, 1));
        assert_eq!(info.classes, 10);
        for name in ["init", "train_step", "eval_step", "quantize"] {
            let f = info.file(name).expect(name);
            assert!(f.exists(), "{f:?}");
        }
    }

    #[test]
    fn missing_profile_is_error() {
        if !have_artifacts() {
            return;
        }
        assert!(load_profile(&artifacts_dir(), "nope").is_err());
    }
}
